//! Dot-product design-space exploration: granularity × routing × grid
//! size, beyond the two slices the paper plots (Figs 5–6) — including the
//! direct-to-root pattern §5 predicts will bottleneck.
//!
//!     cargo run --release --example dot_scaling

use wormsim::arch::DataFormat;
use wormsim::engine::NativeEngine;
use wormsim::kernels::reduction::{run_dot, DotConfig, DotMethod};
use wormsim::noc::RoutePattern;
use wormsim::solver::{dist_random, Problem};
use wormsim::timing::cost::CostModel;
use wormsim::util::stats::fmt_ns;
use wormsim::util::table::Table;

fn main() -> anyhow::Result<()> {
    let engine = NativeEngine::new();
    let cost = CostModel::default();
    let tiles = 16;

    let mut table = Table::new(
        "Dot product: granularity x routing across grid sizes (SFPU FP32, 16 tiles/core)",
        &["grid", "m1+naive", "m1+center", "m2+naive", "m2+center", "m2+direct"],
    );

    for (r, c) in [(2usize, 2usize), (4, 4), (8, 7)] {
        let p = Problem::new(r, c, tiles, DataFormat::Fp32);
        let a = dist_random(&p, 1);
        let b = dist_random(&p, 2);
        let mut cells = vec![format!("{r}x{c}")];
        let mut reference = None;
        for (method, pattern) in [
            (DotMethod::ReduceThenSend, RoutePattern::Naive),
            (DotMethod::ReduceThenSend, RoutePattern::Center),
            (DotMethod::SendTiles, RoutePattern::Naive),
            (DotMethod::SendTiles, RoutePattern::Center),
            (DotMethod::SendTiles, RoutePattern::Direct),
        ] {
            let cfg = DotConfig::paper_section5(method, pattern, tiles);
            let out = run_dot(r, c, &cfg, &a, &b, &engine, &cost)?;
            // All variants must agree on the value.
            let v = *reference.get_or_insert(out.value);
            assert!(
                (out.value - v).abs() <= 1e-3 * v.abs().max(1.0),
                "variant value mismatch"
            );
            cells.push(fmt_ns(out.total_ns));
        }
        table.row(cells);
    }
    println!("{}", table.render());
    println!("expected: direct-to-root degrades at scale (root serializes all merges, §5);");
    println!("center helps most when the network dominates (few tiles/core).");
    Ok(())
}
