//! END-TO-END driver: the paper's headline workload, full scale.
//!
//! Solves the 7-point-Laplacian Poisson problem on the Table-3 grid
//! (512×112×64 ≈ 3.67M unknowns, 8×7 Tensix cores, 64 tiles/core) with
//! both PCG variants, logs the residual curve, reports the per-iteration
//! device time and component breakdown, and compares against the H100
//! baseline model — i.e. it regenerates the paper's bottom-line result
//! (Table 3 + Fig 13) as one program exercising the full public API.
//!
//!     cargo run --release --example poisson_pcg [-- --small] [-- --engine pjrt]
//!                                               [-- --dies N]
//!
//! `--small` runs a 4×4-core/16-tile configuration (fast, used in CI);
//! `--engine pjrt` routes all per-core math through the AOT JAX/Pallas
//! artifacts (requires `make artifacts`; implies `--small` economy sizes
//! are recommended). `--dies N` appends a mesh run: the same element
//! count strong-scaled across N x-stacked dies (each die a full sub-grid
//! with 1/N of the z-tiles), with the Ethernet seam charged per §8.

use wormsim::arch::DataFormat;
use wormsim::baseline::H100Model;
use wormsim::engine::{make_engine, EngineKind};
use wormsim::kernels::DotMethod;
use wormsim::noc::RoutePattern;
use wormsim::profiler::Profiler;
use wormsim::solver::{self, PcgOptions, PcgVariant, Problem};
use wormsim::timing::cost::CostModel;
use wormsim::util::stats::fmt_ns;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let small = args.iter().any(|a| a == "--small");
    let dies: usize = match args.iter().position(|a| a == "--dies") {
        Some(idx) => args
            .get(idx + 1)
            .ok_or_else(|| anyhow::anyhow!("--dies expects a value"))?
            .parse()
            .map_err(|e| anyhow::anyhow!("--dies: {e}"))?,
        None => 0,
    };
    // Engine selection goes through the single `EngineKind: FromStr`
    // impl — unknown names are an error, not a silent native fallback.
    let engine_kind: EngineKind = match args.iter().position(|a| a == "--engine") {
        Some(idx) => args
            .get(idx + 1)
            .ok_or_else(|| anyhow::anyhow!("--engine expects a value"))?
            .parse()
            .map_err(anyhow::Error::msg)?,
        None => EngineKind::Native,
    };
    let (grid_rows, grid_cols, tiles, iters) = if small { (4, 4, 16, 30) } else { (8, 7, 64, 60) };

    let engine = make_engine(engine_kind, std::path::Path::new("artifacts"))?;
    let cost = CostModel::default();
    println!("=== poisson_pcg end-to-end driver (engine: {}) ===\n", engine.name());

    let mut results: Vec<(String, f64)> = Vec::new();
    for variant in [PcgVariant::FusedBf16, PcgVariant::SplitFp32] {
        let problem = Problem::new(grid_rows, grid_cols, tiles, variant.df());
        let (nx, ny, nz) = problem.dims();
        println!(
            "--- {} on {nx}x{ny}x{nz} ({} unknowns, {grid_rows}x{grid_cols} cores, {tiles} tiles/core)",
            variant.label(),
            problem.elems()
        );
        let grid = problem.make_grid()?;
        let b = solver::dist_random(&problem, 20260710);
        let mut opts = PcgOptions::new(variant);
        opts.max_iters = iters;
        // BF16 stalls above FP32 accuracy — absolute thresholds per §3.3.
        opts.tol_abs = match variant {
            PcgVariant::FusedBf16 => 3.0,
            PcgVariant::SplitFp32 => 1e-2,
        };
        opts.dot_method = DotMethod::ReduceThenSend;
        opts.dot_pattern = RoutePattern::Naive;
        let mut prof = Profiler::new();
        let t0 = std::time::Instant::now();
        let res = solver::solve(&grid, &problem, &b, engine.as_ref(), &cost, &opts, &mut prof)?;
        let wall = t0.elapsed();

        // Residual curve (log every few iterations).
        println!("residual curve (absolute ||r||2, §3.3):");
        for (i, r) in res.residual_history.iter().enumerate() {
            if i % 5 == 0 || i + 1 == res.residual_history.len() {
                println!("  iter {:>3}  |r| = {r:.4e}", i + 1);
            }
        }
        println!(
            "{} after {} iterations; simulated {} / iter ({} total); host wall {:.1?}",
            if res.converged { "converged" } else { "stopped" },
            res.iters,
            fmt_ns(res.per_iter_ns),
            fmt_ns(res.total_ns),
            wall
        );
        println!("{}", res.breakdown.render("component breakdown"));
        println!(
            "launch accounting (scheduler-derived): {} enqueues ({:.2}/iter), device gaps {}",
            res.launch.launches,
            res.launches_per_iter(),
            fmt_ns(res.launch.gap_ns)
        );
        results.push((variant.label().to_string(), res.per_iter_ns));
    }

    // H100 baseline on the same problem size.
    let n = 64 * grid_rows * 16 * grid_cols * tiles;
    let h100 = H100Model::default().cg_iteration(n);
    results.push(("H100 (analytic baseline)".into(), h100.total_ns));

    println!("=== per-iteration comparison (paper Table 3 shape) ===");
    for (name, ns) in &results {
        println!("  {name:<32} {}", fmt_ns(*ns));
    }
    let h = results.last().unwrap().1;
    println!(
        "  slowdown vs H100: BF16 {:.1}x, FP32 {:.1}x (paper: ~4.3x and ~8.8x at full scale)",
        results[0].1 / h,
        results[1].1 / h
    );

    // Optional §8 extension: the same element count strong-scaled across
    // an N-die mesh (each die the full sub-grid, 1/N of the z-tiles).
    if dies > 0 {
        use wormsim::device::{DeviceMesh, EthLink, MeshTopology};
        use wormsim::engine::StencilCoeffs;
        use wormsim::kernels::stencil::{StencilConfig, StencilVariant};
        use wormsim::solver::Operator;
        if tiles % dies != 0 {
            anyhow::bail!("--dies {dies} must divide {tiles} tiles/core");
        }
        let mesh = DeviceMesh::new(
            dies,
            grid_rows,
            grid_cols,
            MeshTopology::Line,
            EthLink::for_dies(dies),
        )
        .map_err(anyhow::Error::msg)?;
        let mesh_tiles = tiles / dies;
        let cfg = StencilConfig {
            df: DataFormat::Bf16,
            unit: wormsim::arch::ComputeUnit::Fpu,
            tiles_per_core: mesh_tiles,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let bm = solver::mesh_dist_random(&mesh, mesh_tiles, DataFormat::Bf16, 20260710);
        let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
        opts.max_iters = iters.min(10);
        opts.tol_abs = 0.0;
        let mut prof = Profiler::disabled();
        let res = solver::solve_pcg_mesh(
            &mesh,
            &bm,
            &Operator::Stencil(cfg),
            engine.as_ref(),
            &cost,
            &opts.into(),
            &mut prof,
        )?;
        println!();
        println!(
            "=== mesh extension: {} unknowns on {dies} x {grid_rows}x{grid_cols}-core dies, {mesh_tiles} tiles/core ===",
            mesh.n_cores() * mesh_tiles * 1024
        );
        println!(
            "  {} / iter ({:.2}x vs one die); compute {}, NoC {}, Ethernet {}, dispatch {}",
            fmt_ns(res.per_iter_ns),
            results[0].1 / res.per_iter_ns,
            fmt_ns(res.phases.compute_ns),
            fmt_ns(res.phases.noc_ns),
            fmt_ns(res.phases.ether_ns),
            fmt_ns(res.phases.dispatch_ns)
        );
    }
    Ok(())
}
