//! Single-core roofline exploration (the Fig-3 model, §4): element-wise
//! throughput for each compute unit and data format, against the
//! packer/unpacker bandwidth ceiling.
//!
//!     cargo run --release --example roofline

use wormsim::arch::{ComputeUnit, DataFormat};
use wormsim::kernels::eltwise::eltwise_stream_timing;
use wormsim::timing::cost::CostModel;
use wormsim::util::table::Table;

fn main() {
    let cost = CostModel::default();
    let mut table = Table::new(
        "Wormhole single-core eltwise roofline (256 tiles/core)",
        &["unit", "format", "AI (FLOP/B)", "GFLOP/s", "% of roofline", "cycles/tile"],
    );
    for (unit, df) in [
        (ComputeUnit::Fpu, DataFormat::Bf16),
        (ComputeUnit::Sfpu, DataFormat::Bf16),
        (ComputeUnit::Sfpu, DataFormat::Fp32),
    ] {
        let t = eltwise_stream_timing(&cost, unit, df, 256);
        let bound = (cost.sram_bw_gbs() * t.ai).min(cost.peak_gflops(unit, df));
        table.row(vec![
            unit.to_string(),
            df.to_string(),
            format!("{:.4}", t.ai),
            format!("{:.2}", t.gflops),
            format!("{:.1}%", 100.0 * t.gflops / bound),
            format!("{}", t.cycles_per_tile),
        ]);
    }
    println!("{}", table.render());
    println!(
        "SRAM bandwidth ceiling: {:.0} GB/s (packer/unpacker, 64 B/clk); FPU peak {:.0} GFLOP/s; \
         SFPU peak {:.0} (BF16) / {:.0} (FP32) GFLOP/s",
        cost.sram_bw_gbs(),
        cost.peak_gflops(ComputeUnit::Fpu, DataFormat::Bf16),
        cost.peak_gflops(ComputeUnit::Sfpu, DataFormat::Bf16),
        cost.peak_gflops(ComputeUnit::Sfpu, DataFormat::Fp32),
    );
    println!("The paper's observation (§4): use the FPU and minimal precision whenever possible.");
}
