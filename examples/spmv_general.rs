//! General sparse PCG driver: load or generate an SPD matrix, partition
//! it over the simulated Tensix grid, run SpMV + sparse PCG, and print
//! the SELL occupancy, NoC gather plan, traffic, residual history, and
//! timing breakdown. With no `--mtx` argument it also performs the
//! Laplacian round trip: the generated 3D-Laplacian matrix through the
//! sparse path must reproduce the matrix-free stencil PCG trajectory
//! bit-for-bit.
//!
//!     cargo run --release --example spmv_general [-- --mtx FILE.mtx]
//!         [-- --n 16384] [-- --nnz 27] [-- --stream]

use wormsim::arch::DataFormat;
use wormsim::kernels::spmv::{SpmvConfig, SpmvMode, SpmvOperator};
use wormsim::profiler::Profiler;
use wormsim::solver::{self, Operator, PcgOptions, PcgVariant, Problem};
use wormsim::sparse::{circulant_spd, laplacian_3d, read_mtx, RowPartition};
use wormsim::engine::NativeEngine;
use wormsim::timing::cost::CostModel;
use wormsim::util::prng::Rng;
use wormsim::util::stats::fmt_ns;

fn flag_value(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = flag_value(&args, "--n").map_or(Ok(16 * 1024), |v| v.parse())?;
    let nnz: usize = flag_value(&args, "--nnz").map_or(Ok(27), |v| v.parse())?;
    let mode = if args.iter().any(|a| a == "--stream") {
        SpmvMode::DramStream
    } else {
        SpmvMode::SramResident
    };

    let (a, source) = match flag_value(&args, "--mtx") {
        Some(path) => {
            let m = read_mtx(std::path::Path::new(&path))?;
            (m, path)
        }
        None => (
            circulant_spd(n, nnz, 20260731)?,
            format!("circulant_spd(n={n}, nnz/row={nnz})"),
        ),
    };
    println!("=== spmv_general: {source} ===");
    println!(
        "matrix: {}x{}, {} nnz ({:.1}/row, max {}), symmetric: {}",
        a.n_rows,
        a.n_cols,
        a.nnz(),
        a.avg_row_nnz(),
        a.max_row_nnz(),
        a.is_symmetric(1e-5)
    );
    if !a.is_symmetric(1e-5) {
        anyhow::bail!("PCG needs a symmetric (SPD) matrix");
    }

    // ---- partition + operator ------------------------------------------
    let (grid_rows, grid_cols) = (2usize, 2usize);
    let part = RowPartition::row_block(grid_rows, grid_cols, a.n_rows)?;
    let op = SpmvOperator::new(&a, part.clone(), SpmvConfig::new(DataFormat::Fp32, mode))?;
    let stats = op.stats();
    println!(
        "partition: {grid_rows}x{grid_cols} cores, {} tiles/core | SELL-C-32: \
         {} slices, occupancy {:.1}% (padding overhead {:.3}x)",
        part.tiles_per_core,
        stats.n_slices,
        100.0 * stats.occupancy(),
        stats.overhead()
    );
    println!(
        "gather plan: {} remote x entries over {} NoC messages ({} B); {} local references",
        op.gather.remote_entries,
        op.gather.messages(),
        op.gather.bytes(DataFormat::Fp32),
        op.gather.local_references
    );

    // ---- one SpMV -------------------------------------------------------
    let grid = wormsim::device::TensixGrid::new(grid_rows, grid_cols)?;
    let engine = NativeEngine::new();
    let cost = CostModel::default();
    let mut rng = Rng::new(1);
    let xg: Vec<f32> = (0..a.n_rows).map(|_| rng.next_f32() - 0.5).collect();
    let x = part.dist_from_global(DataFormat::Fp32, &xg);
    let (_, t) = op.apply(&grid, &x, &engine, &cost)?;
    println!("\none SpMV ({mode:?}):");
    println!(
        "  total {}  = gather wait {} + dram {} + local {}",
        fmt_ns(t.total_ns),
        fmt_ns(t.gather_ns),
        fmt_ns(t.dram_ns),
        fmt_ns(t.compute_ns)
    );
    println!(
        "  traffic {:.1} B/row ({} B total), effective {:.2} GB/s",
        t.traffic.per_row(a.n_rows),
        t.traffic.total(),
        t.achieved_gbs()
    );
    // The lowered program carries the same single traffic number plus the
    // SELL occupancy stats as compile-time args.
    let program = op.lower(&cost);
    assert_eq!(program.footprint.traffic_bytes, t.traffic.total());
    println!(
        "  program '{}': {} kernels, footprint {{ tiles/core: {}, sram: {} B, traffic: {} B }}",
        program.name,
        program.kernels.len(),
        program.footprint.tiles_per_core,
        program.footprint.sram_bytes,
        program.footprint.traffic_bytes
    );

    // ---- sparse PCG -----------------------------------------------------
    let bg: Vec<f32> = (0..a.n_rows).map(|_| rng.next_f32() - 0.5).collect();
    let b = part.dist_from_global(DataFormat::Fp32, &bg);
    let mut opts = PcgOptions::new(PcgVariant::SplitFp32);
    opts.max_iters = 200;
    opts.tol_abs = 1e-4;
    let mut prof = Profiler::disabled();
    let res = solver::solve_operator(&grid, &b, &Operator::Sparse(&op), &engine, &cost, &opts, &mut prof)?;
    println!("\nsparse PCG ({:?}):", op.cfg.mode);
    for (i, r) in res.residual_history.iter().enumerate() {
        if i % 10 == 0 || i + 1 == res.residual_history.len() {
            println!("  iter {:>3}  |r| = {r:.4e}", i + 1);
        }
    }
    println!(
        "{} after {} iterations; simulated {} / iter ({} total); {} launches",
        if res.converged { "converged" } else { "stopped" },
        res.iters,
        fmt_ns(res.per_iter_ns),
        fmt_ns(res.total_ns),
        res.launch.launches
    );
    println!("{}", res.breakdown.render("component breakdown"));

    // ---- Laplacian round trip (generated matrix vs stencil path) --------
    if flag_value(&args, "--mtx").is_none() {
        println!("=== Laplacian operator round trip ===");
        let p = Problem::new(2, 2, 4, DataFormat::Fp32);
        let (nx, ny, nz) = p.dims();
        let lap = laplacian_3d(nx, ny, nz);
        let lpart = RowPartition::stencil_aligned(2, 2, nz)?;
        let lop = SpmvOperator::new(&lap, lpart, SpmvConfig::new(DataFormat::Fp32, SpmvMode::SramResident))?;
        let lb = solver::dist_random(&p, 7);
        let mut lopts = PcgOptions::new(PcgVariant::SplitFp32);
        lopts.max_iters = 400;
        lopts.tol_abs = 1e-3;
        let lgrid = p.make_grid()?;
        let stencil = solver::solve(&lgrid, &p, &lb, &engine, &cost, &lopts, &mut prof)?;
        let sparse = solver::solve_operator(&lgrid, &lb, &Operator::Sparse(&lop), &engine, &cost, &lopts, &mut prof)?;
        let identical = stencil.residual_history == sparse.residual_history
            && stencil.iters == sparse.iters;
        println!(
            "stencil: {} iters | sparse: {} iters | residual trajectories bit-identical: {identical}",
            stencil.iters, sparse.iters
        );
        println!(
            "per-iteration SpMV: stencil {} vs sparse {} — the price of a general matrix",
            fmt_ns(stencil.breakdown.per_iter("spmv")),
            fmt_ns(sparse.breakdown.per_iter("spmv"))
        );
        assert!(identical, "Laplacian round trip must match the stencil trajectory");
    }
    Ok(())
}
