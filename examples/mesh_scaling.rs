//! Strong-scaling driver for the N-die mesh solver (§8 multi-device
//! scaling, generalized past the n300).
//!
//! Fixes the element count of the §7 Poisson problem and sweeps the die
//! count: every die contributes a full sub-grid of cores and holds 1/N of
//! the per-core z-tiles, so per-core work shrinks with N while the
//! x-stacked seam halos and the scalar all-reduces move onto Ethernet.
//! For each N the driver reports time/iteration, the parallel efficiency
//! vs one die, the compute/NoC/Ethernet/dispatch transport split, and the
//! peak per-link utilization under the contended-link model — the table
//! the paper's future-work section asks for.
//!
//!     cargo run --release --example mesh_scaling [-- --small] [-- --overlap serial|pipelined]
//!                                                [-- --schedule classic|prefetch|sstep:<s>]
//!                                                [-- --topology line|ring|torus:RxC|torus]
//!
//! `--small` shrinks the per-die sub-grid and the sweep (CI-friendly);
//! `--overlap pipelined` runs the interior/boundary split schedule that
//! hides the Ethernet seam under interior compute (values identical,
//! clock faster); `--schedule prefetch` additionally issues the next
//! iteration's halo during this iteration's dot/axpy tail (still
//! bit-identical values), and `--schedule sstep:<s>` batches the scalar
//! all-reduces into one combined round every s iterations. `--topology`
//! rewires the dies: a fixed `torus:RxC` shape must match every swept die
//! count, so the sweep-friendly spelling is bare `torus`, which picks the
//! most-square factoring per N ([`MeshTopology::torus_for`]).

use wormsim::arch::DataFormat;
use wormsim::device::{DeviceMesh, EthLink, MeshTopology};
use wormsim::engine::{NativeEngine, StencilCoeffs};
use wormsim::kernels::stencil::{StencilConfig, StencilVariant};
use wormsim::profiler::Profiler;
use wormsim::solver::{self, MeshOptions, Operator, OverlapMode, PcgOptions, PcgVariant, Schedule};
use wormsim::timing::cost::CostModel;
use wormsim::util::stats::fmt_ns;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let small = args.iter().any(|a| a == "--small");
    let overlap: OverlapMode = match args.iter().position(|a| a == "--overlap") {
        Some(idx) => args
            .get(idx + 1)
            .ok_or_else(|| anyhow::anyhow!("--overlap expects serial|pipelined"))?
            .parse()
            .map_err(anyhow::Error::msg)?,
        None => OverlapMode::Serial,
    };
    let schedule: Schedule = match args.iter().position(|a| a == "--schedule") {
        Some(idx) => args
            .get(idx + 1)
            .ok_or_else(|| anyhow::anyhow!("--schedule expects classic|prefetch|sstep:<s>"))?
            .parse()
            .map_err(anyhow::Error::msg)?,
        None => Schedule::Classic,
    };
    // `--topology torus` (bare) re-shapes per swept N via `torus_for`;
    // a fixed shape or line/ring applies to every N as-is.
    let topology_arg: Option<String> = match args.iter().position(|a| a == "--topology") {
        Some(idx) => Some(
            args.get(idx + 1)
                .ok_or_else(|| anyhow::anyhow!("--topology expects line|ring|torus:RxC|torus"))?
                .clone(),
        ),
        None => None,
    };
    let topology_for = |n: usize| -> anyhow::Result<MeshTopology> {
        match topology_arg.as_deref() {
            None => Ok(MeshTopology::Line),
            Some("torus") => Ok(MeshTopology::torus_for(n)),
            Some(s) => s.parse().map_err(anyhow::Error::msg),
        }
    };
    // Total tiles per core at N=1; must divide by every swept N.
    let (rows, cols, total_tiles, sweep): (usize, usize, usize, &[usize]) = if small {
        (2, 2, 16, &[1, 2, 4, 8])
    } else {
        (8, 7, 64, &[1, 2, 4, 8, 16, 32])
    };
    let engine = NativeEngine::new();
    let cost = CostModel::default();
    let elems = rows * cols * total_tiles * 1024;
    println!(
        "=== mesh strong scaling: {elems} unknowns, per-die {rows}x{cols} cores, {} topology, {} overlap, {} schedule ===\n",
        topology_arg.as_deref().unwrap_or("line"),
        overlap.label(),
        schedule.label()
    );
    println!(
        "{:>5} {:>10} {:>6} {:>11} {:>12} {:>9} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "dies",
        "topology",
        "cores",
        "tiles/core",
        "time/iter",
        "speedup",
        "compute",
        "NoC",
        "Ethernet",
        "dispatch",
        "link util"
    );

    let mut base: Option<f64> = None;
    for &n in sweep {
        let tiles = total_tiles / n;
        let topology = topology_for(n)?;
        let mesh = DeviceMesh::new(n, rows, cols, topology, EthLink::for_dies(n))
            .map_err(anyhow::Error::msg)?;
        let cfg = StencilConfig {
            df: DataFormat::Bf16,
            unit: wormsim::arch::ComputeUnit::Fpu,
            tiles_per_core: tiles,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let b = solver::mesh_dist_random(&mesh, tiles, DataFormat::Bf16, 20260731);
        let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
        // s-step needs at least one full block to amortize its combined
        // round; classic/prefetch keep the historical 2-iteration probe.
        opts.max_iters = match schedule {
            Schedule::SStep(s) => s,
            _ => 2,
        };
        opts.tol_abs = 0.0;
        let mut prof = Profiler::disabled();
        let res = solver::solve_pcg_mesh(
            &mesh,
            &b,
            &Operator::Stencil(cfg),
            &engine,
            &cost,
            &MeshOptions::new(opts).with_overlap(overlap).with_schedule(schedule),
            &mut prof,
        )?;
        let b0 = *base.get_or_insert(res.per_iter_ns);
        let topo_label = topology.label();
        println!(
            "{:>5} {:>10} {:>6} {:>11} {:>12} {:>8.2}x {:>12} {:>12} {:>12} {:>12} {:>9.0}%",
            n,
            topo_label,
            mesh.n_cores(),
            tiles,
            fmt_ns(res.per_iter_ns),
            b0 / res.per_iter_ns,
            fmt_ns(res.phases.compute_ns),
            fmt_ns(res.phases.noc_ns),
            fmt_ns(res.phases.ether_ns),
            fmt_ns(res.phases.dispatch_ns),
            100.0 * res.eth_peak_link_util,
        );
        // The telemetry ledger's read on the same numbers: which resource
        // bound this configuration, and through which component.
        println!("      {}", res.bottleneck_verdict());
    }
    println!(
        "\nspeedup = t(1 die) / t(N dies) — dispatch gaps and the Ethernet scalar\n\
         all-reduces bound it; serial mode charges the seam before the dependent\n\
         compute, pipelined mode hides it under the interior chain."
    );
    Ok(())
}
