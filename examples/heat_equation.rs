//! Explicit heat-equation time stepping on the simulated Wormhole — the
//! §8 "extending to additional numerical methods" direction, built purely
//! from the public stencil + axpy kernels.
//!
//! u_{t+1} = u_t + dt * lap(u_t), lap = -A (the 7-point Laplacian with
//! zero Dirichlet walls). A hot Gaussian blob in the domain center decays
//! and spreads; total heat decreases monotonically (the walls are cold).
//!
//!     cargo run --release --example heat_equation

use wormsim::arch::DataFormat;
use wormsim::engine::{CoreBlock, NativeEngine, StencilCoeffs};
use wormsim::kernels::stencil::{run_stencil, StencilConfig, StencilVariant};
use wormsim::solver::{dist_from_fn, Problem};
use wormsim::timing::cost::CostModel;
use wormsim::util::stats::fmt_ns;

fn total_heat(blocks: &[CoreBlock]) -> f64 {
    blocks
        .iter()
        .flat_map(|b| b.to_flat())
        .map(|v| v as f64)
        .sum()
}

fn main() -> anyhow::Result<()> {
    let problem = Problem::new(4, 4, 8, DataFormat::Fp32);
    let (nx, ny, nz) = problem.dims();
    println!("heat equation: {nx}x{ny}x{nz} grid, 4x4 Tensix cores, 8 tiles/core");

    // Gaussian hot spot in the domain center.
    let (cx, cy, cz) = (nx as f32 / 2.0, ny as f32 / 2.0, nz as f32 / 2.0);
    let mut u = dist_from_fn(&problem, |i, j, k| {
        let d2 = (i as f32 - cx).powi(2) / 200.0
            + (j as f32 - cy).powi(2) / 50.0
            + (k as f32 - cz).powi(2) / 4.0;
        100.0 * (-d2).exp()
    });

    let engine = NativeEngine::new();
    let cost = CostModel::default();
    let grid = problem.make_grid()?;
    let dt = 0.12f32; // stable for the unit-coefficient 7-pt Laplacian (< 1/6)
    let cfg = StencilConfig {
        df: DataFormat::Fp32,
        unit: wormsim::arch::ComputeUnit::Sfpu,
        tiles_per_core: problem.tiles_per_core,
        variant: StencilVariant::FULL,
        coeffs: StencilCoeffs::LAPLACIAN,
    };

    let steps = 50;
    let mut device_ns = 0.0;
    let h0 = total_heat(&u);
    let peak0 = u
        .iter()
        .flat_map(|b| b.to_flat())
        .fold(f32::MIN, f32::max);
    println!("t=0      total heat {h0:12.1}   peak {peak0:7.2}");

    let mut prev_heat = h0;
    for step in 1..=steps {
        // Au (A = 6I - sum of neighbors); lap(u) = -Au.
        let (au, t) = run_stencil(&grid, &cfg, &u, &engine, &cost)?;
        device_ns += t.iter_ns;
        // u <- u - dt * Au  (one axpy per core).
        for (ui, aui) in u.iter_mut().zip(&au) {
            *ui = wormsim::engine::ComputeEngine::axpy(&engine, ui, -dt, aui)?;
        }
        if step % 10 == 0 {
            let h = total_heat(&u);
            let peak = u
                .iter()
                .flat_map(|b| b.to_flat())
                .fold(f32::MIN, f32::max);
            println!("t={step:<4}   total heat {h:12.1}   peak {peak:7.2}");
            assert!(h <= prev_heat + 1e-3, "heat must not increase (cold walls)");
            prev_heat = h;
        }
    }
    println!();
    println!(
        "simulated device time: {} for {steps} steps ({} / step)",
        fmt_ns(device_ns),
        fmt_ns(device_ns / steps as f64)
    );
    let hf = total_heat(&u);
    println!("heat retained: {:.1}% (diffused into the cold walls)", 100.0 * hf / h0);
    Ok(())
}
