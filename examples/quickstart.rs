//! Quickstart: solve a small Poisson problem with PCG on the simulated
//! Wormhole, through the AOT JAX/Pallas artifacts if they are built
//! (falling back to the native engine otherwise).
//!
//!     make artifacts && cargo run --release --example quickstart

use wormsim::arch::DataFormat;
use wormsim::engine::{make_engine, EngineKind};
use wormsim::profiler::Profiler;
use wormsim::solver::{self, PcgOptions, PcgVariant, Problem};
use wormsim::timing::cost::CostModel;
use wormsim::util::stats::fmt_ns;

fn main() -> anyhow::Result<()> {
    // 2x2 Tensix cores, 4 tiles per core => a 128 x 32 x 4 grid.
    let problem = Problem::new(2, 2, 4, DataFormat::Fp32);
    let (nx, ny, nz) = problem.dims();
    println!("quickstart: Poisson {nx}x{ny}x{nz} with PCG on a 2x2 Tensix sub-grid");

    // Prefer the PJRT engine (executes the Pallas-authored artifacts).
    let artifacts = std::path::Path::new("artifacts");
    let engine = match make_engine(EngineKind::Pjrt, artifacts) {
        Ok(e) => {
            println!("engine: pjrt (AOT artifacts from {})", artifacts.display());
            e
        }
        Err(e) => {
            println!("engine: native ({e})");
            make_engine(EngineKind::Native, artifacts)?
        }
    };

    let grid = problem.make_grid()?;
    let b = solver::dist_random(&problem, 7);
    let mut opts = PcgOptions::new(PcgVariant::SplitFp32);
    opts.max_iters = 200;
    opts.tol_abs = 1e-3;

    let cost = CostModel::default();
    let mut prof = Profiler::new();
    let res = solver::solve(&grid, &problem, &b, engine.as_ref(), &cost, &opts, &mut prof)?;

    println!(
        "{} in {} iterations; |r| = {:.3e}",
        if res.converged { "converged" } else { "stopped" },
        res.iters,
        res.residual_history.last().copied().unwrap_or(f64::NAN),
    );
    println!(
        "simulated device time {} total, {} per iteration",
        fmt_ns(res.total_ns),
        fmt_ns(res.per_iter_ns)
    );
    println!();
    println!("{}", res.breakdown.render("component breakdown (per iteration)"));

    // Verify against the independent f64 oracle.
    let xg = solver::dist_to_global(&problem, &res.x);
    let bg = solver::dist_to_global(&problem, &b);
    let ax = solver::apply_laplacian_global(&problem, &xg);
    let true_res: f64 = ax
        .iter()
        .zip(&bg)
        .map(|(a, &v)| (a - v as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    println!("independent ||Ax - b|| check: {true_res:.3e}");
    Ok(())
}
