"""Layer-1 Pallas kernel: the 7-point stencil over a core block (§6).

Hardware adaptation (DESIGN.md §2): the Wormhole implementation tiles the
z dimension as a column of 64x16 SRAM tiles per core and builds shifted
tiles with circular-buffer pointer tricks and face transposes. On TPU the
same structure maps to a z-gridded Pallas kernel: each grid step stages the
center z-slab plus its two z-neighbor slabs and the four halo lines in
VMEM (BlockSpec does the HBM->VMEM schedule the Wormhole reader kernel did
over the NoC), and the shifted-tile construction becomes in-register rolls
with halo insertion. The arithmetic is identical, in the same canonical
scale/accumulate order as the Rust native engine, with BF16
round-to-nearest-even + flush-to-zero after every tile operation (§3.3).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE = (1, 64, 16)


def _stencil_kernel(df: str, nz: int):
    def kernel(x_ref, below_ref, above_ref, hn_ref, hs_ref, hw_ref, he_ref, c_ref, o_ref):
        z = pl.program_id(0)

        def q(v):
            return ref.quant(v, df)

        x = q(x_ref[0])  # (64, 16)
        # z-neighbor slabs; BlockSpec clamps the index at the boundary, so
        # mask the Dirichlet-zero planes explicitly here.
        below = jnp.where(z == 0, jnp.zeros_like(x), q(below_ref[0]))
        above = jnp.where(z == nz - 1, jnp.zeros_like(x), q(above_ref[0]))

        hn = q(hn_ref[0])  # (16,)
        hs = q(hs_ref[0])
        hw = q(hw_ref[0])  # (64,)
        he = q(he_ref[0])

        # Shifted-tile construction (§6.2): rows via the pointer trick,
        # columns via the transpose pipeline — both are pure data movement,
        # expressed here as concatenations.
        north = jnp.concatenate([hn[None, :], x[:-1, :]], axis=0)
        south = jnp.concatenate([x[1:, :], hs[None, :]], axis=0)
        west = jnp.concatenate([hw[:, None], x[:, :-1]], axis=1)
        east = jnp.concatenate([x[:, 1:], he[:, None]], axis=1)

        c = c_ref[...]
        acc = q(c[0] * x)
        acc = q(acc + q(c[1] * north))
        acc = q(acc + q(c[2] * south))
        acc = q(acc + q(c[3] * west))
        acc = q(acc + q(c[4] * east))
        acc = q(acc + q(c[5] * below))
        acc = q(acc + q(c[6] * above))
        o_ref[0] = acc

    return kernel


def stencil_apply(df: str, x, halo_n, halo_s, halo_w, halo_e, coeffs):
    """7-point stencil over ``x[nz, 64, 16]`` with halo lines.

    halo_n/halo_s: [nz, 16]; halo_w/halo_e: [nz, 64];
    coeffs: [7] = [center, x_lo, x_hi, y_lo, y_hi, z_lo, z_hi].
    """
    nz = x.shape[0]
    center_spec = pl.BlockSpec(TILE, lambda z: (z, 0, 0))
    # Clamped z-neighbor slabs (masked to zero at the boundary in-kernel).
    below_spec = pl.BlockSpec(TILE, lambda z: (jnp.maximum(z - 1, 0), 0, 0))
    above_spec = pl.BlockSpec(TILE, lambda z: (jnp.minimum(z + 1, nz - 1), 0, 0))
    ns_spec = pl.BlockSpec((1, 16), lambda z: (z, 0))
    ew_spec = pl.BlockSpec((1, 64), lambda z: (z, 0))
    c_spec = pl.BlockSpec((7,), lambda z: (0,))
    return pl.pallas_call(
        _stencil_kernel(df, nz),
        grid=(nz,),
        in_specs=[
            center_spec,
            below_spec,
            above_spec,
            ns_spec,
            ns_spec,
            ew_spec,
            ew_spec,
            c_spec,
        ],
        out_specs=center_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x, x, x, halo_n, halo_s, halo_w, halo_e, coeffs)
