"""Layer-1 Pallas kernels (interpret=True) and their pure-jnp oracles."""
