"""Layer-1 Pallas kernels: element-wise tile arithmetic (§4).

Each kernel processes one 64x16 tile per grid step (the z dimension of the
core block maps to the Pallas grid), with the tile resident in VMEM — the
TPU analogue of the Wormhole SRAM-staged tile stream. BF16 variants
reproduce the FPU data path: inputs and outputs round through bfloat16 with
flush-to-zero (§3.3).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that both pytest (via jax)
and the Rust runtime (via xla/PJRT) execute identically.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE = (1, 64, 16)


def _block_spec():
    return pl.BlockSpec(TILE, lambda z: (z, 0, 0))


def _eltwise_kernel(op: str, df: str):
    def kernel(a_ref, b_ref, o_ref):
        a = ref.quant(a_ref[...], df)
        b = ref.quant(b_ref[...], df)
        if op == "add":
            r = a + b
        elif op == "sub":
            r = a - b
        elif op == "mul":
            r = a * b
        else:
            raise ValueError(f"unknown eltwise op {op!r}")
        o_ref[...] = ref.quant(r, df)

    return kernel


def eltwise(op: str, df: str, a, b):
    """c = a `op` b over a core block [nz, 64, 16] (f32 I/O)."""
    nz = a.shape[0]
    return pl.pallas_call(
        _eltwise_kernel(op, df),
        grid=(nz,),
        in_specs=[_block_spec(), _block_spec()],
        out_specs=_block_spec(),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=True,
    )(a, b)


def _axpy_kernel(df: str):
    def kernel(y_ref, x_ref, alpha_ref, o_ref):
        y = ref.quant(y_ref[...], df)
        x = ref.quant(x_ref[...], df)
        alpha = alpha_ref[0]
        # One fused output quantization (FMA tile op).
        o_ref[...] = ref.quant(y + alpha * x, df)

    return kernel


def axpy(df: str, y, x, alpha):
    """y + alpha * x over a core block; alpha is a scalar."""
    nz = y.shape[0]
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1)
    return pl.pallas_call(
        _axpy_kernel(df),
        grid=(nz,),
        in_specs=[
            _block_spec(),
            _block_spec(),
            pl.BlockSpec((1,), lambda z: (0,)),
        ],
        out_specs=_block_spec(),
        out_shape=jax.ShapeDtypeStruct(y.shape, jnp.float32),
        interpret=True,
    )(y, x, alpha_arr)


def _scale_kernel(df: str):
    def kernel(x_ref, alpha_ref, o_ref):
        x = ref.quant(x_ref[...], df)
        o_ref[...] = ref.quant(alpha_ref[0] * x, df)

    return kernel


def scale(df: str, x, alpha):
    """alpha * x over a core block; alpha is a scalar."""
    nz = x.shape[0]
    alpha_arr = jnp.asarray(alpha, jnp.float32).reshape(1)
    return pl.pallas_call(
        _scale_kernel(df),
        grid=(nz,),
        in_specs=[_block_spec(), pl.BlockSpec((1,), lambda z: (0,))],
        out_specs=_block_spec(),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x, alpha_arr)
