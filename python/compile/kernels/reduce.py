"""Layer-1 Pallas kernel: the local dot-product partial (§5, Fig 4).

One grid step per tile: multiply element-wise at operand precision,
reduce the tile to a scalar, accumulate across grid steps in f32 in the
output ref (the Dst-register accumulation model shared with
``ref.dot_partial`` and the Rust native engine).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE = (1, 64, 16)


def _dot_kernel(df: str):
    def kernel(a_ref, b_ref, o_ref):
        z = pl.program_id(0)

        @pl.when(z == 0)
        def _init():
            o_ref[0, 0] = jnp.float32(0.0)

        a = ref.quant(a_ref[...], df)
        b = ref.quant(b_ref[...], df)
        prod = ref.quant(a * b, df)
        tile_sum = ref.quant(jnp.sum(prod), df)
        o_ref[0, 0] += tile_sum.astype(jnp.float32)

    return kernel


def dot_partial(df: str, a, b):
    """Scalar sum(a*b) over a core block [nz, 64, 16]; returns shape (1,1)."""
    nz = a.shape[0]
    spec = pl.BlockSpec(TILE, lambda z: (z, 0, 0))
    out = pl.pallas_call(
        _dot_kernel(df),
        grid=(nz,),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, 1), lambda z: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(a, b)
    return out
