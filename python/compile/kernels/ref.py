"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These implement the Wormhole value semantics in plain jax.numpy, in the same
canonical operation order as the Rust native engine
(``rust/src/engine/native.rs``) and the Pallas kernels. They are the
correctness reference for pytest and never ship in an artifact.

Numerics (paper §3.3):
- BF16 path: every tile operation rounds to bfloat16 (RNE) and flushes
  subnormals to zero.
- FP32 path: operations run in f32 with flush-to-zero.
"""

import jax.numpy as jnp

# Smallest positive normal for the shared f32/bf16 exponent range. Kept as
# a Python float: module-level jnp constants would be captured as consts by
# Pallas kernel tracing, which pallas_call rejects.
_MIN_NORMAL = float(2.0**-126)


def ftz(x):
    """Flush subnormals to (sign-preserving) zero."""
    x = x.astype(jnp.float32)
    return jnp.where(jnp.abs(x) < _MIN_NORMAL, x * 0.0, x)


def quant(x, df: str):
    """Round a value through the compute-unit data path.

    ``bf16``: RNE to bfloat16 then flush-to-zero; ``f32``: flush-to-zero.
    """
    x = x.astype(jnp.float32)
    if df == "bf16":
        x = x.astype(jnp.bfloat16).astype(jnp.float32)
    elif df != "f32":
        raise ValueError(f"unknown data format {df!r}")
    return ftz(x)


# ---------------------------------------------------------------------------
# Element-wise kernels (§4)
# ---------------------------------------------------------------------------

def eltwise(op: str, a, b, df: str):
    a = quant(a, df)
    b = quant(b, df)
    if op == "add":
        r = a + b
    elif op == "sub":
        r = a - b
    elif op == "mul":
        r = a * b
    else:
        raise ValueError(f"unknown eltwise op {op!r}")
    return quant(r, df)


def axpy(y, x, alpha, df: str):
    """y + alpha * x with a single output quantization (fused FMA tile op)."""
    return quant(quant(y, df) + alpha * quant(x, df), df)


def scale(x, alpha, df: str):
    return quant(alpha * quant(x, df), df)


# ---------------------------------------------------------------------------
# Dot-product partial (§5, Fig 4)
# ---------------------------------------------------------------------------

def dot_partial(a, b, df: str):
    """sum(a*b) over a core's tiles: per-element products quantized at
    operand precision, per-tile sums accumulated in f32 and quantized, tile
    partials accumulated in f32 (the Dst-register accumulation model)."""
    a = quant(a, df).reshape(-1, 64 * 16)
    b = quant(b, df).reshape(-1, 64 * 16)
    prod = quant(a * b, df)
    tile_sums = quant(jnp.sum(prod, axis=1), df)
    return jnp.sum(tile_sums).astype(jnp.float32)


# ---------------------------------------------------------------------------
# 7-point stencil (§6)
# ---------------------------------------------------------------------------

def _shift_north(x, halo_n):
    """out[z,0,:] = halo_n[z]; out[z,r,:] = x[z,r-1,:]."""
    return jnp.concatenate([halo_n[:, None, :], x[:, :-1, :]], axis=1)


def _shift_south(x, halo_s):
    return jnp.concatenate([x[:, 1:, :], halo_s[:, None, :]], axis=1)


def _shift_west(x, halo_w):
    """out[z,:,0] = halo_w[z]; out[z,:,c] = x[z,:,c-1]."""
    return jnp.concatenate([halo_w[:, :, None], x[:, :, :-1]], axis=2)


def _shift_east(x, halo_e):
    return jnp.concatenate([x[:, :, 1:], halo_e[:, :, None]], axis=2)


def stencil_apply(x, halo_n, halo_s, halo_w, halo_e, coeffs, df: str):
    """7-point stencil over a core block ``x[nz, 64, 16]``.

    ``coeffs = [center, x_lo, x_hi, y_lo, y_hi, z_lo, z_hi]`` (§7 Eq. 2 uses
    [6, -1, -1, -1, -1, -1, -1]). Halos: ``halo_n/halo_s [nz, 16]``,
    ``halo_w/halo_e [nz, 64]``. z boundaries are zero Dirichlet.

    Canonical order (shared with the native engine and the Pallas kernel):
    acc = c*x; acc += cN*north; acc += cS*south; acc += cW*west;
    acc += cE*east; acc += cZlo*below; acc += cZhi*above — with scale and
    add each quantized.
    """
    x = quant(x, df)
    zeros_plane = jnp.zeros_like(x[:1])
    below = jnp.concatenate([zeros_plane, x[:-1]], axis=0)
    above = jnp.concatenate([x[1:], zeros_plane], axis=0)

    def q(v):
        return quant(v, df)

    acc = q(coeffs[0] * x)
    acc = q(acc + q(coeffs[1] * _shift_north(x, quant(halo_n, df))))
    acc = q(acc + q(coeffs[2] * _shift_south(x, quant(halo_s, df))))
    acc = q(acc + q(coeffs[3] * _shift_west(x, quant(halo_w, df))))
    acc = q(acc + q(coeffs[4] * _shift_east(x, quant(halo_e, df))))
    acc = q(acc + q(coeffs[5] * below))
    acc = q(acc + q(coeffs[6] * above))
    return acc
