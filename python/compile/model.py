"""Layer-2: the per-core compute graphs, in JAX, calling the Layer-1
Pallas kernels. These are the functions `python/compile/aot.py` lowers to
the HLO-text artifacts the Rust runtime executes.

All I/O is f32; BF16 variants carry the Wormhole FPU numerics (RNE +
flush-to-zero after every tile op) inside the graph, so the Rust side never
needs a bfloat16 ABI.

Artifact naming (shared with rust/src/runtime/artifacts.rs):
    {op}_{df}_t{nz}  with df in {bf16, f32}
    ops: eltwise_add, eltwise_sub, eltwise_mul, axpy, scale, dot, stencil
"""

import jax.numpy as jnp

from .kernels import eltwise as k_eltwise
from .kernels import reduce as k_reduce
from .kernels import stencil as k_stencil

DFS = ("bf16", "f32")
OPS = ("eltwise_add", "eltwise_sub", "eltwise_mul", "axpy", "scale", "dot", "stencil")


def eltwise_add(df):
    return lambda a, b: (k_eltwise.eltwise("add", df, a, b),)


def eltwise_sub(df):
    return lambda a, b: (k_eltwise.eltwise("sub", df, a, b),)


def eltwise_mul(df):
    return lambda a, b: (k_eltwise.eltwise("mul", df, a, b),)


def axpy(df):
    """(y, x, alpha) -> y + alpha * x."""
    return lambda y, x, alpha: (k_eltwise.axpy(df, y, x, alpha),)


def scale(df):
    """(x, alpha) -> alpha * x."""
    return lambda x, alpha: (k_eltwise.scale(df, x, alpha),)


def dot(df):
    """(a, b) -> scalar partial dot product, shape (1, 1)."""
    return lambda a, b: (k_reduce.dot_partial(df, a, b),)


def stencil(df):
    """(x, hn, hs, hw, he, coeffs) -> 7-point stencil application."""
    return lambda x, hn, hs, hw, he, coeffs: (
        k_stencil.stencil_apply(df, x, hn, hs, hw, he, coeffs),
    )


def build(op: str, df: str):
    """The jax callable for an (op, df) pair."""
    if df not in DFS:
        raise ValueError(f"unknown df {df!r}")
    fns = {
        "eltwise_add": eltwise_add,
        "eltwise_sub": eltwise_sub,
        "eltwise_mul": eltwise_mul,
        "axpy": axpy,
        "scale": scale,
        "dot": dot,
        "stencil": stencil,
    }
    if op not in fns:
        raise ValueError(f"unknown op {op!r}")
    return fns[op](df)


def example_args(op: str, nz: int):
    """ShapeDtypeStructs to lower `op` for a core block of `nz` tiles."""
    import jax

    f32 = jnp.float32
    block = jax.ShapeDtypeStruct((nz, 64, 16), f32)
    scalar = jax.ShapeDtypeStruct((), f32)
    if op in ("eltwise_add", "eltwise_sub", "eltwise_mul", "dot"):
        return (block, block)
    if op == "axpy":
        return (block, block, scalar)
    if op == "scale":
        return (block, scalar)
    if op == "stencil":
        ns = jax.ShapeDtypeStruct((nz, 16), f32)
        ew = jax.ShapeDtypeStruct((nz, 64), f32)
        coeffs = jax.ShapeDtypeStruct((7,), f32)
        return (block, ns, ns, ew, ew, coeffs)
    raise ValueError(f"unknown op {op!r}")
