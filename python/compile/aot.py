"""AOT lowering: jax (L2) + pallas (L1) -> HLO **text** artifacts for the
Rust PJRT runtime (L3).

HLO text — not ``lowered.compiler_ir("hlo").as_serialized_hlo_module_proto()``
— is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the image's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:
    python -m compile.aot --out-dir ../artifacts [--tiles 1,2,4,8,64,164]
"""

import argparse
import pathlib
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model

# Tile counts the artifact set covers. Runtime lookups for other counts
# fail with a pointer here (rust/src/engine/pjrt.rs::lookup).
DEFAULT_TILE_COUNTS = (1, 2, 4, 8, 64, 164)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps a tuple uniformly)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(op: str, df: str, nz: int) -> str:
    fn = model.build(op, df)
    args = model.example_args(op, nz)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def emit(out_dir: pathlib.Path, tile_counts, force: bool, verbose: bool = True) -> int:
    out_dir.mkdir(parents=True, exist_ok=True)
    n_written = 0
    t0 = time.time()
    for op in model.OPS:
        for df in model.DFS:
            for nz in tile_counts:
                name = f"{op}_{df}_t{nz}"
                path = out_dir / f"{name}.hlo.txt"
                if path.exists() and not force:
                    continue
                text = lower_one(op, df, nz)
                path.write_text(text)
                n_written += 1
                if verbose:
                    print(f"  [{time.time() - t0:6.1f}s] wrote {path.name} ({len(text)} chars)")
    return n_written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--tiles",
        default=",".join(str(t) for t in DEFAULT_TILE_COUNTS),
        help="comma-separated tile counts to lower",
    )
    ap.add_argument("--force", action="store_true", help="re-emit existing artifacts")
    args = ap.parse_args()
    tile_counts = tuple(int(t) for t in args.tiles.split(","))
    out_dir = pathlib.Path(args.out_dir)
    n = emit(out_dir, tile_counts, args.force)
    total = len(model.OPS) * len(model.DFS) * len(tile_counts)
    print(f"artifacts: {n} written, {total - n} up-to-date, dir {out_dir.resolve()}")
    sys.exit(0)


if __name__ == "__main__":
    main()
