"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).

Hypothesis sweeps shapes, data formats, and value distributions (including
subnormals, which must flush to zero on the simulated Wormhole data path,
paper §3.3).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

DFS = ("bf16", "f32")

# Tolerances: interpret-mode Pallas may fuse multiply-adds where the oracle
# does not; 1-2 ulp at f32 scale.
ATOL = 5e-6
RTOL = 3e-6


def rand_block(rng, nz, nasty=False):
    x = rng.standard_normal((nz, 64, 16)).astype(np.float32)
    if nasty:
        # Sprinkle subnormals, zeros, extremes.
        mask = rng.random(x.shape)
        x = np.where(mask < 0.1, np.float32(1e-40), x)  # subnormal
        x = np.where((0.1 <= mask) & (mask < 0.2), np.float32(0.0), x)
        x = np.where((0.2 <= mask) & (mask < 0.25), np.float32(1e30), x)
    return x


@pytest.mark.parametrize("df", DFS)
@pytest.mark.parametrize("op", ["add", "sub", "mul"])
@pytest.mark.parametrize("nz", [1, 3])
def test_eltwise_matches_ref(op, df, nz):
    rng = np.random.default_rng(1)
    a = rand_block(rng, nz)
    b = rand_block(rng, nz)
    got = model.build(f"eltwise_{op}", df)(a, b)[0]
    want = ref.eltwise(op, a, b, df)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL, rtol=RTOL)


@pytest.mark.parametrize("df", DFS)
def test_eltwise_flushes_subnormals(df):
    rng = np.random.default_rng(2)
    a = rand_block(rng, 2, nasty=True)
    b = rand_block(rng, 2, nasty=True)
    got = np.asarray(model.build("eltwise_mul", df)(a, b)[0])
    # No subnormal outputs may survive (§3.3 flush-to-zero).
    nonzero = got[got != 0.0]
    assert np.all(np.abs(nonzero) >= np.float32(2.0**-126))
    want = ref.eltwise("mul", a, b, df)
    np.testing.assert_allclose(got, np.asarray(want), atol=ATOL, rtol=RTOL)


@settings(max_examples=25, deadline=None)
@given(
    nz=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    df=st.sampled_from(DFS),
    alpha=st.floats(min_value=-4.0, max_value=4.0, allow_nan=False, width=32),
)
def test_axpy_matches_ref_hypothesis(nz, seed, df, alpha):
    rng = np.random.default_rng(seed)
    y = rand_block(rng, nz)
    x = rand_block(rng, nz)
    got = model.build("axpy", df)(y, x, jnp.float32(alpha))[0]
    want = ref.axpy(y, x, jnp.float32(alpha), df)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    nz=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    df=st.sampled_from(DFS),
)
def test_dot_matches_ref_hypothesis(nz, seed, df):
    rng = np.random.default_rng(seed)
    a = rand_block(rng, nz)
    b = rand_block(rng, nz)
    got = np.asarray(model.build("dot", df)(a, b)[0]).ravel()[0]
    want = float(ref.dot_partial(a, b, df))
    assert got == pytest.approx(want, rel=1e-5, abs=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    nz=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    df=st.sampled_from(DFS),
)
def test_stencil_matches_ref_hypothesis(nz, seed, df):
    rng = np.random.default_rng(seed)
    x = rand_block(rng, nz)
    hn = rng.standard_normal((nz, 16)).astype(np.float32)
    hs = rng.standard_normal((nz, 16)).astype(np.float32)
    hw = rng.standard_normal((nz, 64)).astype(np.float32)
    he = rng.standard_normal((nz, 64)).astype(np.float32)
    c = np.array([6, -1, -1, -1, -1, -1, -1], np.float32)
    got = model.build("stencil", df)(x, hn, hs, hw, he, c)[0]
    want = ref.stencil_apply(x, hn, hs, hw, he, c, df)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=ATOL, rtol=RTOL)


def test_stencil_laplacian_of_linear_field_is_zero_inside():
    """Interior Laplacian of a linear field vanishes — catches any shifted-
    tile misalignment (the §6.2 correctness concern)."""
    nz = 4
    i = np.arange(64, dtype=np.float32)[None, :, None]
    j = np.arange(16, dtype=np.float32)[None, None, :]
    k = np.arange(nz, dtype=np.float32)[:, None, None]
    x = (i + 2 * j + 3 * k) * 1e-3
    x = np.broadcast_to(x, (nz, 64, 16)).astype(np.float32)
    # Halos continue the linear field.
    hn = (x[:, 0, :] - 1e-3).astype(np.float32)       # i = -1
    hs = (x[:, -1, :] + 1e-3).astype(np.float32)      # i = 64
    hw = (x[:, :, 0] - 2e-3).astype(np.float32)       # j = -1
    he = (x[:, :, -1] + 2e-3).astype(np.float32)      # j = 16
    c = np.array([6, -1, -1, -1, -1, -1, -1], np.float32)
    got = np.asarray(model.build("stencil", "f32")(x, hn, hs, hw, he, c)[0])
    interior = got[1:-1, :, :]
    np.testing.assert_allclose(interior, np.zeros_like(interior), atol=1e-5)


def test_stencil_zero_dirichlet_z():
    """z boundaries are zero Dirichlet: constant field of ones, coefficient
    sum at the fully-interior level is 0, at z extremes it is +1."""
    nz = 3
    x = np.ones((nz, 64, 16), np.float32)
    ones16 = np.ones((nz, 16), np.float32)
    ones64 = np.ones((nz, 64), np.float32)
    c = np.array([6, -1, -1, -1, -1, -1, -1], np.float32)
    got = np.asarray(model.build("stencil", "f32")(x, ones16, ones16, ones64, ones64, c)[0])
    assert got[1, 30, 8] == pytest.approx(0.0)
    assert got[0, 30, 8] == pytest.approx(1.0)
    assert got[2, 30, 8] == pytest.approx(1.0)


def test_bf16_quantization_visible():
    """257 is not representable in bf16: add must round."""
    a = np.full((1, 64, 16), 256.0, np.float32)
    b = np.ones((1, 64, 16), np.float32)
    got = np.asarray(model.build("eltwise_add", "bf16")(a, b)[0])
    assert np.all(got == 256.0)
    got32 = np.asarray(model.build("eltwise_add", "f32")(a, b)[0])
    assert np.all(got32 == 257.0)
