"""Layer-2 + AOT pipeline tests: shapes, lowering, and HLO-text emission."""

import pathlib
import tempfile

import numpy as np
import pytest

import jax

from compile import aot, model


@pytest.mark.parametrize("op", model.OPS)
@pytest.mark.parametrize("df", model.DFS)
def test_example_args_lower(op, df):
    """Every (op, df) pair must lower cleanly at a small tile count."""
    fn = model.build(op, df)
    args = model.example_args(op, 2)
    lowered = jax.jit(fn).lower(*args)
    assert lowered is not None


@pytest.mark.parametrize("op", ["eltwise_add", "dot", "stencil"])
def test_hlo_text_emission(op):
    """HLO text (not proto) comes out of the lowering recipe and contains
    an entry computation."""
    text = aot.lower_one(op, "f32", 2)
    assert "ENTRY" in text
    assert "HloModule" in text
    # jax >= 0.5 proto ids overflow xla_extension 0.5.1 — text must be used.
    assert len(text) > 100


def test_emit_is_idempotent(tmp_path):
    n1 = aot.emit(pathlib.Path(tmp_path), (1,), force=False, verbose=False)
    assert n1 == len(model.OPS) * len(model.DFS)
    n2 = aot.emit(pathlib.Path(tmp_path), (1,), force=False, verbose=False)
    assert n2 == 0, "second emit must be a no-op"
    names = sorted(p.name for p in pathlib.Path(tmp_path).glob("*.hlo.txt"))
    assert "stencil_bf16_t1.hlo.txt" in names
    assert "axpy_f32_t1.hlo.txt" in names


def test_output_shapes():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4, 64, 16)).astype(np.float32)
    y = rng.standard_normal((4, 64, 16)).astype(np.float32)
    out = model.build("eltwise_add", "f32")(x, y)
    assert len(out) == 1 and out[0].shape == (4, 64, 16)
    d = model.build("dot", "f32")(x, y)
    assert d[0].shape == (1, 1)


def test_unknown_op_rejected():
    with pytest.raises(ValueError):
        model.build("nope", "f32")
    with pytest.raises(ValueError):
        model.build("dot", "f64")
    with pytest.raises(ValueError):
        model.example_args("nope", 2)
