//! Machine-readable bench snapshots (`BENCH_<name>.json`) and the
//! regression comparator behind `wormsim bench-diff`.
//!
//! Schema (`wormsim-bench-v1`):
//!
//! ```json
//! {
//!   "schema": "wormsim-bench-v1",
//!   "name": "pcg",
//!   "meta": {"provenance": "...", "config": "..."},
//!   "metrics": [
//!     {"name": "iter_ns", "labels": {"overlap": "serial", "dies": "4"},
//!      "value": 1.2e6, "unit": "ns", "better": "lower"}
//!   ]
//! }
//! ```
//!
//! Snapshots carry **no timestamps** — the committed files must be
//! byte-stable under regeneration with an unchanged model.  A metric's
//! identity is `name{label=value,...}` with sorted labels; `diff` matches
//! metrics by identity and flags relative changes beyond a threshold in the
//! metric's "worse" direction (`better: "info"` metrics are never flagged).

use std::fs;
use std::io;
use std::path::Path;

use super::metrics::metric_id;
use crate::util::jsonmini::Json;

/// Which direction of change counts as an improvement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Better {
    Lower,
    Higher,
    /// Contextual metric: recorded but never flagged by `diff`.
    Info,
}

impl Better {
    pub fn label(self) -> &'static str {
        match self {
            Better::Lower => "lower",
            Better::Higher => "higher",
            Better::Info => "info",
        }
    }

    fn parse(s: &str) -> Result<Self, String> {
        match s {
            "lower" => Ok(Better::Lower),
            "higher" => Ok(Better::Higher),
            "info" => Ok(Better::Info),
            other => Err(format!("unknown better direction '{other}'")),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct BenchMetric {
    pub name: String,
    /// Sorted `(key, value)` label pairs.
    pub labels: Vec<(String, String)>,
    pub value: f64,
    pub unit: String,
    pub better: Better,
}

impl BenchMetric {
    pub fn id(&self) -> String {
        metric_id(&self.name, &self.labels)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct BenchSnapshot {
    pub name: String,
    /// Free-form provenance/config notes, written in insertion order.
    pub meta: Vec<(String, String)>,
    pub metrics: Vec<BenchMetric>,
}

impl BenchSnapshot {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            meta: Vec::new(),
            metrics: Vec::new(),
        }
    }

    pub fn meta(&mut self, key: &str, value: &str) {
        self.meta.push((key.to_string(), value.to_string()));
    }

    /// Append one metric; labels are sorted to canonicalize identity.
    pub fn push(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        value: f64,
        unit: &str,
        better: Better,
    ) {
        let mut labels: Vec<(String, String)> = labels
            .iter()
            .map(|&(k, v)| (k.to_string(), v.to_string()))
            .collect();
        labels.sort();
        self.metrics.push(BenchMetric {
            name: name.to_string(),
            labels,
            value,
            unit: unit.to_string(),
            better,
        });
    }

    pub fn find(&self, id: &str) -> Option<&BenchMetric> {
        self.metrics.iter().find(|m| m.id() == id)
    }

    pub fn to_json(&self) -> String {
        let meta = self.meta.clone();
        let metrics: Vec<Json> = self
            .metrics
            .iter()
            .map(|m| {
                Json::Obj(vec![
                    ("name".to_string(), Json::Str(m.name.clone())),
                    (
                        "labels".to_string(),
                        Json::Obj(
                            m.labels
                                .iter()
                                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                                .collect(),
                        ),
                    ),
                    ("value".to_string(), Json::Num(m.value)),
                    ("unit".to_string(), Json::Str(m.unit.clone())),
                    (
                        "better".to_string(),
                        Json::Str(m.better.label().to_string()),
                    ),
                ])
            })
            .collect();
        let doc = Json::Obj(vec![
            (
                "schema".to_string(),
                Json::Str("wormsim-bench-v1".to_string()),
            ),
            ("name".to_string(), Json::Str(self.name.clone())),
            (
                "meta".to_string(),
                Json::Obj(
                    meta.into_iter()
                        .map(|(k, v)| (k, Json::Str(v)))
                        .collect(),
                ),
            ),
            ("metrics".to_string(), Json::Arr(metrics)),
        ]);
        // Pretty-ish: one metric per line so git diffs stay reviewable.
        let mut out = String::new();
        out.push_str("{\"schema\":\"wormsim-bench-v1\",\n");
        out.push_str(&format!(
            "\"name\":{},\n",
            Json::Str(self.name.clone()).to_json_string()
        ));
        let Json::Obj(fields) = doc else { unreachable!() };
        let meta_json = &fields[2].1;
        out.push_str(&format!("\"meta\":{},\n", meta_json.to_json_string()));
        out.push_str("\"metrics\":[\n");
        let Json::Arr(items) = &fields[3].1 else {
            unreachable!()
        };
        for (i, m) in items.iter().enumerate() {
            out.push_str(&m.to_json_string());
            if i + 1 < items.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]}\n");
        out
    }

    pub fn parse(s: &str) -> Result<Self, String> {
        let doc = Json::parse(s)?;
        match doc.get("schema").and_then(Json::as_str) {
            Some("wormsim-bench-v1") => {}
            other => return Err(format!("unsupported snapshot schema {other:?}")),
        }
        let name = doc
            .get("name")
            .and_then(Json::as_str)
            .ok_or("snapshot missing 'name'")?
            .to_string();
        let mut snap = BenchSnapshot::new(&name);
        if let Some(meta) = doc.get("meta").and_then(Json::as_obj) {
            for (k, v) in meta {
                snap.meta
                    .push((k.clone(), v.as_str().unwrap_or("").to_string()));
            }
        }
        let metrics = doc
            .get("metrics")
            .and_then(Json::as_arr)
            .ok_or("snapshot missing 'metrics'")?;
        for m in metrics {
            let name = m
                .get("name")
                .and_then(Json::as_str)
                .ok_or("metric missing 'name'")?;
            let mut labels: Vec<(String, String)> = m
                .get("labels")
                .and_then(Json::as_obj)
                .map(|pairs| {
                    pairs
                        .iter()
                        .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
                        .collect()
                })
                .unwrap_or_default();
            labels.sort();
            let value = m
                .get("value")
                .and_then(Json::as_f64)
                .ok_or("metric missing 'value'")?;
            let unit = m.get("unit").and_then(Json::as_str).unwrap_or("").to_string();
            let better = Better::parse(m.get("better").and_then(Json::as_str).unwrap_or("info"))?;
            snap.metrics.push(BenchMetric {
                name: name.to_string(),
                labels,
                value,
                unit,
                better,
            });
        }
        Ok(snap)
    }

    /// Write to `path`, creating parent directories. Atomic
    /// (temp-then-rename): an interrupted bench never leaves a
    /// truncated `BENCH_*.json` for `bench-diff` to choke on.
    pub fn write(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        crate::util::fsatomic::write_atomic(path, &self.to_json())
    }

    pub fn read(path: &Path) -> Result<Self, String> {
        let text =
            fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))
    }
}

/// One metric that moved between two snapshots.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffEntry {
    pub id: String,
    pub a: f64,
    pub b: f64,
    /// Signed relative change `(b - a) / |a|`.
    pub rel: f64,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct BenchDiff {
    /// Metrics that moved in their "worse" direction beyond the threshold.
    pub regressions: Vec<DiffEntry>,
    /// Metrics that moved in their "better" direction beyond the threshold.
    pub improvements: Vec<DiffEntry>,
    /// Metric ids present in `a` but absent in `b` (advisory note).
    pub missing: Vec<String>,
    /// Metric ids present in `b` but absent in `a` (advisory note).
    pub added: Vec<String>,
}

/// Compare snapshot `b` against baseline `a`. `threshold` is the relative
/// change (e.g. `0.05` = 5%) beyond which a directional metric is flagged.
pub fn diff(a: &BenchSnapshot, b: &BenchSnapshot, threshold: f64) -> BenchDiff {
    let mut out = BenchDiff::default();
    for ma in &a.metrics {
        let id = ma.id();
        let Some(mb) = b.find(&id) else {
            out.missing.push(id);
            continue;
        };
        let denom = ma.value.abs().max(1e-12);
        let rel = (mb.value - ma.value) / denom;
        let entry = DiffEntry {
            id: id.clone(),
            a: ma.value,
            b: mb.value,
            rel,
        };
        let (worse, improved) = match ma.better {
            Better::Lower => (rel > threshold, rel < -threshold),
            Better::Higher => (rel < -threshold, rel > threshold),
            Better::Info => (false, false),
        };
        if worse {
            out.regressions.push(entry);
        } else if improved {
            out.improvements.push(entry);
        }
    }
    for mb in &b.metrics {
        let id = mb.id();
        if a.find(&id).is_none() {
            out.added.push(id);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap() -> BenchSnapshot {
        let mut s = BenchSnapshot::new("pcg");
        s.meta("config", "8x7 grid, 64 tiles");
        s.push(
            "iter_ns",
            &[("overlap", "serial"), ("dies", "4")],
            1.2e6,
            "ns",
            Better::Lower,
        );
        s.push("peak_link_util", &[("dies", "4")], 1.0, "frac", Better::Info);
        s.push("residual_drop", &[], 0.5, "frac", Better::Higher);
        s
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let s = snap();
        let back = BenchSnapshot::parse(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // And byte-stable on re-serialization.
        assert_eq!(back.to_json(), s.to_json());
    }

    #[test]
    fn self_diff_flags_nothing() {
        let s = snap();
        let d = diff(&s, &s, 0.05);
        assert!(d.regressions.is_empty());
        assert!(d.improvements.is_empty());
        assert!(d.missing.is_empty());
        assert!(d.added.is_empty());
    }

    #[test]
    fn diff_respects_direction_and_threshold() {
        let a = snap();
        let mut b = snap();
        b.metrics[0].value = 1.2e6 * 1.10; // iter_ns up 10% → regression
        b.metrics[1].value = 0.2; // info metric moves → ignored
        b.metrics[2].value = 0.4; // higher-is-better down 20% → regression
        let d = diff(&a, &b, 0.05);
        assert_eq!(d.regressions.len(), 2);
        assert_eq!(d.regressions[0].id, "iter_ns{dies=4,overlap=serial}");
        assert_eq!(d.regressions[1].id, "residual_drop");
        // Same moves under a huge threshold → clean.
        assert!(diff(&a, &b, 0.5).regressions.is_empty());
        // Improvement direction.
        let mut c = snap();
        c.metrics[0].value = 1.2e6 * 0.8;
        let d2 = diff(&a, &c, 0.05);
        assert!(d2.regressions.is_empty());
        assert_eq!(d2.improvements.len(), 1);
    }

    #[test]
    fn missing_and_added_are_notes_not_regressions() {
        let a = snap();
        let mut b = BenchSnapshot::new("pcg");
        b.push("new_metric", &[], 1.0, "ns", Better::Lower);
        let d = diff(&a, &b, 0.05);
        assert_eq!(d.missing.len(), 3);
        assert_eq!(d.added, vec!["new_metric".to_string()]);
        assert!(d.regressions.is_empty());
    }

    #[test]
    fn topology_relabel_is_advisory_not_a_regression() {
        // PR-9 pin: adding the `topology` label to the pcg sweep changes
        // every metric id, so diffing an old committed snapshot against a
        // freshly built one must classify each row as missing/added —
        // advisory notes — and NEVER as a regression, even when the new
        // row's value moved far past any threshold.
        let mut old = BenchSnapshot::new("pcg");
        old.push(
            "iter_ns",
            &[("dies", "4"), ("overlap", "serial"), ("schedule", "classic")],
            1.0e6,
            "ns",
            Better::Lower,
        );
        let mut new = BenchSnapshot::new("pcg");
        new.push(
            "iter_ns",
            &[
                ("dies", "4"),
                ("topology", "line"),
                ("overlap", "serial"),
                ("schedule", "classic"),
            ],
            5.0e6, // 5x worse than the old row — still not a regression
            "ns",
            Better::Lower,
        );
        let d = diff(&old, &new, 0.05);
        assert!(d.regressions.is_empty());
        assert_eq!(
            d.missing,
            vec!["iter_ns{dies=4,overlap=serial,schedule=classic}".to_string()]
        );
        assert_eq!(
            d.added,
            vec!["iter_ns{dies=4,overlap=serial,schedule=classic,topology=line}".to_string()]
        );
    }

    #[test]
    fn write_and_read_disk_round_trip() {
        let dir = std::env::temp_dir().join("wormsim_snapshot_test");
        let path = dir.join("BENCH_t.json");
        let s = snap();
        s.write(&path).unwrap();
        let back = BenchSnapshot::read(&path).unwrap();
        assert_eq!(back, s);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
