//! Labelled counters, sums, and time-series sampled in simulated ns.
//!
//! The registry is deliberately tiny: three `BTreeMap`s keyed by metric name
//! plus a *sorted* label list, so iteration order (and therefore every
//! serialized artifact) is deterministic.  All values are observations of
//! simulated quantities — recording a metric never advances simulated time,
//! which is what makes telemetry-on vs. telemetry-off runs bit-identical.

use std::collections::BTreeMap;

use crate::timing::SimNs;

/// Sorted `(key, value)` label pairs identifying one series of a metric.
pub type Labels = Vec<(String, String)>;

type MetricKey = (String, Labels);

fn key(name: &str, labels: &[(&str, &str)]) -> MetricKey {
    let mut l: Labels = labels
        .iter()
        .map(|&(k, v)| (k.to_string(), v.to_string()))
        .collect();
    l.sort();
    (name.to_string(), l)
}

/// Render a metric identity as `name{k=v,...}` (no braces when unlabelled).
pub fn metric_id(name: &str, labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let inner: Vec<String> = labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
    format!("{}{{{}}}", name, inner.join(","))
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    counts: BTreeMap<MetricKey, u64>,
    sums: BTreeMap<MetricKey, f64>,
    series: BTreeMap<MetricKey, Vec<(SimNs, f64)>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a monotone counter.
    pub fn count(&mut self, name: &str, labels: &[(&str, &str)], n: u64) {
        *self.counts.entry(key(name, labels)).or_insert(0) += n;
    }

    /// Accumulate into a running sum (e.g. nanoseconds, bytes).
    pub fn add(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        *self.sums.entry(key(name, labels)).or_insert(0.0) += v;
    }

    /// Append one `(simulated ns, value)` sample to a time series.
    pub fn series_push(&mut self, name: &str, labels: &[(&str, &str)], t_ns: SimNs, v: f64) {
        self.series.entry(key(name, labels)).or_default().push((t_ns, v));
    }

    pub fn get_count(&self, name: &str, labels: &[(&str, &str)]) -> u64 {
        self.counts.get(&key(name, labels)).copied().unwrap_or(0)
    }

    pub fn get_sum(&self, name: &str, labels: &[(&str, &str)]) -> f64 {
        self.sums.get(&key(name, labels)).copied().unwrap_or(0.0)
    }

    pub fn get_series(&self, name: &str, labels: &[(&str, &str)]) -> &[(SimNs, f64)] {
        self.series
            .get(&key(name, labels))
            .map(Vec::as_slice)
            .unwrap_or(&[])
    }

    /// Linear-interpolated percentile of a series' sampled *values*
    /// (timestamps ignored), e.g. `series_percentile("component_ns",
    /// &[("component", "spmv")], 95.0)` for the p95 per-dispatch time.
    /// `None` for an empty/unknown series or a non-finite `pct`.
    pub fn series_percentile(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        pct: f64,
    ) -> Option<f64> {
        let samples = self.get_series(name, labels);
        if samples.is_empty() || !pct.is_finite() {
            return None;
        }
        let mut vals: Vec<f64> = samples.iter().map(|&(_, v)| v).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        Some(crate::util::stats::percentile_sorted(
            &vals,
            pct.clamp(0.0, 100.0),
        ))
    }

    /// The standard latency trio `(p50, p95, p99)` of a series' values.
    /// `None` when the series has no samples.
    pub fn series_quantiles(
        &self,
        name: &str,
        labels: &[(&str, &str)],
    ) -> Option<(f64, f64, f64)> {
        Some((
            self.series_percentile(name, labels, 50.0)?,
            self.series_percentile(name, labels, 95.0)?,
            self.series_percentile(name, labels, 99.0)?,
        ))
    }

    /// Sum of every `sums` entry whose metric name matches, across labels.
    pub fn sum_over_labels(&self, name: &str) -> f64 {
        self.sums
            .iter()
            .filter(|((n, _), _)| n == name)
            .map(|(_, v)| v)
            .sum()
    }

    /// Sum of every counter whose metric name matches, across labels.
    pub fn count_over_labels(&self, name: &str) -> u64 {
        self.counts
            .iter()
            .filter(|((n, _), _)| n == name)
            .map(|(_, v)| v)
            .sum()
    }

    pub fn counts(&self) -> impl Iterator<Item = (String, u64)> + '_ {
        self.counts
            .iter()
            .map(|((n, l), &v)| (metric_id(n, l), v))
    }

    pub fn sums(&self) -> impl Iterator<Item = (String, f64)> + '_ {
        self.sums.iter().map(|((n, l), &v)| (metric_id(n, l), v))
    }

    /// All time series as `(id, samples)`, sorted by id (BTreeMap order).
    pub fn all_series(&self) -> impl Iterator<Item = (String, &[(SimNs, f64)])> + '_ {
        self.series
            .iter()
            .map(|((n, l), v)| (metric_id(n, l), v.as_slice()))
    }

    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (k, &v) in &other.counts {
            *self.counts.entry(k.clone()).or_insert(0) += v;
        }
        for (k, &v) in &other.sums {
            *self.sums.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, v) in &other.series {
            self.series.entry(k.clone()).or_default().extend_from_slice(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_order_insensitive() {
        let mut m = MetricsRegistry::new();
        m.count("dispatches", &[("component", "dot"), ("die", "0")], 1);
        m.count("dispatches", &[("die", "0"), ("component", "dot")], 2);
        assert_eq!(
            m.get_count("dispatches", &[("component", "dot"), ("die", "0")]),
            3
        );
    }

    #[test]
    fn sums_series_and_rollups() {
        let mut m = MetricsRegistry::new();
        m.add("eth_bytes", &[("component", "spmv")], 100.0);
        m.add("eth_bytes", &[("component", "dot")], 50.0);
        m.series_push("residual", &[], 10.0, 1.0);
        m.series_push("residual", &[], 20.0, 0.5);
        assert_eq!(m.sum_over_labels("eth_bytes"), 150.0);
        assert_eq!(m.get_series("residual", &[]), &[(10.0, 1.0), (20.0, 0.5)]);
    }

    #[test]
    fn metric_ids_are_stable() {
        let mut m = MetricsRegistry::new();
        m.count("x", &[("b", "2"), ("a", "1")], 1);
        let ids: Vec<String> = m.counts().map(|(id, _)| id).collect();
        assert_eq!(ids, vec!["x{a=1,b=2}".to_string()]);
        assert_eq!(metric_id("plain", &[]), "plain");
    }

    #[test]
    fn percentiles_on_known_distributions() {
        // 1..=100 uniform: with linear interpolation over rank
        // pct/100*(len-1), p50 = 50.5, p95 = 95.05, p99 = 99.01.
        let mut m = MetricsRegistry::new();
        for i in 1..=100u32 {
            m.series_push("lat", &[("k", "v")], i as f64, f64::from(i));
        }
        let p50 = m.series_percentile("lat", &[("k", "v")], 50.0).unwrap();
        let p95 = m.series_percentile("lat", &[("k", "v")], 95.0).unwrap();
        let p99 = m.series_percentile("lat", &[("k", "v")], 99.0).unwrap();
        assert!((p50 - 50.5).abs() < 1e-9);
        assert!((p95 - 95.05).abs() < 1e-9);
        assert!((p99 - 99.01).abs() < 1e-9);
        assert_eq!(m.series_percentile("lat", &[("k", "v")], 0.0), Some(1.0));
        assert_eq!(m.series_percentile("lat", &[("k", "v")], 100.0), Some(100.0));
        assert_eq!(
            m.series_quantiles("lat", &[("k", "v")]),
            Some((p50, p95, p99))
        );
    }

    #[test]
    fn percentiles_ignore_insertion_order_and_timestamps() {
        // Same multiset pushed in two orders with scrambled timestamps
        // yields identical percentiles: only the values matter.
        let vals = [9.0, 1.0, 7.0, 3.0, 5.0];
        let mut a = MetricsRegistry::new();
        let mut b = MetricsRegistry::new();
        for (i, &v) in vals.iter().enumerate() {
            a.series_push("s", &[], i as f64, v);
        }
        for (i, &v) in vals.iter().rev().enumerate() {
            b.series_push("s", &[], 1000.0 - i as f64, v);
        }
        for pct in [0.0, 25.0, 50.0, 75.0, 95.0, 100.0] {
            assert_eq!(
                a.series_percentile("s", &[], pct),
                b.series_percentile("s", &[], pct)
            );
        }
        // Median of {1,3,5,7,9} is the middle sample exactly.
        assert_eq!(a.series_percentile("s", &[], 50.0), Some(5.0));
    }

    #[test]
    fn percentile_edge_cases() {
        let mut m = MetricsRegistry::new();
        // Absent series: None at every pct.
        assert_eq!(m.series_percentile("missing", &[], 50.0), None);
        assert_eq!(m.series_quantiles("missing", &[]), None);
        // Single sample: every percentile returns it.
        m.series_push("one", &[], 0.0, 42.0);
        for pct in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(m.series_percentile("one", &[], pct), Some(42.0));
        }
        // Out-of-range pcts clamp; non-finite pcts are rejected.
        assert_eq!(m.series_percentile("one", &[], -10.0), Some(42.0));
        assert_eq!(m.series_percentile("one", &[], 250.0), Some(42.0));
        assert_eq!(m.series_percentile("one", &[], f64::NAN), None);
    }

    #[test]
    fn merge_adds_and_extends() {
        let mut a = MetricsRegistry::new();
        a.count("launches", &[], 1);
        a.series_push("s", &[], 1.0, 1.0);
        let mut b = MetricsRegistry::new();
        b.count("launches", &[], 2);
        b.add("ns", &[], 5.0);
        b.series_push("s", &[], 2.0, 2.0);
        a.merge(&b);
        assert_eq!(a.get_count("launches", &[]), 3);
        assert_eq!(a.get_sum("ns", &[]), 5.0);
        assert_eq!(a.get_series("s", &[]), &[(1.0, 1.0), (2.0, 2.0)]);
    }
}
