//! Causal span graph: *which dependency chain gated wall time*.
//!
//! The resource ledger (PR 6) answers "where did the nanoseconds go";
//! this module answers the question the ledger cannot: which chain of
//! dependent work the simulated clock actually waited on. During
//! [`crate::ttm::exec::execute_program`] every timing composition rule —
//! per-sender NoC queues, halo-gates-compute, the reduce-tree merge
//! order, the serial/pipelined seam rules — is recorded as a [`Span`]
//! with explicit dependency edges, and the solvers assemble the
//! per-dispatch program graphs plus the host launch/gap/readback chain
//! into one solve-wide graph.
//!
//! **The invariant** (the analogue of ledger conservation): every span
//! starts *exactly* when its latest predecessor ends —
//! `span.start == max(pred.end)`, bit-for-bit. [`SpanGraph::span`]
//! enforces it by construction: predecessors that end after the span
//! starts are dropped (they were not gating), and any positive gap to
//! the latest remaining predecessor is bridged by an explicit `wait`
//! span on [`Resource::Idle`]. Two properties fall out and are enforced
//! by `tests/prop_critpath.rs`:
//!
//! - the critical path ([`crate::telemetry::critical_path`]) is a
//!   contiguous chain from the graph origin to the sink, so its length
//!   equals the simulated wall time exactly;
//! - the identity what-if ([`crate::telemetry::retime`] with all scales
//!   = 1.0) reproduces every recorded end time bit-exactly.
//!
//! Graphs compose: [`SpanGraph::append_anchored`] grafts a program's
//! graph (recorded at device start 0) into a solve graph at its dispatch
//! window by adding one constant offset to every time. Adding the same
//! constant to identical floats preserves both the ordering and the
//! `max` structure, so the invariant survives re-anchoring bit-exactly.

use crate::telemetry::Resource;
use crate::timing::SimNs;

/// Index of the origin span every [`SpanGraph`] is created with.
pub const ORIGIN: usize = 0;

/// One unit of causally-ordered work (or an explicit wait) on a resource.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Human-readable label ("dram c3", "eth:halo", "enqueue(spmv)").
    pub name: String,
    /// Solve component this span belongs to ("spmv", "dot", …; "host"
    /// for the dispatch chain, "" inside a bare program graph).
    pub component: String,
    /// Resource class the span's duration is charged to (and that the
    /// what-if re-timer scales).
    pub resource: Resource,
    pub start: SimNs,
    pub end: SimNs,
    /// For [`Resource::Ethernet`] spans: the portion of the duration
    /// that is fixed per-hop link latency rather than payload transfer
    /// (`hops * link.latency_ns`, clamped to the span's duration). The
    /// what-if re-timer scales this part with `eth_lat=` and the
    /// remainder with `eth_bw=`. 0 for every other resource.
    pub lat_ns: SimNs,
    /// Indices of gating predecessors; always < this span's own index,
    /// so span order is a topological order.
    pub preds: Vec<usize>,
}

impl Span {
    pub fn duration(&self) -> SimNs {
        self.end - self.start
    }
}

/// The causal span graph of one program execution or one whole solve.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SpanGraph {
    pub spans: Vec<Span>,
    /// Graph origin time (program/solve start).
    pub t0: SimNs,
    /// The designated terminal span (the solve's last clock advance).
    /// Wall time is `sink.end - t0`; ulp-level float drift on detail
    /// spans past the sink is deliberately ignored.
    sink: Option<usize>,
}

impl SpanGraph {
    /// New graph with the zero-duration origin span at `t0`.
    pub fn new(t0: SimNs) -> Self {
        Self {
            spans: vec![Span {
                name: "origin".to_string(),
                component: String::new(),
                resource: Resource::Idle,
                start: t0,
                end: t0,
                lat_ns: 0.0,
                preds: Vec::new(),
            }],
            t0,
            sink: None,
        }
    }

    /// True when no spans beyond the origin were recorded (e.g. the
    /// solve ran with telemetry off).
    pub fn is_empty(&self) -> bool {
        self.spans.len() <= 1
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn sink(&self) -> Option<usize> {
        self.sink
    }

    pub fn set_sink(&mut self, id: usize) {
        debug_assert!(id < self.spans.len());
        self.sink = Some(id);
    }

    /// Wall time the graph describes: sink end minus origin. 0 when no
    /// sink was designated.
    pub fn wall_ns(&self) -> SimNs {
        self.sink.map_or(0.0, |s| self.spans[s].end - self.t0)
    }

    /// Add a span, enforcing the gating invariant: predecessors ending
    /// after `start` are dropped (not gating), a missing predecessor
    /// falls back to the origin, and a positive gap to the latest
    /// remaining predecessor is bridged with an explicit `wait` span on
    /// [`Resource::Idle`]. After this, `start == max(pred.end)` holds
    /// bit-exactly. Returns the new span's index.
    pub fn span(
        &mut self,
        name: impl Into<String>,
        component: &str,
        resource: Resource,
        start: SimNs,
        end: SimNs,
        preds: &[usize],
    ) -> usize {
        debug_assert!(end >= start, "span must not end before it starts");
        let mut eff: Vec<usize> = preds
            .iter()
            .copied()
            .filter(|&p| p < self.spans.len() && self.spans[p].end <= start)
            .collect();
        eff.dedup();
        if eff.is_empty() && start >= self.t0 {
            eff.push(ORIGIN);
        }
        if let Some(&latest) = eff
            .iter()
            .max_by(|&&a, &&b| self.spans[a].end.partial_cmp(&self.spans[b].end).unwrap())
        {
            let m = self.spans[latest].end;
            if m < start {
                let bridge = self.push_raw(
                    "wait".to_string(),
                    component,
                    Resource::Idle,
                    m,
                    start,
                    vec![latest],
                );
                eff.push(bridge);
            }
        }
        self.push_raw(name.into(), component, resource, start, end, eff)
    }

    /// Append a span verbatim, trusting the caller to uphold the gating
    /// invariant (used by [`append_anchored`](Self::append_anchored)).
    fn push_raw(
        &mut self,
        name: String,
        component: &str,
        resource: Resource,
        start: SimNs,
        end: SimNs,
        preds: Vec<usize>,
    ) -> usize {
        let id = self.spans.len();
        self.spans.push(Span {
            name,
            component: component.to_string(),
            resource,
            start,
            end,
            lat_ns: 0.0,
            preds,
        });
        id
    }

    /// Graft another graph (a program execution recorded at device start
    /// `sub.t0`) into this one at `anchor`'s end: every time shifts by
    /// the constant `anchor.end - sub.t0`, every span is tagged with
    /// `component`, and the sub-graph's origin gains `anchor` as its
    /// predecessor. Returns the mapped index of `sub`'s sink (or of its
    /// origin when `sub` never designated one).
    ///
    /// Exactness: for the grafted sink to land bit-exactly on the
    /// solver's own clock arithmetic, `sub.t0` must be `0.0` — then the
    /// offset is `anchor.end` itself and `origin + offset == anchor.end`
    /// with no rounding. The solvers pre-execute their component
    /// programs at device start 0 for precisely this reason.
    pub fn append_anchored(&mut self, sub: &SpanGraph, anchor: usize, component: &str) -> usize {
        debug_assert!(anchor < self.spans.len());
        let c = self.spans[anchor].end - sub.t0;
        let base = self.spans.len();
        for (i, s) in sub.spans.iter().enumerate() {
            let preds = if i == ORIGIN {
                vec![anchor]
            } else {
                s.preds.iter().map(|&p| p + base).collect()
            };
            let id = self.push_raw(
                s.name.clone(),
                component,
                s.resource,
                s.start + c,
                s.end + c,
                preds,
            );
            self.spans[id].lat_ns = s.lat_ns;
        }
        base + sub.sink.unwrap_or(ORIGIN)
    }

    /// Check the gating invariant on every span: `start == max(pred.end)`
    /// exactly (origin and pred-less spans excepted). Returns the first
    /// violation as an error string.
    pub fn validate(&self) -> Result<(), String> {
        for (i, s) in self.spans.iter().enumerate() {
            if s.preds.is_empty() {
                continue;
            }
            let mut m = f64::NEG_INFINITY;
            for &p in &s.preds {
                if p >= i {
                    return Err(format!("span {i} '{}' has forward pred {p}", s.name));
                }
                m = m.max(self.spans[p].end);
            }
            if m != s.start {
                return Err(format!(
                    "span {i} '{}' starts at {} but its latest pred ends at {}",
                    s.name, s.start, m
                ));
            }
            if s.end < s.start {
                return Err(format!("span {i} '{}' ends before it starts", s.name));
            }
        }
        Ok(())
    }

    /// Derive Perfetto flow arrows from the graph's cross-transport
    /// edges: every dependency into or out of an Ethernet span (the
    /// cross-die causality the traces could not show), idle bridges
    /// excluded. The `s`/`f` pair shares `id`; timestamps are the edge's
    /// meeting point on each side.
    pub fn flow_events(&self) -> Vec<crate::profiler::FlowEvent> {
        let scope_of = |r: Resource| match r {
            Resource::Ethernet => "ethernet",
            Resource::Dispatch => "host",
            _ => "device",
        };
        let mut flows = Vec::new();
        for (i, s) in self.spans.iter().enumerate() {
            for &p in &s.preds {
                let ps = &self.spans[p];
                let cross_eth = (s.resource == Resource::Ethernet)
                    != (ps.resource == Resource::Ethernet);
                if !cross_eth
                    || s.resource == Resource::Idle
                    || ps.resource == Resource::Idle
                    || p == ORIGIN
                {
                    continue;
                }
                flows.push(crate::profiler::FlowEvent {
                    name: format!("{}->{}", ps.name, s.name),
                    id: flows.len() as u64 + 1,
                    from_scope: scope_of(ps.resource).to_string(),
                    from_ts: ps.end,
                    to_scope: scope_of(s.resource).to_string(),
                    to_ts: s.start,
                });
                let _ = i;
            }
        }
        flows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invariant_enforced_by_construction() {
        let mut g = SpanGraph::new(0.0);
        let a = g.span("a", "c", Resource::Compute, 0.0, 10.0, &[]);
        // Gap to the latest pred is bridged by an idle wait.
        let b = g.span("b", "c", Resource::Noc, 15.0, 20.0, &[a]);
        assert_eq!(g.spans[b].preds.len(), 2, "original pred + bridge");
        // A pred that ends after the span starts is dropped (not gating).
        let d = g.span("d", "c", Resource::Dram, 10.0, 12.0, &[a, b]);
        assert_eq!(g.spans[d].preds, vec![a]);
        g.set_sink(b);
        g.validate().unwrap();
        assert_eq!(g.wall_ns(), 20.0);
    }

    #[test]
    fn predless_span_falls_back_to_origin() {
        let mut g = SpanGraph::new(5.0);
        let a = g.span("a", "", Resource::Compute, 5.0, 9.0, &[]);
        assert_eq!(g.spans[a].preds, vec![ORIGIN]);
        g.validate().unwrap();
    }

    #[test]
    fn append_anchored_shifts_and_rewires() {
        let mut sub = SpanGraph::new(0.0);
        let a = sub.span("work", "", Resource::Compute, 0.0, 7.0, &[]);
        sub.spans[a].lat_ns = 2.0;
        sub.set_sink(a);

        let mut g = SpanGraph::new(0.0);
        let launch = g.span("launch", "host", Resource::Dispatch, 0.0, 3.0, &[]);
        let sink = g.append_anchored(&sub, launch, "spmv");
        assert_eq!(g.spans[sink].end, 10.0);
        assert_eq!(g.spans[sink].component, "spmv");
        assert_eq!(g.spans[sink].lat_ns, 2.0, "lat split survives re-anchoring");
        g.set_sink(sink);
        g.validate().unwrap();
        assert_eq!(g.wall_ns(), 10.0);
    }

    #[test]
    fn flow_events_cross_ethernet_edges_only() {
        let mut g = SpanGraph::new(0.0);
        let a = g.span("compute", "spmv", Resource::Compute, 0.0, 4.0, &[]);
        let e = g.span("eth:halo", "spmv", Resource::Ethernet, 4.0, 9.0, &[a]);
        let b = g.span("boundary", "spmv", Resource::Compute, 9.0, 11.0, &[e]);
        let _ = g.span("dram", "spmv", Resource::Dram, 0.0, 2.0, &[]);
        let flows = g.flow_events();
        assert_eq!(flows.len(), 2);
        assert_eq!(flows[0].from_scope, "device");
        assert_eq!(flows[0].to_scope, "ethernet");
        assert_eq!(flows[1].from_ts, g.spans[e].end);
        assert_eq!(flows[1].to_ts, g.spans[b].start);
        // Ids are unique and nonzero.
        assert_ne!(flows[0].id, flows[1].id);
    }
}
