//! Critical-path extraction, slack analysis, and the what-if re-timer.
//!
//! Operates on the [`SpanGraph`] recorded by the executor and solvers.
//! Because the graph upholds `span.start == max(pred.end)` bit-exactly
//! (see `telemetry::spans`), the longest dependency chain can be walked
//! *backwards* from the sink by exact float equality — at every span
//! some predecessor ends exactly when the span starts — and its length
//! telescopes to `sink.end - t0`, i.e. the simulated wall time, with no
//! accumulated rounding. That equality is the module's conservation
//! property, enforced by `tests/prop_critpath.rs` the same way ledger
//! conservation is.
//!
//! The what-if re-timer answers "what if Ethernet bandwidth doubled /
//! dispatch were free / the NoC were 1.5× faster" without re-simulating:
//! it re-walks the recorded graph in topological order, scaling each
//! span's duration by its resource's factor. Durations are recorded
//! facts, the dependency structure is recorded causality, so the result
//! is an Amdahl-style ceiling — real overlap-restructuring gains (e.g.
//! a smarter schedule) are out of scope by construction.

use std::collections::BTreeMap;

use crate::telemetry::spans::{SpanGraph, ORIGIN};
use crate::telemetry::Resource;
use crate::timing::SimNs;
use crate::util::stats::fmt_ns;

/// The extracted longest dependency chain.
#[derive(Debug, Clone, PartialEq)]
pub struct CritPath {
    /// Span indices from origin-side to sink, contiguous in time.
    pub ids: Vec<usize>,
    /// `sink.end - t0` — equals simulated wall time exactly.
    pub length_ns: SimNs,
}

/// Walk the critical path back from the graph's sink. At each step the
/// gating predecessor is the one whose end equals the span's start
/// (exact float equality, guaranteed by construction); ties prefer
/// non-idle spans, then longer ones, for the most informative path.
/// Errors if the chain is ever discontinuous — that would mean the
/// graph was not built through [`SpanGraph::span`]'s invariant.
pub fn critical_path(g: &SpanGraph) -> Result<CritPath, String> {
    let sink = g.sink().ok_or("span graph has no sink")?;
    let mut ids = vec![sink];
    let mut cur = sink;
    loop {
        let s = &g.spans[cur];
        if s.preds.is_empty() {
            break;
        }
        let gating = s
            .preds
            .iter()
            .copied()
            .filter(|&p| g.spans[p].end == s.start)
            .max_by(|&a, &b| {
                let (sa, sb) = (&g.spans[a], &g.spans[b]);
                (sa.resource != Resource::Idle, sa.duration())
                    .partial_cmp(&(sb.resource != Resource::Idle, sb.duration()))
                    .unwrap()
            });
        match gating {
            Some(p) => {
                ids.push(p);
                cur = p;
            }
            None => {
                return Err(format!(
                    "critical path broke at span {cur} '{}': no predecessor ends at {}",
                    s.name, s.start
                ))
            }
        }
    }
    ids.reverse();
    Ok(CritPath {
        ids,
        length_ns: g.spans[sink].end - g.t0,
    })
}

/// Per-resource critical-path share and slack.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceCrit {
    pub resource: Resource,
    /// Time this resource spends on the critical path.
    pub crit_ns: SimNs,
    /// `crit_ns / wall` (0 when wall is 0).
    pub frac: f64,
    /// Classic CPM slack: the smallest amount any span of this resource
    /// could slip without delaying the sink. 0 when the resource is on
    /// the critical path; equal to wall when the resource never appears.
    pub slack_ns: SimNs,
}

/// Full critical-path report for one solve or program graph.
#[derive(Debug, Clone, PartialEq)]
pub struct CritPathReport {
    pub wall_ns: SimNs,
    pub path: CritPath,
    /// One row per resource in `Resource::ALL` order.
    pub per_resource: Vec<ResourceCrit>,
    /// Critical nanoseconds per solve component, descending.
    pub per_component: Vec<(String, SimNs)>,
    /// Critical nanoseconds aggregated by span name, descending (top 10).
    pub top_spans: Vec<(String, SimNs)>,
}

impl CritPathReport {
    /// Critical-path fraction for one resource.
    pub fn frac(&self, r: Resource) -> f64 {
        self.per_resource
            .iter()
            .find(|row| row.resource == r)
            .map_or(0.0, |row| row.frac)
    }

    /// Render the human-readable report printed by `wormsim critpath`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "critical path: {} spans, {} (= wall time)\n",
            self.path.ids.len(),
            fmt_ns(self.wall_ns)
        ));
        out.push_str("  resource     crit-frac   crit-time     slack\n");
        for row in &self.per_resource {
            if row.crit_ns == 0.0 && row.slack_ns >= self.wall_ns {
                continue; // resource never appears in the graph
            }
            out.push_str(&format!(
                "  {:<12} {:>8.1}%  {:>10}  {:>10}\n",
                row.resource.label(),
                row.frac * 100.0,
                fmt_ns(row.crit_ns),
                fmt_ns(row.slack_ns)
            ));
        }
        if !self.per_component.is_empty() {
            out.push_str("  critical time by component:\n");
            for (name, ns) in &self.per_component {
                let label = if name.is_empty() { "(program)" } else { name };
                out.push_str(&format!(
                    "    {:<14} {:>10}  ({:.1}%)\n",
                    label,
                    fmt_ns(*ns),
                    if self.wall_ns > 0.0 { ns / self.wall_ns * 100.0 } else { 0.0 }
                ));
            }
        }
        if !self.top_spans.is_empty() {
            out.push_str("  top critical spans:\n");
            for (name, ns) in &self.top_spans {
                out.push_str(&format!("    {:<24} {:>10}\n", name, fmt_ns(*ns)));
            }
        }
        out
    }
}

/// Extract the critical path and compute per-resource fractions and CPM
/// slack (backward pass over the DAG).
pub fn analyze(g: &SpanGraph) -> Result<CritPathReport, String> {
    let path = critical_path(g)?;
    let sink = g.sink().expect("critical_path verified the sink");
    let wall = g.spans[sink].end - g.t0;

    // Backward pass: latest end each span may have without delaying the
    // sink. Spans with no successors cannot delay anything.
    let n = g.spans.len();
    let mut latest = vec![f64::INFINITY; n];
    latest[sink] = g.spans[sink].end;
    for i in (0..n).rev() {
        if latest[i] == f64::INFINITY {
            latest[i] = g.spans[sink].end.max(g.spans[i].end);
        }
        let latest_start = latest[i] - g.spans[i].duration();
        for &p in &g.spans[i].preds {
            latest[p] = latest[p].min(latest_start);
        }
    }

    let mut crit_ns: BTreeMap<Resource, SimNs> = BTreeMap::new();
    let mut by_component: BTreeMap<String, SimNs> = BTreeMap::new();
    let mut by_name: BTreeMap<String, SimNs> = BTreeMap::new();
    for &i in &path.ids {
        let s = &g.spans[i];
        *crit_ns.entry(s.resource).or_insert(0.0) += s.duration();
        if s.duration() > 0.0 {
            *by_component.entry(s.component.clone()).or_insert(0.0) += s.duration();
            *by_name.entry(s.name.clone()).or_insert(0.0) += s.duration();
        }
    }
    let mut slack: BTreeMap<Resource, SimNs> = BTreeMap::new();
    for (i, s) in g.spans.iter().enumerate() {
        let sl = (latest[i] - s.end).max(0.0);
        slack
            .entry(s.resource)
            .and_modify(|v| *v = v.min(sl))
            .or_insert(sl);
    }

    let per_resource = Resource::ALL
        .iter()
        .map(|&r| {
            let c = crit_ns.get(&r).copied().unwrap_or(0.0);
            ResourceCrit {
                resource: r,
                crit_ns: c,
                frac: if wall > 0.0 { c / wall } else { 0.0 },
                slack_ns: slack.get(&r).copied().unwrap_or(wall),
            }
        })
        .collect();
    let mut per_component: Vec<(String, SimNs)> = by_component.into_iter().collect();
    per_component.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let mut top_spans: Vec<(String, SimNs)> = by_name.into_iter().collect();
    top_spans.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    top_spans.truncate(10);

    Ok(CritPathReport {
        wall_ns: wall,
        path,
        per_resource,
        per_component,
        top_spans,
    })
}

/// Counterfactual duration scalings per resource for the re-timer.
///
/// Spec grammar (comma-separated): `<key>=<value>` where the key names
/// a resource (`eth`/`eth_bw`, `noc`/`noc_bw`, `dram`/`dram_bw`,
/// `compute`, `riscv`, `dispatch`, `idle`) and the value is either a
/// plain duration multiplier (`dispatch=0`, `compute=0.5`) or a speedup
/// factor with an `x` suffix meaning *that many times faster*, i.e. the
/// duration divides (`eth_bw=2x` halves Ethernet durations). Keys
/// ending in `_bw` always read as speedups.
///
/// `eth_lat=` is a separate knob, not a resource: Ethernet spans carry
/// a recorded latency portion (`Span::lat_ns` — the per-round fixed
/// link latency; all-reduces are nearly pure latency, halos mostly
/// payload). `eth_lat` scales only that portion while `eth`/`eth_bw`
/// scales only the remainder, so "what if the link latency halved"
/// (`eth_lat=2x`) and "what if bandwidth doubled" (`eth_bw=2x`) answer
/// different questions — exactly the split that predicts the s-step
/// schedule's win before building it.
#[derive(Debug, Clone, PartialEq)]
pub struct WhatIf {
    scales: BTreeMap<Resource, f64>,
    /// Duration multiplier for the latency portion of Ethernet spans.
    eth_lat: f64,
}

impl Default for WhatIf {
    fn default() -> Self {
        Self::identity()
    }
}

impl WhatIf {
    /// No scaling: every duration multiplier is 1.0.
    pub fn identity() -> Self {
        Self {
            scales: BTreeMap::new(),
            eth_lat: 1.0,
        }
    }

    pub fn is_identity(&self) -> bool {
        self.scales.values().all(|&s| s == 1.0) && self.eth_lat == 1.0
    }

    /// Duration multiplier for one resource (1.0 unless scaled).
    pub fn scale(&self, r: Resource) -> f64 {
        self.scales.get(&r).copied().unwrap_or(1.0)
    }

    /// Duration multiplier for the latency portion of Ethernet spans.
    pub fn eth_lat_scale(&self) -> f64 {
        self.eth_lat
    }

    /// Set one resource's duration multiplier.
    pub fn with(mut self, r: Resource, scale: f64) -> Self {
        self.scales.insert(r, scale);
        self
    }

    /// Set the Ethernet-latency duration multiplier.
    pub fn with_eth_lat(mut self, scale: f64) -> Self {
        self.eth_lat = scale;
        self
    }

    /// Parse a `--what-if` spec like `eth_bw=2x,eth_lat=4x,dispatch=0`.
    pub fn parse(spec: &str) -> Result<Self, String> {
        /// One entry's value as a duration multiplier: an `x` suffix
        /// reads as a speedup (duration divides); a plain number is a
        /// multiplier unless `default_speedup` (the `_bw` keys).
        fn scale_of(entry: &str, value: &str, default_speedup: bool) -> Result<f64, String> {
            let value = value.trim();
            let (num, is_speedup) = match value.strip_suffix('x') {
                Some(v) => (v, true),
                None => (value, default_speedup),
            };
            let f: f64 = num
                .parse()
                .map_err(|_| format!("what-if value '{value}' is not a number"))?;
            if !f.is_finite() || f < 0.0 {
                return Err(format!("what-if value '{value}' must be finite and >= 0"));
            }
            if is_speedup {
                if f <= 0.0 {
                    return Err(format!("speedup factor in '{entry}' must be > 0"));
                }
                Ok(1.0 / f)
            } else {
                Ok(f)
            }
        }
        let mut w = Self::identity();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (key, value) = entry
                .split_once('=')
                .ok_or_else(|| format!("what-if entry '{entry}' is not key=value"))?;
            let key = key.trim();
            if key == "eth_lat" {
                w.eth_lat = scale_of(entry, value, false)?;
                continue;
            }
            let resource = match key.trim_end_matches("_bw") {
                "eth" | "ethernet" => Resource::Ethernet,
                "noc" => Resource::Noc,
                "dram" => Resource::Dram,
                "compute" => Resource::Compute,
                "riscv" | "risc-v" => Resource::Riscv,
                "dispatch" | "launch" => Resource::Dispatch,
                "retry" => Resource::Retry,
                "idle" => Resource::Idle,
                other => return Err(format!("unknown what-if resource '{other}'")),
            };
            w.scales
                .insert(resource, scale_of(entry, value, key.ends_with("_bw"))?);
        }
        Ok(w)
    }

    /// Human-readable summary of the scalings, e.g. `ethernet x0.50`.
    pub fn describe(&self) -> String {
        if self.is_identity() {
            return "identity".to_string();
        }
        let mut parts: Vec<String> = self
            .scales
            .iter()
            .map(|(r, s)| format!("{} x{:.3}", r.label(), s))
            .collect();
        if self.eth_lat != 1.0 {
            parts.push(format!("eth_lat x{:.3}", self.eth_lat));
        }
        parts.join(", ")
    }
}

/// Re-walk the graph under counterfactual duration scalings and return
/// the predicted wall time (`sink.end' - t0`).
///
/// Rule per span, in topological (construction) order: the new start is
/// the max of the new predecessor ends (roots keep their recorded
/// start); the new end is `start' + scale(resource) * duration`. When a
/// span's start is unchanged and its resource unscaled, the *recorded*
/// end is reused verbatim — which is why the identity what-if
/// reproduces the simulated solve time bit-exactly rather than merely
/// to rounding error.
pub fn retime(g: &SpanGraph, w: &WhatIf) -> Result<SimNs, String> {
    let sink = g.sink().ok_or("span graph has no sink")?;
    let mut end = vec![0.0_f64; g.spans.len()];
    for (i, s) in g.spans.iter().enumerate() {
        let start = if s.preds.is_empty() {
            s.start
        } else {
            s.preds
                .iter()
                .map(|&p| end[p])
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let k = w.scale(s.resource);
        let lat_split = s.resource == Resource::Ethernet && s.lat_ns > 0.0;
        end[i] = if start == s.start && k == 1.0 && (w.eth_lat == 1.0 || !lat_split) {
            // Unchanged start, unscaled resource: reuse the recorded end
            // verbatim (the identity what-if stays bit-exact).
            s.end
        } else if lat_split {
            // Ethernet spans split into a fixed-latency portion (scaled
            // by `eth_lat=`) and a payload portion (scaled by the
            // resource factor, i.e. `eth_bw=`).
            let lat = s.lat_ns.min(s.end - s.start);
            start + w.eth_lat * lat + k * ((s.end - s.start) - lat)
        } else {
            start + k * (s.end - s.start)
        };
    }
    let _ = ORIGIN;
    Ok(end[sink] - g.t0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small diamond: dispatch -> {compute, eth} -> join, with the
    /// Ethernet arm longer (on the critical path).
    fn diamond() -> SpanGraph {
        let mut g = SpanGraph::new(0.0);
        let d = g.span("launch", "host", Resource::Dispatch, 0.0, 10.0, &[]);
        let c = g.span("compute", "spmv", Resource::Compute, 10.0, 40.0, &[d]);
        let e = g.span("eth:halo", "spmv", Resource::Ethernet, 10.0, 90.0, &[d]);
        let j = g.span("join", "spmv", Resource::Noc, 90.0, 100.0, &[c, e]);
        g.set_sink(j);
        g
    }

    #[test]
    fn walks_the_gating_chain_and_matches_wall() {
        let g = diamond();
        let p = critical_path(&g).unwrap();
        assert_eq!(p.length_ns, 100.0);
        let names: Vec<&str> = p.ids.iter().map(|&i| g.spans[i].name.as_str()).collect();
        assert_eq!(names, vec!["origin", "launch", "eth:halo", "join"]);
    }

    #[test]
    fn report_fractions_and_slack() {
        let g = diamond();
        let rep = analyze(&g).unwrap();
        assert_eq!(rep.wall_ns, 100.0);
        assert!((rep.frac(Resource::Ethernet) - 0.80).abs() < 1e-12);
        assert!((rep.frac(Resource::Dispatch) - 0.10).abs() < 1e-12);
        assert_eq!(rep.frac(Resource::Compute), 0.0);
        let compute = rep
            .per_resource
            .iter()
            .find(|r| r.resource == Resource::Compute)
            .unwrap();
        // Compute may slip 50 ns (ends at 40, join needs it by 90).
        assert_eq!(compute.slack_ns, 50.0);
        let eth = rep
            .per_resource
            .iter()
            .find(|r| r.resource == Resource::Ethernet)
            .unwrap();
        assert_eq!(eth.slack_ns, 0.0);
        let rendered = rep.render();
        assert!(rendered.contains("ethernet"));
        assert!(rendered.contains("= wall time"));
    }

    #[test]
    fn identity_retime_is_bit_exact() {
        let g = diamond();
        assert_eq!(retime(&g, &WhatIf::identity()).unwrap(), g.wall_ns());
    }

    #[test]
    fn what_if_scales_follow_amdahl() {
        let g = diamond();
        // Doubling Ethernet bandwidth halves the eth arm: 10 + 40 + 10,
        // but the compute arm (ends at 40) now gates the join equally.
        let w = WhatIf::parse("eth_bw=2x").unwrap();
        assert_eq!(retime(&g, &w).unwrap(), 60.0);
        // Free dispatch removes the leading 10 ns from both arms.
        let w = WhatIf::parse("dispatch=0").unwrap();
        assert_eq!(retime(&g, &w).unwrap(), 90.0);
        // Near-infinite ethernet speed and free dispatch: the compute
        // arm takes over (30 ns compute + 10 ns join).
        let w = WhatIf::parse("eth_bw=1000000x,dispatch=0").unwrap();
        assert!((retime(&g, &w).unwrap() - 40.0).abs() < 1e-3);
    }

    #[test]
    fn eth_lat_scales_only_the_latency_portion() {
        // Diamond with the eth arm's duration split: 80 ns total, of
        // which 30 ns is fixed per-round link latency.
        let mut g = diamond();
        let e = g
            .spans
            .iter()
            .position(|s| s.resource == Resource::Ethernet)
            .unwrap();
        g.spans[e].lat_ns = 30.0;

        // Identity stays bit-exact with the split recorded.
        assert_eq!(retime(&g, &WhatIf::identity()).unwrap(), g.wall_ns());

        // Free latency removes exactly the 30 ns latency portion:
        // 10 + (0 + 50) + 10.
        let w = WhatIf::parse("eth_lat=0").unwrap();
        assert_eq!(retime(&g, &w).unwrap(), 70.0);
        // Halving latency removes 15 ns: 10 + (15 + 50) + 10.
        let w = WhatIf::parse("eth_lat=2x").unwrap();
        assert_eq!(retime(&g, &w).unwrap(), 85.0);
        // Bandwidth now scales only the payload portion: 10 + (30 + 25)
        // + 10 — not the 60 ns the unsplit span would predict.
        let w = WhatIf::parse("eth_bw=2x").unwrap();
        assert_eq!(retime(&g, &w).unwrap(), 75.0);
        // Both knobs compose: 10 + (15 + 25) + 10.
        let w = WhatIf::parse("eth_bw=2x,eth_lat=2x").unwrap();
        assert_eq!(retime(&g, &w).unwrap(), 60.0);

        // Grammar + identity accounting for the new knob.
        assert_eq!(WhatIf::parse("eth_lat=4x").unwrap().eth_lat_scale(), 0.25);
        assert_eq!(WhatIf::parse("eth_lat=0.5").unwrap().eth_lat_scale(), 0.5);
        assert!(!WhatIf::parse("eth_lat=2x").unwrap().is_identity());
        assert!(WhatIf::parse("eth_lat=1").unwrap().is_identity());
        assert!(WhatIf::parse("eth_lat=2x").unwrap().describe().contains("eth_lat"));
        assert!(WhatIf::parse("eth_lat=nope").is_err());
        assert!(WhatIf::parse("eth_lat=-1").is_err());
    }

    #[test]
    fn parse_grammar() {
        let w = WhatIf::parse("eth_bw=2x, dispatch=0, noc_bw=1.5x").unwrap();
        assert_eq!(w.scale(Resource::Ethernet), 0.5);
        assert_eq!(w.scale(Resource::Dispatch), 0.0);
        assert!((w.scale(Resource::Noc) - 1.0 / 1.5).abs() < 1e-15);
        assert_eq!(w.scale(Resource::Compute), 1.0);
        // `_bw` keys read plain numbers as speedups too.
        let w = WhatIf::parse("dram_bw=4").unwrap();
        assert_eq!(w.scale(Resource::Dram), 0.25);
        assert!(WhatIf::parse("eth_bw").is_err());
        assert!(WhatIf::parse("warp=2x").is_err());
        assert!(WhatIf::parse("eth_bw=fast").is_err());
        assert!(WhatIf::parse("compute=-1").is_err());
        assert!(WhatIf::identity().is_identity());
        assert!(!WhatIf::parse("eth_bw=2x").unwrap().is_identity());
        assert!(WhatIf::parse("eth_bw=2x").unwrap().describe().contains("ethernet"));
    }
}
