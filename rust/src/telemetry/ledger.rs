//! Per-resource utilization attribution.
//!
//! A [`ResourceLedger`] splits a span of simulated wall-clock time into
//! mutually-exclusive resource buckets: where did the nanoseconds go?  The
//! executor ([`crate::ttm::exec::execute_program`]) builds one ledger per
//! program by attributing the *critical core's* own phase components plus the
//! marginal extensions contributed by the reduce tree, the broadcast, and the
//! Ethernet phase.  The invariant — enforced by `tests/prop_telemetry.rs` —
//! is *conservation*: the rows sum to the program's `device_ns()` wall time.
//!
//! Solvers accumulate per-dispatch program ledgers into a [`SolveLedger`]
//! (one row set per component plus a grand total), add the host dispatch
//! overheads (launch / gap / readback) as an explicit `Dispatch` row, and
//! book any gap between the charged component time and the program ledger as
//! `Idle` so the solve-level invariant holds by construction:
//! `ledger.total() == result.total_ns`.
//!
//! [`SolveLedger::verdict`] turns the grand total into the one-line
//! bottleneck statement the ISSUE asks for ("ethernet-bound (54% of solve,
//! dominated by dot, link 0-1)").

use std::collections::BTreeMap;

use crate::timing::SimNs;

/// The mutually-exclusive resources simulated time is attributed to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Resource {
    /// FPU/SFPU tile math on the compute core of the critical core.
    Compute,
    /// Baby RISC-V software overhead (issue loops, zero-fill, merges).
    Riscv,
    /// DRAM streaming latency/bandwidth.
    Dram,
    /// On-die NoC: data-movement wait, reduce tree, broadcast.
    Noc,
    /// Die-to-die Ethernet phases (marginal extension past local work).
    Ethernet,
    /// Host dispatch: kernel launches, inter-kernel gaps, residual readback.
    Dispatch,
    /// Fault handling: Ethernet timeout detection and bounded
    /// retry-with-backoff windows, plus epoch re-lowering stalls
    /// (populated only when a [`crate::device::FaultPlan`] fires).
    Retry,
    /// Charged-but-unattributed time (solver-level slack).
    Idle,
}

impl Resource {
    /// All resources, in display order.
    pub const ALL: [Resource; 8] = [
        Resource::Compute,
        Resource::Riscv,
        Resource::Dram,
        Resource::Noc,
        Resource::Ethernet,
        Resource::Dispatch,
        Resource::Retry,
        Resource::Idle,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Resource::Compute => "compute",
            Resource::Riscv => "risc-v",
            Resource::Dram => "dram",
            Resource::Noc => "noc",
            Resource::Ethernet => "ethernet",
            Resource::Dispatch => "dispatch",
            Resource::Retry => "retry",
            Resource::Idle => "idle",
        }
    }
}

/// Attribution of one span of simulated time to resources, plus per-link
/// Ethernet busy time for bottleneck identification.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ResourceLedger {
    rows: BTreeMap<Resource, SimNs>,
    /// Busy nanoseconds per Ethernet link `(min_die, max_die)` within the
    /// span (sum of transfer windows, not the marginal `Ethernet` row).
    pub eth_link_busy: Vec<((usize, usize), SimNs)>,
    /// The busiest Ethernet link, if any transfers happened.
    pub eth_bottleneck: Option<(usize, usize)>,
}

impl ResourceLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `ns` to `resource`'s row. Tiny negative values (floating-point
    /// cancellation in marginal attributions) are clamped to zero.
    pub fn add(&mut self, resource: Resource, ns: SimNs) {
        let ns = ns.max(0.0);
        if ns > 0.0 {
            *self.rows.entry(resource).or_insert(0.0) += ns;
        }
    }

    pub fn get(&self, resource: Resource) -> SimNs {
        self.rows.get(&resource).copied().unwrap_or(0.0)
    }

    /// Sum of all rows. Conservation says this equals the wall time of the
    /// span the ledger describes.
    pub fn total(&self) -> SimNs {
        self.rows.values().sum()
    }

    pub fn rows(&self) -> impl Iterator<Item = (Resource, SimNs)> + '_ {
        self.rows.iter().map(|(&r, &ns)| (r, ns))
    }

    /// Merge another ledger into this one (row-wise add, link busy append).
    pub fn merge(&mut self, other: &ResourceLedger) {
        for (r, ns) in other.rows() {
            self.add(r, ns);
        }
        for &(link, busy) in &other.eth_link_busy {
            match self.eth_link_busy.iter_mut().find(|(l, _)| *l == link) {
                Some((_, b)) => *b += busy,
                None => self.eth_link_busy.push((link, busy)),
            }
        }
        self.eth_link_busy.sort_by_key(|&(l, _)| l);
        self.eth_bottleneck = self
            .eth_link_busy
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).expect("link busy is finite"))
            .map(|&(l, _)| l);
    }

    /// The resource with the largest row, ignoring `Idle` (which is slack,
    /// not a bottleneck). Ties resolve to the earliest in `Resource::ALL`.
    pub fn dominant(&self) -> Option<(Resource, SimNs)> {
        Resource::ALL
            .iter()
            .filter(|&&r| r != Resource::Idle)
            .map(|&r| (r, self.get(r)))
            .filter(|&(_, ns)| ns > 0.0)
            .max_by(|a, b| a.1.partial_cmp(&b.1).expect("ledger rows are finite"))
    }
}

/// Whole-solve attribution: a grand total plus per-component sub-ledgers.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SolveLedger {
    /// Grand total over the solve; `total.total() == result.total_ns`.
    pub total: ResourceLedger,
    /// Per-component (spmv / dot / axpy / ...) sub-ledgers.
    pub per_component: BTreeMap<String, ResourceLedger>,
    /// Number of PCG iterations the ledger covers.
    pub iterations: u64,
}

impl SolveLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge one dispatched component: merge the program's ledger into the
    /// totals and book the difference between the time the scheduler charged
    /// (`charged_ns`) and the time the program ledger attributes as `Idle`.
    /// If the program ledger attributes *more* than was charged (the solver
    /// charged a wrapper time below the lowered program's wall time), the
    /// rows are scaled down proportionally instead.  Either way the charged
    /// time is conserved exactly, by construction.
    pub fn charge(&mut self, component: &str, program: &ResourceLedger, charged_ns: SimNs) {
        let attributed = program.total();
        let scaled;
        let (ledger, slack) = if attributed > charged_ns && attributed > 0.0 {
            let f = charged_ns / attributed;
            let mut s = program.clone();
            for v in s.rows.values_mut() {
                *v *= f;
            }
            scaled = s;
            (&scaled, 0.0)
        } else {
            (program, charged_ns - attributed)
        };
        let sub = self
            .per_component
            .entry(component.to_string())
            .or_default();
        sub.merge(ledger);
        self.total.merge(ledger);
        sub.add(Resource::Idle, slack);
        self.total.add(Resource::Idle, slack);
    }

    /// Book host dispatch overhead (kernel launches + inter-kernel gaps +
    /// residual readbacks) as an explicit row.
    pub fn add_dispatch(&mut self, ns: SimNs) {
        self.total.add(Resource::Dispatch, ns);
    }

    /// Book fault-handling time (Ethernet timeout detection + bounded
    /// retries, epoch re-lowering) as an explicit `Retry` row — the
    /// fault layer's honest line in the conservation invariant.
    pub fn add_retry(&mut self, ns: SimNs) {
        self.total.add(Resource::Retry, ns);
    }

    /// The component whose sub-ledger has the largest share of `resource`.
    fn dominant_component(&self, resource: Resource) -> Option<&str> {
        self.per_component
            .iter()
            .map(|(name, l)| (name.as_str(), l.get(resource)))
            .filter(|&(_, ns)| ns > 0.0)
            .max_by(|a, b| a.1.partial_cmp(&b.1).expect("ledger rows are finite"))
            .map(|(name, _)| name)
    }

    /// One-line bottleneck statement, e.g. `"ethernet-bound (54% of solve,
    /// dominated by dot, link 0-1)"`.
    pub fn verdict(&self) -> String {
        let total = self.total.total();
        let Some((res, ns)) = self.total.dominant() else {
            return "no time attributed".to_string();
        };
        if total <= 0.0 {
            return "no time attributed".to_string();
        }
        let pct = 100.0 * ns / total;
        let mut v = format!("{}-bound ({:.0}% of solve", res.label(), pct);
        if let Some(c) = self.dominant_component(res) {
            v.push_str(&format!(", dominated by {c}"));
        }
        if res == Resource::Ethernet {
            if let Some((a, b)) = self.total.eth_bottleneck {
                v.push_str(&format!(", link {a}-{b}"));
            }
        }
        v.push(')');
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_accumulate_and_conserve() {
        let mut l = ResourceLedger::new();
        l.add(Resource::Compute, 10.0);
        l.add(Resource::Compute, 5.0);
        l.add(Resource::Noc, 2.5);
        l.add(Resource::Dram, -1e-9); // clamped
        assert_eq!(l.get(Resource::Compute), 15.0);
        assert_eq!(l.get(Resource::Dram), 0.0);
        assert!((l.total() - 17.5).abs() < 1e-12);
    }

    #[test]
    fn merge_combines_rows_and_links() {
        let mut a = ResourceLedger::new();
        a.add(Resource::Ethernet, 4.0);
        a.eth_link_busy = vec![((0, 1), 4.0)];
        let mut b = ResourceLedger::new();
        b.add(Resource::Ethernet, 6.0);
        b.eth_link_busy = vec![((0, 1), 1.0), ((1, 2), 6.0)];
        a.merge(&b);
        assert_eq!(a.get(Resource::Ethernet), 10.0);
        assert_eq!(a.eth_link_busy, vec![((0, 1), 5.0), ((1, 2), 6.0)]);
        assert_eq!(a.eth_bottleneck, Some((1, 2)));
    }

    #[test]
    fn solve_ledger_conserves_by_construction() {
        let mut program = ResourceLedger::new();
        program.add(Resource::Compute, 80.0);
        program.add(Resource::Noc, 15.0);
        let mut s = SolveLedger::new();
        // Charged 100 ns for a program whose ledger explains 95 → 5 idle.
        s.charge("spmv", &program, 100.0);
        s.add_dispatch(12.0);
        assert!((s.total.total() - 112.0).abs() < 1e-9);
        assert!((s.total.get(Resource::Idle) - 5.0).abs() < 1e-9);
        assert!((s.per_component["spmv"].total() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn over_attributed_charge_scales_down_and_still_conserves() {
        let mut program = ResourceLedger::new();
        program.add(Resource::Compute, 90.0);
        program.add(Resource::Noc, 30.0); // attributes 120 ns
        let mut s = SolveLedger::new();
        s.charge("spmv", &program, 100.0); // but only 100 ns were charged
        assert!((s.total.total() - 100.0).abs() < 1e-9);
        assert_eq!(s.total.get(Resource::Idle), 0.0);
        assert!((s.total.get(Resource::Compute) - 75.0).abs() < 1e-9);
        assert!((s.total.get(Resource::Noc) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn verdict_names_resource_component_and_link() {
        let mut program = ResourceLedger::new();
        program.add(Resource::Ethernet, 70.0);
        program.add(Resource::Compute, 30.0);
        program.eth_link_busy = vec![((0, 1), 70.0)];
        program.eth_bottleneck = Some((0, 1));
        let mut s = SolveLedger::new();
        s.charge("dot", &program, 100.0);
        let v = s.verdict();
        assert!(v.starts_with("ethernet-bound (70%"), "verdict: {v}");
        assert!(v.contains("dominated by dot"), "verdict: {v}");
        assert!(v.contains("link 0-1"), "verdict: {v}");
    }

    #[test]
    fn empty_ledger_has_no_verdict_target() {
        assert_eq!(SolveLedger::new().verdict(), "no time attributed");
    }
}
