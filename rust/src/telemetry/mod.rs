//! Unified telemetry layer: the single source of truth for where simulated
//! time and bytes go.
//!
//! The pipeline, bottom to top (see README §Observability):
//!
//! 1. **Ledger** ([`ledger`]): [`crate::ttm::exec::execute_program`] builds a
//!    per-program [`ResourceLedger`] splitting wall time into compute /
//!    RISC-V / DRAM / NoC / Ethernet; the solvers fold those into a
//!    [`SolveLedger`] with explicit dispatch and idle rows.  Conservation —
//!    rows sum to the measured wall time — is enforced by
//!    `tests/prop_telemetry.rs`.
//! 2. **Metrics** ([`metrics`]): labelled counters / sums / time-series
//!    recorded by `HostQueue` and the solvers (dispatch counts, per-component
//!    device ns, Ethernet and NoC byte counters, residual decay).
//! 3. **Events** ([`events`]): one [`SolverEvent`] per PCG residual
//!    evaluation, exported as JSONL (`wormsim solve --telemetry out.jsonl`).
//! 4. **Spans** ([`spans`]): the causal [`SpanGraph`] recorded by the
//!    executor and solvers — which dependency chain the clock waited on,
//!    with `start == max(pred.end)` bit-exact by construction.
//! 5. **Critical path** ([`critpath`]): path extraction (length == wall
//!    time exactly), per-resource fractions + CPM slack, and the what-if
//!    re-timer (`wormsim critpath --what-if eth_bw=2x,dispatch=0`).
//! 6. **Traces**: time-series render as Perfetto counter ("C") tracks and
//!    span dependencies as flow arrows next to the profiler's zone events
//!    via [`crate::profiler::to_chrome_trace_full`].
//! 7. **Snapshots** ([`snapshot`]): bench sweeps serialize to
//!    `BENCH_<name>.json` (`wormsim bench --emit-json`), compared by
//!    `wormsim bench-diff`.
//!
//! Telemetry is *observational*: recording never advances simulated time, so
//! solver results are bit-identical with telemetry on or off (also enforced
//! by `tests/prop_telemetry.rs`).

pub mod critpath;
pub mod events;
pub mod ledger;
pub mod metrics;
pub mod snapshot;
pub mod spans;

use std::io;
use std::path::Path;

use crate::profiler::CounterTrack;
use crate::timing::SimNs;

pub use critpath::{analyze, critical_path, retime, CritPath, CritPathReport, ResourceCrit, WhatIf};
pub use events::{events_to_jsonl, write_events_jsonl, SolverEvent};
pub use ledger::{Resource, ResourceLedger, SolveLedger};
pub use metrics::{metric_id, Labels, MetricsRegistry};
pub use snapshot::{diff, BenchDiff, BenchMetric, BenchSnapshot, Better, DiffEntry};
pub use spans::{Span, SpanGraph};

/// A solve-scoped telemetry sink: metrics registry + solver event stream,
/// gated by one `enabled` flag so disabled runs do no work and allocate
/// nothing beyond the empty maps.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Telemetry {
    pub enabled: bool,
    pub metrics: MetricsRegistry,
    pub events: Vec<SolverEvent>,
}

impl Telemetry {
    pub fn new(enabled: bool) -> Self {
        Self {
            enabled,
            ..Self::default()
        }
    }

    pub fn count(&mut self, name: &str, labels: &[(&str, &str)], n: u64) {
        if self.enabled {
            self.metrics.count(name, labels, n);
        }
    }

    pub fn add(&mut self, name: &str, labels: &[(&str, &str)], v: f64) {
        if self.enabled {
            self.metrics.add(name, labels, v);
        }
    }

    pub fn series(&mut self, name: &str, labels: &[(&str, &str)], t_ns: SimNs, v: f64) {
        if self.enabled {
            self.metrics.series_push(name, labels, t_ns, v);
        }
    }

    pub fn event(&mut self, e: SolverEvent) {
        if self.enabled {
            self.events.push(e);
        }
    }

    /// Merge another sink's recordings (e.g. the host queue's) into this one.
    pub fn merge(&mut self, other: &Telemetry) {
        self.metrics.merge(&other.metrics);
        self.events.extend_from_slice(&other.events);
    }

    /// Render every recorded time series as a Perfetto counter track.
    pub fn counter_tracks(&self) -> Vec<CounterTrack> {
        self.metrics
            .all_series()
            .map(|(id, samples)| CounterTrack {
                name: id,
                samples: samples.to_vec(),
            })
            .collect()
    }

    pub fn events_jsonl(&self) -> String {
        events_to_jsonl(&self.events)
    }

    pub fn write_events_jsonl(&self, path: &Path) -> io::Result<()> {
        write_events_jsonl(&self.events, path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_sink_records_nothing() {
        let mut t = Telemetry::new(false);
        t.count("launches", &[], 1);
        t.add("ns", &[], 5.0);
        t.series("residual", &[], 1.0, 2.0);
        t.event(SolverEvent {
            t_ns: 0.0,
            iter: 1,
            residual: 1.0,
            launches: 1,
            component_ns: vec![],
        });
        assert_eq!(t.metrics, MetricsRegistry::new());
        assert!(t.events.is_empty());
    }

    #[test]
    fn counter_tracks_mirror_series() {
        let mut t = Telemetry::new(true);
        t.series("residual", &[], 10.0, 1.0);
        t.series("component_ns", &[("component", "dot")], 5.0, 2.0);
        let tracks = t.counter_tracks();
        assert_eq!(tracks.len(), 2);
        // BTreeMap order: component_ns{...} sorts before residual.
        assert_eq!(tracks[0].name, "component_ns{component=dot}");
        assert_eq!(tracks[1].name, "residual");
        assert_eq!(tracks[1].samples, vec![(10.0, 1.0)]);
    }

    #[test]
    fn merge_pulls_in_queue_telemetry() {
        let mut solver = Telemetry::new(true);
        solver.count("dispatches", &[], 8);
        let mut queue = Telemetry::new(true);
        queue.count("host_launches", &[], 8);
        solver.merge(&queue);
        assert_eq!(solver.metrics.get_count("dispatches", &[]), 8);
        assert_eq!(solver.metrics.get_count("host_launches", &[]), 8);
    }
}
