//! Per-iteration solver event stream, serialized as JSONL.
//!
//! One [`SolverEvent`] is recorded at each residual evaluation: the solve
//! timestamp (simulated ns), the iteration index, the residual norm, the
//! cumulative kernel-launch count, and the per-component device time charged
//! since the previous event.  The JSONL form (one JSON object per line) is
//! what `wormsim solve --telemetry out.jsonl` writes; it is hand-rolled the
//! same way `profiler::trace` is, since the image vendors no serde.

use std::fs;
use std::io;
use std::path::Path;

use crate::timing::SimNs;

#[derive(Debug, Clone, PartialEq)]
pub struct SolverEvent {
    /// Simulated solve time at which the residual became known.
    pub t_ns: SimNs,
    /// 1-based PCG iteration index.
    pub iter: u64,
    /// Residual norm at this iteration.
    pub residual: f64,
    /// Cumulative host kernel launches up to this event.
    pub launches: u64,
    /// Per-component device ns charged since the previous event.
    pub component_ns: Vec<(String, SimNs)>,
    /// Fault-layer annotation ("link_down:0-1", "retry", "sdc_detected",
    /// "rollback", "die_down:3", ...). `None` on every fault-free event,
    /// and omitted from the JSON entirely so fault-free streams stay
    /// byte-identical to the pre-fault format.
    pub fault: Option<String>,
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

impl SolverEvent {
    /// One JSON object, no trailing newline.
    pub fn to_json(&self) -> String {
        let comps: Vec<String> = self
            .component_ns
            .iter()
            .map(|(name, ns)| format!("\"{}\":{}", crate::util::jsonmini::escape(name), json_f64(*ns)))
            .collect();
        let fault = match &self.fault {
            Some(f) => format!(",\"fault\":\"{}\"", crate::util::jsonmini::escape(f)),
            None => String::new(),
        };
        format!(
            "{{\"t_ns\":{},\"iter\":{},\"residual\":{},\"launches\":{},\"component_ns\":{{{}}}{}}}",
            json_f64(self.t_ns),
            self.iter,
            json_f64(self.residual),
            self.launches,
            comps.join(","),
            fault
        )
    }
}

/// Render events as JSONL (one object per line, trailing newline).
pub fn events_to_jsonl(events: &[SolverEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.to_json());
        out.push('\n');
    }
    out
}

/// Write events as JSONL, creating parent directories. The write is
/// atomic (temp-then-rename): an interrupted run leaves the previous
/// file — or no file — never a truncated one.
pub fn write_events_jsonl(events: &[SolverEvent], path: &Path) -> io::Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    crate::util::fsatomic::write_atomic(path, &events_to_jsonl(events))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::jsonmini::Json;

    fn sample() -> SolverEvent {
        SolverEvent {
            t_ns: 1500.5,
            iter: 3,
            residual: 0.25,
            launches: 24,
            component_ns: vec![("spmv".to_string(), 1000.0), ("dot".to_string(), 250.5)],
            fault: None,
        }
    }

    #[test]
    fn jsonl_round_trips_through_parser() {
        let s = events_to_jsonl(&[sample()]);
        assert_eq!(s.lines().count(), 1);
        let v = Json::parse(s.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("iter").and_then(Json::as_f64), Some(3.0));
        assert_eq!(v.get("residual").and_then(Json::as_f64), Some(0.25));
        let comps = v.get("component_ns").unwrap();
        assert_eq!(comps.get("spmv").and_then(Json::as_f64), Some(1000.0));
        assert_eq!(comps.get("dot").and_then(Json::as_f64), Some(250.5));
    }

    #[test]
    fn jsonl_survives_hostile_names_and_non_finite_values() {
        // Component names carrying control characters must escape (the
        // JSONL consumer splits on raw newlines, so an unescaped \n in a
        // name would shear the record in two), and non-finite values must
        // degrade to null rather than emit NaN/inf literals.
        let ev = SolverEvent {
            t_ns: f64::INFINITY,
            iter: 1,
            residual: f64::NAN,
            launches: 2,
            component_ns: vec![("sp\nmv\t\"x\"\u{1}".to_string(), 7.0)],
            fault: None,
        };
        let s = events_to_jsonl(&[ev]);
        assert_eq!(s.lines().count(), 1, "escaped name must not break line framing");
        let v = Json::parse(s.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("t_ns"), Some(&Json::Null));
        assert_eq!(v.get("residual"), Some(&Json::Null));
        let comps = v.get("component_ns").unwrap();
        assert_eq!(
            comps.get("sp\nmv\t\"x\"\u{1}").and_then(Json::as_f64),
            Some(7.0)
        );
    }

    #[test]
    fn fault_annotation_is_emitted_only_when_present() {
        // A fault-free event serializes byte-identically to the
        // pre-fault format: no "fault" key at all.
        let clean = sample().to_json();
        assert!(!clean.contains("fault"), "clean event leaks a fault key: {clean}");
        let mut ev = sample();
        ev.fault = Some("sdc_detected".to_string());
        let v = Json::parse(&ev.to_json()).unwrap();
        assert_eq!(v.get("fault").and_then(Json::as_str), Some("sdc_detected"));
        // The annotation escapes like every other string.
        ev.fault = Some("link\n0-1".to_string());
        let s = events_to_jsonl(&[ev]);
        assert_eq!(s.lines().count(), 1);
        let v = Json::parse(s.lines().next().unwrap()).unwrap();
        assert_eq!(v.get("fault").and_then(Json::as_str), Some("link\n0-1"));
    }

    #[test]
    fn writes_file_with_one_line_per_event() {
        let dir = std::env::temp_dir().join("wormsim_events_test");
        let path = dir.join("ev.jsonl");
        write_events_jsonl(&[sample(), sample()], &path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back.lines().count(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
