//! Artifact registry: name → compiled PJRT executable, compiled lazily and
//! cached. Artifact names follow the `python/compile/aot.py` convention,
//! e.g. `stencil_bf16_t64`, `axpy_f32_t8`, `dot_bf16_t164`.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::arch::DataFormat;
use crate::error::{Result, SimError};
use crate::runtime::client::RtClient;

/// Lazily-compiling executable cache over an artifacts directory.
pub struct ArtifactStore {
    dir: PathBuf,
    client: RtClient,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl std::fmt::Debug for ArtifactStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactStore")
            .field("dir", &self.dir)
            .field("cached", &self.cache.borrow().len())
            .finish()
    }
}

/// Data-format tag used in artifact names.
pub fn df_tag(df: DataFormat) -> &'static str {
    match df {
        DataFormat::Bf16 => "bf16",
        DataFormat::Fp32 => "f32",
        DataFormat::Fp8 => "f8",
    }
}

impl ArtifactStore {
    pub fn new(dir: &Path) -> Result<Self> {
        if !dir.is_dir() {
            return Err(SimError::Artifact(format!(
                "artifacts directory {} does not exist — run `make artifacts` first",
                dir.display()
            )));
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            client: RtClient::cpu()?,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform()
    }

    pub fn path_for(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn available(&self, name: &str) -> bool {
        self.path_for(name).is_file()
    }

    /// List all artifact names present on disk.
    pub fn list(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for e in entries.flatten() {
                if let Some(n) = e.file_name().to_str() {
                    if let Some(stem) = n.strip_suffix(".hlo.txt") {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        names
    }

    /// Get (compiling + caching on first use) an executable by name.
    pub fn get(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.path_for(name);
        if !path.is_file() {
            return Err(SimError::Artifact(format!(
                "artifact '{}' not found at {} (available: {:?}) — re-run `make artifacts`",
                name,
                path.display(),
                self.list()
            )));
        }
        let exe = Rc::new(self.client.compile_hlo_text(&path)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name` on f32 inputs; see [`RtClient::run_f32`].
    pub fn run(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let exe = self.get(name)?;
        RtClient::run_f32(&exe, inputs)
    }
}
