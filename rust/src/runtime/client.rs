//! Thin wrapper over the `xla` crate's PJRT CPU client.

use crate::error::{Result, SimError};

/// A PJRT client plus helpers to build/execute computations.
pub struct RtClient {
    client: xla::PjRtClient,
}

impl std::fmt::Debug for RtClient {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RtClient")
            .field("platform", &self.client.platform_name())
            .field("devices", &self.client.device_count())
            .finish()
    }
}

fn xe(e: xla::Error) -> SimError {
    SimError::Runtime(e.to_string())
}

impl RtClient {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(xe)?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO text file and compile it.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| SimError::Artifact(format!("non-utf8 path {path:?}")))?;
        let proto = xla::HloModuleProto::from_text_file(path_str).map_err(xe)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(xe)
    }

    /// Execute a compiled artifact on f32 tensor inputs, returning the
    /// flattened f32 outputs. Each input is `(data, dims)`; the artifact
    /// was lowered with `return_tuple=True`, so the single on-device output
    /// is a tuple whose elements we return in order.
    pub fn run_f32(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = if dims.is_empty() {
                if data.len() != 1 {
                    return Err(SimError::Runtime(format!(
                        "scalar input needs 1 element, got {}",
                        data.len()
                    )));
                }
                xla::Literal::scalar(data[0])
            } else {
                xla::Literal::vec1(data).reshape(dims).map_err(xe)?
            };
            literals.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&literals).map_err(xe)?[0][0]
            .to_literal_sync()
            .map_err(xe)?;
        let parts = result.to_tuple().map_err(xe)?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>().map_err(xe)?);
        }
        Ok(out)
    }
}
