//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from the Rust hot path.
//!
//! The interchange format is **HLO text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that the image's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids and
//! round-trips cleanly (see /opt/xla-example/README.md). Executables are
//! compiled once per artifact and cached.

pub mod artifacts;
pub mod client;

pub use artifacts::ArtifactStore;
pub use client::RtClient;
