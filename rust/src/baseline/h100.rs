//! Analytic model of the paper's H100 CG reference (§7.3).
//!
//! The GPU implementation follows the traditional offload style: four
//! kernels (norm, dot, axpy, SpMV) assembled per iteration, norm/dot/axpy
//! via Kokkos in a straightforward way, SpMV via cuSPARSE Sliced-ELL, all
//! FP32, timed with cudaEvent pairs. Every kernel at this problem size is
//! memory-bandwidth-bound, so time = bytes / achieved-bandwidth plus
//! launch/synchronization overheads. Parameters are calibrated against the
//! paper's measured 0.28 ms/iteration at 512×112×64 (Table 3); the
//! component split then reproduces Fig 13's H100 bars.

use crate::arch::specs::H100;
use crate::baseline::sell::SellTraffic;
use crate::profiler::Breakdown;
use crate::timing::SimNs;

/// Tunable parameters of the GPU model.
#[derive(Debug, Clone, PartialEq)]
pub struct H100Params {
    /// Fraction of the 3.9 TB/s peak a well-written streaming kernel
    /// achieves in practice.
    pub bw_efficiency: f64,
    /// Host-side launch overhead per kernel, ns.
    pub launch_ns: f64,
    /// Device-to-host synchronization for a reduction result (the Kokkos
    /// parallel_reduce in dot/norm returns the value to the host; §7.3
    /// notes the dot time includes this transfer).
    pub d2h_sync_ns: f64,
    pub sell: SellTraffic,
    /// FP32 element size.
    pub elem_bytes: usize,
}

impl Default for H100Params {
    fn default() -> Self {
        Self {
            // Calibrated so the Table-3 problem lands at 0.28 ms/iter.
            bw_efficiency: 0.58,
            launch_ns: 3_000.0,
            d2h_sync_ns: 12_000.0,
            sell: SellTraffic::laplacian_fp32(),
            elem_bytes: 4,
        }
    }
}

/// Per-iteration component times for the GPU CG at `n` unknowns.
#[derive(Debug, Clone)]
pub struct H100Iteration {
    pub breakdown: Breakdown,
    /// Device compute time (the Fig-13 bars: launches excluded, §7.3).
    pub components_ns: SimNs,
    /// Wall per-iteration time including launches (Table 3).
    pub total_ns: SimNs,
}

#[derive(Debug, Clone, Default)]
pub struct H100Model {
    pub params: H100Params,
}

impl H100Model {
    pub fn new(params: H100Params) -> Self {
        Self { params }
    }

    fn bw_bytes_per_ns(&self) -> f64 {
        H100.peak_mem_bw_gbs * self.params.bw_efficiency // GB/s == bytes/ns
    }

    fn stream_ns(&self, bytes: f64) -> f64 {
        bytes / self.bw_bytes_per_ns()
    }

    /// One CG iteration (Algorithm 1) at `n` unknowns.
    ///
    /// Kernels per iteration: 1 SpMV, 2 dots, 3 axpys + 1 preconditioner
    /// scale (reported under axpy, as the Kokkos code fuses it there),
    /// 1 norm. The dot/norm reductions each pay a D2H sync.
    pub fn cg_iteration(&self, n: usize) -> H100Iteration {
        let p = &self.params;
        let nb = n as f64 * p.elem_bytes as f64;
        let mut b = Breakdown::new();
        b.iterations = 1;

        // SpMV: SELL traffic.
        let spmv = self.stream_ns(p.sell.bytes(n));
        b.add("spmv", spmv);

        // dot: two vectors in; result reduced and synced to host. ×2.
        let dot_one = self.stream_ns(2.0 * nb) + p.d2h_sync_ns;
        b.add("dot", 2.0 * dot_one);

        // axpy: 2 reads + 1 write, ×3; plus the Jacobi scale (1 read +
        // 1 write) reported under axpy.
        let axpy_one = self.stream_ns(3.0 * nb);
        let precond = self.stream_ns(2.0 * nb);
        b.add("axpy", 3.0 * axpy_one + precond);

        // norm: one vector in, reduce, sync.
        let norm = self.stream_ns(nb) + p.d2h_sync_ns;
        b.add("norm", norm);

        let components: f64 = b.total_per_iter();
        // 8 kernel launches per iteration (spmv, 2 dot, 3 axpy, precond,
        // norm) — excluded from the Fig-13 bars (§7.3), included in the
        // Table-3 wall time.
        let total = components + 8.0 * p.launch_ns;
        H100Iteration {
            breakdown: b,
            components_ns: components,
            total_ns: total,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TABLE3_N: usize = 512 * 112 * 64;

    #[test]
    fn table3_calibration() {
        // Paper: 0.28 ms/iteration for the 512×112×64 grid.
        let m = H100Model::default();
        let it = m.cg_iteration(TABLE3_N);
        let ms = it.total_ns / 1e6;
        assert!(
            (0.24..0.32).contains(&ms),
            "H100 model {ms} ms/iter vs paper 0.28"
        );
    }

    #[test]
    fn fig13_component_shape() {
        // §7.3: SpMV and dot are roughly comparable; axpy is NOT the most
        // expensive device component... actually "the axpy kernel is the
        // least expensive" refers to Wormhole-relative cost; on H100 axpy
        // moves the most bytes of the vector kernels. We check the robust
        // claims: spmv is the largest single component and norm the
        // smallest.
        let m = H100Model::default();
        let it = m.cg_iteration(TABLE3_N);
        let g = |k: &str| it.breakdown.per_iter(k);
        assert!(g("spmv") > g("dot"));
        assert!(g("spmv") > g("axpy"));
        assert!(g("norm") < g("dot"));
        assert!(g("norm") < g("axpy"));
        // Dot and spmv within ~2.5x of each other ("relative equality").
        assert!(g("spmv") / g("dot") < 2.5);
    }

    #[test]
    fn scales_linearly_with_n() {
        let m = H100Model::default();
        let a = m.cg_iteration(1_000_000);
        let b = m.cg_iteration(2_000_000);
        let compute_a = a.components_ns - 3.0 * m.params.d2h_sync_ns;
        let compute_b = b.components_ns - 3.0 * m.params.d2h_sync_ns;
        let ratio = compute_b / compute_a;
        assert!((1.9..2.1).contains(&ratio), "ratio {ratio}");
    }
}
