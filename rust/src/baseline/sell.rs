//! Traffic model for cuSPARSE's Sliced-ELL SpMV (§7.3).
//!
//! The paper's GPU reference realizes the 7-point structured matrix through
//! cuSPARSE's Sliced-ELL format ("generally recognized as state-of-the-art
//! in performance for matrices with limited row-length variability"). A
//! memory-bound SpMV's time is its byte traffic over the achieved
//! bandwidth; this module counts the bytes.

/// Bytes moved per matrix row for a Sliced-ELL SpMV at FP32.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SellTraffic {
    /// Nonzeros per row (7 for the 7-point stencil; SELL pads to the slice
    /// maximum, which is uniform here, so no padding waste).
    pub nnz_per_row: usize,
    /// Bytes per stored value (4 = FP32, as §7.3 fixes).
    pub value_bytes: usize,
    /// Bytes per column index (cuSPARSE uses 32-bit indices).
    pub index_bytes: usize,
    /// Effective bytes of `x` read per row after cache reuse. A 7-point
    /// stencil re-reads each x element ~7 times; with good L2 behaviour the
    /// effective traffic is a small multiple of one compulsory read.
    pub x_read_bytes: f64,
    /// Bytes written to `y` per row.
    pub y_write_bytes: usize,
}

impl SellTraffic {
    /// The 7-point Laplacian at FP32 with 32-bit indices.
    pub fn laplacian_fp32() -> Self {
        Self {
            nnz_per_row: 7,
            value_bytes: 4,
            index_bytes: 4,
            // ~2 compulsory-equivalent reads of x per row: the stencil's
            // z-neighbour reuse distance exceeds L2 at the Table-3 problem
            // size, so part of x streams twice.
            x_read_bytes: 8.0,
            y_write_bytes: 4,
        }
    }

    /// Total bytes per row.
    pub fn bytes_per_row(&self) -> f64 {
        (self.nnz_per_row * (self.value_bytes + self.index_bytes)) as f64
            + self.x_read_bytes
            + self.y_write_bytes as f64
    }

    /// Total bytes for an `n`-row SpMV.
    pub fn bytes(&self, n: usize) -> f64 {
        self.bytes_per_row() * n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laplacian_traffic() {
        let t = SellTraffic::laplacian_fp32();
        // 7*(4+4) + 8 + 4 = 68 bytes/row.
        assert_eq!(t.bytes_per_row(), 68.0);
        assert_eq!(t.bytes(1000), 68_000.0);
    }

    #[test]
    fn matrix_traffic_dominates_vector_traffic() {
        // SELL stores explicit values+indices, which is why the GPU SpMV
        // moves ~5x more bytes than the matrix-free Wormhole stencil.
        let t = SellTraffic::laplacian_fp32();
        let matrix = (t.nnz_per_row * (t.value_bytes + t.index_bytes)) as f64;
        assert!(matrix > 4.0 * (t.x_read_bytes + t.y_write_bytes as f64) / 2.0);
    }
}
