//! GPU comparison baseline (§7.3): an analytic, traffic-calibrated model of
//! the paper's H100 CG reference (Kokkos norm/dot/axpy + cuSPARSE
//! Sliced-ELL SpMV at FP32).

pub mod energy;
pub mod h100;
pub mod sell;

pub use energy::{wormhole_utilization, EnergyModel};
pub use h100::{H100Iteration, H100Model, H100Params};
pub use sell::SellTraffic;
