//! Energy-to-solution model (§8 future work: "system-level consumption and
//! energy-to-solution could be measured relatively accurately and would be
//! a useful addition").
//!
//! The paper contextualizes its performance results with TDP (§7.3) and
//! notes the n150d's 160 W is the relevant budget for single-die runs. We
//! implement the TDP-proxy energy model the paper gestures at: energy =
//! board power × time, with an idle/active split so partial sub-grid
//! utilization is not billed the full board.

use crate::arch::specs::{AcceleratorSpec, H100, N150D};
use crate::timing::SimNs;

/// TDP-proxy energy model for one accelerator.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    pub spec: &'static AcceleratorSpec,
    /// Fraction of TDP drawn when the part is powered but compute-idle
    /// (uncore, DRAM refresh, NoC). Public measurements for both GDDR6
    /// accelerator boards and H100 hover near 30–40% of TDP at idle.
    pub idle_fraction: f64,
}

impl EnergyModel {
    pub fn n150d() -> Self {
        // Single Wormhole die — the §7.3-recommended comparison basis.
        Self {
            spec: &N150D,
            idle_fraction: 0.35,
        }
    }

    pub fn h100() -> Self {
        Self {
            spec: &H100,
            idle_fraction: 0.35,
        }
    }

    /// Average power (W) at a given active-resource utilization in [0,1]
    /// (for Wormhole: active cores / 80; for the GPU: 1.0 for a saturating
    /// kernel stream).
    pub fn power_w(&self, utilization: f64) -> f64 {
        let u = utilization.clamp(0.0, 1.0);
        self.spec.tdp_w * (self.idle_fraction + (1.0 - self.idle_fraction) * u)
    }

    /// Energy in joules for `ns` of execution at `utilization`.
    pub fn energy_j(&self, ns: SimNs, utilization: f64) -> f64 {
        self.power_w(utilization) * (ns * 1e-9)
    }

    /// Energy per PCG iteration in millijoules.
    pub fn energy_per_iter_mj(&self, iter_ns: SimNs, utilization: f64) -> f64 {
        self.energy_j(iter_ns, utilization) * 1e3
    }
}

/// Wormhole utilization for an `rows × cols` compute sub-grid.
pub fn wormhole_utilization(rows: usize, cols: usize) -> f64 {
    (rows * cols) as f64 / crate::arch::constants::TENSIX_PER_DIE as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_scales_between_idle_and_tdp() {
        let m = EnergyModel::n150d();
        assert!((m.power_w(0.0) - 160.0 * 0.35).abs() < 1e-9);
        assert!((m.power_w(1.0) - 160.0).abs() < 1e-9);
        assert!(m.power_w(0.5) > m.power_w(0.0));
        assert!(m.power_w(2.0) <= 160.0, "utilization clamped");
    }

    #[test]
    fn energy_per_iteration_comparison_shape() {
        // Table-3 numbers: H100 0.28 ms at 350 W vs Wormhole BF16 1.2 ms
        // at 160 W × 70% utilization. The energy gap must be much smaller
        // than the time gap — the paper's §7.3 point that "the performance
        // differential should be considered relative to power draw".
        let wh = EnergyModel::n150d();
        let gpu = EnergyModel::h100();
        let wh_e = wh.energy_per_iter_mj(1.20e6, wormhole_utilization(8, 7));
        let gpu_e = gpu.energy_per_iter_mj(0.28e6, 1.0);
        let energy_ratio = wh_e / gpu_e;
        let time_ratio = 1.20 / 0.28;
        assert!(energy_ratio < time_ratio, "energy {energy_ratio} vs time {time_ratio}");
        assert!(energy_ratio > 1.0, "H100 still wins on energy here");
    }

    #[test]
    fn utilization_fraction() {
        assert!((wormhole_utilization(8, 7) - 0.7).abs() < 1e-9);
        assert!((wormhole_utilization(1, 1) - 1.0 / 80.0).abs() < 1e-12);
    }
}
