//! Data formats supported by the Wormhole compute units (paper §3.3).
//!
//! The FPU (matrix engine) is limited to ≤19-bit formats — for our purposes
//! BF16 — while the SFPU (vector engine) supports both 16- and 32-bit
//! formats. FP8 appears only in the Table-2 peak-TFLOPS characteristics.

use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataFormat {
    /// bfloat16: 1 sign, 8 exponent, 7 mantissa bits. FPU-native.
    Bf16,
    /// IEEE-754 binary32. SFPU only (with flush-to-zero, §3.3).
    Fp32,
    /// 8-bit float (Table 2 peak numbers only; not used by the kernels).
    Fp8,
}

impl DataFormat {
    /// Bytes per element.
    pub const fn bytes(self) -> usize {
        match self {
            DataFormat::Bf16 => 2,
            DataFormat::Fp32 => 4,
            DataFormat::Fp8 => 1,
        }
    }

    /// Bytes per 1024-element tile (32×32 or 64×16).
    pub const fn tile_bytes(self) -> usize {
        self.bytes() * crate::arch::constants::TILE_ELEMS
    }

    /// Whether the FPU (matrix engine) can operate on this format
    /// (restricted to ≤19-bit formats, §3.3).
    pub const fn fpu_capable(self) -> bool {
        matches!(self, DataFormat::Bf16 | DataFormat::Fp8)
    }

    /// Whether the SFPU supports this format (16- and 32-bit, §3.3).
    pub const fn sfpu_capable(self) -> bool {
        matches!(self, DataFormat::Bf16 | DataFormat::Fp32)
    }
}

impl fmt::Display for DataFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DataFormat::Bf16 => write!(f, "BF16"),
            DataFormat::Fp32 => write!(f, "FP32"),
            DataFormat::Fp8 => write!(f, "FP8"),
        }
    }
}

impl std::str::FromStr for DataFormat {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "bf16" | "bfloat16" => Ok(DataFormat::Bf16),
            "fp32" | "f32" | "float32" => Ok(DataFormat::Fp32),
            "fp8" | "f8" => Ok(DataFormat::Fp8),
            _ => Err(format!("unknown data format '{s}' (expected bf16|fp32|fp8)")),
        }
    }
}

/// Which compute unit executes an operation (paper §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ComputeUnit {
    /// Matrix engine: 8×16 SPMD element-wise / matmul / 16×16 reduction per
    /// cycle; ≤19-bit formats.
    Fpu,
    /// Vector engine: 32 lanes × 32 bits; needs Dst-register staging and
    /// lane load/stores on top of pack/unpack.
    Sfpu,
}

impl ComputeUnit {
    /// The unit the paper uses for a given precision: FPU for BF16,
    /// SFPU (mandatory) for FP32.
    pub const fn for_format(df: DataFormat) -> ComputeUnit {
        match df {
            DataFormat::Fp32 => ComputeUnit::Sfpu,
            _ => ComputeUnit::Fpu,
        }
    }

    pub const fn supports(self, df: DataFormat) -> bool {
        match self {
            ComputeUnit::Fpu => df.fpu_capable(),
            ComputeUnit::Sfpu => df.sfpu_capable(),
        }
    }
}

impl fmt::Display for ComputeUnit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ComputeUnit::Fpu => write!(f, "FPU"),
            ComputeUnit::Sfpu => write!(f, "SFPU"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DataFormat::Bf16.bytes(), 2);
        assert_eq!(DataFormat::Fp32.bytes(), 4);
        assert_eq!(DataFormat::Bf16.tile_bytes(), 2048);
        assert_eq!(DataFormat::Fp32.tile_bytes(), 4096);
    }

    #[test]
    fn unit_capabilities_match_paper() {
        // §3.3: FPU restricted to ≤19-bit; SFPU supports 16/32-bit.
        assert!(DataFormat::Bf16.fpu_capable());
        assert!(!DataFormat::Fp32.fpu_capable());
        assert!(DataFormat::Fp32.sfpu_capable());
        assert!(!DataFormat::Fp8.sfpu_capable());
        assert_eq!(ComputeUnit::for_format(DataFormat::Fp32), ComputeUnit::Sfpu);
        assert_eq!(ComputeUnit::for_format(DataFormat::Bf16), ComputeUnit::Fpu);
        assert!(!ComputeUnit::Fpu.supports(DataFormat::Fp32));
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!("bf16".parse::<DataFormat>().unwrap(), DataFormat::Bf16);
        assert_eq!("FP32".parse::<DataFormat>().unwrap(), DataFormat::Fp32);
        assert!("fp64".parse::<DataFormat>().is_err());
    }
}
