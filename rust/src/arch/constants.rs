//! Architectural constants of the Tenstorrent Wormhole n300d, as described
//! in the paper (§3, Tables 1–2). Every constant cites the paper statement
//! that fixes it. Calibration *tunables* (cost-model knobs that the paper
//! does not pin down numerically) live in [`crate::timing::calib`] instead.

/// Tile edge sizes: tiles are 32×32 = 1024 elements (§3.1); the stencil
/// work uses 64×16 tiles, also 1024 elements (§6.1).
pub const TILE_ELEMS: usize = 1024;
/// Standard tile shape (rows, cols) (§3.1).
pub const TILE_SQUARE: (usize, usize) = (32, 32);
/// Stencil tile shape chosen to align rows with 32B pointer steps (§6.2).
pub const TILE_STENCIL: (usize, usize) = (64, 16);
/// Subtiles ("faces") are 16×16 and interleaved in physical memory (§3.1, Fig 2).
pub const FACE: usize = 16;

/// Die grid: 10×12 elements, 80 of which are Tensix compute cores (§3).
pub const DIE_ROWS: usize = 10;
pub const DIE_COLS: usize = 12;
pub const TENSIX_PER_DIE: usize = 80;
/// Maximum usable compute sub-grid in the paper's experiments (§7.2).
pub const MAX_SUBGRID: (usize, usize) = (8, 7);

/// Per-core local SRAM, "approximately 1.5MB" (§3).
pub const SRAM_BYTES: usize = 1536 * 1024;

/// Number of baby RISC-V cores per Tensix (§3): 2 NoC data-movement cores,
/// 3 compute-side movement/issue cores.
pub const BABY_RISCV_PER_CORE: usize = 5;
pub const NOC_RISCV_PER_CORE: usize = 2;

/// DRAM: 24 GB GDDR6 shared by both dies on the n300d (§3 / Table 2).
pub const N300D_DRAM_BYTES: u64 = 24 * 1024 * 1024 * 1024;
/// Peak DRAM bandwidth per die: n150d column of Table 2 (288 GB/s; the
/// n300d shows 576 GB/s for two dies — experiments use a single die).
pub const DRAM_BW_PER_DIE_GBS: f64 = 288.0;

/// Alignment rules (§3.3): DRAM reads 32B, DRAM writes 16B, L1 16B.
pub const DRAM_READ_ALIGN: usize = 32;
pub const DRAM_WRITE_ALIGN: usize = 16;
pub const L1_ALIGN: usize = 32;
/// CB read-pointer manipulation granularity (§6.2): multiples of 32B.
pub const CB_PTR_ALIGN: usize = 32;

// ---------------------------------------------------------------------
// Table 1: single-cycle capabilities of the Wormhole FPU.
// ---------------------------------------------------------------------

/// Matrix multiply: 8x16 × 16x16 = 8x16 per cycle.
pub const FPU_MATMUL_SHAPE: ((usize, usize), (usize, usize)) = ((8, 16), (16, 16));
/// Reduction: one 16×16 face per cycle.
pub const FPU_REDUCE_ELEMS_PER_CLK: usize = FACE * FACE; // 256
/// Element-wise add/sub/mul: one 8×16 slab per cycle = 128 ops/clk (§4).
pub const FPU_ELTWISE_ELEMS_PER_CLK: usize = 8 * 16; // 128

// ---------------------------------------------------------------------
// SFPU capabilities (§3.3, §4).
// ---------------------------------------------------------------------

/// SFPU is 32 lanes × 32 bits; 2 cycles per element-wise op on 64 16-bit
/// elements → 32 16-bit elems/clk; 16 32-bit elems/clk.
pub const SFPU_LANES: usize = 32;
pub const SFPU_ELEMS_PER_CLK_16B: usize = 32;
pub const SFPU_ELEMS_PER_CLK_32B: usize = 16;

// ---------------------------------------------------------------------
// Intra-core movement bandwidths (§4 roofline).
// ---------------------------------------------------------------------

/// Packer and unpacker peak throughput between SRAM and registers.
pub const PACKER_BYTES_PER_CLK: usize = 64;
pub const UNPACKER_BYTES_PER_CLK: usize = 64;
/// Copy into the Dst register is limited to 32 B/cycle (§4).
pub const DST_COPY_BYTES_PER_CLK: usize = 32;

/// Dst register set capacity (§3.3): 16 tiles of 16-bit or 8 tiles of 32-bit.
pub const DST_TILES_16B: usize = 16;
pub const DST_TILES_32B: usize = 8;
/// SrcA/SrcB: 64 rows × 16 datums, ≤19 bits each (§3.3).
pub const SRC_REG_ROWS: usize = 64;
pub const SRC_REG_COLS: usize = 16;

/// Tensix clock. Wormhole's AI clock is ~1 GHz; the paper reports times in
/// ms and the roofline in per-clock units, so 1 GHz makes cycles ≡ ns.
pub const CLOCK_HZ: f64 = 1.0e9;

/// Convert cycles to nanoseconds at the Tensix clock.
#[inline]
pub fn cycles_to_ns(cycles: u64) -> f64 {
    cycles as f64 / CLOCK_HZ * 1e9
}

// ---------------------------------------------------------------------
// Memory capacity model (§7.2): maximum tiles per core for each solver
// variant. Derivation in DESIGN.md §6 — reservations tuned so the paper's
// reported ceilings (64 FP32 split / 164 BF16 fused) emerge from SRAM_BYTES.
// ---------------------------------------------------------------------

/// SRAM reserved for stack + program + circular buffers, split-kernel
/// variant (needs more CB staging, §7.1).
pub const SRAM_RESERVE_SPLIT: usize = 256 * 1024;
/// Same for the fused-kernel variant (less staging, §7.1).
pub const SRAM_RESERVE_FUSED: usize = 224 * 1024;
/// Number of resident whole-domain vectors: split PCG keeps x, r, z, p, q;
/// fused PCG aliases z into the preconditioner application: x, r, p, q.
pub const PCG_VECTORS_SPLIT: usize = 5;
pub const PCG_VECTORS_FUSED: usize = 4;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::dataformat::DataFormat;

    #[test]
    fn tile_shapes_are_1024_elements() {
        assert_eq!(TILE_SQUARE.0 * TILE_SQUARE.1, TILE_ELEMS);
        assert_eq!(TILE_STENCIL.0 * TILE_STENCIL.1, TILE_ELEMS);
        // 64×16 BF16 rows are exactly one 32B pointer step (§6.2).
        assert_eq!(TILE_STENCIL.1 * DataFormat::Bf16.bytes(), CB_PTR_ALIGN);
    }

    #[test]
    fn table1_fpu_capabilities() {
        // Table 1 exactly as printed.
        assert_eq!(FPU_MATMUL_SHAPE, ((8, 16), (16, 16)));
        assert_eq!(FPU_REDUCE_ELEMS_PER_CLK, 256);
        assert_eq!(FPU_ELTWISE_ELEMS_PER_CLK, 128);
    }

    #[test]
    fn sfpu_rates_match_section4() {
        // "32 and 16 operations per clock cycle" for 16/32-bit (§4).
        assert_eq!(SFPU_ELEMS_PER_CLK_16B, 32);
        assert_eq!(SFPU_ELEMS_PER_CLK_32B, 16);
        // FPU/SFPU eltwise ratio underlying the "~6x slower" observation.
        assert_eq!(FPU_ELTWISE_ELEMS_PER_CLK / SFPU_ELEMS_PER_CLK_16B, 4);
    }

    #[test]
    fn max_tiles_per_core_match_paper() {
        // §7.2: "64 tiles of 1024 FP32 elements" (split) and "164 tiles of
        // 1024 BF16 elements" (fused) — these must fall out of the capacity
        // model, not be hardcoded.
        let avail_split = SRAM_BYTES - SRAM_RESERVE_SPLIT;
        let per_tile_split = PCG_VECTORS_SPLIT * DataFormat::Fp32.tile_bytes();
        assert_eq!(avail_split / per_tile_split, 64);

        let avail_fused = SRAM_BYTES - SRAM_RESERVE_FUSED;
        let per_tile_fused = PCG_VECTORS_FUSED * DataFormat::Bf16.tile_bytes();
        assert_eq!(avail_fused / per_tile_fused, 164);
    }

    #[test]
    fn element_ceilings_match_paper() {
        // §7.2: ~3.6M FP32 elements and ~9.4M BF16 elements on 8×7 cores.
        let cores = MAX_SUBGRID.0 * MAX_SUBGRID.1;
        let fp32_elems = cores * 64 * TILE_ELEMS;
        let bf16_elems = cores * 164 * TILE_ELEMS;
        assert!((3.5e6..3.8e6).contains(&(fp32_elems as f64)), "{fp32_elems}");
        assert!((9.2e6..9.6e6).contains(&(bf16_elems as f64)), "{bf16_elems}");
    }

    #[test]
    fn grid_counts() {
        assert!(TENSIX_PER_DIE <= DIE_ROWS * DIE_COLS);
        assert!(MAX_SUBGRID.0 * MAX_SUBGRID.1 <= TENSIX_PER_DIE);
    }
}
