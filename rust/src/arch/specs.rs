//! Table 2: high-level characteristics of the accelerators compared in the
//! paper. Used by the `tables t2` runner and by the H100 baseline model.

/// One accelerator column of Table 2.
#[derive(Debug, Clone, PartialEq)]
pub struct AcceleratorSpec {
    pub name: &'static str,
    pub vendor: &'static str,
    pub form_factor: &'static str,
    pub tdp_w: f64,
    pub process_node: &'static str,
    pub peak_mem_bw_gbs: f64,
    pub memory: &'static str,
    pub fp8_tflops: f64,
    pub fp16_tflops: f64,
    pub fp32_tflops: f64,
}

/// Wormhole n150d (single-die reference point; §7.3 notes it is the more
/// relevant TDP comparison since experiments use one die of the n300d).
pub const N150D: AcceleratorSpec = AcceleratorSpec {
    name: "Wormhole n150d",
    vendor: "Tenstorrent",
    form_factor: "PCIe",
    tdp_w: 160.0,
    process_node: "GF 12nm",
    peak_mem_bw_gbs: 288.0,
    memory: "12 GB GDDR6",
    fp8_tflops: 262.0,
    fp16_tflops: 74.0,
    fp32_tflops: 2.3,
};

/// Wormhole n300d (the test system; two Tensix dies).
pub const N300D: AcceleratorSpec = AcceleratorSpec {
    name: "Wormhole n300d",
    vendor: "Tenstorrent",
    form_factor: "PCIe",
    tdp_w: 300.0,
    process_node: "GF 12nm",
    peak_mem_bw_gbs: 576.0,
    memory: "24 GB GDDR6",
    fp8_tflops: 466.0,
    fp16_tflops: 131.0,
    fp32_tflops: 4.1,
};

/// Nvidia H100 PCIe (the GPU comparison point).
pub const H100: AcceleratorSpec = AcceleratorSpec {
    name: "H100",
    vendor: "Nvidia",
    form_factor: "PCIe",
    tdp_w: 350.0,
    process_node: "TSMC N4",
    peak_mem_bw_gbs: 3900.0,
    memory: "80 GB HBM3",
    fp8_tflops: 1513.0,
    fp16_tflops: 102.4,
    fp32_tflops: 51.2,
};

pub const ALL_SPECS: [&AcceleratorSpec; 3] = [&N150D, &N300D, &H100];

/// Raw parameters of one die-to-die Ethernet link class. The typed link
/// object ([`crate::device::mesh::EthLink`]) is constructed from these —
/// the per-topology presets live here next to the board specs they come
/// from.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EthLinkSpec {
    /// One-way message latency, ns (Ethernet MAC + SerDes).
    pub latency_ns: f64,
    /// Usable bandwidth, GB/s.
    pub bw_gbs: f64,
}

/// n300 on-board die-to-die link: two dies on one PCB, 100 GbE lanes
/// between them (≈ 25 GB/s raw per pair; one link's usable rate). This is
/// the link the dual-die solver has always modeled.
pub const ETH_ONBOARD: EthLinkSpec = EthLinkSpec {
    latency_ns: 800.0,
    bw_gbs: 11.0,
};

/// Galaxy backplane link: the 32-die Galaxy connects boards over QSFP-DD
/// cabling and retimers — same 100 GbE class, longer flight time and a
/// little less usable bandwidth. Estimated (the paper stops at one die).
pub const ETH_BACKPLANE: EthLinkSpec = EthLinkSpec {
    latency_ns: 1400.0,
    bw_gbs: 9.0,
};

/// Dies in the largest Wormhole system (Galaxy).
pub const GALAXY_DIES: usize = 32;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_values_as_printed() {
        assert_eq!(N150D.tdp_w, 160.0);
        assert_eq!(N300D.tdp_w, 300.0);
        assert_eq!(H100.tdp_w, 350.0);
        assert_eq!(N300D.peak_mem_bw_gbs, 576.0);
        assert_eq!(H100.peak_mem_bw_gbs, 3900.0);
        assert_eq!(H100.fp32_tflops, 51.2);
        assert_eq!(N150D.fp32_tflops, 2.3);
    }

    #[test]
    fn n300d_is_two_n150d_dies() {
        assert_eq!(N300D.peak_mem_bw_gbs, 2.0 * N150D.peak_mem_bw_gbs);
        assert!((N300D.fp16_tflops - 2.0 * N150D.fp16_tflops).abs() < 20.0);
    }
}
