//! Wormhole architecture model: data formats, Table-1/2 constants, and
//! BF16 flush-to-zero arithmetic (paper §3).

pub mod bf16;
pub mod constants;
pub mod dataformat;
pub mod specs;

pub use bf16::{bf16_round, bf16_round_slice, ftz_f32, Bf16};
pub use dataformat::{ComputeUnit, DataFormat};
