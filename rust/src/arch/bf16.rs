//! Software bfloat16 with Wormhole's flush-to-zero (FTZ) semantics.
//!
//! Paper §3.3 ("Subnormals"): the Wormhole compute units do not support
//! denormal/subnormal computation and instead flush to zero. We model this
//! exactly: subnormal *inputs* are flushed before an operation and
//! subnormal *results* are flushed after rounding. Rounding is
//! round-to-nearest-even (truncation of the f32 mantissa with RNE, the
//! standard bf16 conversion).
//!
//! The same FTZ treatment is applied to the FP32 SFPU path via
//! [`ftz_f32`], since §3.3 describes FTZ as a property of the compute
//! units, not of the 16-bit format.

/// A bfloat16 value stored as its raw 16-bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bf16(pub u16);

/// Flush f32 subnormals to (sign-preserving) zero.
#[inline]
pub fn ftz_f32(x: f32) -> f32 {
    if x != 0.0 && x.abs() < f32::MIN_POSITIVE {
        if x.is_sign_negative() {
            -0.0
        } else {
            0.0
        }
    } else {
        x
    }
}

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    pub const ONE: Bf16 = Bf16(0x3F80);

    /// Smallest positive *normal* bf16 = 2^-126 (same exponent range as f32).
    pub const MIN_POSITIVE: f32 = f32::MIN_POSITIVE;

    /// Convert from f32 with round-to-nearest-even, flushing subnormal
    /// inputs and subnormal results to zero.
    #[inline]
    pub fn from_f32(x: f32) -> Bf16 {
        let x = ftz_f32(x);
        if x.is_nan() {
            // Quiet NaN, preserving sign bit.
            let bits = x.to_bits();
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let bits = x.to_bits();
        // Round to nearest even on the truncated 16 bits.
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
        let mut hi = (rounded >> 16) as u16;
        let _ = round_bit;
        // Flush results that became subnormal in bf16 (exponent == 0,
        // mantissa != 0). bf16 shares f32's exponent range, so this only
        // triggers for inputs that were already near the subnormal edge.
        if (hi & 0x7F80) == 0 && (hi & 0x007F) != 0 {
            hi &= 0x8000; // signed zero
        }
        Bf16(hi)
    }

    /// Widen to f32 (exact), flushing stored subnormals (defensive; they
    /// cannot normally be constructed through this API).
    #[inline]
    pub fn to_f32(self) -> f32 {
        let f = f32::from_bits((self.0 as u32) << 16);
        ftz_f32(f)
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        (self.0 & 0x7F80) == 0x7F80 && (self.0 & 0x007F) != 0
    }

    /// a + b in the Wormhole BF16 data path: flush inputs, compute in f32,
    /// round to bf16 (RNE), flush result.
    #[inline]
    pub fn add(a: Bf16, b: Bf16) -> Bf16 {
        Bf16::from_f32(a.to_f32() + b.to_f32())
    }

    #[inline]
    pub fn sub(a: Bf16, b: Bf16) -> Bf16 {
        Bf16::from_f32(a.to_f32() - b.to_f32())
    }

    #[inline]
    pub fn mul(a: Bf16, b: Bf16) -> Bf16 {
        Bf16::from_f32(a.to_f32() * b.to_f32())
    }
}

/// Round an f32 through the BF16 datapath: the canonical "value passed
/// through the FPU" operation used by the native engine for BF16 kernels.
///
/// §Perf optimization 2: this is the native engine's innermost operation
/// (~180M calls per simulated PCG iteration at the Table-3 size), so it is
/// implemented directly on the bit pattern — semantically identical to
/// `Bf16::from_f32(x).to_f32()` (pinned by `fast_path_matches_bf16_type`):
/// flush subnormal inputs, RNE-round to bf16, quiet NaNs, overflow to inf.
#[inline(always)]
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let exp = bits & 0x7F80_0000;
    if exp == 0 {
        // Zero or subnormal input: flush to sign-preserving zero (§3.3).
        return f32::from_bits(bits & 0x8000_0000);
    }
    if exp == 0x7F80_0000 {
        // Inf passes through; NaN gets the quiet bit, as Bf16::from_f32.
        if bits & 0x007F_FFFF != 0 {
            return f32::from_bits((bits & 0xFFFF_0000) | 0x0040_0000);
        }
        return x;
    }
    // Round-to-nearest-even on the low 16 bits. A normal input cannot
    // round to a bf16 subnormal (magnitude never decreases past the
    // exponent floor), so no post-round flush is needed.
    let lsb = (bits >> 16) & 1;
    f32::from_bits(bits.wrapping_add(0x7FFF + lsb) & 0xFFFF_0000)
}

/// Element-wise helper: round a whole slice through BF16.
pub fn bf16_round_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = bf16_round(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, -0.5, 2.0, 256.0, -1024.0, 1.5] {
            assert_eq!(Bf16::from_f32(v).to_f32(), v, "roundtrip {v}");
        }
        assert_eq!(Bf16::ONE.to_f32(), 1.0);
    }

    #[test]
    fn rne_rounding() {
        // 1 + 2^-8 is exactly between bf16(1.0) and the next value; RNE
        // picks the even mantissa (1.0).
        let x = 1.0 + 2f32.powi(-8);
        assert_eq!(Bf16::from_f32(x).to_f32(), 1.0);
        // 1 + 3*2^-8 is between 1+2^-7 and 1+2^-6; RNE picks 1+2^-6 (even).
        let y = 1.0 + 3.0 * 2f32.powi(-8);
        assert_eq!(Bf16::from_f32(y).to_f32(), 1.0 + 2f32.powi(-6));
        // Values just above the midpoint round up.
        let z = 1.0 + 2f32.powi(-8) + 2f32.powi(-12);
        assert_eq!(Bf16::from_f32(z).to_f32(), 1.0 + 2f32.powi(-7));
    }

    #[test]
    fn subnormal_inputs_flush_to_zero() {
        let sub = f32::MIN_POSITIVE / 2.0;
        assert!(sub > 0.0 && !sub.is_normal());
        assert_eq!(Bf16::from_f32(sub).to_f32(), 0.0);
        assert_eq!(Bf16::from_f32(-sub).to_f32(), -0.0);
        assert!(Bf16::from_f32(-sub).to_f32().is_sign_negative());
        assert_eq!(ftz_f32(sub), 0.0);
        assert_eq!(ftz_f32(1.0), 1.0);
        assert_eq!(ftz_f32(-0.0), -0.0);
    }

    #[test]
    fn multiply_underflow_flushes() {
        // 2^-100 * 2^-100 = 2^-200 → subnormal/underflow → 0 on Wormhole.
        let a = Bf16::from_f32(2f32.powi(-100));
        let b = Bf16::from_f32(2f32.powi(-100));
        assert_eq!(Bf16::mul(a, b).to_f32(), 0.0);
        // While IEEE would give a subnormal f32 here.
        let ieee = 2f32.powi(-100) * 2f32.powi(-100);
        assert!(ieee == 0.0 || !ieee.is_normal());
    }

    #[test]
    fn arithmetic_matches_f32_then_round() {
        let a = Bf16::from_f32(1.25);
        let b = Bf16::from_f32(3.5);
        assert_eq!(Bf16::add(a, b).to_f32(), 4.75);
        assert_eq!(Bf16::sub(a, b).to_f32(), -2.25);
        assert_eq!(Bf16::mul(a, b).to_f32(), 4.375);
    }

    #[test]
    fn nan_and_inf_preserved() {
        assert!(Bf16::from_f32(f32::NAN).is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
        // Overflow to infinity.
        assert_eq!(Bf16::from_f32(3.4e38f32 * 2.0).to_f32(), f32::INFINITY);
    }

    #[test]
    fn precision_is_8_bits() {
        // bf16 has 8 significand bits: 256 + 1 is not representable.
        assert_eq!(bf16_round(257.0), 256.0);
        assert_eq!(bf16_round(258.0), 258.0);
    }

    #[test]
    fn fast_path_matches_bf16_type() {
        // bf16_round must equal Bf16::from_f32().to_f32() bit for bit
        // across the full value spectrum, including subnormals, ±0,
        // inf/NaN, and overflow.
        use crate::util::prng::Rng;
        let mut rng = Rng::new(0xFA57);
        let mut check = |x: f32| {
            let fast = bf16_round(x);
            let slow = Bf16::from_f32(x).to_f32();
            if fast.is_nan() || slow.is_nan() {
                assert_eq!(fast.is_nan(), slow.is_nan(), "NaN mismatch for {x}");
            } else {
                assert_eq!(fast.to_bits(), slow.to_bits(), "mismatch for {x:e}");
            }
        };
        for &x in &[
            0.0f32,
            -0.0,
            1.0,
            -1.0,
            257.0,
            f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 2.0,
            -f32::MIN_POSITIVE / 4.0,
            f32::MAX,
            -f32::MAX,
            3.39e38,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            1.0 + 2f32.powi(-8),
            1.0 + 3.0 * 2f32.powi(-8),
        ] {
            check(x);
        }
        for _ in 0..200_000 {
            check(f32::from_bits(rng.next_u64() as u32));
        }
    }

    #[test]
    fn round_slice() {
        let mut xs = vec![1.0f32, 257.0, f32::MIN_POSITIVE / 2.0];
        bf16_round_slice(&mut xs);
        assert_eq!(xs, vec![1.0, 256.0, 0.0]);
    }
}
