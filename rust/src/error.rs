//! Crate-wide error type.

use thiserror::Error;

#[derive(Debug, Error)]
pub enum SimError {
    #[error("SRAM exhausted on core {core}: requested {requested} B, {available} B free of {capacity} B")]
    SramExhausted {
        core: String,
        requested: usize,
        available: usize,
        capacity: usize,
    },

    #[error("misaligned {what}: address/size {value:#x} must be {align}-byte aligned")]
    Misaligned {
        what: &'static str,
        value: usize,
        align: usize,
    },

    #[error("circular buffer '{name}' overflow: capacity {capacity} pages, {pending} pending")]
    CbOverflow {
        name: String,
        capacity: usize,
        pending: usize,
    },

    #[error("circular buffer '{name}' underflow: pop/wait on empty buffer")]
    CbUnderflow { name: String },

    #[error("CB pointer manipulation on '{name}' by {delta} B not a multiple of {align} B (§6.2)")]
    CbPtrAlign {
        name: String,
        delta: isize,
        align: usize,
    },

    #[error("DRAM access out of range: offset {offset} + len {len} > capacity {capacity}")]
    DramRange {
        offset: u64,
        len: usize,
        capacity: u64,
    },

    #[error("invalid core coordinate ({row}, {col}) for {rows}x{cols} grid")]
    BadCoord {
        row: usize,
        col: usize,
        rows: usize,
        cols: usize,
    },

    #[error("sub-grid {rows}x{cols} exceeds the maximum usable compute sub-grid {max_rows}x{max_cols} (§7.2)")]
    SubgridTooLarge {
        rows: usize,
        cols: usize,
        max_rows: usize,
        max_cols: usize,
    },

    #[error("problem does not tile evenly: {what}")]
    BadProblem { what: String },

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("{0}")]
    Other(String),
}

pub type Result<T> = std::result::Result<T, SimError>;
