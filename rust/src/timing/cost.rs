//! Cycle cost model for Tensix operations (§3.3, §4).
//!
//! Costs are charged per *tile operation*. An operation's cycles combine:
//!
//! - unpack (SRAM → Src regs) at 64 B/clk per input tile,
//! - compute on the FPU (128 eltwise elems/clk, 256 reduce elems/clk) or
//!   SFPU (32/16 elems/clk for 16/32-bit) with its Dst-copy (32 B/clk) and
//!   lane load/store surcharges,
//! - pack (Dst → SRAM) at 64 B/clk,
//! - a RISC-V issue overhead that depends on whether the op streams through
//!   a long pipeline (amortized) or sits in a dependent sequence (exposed).
//!
//! The FPU eltwise point of the paper's Fig-3 roofline emerges from this
//! model: 3 tiles moved at 64 B/clk dominates the 8-cycle compute, giving
//! the 1-FLOP-per-6-bytes arithmetic intensity; the SFPU point adds the
//! Dst copy and lane load/stores for ~1/16 FLOP per byte.

use crate::arch::constants::*;
use crate::arch::{ComputeUnit, DataFormat};
use crate::timing::calib::Calib;

/// What a tile operation does, for costing purposes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileOpKind {
    /// Element-wise binary op (add/sub/mul): 2 inputs, 1 output.
    EltwiseBinary,
    /// Element-wise unary (scale / scalar add / copy): 1 input, 1 output.
    EltwiseUnary,
    /// Reduce one tile to a scalar (row/col reduction tree on the FPU).
    ReduceTile,
    /// Face-wise transpose (matrix unit), 1 input, 1 output.
    Transpose,
    /// Copy through a displaced CB read pointer (§6.2): costed as a copy.
    ShiftCopy,
}

impl TileOpKind {
    pub const fn input_tiles(self) -> u64 {
        match self {
            TileOpKind::EltwiseBinary => 2,
            _ => 1,
        }
    }

    pub const fn output_tiles(self) -> u64 {
        match self {
            TileOpKind::ReduceTile => 0, // scalar result stays in Dst
            _ => 1,
        }
    }

    /// FLOPs per element, for roofline accounting.
    pub const fn flops_per_elem(self) -> u64 {
        match self {
            TileOpKind::EltwiseBinary => 1,
            TileOpKind::EltwiseUnary => 1,
            TileOpKind::ReduceTile => 1,
            TileOpKind::Transpose | TileOpKind::ShiftCopy => 0,
        }
    }
}

/// Whether issue overhead is amortized by pipelining.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineMode {
    /// Long independent tile stream: unpack/compute/pack overlap across
    /// tiles and the issue cost is the residual per-tile bookkeeping.
    Streamed,
    /// Dependent sequence (stencil shift/transpose chains): each op's
    /// movement and issue are exposed.
    Dependent,
}

/// Cycle cost model, parameterized by the calibration set.
#[derive(Debug, Clone, Default)]
pub struct CostModel {
    pub calib: Calib,
}

impl CostModel {
    pub fn new(calib: Calib) -> Self {
        Self { calib }
    }

    /// Cycles to unpack one tile from SRAM into Src registers.
    pub fn unpack_cycles(&self, df: DataFormat) -> u64 {
        (df.tile_bytes() as u64).div_ceil(UNPACKER_BYTES_PER_CLK as u64)
    }

    /// Cycles to pack one tile from Dst back to SRAM.
    pub fn pack_cycles(&self, df: DataFormat) -> u64 {
        (df.tile_bytes() as u64).div_ceil(PACKER_BYTES_PER_CLK as u64)
    }

    /// Pure arithmetic cycles for one tile on a unit.
    pub fn compute_cycles(&self, unit: ComputeUnit, df: DataFormat, kind: TileOpKind) -> u64 {
        let n = TILE_ELEMS as u64;
        match unit {
            ComputeUnit::Fpu => {
                assert!(
                    df.fpu_capable(),
                    "FPU restricted to <=19-bit formats (§3.3), got {df}"
                );
                match kind {
                    TileOpKind::EltwiseBinary | TileOpKind::EltwiseUnary | TileOpKind::ShiftCopy => {
                        n.div_ceil(FPU_ELTWISE_ELEMS_PER_CLK as u64)
                    }
                    TileOpKind::ReduceTile => n.div_ceil(FPU_REDUCE_ELEMS_PER_CLK as u64),
                    // The matrix unit transposes 4 faces; same engine rate
                    // as an eltwise pass.
                    TileOpKind::Transpose => n.div_ceil(FPU_ELTWISE_ELEMS_PER_CLK as u64),
                }
            }
            ComputeUnit::Sfpu => {
                assert!(df.sfpu_capable(), "SFPU supports 16/32-bit formats, got {df}");
                let per_clk = match df {
                    DataFormat::Fp32 => SFPU_ELEMS_PER_CLK_32B,
                    _ => SFPU_ELEMS_PER_CLK_16B,
                } as u64;
                let arith = match kind {
                    // Reductions on the SFPU need a log-depth shuffle
                    // sequence; "a more expensive sequence of operations"
                    // (§5). Model as 3 passes.
                    TileOpKind::ReduceTile => 3 * n.div_ceil(per_clk),
                    // The tile transpose is a matrix-unit primitive (§6.3)
                    // limited to ≤19-bit formats; at FP32 it must be
                    // emulated through the vector lanes — 2 passes.
                    TileOpKind::Transpose => 2 * n.div_ceil(per_clk),
                    _ => n.div_ceil(per_clk),
                };
                // Dst copy (32 B/clk) + lane load/store surcharge (§4).
                let dst_copy = (df.tile_bytes() as u64).div_ceil(DST_COPY_BYTES_PER_CLK as u64);
                arith + dst_copy + self.calib.sfpu_lane_loadstore_cycles
            }
        }
    }

    /// Full cost of one tile operation.
    pub fn tile_op_cycles(
        &self,
        unit: ComputeUnit,
        df: DataFormat,
        kind: TileOpKind,
        mode: PipelineMode,
    ) -> u64 {
        let unpack = kind.input_tiles() * self.unpack_cycles(df);
        let pack = kind.output_tiles() * self.pack_cycles(df);
        let compute = self.compute_cycles(unit, df, kind);
        match mode {
            PipelineMode::Streamed => {
                // Movement and compute overlap across the stream. Unpack
                // and pack contend for the same SRAM bandwidth (the paper's
                // Fig-3 roofline uses a single 64 B/clk ceiling for all
                // tile movement), so their sum is the memory term; the
                // slower of memory and compute binds, plus residual issue.
                (unpack + pack).max(compute) + self.calib.stream_issue_cycles
            }
            PipelineMode::Dependent => {
                unpack + compute + pack + self.calib.tile_op_issue_cycles
            }
        }
    }

    /// Cycles for the baby RISC-V to zero-fill `elems` halo elements (§6.3).
    pub fn zero_fill_cycles(&self, elems: u64) -> u64 {
        elems * self.calib.zero_fill_cycles_per_elem
    }

    /// Cycles to stream `bytes` from/to DRAM (single-core stream; used by
    /// the Fig-3 DRAM-facing variants and the split-kernel staging model).
    pub fn dram_stream_cycles(&self, bytes: u64) -> u64 {
        let bw_bytes_per_cycle =
            DRAM_BW_PER_DIE_GBS * 1e9 * self.calib.dram_bw_efficiency / CLOCK_HZ;
        self.calib.dram_latency_cycles + (bytes as f64 / bw_bytes_per_cycle).ceil() as u64
    }

    /// Achieved FLOP/s for an eltwise stream at the Tensix clock, given the
    /// per-tile cycle cost (Fig-3 y-axis).
    pub fn eltwise_gflops(&self, cycles_per_tile: u64) -> f64 {
        TILE_ELEMS as f64 / cycles_per_tile as f64 * CLOCK_HZ / 1e9
    }

    /// Roofline characterization for Fig 3.
    /// Returns (arithmetic intensity FLOP/byte, attainable GFLOP/s) for an
    /// eltwise add on `unit`.
    pub fn roofline_point(&self, unit: ComputeUnit, df: DataFormat) -> (f64, f64) {
        let cycles = self.tile_op_cycles(unit, df, TileOpKind::EltwiseBinary, PipelineMode::Streamed);
        let ai = match unit {
            // 2 reads + 1 write per element (§4): 1 FLOP / 6 bytes at BF16.
            ComputeUnit::Fpu => 1.0 / (3.0 * df.bytes() as f64),
            // + Dst copy and lane load/stores: ~1 FLOP / 16 bytes (§4).
            ComputeUnit::Sfpu => 1.0 / (3.0 * df.bytes() as f64 + 10.0),
        };
        (ai, self.eltwise_gflops(cycles))
    }

    /// Peak compute for the roofline ceiling (GFLOP/s per core).
    pub fn peak_gflops(&self, unit: ComputeUnit, df: DataFormat) -> f64 {
        let per_clk = match unit {
            ComputeUnit::Fpu => FPU_ELTWISE_ELEMS_PER_CLK,
            ComputeUnit::Sfpu => match df {
                DataFormat::Fp32 => SFPU_ELEMS_PER_CLK_32B,
                _ => SFPU_ELEMS_PER_CLK_16B,
            },
        };
        per_clk as f64 * CLOCK_HZ / 1e9
    }

    /// SRAM bandwidth ceiling of the roofline (GB/s through pack/unpack).
    pub fn sram_bw_gbs(&self) -> f64 {
        UNPACKER_BYTES_PER_CLK as f64 * CLOCK_HZ / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> CostModel {
        CostModel::default()
    }

    #[test]
    fn unpack_pack_rates() {
        // BF16 tile = 2048 B at 64 B/clk = 32 cycles.
        assert_eq!(m().unpack_cycles(DataFormat::Bf16), 32);
        assert_eq!(m().pack_cycles(DataFormat::Bf16), 32);
        assert_eq!(m().unpack_cycles(DataFormat::Fp32), 64);
    }

    #[test]
    fn fpu_compute_rates_from_table1() {
        let c = m();
        assert_eq!(
            c.compute_cycles(ComputeUnit::Fpu, DataFormat::Bf16, TileOpKind::EltwiseBinary),
            8
        ); // 1024 / 128
        assert_eq!(
            c.compute_cycles(ComputeUnit::Fpu, DataFormat::Bf16, TileOpKind::ReduceTile),
            4
        ); // 1024 / 256
    }

    #[test]
    #[should_panic(expected = "FPU restricted")]
    fn fpu_rejects_fp32() {
        let _ = m().compute_cycles(ComputeUnit::Fpu, DataFormat::Fp32, TileOpKind::EltwiseBinary);
    }

    #[test]
    fn streamed_fpu_eltwise_is_memory_bound() {
        // §4: the FPU eltwise achieves near-peak of the 64 B/clk roofline.
        // 3 tiles moved (2 unpack + 1 pack) of 2048 B = 96 cycles dominates
        // the 8 compute cycles.
        let c = m();
        let cycles = c.tile_op_cycles(
            ComputeUnit::Fpu,
            DataFormat::Bf16,
            TileOpKind::EltwiseBinary,
            PipelineMode::Streamed,
        );
        assert_eq!(cycles, 96 + c.calib.stream_issue_cycles);
    }

    #[test]
    fn sfpu_is_about_6x_slower_than_fpu_at_bf16() {
        // §4: "around 6 times slower than the FPU".
        let c = m();
        let fpu = c.tile_op_cycles(
            ComputeUnit::Fpu,
            DataFormat::Bf16,
            TileOpKind::EltwiseBinary,
            PipelineMode::Streamed,
        );
        let sfpu = c.tile_op_cycles(
            ComputeUnit::Sfpu,
            DataFormat::Bf16,
            TileOpKind::EltwiseBinary,
            PipelineMode::Streamed,
        );
        let ratio = sfpu as f64 / fpu as f64;
        assert!((4.0..8.0).contains(&ratio), "SFPU/FPU ratio {ratio}");
    }

    #[test]
    fn fp32_sfpu_slower_than_bf16_sfpu() {
        let c = m();
        let b = c.compute_cycles(ComputeUnit::Sfpu, DataFormat::Bf16, TileOpKind::EltwiseBinary);
        let f = c.compute_cycles(ComputeUnit::Sfpu, DataFormat::Fp32, TileOpKind::EltwiseBinary);
        assert!(f > b);
    }

    #[test]
    fn roofline_points_fig3() {
        let c = m();
        let (ai_fpu, gf_fpu) = c.roofline_point(ComputeUnit::Fpu, DataFormat::Bf16);
        let (ai_sfpu, gf_sfpu) = c.roofline_point(ComputeUnit::Sfpu, DataFormat::Bf16);
        // §4: FPU AI = 1/6, SFPU ≈ 1/16 at 16-bit.
        assert!((ai_fpu - 1.0 / 6.0).abs() < 1e-9);
        assert!((ai_sfpu - 1.0 / 16.0).abs() < 1e-9);
        // FPU point near the BW-bound roofline: BW * AI.
        let bound = c.sram_bw_gbs() * ai_fpu;
        assert!(gf_fpu > 0.8 * bound, "gf_fpu {gf_fpu} vs bound {bound}");
        assert!(gf_fpu <= bound * 1.01);
        // SFPU several times below.
        assert!(gf_fpu / gf_sfpu > 4.0);
    }

    #[test]
    fn dependent_mode_charges_full_movement() {
        let c = m();
        let s = c.tile_op_cycles(
            ComputeUnit::Fpu,
            DataFormat::Bf16,
            TileOpKind::Transpose,
            PipelineMode::Streamed,
        );
        let d = c.tile_op_cycles(
            ComputeUnit::Fpu,
            DataFormat::Bf16,
            TileOpKind::Transpose,
            PipelineMode::Dependent,
        );
        assert!(d > s);
    }

    #[test]
    fn dram_stream_includes_latency() {
        let c = m();
        let small = c.dram_stream_cycles(32);
        assert!(small >= c.calib.dram_latency_cycles);
        let big = c.dram_stream_cycles(1 << 20);
        assert!(big > small);
    }
}
