//! Calibration constants for the cycle cost model.
//!
//! Everything the paper pins down numerically lives in
//! [`crate::arch::constants`]. The constants here are *tunables*: quantities
//! the paper describes qualitatively (e.g. "the high latency load and store
//! access of the baby RISC-V's to the L1", §6.3) but does not quantify.
//! Each is documented with the paper statement that motivates it and the
//! observable it was tuned against (see EXPERIMENTS.md §Calibration).
//! They can be overridden at run time through `[calib]` entries in a config
//! file for sensitivity studies (`wormsim figures --config ...`).

/// Per-hop router latency of the NoC, cycles. The paper repeatedly observes
/// the NoC is "incredibly low latency" (§5.1); Tenstorrent documents ~1
/// cycle per hop plus link traversal — we use a small constant.
pub const NOC_HOP_CYCLES: u64 = 9;

/// NoC link bandwidth, bytes per cycle per link (Wormhole NoC moves 32B
/// flits per cycle per direction).
pub const NOC_LINK_BYTES_PER_CLK: u64 = 32;

/// Software cost for a baby RISC-V NoC core to issue one asynchronous
/// NoC transaction (address formation, command queue write). Motivated by
/// §6.3's observation that RISC-V-driven L1 traffic is slow. Tuned against
/// Fig 6 (center-vs-naive crossover at small tile counts).
pub const NOC_ISSUE_CYCLES: u64 = 250;

/// Cost for the receiving core to notice + account an arrived transfer
/// (semaphore check on the data-movement core).
pub const NOC_RECV_CYCLES: u64 = 80;

/// Marginal issue cost for subsequent messages in a *batched* send
/// sequence (the halo exchange issues one write per tile per direction
/// back-to-back; address generation in a tight RISC-V loop is much cheaper
/// than a cold transaction). Tuned so the Fig-11 halo cost stays well
/// below local compute, as the paper observes (§6.3).
pub const NOC_BATCH_ISSUE_CYCLES: u64 = 28;

/// Per-element cycle cost for the baby RISC-V to zero-fill halo elements
/// through L1 ("unexpectedly expensive due to the high latency load and
/// store access of the baby RISC-V's to the L1", §6.3). Tuned against the
/// Fig 11 1×1/2×2 anomaly.
pub const ZERO_FILL_CYCLES_PER_ELEM: u64 = 18;

/// Issue overhead charged per dependent tile operation in a compute kernel:
/// CB reserve/push/wait/pop bookkeeping plus compute-core dispatch. This is
/// the dominant non-arithmetic cost of the stencil's shift/transpose
/// pipeline. Tuned against Table 3 (BF16 1.20 ms/iter).
pub const TILE_OP_ISSUE_CYCLES: u64 = 760;

/// Residual issue overhead for *streamed* (pipelined) element-wise
/// operations where the three kernels overlap unpack/compute/pack across a
/// long tile stream (§4's near-roofline FPU point requires this to be
/// small).
pub const STREAM_ISSUE_CYCLES: u64 = 12;

/// Extra per-tile cycles for SFPU operations beyond the 32-lane arithmetic:
/// moving data between Dst and the vector lanes and back ("further
/// load-store operations", §4). Tuned so the SFPU eltwise point lands ~6×
/// below the FPU point (Fig 3).
pub const SFPU_LANE_LOADSTORE_CYCLES: u64 = 550;

/// Host-side cost to launch one kernel on the device (enqueue, dispatch,
/// start barrier), nanoseconds. Charged per kernel per iteration in the
/// split-kernel PCG; once overall in the fused PCG. Tuned against the
/// FP32/BF16 gap in Table 3.
pub const KERNEL_LAUNCH_NS: f64 = 12_000.0;

/// Cost to move the residual norm back to the host through DRAM + PCIe,
/// nanoseconds per iteration (split-kernel PCG only; the fused variant
/// keeps it in SRAM, §7.1).
pub const RESIDUAL_READBACK_NS: f64 = 55_000.0;

/// Per-iteration device-side synchronization gap observed between
/// immediately-subsequent kernels in the paper's Tracy traces (§7.3:
/// "substantial execution gaps ... between what should be
/// immediately-subsequent kernels"). Charged once per kernel boundary on
/// the device. Nanoseconds.
pub const INTER_KERNEL_GAP_NS: f64 = 9_000.0;

/// Cycles for a baby RISC-V to merge one incoming *scalar* partial into a
/// local accumulator (§5.1 method 1 per-hop work).
pub const SCALAR_MERGE_CYCLES: u64 = 60;

/// Extra per-core cycles of routing logic for the center reduction pattern
/// ("the increased complexity of the center routing pattern computation",
/// §5.2 — it outweighs the benefit at the smallest problem sizes). Tuned
/// against the Fig 6 crossover.
pub const CENTER_ROUTE_OVERHEAD_CYCLES: u64 = 1000;

/// DRAM round-trip: cycles of latency for the first access of a stream.
pub const DRAM_LATENCY_CYCLES: u64 = 350;

/// Fraction of peak DRAM bandwidth a single streaming reader achieves
/// (GDDR6 efficiency; used by the Fig-3 DRAM-facing eltwise variants).
pub const DRAM_BW_EFFICIENCY: f64 = 0.75;

/// A mutable snapshot of the tunables, so experiments can run sensitivity
/// sweeps without recompiling. `Calib::default()` is the calibrated set.
#[derive(Debug, Clone, PartialEq)]
pub struct Calib {
    pub noc_hop_cycles: u64,
    pub noc_link_bytes_per_clk: u64,
    pub noc_issue_cycles: u64,
    pub noc_recv_cycles: u64,
    pub noc_batch_issue_cycles: u64,
    pub zero_fill_cycles_per_elem: u64,
    pub tile_op_issue_cycles: u64,
    pub stream_issue_cycles: u64,
    pub sfpu_lane_loadstore_cycles: u64,
    pub scalar_merge_cycles: u64,
    pub center_route_overhead_cycles: u64,
    pub kernel_launch_ns: f64,
    pub residual_readback_ns: f64,
    pub inter_kernel_gap_ns: f64,
    pub dram_latency_cycles: u64,
    pub dram_bw_efficiency: f64,
}

impl Default for Calib {
    fn default() -> Self {
        Self {
            noc_hop_cycles: NOC_HOP_CYCLES,
            noc_link_bytes_per_clk: NOC_LINK_BYTES_PER_CLK,
            noc_issue_cycles: NOC_ISSUE_CYCLES,
            noc_recv_cycles: NOC_RECV_CYCLES,
            noc_batch_issue_cycles: NOC_BATCH_ISSUE_CYCLES,
            zero_fill_cycles_per_elem: ZERO_FILL_CYCLES_PER_ELEM,
            tile_op_issue_cycles: TILE_OP_ISSUE_CYCLES,
            stream_issue_cycles: STREAM_ISSUE_CYCLES,
            sfpu_lane_loadstore_cycles: SFPU_LANE_LOADSTORE_CYCLES,
            scalar_merge_cycles: SCALAR_MERGE_CYCLES,
            center_route_overhead_cycles: CENTER_ROUTE_OVERHEAD_CYCLES,
            kernel_launch_ns: KERNEL_LAUNCH_NS,
            residual_readback_ns: RESIDUAL_READBACK_NS,
            inter_kernel_gap_ns: INTER_KERNEL_GAP_NS,
            dram_latency_cycles: DRAM_LATENCY_CYCLES,
            dram_bw_efficiency: DRAM_BW_EFFICIENCY,
        }
    }
}

impl Calib {
    /// Apply `[calib]` overrides from a mini-TOML document.
    pub fn apply_overrides(&mut self, doc: &crate::util::tomlmini::Doc) {
        let sec = "calib";
        let get_u = |k: &str, tgt: &mut u64| {
            if let Some(v) = doc.get_int(sec, k) {
                *tgt = v as u64;
            }
        };
        get_u("noc_hop_cycles", &mut self.noc_hop_cycles);
        get_u("noc_link_bytes_per_clk", &mut self.noc_link_bytes_per_clk);
        get_u("noc_issue_cycles", &mut self.noc_issue_cycles);
        get_u("noc_recv_cycles", &mut self.noc_recv_cycles);
        get_u("noc_batch_issue_cycles", &mut self.noc_batch_issue_cycles);
        get_u("zero_fill_cycles_per_elem", &mut self.zero_fill_cycles_per_elem);
        get_u("tile_op_issue_cycles", &mut self.tile_op_issue_cycles);
        get_u("stream_issue_cycles", &mut self.stream_issue_cycles);
        get_u(
            "sfpu_lane_loadstore_cycles",
            &mut self.sfpu_lane_loadstore_cycles,
        );
        get_u("scalar_merge_cycles", &mut self.scalar_merge_cycles);
        get_u(
            "center_route_overhead_cycles",
            &mut self.center_route_overhead_cycles,
        );
        get_u("dram_latency_cycles", &mut self.dram_latency_cycles);
        let get_f = |k: &str, tgt: &mut f64| {
            if let Some(v) = doc.get_float(sec, k) {
                *tgt = v;
            }
        };
        get_f("kernel_launch_ns", &mut self.kernel_launch_ns);
        get_f("residual_readback_ns", &mut self.residual_readback_ns);
        get_f("inter_kernel_gap_ns", &mut self.inter_kernel_gap_ns);
        get_f("dram_bw_efficiency", &mut self.dram_bw_efficiency);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tomlmini::Doc;

    #[test]
    fn default_matches_constants() {
        let c = Calib::default();
        assert_eq!(c.noc_hop_cycles, NOC_HOP_CYCLES);
        assert_eq!(c.tile_op_issue_cycles, TILE_OP_ISSUE_CYCLES);
    }

    #[test]
    fn overrides_apply() {
        let mut c = Calib::default();
        let doc = Doc::parse("[calib]\nnoc_hop_cycles = 3\nkernel_launch_ns = 5.5").unwrap();
        c.apply_overrides(&doc);
        assert_eq!(c.noc_hop_cycles, 3);
        assert_eq!(c.kernel_launch_ns, 5.5);
        // untouched fields keep defaults
        assert_eq!(c.noc_issue_cycles, NOC_ISSUE_CYCLES);
    }
}
