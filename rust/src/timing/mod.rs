//! Cycle-approximate timing: calibration constants and the Tensix cost
//! model. The simulator separates *values* (computed by an engine) from
//! *cycles* (charged here), so timing is identical across engines.

pub mod calib;
pub mod cost;

pub use calib::Calib;
pub use cost::{CostModel, PipelineMode, TileOpKind};

/// Simulated time in nanoseconds (f64 to mix cycle- and ns-domain costs).
pub type SimNs = f64;

/// Convert device cycles to simulated nanoseconds at the Tensix clock.
pub fn cycles_ns(cycles: u64) -> SimNs {
    crate::arch::constants::cycles_to_ns(cycles)
}
