//! The PJRT engine: executes the AOT-compiled JAX/Pallas artifacts for
//! every per-core kernel application. This is the three-layer composition
//! the architecture demands — L1 Pallas kernels inside L2 JAX graphs,
//! lowered once at build time, executed from the L3 Rust hot path with
//! Python nowhere at run time.
//!
//! BF16 semantics (round-to-nearest-even + flush-to-zero after every tile
//! op) are baked into the artifact graphs by `python/compile/model.py`, so
//! this engine and [`crate::engine::native::NativeEngine`] agree at BF16
//! (integration-tested in `rust/tests/integration_runtime.rs`).

use std::path::Path;

use crate::arch::DataFormat;
use crate::engine::block::{CoreBlock, Halos};
use crate::engine::traits::{ComputeEngine, StencilCoeffs};
use crate::error::{Result, SimError};
use crate::runtime::artifacts::{df_tag, ArtifactStore};
use crate::tile::EltwiseOp;

pub struct PjrtEngine {
    store: ArtifactStore,
}

impl std::fmt::Debug for PjrtEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtEngine").field("store", &self.store).finish()
    }
}

impl PjrtEngine {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        Ok(Self {
            store: ArtifactStore::new(artifacts_dir)?,
        })
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    fn artifact_name(op: &str, df: DataFormat, nz: usize) -> String {
        format!("{op}_{}_t{nz}", df_tag(df))
    }

    fn lookup(&self, op: &str, df: DataFormat, nz: usize) -> Result<String> {
        let name = Self::artifact_name(op, df, nz);
        if self.store.available(&name) {
            Ok(name)
        } else {
            Err(SimError::Artifact(format!(
                "no artifact '{name}' — AOT set covers tile counts {:?}; \
                 add {nz} to TILE_COUNTS in python/compile/aot.py and re-run `make artifacts`",
                self.store
                    .list()
                    .iter()
                    .filter(|n| n.starts_with(op))
                    .collect::<Vec<_>>()
            )))
        }
    }

    fn run_block_binary(&self, op: &str, a: &CoreBlock, b: &CoreBlock, alpha: Option<f32>) -> Result<CoreBlock> {
        if a.df != b.df || a.nz() != b.nz() {
            return Err(SimError::Other("block mismatch in pjrt engine".into()));
        }
        let nz = a.nz();
        let name = self.lookup(op, a.df, nz)?;
        let af = a.to_flat();
        let bf = b.to_flat();
        let dims = [nz as i64, 64, 16];
        let alpha_store;
        let mut inputs: Vec<(&[f32], &[i64])> = vec![(&af, &dims), (&bf, &dims)];
        if let Some(al) = alpha {
            alpha_store = [al];
            inputs.push((&alpha_store, &[]));
        }
        let out = self.store.run(&name, &inputs)?;
        Ok(CoreBlock::from_flat(a.df, nz, &out[0]))
    }
}

impl ComputeEngine for PjrtEngine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn eltwise(&self, op: EltwiseOp, a: &CoreBlock, b: &CoreBlock) -> Result<CoreBlock> {
        let op_name = match op {
            EltwiseOp::Add => "eltwise_add",
            EltwiseOp::Sub => "eltwise_sub",
            EltwiseOp::Mul => "eltwise_mul",
        };
        self.run_block_binary(op_name, a, b, None)
    }

    fn axpy(&self, y: &CoreBlock, alpha: f32, x: &CoreBlock) -> Result<CoreBlock> {
        self.run_block_binary("axpy", y, x, Some(alpha))
    }

    fn scale(&self, a: &CoreBlock, alpha: f32) -> Result<CoreBlock> {
        let nz = a.nz();
        let name = self.lookup("scale", a.df, nz)?;
        let af = a.to_flat();
        let dims = [nz as i64, 64, 16];
        let alpha_store = [alpha];
        let out = self.store.run(&name, &[(&af, &dims), (&alpha_store, &[])])?;
        Ok(CoreBlock::from_flat(a.df, nz, &out[0]))
    }

    fn dot_partial(&self, a: &CoreBlock, b: &CoreBlock) -> Result<f32> {
        if a.df != b.df || a.nz() != b.nz() {
            return Err(SimError::Other("block mismatch in pjrt engine".into()));
        }
        let nz = a.nz();
        let name = self.lookup("dot", a.df, nz)?;
        let af = a.to_flat();
        let bf = b.to_flat();
        let dims = [nz as i64, 64, 16];
        let out = self.store.run(&name, &[(&af, &dims), (&bf, &dims)])?;
        out[0]
            .first()
            .copied()
            .ok_or_else(|| SimError::Runtime("dot artifact returned empty output".into()))
    }

    fn stencil_apply(&self, x: &CoreBlock, halos: &Halos, coeffs: StencilCoeffs) -> Result<CoreBlock> {
        let nz = x.nz();
        let name = self.lookup("stencil", x.df, nz)?;
        let xf = x.to_flat();
        let (hn, hs, hw, he) = halos.to_flat(nz);
        let cf = coeffs.to_array();
        let dims = [nz as i64, 64, 16];
        let dims_ns = [nz as i64, 16];
        let dims_ew = [nz as i64, 64];
        let dims_c = [7i64];
        let out = self.store.run(
            &name,
            &[
                (&xf, &dims),
                (&hn, &dims_ns),
                (&hs, &dims_ns),
                (&hw, &dims_ew),
                (&he, &dims_ew),
                (&cf, &dims_c),
            ],
        )?;
        Ok(CoreBlock::from_flat(x.df, nz, &out[0]))
    }
}
