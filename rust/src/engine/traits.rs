//! The compute-engine abstraction.
//!
//! Engines produce *values* for per-core kernel applications; the
//! simulator charges *time* independently through [`crate::timing`], so
//! any engine yields identical performance results. Two engines exist:
//!
//! - [`crate::engine::native::NativeEngine`] — Rust tile arithmetic with
//!   BF16 flush-to-zero, used for large sweeps and as the cross-check
//!   reference;
//! - [`crate::engine::pjrt::PjrtEngine`] — executes the AOT-compiled
//!   JAX/Pallas artifacts (`artifacts/*.hlo.txt`) through the PJRT C API,
//!   proving the three-layer composition end to end.

use crate::engine::block::{CoreBlock, Halos};
use crate::tile::EltwiseOp;

/// The 7-point stencil coefficients (§7, Eq. 2): the standard finite
/// difference Laplacian uses `[-1,-1,-1, 6, -1,-1,-1]`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StencilCoeffs {
    pub center: f32,
    pub x_lo: f32,
    pub x_hi: f32,
    pub y_lo: f32,
    pub y_hi: f32,
    pub z_lo: f32,
    pub z_hi: f32,
}

impl StencilCoeffs {
    /// The paper's 7-point Laplacian (§7).
    pub const LAPLACIAN: StencilCoeffs = StencilCoeffs {
        center: 6.0,
        x_lo: -1.0,
        x_hi: -1.0,
        y_lo: -1.0,
        y_hi: -1.0,
        z_lo: -1.0,
        z_hi: -1.0,
    };

    /// Flatten in the canonical artifact order:
    /// `[center, x_lo, x_hi, y_lo, y_hi, z_lo, z_hi]`.
    pub fn to_array(self) -> [f32; 7] {
        [
            self.center, self.x_lo, self.x_hi, self.y_lo, self.y_hi, self.z_lo, self.z_hi,
        ]
    }
}

/// Per-core compute operations. All methods are value-semantics: inputs
/// are immutable, outputs are fresh blocks rounded through the block's
/// data format (BF16 blocks get FTZ + RNE after every operation).
pub trait ComputeEngine {
    fn name(&self) -> &'static str;

    /// c = a `op` b, element-wise.
    fn eltwise(&self, op: EltwiseOp, a: &CoreBlock, b: &CoreBlock) -> crate::Result<CoreBlock>;

    /// out = y + alpha * x.
    fn axpy(&self, y: &CoreBlock, alpha: f32, x: &CoreBlock) -> crate::Result<CoreBlock>;

    /// y ← y + alpha * x, in place. Default delegates to [`axpy`]
    /// (engines backed by immutable executables keep the default); the
    /// native engine overrides it to avoid reallocating every tile in the
    /// solver's axpy sweeps (§Perf optimization 5).
    fn axpy_into(&self, y: &mut CoreBlock, alpha: f32, x: &CoreBlock) -> crate::Result<()> {
        *y = self.axpy(y, alpha, x)?;
        Ok(())
    }

    /// out = alpha * a.
    fn scale(&self, a: &CoreBlock, alpha: f32) -> crate::Result<CoreBlock>;

    /// Partial dot product sum(a .* b) over this core's tiles.
    fn dot_partial(&self, a: &CoreBlock, b: &CoreBlock) -> crate::Result<f32>;

    /// One 7-point stencil application over the core's block with the
    /// given halos (§6): the SpMV building block.
    fn stencil_apply(
        &self,
        x: &CoreBlock,
        halos: &Halos,
        coeffs: StencilCoeffs,
    ) -> crate::Result<CoreBlock>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laplacian_coefficients_match_eq2() {
        let c = StencilCoeffs::LAPLACIAN.to_array();
        assert_eq!(c, [6.0, -1.0, -1.0, -1.0, -1.0, -1.0, -1.0]);
    }
}
