//! Compute engines: value semantics for per-core kernels.
//!
//! `native` computes in Rust; `pjrt` executes the AOT JAX/Pallas artifacts
//! through the PJRT C API. Timing is engine-independent (see
//! [`crate::timing`]); integration tests assert the engines agree.

pub mod block;
pub mod native;
pub mod pjrt;
pub mod traits;

pub use block::{CoreBlock, Halos};
pub use native::NativeEngine;
pub use traits::{ComputeEngine, StencilCoeffs};

/// Engine selector used by the CLI / examples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    Native,
    Pjrt,
}

impl std::str::FromStr for EngineKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(EngineKind::Native),
            "pjrt" => Ok(EngineKind::Pjrt),
            _ => Err(format!("unknown engine '{s}' (expected native|pjrt)")),
        }
    }
}

/// Instantiate an engine. For `Pjrt`, `artifacts_dir` must contain the
/// `*.hlo.txt` files produced by `make artifacts`.
pub fn make_engine(
    kind: EngineKind,
    artifacts_dir: &std::path::Path,
) -> crate::Result<Box<dyn ComputeEngine>> {
    match kind {
        EngineKind::Native => Ok(Box::new(NativeEngine::new())),
        EngineKind::Pjrt => Ok(Box::new(pjrt::PjrtEngine::new(artifacts_dir)?)),
    }
}
