//! Per-core data blocks.
//!
//! Under the paper's data distribution (§6.1) each Tensix core owns a
//! column of `nz` 64×16 tiles: a 64(x) × 16(y) footprint in the horizontal
//! plane, replicated along z as one tile per level. A [`CoreBlock`] is that
//! column for one distributed vector. Grid axes map as:
//!
//! - tile rows (64)  = x  → row-shift (pointer trick) stencil direction,
//! - tile cols (16)  = y  → column-shift (transpose) stencil direction,
//! - tile index (nz) = z  → core-local vertical neighbors.

use crate::arch::DataFormat;
use crate::tile::{Tile, TileShape};

/// One core's column of tiles for one vector (§6.1).
#[derive(Debug, Clone, PartialEq)]
pub struct CoreBlock {
    pub df: DataFormat,
    pub tiles: Vec<Tile>,
}

impl CoreBlock {
    pub fn zeros(df: DataFormat, nz: usize) -> Self {
        Self {
            df,
            tiles: (0..nz).map(|_| Tile::zeros(TileShape::STENCIL, df)).collect(),
        }
    }

    /// Build from a generator over (z, x_row, y_col).
    pub fn from_fn(df: DataFormat, nz: usize, mut f: impl FnMut(usize, usize, usize) -> f32) -> Self {
        let tiles = (0..nz)
            .map(|k| Tile::from_fn(TileShape::STENCIL, df, |r, c| f(k, r, c)))
            .collect();
        Self { df, tiles }
    }

    pub fn nz(&self) -> usize {
        self.tiles.len()
    }

    pub fn elems(&self) -> usize {
        self.nz() * crate::arch::constants::TILE_ELEMS
    }

    /// Flatten to `[nz][64][16]` row-major (the artifact I/O layout).
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.elems());
        for t in &self.tiles {
            out.extend_from_slice(&t.data);
        }
        out
    }

    /// Rebuild from `[nz][64][16]` row-major.
    pub fn from_flat(df: DataFormat, nz: usize, flat: &[f32]) -> Self {
        let n = crate::arch::constants::TILE_ELEMS;
        assert_eq!(flat.len(), nz * n, "flat block length mismatch");
        let tiles = (0..nz)
            .map(|k| Tile::from_vec(TileShape::STENCIL, df, flat[k * n..(k + 1) * n].to_vec()))
            .collect();
        Self { df, tiles }
    }

    pub fn get(&self, z: usize, x: usize, y: usize) -> f32 {
        self.tiles[z].get(x, y)
    }

    pub fn set(&mut self, z: usize, x: usize, y: usize, v: f32) {
        self.tiles[z].set(x, y, v);
    }

    /// SRAM bytes of this block at its data format.
    pub fn bytes(&self) -> usize {
        self.tiles.iter().map(|t| t.bytes()).sum()
    }
}

/// Halo planes a core receives from its four neighbors for one stencil
/// application (§6.1): per z-level, one 16-wide y-row from the ±x
/// neighbors and one 64-long x-column from the ±y neighbors. `None` ⇒
/// global domain boundary ⇒ zero fill (§6.3).
#[derive(Debug, Clone, Default)]
pub struct Halos {
    /// From the x-1 neighbor: per z, the neighbor's last row (16 values).
    pub north: Option<Vec<Vec<f32>>>,
    /// From the x+1 neighbor: per z, the neighbor's first row.
    pub south: Option<Vec<Vec<f32>>>,
    /// From the y-1 neighbor: per z, the neighbor's last column (64 values).
    pub west: Option<Vec<Vec<f32>>>,
    /// From the y+1 neighbor: per z, the neighbor's first column.
    pub east: Option<Vec<Vec<f32>>>,
}

impl Halos {
    pub fn none() -> Self {
        Self::default()
    }

    /// Extract the halo planes `dst` needs from its neighbors' blocks.
    /// Each argument is the neighbor's block in the given direction, if any.
    pub fn gather(
        north: Option<&CoreBlock>,
        south: Option<&CoreBlock>,
        west: Option<&CoreBlock>,
        east: Option<&CoreBlock>,
    ) -> Self {
        let rows = crate::tile::TileShape::STENCIL.rows;
        let cols = crate::tile::TileShape::STENCIL.cols;
        Self {
            north: north.map(|b| {
                b.tiles.iter().map(|t| t.row(rows - 1).to_vec()).collect()
            }),
            south: south.map(|b| b.tiles.iter().map(|t| t.row(0).to_vec()).collect()),
            west: west.map(|b| b.tiles.iter().map(|t| t.col(cols - 1)).collect()),
            east: east.map(|b| b.tiles.iter().map(|t| t.col(0)).collect()),
        }
    }

    /// Flattened planes for the artifact I/O: absent halos become zeros.
    /// Returns (north `[nz*16]`, south `[nz*16]`, west `[nz*64]`, east `[nz*64]`).
    pub fn to_flat(&self, nz: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let flat_or_zero = |h: &Option<Vec<Vec<f32>>>, width: usize| -> Vec<f32> {
            match h {
                Some(planes) => {
                    assert_eq!(planes.len(), nz, "halo plane count mismatch");
                    planes
                        .iter()
                        .flat_map(|p| {
                            assert_eq!(p.len(), width, "halo plane width mismatch");
                            p.iter().copied()
                        })
                        .collect()
                }
                None => vec![0.0; nz * width],
            }
        };
        (
            flat_or_zero(&self.north, 16),
            flat_or_zero(&self.south, 16),
            flat_or_zero(&self.west, 64),
            flat_or_zero(&self.east, 64),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_roundtrip() {
        let b = CoreBlock::from_fn(DataFormat::Fp32, 3, |z, x, y| (z * 10000 + x * 100 + y) as f32);
        let flat = b.to_flat();
        assert_eq!(flat.len(), 3 * 1024);
        let b2 = CoreBlock::from_flat(DataFormat::Fp32, 3, &flat);
        assert_eq!(b, b2);
        assert_eq!(b.get(2, 63, 15), 26315.0);
    }

    #[test]
    fn bytes_respects_format() {
        assert_eq!(CoreBlock::zeros(DataFormat::Bf16, 4).bytes(), 4 * 2048);
        assert_eq!(CoreBlock::zeros(DataFormat::Fp32, 4).bytes(), 4 * 4096);
    }

    #[test]
    fn halo_gather_pulls_facing_boundaries() {
        // The north neighbor contributes ITS south-most (last) row.
        let nb = CoreBlock::from_fn(DataFormat::Fp32, 2, |z, x, y| {
            if x == 63 { 100.0 + (z * 16 + y) as f32 } else { 0.0 }
        });
        let eb = CoreBlock::from_fn(DataFormat::Fp32, 2, |z, x, y| {
            if y == 0 { 200.0 + (z * 64 + x) as f32 } else { 0.0 }
        });
        let h = Halos::gather(Some(&nb), None, None, Some(&eb));
        let n = h.north.as_ref().unwrap();
        assert_eq!(n[0][3], 103.0);
        assert_eq!(n[1][0], 116.0);
        let e = h.east.as_ref().unwrap();
        assert_eq!(e[0][5], 205.0);
        assert_eq!(e[1][63], 200.0 + 127.0);
        assert!(h.south.is_none() && h.west.is_none());
    }

    #[test]
    fn halo_flat_zero_fills_missing() {
        let h = Halos::none();
        let (n, s, w, e) = h.to_flat(2);
        assert_eq!(n.len(), 32);
        assert_eq!(s.len(), 32);
        assert_eq!(w.len(), 128);
        assert_eq!(e.len(), 128);
        assert!(n.iter().chain(&s).chain(&w).chain(&e).all(|&v| v == 0.0));
    }
}
