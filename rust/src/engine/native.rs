//! The native engine: Rust tile arithmetic with the Wormhole numerics
//! (BF16 round-to-nearest-even + flush-to-zero after every tile op; FTZ on
//! the FP32/SFPU path). The stencil follows the §6.2 device pipeline —
//! shifted-tile construction then scaled accumulation — in a canonical
//! operation order shared with the Pallas kernel (`python/compile/kernels/
//! stencil.py`), so native and PJRT engines agree bit-for-bit at BF16.

use crate::engine::block::{CoreBlock, Halos};
use crate::engine::traits::{ComputeEngine, StencilCoeffs};
use crate::error::{Result, SimError};
use crate::tile::ops::{self, EltwiseOp};
use crate::tile::shift::{shift_logical, ShiftDir};
use crate::tile::Tile;

#[derive(Debug, Default, Clone)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        Self
    }

    fn check_match(a: &CoreBlock, b: &CoreBlock) -> Result<()> {
        if a.df != b.df || a.nz() != b.nz() {
            return Err(SimError::Other(format!(
                "block mismatch: {:?}/{} vs {:?}/{}",
                a.df,
                a.nz(),
                b.df,
                b.nz()
            )));
        }
        Ok(())
    }

    /// The z-neighbor tile, or a zero tile at the global top/bottom
    /// boundary (zero Dirichlet, §7).
    fn z_neighbor(x: &CoreBlock, k: usize, dz: isize) -> Tile {
        let kk = k as isize + dz;
        if kk < 0 || kk >= x.nz() as isize {
            Tile::zeros(x.tiles[k].shape, x.df)
        } else {
            x.tiles[kk as usize].clone()
        }
    }
}

/// Per-element quantization matching `tile::ops::quant`, monomorphized on
/// the data format so the stencil inner loop stays branch-free.
#[inline(always)]
fn q_elem<const BF16: bool>(v: f32) -> f32 {
    if BF16 {
        crate::arch::bf16::bf16_round(v)
    } else {
        crate::arch::bf16::ftz_f32(v)
    }
}

/// Scale-and-quantize q(c·x) with exactness shortcuts (§Perf optimization
/// 3): for c = ±1 the product of an already-quantized x is exact in either
/// format (sign flip / identity), so the rounding is a no-op and is
/// skipped. The stencil coefficients are ±1 except the center, so this
/// removes 6 of the 13 per-element roundings. The branch is on the
/// (loop-invariant) coefficient, so it predicts perfectly.
#[inline(always)]
fn q_scale<const BF16: bool>(c: f32, x: f32) -> f32 {
    if c == -1.0 {
        -x
    } else if c == 1.0 {
        x
    } else {
        q_elem::<BF16>(c * x)
    }
}

/// Fused 7-point stencil over a core block (§Perf optimization 1): one
/// pass per tile, same canonical quantization order as the operator form.
fn stencil_fused<const BF16: bool>(x: &CoreBlock, halos: &Halos, c: StencilCoeffs) -> Vec<Tile> {
    let nz = x.nz();
    let shape = crate::tile::TileShape::STENCIL;
    let (rows, cols) = (shape.rows, shape.cols);
    let zero_row = [0.0f32; 16];
    let zero_col = [0.0f32; 64];
    let zero_tile = vec![0.0f32; rows * cols];
    let mut out = Vec::with_capacity(nz);
    for k in 0..nz {
        let center = &x.tiles[k].data;
        let below: &[f32] = if k > 0 { &x.tiles[k - 1].data } else { &zero_tile };
        let above: &[f32] = if k + 1 < nz { &x.tiles[k + 1].data } else { &zero_tile };
        let hn: &[f32] = halos.north.as_ref().map(|p| p[k].as_slice()).unwrap_or(&zero_row);
        let hs: &[f32] = halos.south.as_ref().map(|p| p[k].as_slice()).unwrap_or(&zero_row);
        let hw: &[f32] = halos.west.as_ref().map(|p| p[k].as_slice()).unwrap_or(&zero_col);
        let he: &[f32] = halos.east.as_ref().map(|p| p[k].as_slice()).unwrap_or(&zero_col);
        let mut data = vec![0.0f32; rows * cols];
        for r in 0..rows {
            let row = &center[r * cols..(r + 1) * cols];
            // Halo values are quantized on insertion in the tile-op form
            // (Tile::set) and in the Pallas kernel (quant(halo, df)); block
            // values are maintained quantized in storage, so only halo
            // loads need the extra rounding here.
            let north_row: [f32; 16];
            let south_row: [f32; 16];
            let north_ref: &[f32] = if r > 0 {
                &center[(r - 1) * cols..r * cols]
            } else {
                north_row = std::array::from_fn(|i| q_elem::<BF16>(hn[i]));
                &north_row
            };
            let south_ref: &[f32] = if r + 1 < rows {
                &center[(r + 1) * cols..(r + 2) * cols]
            } else {
                south_row = std::array::from_fn(|i| q_elem::<BF16>(hs[i]));
                &south_row
            };
            let out_row = &mut data[r * cols..(r + 1) * cols];
            for cc in 0..cols {
                let west = if cc > 0 { row[cc - 1] } else { q_elem::<BF16>(hw[r]) };
                let east = if cc + 1 < cols { row[cc + 1] } else { q_elem::<BF16>(he[r]) };
                // Canonical order (identical to the tile-op pipeline and
                // the Pallas kernel): every scale and accumulate quantized.
                let mut acc = q_scale::<BF16>(c.center, row[cc]);
                acc = q_elem::<BF16>(acc + q_scale::<BF16>(c.x_lo, north_ref[cc]));
                acc = q_elem::<BF16>(acc + q_scale::<BF16>(c.x_hi, south_ref[cc]));
                acc = q_elem::<BF16>(acc + q_scale::<BF16>(c.y_lo, west));
                acc = q_elem::<BF16>(acc + q_scale::<BF16>(c.y_hi, east));
                acc = q_elem::<BF16>(acc + q_scale::<BF16>(c.z_lo, below[r * cols + cc]));
                acc = q_elem::<BF16>(acc + q_scale::<BF16>(c.z_hi, above[r * cols + cc]));
                out_row[cc] = acc;
            }
        }
        out.push(Tile {
            shape,
            df: x.df,
            data,
        });
    }
    out
}

impl NativeEngine {
    /// The original tile-operator pipeline (scale / shift / accumulate as
    /// whole-tile ops) — kept as the §6.2 reference implementation; a unit
    /// test pins `stencil_apply` to it bit-for-bit.
    pub fn stencil_apply_tile_ops(
        &self,
        x: &CoreBlock,
        halos: &Halos,
        coeffs: StencilCoeffs,
    ) -> Result<CoreBlock> {
        let nz = x.nz();
        let plane =
            |h: &Option<Vec<Vec<f32>>>, k: usize| -> Option<Vec<f32>> { h.as_ref().map(|p| p[k].clone()) };
        let mut out_tiles = Vec::with_capacity(nz);
        for k in 0..nz {
            let center = &x.tiles[k];
            let mut acc = ops::scale(center, coeffs.center);
            let north = shift_logical(center, ShiftDir::North, plane(&halos.north, k).as_deref());
            acc = ops::eltwise(EltwiseOp::Add, &acc, &ops::scale(&north, coeffs.x_lo));
            let south = shift_logical(center, ShiftDir::South, plane(&halos.south, k).as_deref());
            acc = ops::eltwise(EltwiseOp::Add, &acc, &ops::scale(&south, coeffs.x_hi));
            let west = shift_logical(center, ShiftDir::West, plane(&halos.west, k).as_deref());
            acc = ops::eltwise(EltwiseOp::Add, &acc, &ops::scale(&west, coeffs.y_lo));
            let east = shift_logical(center, ShiftDir::East, plane(&halos.east, k).as_deref());
            acc = ops::eltwise(EltwiseOp::Add, &acc, &ops::scale(&east, coeffs.y_hi));
            let below = Self::z_neighbor(x, k, -1);
            acc = ops::eltwise(EltwiseOp::Add, &acc, &ops::scale(&below, coeffs.z_lo));
            let above = Self::z_neighbor(x, k, 1);
            acc = ops::eltwise(EltwiseOp::Add, &acc, &ops::scale(&above, coeffs.z_hi));
            out_tiles.push(acc);
        }
        Ok(CoreBlock {
            df: x.df,
            tiles: out_tiles,
        })
    }
}

impl ComputeEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn eltwise(&self, op: EltwiseOp, a: &CoreBlock, b: &CoreBlock) -> Result<CoreBlock> {
        Self::check_match(a, b)?;
        let tiles = a
            .tiles
            .iter()
            .zip(&b.tiles)
            .map(|(x, y)| ops::eltwise(op, x, y))
            .collect();
        Ok(CoreBlock { df: a.df, tiles })
    }

    fn axpy(&self, y: &CoreBlock, alpha: f32, x: &CoreBlock) -> Result<CoreBlock> {
        Self::check_match(y, x)?;
        let tiles = y
            .tiles
            .iter()
            .zip(&x.tiles)
            .map(|(yt, xt)| ops::axpy(yt, alpha, xt))
            .collect();
        Ok(CoreBlock { df: y.df, tiles })
    }

    fn axpy_into(&self, y: &mut CoreBlock, alpha: f32, x: &CoreBlock) -> Result<()> {
        Self::check_match(y, x)?;
        for (yt, xt) in y.tiles.iter_mut().zip(&x.tiles) {
            ops::axpy_into(yt, alpha, xt);
        }
        Ok(())
    }

    fn scale(&self, a: &CoreBlock, alpha: f32) -> Result<CoreBlock> {
        let tiles = a.tiles.iter().map(|t| ops::scale(t, alpha)).collect();
        Ok(CoreBlock { df: a.df, tiles })
    }

    fn dot_partial(&self, a: &CoreBlock, b: &CoreBlock) -> Result<f32> {
        Self::check_match(a, b)?;
        // Per-tile partials at operand precision, accumulated in f32 (the
        // Dst-register accumulation model; see tile::ops::dot_partial).
        let mut s = 0.0f32;
        for (x, y) in a.tiles.iter().zip(&b.tiles) {
            s += ops::dot_partial(x, y);
        }
        Ok(s)
    }

    fn stencil_apply(&self, x: &CoreBlock, halos: &Halos, coeffs: StencilCoeffs) -> Result<CoreBlock> {
        // §Perf: fused single-pass implementation. The tile-level pipeline
        // (scale + 6 shifted-tile accumulations, each op quantized) is
        // element-wise, so fusing it into one loop with the SAME
        // per-element quantization order is bit-identical while avoiding
        // the 13 tile allocations per tile the operator form costs. The
        // operator form survives as `stencil_apply_tile_ops` and a unit
        // test pins their equality.
        let out_tiles = match x.df {
            crate::arch::DataFormat::Bf16 => stencil_fused::<true>(x, halos, coeffs),
            _ => stencil_fused::<false>(x, halos, coeffs),
        };
        Ok(CoreBlock {
            df: x.df,
            tiles: out_tiles,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DataFormat;
    use crate::util::prng::Rng;

    fn rand_block(seed: u64, df: DataFormat, nz: usize) -> CoreBlock {
        let mut rng = Rng::new(seed);
        CoreBlock::from_fn(df, nz, |_, _, _| rng.next_f32() * 2.0 - 1.0)
    }

    #[test]
    fn eltwise_and_axpy() {
        let e = NativeEngine::new();
        let a = rand_block(1, DataFormat::Fp32, 2);
        let b = rand_block(2, DataFormat::Fp32, 2);
        let c = e.eltwise(EltwiseOp::Add, &a, &b).unwrap();
        assert_eq!(c.get(1, 5, 5), a.get(1, 5, 5) + b.get(1, 5, 5));
        let d = e.axpy(&a, 2.0, &b).unwrap();
        assert_eq!(d.get(0, 0, 0), a.get(0, 0, 0) + 2.0 * b.get(0, 0, 0));
    }

    #[test]
    fn mismatch_rejected() {
        let e = NativeEngine::new();
        let a = CoreBlock::zeros(DataFormat::Fp32, 2);
        let b = CoreBlock::zeros(DataFormat::Fp32, 3);
        assert!(e.eltwise(EltwiseOp::Add, &a, &b).is_err());
        let c = CoreBlock::zeros(DataFormat::Bf16, 2);
        assert!(e.axpy(&a, 1.0, &c).is_err());
    }

    #[test]
    fn dot_partial_matches_reference() {
        let e = NativeEngine::new();
        let a = rand_block(3, DataFormat::Fp32, 4);
        let b = rand_block(4, DataFormat::Fp32, 4);
        let got = e.dot_partial(&a, &b).unwrap();
        let want: f64 = a
            .to_flat()
            .iter()
            .zip(b.to_flat().iter())
            .map(|(&x, &y)| x as f64 * y as f64)
            .sum();
        assert!((got as f64 - want).abs() < 1e-2 * want.abs().max(1.0));
    }

    /// Reference stencil in plain f64 over the assembled local 3D block
    /// with explicit halos — validates the tile-shift implementation.
    fn reference_stencil(
        x: &CoreBlock,
        halos: &Halos,
        c: StencilCoeffs,
    ) -> Vec<f64> {
        let nz = x.nz();
        let at = |z: isize, r: isize, q: isize| -> f64 {
            if z < 0 || z >= nz as isize {
                return 0.0;
            }
            let zu = z as usize;
            if r < 0 {
                return halos
                    .north
                    .as_ref()
                    .map(|p| p[zu][q as usize] as f64)
                    .unwrap_or(0.0);
            }
            if r > 63 {
                return halos
                    .south
                    .as_ref()
                    .map(|p| p[zu][q as usize] as f64)
                    .unwrap_or(0.0);
            }
            if q < 0 {
                return halos
                    .west
                    .as_ref()
                    .map(|p| p[zu][r as usize] as f64)
                    .unwrap_or(0.0);
            }
            if q > 15 {
                return halos
                    .east
                    .as_ref()
                    .map(|p| p[zu][r as usize] as f64)
                    .unwrap_or(0.0);
            }
            x.get(zu, r as usize, q as usize) as f64
        };
        let mut out = Vec::new();
        for z in 0..nz as isize {
            for r in 0..64isize {
                for q in 0..16isize {
                    out.push(
                        c.center as f64 * at(z, r, q)
                            + c.x_lo as f64 * at(z, r - 1, q)
                            + c.x_hi as f64 * at(z, r + 1, q)
                            + c.y_lo as f64 * at(z, r, q - 1)
                            + c.y_hi as f64 * at(z, r, q + 1)
                            + c.z_lo as f64 * at(z - 1, r, q)
                            + c.z_hi as f64 * at(z + 1, r, q),
                    );
                }
            }
        }
        out
    }

    #[test]
    fn stencil_matches_reference_with_halos() {
        let e = NativeEngine::new();
        let x = rand_block(5, DataFormat::Fp32, 3);
        let nb = rand_block(6, DataFormat::Fp32, 3);
        let sb = rand_block(7, DataFormat::Fp32, 3);
        let wb = rand_block(8, DataFormat::Fp32, 3);
        let eb = rand_block(9, DataFormat::Fp32, 3);
        let halos = Halos::gather(Some(&nb), Some(&sb), Some(&wb), Some(&eb));
        let got = e.stencil_apply(&x, &halos, StencilCoeffs::LAPLACIAN).unwrap();
        let want = reference_stencil(&x, &halos, StencilCoeffs::LAPLACIAN);
        for (i, (&g, &w)) in got.to_flat().iter().zip(want.iter().map(|v| v as &f64)).enumerate() {
            assert!(
                (g as f64 - w).abs() < 1e-4,
                "elem {i}: got {g}, want {w}"
            );
        }
    }

    #[test]
    fn stencil_zero_boundaries() {
        let e = NativeEngine::new();
        let x = CoreBlock::from_fn(DataFormat::Fp32, 2, |_, _, _| 1.0);
        let got = e
            .stencil_apply(&x, &Halos::none(), StencilCoeffs::LAPLACIAN)
            .unwrap();
        // Fully interior element of a constant-1 field with nz=2: the z
        // direction has one neighbor inside (the other tile) and one
        // Dirichlet zero => 6*1 - (1+1+1+1) - 1 - 0 = 1.
        assert_eq!(got.get(0, 30, 8), 1.0);
        // Corner element (0,0,0): neighbors inside = x_hi, y_hi, z_hi = 3.
        assert_eq!(got.get(0, 0, 0), 3.0);
    }

    #[test]
    fn fused_stencil_bit_identical_to_tile_op_pipeline() {
        // §Perf optimization 1 must not change a single bit, for both
        // formats, with and without halos.
        let e = NativeEngine::new();
        for df in [DataFormat::Fp32, DataFormat::Bf16] {
            for seed in 0..4 {
                let x = rand_block(100 + seed, df, 3);
                let nb = rand_block(200 + seed, df, 3);
                let eb = rand_block(300 + seed, df, 3);
                for halos in [Halos::none(), Halos::gather(Some(&nb), None, None, Some(&eb))] {
                    let fused = e.stencil_apply(&x, &halos, StencilCoeffs::LAPLACIAN).unwrap();
                    let ops_form = e
                        .stencil_apply_tile_ops(&x, &halos, StencilCoeffs::LAPLACIAN)
                        .unwrap();
                    assert_eq!(fused, ops_form, "df {df} seed {seed}");
                }
            }
        }
    }

    #[test]
    fn bf16_stencil_quantizes() {
        let e = NativeEngine::new();
        let x = rand_block(10, DataFormat::Bf16, 2);
        let got = e
            .stencil_apply(&x, &Halos::none(), StencilCoeffs::LAPLACIAN)
            .unwrap();
        for &v in &got.to_flat() {
            assert_eq!(v, crate::arch::bf16::bf16_round(v), "value {v} not bf16");
        }
    }
}
