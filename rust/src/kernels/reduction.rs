//! Global reduction: the distributed dot product (§5).
//!
//! Every core owns corresponding tiles of both input vectors; it multiplies
//! element-wise and accumulates a partial-result tile (Fig 4). The global
//! phase then reduces across cores over the NoC, with two orthogonal
//! implementation choices the paper evaluates:
//!
//! - **Granularity** (§5.1): method 1 reduces each core's partial tile to a
//!   scalar before sending (less traffic, more compute); method 2 sends
//!   whole tiles and reduces to a scalar only at the root.
//! - **Routing** (§5.2): naive (rows leftward, then up column 0) vs center
//!   (toward the grid center) vs direct (everyone → root; §5 mentions it
//!   but expects a root bottleneck — provided for the ablation).
//!
//! At every hop only the sum of incoming partials is forwarded. The scalar
//! result is finally multicast back to all cores.
//!
//! The kernel lowers to a [`Program`] with a [`ReduceSpec`] network phase
//! ([`lower_dot`]) and executes through [`crate::ttm::HostQueue::run`];
//! this module computes operation *cycles*, never dispatch or phase
//! timing.

use crate::arch::{ComputeUnit, DataFormat};
use crate::engine::{ComputeEngine, CoreBlock};
use crate::noc::patterns::RoutePattern;
use crate::profiler::Profiler;
use crate::timing::cost::{CostModel, PipelineMode, TileOpKind};
use crate::timing::SimNs;
use crate::ttm::{Footprint, HostQueue, Program, ReduceSpec, Workload};

/// §5.1 granularity methods.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DotMethod {
    /// Method 1: reduce to a scalar on each core, send scalars.
    ReduceThenSend,
    /// Method 2: send partial tiles, reduce at the root.
    SendTiles,
}

#[derive(Debug, Clone)]
pub struct DotConfig {
    pub method: DotMethod,
    pub pattern: RoutePattern,
    pub df: DataFormat,
    pub unit: ComputeUnit,
    pub tiles_per_core: usize,
}

impl DotConfig {
    /// The paper's §5 experiment configuration: SFPU FP32.
    pub fn paper_section5(method: DotMethod, pattern: RoutePattern, tiles: usize) -> Self {
        Self {
            method,
            pattern,
            df: DataFormat::Fp32,
            unit: ComputeUnit::Sfpu,
            tiles_per_core: tiles,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct DotOutcome {
    /// The dot-product value (identical across granularity methods up to
    /// accumulation-order rounding; computed by the engine).
    pub value: f32,
    /// Slowest core's local phase (mul + accumulate [+ local reduce]).
    pub local_ns: SimNs,
    /// Tree-reduction network phase (merges + transfers) past local.
    pub network_ns: SimNs,
    /// Result multicast back to all cores.
    pub bcast_ns: SimNs,
    /// Total = time until every core holds the scalar result.
    pub total_ns: SimNs,
    pub messages: u64,
    pub bytes: u64,
}

/// Lower the distributed dot product to a program named `name` ("dot" or
/// "norm" in the solver): a uniform local multiply/accumulate phase (Fig
/// 4), the §5.1 granularity choice encoded as payload + merge cycles of
/// the [`ReduceSpec`], and the scalar result broadcast.
pub fn lower_dot_as(
    name: &str,
    rows: usize,
    cols: usize,
    cfg: &DotConfig,
    cost: &CostModel,
) -> Program {
    let calib = &cost.calib;
    let n_cores = rows * cols;
    let t = cfg.tiles_per_core as u64;
    // Local phase (Fig 4): per tile, eltwise multiply + accumulate into the
    // partial tile. Dependent sequence: accumulation chains.
    let mul = cost.tile_op_cycles(cfg.unit, cfg.df, TileOpKind::EltwiseBinary, PipelineMode::Streamed);
    let acc = cost.tile_op_cycles(cfg.unit, cfg.df, TileOpKind::EltwiseBinary, PipelineMode::Dependent);
    let mut local_cycles = t * (mul + acc);
    // Method 1: local tile → scalar reduction on every core.
    let reduce_cycles = cost.tile_op_cycles(cfg.unit, cfg.df, TileOpKind::ReduceTile, PipelineMode::Dependent);
    if cfg.method == DotMethod::ReduceThenSend {
        local_cycles += reduce_cycles;
    }
    // Center pattern pays extra routing logic per core (§5.2).
    if cfg.pattern == RoutePattern::Center {
        local_cycles += calib.center_route_overhead_cycles;
    }

    let payload: u64 = match cfg.method {
        // A scalar still moves as one 32B-aligned beat (§3.3).
        DotMethod::ReduceThenSend => 32,
        DotMethod::SendTiles => cfg.df.tile_bytes() as u64,
    };
    let merge_cycles: u64 = match cfg.method {
        DotMethod::ReduceThenSend => calib.scalar_merge_cycles,
        // Tile merges integrate into the receiver's unpack/compute/pack
        // pipeline as the payload streams in (streamed mode).
        DotMethod::SendTiles => {
            cost.tile_op_cycles(cfg.unit, cfg.df, TileOpKind::EltwiseBinary, PipelineMode::Streamed)
        }
    };
    // Method 2: the root reduces the merged tile to a scalar (§5.1).
    let root_extra = if cfg.method == DotMethod::SendTiles {
        reduce_cycles
    } else {
        0
    };

    let mut program = Program::standard(name);
    for k in &mut program.kernels {
        k.ct_args.push(("tiles".to_string(), cfg.tiles_per_core.to_string()));
        k.ct_args.push(("df".to_string(), cfg.df.to_string()));
        k.ct_args.push(("method".to_string(), format!("{:?}", cfg.method)));
        k.ct_args.push(("pattern".to_string(), format!("{:?}", cfg.pattern)));
    }
    program
        .with_work(Workload {
            grid: (rows, cols),
            compute_cycles: vec![local_cycles; n_cores],
            reduce: Some(ReduceSpec {
                pattern: cfg.pattern,
                payload_bytes: payload,
                merge_cycles,
                root_extra_cycles: root_extra,
                // "the scalar result is then multicast back to all cores"
                // (§5.1): one 32B-aligned beat.
                bcast_bytes: 32,
            }),
            ..Workload::default()
        })
        .with_footprint(Footprint {
            tiles_per_core: cfg.tiles_per_core,
            // Two input vectors + the partial-result tile.
            sram_bytes: (2 * cfg.tiles_per_core + 1) * cfg.df.tile_bytes(),
            traffic_bytes: (n_cores.saturating_sub(1) as u64) * (payload + 32),
            eth_bytes: 0,
        })
}

/// [`lower_dot_as`] with the canonical "dot" program name.
pub fn lower_dot(rows: usize, cols: usize, cfg: &DotConfig, cost: &CostModel) -> Program {
    lower_dot_as("dot", rows, cols, cfg, cost)
}

/// Run the distributed dot product: values via `engine`, timing by
/// lowering to a program and executing it through the host queue.
pub fn run_dot(
    rows: usize,
    cols: usize,
    cfg: &DotConfig,
    a: &[CoreBlock],
    b: &[CoreBlock],
    engine: &dyn ComputeEngine,
    cost: &CostModel,
) -> crate::Result<DotOutcome> {
    let n_cores = rows * cols;
    assert_eq!(a.len(), n_cores, "one block per core");
    assert_eq!(b.len(), n_cores);

    // ---- values --------------------------------------------------------
    let mut value = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        value += engine.dot_partial(x, y)?;
    }

    // ---- timing: lower → enqueue → collect ------------------------------
    let program = lower_dot(rows, cols, cfg, cost);
    let mut queue = HostQueue::new(cost.calib.clone());
    let out = queue.run(&program, cost, 0.0, &mut Profiler::disabled())?;

    Ok(DotOutcome {
        value,
        local_ns: out.compute_ns,
        network_ns: out.reduce_ns,
        bcast_ns: out.bcast_ns,
        total_ns: out.device_ns(),
        messages: out.messages,
        bytes: out.bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::util::prng::Rng;

    fn blocks(seed: u64, n: usize, tiles: usize, df: DataFormat) -> Vec<CoreBlock> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| CoreBlock::from_fn(df, tiles, |_, _, _| rng.next_f32() - 0.5))
            .collect()
    }

    fn reference_dot(a: &[CoreBlock], b: &[CoreBlock]) -> f64 {
        a.iter()
            .zip(b)
            .flat_map(|(x, y)| x.to_flat().into_iter().zip(y.to_flat()))
            .map(|(x, y)| x as f64 * y as f64)
            .sum()
    }

    #[test]
    fn value_matches_reference_both_methods() {
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let a = blocks(1, 12, 4, DataFormat::Fp32);
        let b = blocks(2, 12, 4, DataFormat::Fp32);
        let want = reference_dot(&a, &b);
        for method in [DotMethod::ReduceThenSend, DotMethod::SendTiles] {
            let cfg = DotConfig::paper_section5(method, RoutePattern::Naive, 4);
            let out = run_dot(3, 4, &cfg, &a, &b, &e, &cost).unwrap();
            assert!(
                (out.value as f64 - want).abs() < 1e-2 * want.abs().max(1.0),
                "{method:?}: {} vs {want}",
                out.value
            );
        }
    }

    #[test]
    fn method1_reduces_traffic_method2_reduces_local_compute() {
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let a = blocks(3, 56, 8, DataFormat::Fp32);
        let b = blocks(4, 56, 8, DataFormat::Fp32);
        let m1 = run_dot(
            8,
            7,
            &DotConfig::paper_section5(DotMethod::ReduceThenSend, RoutePattern::Naive, 8),
            &a,
            &b,
            &e,
            &cost,
        )
        .unwrap();
        let m2 = run_dot(
            8,
            7,
            &DotConfig::paper_section5(DotMethod::SendTiles, RoutePattern::Naive, 8),
            &a,
            &b,
            &e,
            &cost,
        )
        .unwrap();
        assert!(m1.bytes < m2.bytes, "method 1 sends less data");
        assert!(m1.local_ns > m2.local_ns, "method 1 does more local compute");
    }

    #[test]
    fn methods_converge_on_single_core() {
        // §5.1: "the methods converge as the grid size decreases to a
        // single Tensix core" (no network phase at 1×1 for method 1; the
        // only difference is where the final reduce happens — nowhere to
        // send, so both reduce locally).
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let a = blocks(5, 1, 64, DataFormat::Fp32);
        let b = blocks(6, 1, 64, DataFormat::Fp32);
        let m1 = run_dot(
            1,
            1,
            &DotConfig::paper_section5(DotMethod::ReduceThenSend, RoutePattern::Naive, 64),
            &a,
            &b,
            &e,
            &cost,
        )
        .unwrap();
        let m2 = run_dot(
            1,
            1,
            &DotConfig::paper_section5(DotMethod::SendTiles, RoutePattern::Naive, 64),
            &a,
            &b,
            &e,
            &cost,
        )
        .unwrap();
        let rel = (m1.total_ns - m2.total_ns).abs() / m2.total_ns;
        assert!(rel < 0.02, "1x1 methods should converge, rel diff {rel}");
    }

    #[test]
    fn center_beats_naive_at_one_tile_on_full_grid() {
        // §5.2: ~15% speedup at a single tile per core on the full grid.
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let a = blocks(7, 56, 1, DataFormat::Fp32);
        let b = blocks(8, 56, 1, DataFormat::Fp32);
        let naive = run_dot(
            8,
            7,
            &DotConfig::paper_section5(DotMethod::SendTiles, RoutePattern::Naive, 1),
            &a,
            &b,
            &e,
            &cost,
        )
        .unwrap();
        let center = run_dot(
            8,
            7,
            &DotConfig::paper_section5(DotMethod::SendTiles, RoutePattern::Center, 1),
            &a,
            &b,
            &e,
            &cost,
        )
        .unwrap();
        assert!(
            center.total_ns < naive.total_ns,
            "center {} vs naive {}",
            center.total_ns,
            naive.total_ns
        );
    }

    #[test]
    fn local_compute_dominates_at_many_tiles() {
        // §5.2: at 128 tiles/core the speedup is negligible because local
        // compute dominates network time.
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let a = blocks(9, 56, 128, DataFormat::Fp32);
        let b = blocks(10, 56, 128, DataFormat::Fp32);
        let out = run_dot(
            8,
            7,
            &DotConfig::paper_section5(DotMethod::SendTiles, RoutePattern::Naive, 128),
            &a,
            &b,
            &e,
            &cost,
        )
        .unwrap();
        assert!(out.local_ns > 5.0 * (out.network_ns + out.bcast_ns));
    }
}
