//! Basic element-wise arithmetic kernels (§4).
//!
//! Two implementation variants exist, mirroring the paper's Fig 3: the FPU
//! (BF16, near the SRAM-bandwidth roofline) and the SFPU (16/32-bit,
//! substantially more expensive due to Dst-register staging and lane
//! load/stores). Both stream tiles DRAM → SRAM → compute → SRAM → DRAM;
//! the DRAM legs are charged separately from the roofline (the paper's
//! simplified roofline excludes them, and so does ours for the Fig-3
//! point).

use crate::arch::{ComputeUnit, DataFormat};
use crate::engine::{ComputeEngine, CoreBlock};
use crate::timing::cost::{CostModel, PipelineMode, TileOpKind};
use crate::timing::SimNs;
use crate::tile::EltwiseOp;

/// Timing of a single-core element-wise streaming run.
#[derive(Debug, Clone, PartialEq)]
pub struct EltwiseTiming {
    pub unit: ComputeUnit,
    pub df: DataFormat,
    pub tiles: usize,
    /// On-core cycles per tile (pack/unpack/compute/issue).
    pub cycles_per_tile: u64,
    /// Total on-core time for the stream.
    pub core_ns: SimNs,
    /// DRAM staging time (in + out), not part of the Fig-3 roofline.
    pub dram_ns: SimNs,
    /// Achieved arithmetic throughput of the on-core stream (GFLOP/s).
    pub gflops: f64,
    /// Arithmetic intensity (FLOP/byte) of the variant.
    pub ai: f64,
}

/// Single-core streaming element-wise timing (the Fig-3 experiment:
/// 256 tiles per core = 262,144 elements).
pub fn eltwise_stream_timing(
    cost: &CostModel,
    unit: ComputeUnit,
    df: DataFormat,
    tiles: usize,
) -> EltwiseTiming {
    let cycles_per_tile =
        cost.tile_op_cycles(unit, df, TileOpKind::EltwiseBinary, PipelineMode::Streamed);
    let core_cycles = cycles_per_tile * tiles as u64;
    // DRAM legs: two input vectors in, one result out.
    let bytes = (3 * tiles * df.tile_bytes()) as u64;
    let dram_cycles = cost.dram_stream_cycles(bytes);
    let (ai, gflops) = cost.roofline_point(unit, df);
    EltwiseTiming {
        unit,
        df,
        tiles,
        cycles_per_tile,
        core_ns: crate::timing::cycles_ns(core_cycles),
        dram_ns: crate::timing::cycles_ns(dram_cycles),
        gflops,
        ai,
    }
}

/// Per-core time for a distributed element-wise/axpy-style operation over
/// `tiles` resident tiles (used by the PCG component model; data is already
/// in SRAM, so no DRAM legs).
pub fn block_op_ns(
    cost: &CostModel,
    unit: ComputeUnit,
    df: DataFormat,
    kind: TileOpKind,
    tiles: usize,
    mode: PipelineMode,
) -> SimNs {
    crate::timing::cycles_ns(cost.tile_op_cycles(unit, df, kind, mode) * tiles as u64)
}

/// Distributed element-wise values: `c = a op b` on every core's block.
pub fn run_eltwise_values(
    engine: &dyn ComputeEngine,
    op: EltwiseOp,
    a: &[CoreBlock],
    b: &[CoreBlock],
) -> crate::Result<Vec<CoreBlock>> {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| engine.eltwise(op, x, y))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;

    #[test]
    fn fig3_point_shapes() {
        let cost = CostModel::default();
        let fpu = eltwise_stream_timing(&cost, ComputeUnit::Fpu, DataFormat::Bf16, 256);
        let sfpu = eltwise_stream_timing(&cost, ComputeUnit::Sfpu, DataFormat::Bf16, 256);
        // §4: SFPU ~6x slower than FPU at 16-bit.
        let ratio = sfpu.core_ns / fpu.core_ns;
        assert!((4.5..8.0).contains(&ratio), "ratio {ratio}");
        // AI: 1/6 vs 1/16.
        assert!((fpu.ai - 1.0 / 6.0).abs() < 1e-9);
        assert!((sfpu.ai - 1.0 / 16.0).abs() < 1e-9);
        assert!(fpu.gflops > sfpu.gflops);
        assert!(fpu.dram_ns > 0.0);
    }

    #[test]
    fn fp32_sfpu_slower_than_bf16_sfpu() {
        let cost = CostModel::default();
        let b = eltwise_stream_timing(&cost, ComputeUnit::Sfpu, DataFormat::Bf16, 64);
        let f = eltwise_stream_timing(&cost, ComputeUnit::Sfpu, DataFormat::Fp32, 64);
        assert!(f.core_ns > b.core_ns);
    }

    #[test]
    fn distributed_values() {
        let e = NativeEngine::new();
        let a: Vec<CoreBlock> = (0..4)
            .map(|i| CoreBlock::from_fn(DataFormat::Fp32, 2, move |_, _, _| i as f32))
            .collect();
        let b: Vec<CoreBlock> = (0..4)
            .map(|_| CoreBlock::from_fn(DataFormat::Fp32, 2, |_, _, _| 10.0))
            .collect();
        let c = run_eltwise_values(&e, EltwiseOp::Add, &a, &b).unwrap();
        assert_eq!(c[3].get(1, 10, 10), 13.0);
    }
}
