//! Basic element-wise arithmetic kernels (§4).
//!
//! Two implementation variants exist, mirroring the paper's Fig 3: the FPU
//! (BF16, near the SRAM-bandwidth roofline) and the SFPU (16/32-bit,
//! substantially more expensive due to Dst-register staging and lane
//! load/stores). Both stream tiles DRAM → SRAM → compute → SRAM → DRAM;
//! the DRAM legs are charged separately from the roofline (the paper's
//! simplified roofline excludes them, and so does ours for the Fig-3
//! point).
//!
//! The kernel lowers to a [`Program`] ([`lower_eltwise`] /
//! [`lower_block_op`]) and executes through [`HostQueue::run`]; this
//! module computes operation *cycles*, never dispatch or phase timing.

use crate::arch::{ComputeUnit, DataFormat};
use crate::engine::{ComputeEngine, CoreBlock};
use crate::profiler::Profiler;
use crate::timing::cost::{CostModel, PipelineMode, TileOpKind};
use crate::timing::SimNs;
use crate::tile::EltwiseOp;
use crate::ttm::{Footprint, HostQueue, Program, Workload};

/// Timing of a single-core element-wise streaming run.
#[derive(Debug, Clone, PartialEq)]
pub struct EltwiseTiming {
    pub unit: ComputeUnit,
    pub df: DataFormat,
    pub tiles: usize,
    /// On-core cycles per tile (pack/unpack/compute/issue).
    pub cycles_per_tile: u64,
    /// Total on-core time for the stream.
    pub core_ns: SimNs,
    /// DRAM staging time (in + out), not part of the Fig-3 roofline.
    pub dram_ns: SimNs,
    /// Achieved arithmetic throughput of the on-core stream (GFLOP/s).
    pub gflops: f64,
    /// Arithmetic intensity (FLOP/byte) of the variant.
    pub ai: f64,
}

/// Lower the single-core streaming element-wise kernel (the Fig-3
/// experiment) to a program: one core, a `tiles`-long compute stream,
/// two input vectors staged in and one result out through DRAM.
pub fn lower_eltwise(
    cost: &CostModel,
    unit: ComputeUnit,
    df: DataFormat,
    tiles: usize,
) -> Program {
    let cycles_per_tile =
        cost.tile_op_cycles(unit, df, TileOpKind::EltwiseBinary, PipelineMode::Streamed);
    let dram_bytes = (3 * tiles * df.tile_bytes()) as u64;
    let mut program = Program::standard("eltwise");
    for k in &mut program.kernels {
        k.ct_args.push(("tiles".to_string(), tiles.to_string()));
        k.ct_args.push(("df".to_string(), df.to_string()));
        k.ct_args.push(("unit".to_string(), unit.to_string()));
    }
    program
        .with_work(Workload {
            grid: (1, 1),
            dram_bytes: vec![dram_bytes],
            compute_cycles: vec![cycles_per_tile * tiles as u64],
            ..Workload::default()
        })
        .with_footprint(Footprint {
            tiles_per_core: tiles,
            sram_bytes: 3 * tiles * df.tile_bytes(),
            traffic_bytes: dram_bytes,
            eth_bytes: 0,
        })
}

/// Lower a distributed block operation (axpy / scale / preconditioner
/// application over every core's resident tiles) to a program on the
/// `rows`×`cols` sub-grid — the PCG component programs.
#[allow(clippy::too_many_arguments)]
pub fn lower_block_op(
    name: &str,
    rows: usize,
    cols: usize,
    cost: &CostModel,
    unit: ComputeUnit,
    df: DataFormat,
    kind: TileOpKind,
    tiles: usize,
    mode: PipelineMode,
) -> Program {
    let n_cores = rows * cols;
    let cycles = cost.tile_op_cycles(unit, df, kind, mode) * tiles as u64;
    let mut program = Program::standard(name);
    for k in &mut program.kernels {
        k.ct_args.push(("tiles".to_string(), tiles.to_string()));
        k.ct_args.push(("df".to_string(), df.to_string()));
    }
    program
        .with_work(Workload {
            grid: (rows, cols),
            compute_cycles: vec![cycles; n_cores],
            ..Workload::default()
        })
        .with_footprint(Footprint {
            tiles_per_core: tiles,
            sram_bytes: 3 * tiles * df.tile_bytes(),
            traffic_bytes: 0,
            eth_bytes: 0,
        })
}

/// Single-core streaming element-wise timing (the Fig-3 experiment:
/// 256 tiles per core = 262,144 elements). Thin wrapper: lower, run
/// through the host queue, collect the phase breakdown.
pub fn eltwise_stream_timing(
    cost: &CostModel,
    unit: ComputeUnit,
    df: DataFormat,
    tiles: usize,
) -> EltwiseTiming {
    let cycles_per_tile =
        cost.tile_op_cycles(unit, df, TileOpKind::EltwiseBinary, PipelineMode::Streamed);
    let program = lower_eltwise(cost, unit, df, tiles);
    let mut queue = HostQueue::new(cost.calib.clone());
    let out = queue
        .run(&program, cost, 0.0, &mut Profiler::disabled())
        .expect("eltwise program is well-formed");
    let (ai, gflops) = cost.roofline_point(unit, df);
    EltwiseTiming {
        unit,
        df,
        tiles,
        cycles_per_tile,
        core_ns: out.compute_ns,
        dram_ns: out.dram_ns,
        gflops,
        ai,
    }
}

/// Per-core time for a distributed element-wise/axpy-style operation over
/// `tiles` resident tiles (used by the PCG component model; data is already
/// in SRAM, so no DRAM legs).
pub fn block_op_ns(
    cost: &CostModel,
    unit: ComputeUnit,
    df: DataFormat,
    kind: TileOpKind,
    tiles: usize,
    mode: PipelineMode,
) -> SimNs {
    crate::timing::cycles_ns(cost.tile_op_cycles(unit, df, kind, mode) * tiles as u64)
}

/// Distributed element-wise values: `c = a op b` on every core's block.
pub fn run_eltwise_values(
    engine: &dyn ComputeEngine,
    op: EltwiseOp,
    a: &[CoreBlock],
    b: &[CoreBlock],
) -> crate::Result<Vec<CoreBlock>> {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| engine.eltwise(op, x, y))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;

    #[test]
    fn fig3_point_shapes() {
        let cost = CostModel::default();
        let fpu = eltwise_stream_timing(&cost, ComputeUnit::Fpu, DataFormat::Bf16, 256);
        let sfpu = eltwise_stream_timing(&cost, ComputeUnit::Sfpu, DataFormat::Bf16, 256);
        // §4: SFPU ~6x slower than FPU at 16-bit.
        let ratio = sfpu.core_ns / fpu.core_ns;
        assert!((4.5..8.0).contains(&ratio), "ratio {ratio}");
        // AI: 1/6 vs 1/16.
        assert!((fpu.ai - 1.0 / 6.0).abs() < 1e-9);
        assert!((sfpu.ai - 1.0 / 16.0).abs() < 1e-9);
        assert!(fpu.gflops > sfpu.gflops);
        assert!(fpu.dram_ns > 0.0);
    }

    #[test]
    fn fp32_sfpu_slower_than_bf16_sfpu() {
        let cost = CostModel::default();
        let b = eltwise_stream_timing(&cost, ComputeUnit::Sfpu, DataFormat::Bf16, 64);
        let f = eltwise_stream_timing(&cost, ComputeUnit::Sfpu, DataFormat::Fp32, 64);
        assert!(f.core_ns > b.core_ns);
    }

    #[test]
    fn timing_matches_direct_cost_model() {
        // The program path must reproduce the direct cycle arithmetic.
        let cost = CostModel::default();
        let t = eltwise_stream_timing(&cost, ComputeUnit::Fpu, DataFormat::Bf16, 256);
        let want_core = crate::timing::cycles_ns(t.cycles_per_tile * 256);
        assert!((t.core_ns - want_core).abs() < 1e-9);
        let bytes = (3 * 256 * DataFormat::Bf16.tile_bytes()) as u64;
        let want_dram = crate::timing::cycles_ns(cost.dram_stream_cycles(bytes));
        assert!((t.dram_ns - want_dram).abs() < 1e-9);
    }

    #[test]
    fn lowering_is_deterministic() {
        let cost = CostModel::default();
        let a = lower_eltwise(&cost, ComputeUnit::Fpu, DataFormat::Bf16, 64);
        let b = lower_eltwise(&cost, ComputeUnit::Fpu, DataFormat::Bf16, 64);
        assert_eq!(a, b);
    }

    #[test]
    fn distributed_values() {
        let e = NativeEngine::new();
        let a: Vec<CoreBlock> = (0..4)
            .map(|i| CoreBlock::from_fn(DataFormat::Fp32, 2, move |_, _, _| i as f32))
            .collect();
        let b: Vec<CoreBlock> = (0..4)
            .map(|_| CoreBlock::from_fn(DataFormat::Fp32, 2, |_, _, _| 10.0))
            .collect();
        let c = run_eltwise_values(&e, EltwiseOp::Add, &a, &b).unwrap();
        assert_eq!(c[3].get(1, 10, 10), 13.0);
    }
}
