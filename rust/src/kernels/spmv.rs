//! General SpMV on the simulated Tensix grid.
//!
//! `y = A x` for an arbitrary sparse matrix in per-core SELL-C-32 (see
//! [`crate::sparse`]), mirroring how the three paper kernels are built:
//! *values* go through the [`ComputeEngine`] trait, *cycles* through the
//! cost model and the NoC simulator.
//!
//! Per application, every core
//!
//! 1. receives the remote `x` entries its column footprint needs (one
//!    batched NoC write per owning core, from the partition's
//!    [`GatherPlan`] — the unstructured analog of the stencil's halo
//!    exchange, §6.3);
//! 2. has its baby RISC-Vs assemble operand tiles by indexed L1
//!    gather/scatter — charged per padded entry at the §6.3 L1
//!    load+store latency, the cost the stencil's pointer trick (§6.2)
//!    exists to avoid;
//! 3. multiply-accumulates slice columns as whole-tile ops: one eltwise
//!    multiply (streamed) plus one accumulate (dependent — the running
//!    `y` chains) per operand tile.
//!
//! Two variants mirror the §7.1 split/fused distinction: **DramStream**
//! re-stages the matrix (values + indices) from DRAM on every
//! application, charged serially as an upper bound; **SramResident**
//! keeps it in L1, which the per-core SRAM footprint check must admit.
//!
//! The value path computes each row's products and accumulations in the
//! row's stored entry order with the engine's per-op rounding. For the
//! stencil-ordered Laplacian on the stencil-aligned partition this makes
//! the sparse SpMV **bit-identical** to
//! [`ComputeEngine::stencil_apply`] — interleaved missing-neighbor terms
//! add an exact ±0 and trailing padding multiplies to ±0, both rounding
//! no-ops — which is what the solver's operator round-trip test pins.

use crate::arch::constants::{L1_ALIGN, SRAM_RESERVE_SPLIT, TILE_ELEMS};
use crate::arch::{ComputeUnit, DataFormat};
use crate::device::TensixGrid;
use crate::engine::{ComputeEngine, CoreBlock};
use crate::error::{Result, SimError};
use crate::profiler::Profiler;
use crate::sparse::{CsrMatrix, GatherPlan, RowPartition, SellMatrix, SellStats, SELL_SLICE_HEIGHT};
use crate::tile::EltwiseOp;
use crate::timing::cost::{CostModel, PipelineMode, TileOpKind};
use crate::timing::SimNs;
use crate::ttm::{Footprint, HostQueue, NocSend, Program, SendQueue, Workload};

/// Where the matrix lives between applications (§7.1 split/fused analog).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpmvMode {
    /// Stream values + indices from DRAM on every application.
    DramStream,
    /// Matrix resident in L1 SRAM; must pass the footprint check.
    SramResident,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpmvConfig {
    pub df: DataFormat,
    pub unit: ComputeUnit,
    pub mode: SpmvMode,
    /// SELL sorting window (rows); 1 or a multiple of the slice height.
    pub sigma: usize,
}

impl SpmvConfig {
    /// Default σ: 8 slices of length-sorting window.
    pub const DEFAULT_SIGMA: usize = 8 * SELL_SLICE_HEIGHT;

    pub fn new(df: DataFormat, mode: SpmvMode) -> Self {
        Self {
            df,
            unit: ComputeUnit::for_format(df),
            mode,
            sigma: Self::DEFAULT_SIGMA,
        }
    }

    pub fn with_sigma(mut self, sigma: usize) -> Self {
        self.sigma = sigma;
        self
    }
}

/// Byte traffic of one SpMV application (the on-device counterpart of the
/// [`crate::baseline::sell::SellTraffic`] cuSPARSE model).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpmvTraffic {
    /// Stored matrix values moved (padding included), all cores.
    pub value_bytes: u64,
    /// Stored 32-bit column indices moved.
    pub index_bytes: u64,
    /// Remote `x` entries over the NoC (32 B-aligned batches).
    pub x_gather_bytes: u64,
    /// Result vector written back.
    pub y_write_bytes: u64,
}

impl SpmvTraffic {
    pub fn total(&self) -> u64 {
        self.value_bytes + self.index_bytes + self.x_gather_bytes + self.y_write_bytes
    }

    pub fn per_row(&self, n_rows: usize) -> f64 {
        self.total() as f64 / n_rows.max(1) as f64
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct SpmvTiming {
    /// Whole-application time (slowest core; gather waits included).
    pub total_ns: SimNs,
    /// Slowest core's NoC gather wait (send issue + inbound arrival).
    pub gather_ns: SimNs,
    /// Slowest core's local phase: RISC-V tile assembly + tile math.
    pub compute_ns: SimNs,
    /// Slowest core's DRAM staging (zero for SramResident).
    pub dram_ns: SimNs,
    pub messages: u64,
    pub bytes: u64,
    pub traffic: SpmvTraffic,
}

impl SpmvTiming {
    /// Achieved effective bandwidth over the counted traffic, GB/s.
    pub fn achieved_gbs(&self) -> f64 {
        if self.total_ns <= 0.0 {
            0.0
        } else {
            self.traffic.total() as f64 / self.total_ns
        }
    }
}

fn align32(bytes: usize) -> u64 {
    (bytes as u64).div_ceil(L1_ALIGN as u64) * L1_ALIGN as u64
}

/// A matrix partitioned, converted, and preloaded for repeated SpMV on
/// the grid: the sparse implementor of the solver's operator abstraction.
#[derive(Debug, Clone)]
pub struct SpmvOperator {
    pub cfg: SpmvConfig,
    pub part: RowPartition,
    pub gather: GatherPlan,
    /// Per-core SELL conversions (kept for stats/reporting).
    pub sells: Vec<SellMatrix>,
    /// k-th-entry value blocks per core, already quantized at `cfg.df`.
    val_blocks: Vec<Vec<CoreBlock>>,
    /// Global column per (core, k, slot); 0 under zero-valued padding.
    col_maps: Vec<Vec<Vec<u32>>>,
    diag: Vec<f32>,
    /// Largest per-core SRAM working set (vectors + gather staging +
    /// matrix or its streaming CB), recorded for the program footprint.
    sram_bytes: usize,
}

impl SpmvOperator {
    /// Partition `a`, convert each core's rows to SELL-C-32, verify the
    /// per-core SRAM footprint, and precompute the operand value tiles.
    pub fn new(a: &CsrMatrix, part: RowPartition, cfg: SpmvConfig) -> Result<Self> {
        if a.n_rows != a.n_cols {
            return Err(SimError::BadProblem {
                what: format!("SpMV operator must be square, got {}x{}", a.n_rows, a.n_cols),
            });
        }
        if a.n_rows != part.n {
            return Err(SimError::BadProblem {
                what: format!("matrix dimension {} != partition n {}", a.n_rows, part.n),
            });
        }
        if !cfg.unit.supports(cfg.df) {
            return Err(SimError::BadProblem {
                what: format!("{} cannot execute {} (§3.3)", cfg.unit, cfg.df),
            });
        }
        let gather = part.gather_plan(a)?;
        let n_cores = part.n_cores();
        let slots = part.slots_per_core();
        let tiles = part.tiles_per_core;

        let mut sells = Vec::with_capacity(n_cores);
        let mut val_blocks = Vec::with_capacity(n_cores);
        let mut col_maps = Vec::with_capacity(n_cores);
        let mut sram_bytes = 0usize;
        for core in 0..n_cores {
            // Core-local CSR: one row per slot, in slot order; padding
            // slots are empty rows.
            let mut row_ptr = Vec::with_capacity(slots + 1);
            let mut col_idx = Vec::new();
            let mut vals = Vec::new();
            row_ptr.push(0);
            for slot in 0..slots {
                if let Some(g) = part.slot_to_global(core, slot) {
                    let (cols, rvals) = a.row(g);
                    col_idx.extend_from_slice(cols);
                    vals.extend_from_slice(rvals);
                }
                row_ptr.push(col_idx.len());
            }
            let local = CsrMatrix::new(slots, part.n, row_ptr, col_idx, vals)?;
            let sell = SellMatrix::from_csr(&local, SELL_SLICE_HEIGHT, cfg.sigma)?;

            // SRAM footprint (§7.2 style, through the bump allocator).
            let matrix_bytes =
                (sell.value_bytes(cfg.df) + sell.index_bytes()) as usize + 8 * sell.n_slices();
            let vector_bytes = 2 * tiles * cfg.df.tile_bytes(); // x + y blocks
            let gather_bytes = align32(gather.remote_entries_of(core) * cfg.df.bytes()) as usize;
            let mut regions: Vec<(&str, usize)> = vec![
                ("spmv/x+y", vector_bytes),
                ("spmv/x-gather", gather_bytes),
            ];
            match cfg.mode {
                SpmvMode::SramResident => regions.push(("spmv/matrix", matrix_bytes)),
                SpmvMode::DramStream => {
                    // Double-buffered value+index staging, one tile column.
                    regions.push(("spmv/matrix-cb", 2 * TILE_ELEMS * (cfg.df.bytes() + 4)));
                }
            }
            part.check_sram(core, SRAM_RESERVE_SPLIT, &regions)?;
            sram_bytes = sram_bytes.max(regions.iter().map(|(_, b)| *b).sum());

            // Operand tiles: for each entry position k, the value block
            // (quantized at df by construction) and the global column map.
            let kmax = sell.slice_width.iter().copied().max().unwrap_or(0);
            let mut vk = Vec::with_capacity(kmax);
            let mut ck = Vec::with_capacity(kmax);
            for k in 0..kmax {
                vk.push(CoreBlock::from_fn(cfg.df, tiles, |z, xr, yc| {
                    let slot = z * TILE_ELEMS + xr * 16 + yc;
                    let (cols, rvals) = local.row(slot);
                    if k < cols.len() { rvals[k] } else { 0.0 }
                }));
                let cols_k: Vec<u32> = (0..slots)
                    .map(|slot| {
                        let (cols, _) = local.row(slot);
                        if k < cols.len() { cols[k] } else { 0 }
                    })
                    .collect();
                ck.push(cols_k);
            }
            sells.push(sell);
            val_blocks.push(vk);
            col_maps.push(ck);
        }

        Ok(Self {
            cfg,
            part,
            gather,
            sells,
            val_blocks,
            col_maps,
            diag: a.diagonal(),
            sram_bytes,
        })
    }

    /// Aggregated SELL occupancy statistics over all cores.
    pub fn stats(&self) -> SellStats {
        let mut s = SellStats {
            nnz: 0,
            padded_nnz: 0,
            n_slices: 0,
            max_width: 0,
        };
        for sell in &self.sells {
            let cs = sell.stats();
            s.nnz += cs.nnz;
            s.padded_nnz += cs.padded_nnz;
            s.n_slices += cs.n_slices;
            s.max_width = s.max_width.max(cs.max_width);
        }
        s
    }

    /// The matrix diagonal (for the Jacobi preconditioner).
    pub fn diagonal(&self) -> &[f32] {
        &self.diag
    }

    /// `Some(d)` when every diagonal entry is exactly `d` — the solver
    /// then preconditions with a scalar scale, matching the stencil path
    /// bit-for-bit.
    pub fn uniform_diagonal(&self) -> Option<f32> {
        let d = *self.diag.first()?;
        self.diag.iter().all(|&v| v == d).then_some(d)
    }

    /// Byte traffic of one application.
    pub fn traffic(&self) -> SpmvTraffic {
        SpmvTraffic {
            value_bytes: self.sells.iter().map(|s| s.value_bytes(self.cfg.df)).sum(),
            index_bytes: self.sells.iter().map(|s| s.index_bytes()).sum(),
            x_gather_bytes: self.gather.bytes(self.cfg.df),
            y_write_bytes: (self.part.n * self.cfg.df.bytes()) as u64,
        }
    }

    /// Lower one SpMV application to a program: per-owner gather send
    /// queues (the unstructured halo exchange), per-core RISC-V tile
    /// assembly + tile-math cycles, and DRAM staging for the streaming
    /// variant. The SELL occupancy statistics ride along as compile-time
    /// args, and the footprint carries the one traffic number per program
    /// (equal to [`SpmvTraffic::total`]).
    pub fn lower(&self, cost: &CostModel) -> Program {
        let n_cores = self.part.n_cores();
        let df = self.cfg.df;

        // NoC gather of remote x entries (cf. §6.3 halo exchange): each
        // owner issues one batched write per consumer, first one cold.
        let mut data_movement = Vec::with_capacity(n_cores);
        for owner in 0..n_cores {
            let mut queue = SendQueue::default();
            for consumer in 0..n_cores {
                let Some(&cnt) = self.gather.per_core[consumer].get(&owner) else {
                    continue;
                };
                queue.sends.push(NocSend {
                    src: self.part.core_coord(owner),
                    dst: self.part.core_coord(consumer),
                    bytes: align32(cnt * df.bytes()),
                    cold: queue.sends.is_empty(),
                });
            }
            data_movement.push(queue);
        }

        // Per-core local phase: indexed gather/scatter through L1 by the
        // baby RISC-Vs (one load + one store per padded operand entry at
        // the §6.3 latency — the cost the stencil's pointer trick avoids),
        // then whole-tile multiply-accumulate columns.
        let mul = cost.tile_op_cycles(self.cfg.unit, df, TileOpKind::EltwiseBinary, PipelineMode::Streamed);
        let acc = cost.tile_op_cycles(self.cfg.unit, df, TileOpKind::EltwiseBinary, PipelineMode::Dependent);
        let mut riscv_cycles = Vec::with_capacity(n_cores);
        let mut compute_cycles = Vec::with_capacity(n_cores);
        let mut dram_bytes = Vec::with_capacity(n_cores);
        for core in 0..n_cores {
            let padded = self.sells[core].padded_nnz() as u64;
            let tile_cols = padded.div_ceil(TILE_ELEMS as u64);
            riscv_cycles.push(2 * cost.zero_fill_cycles(padded));
            compute_cycles.push(tile_cols * (mul + acc));
            dram_bytes.push(match self.cfg.mode {
                SpmvMode::DramStream => {
                    self.sells[core].value_bytes(df) + self.sells[core].index_bytes()
                }
                SpmvMode::SramResident => 0,
            });
        }

        let stats = self.stats();
        let mut program = Program::standard("spmv");
        for k in &mut program.kernels {
            k.ct_args.push(("df".to_string(), df.to_string()));
            k.ct_args.push(("mode".to_string(), format!("{:?}", self.cfg.mode)));
            k.ct_args.push(("sigma".to_string(), self.cfg.sigma.to_string()));
            k.ct_args.push(("nnz".to_string(), stats.nnz.to_string()));
            k.ct_args.push(("padded_nnz".to_string(), stats.padded_nnz.to_string()));
            k.ct_args.push(("occupancy".to_string(), format!("{:.4}", stats.occupancy())));
            k.ct_args.push(("slices".to_string(), stats.n_slices.to_string()));
        }
        program
            .with_work(Workload {
                grid: (self.part.grid_rows, self.part.grid_cols),
                data_movement,
                dram_bytes,
                riscv_cycles,
                compute_cycles,
                ..Workload::default()
            })
            .with_footprint(Footprint {
                tiles_per_core: self.part.tiles_per_core,
                sram_bytes: self.sram_bytes,
                traffic_bytes: self.traffic().total(),
                eth_bytes: 0,
            })
    }

    /// The value half of one SpMV application — no grid or timing
    /// involved, so it also serves mesh solvers whose logical core grid
    /// exceeds a single die's sub-grid ceiling.
    pub fn apply_values(&self, x: &[CoreBlock], engine: &dyn ComputeEngine) -> Result<Vec<CoreBlock>> {
        let n_cores = self.part.n_cores();
        if x.len() != n_cores {
            return Err(SimError::BadProblem {
                what: format!("operand has {} blocks for {n_cores} cores", x.len()),
            });
        }
        let df = self.cfg.df;
        let tiles = self.part.tiles_per_core;
        for blk in x {
            if blk.df != df || blk.nz() != tiles {
                return Err(SimError::BadProblem {
                    what: format!(
                        "operand block {:?}/{} does not match operator {df}/{tiles}",
                        blk.df,
                        blk.nz()
                    ),
                });
            }
        }
        let xg = self.part.dist_to_global(x);
        let mut values = Vec::with_capacity(n_cores);
        for core in 0..n_cores {
            // Multiply-accumulate the entry-position columns in stored row
            // order (see module docs on bit-exactness).
            let mut y: Option<CoreBlock> = None;
            for (k, vk) in self.val_blocks[core].iter().enumerate() {
                let cols = &self.col_maps[core][k];
                let xk = CoreBlock::from_fn(df, tiles, |z, xr, yc| {
                    xg[cols[z * TILE_ELEMS + xr * 16 + yc] as usize]
                });
                let prod = engine.eltwise(EltwiseOp::Mul, vk, &xk)?;
                match y.as_mut() {
                    None => y = Some(prod),
                    Some(yb) => engine.axpy_into(yb, 1.0, &prod)?,
                }
            }
            values.push(y.unwrap_or_else(|| CoreBlock::zeros(df, tiles)));
        }
        Ok(values)
    }

    /// Lower one mesh-wide SpMV application to per-die programs (one per
    /// die, all on the per-die sub-grid): die-local gather sends stay NoC
    /// sends (remapped to die-local coordinates), references crossing a
    /// die boundary move to an Ethernet halo phase derived from the
    /// partition's [`crate::sparse::DieCutPlan`] and routed over the mesh
    /// topology. Every program carries the same (mesh-global) Ethernet
    /// phase — the mesh solver takes the slowest die's time and counts
    /// the phase once.
    pub fn lower_mesh(
        &self,
        mesh: &crate::device::DeviceMesh,
        cost: &CostModel,
    ) -> Result<Vec<Program>> {
        if self.part.grid_rows != mesh.logical_rows() || self.part.grid_cols != mesh.logical_cols() {
            return Err(SimError::BadProblem {
                what: format!(
                    "partition {}x{} does not span a {}-die mesh of {}x{} dies",
                    self.part.grid_rows,
                    self.part.grid_cols,
                    mesh.n_dies,
                    mesh.die_rows,
                    mesh.die_cols
                ),
            });
        }
        let df = self.cfg.df;
        let (mesh_rows, mesh_cols) = mesh.mesh_shape();
        let cut = self.part.die_cut_grid(&self.gather, mesh_rows, mesh_cols, df)?;
        let ether = crate::ttm::EtherPhase::halo("spmv-cut", mesh, &cut.flows());
        let cores_per_die = mesh.cores_per_die();
        let die_of = |core: usize| mesh.die_of_core(core);
        let local_coord = |core: usize| {
            let c = self.part.core_coord(core);
            let (dr, dc) = mesh.die_coord(die_of(core));
            crate::device::Coord::new(c.row - dr * mesh.die_rows, c.col - dc * mesh.die_cols)
        };
        // One die's logical core indices in die-local row-major order
        // (contiguous `base..base+cores_per_die` only on 1D meshes — a
        // 2D die grid strides them across the logical grid).
        let cores_of_die = |die: usize| -> Vec<usize> {
            let (dr, dc) = mesh.die_coord(die);
            (0..mesh.die_rows)
                .flat_map(|r| {
                    (0..mesh.die_cols).map(move |c| {
                        (dr * mesh.die_rows + r) * mesh.logical_cols() + dc * mesh.die_cols + c
                    })
                })
                .collect()
        };

        let mul = cost.tile_op_cycles(self.cfg.unit, df, TileOpKind::EltwiseBinary, PipelineMode::Streamed);
        let acc = cost.tile_op_cycles(self.cfg.unit, df, TileOpKind::EltwiseBinary, PipelineMode::Dependent);
        let stats = self.stats();
        let mut programs = Vec::with_capacity(mesh.n_dies);
        for die in 0..mesh.n_dies {
            let die_cores = cores_of_die(die);
            let mut data_movement = Vec::with_capacity(cores_per_die);
            let mut intra_bytes = 0u64;
            for &owner in &die_cores {
                let mut queue = SendQueue::default();
                for &consumer in &die_cores {
                    let Some(&cnt) = self.gather.per_core[consumer].get(&owner) else {
                        continue;
                    };
                    let bytes = align32(cnt * df.bytes());
                    intra_bytes += bytes;
                    queue.sends.push(NocSend {
                        src: local_coord(owner),
                        dst: local_coord(consumer),
                        bytes,
                        cold: queue.sends.is_empty(),
                    });
                }
                data_movement.push(queue);
            }

            let mut riscv_cycles = Vec::with_capacity(cores_per_die);
            let mut compute_cycles = Vec::with_capacity(cores_per_die);
            let mut boundary_riscv = Vec::with_capacity(cores_per_die);
            let mut boundary_compute = Vec::with_capacity(cores_per_die);
            let mut dram_bytes = Vec::with_capacity(cores_per_die);
            let mut die_rows_owned = 0u64;
            let mut matrix_bytes = 0u64;
            for &core in &die_cores {
                let padded = self.sells[core].padded_nnz() as u64;
                let tile_cols = padded.div_ceil(TILE_ELEMS as u64);
                let riscv = 2 * cost.zero_fill_cycles(padded);
                let compute = tile_cols * (mul + acc);
                riscv_cycles.push(riscv);
                compute_cycles.push(compute);
                // Interior/boundary split: the chain that consumes x
                // entries gathered from ANOTHER die — their share of the
                // indexed tile assembly plus the multiply-accumulate of
                // the tile columns they land in — cannot finish before
                // the Ethernet cut drains; the rest is die-local.
                let cut_entries: u64 = self.gather.per_core[core]
                    .iter()
                    .filter(|&(&owner, _)| die_of(owner) != die)
                    .map(|(_, &cnt)| cnt as u64)
                    .sum();
                boundary_riscv.push((2 * cost.zero_fill_cycles(cut_entries)).min(riscv));
                boundary_compute
                    .push((cut_entries.div_ceil(TILE_ELEMS as u64) * (mul + acc)).min(compute));
                let core_matrix = self.sells[core].value_bytes(df) + self.sells[core].index_bytes();
                matrix_bytes += core_matrix;
                dram_bytes.push(match self.cfg.mode {
                    SpmvMode::DramStream => core_matrix,
                    SpmvMode::SramResident => 0,
                });
                die_rows_owned += (0..self.part.slots_per_core())
                    .filter(|&s| self.part.slot_to_global(core, s).is_some())
                    .count() as u64;
            }

            let mut program = Program::standard("spmv");
            for k in &mut program.kernels {
                k.ct_args.push(("die".to_string(), die.to_string()));
                k.ct_args.push(("n_dies".to_string(), mesh.n_dies.to_string()));
                k.ct_args.push(("df".to_string(), df.to_string()));
                k.ct_args.push(("mode".to_string(), format!("{:?}", self.cfg.mode)));
                k.ct_args.push(("nnz".to_string(), stats.nnz.to_string()));
                k.ct_args.push(("padded_nnz".to_string(), stats.padded_nnz.to_string()));
                k.ct_args.push(("cut_entries".to_string(), cut.cut_entries().to_string()));
            }
            programs.push(
                program
                    .with_work(Workload {
                        grid: (mesh.die_rows, mesh.die_cols),
                        data_movement,
                        dram_bytes,
                        riscv_cycles,
                        compute_cycles,
                        boundary_riscv_cycles: boundary_riscv,
                        boundary_compute_cycles: boundary_compute,
                        ether: ether.clone(),
                        ..Workload::default()
                    })
                    .with_footprint(Footprint {
                        tiles_per_core: self.part.tiles_per_core,
                        sram_bytes: self.sram_bytes,
                        traffic_bytes: matrix_bytes + intra_bytes + die_rows_owned * df.bytes() as u64,
                        eth_bytes: cut.cut_bytes(),
                    }),
            );
        }
        Ok(programs)
    }

    /// One SpMV application: values through `engine`, timing by lowering
    /// to a program and executing it through the host queue.
    pub fn apply(
        &self,
        grid: &TensixGrid,
        x: &[CoreBlock],
        engine: &dyn ComputeEngine,
        cost: &CostModel,
    ) -> Result<(Vec<CoreBlock>, SpmvTiming)> {
        if grid.rows != self.part.grid_rows || grid.cols != self.part.grid_cols {
            return Err(SimError::BadProblem {
                what: format!(
                    "grid {}x{} does not match partition {}x{}",
                    grid.rows, grid.cols, self.part.grid_rows, self.part.grid_cols
                ),
            });
        }
        // ---- values -----------------------------------------------------
        let values = self.apply_values(x, engine)?;

        // ---- timing: lower → enqueue → collect --------------------------
        let program = self.lower(cost);
        let mut queue = HostQueue::new(cost.calib.clone());
        let out = queue.run(&program, cost, 0.0, &mut Profiler::disabled())?;

        Ok((
            values,
            SpmvTiming {
                total_ns: out.device_ns(),
                gather_ns: out.data_movement_ns,
                compute_ns: out.local_ns,
                dram_ns: out.dram_ns,
                messages: out.messages,
                bytes: out.bytes,
                traffic: self.traffic(),
            },
        ))
    }
}

/// Run one SpMV — free-function form matching `run_stencil`/`run_dot`.
pub fn run_spmv(
    grid: &TensixGrid,
    op: &SpmvOperator,
    x: &[CoreBlock],
    engine: &dyn ComputeEngine,
    cost: &CostModel,
) -> Result<(Vec<CoreBlock>, SpmvTiming)> {
    op.apply(grid, x, engine, cost)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{NativeEngine, StencilCoeffs};
    use crate::kernels::stencil::{run_stencil, StencilConfig, StencilVariant};
    use crate::solver::problem::{dist_random, Problem};
    use crate::sparse::{banded, circulant_spd, laplacian_3d};
    use crate::util::prng::Rng;

    fn laplacian_operator(
        grid_rows: usize,
        grid_cols: usize,
        nz: usize,
        df: DataFormat,
        mode: SpmvMode,
    ) -> SpmvOperator {
        let a = laplacian_3d(64 * grid_rows, 16 * grid_cols, nz);
        let part = RowPartition::stencil_aligned(grid_rows, grid_cols, nz).unwrap();
        SpmvOperator::new(&a, part, SpmvConfig::new(df, mode)).unwrap()
    }

    #[test]
    fn laplacian_spmv_bit_identical_to_stencil() {
        // The acceptance-criterion core: explicit-matrix SpMV reproduces
        // the matrix-free stencil engine exactly, at both formats.
        let e = NativeEngine::new();
        let cost = CostModel::default();
        for df in [DataFormat::Fp32, DataFormat::Bf16] {
            let p = Problem::new(2, 2, 3, df);
            let grid = p.make_grid().unwrap();
            let x = dist_random(&p, 17);
            let scfg = StencilConfig {
                df,
                unit: ComputeUnit::for_format(df),
                tiles_per_core: 3,
                variant: StencilVariant::FULL,
                coeffs: StencilCoeffs::LAPLACIAN,
            };
            let (want, _) = run_stencil(&grid, &scfg, &x, &e, &cost).unwrap();
            let op = laplacian_operator(2, 2, 3, df, SpmvMode::SramResident);
            let (got, _) = op.apply(&grid, &x, &e, &cost).unwrap();
            assert_eq!(got, want, "df {df}");
        }
    }

    #[test]
    fn general_matrix_matches_f64_oracle() {
        let n = 2 * 1024;
        let a = circulant_spd(n, 5, 3).unwrap();
        let part = RowPartition::row_block(1, 2, n).unwrap();
        let op = SpmvOperator::new(&a, part.clone(), SpmvConfig::new(DataFormat::Fp32, SpmvMode::SramResident)).unwrap();
        let grid = TensixGrid::new(1, 2).unwrap();
        let mut rng = Rng::new(4);
        let xg: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let x = part.dist_from_global(DataFormat::Fp32, &xg);
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let (y, t) = op.apply(&grid, &x, &e, &cost).unwrap();
        let got = part.dist_to_global(&y);
        let want = a.apply_f64(&xg);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((*g as f64 - w).abs() < 1e-4, "row {i}: {g} vs {w}");
        }
        assert_eq!(t.messages, op.gather.messages());
        assert!(t.total_ns > 0.0);
    }

    #[test]
    fn dram_streaming_slower_than_resident() {
        let n = 2 * 1024;
        let a = banded(n, 16).unwrap();
        let part = RowPartition::row_block(1, 2, n).unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let grid = TensixGrid::new(1, 2).unwrap();
        let ones = vec![1.0f32; n];
        let x = part.dist_from_global(DataFormat::Fp32, &ones);
        let mk = |mode| {
            SpmvOperator::new(&a, part.clone(), SpmvConfig::new(DataFormat::Fp32, mode)).unwrap()
        };
        let (ys, ts) = mk(SpmvMode::SramResident).apply(&grid, &x, &e, &cost).unwrap();
        let (yd, td) = mk(SpmvMode::DramStream).apply(&grid, &x, &e, &cost).unwrap();
        assert_eq!(ys, yd, "mode must not change values");
        assert_eq!(ts.dram_ns, 0.0);
        assert!(td.dram_ns > 0.0);
        assert!(td.total_ns > ts.total_ns);
    }

    #[test]
    fn sram_ceiling_enforced_for_resident_matrix() {
        // 64 nnz/row FP32 on one core with 8 tiles: 8192 rows × 64 × 8 B
        // ≈ 4 MB of matrix ≫ 1.5 MB L1.
        let n = 8 * 1024;
        let a = banded(n, 32).unwrap();
        let part = RowPartition::row_block(1, 1, n).unwrap();
        let err = SpmvOperator::new(&a, part.clone(), SpmvConfig::new(DataFormat::Fp32, SpmvMode::SramResident));
        assert!(matches!(err, Err(SimError::SramExhausted { .. })));
        // Streaming the same matrix works.
        assert!(SpmvOperator::new(&a, part, SpmvConfig::new(DataFormat::Fp32, SpmvMode::DramStream)).is_ok());
    }

    #[test]
    fn uniform_seven_nnz_traffic_matches_cusparse_model() {
        // Acceptance criterion: value/index bytes agree with
        // baseline::sell::SellTraffic::laplacian_fp32 on a uniform
        // 7-nnz/row matrix (no padding on either side).
        let n = 2 * 1024;
        let a = circulant_spd(n, 7, 9).unwrap();
        let part = RowPartition::row_block(1, 2, n).unwrap();
        let op = SpmvOperator::new(&a, part, SpmvConfig::new(DataFormat::Fp32, SpmvMode::SramResident)).unwrap();
        let t = op.traffic();
        let gpu = crate::baseline::sell::SellTraffic::laplacian_fp32();
        assert_eq!(t.value_bytes, (gpu.nnz_per_row * gpu.value_bytes * n) as u64);
        assert_eq!(t.index_bytes, (gpu.nnz_per_row * gpu.index_bytes * n) as u64);
        assert_eq!(t.y_write_bytes, (gpu.y_write_bytes * n) as u64);
        assert_eq!(op.stats().padded_nnz, 7 * n, "uniform rows pad nothing");
    }

    #[test]
    fn gather_traffic_matches_halo_shape_on_laplacian() {
        // Stencil-aligned Laplacian: remote x entries are exactly the §6.1
        // halo faces, so NoC bytes scale with the core-boundary surface.
        let op = laplacian_operator(2, 2, 2, DataFormat::Fp32, SpmvMode::SramResident);
        // Per corner core: 16·nz south/north face + 64·nz east/west face.
        assert_eq!(op.gather.remote_entries, 4 * (16 * 2 + 64 * 2) as u64);
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let p = Problem::new(2, 2, 2, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let x = dist_random(&p, 5);
        let (_, t) = op.apply(&grid, &x, &e, &cost).unwrap();
        assert_eq!(t.bytes, op.gather.bytes(DataFormat::Fp32));
        assert!(t.gather_ns > 0.0 && t.gather_ns < t.compute_ns);
    }

    #[test]
    fn mesh_lowering_splits_gather_between_noc_and_ethernet() {
        use crate::device::{DeviceMesh, EthLink, MeshTopology};
        let cost = CostModel::default();
        let op = laplacian_operator(2, 2, 2, DataFormat::Fp32, SpmvMode::SramResident);

        // One die: the mesh lowering degenerates to the single-die one.
        let single = DeviceMesh::new(1, 2, 2, MeshTopology::Line, EthLink::default()).unwrap();
        let ps = op.lower_mesh(&single, &cost).unwrap();
        assert_eq!(ps.len(), 1);
        assert!(ps[0].work.ether.is_none());
        assert_eq!(ps[0].work.data_movement, op.lower(&cost).work.data_movement);
        assert_eq!(ps[0].work.compute_cycles, op.lower(&cost).work.compute_cycles);

        // Two dies: the x-face seam leaves the NoC and rides Ethernet.
        let mesh = DeviceMesh::new(2, 1, 2, MeshTopology::Line, EthLink::default()).unwrap();
        let pd = op.lower_mesh(&mesh, &cost).unwrap();
        assert_eq!(pd.len(), 2);
        let cut = op
            .part
            .die_cut(&op.gather, 2, DataFormat::Fp32)
            .unwrap();
        for p in &pd {
            p.validate().unwrap();
            assert_eq!(p.work.grid, (1, 2));
            let eth = p.work.ether.as_ref().expect("seam phase");
            assert!(eth.overlaps_local);
            assert_eq!(eth.bytes(), cut.cut_bytes());
            assert_eq!(p.footprint.eth_bytes, cut.cut_bytes());
            // NoC sends stay within the die's sub-grid (validate() already
            // rejects out-of-grid coords; assert the byte split too).
            let noc_bytes: u64 = p
                .work
                .data_movement
                .iter()
                .flat_map(|q| q.sends.iter())
                .map(|s| s.bytes)
                .sum();
            assert!(noc_bytes > 0, "E/W faces stay on the NoC");
            // Interior/boundary split: every core of this thin-die mesh
            // touches the seam, so each carries a nonzero boundary chain
            // strictly inside its totals.
            for i in 0..p.work.n_cores() {
                let (br, bc) = (
                    p.work.boundary_riscv_cycles[i],
                    p.work.boundary_compute_cycles[i],
                );
                assert!(br > 0 && bc > 0, "seam core {i} carries a boundary chain");
                assert!(br < p.work.riscv_cycles[i]);
                assert!(bc < p.work.compute_cycles[i]);
            }
        }
        // NoC + Ethernet together cover exactly the single-die gather.
        let full: u64 = op.lower(&cost).work.data_movement.iter().flat_map(|q| q.sends.iter()).map(|s| s.bytes).sum();
        let split: u64 = pd
            .iter()
            .flat_map(|p| p.work.data_movement.iter())
            .flat_map(|q| q.sends.iter())
            .map(|s| s.bytes)
            .sum::<u64>()
            + cut.cut_bytes();
        assert_eq!(split, full);
        // Deterministic lowering.
        assert_eq!(op.lower_mesh(&mesh, &cost).unwrap(), pd);
    }

    #[test]
    fn operator_validates_inputs() {
        let a = banded(100, 2).unwrap();
        let part = RowPartition::row_block(1, 1, 100).unwrap();
        // FPU cannot run FP32.
        let bad = SpmvConfig {
            df: DataFormat::Fp32,
            unit: ComputeUnit::Fpu,
            mode: SpmvMode::SramResident,
            sigma: 1,
        };
        assert!(SpmvOperator::new(&a, part.clone(), bad).is_err());
        // Rectangular and mismatched sizes.
        let rect = CsrMatrix::from_triplets(4, 5, &[(0, 0, 1.0)]).unwrap();
        assert!(SpmvOperator::new(&rect, part.clone(), SpmvConfig::new(DataFormat::Fp32, SpmvMode::SramResident)).is_err());
        let op = SpmvOperator::new(&a, part, SpmvConfig::new(DataFormat::Fp32, SpmvMode::SramResident)).unwrap();
        assert_eq!(op.uniform_diagonal(), Some(4.0));
        // Wrong grid shape at apply time.
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let grid = TensixGrid::new(2, 1).unwrap();
        let x = vec![CoreBlock::zeros(DataFormat::Fp32, 1)];
        assert!(op.apply(&grid, &x, &e, &cost).is_err());
    }

    #[test]
    fn spmv_values_independent_of_sigma_and_mode() {
        let n = 1024;
        let a = banded(n, 5).unwrap();
        let part = RowPartition::row_block(1, 1, n).unwrap();
        let grid = TensixGrid::new(1, 1).unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let mut rng = Rng::new(6);
        let xg: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let x = part.dist_from_global(DataFormat::Fp32, &xg);
        let mut results = Vec::new();
        for sigma in [1, 32, 256] {
            let cfg = SpmvConfig::new(DataFormat::Fp32, SpmvMode::SramResident).with_sigma(sigma);
            let op = SpmvOperator::new(&a, part.clone(), cfg).unwrap();
            let (y, _) = op.apply(&grid, &x, &e, &cost).unwrap();
            results.push(y);
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[1], results[2]);
    }

    #[test]
    fn single_core_has_no_gather_traffic() {
        // A 1×1 grid owns every column: no remote entries, no NoC traffic.
        let op = laplacian_operator(1, 1, 2, DataFormat::Fp32, SpmvMode::SramResident);
        assert_eq!(op.gather.remote_entries, 0);
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let p = Problem::new(1, 1, 2, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let x = dist_random(&p, 8);
        let (got, t) = op.apply(&grid, &x, &e, &cost).unwrap();
        assert_eq!(t.messages, 0);
        // And still equals the stencil with zero-fill boundaries all round.
        let scfg = StencilConfig {
            df: DataFormat::Fp32,
            unit: ComputeUnit::Sfpu,
            tiles_per_core: 2,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let (want, _) = run_stencil(&grid, &scfg, &x, &e, &cost).unwrap();
        assert_eq!(got, want);
    }
}
