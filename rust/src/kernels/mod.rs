//! The paper's three numerical kernels (§4–§6) — element-wise arithmetic,
//! global dot-product reduction, and the 7-point 3D stencil — plus the
//! general sparse SpMV that extends the stencil's fixed operator to
//! arbitrary matrices (see [`crate::sparse`]). Each kernel produces values
//! through a [`crate::engine::ComputeEngine`] and timing through the cost
//! model + NoC simulator.

pub mod eltwise;
pub mod reduction;
pub mod spmv;
pub mod stencil;

pub use eltwise::{block_op_ns, eltwise_stream_timing, EltwiseTiming};
pub use reduction::{run_dot, DotConfig, DotMethod, DotOutcome};
pub use spmv::{run_spmv, SpmvConfig, SpmvMode, SpmvOperator, SpmvTiming, SpmvTraffic};
pub use stencil::{run_stencil, StencilConfig, StencilTiming, StencilVariant};
