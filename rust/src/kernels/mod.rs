//! The paper's three numerical kernels (§4–§6) — element-wise arithmetic,
//! global dot-product reduction, and the 7-point 3D stencil — plus the
//! general sparse SpMV that extends the stencil's fixed operator to
//! arbitrary matrices (see [`crate::sparse`]).
//!
//! Each kernel produces values through a
//! [`crate::engine::ComputeEngine`] and timing by *lowering* to a
//! [`crate::ttm::Program`] (the `lower_*` constructors) executed through
//! [`crate::ttm::HostQueue::run`]. To add a kernel, write a lowering —
//! not a timing path: describe its NoC sends, RISC-V element loops,
//! compute-pipeline cycles, and DRAM staging as a
//! [`crate::ttm::Workload`], and the scheduler owns dispatch cost,
//! per-phase timing, and profiler zones.

pub mod eltwise;
pub mod reduction;
pub mod spmv;
pub mod stencil;

pub use eltwise::{block_op_ns, eltwise_stream_timing, lower_block_op, lower_eltwise, EltwiseTiming};
pub use reduction::{lower_dot, lower_dot_as, run_dot, DotConfig, DotMethod, DotOutcome};
pub use spmv::{run_spmv, SpmvConfig, SpmvMode, SpmvOperator, SpmvTiming, SpmvTraffic};
pub use stencil::{
    boundary_tile_cycles, boundary_tile_cycles_ew, lower_stencil, lower_stencil_die, run_stencil,
    StencilConfig, StencilTiming, StencilVariant,
};
