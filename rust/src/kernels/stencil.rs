//! The 7-point 3D stencil kernel (§6) — the SpMV building block of PCG.
//!
//! Data distribution (§6.1): the 3D grid collapses its z dimension onto the
//! plane; each core owns a column of `nz` 64×16 tiles. Per application,
//! every core
//!
//! 1. exchanges boundary data with its four cardinal neighbors over the
//!    NoC — N/S boundaries are one contiguous 32B row per tile; E/W
//!    boundaries cross the face transpose and travel as **4 discontiguous
//!    16-element segments** per tile (§6.3, Fig 10);
//! 2. zero-fills halo rows/columns on global-domain boundaries (§6.3 —
//!    "unexpectedly expensive" on the baby RISC-Vs);
//! 3. builds shifted tiles (pointer-trick rows, transpose-pipeline
//!    columns; §6.2) and accumulates the 7 scaled components.
//!
//! Values come from the engine (native tile math or the AOT Pallas
//! artifact); timing comes from lowering the kernel to a [`Program`]
//! ([`lower_stencil`]: halo sends, zero-fill RISC-V cycles, and the
//! shift/transpose compute pipeline per core) executed through
//! [`crate::ttm::HostQueue::run`].

use crate::arch::{ComputeUnit, DataFormat};
use crate::device::TensixGrid;
use crate::engine::{ComputeEngine, CoreBlock, Halos, StencilCoeffs};
use crate::profiler::Profiler;
use crate::tile::ShiftDir;
use crate::timing::cost::{CostModel, PipelineMode, TileOpKind};
use crate::timing::SimNs;
use crate::ttm::{Footprint, HostQueue, NocSend, Program, SendQueue, Workload};

/// Which parts of the stencil run (the Fig-11 ablation variants).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StencilVariant {
    pub halo_exchange: bool,
    pub zero_fill: bool,
}

impl StencilVariant {
    pub const FULL: Self = Self { halo_exchange: true, zero_fill: true };
    pub const NO_HALO: Self = Self { halo_exchange: false, zero_fill: true };
    pub const NO_ZERO_FILL: Self = Self { halo_exchange: true, zero_fill: false };
    pub const NEITHER: Self = Self { halo_exchange: false, zero_fill: false };

    pub fn label(self) -> &'static str {
        match (self.halo_exchange, self.zero_fill) {
            (true, true) => "full",
            (false, true) => "no halo",
            (true, false) => "no zero fill",
            (false, false) => "neither",
        }
    }
}

#[derive(Debug, Clone)]
pub struct StencilConfig {
    pub df: DataFormat,
    pub unit: ComputeUnit,
    pub tiles_per_core: usize,
    pub variant: StencilVariant,
    pub coeffs: StencilCoeffs,
}

impl StencilConfig {
    /// The paper's Fig-11 configuration: BF16 on the FPU.
    pub fn paper_fig11(tiles: usize, variant: StencilVariant) -> Self {
        Self {
            df: DataFormat::Bf16,
            unit: ComputeUnit::Fpu,
            tiles_per_core: tiles,
            variant,
            coeffs: StencilCoeffs::LAPLACIAN,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct StencilTiming {
    /// Whole-iteration time (slowest core, halo waits included).
    pub iter_ns: SimNs,
    /// Slowest core's local shift/transpose/accumulate compute.
    pub compute_ns: SimNs,
    /// Slowest core's halo send-issue + wait time.
    pub halo_ns: SimNs,
    /// Slowest core's zero-fill time.
    pub zero_fill_ns: SimNs,
    pub messages: u64,
    pub bytes: u64,
}

/// Local per-tile operation count/cost of the stencil pipeline (§6.2):
/// center scale; N/S = shift-copy + accumulate each; E/W = transpose +
/// shift-copy + transpose + accumulate each; z = 2 accumulates.
pub fn local_tile_cycles(cost: &CostModel, unit: ComputeUnit, df: DataFormat) -> u64 {
    let dep = PipelineMode::Dependent;
    let scale = cost.tile_op_cycles(unit, df, TileOpKind::EltwiseUnary, dep);
    let shift = cost.tile_op_cycles(unit, df, TileOpKind::ShiftCopy, dep);
    let transpose = cost.tile_op_cycles(unit, df, TileOpKind::Transpose, dep);
    let add = cost.tile_op_cycles(unit, df, TileOpKind::EltwiseBinary, dep);
    // center + 2×(N/S) + 2×(E/W) + 2×z
    scale + 2 * (shift + add) + 2 * (2 * transpose + shift + add) + 2 * add
}

/// The seam-dependent (*boundary*) per-tile cycles of ONE N/S direction
/// whose halo row arrives over an inter-die Ethernet seam: the §6.2
/// shift-copy that rebuilds the displaced tile around the seam row plus
/// the accumulate that folds it in. Everything else in
/// [`local_tile_cycles`] — center, the other N/S direction, both E/W
/// transposes, the z accumulates — is *interior*: it depends only on
/// die-local data and can run while the seam is still in flight.
pub fn boundary_tile_cycles(cost: &CostModel, unit: ComputeUnit, df: DataFormat) -> u64 {
    let dep = PipelineMode::Dependent;
    cost.tile_op_cycles(unit, df, TileOpKind::ShiftCopy, dep)
        + cost.tile_op_cycles(unit, df, TileOpKind::EltwiseBinary, dep)
}

/// The seam-dependent per-tile cycles of ONE E/W direction whose halo
/// column arrives over an inter-die Ethernet seam (2D die grids): the
/// §6.2 transpose → shift-copy → transpose pipeline that rebuilds the
/// displaced tile across the face, plus the accumulate — the E/W slice
/// of [`local_tile_cycles`], heavier than the N/S slice by the two
/// transposes.
pub fn boundary_tile_cycles_ew(cost: &CostModel, unit: ComputeUnit, df: DataFormat) -> u64 {
    let dep = PipelineMode::Dependent;
    2 * cost.tile_op_cycles(unit, df, TileOpKind::Transpose, dep)
        + cost.tile_op_cycles(unit, df, TileOpKind::ShiftCopy, dep)
        + cost.tile_op_cycles(unit, df, TileOpKind::EltwiseBinary, dep)
}

/// Bytes of one N/S halo row and one E/W halo segment at `df` (§6.3).
fn halo_unit_bytes(df: DataFormat) -> (u64, u64) {
    let row = (16 * df.bytes()) as u64; // one tile row = one NoC write
    let seg = (16 * df.bytes()) as u64; // one of 4 E/W face segments
    (row, seg)
}

/// Zero-fill element count per tile for each missing side: N/S = one
/// 16-element row, E/W = one 64-element column (§6.3).
fn zero_fill_elems(missing: &[ShiftDir]) -> u64 {
    missing
        .iter()
        .map(|d| match d {
            ShiftDir::North | ShiftDir::South => 16u64,
            ShiftDir::East | ShiftDir::West => 64u64,
        })
        .sum()
}

/// Lower the stencil application to a program: per-core halo send queues
/// (first transaction per direction cold, per-tile rest batched, §6.3),
/// zero-fill RISC-V element loops at the boundary, and the §6.2
/// shift/transpose compute pipeline.
pub fn lower_stencil(grid: &TensixGrid, cfg: &StencilConfig, cost: &CostModel) -> Program {
    let n_cores = grid.n_cores();
    let nz = cfg.tiles_per_core as u64;
    let (row_bytes, seg_bytes) = halo_unit_bytes(cfg.df);

    // Halo exchange (§6.3): the writer RISC-V issues each core's sends
    // sequentially, in direction order.
    let mut data_movement = Vec::with_capacity(n_cores);
    let mut halo_bytes = 0u64;
    if cfg.variant.halo_exchange {
        for coord in grid.coords() {
            let mut queue = SendQueue::default();
            for dir in ShiftDir::ALL {
                if let Some(nb) = grid.neighbor(coord, dir) {
                    let (n_msgs, bytes) = match dir {
                        // One contiguous row write per tile (§6.3).
                        ShiftDir::North | ShiftDir::South => (nz, row_bytes),
                        // Four discontiguous segments per tile (§6.3).
                        ShiftDir::East | ShiftDir::West => (4 * nz, seg_bytes),
                    };
                    for m in 0..n_msgs {
                        queue.sends.push(NocSend {
                            src: coord,
                            dst: nb,
                            bytes,
                            cold: m == 0,
                        });
                        halo_bytes += bytes;
                    }
                }
            }
            data_movement.push(queue);
        }
    }

    // Zero fills at the global boundary (§6.3) on the baby RISC-Vs.
    let mut riscv_cycles = Vec::with_capacity(n_cores);
    for coord in grid.coords() {
        let missing: Vec<ShiftDir> = ShiftDir::ALL
            .into_iter()
            .filter(|&d| grid.neighbor(coord, d).is_none())
            .collect();
        riscv_cycles.push(if cfg.variant.zero_fill {
            cost.zero_fill_cycles(zero_fill_elems(&missing) * nz)
        } else {
            0
        });
    }

    let local_cycles = local_tile_cycles(cost, cfg.unit, cfg.df) * nz;

    let mut program = Program::standard("stencil");
    for k in &mut program.kernels {
        k.ct_args.push(("tiles".to_string(), cfg.tiles_per_core.to_string()));
        k.ct_args.push(("df".to_string(), cfg.df.to_string()));
        k.ct_args.push(("variant".to_string(), cfg.variant.label().to_string()));
    }
    program
        .with_work(Workload {
            grid: (grid.rows, grid.cols),
            data_movement,
            riscv_cycles,
            compute_cycles: vec![local_cycles; n_cores],
            ..Workload::default()
        })
        .with_footprint(Footprint {
            tiles_per_core: cfg.tiles_per_core,
            // x + result vectors resident per core.
            sram_bytes: 2 * cfg.tiles_per_core * cfg.df.tile_bytes(),
            traffic_bytes: halo_bytes,
            eth_bytes: 0,
        })
}

/// Lower one die's stencil program for a die-grid mesh: the per-die
/// NoC halo schedule of [`lower_stencil`], plus the interior/boundary
/// compute split on seam-adjacent core strips. `seam_north` marks a
/// neighboring die above (logical row 0 of this die consumes its seam),
/// `seam_south` one below (last row); on 2D die grids `seam_west` /
/// `seam_east` mark neighbors left/right (first/last core *column*),
/// extending the split to four boundary strips. The boundary chain is
/// carved out of the same per-core totals — [`boundary_tile_cycles`]
/// per tile per N/S side, [`boundary_tile_cycles_ew`] per E/W side — so
/// a Serial schedule times identically to the unsplit lowering; a
/// Pipelined schedule may overlap the interior chain with the Ethernet
/// seams.
pub fn lower_stencil_die(
    grid: &TensixGrid,
    cfg: &StencilConfig,
    cost: &CostModel,
    seam_north: bool,
    seam_south: bool,
    seam_west: bool,
    seam_east: bool,
) -> Program {
    let mut program = lower_stencil(grid, cfg, cost);
    if !(seam_north || seam_south || seam_west || seam_east) {
        return program;
    }
    let per_side = boundary_tile_cycles(cost, cfg.unit, cfg.df) * cfg.tiles_per_core as u64;
    let per_side_ew = boundary_tile_cycles_ew(cost, cfg.unit, cfg.df) * cfg.tiles_per_core as u64;
    let mut boundary = vec![0u64; grid.n_cores()];
    for coord in grid.coords() {
        let mut b = 0u64;
        if seam_north && coord.row == 0 {
            b += per_side;
        }
        if seam_south && coord.row + 1 == grid.rows {
            b += per_side;
        }
        if seam_west && coord.col == 0 {
            b += per_side_ew;
        }
        if seam_east && coord.col + 1 == grid.cols {
            b += per_side_ew;
        }
        let i = coord.row * grid.cols + coord.col;
        boundary[i] = b.min(program.work.compute_cycles[i]);
    }
    program.work.boundary_compute_cycles = boundary;
    program
}

/// Outcome: the stencil-applied blocks (core-indexed) plus timing. Thin
/// wrapper: lower, run through the host queue, compute values via the
/// engine.
pub fn run_stencil(
    grid: &TensixGrid,
    cfg: &StencilConfig,
    x: &[CoreBlock],
    engine: &dyn ComputeEngine,
    cost: &CostModel,
) -> crate::Result<(Vec<CoreBlock>, StencilTiming)> {
    let n_cores = grid.n_cores();
    assert_eq!(x.len(), n_cores, "one block per core");

    // ---- timing: lower → enqueue → collect ------------------------------
    let program = lower_stencil(grid, cfg, cost);
    let mut queue = HostQueue::new(cost.calib.clone());
    let out = queue.run(&program, cost, 0.0, &mut Profiler::disabled())?;

    // ---- values ----------------------------------------------------------
    let mut values = Vec::with_capacity(n_cores);
    for coord in grid.coords() {
        let i = grid.index(coord)?;
        let get = |dir: ShiftDir| -> Option<&CoreBlock> {
            grid.neighbor(coord, dir)
                .map(|nb| &x[grid.index(nb).unwrap()])
        };
        let halos = if cfg.variant.halo_exchange {
            Halos::gather(
                get(ShiftDir::North),
                get(ShiftDir::South),
                get(ShiftDir::West),
                get(ShiftDir::East),
            )
        } else {
            Halos::none()
        };
        values.push(engine.stencil_apply(&x[i], &halos, cfg.coeffs)?);
    }

    Ok((
        values,
        StencilTiming {
            iter_ns: out.device_ns(),
            compute_ns: out.compute_ns,
            halo_ns: out.data_movement_ns,
            zero_fill_ns: out.riscv_ns,
            messages: out.messages,
            bytes: out.bytes,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::util::prng::Rng;

    fn blocks(seed: u64, n: usize, tiles: usize, df: DataFormat) -> Vec<CoreBlock> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| CoreBlock::from_fn(df, tiles, |_, _, _| rng.next_f32() - 0.5))
            .collect()
    }

    #[test]
    fn halo_exchange_stitches_cores_correctly() {
        // A global linear field f(x,y,z) = x + 2y + 3z has Laplacian 0 at
        // interior points — any cross-core stitching error shows up as a
        // nonzero interior value.
        let grid = TensixGrid::new(2, 2).unwrap();
        let nz = 3;
        let cfg = StencilConfig {
            df: DataFormat::Fp32,
            unit: ComputeUnit::Sfpu,
            tiles_per_core: nz,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let mut xs = Vec::new();
        for r in 0..2 {
            for c in 0..2 {
                xs.push(CoreBlock::from_fn(DataFormat::Fp32, nz, |z, xr, yc| {
                    let gx = (r * 64 + xr) as f32;
                    let gy = (c * 16 + yc) as f32;
                    (gx + 2.0 * gy + 3.0 * z as f32) * 1e-3
                }));
            }
        }
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let (out, _) = run_stencil(&grid, &cfg, &xs, &e, &cost).unwrap();
        // Check global-interior points, including ones adjacent to core
        // boundaries (x=63/64 within core 0/2, y=15/16 across cores 0/1).
        for (idx, (zz, xx, yy)) in [
            (0usize, (1usize, 63usize, 8usize)),
            (2, (1, 0, 8)),
            (0, (1, 30, 15)),
            (1, (1, 30, 0)),
        ] {
            let v = out[idx].get(zz, xx, yy);
            assert!(
                v.abs() < 1e-5,
                "interior Laplacian of linear field should be ~0, got {v} at block {idx} ({zz},{xx},{yy})"
            );
        }
    }

    #[test]
    fn fig11_variant_timing_ordering() {
        let grid = TensixGrid::new(2, 2).unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let xs = blocks(1, 4, 8, DataFormat::Bf16);
        let mut t = std::collections::HashMap::new();
        for v in [
            StencilVariant::FULL,
            StencilVariant::NO_HALO,
            StencilVariant::NO_ZERO_FILL,
            StencilVariant::NEITHER,
        ] {
            let cfg = StencilConfig::paper_fig11(8, v);
            let (_, timing) = run_stencil(&grid, &cfg, &xs, &e, &cost).unwrap();
            t.insert(v.label(), timing.iter_ns);
        }
        assert!(t["full"] >= t["no halo"]);
        assert!(t["full"] >= t["no zero fill"]);
        assert!(t["no halo"] >= t["neither"]);
        assert!(t["no zero fill"] >= t["neither"]);
    }

    #[test]
    fn local_compute_dominates_communication() {
        // §6.3: "The local compute is much more expensive than the
        // communication, demonstrating the strength of the Wormhole NoC".
        let grid = TensixGrid::new(4, 4).unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let xs = blocks(2, 16, 64, DataFormat::Bf16);
        let cfg = StencilConfig::paper_fig11(64, StencilVariant::FULL);
        let (_, timing) = run_stencil(&grid, &cfg, &xs, &e, &cost).unwrap();
        assert!(
            timing.compute_ns > 3.0 * timing.halo_ns,
            "compute {} vs halo {}",
            timing.compute_ns,
            timing.halo_ns
        );
    }

    #[test]
    fn ew_exchange_is_4x_ns_message_count() {
        // §6.3: E/W halo needs 4 sends per tile vs 1 for N/S.
        let grid = TensixGrid::new(1, 2).unwrap(); // E/W neighbors only
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let xs = blocks(3, 2, 4, DataFormat::Bf16);
        let cfg = StencilConfig::paper_fig11(4, StencilVariant::FULL);
        let (_, t_ew) = run_stencil(&grid, &cfg, &xs, &e, &cost).unwrap();
        // 2 cores × 1 neighbor × 4 tiles × 4 segments = 32 messages.
        assert_eq!(t_ew.messages, 32);

        let grid_ns = TensixGrid::new(2, 1).unwrap(); // N/S neighbors only
        let (_, t_ns) = run_stencil(&grid_ns, &cfg, &xs, &e, &cost).unwrap();
        // 2 cores × 1 neighbor × 4 tiles × 1 row = 8 messages.
        assert_eq!(t_ns.messages, 8);
        assert_eq!(t_ew.messages, 4 * t_ns.messages);
    }

    #[test]
    fn die_lowering_splits_seam_rows_only() {
        let grid = TensixGrid::new(3, 2).unwrap();
        let cost = CostModel::default();
        let cfg = StencilConfig::paper_fig11(4, StencilVariant::FULL);
        let per_side = boundary_tile_cycles(&cost, cfg.unit, cfg.df) * 4;
        assert!(per_side > 0);

        // No seam: the plain lowering, no split carried.
        let alone = lower_stencil_die(&grid, &cfg, &cost, false, false, false, false);
        assert_eq!(alone, lower_stencil(&grid, &cfg, &cost));
        assert!(alone.work.boundary_compute_cycles.is_empty());

        // Middle die of a column: first row consumes the north seam,
        // last row the south seam, interior rows carry no boundary chain.
        let mid = lower_stencil_die(&grid, &cfg, &cost, true, true, false, false);
        mid.validate().unwrap();
        assert_eq!(
            mid.work.boundary_compute_cycles,
            vec![per_side, per_side, 0, 0, per_side, per_side]
        );
        // The split never changes the totals: Serial timing is the
        // unsplit model's bit for bit.
        assert_eq!(mid.work.compute_cycles, alone.work.compute_cycles);
        assert_eq!(mid.work.riscv_cycles, alone.work.riscv_cycles);
        assert_eq!(mid.work.data_movement, alone.work.data_movement);

        // A one-row die on both seams stacks the two sides on one core.
        let thin = TensixGrid::new(1, 2).unwrap();
        let both = lower_stencil_die(&thin, &cfg, &cost, true, true, false, false);
        both.validate().unwrap();
        assert_eq!(both.work.boundary_compute_cycles, vec![2 * per_side; 2]);
        // The boundary chain stays a strict subset of the local compute.
        for (b, c) in both
            .work
            .boundary_compute_cycles
            .iter()
            .zip(&both.work.compute_cycles)
        {
            assert!(b < c);
        }
    }

    #[test]
    fn die_lowering_splits_four_seam_strips_on_2d_grids() {
        let grid = TensixGrid::new(3, 3).unwrap();
        let cost = CostModel::default();
        let cfg = StencilConfig::paper_fig11(4, StencilVariant::FULL);
        let ns = boundary_tile_cycles(&cost, cfg.unit, cfg.df) * 4;
        let ew = boundary_tile_cycles_ew(&cost, cfg.unit, cfg.df) * 4;
        // The E/W slice carries the two face transposes on top of the
        // N/S shift+accumulate.
        assert!(ew > ns);

        // An interior die of a 2D die grid consumes all four seams: the
        // corner cores stack an N/S and an E/W side, edge-center cores
        // carry one side, the center core none.
        let all = lower_stencil_die(&grid, &cfg, &cost, true, true, true, true);
        all.validate().unwrap();
        assert_eq!(
            all.work.boundary_compute_cycles,
            vec![
                ns + ew, ns, ns + ew,
                ew,      0,  ew,
                ns + ew, ns, ns + ew,
            ]
        );
        // Totals unchanged: Serial timing is the unsplit model's.
        let alone = lower_stencil(&grid, &cfg, &cost);
        assert_eq!(all.work.compute_cycles, alone.work.compute_cycles);
        // East-only seam marks the last core column.
        let east = lower_stencil_die(&grid, &cfg, &cost, false, false, false, true);
        assert_eq!(east.work.boundary_compute_cycles, vec![0, 0, ew, 0, 0, ew, 0, 0, ew]);
        // The boundary chain never exceeds the local compute.
        for (b, c) in all.work.boundary_compute_cycles.iter().zip(&all.work.compute_cycles) {
            assert!(b <= c);
        }
    }

    #[test]
    fn single_core_full_zero_fill_cost() {
        // 1×1 grid: all four sides zero-filled — the Fig-11 anomaly source.
        let grid = TensixGrid::new(1, 1).unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let xs = blocks(4, 1, 8, DataFormat::Bf16);
        let full = StencilConfig::paper_fig11(8, StencilVariant::FULL);
        let nozf = StencilConfig::paper_fig11(8, StencilVariant::NO_ZERO_FILL);
        let (_, tf) = run_stencil(&grid, &full, &xs, &e, &cost).unwrap();
        let (_, tn) = run_stencil(&grid, &nozf, &xs, &e, &cost).unwrap();
        // (16+16+64+64) elems × 8 tiles × per-elem cost.
        let expect = crate::timing::cycles_ns(cost.zero_fill_cycles(160 * 8));
        assert!((tf.iter_ns - tn.iter_ns - expect).abs() < 1e-6);
        assert_eq!(tf.messages, 0, "no neighbors, no traffic");
    }
}
