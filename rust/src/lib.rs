//! # wormsim
//!
//! A production-quality reproduction of *"Numerical Kernels on a Spatial
//! Accelerator: A Study of Tenstorrent Wormhole"* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)**: a cycle-approximate simulator of one
//!   Wormhole Tensix die (tiles, circular buffers, SRAM, NoC, FPU/SFPU
//!   cost model) plus the paper's three numerical kernels (element-wise
//!   arithmetic, global dot-product reduction, 7-point 3D stencil), a
//!   general sparse-matrix subsystem ([`sparse`]: CSR / SELL-C-32,
//!   Matrix Market I/O, grid partitioning) with a SELL SpMV kernel
//!   ([`kernels::spmv`]), and the preconditioned conjugate-gradient
//!   solver built from them — runnable on the hard-coded Laplacian or on
//!   arbitrary SPD matrices through [`solver::Operator`].
//!
//! Execution follows one pipeline: every kernel **lowers** to a
//! [`ttm::Program`] (reader/compute/writer kernel specs + a per-core
//! [`ttm::Workload`] of NoC sends, RISC-V element loops, compute cycles,
//! DRAM staging, and — on a multi-die mesh — inter-die
//! [`ttm::EtherPhase`] steps) and executes through
//! [`ttm::HostQueue::run`], the single scheduler that owns dispatch
//! overhead, per-phase timing, and profiler zones. Iterative solvers
//! derive their §7.1 fused-vs-split launch accounting from a
//! [`ttm::IterSchedule`] over the component programs
//! ([`ttm::Program::fuse`] checks the §7.2 SRAM budget). To add a
//! kernel, write a lowering — not a timing path.
//!
//! Beyond one die, [`device::DeviceMesh`] models N Ethernet-connected
//! dies (n150 → n300 → Galaxy; line or ring) with per-link occupancy
//! ([`device::EthSim`]: shared links serialize concurrent hops), and
//! [`solver::solve_pcg_mesh`] distributes PCG across them with
//! trajectories bit-identical to the single-die solver — the §8
//! multi-device future work, built in. `MeshOptions::overlap` picks the
//! seam schedule: serial (the paper's model) or pipelined (interior
//! compute hides the halo via the lowered interior/boundary split).
//!
//! Observability is unified in [`telemetry`]: every executed program
//! carries a per-resource [`telemetry::ResourceLedger`] (conservation:
//! rows sum to wall time), solvers expose a [`telemetry::SolveLedger`]
//! with a bottleneck verdict plus JSONL iteration events, the profiler
//! renders Perfetto zones *and* counter tracks, and bench sweeps
//! serialize to `BENCH_<name>.json` via [`telemetry::BenchSnapshot`].
//! - **Layer 2** (`python/compile/model.py`): per-core compute graphs in
//!   JAX, AOT-lowered to HLO text artifacts.
//! - **Layer 1** (`python/compile/kernels/`): Pallas kernels for the
//!   compute hot spots, validated against pure-jnp oracles.
//!
//! The PJRT runtime ([`runtime`]) loads the AOT artifacts and executes
//! them from the Rust hot path; Python never runs at request time.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record of every table and figure.

pub mod arch;
pub mod baseline;
pub mod device;
pub mod error;
pub mod experiments;
pub mod kernels;
pub mod noc;
pub mod engine;
pub mod profiler;
pub mod tile;
pub mod runtime;
pub mod solver;
pub mod sparse;
pub mod telemetry;
pub mod ttm;
pub mod timing;
pub mod util;

pub use error::{Result, SimError};
