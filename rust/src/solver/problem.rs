//! The model problem (§7): a 7-point finite-difference Laplacian on a 3D
//! structured grid with zero Dirichlet boundary conditions, distributed
//! over the Tensix grid per §6.1.
//!
//! Grid ↔ core mapping: the global `Nx × Ny × Nz` domain satisfies
//! `Nx = 64 × grid_rows`, `Ny = 16 × grid_cols`, `Nz = tiles_per_core`
//! (each core holds a column of `Nz` 64×16 tiles). The paper's Table-3
//! problem (512×112×64 on 8×7 cores with 64 tiles/core) is exactly this
//! mapping. Vectors are indexed `x[i + Nx*(j + Ny*k)]` (§7, Eq. 1).

use crate::arch::constants::{
    PCG_VECTORS_FUSED, PCG_VECTORS_SPLIT, SRAM_RESERVE_FUSED, SRAM_RESERVE_SPLIT, TILE_STENCIL,
};
use crate::arch::DataFormat;
use crate::device::{Sram, TensixGrid};
use crate::engine::CoreBlock;
use crate::error::{Result, SimError};
use crate::util::prng::Rng;

/// Problem description.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Problem {
    pub grid_rows: usize,
    pub grid_cols: usize,
    pub tiles_per_core: usize,
    pub df: DataFormat,
}

impl Problem {
    pub fn new(grid_rows: usize, grid_cols: usize, tiles_per_core: usize, df: DataFormat) -> Self {
        Self {
            grid_rows,
            grid_cols,
            tiles_per_core,
            df,
        }
    }

    /// Global domain extents (Nx, Ny, Nz).
    pub fn dims(&self) -> (usize, usize, usize) {
        (
            TILE_STENCIL.0 * self.grid_rows,
            TILE_STENCIL.1 * self.grid_cols,
            self.tiles_per_core,
        )
    }

    pub fn n_cores(&self) -> usize {
        self.grid_rows * self.grid_cols
    }

    pub fn elems(&self) -> usize {
        let (nx, ny, nz) = self.dims();
        nx * ny * nz
    }

    /// Validate against the §7.2 SRAM capacity model for the PCG variant
    /// that will run on it (`fused` = the BF16 fused-kernel layout).
    pub fn validate_capacity(&self, fused: bool) -> Result<()> {
        let sram = Sram::new("capacity-check");
        let (reserve, vectors) = if fused {
            (SRAM_RESERVE_FUSED, PCG_VECTORS_FUSED)
        } else {
            (SRAM_RESERVE_SPLIT, PCG_VECTORS_SPLIT)
        };
        let max = sram.max_tiles(reserve, vectors * self.df.tile_bytes());
        if self.tiles_per_core > max {
            return Err(SimError::BadProblem {
                what: format!(
                    "{} tiles/core exceeds the {max}-tile SRAM ceiling for {} {} PCG (§7.2)",
                    self.tiles_per_core,
                    self.df,
                    if fused { "fused" } else { "split" }
                ),
            });
        }
        Ok(())
    }

    pub fn make_grid(&self) -> Result<TensixGrid> {
        TensixGrid::new(self.grid_rows, self.grid_cols)
    }

    /// Global flat index (§7 Eq. 1).
    pub fn global_index(&self, i: usize, j: usize, k: usize) -> usize {
        let (nx, ny, _) = self.dims();
        i + nx * (j + ny * k)
    }
}

/// A vector distributed over the core grid: one [`CoreBlock`] per core, in
/// the grid's row-major core order.
pub type DistVector = Vec<CoreBlock>;

/// Zero-filled distributed vector.
pub fn dist_zeros(p: &Problem) -> DistVector {
    (0..p.n_cores())
        .map(|_| CoreBlock::zeros(p.df, p.tiles_per_core))
        .collect()
}

/// Distributed vector from a global generator f(i, j, k).
pub fn dist_from_fn(p: &Problem, mut f: impl FnMut(usize, usize, usize) -> f32) -> DistVector {
    let mut out = Vec::with_capacity(p.n_cores());
    for gr in 0..p.grid_rows {
        for gc in 0..p.grid_cols {
            out.push(CoreBlock::from_fn(p.df, p.tiles_per_core, |z, xr, yc| {
                f(gr * 64 + xr, gc * 16 + yc, z)
            }));
        }
    }
    out
}

/// Deterministic random distributed vector in [-1, 1).
pub fn dist_random(p: &Problem, seed: u64) -> DistVector {
    let mut rng = Rng::new(seed);
    // Generate through a global buffer so the values are independent of the
    // distribution layout.
    let (nx, ny, nz) = p.dims();
    let global: Vec<f32> = (0..nx * ny * nz).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    dist_from_fn(p, |i, j, k| global[p.global_index(i, j, k)])
}

/// Gather to a global flat vector (Eq. 1 ordering).
pub fn dist_to_global(p: &Problem, v: &DistVector) -> Vec<f32> {
    let (nx, ny, nz) = p.dims();
    let mut out = vec![0.0f32; nx * ny * nz];
    for gr in 0..p.grid_rows {
        for gc in 0..p.grid_cols {
            let block = &v[gr * p.grid_cols + gc];
            for z in 0..nz {
                for xr in 0..64 {
                    for yc in 0..16 {
                        out[p.global_index(gr * 64 + xr, gc * 16 + yc, z)] =
                            block.get(z, xr, yc);
                    }
                }
            }
        }
    }
    out
}

/// Reference 7-point Laplacian on the global vector in f64 (zero Dirichlet):
/// the §7 Eq.-2 operator, used as the correctness oracle.
pub fn apply_laplacian_global(p: &Problem, x: &[f32]) -> Vec<f64> {
    let (nx, ny, nz) = p.dims();
    assert_eq!(x.len(), nx * ny * nz);
    let at = |i: isize, j: isize, k: isize| -> f64 {
        if i < 0 || j < 0 || k < 0 || i >= nx as isize || j >= ny as isize || k >= nz as isize {
            0.0
        } else {
            x[p.global_index(i as usize, j as usize, k as usize)] as f64
        }
    };
    let mut out = vec![0.0f64; x.len()];
    for k in 0..nz as isize {
        for j in 0..ny as isize {
            for i in 0..nx as isize {
                out[p.global_index(i as usize, j as usize, k as usize)] = 6.0 * at(i, j, k)
                    - at(i - 1, j, k)
                    - at(i + 1, j, k)
                    - at(i, j - 1, k)
                    - at(i, j + 1, k)
                    - at(i, j, k - 1)
                    - at(i, j, k + 1);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_problem_dimensions() {
        // §7.2 / Table 3: 8×7 cores × 64 tiles = 512×112×64.
        let p = Problem::new(8, 7, 64, DataFormat::Bf16);
        assert_eq!(p.dims(), (512, 112, 64));
        assert_eq!(p.elems(), 3_670_016);
        assert_eq!(p.n_cores(), 56);
    }

    #[test]
    fn capacity_validation_matches_paper() {
        // 64 FP32 split fits; 65 does not. 164 BF16 fused fits; 165 not.
        assert!(Problem::new(8, 7, 64, DataFormat::Fp32).validate_capacity(false).is_ok());
        assert!(Problem::new(8, 7, 65, DataFormat::Fp32).validate_capacity(false).is_err());
        assert!(Problem::new(8, 7, 164, DataFormat::Bf16).validate_capacity(true).is_ok());
        assert!(Problem::new(8, 7, 165, DataFormat::Bf16).validate_capacity(true).is_err());
    }

    #[test]
    fn dist_global_roundtrip() {
        let p = Problem::new(2, 2, 3, DataFormat::Fp32);
        let v = dist_random(&p, 42);
        let g = dist_to_global(&p, &v);
        let v2 = dist_from_fn(&p, |i, j, k| g[p.global_index(i, j, k)]);
        assert_eq!(v, v2);
        // Eq. 1: x-fastest ordering.
        assert_eq!(p.global_index(1, 0, 0), 1);
        assert_eq!(p.global_index(0, 1, 0), 128); // Nx = 128
        assert_eq!(p.global_index(0, 0, 1), 128 * 32);
    }

    #[test]
    fn laplacian_of_constant_is_boundary_only() {
        let p = Problem::new(1, 1, 4, DataFormat::Fp32);
        let x = vec![1.0f32; p.elems()];
        let ax = apply_laplacian_global(&p, &x);
        // Deep interior: 6 - 6 neighbors = 0.
        assert_eq!(ax[p.global_index(30, 8, 2)], 0.0);
        // Corner: 6 - 3 interior neighbors = 3.
        assert_eq!(ax[p.global_index(0, 0, 0)], 3.0);
    }
}
