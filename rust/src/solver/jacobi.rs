//! The Jacobi preconditioner (§7): M = diag(A). For the hardcoded 7-point
//! Laplacian the diagonal is the constant stencil center coefficient, so
//! applying M⁻¹ is an element-wise scale by 1/6 — exactly how the paper's
//! proof-of-concept implements lines 2/13 of Algorithm 1.

use crate::engine::{ComputeEngine, CoreBlock, StencilCoeffs};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JacobiPreconditioner {
    pub inv_diag: f32,
}

impl JacobiPreconditioner {
    /// Build from the stencil coefficients: M = diag(A) = center.
    pub fn from_coeffs(c: StencilCoeffs) -> crate::Result<Self> {
        if c.center == 0.0 {
            return Err(crate::SimError::BadProblem {
                what: "Jacobi preconditioner needs a nonzero diagonal".to_string(),
            });
        }
        Ok(Self {
            inv_diag: 1.0 / c.center,
        })
    }

    /// z = M⁻¹ r (per core).
    pub fn apply(&self, engine: &dyn ComputeEngine, r: &CoreBlock) -> crate::Result<CoreBlock> {
        engine.scale(r, self.inv_diag)
    }

    /// Identity preconditioner (plain CG) for ablations.
    pub fn identity() -> Self {
        Self { inv_diag: 1.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DataFormat;
    use crate::engine::NativeEngine;

    #[test]
    fn scales_by_inverse_diagonal() {
        let p = JacobiPreconditioner::from_coeffs(StencilCoeffs::LAPLACIAN).unwrap();
        assert!((p.inv_diag - 1.0 / 6.0).abs() < 1e-7);
        let e = NativeEngine::new();
        let r = CoreBlock::from_fn(DataFormat::Fp32, 2, |_, _, _| 12.0);
        let z = p.apply(&e, &r).unwrap();
        assert!((z.get(0, 0, 0) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn zero_diagonal_rejected() {
        let mut c = StencilCoeffs::LAPLACIAN;
        c.center = 0.0;
        assert!(JacobiPreconditioner::from_coeffs(c).is_err());
    }

    #[test]
    fn identity_is_noop() {
        let p = JacobiPreconditioner::identity();
        let e = NativeEngine::new();
        let r = CoreBlock::from_fn(DataFormat::Fp32, 1, |_, x, y| (x + y) as f32);
        let z = p.apply(&e, &r).unwrap();
        assert_eq!(z, r);
    }
}
