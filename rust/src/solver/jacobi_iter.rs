//! The Jacobi *iterative method* as a standalone solver:
//! u ← u + ω·D⁻¹(b − A·u).
//!
//! This is the algorithm Brown & Barton ran on Grayskull (§2) — the
//! predecessor work this paper extends. Implementing it on the same
//! kernels lets us regenerate the paper's implicit comparison: PCG
//! converges in far fewer iterations than Jacobi on the same Poisson
//! problem, at a similar per-iteration cost (both are SpMV-dominated), and
//! unlike the 2D Grayskull study ours exercises the full 3D stencil.

use crate::device::TensixGrid;
use crate::engine::{ComputeEngine, StencilCoeffs};
use crate::kernels::eltwise::block_op_ns;
use crate::kernels::reduction::{run_dot, DotConfig, DotMethod};
use crate::kernels::stencil::{run_stencil, StencilConfig, StencilVariant};
use crate::noc::RoutePattern;
use crate::profiler::Breakdown;
use crate::solver::problem::{dist_zeros, DistVector, Problem};
use crate::timing::cost::{CostModel, PipelineMode, TileOpKind};
use crate::timing::SimNs;

#[derive(Debug, Clone)]
pub struct JacobiOptions {
    pub max_iters: usize,
    /// Absolute residual threshold (§3.3 recommends absolute).
    pub tol_abs: f64,
    /// Damping factor ω (1.0 = classical Jacobi; 2/3 is the usual damped
    /// choice for the 3D Laplacian's smoother role).
    pub omega: f32,
    /// Compute the residual norm every `check_every` iterations (the norm
    /// costs a global reduction; Jacobi itself needs none — its only
    /// communication is the halo exchange, which is why Brown & Barton
    /// could run it without collectives).
    pub check_every: usize,
}

impl Default for JacobiOptions {
    fn default() -> Self {
        Self {
            max_iters: 1000,
            tol_abs: 1e-4,
            omega: 1.0,
            check_every: 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct JacobiResult {
    pub u: DistVector,
    pub iters: usize,
    pub converged: bool,
    pub residual_history: Vec<(usize, f64)>,
    pub total_ns: SimNs,
    pub per_iter_ns: SimNs,
    pub breakdown: Breakdown,
}

/// Solve `A u = b` with damped Jacobi on the distributed stencil operator.
pub fn solve_jacobi(
    grid: &TensixGrid,
    problem: &Problem,
    b: &DistVector,
    engine: &dyn ComputeEngine,
    cost: &CostModel,
    opts: &JacobiOptions,
) -> crate::Result<JacobiResult> {
    let df = problem.df;
    let unit = crate::arch::ComputeUnit::for_format(df);
    let tiles = problem.tiles_per_core;
    let stencil_cfg = StencilConfig {
        df,
        unit,
        tiles_per_core: tiles,
        variant: StencilVariant::FULL,
        coeffs: StencilCoeffs::LAPLACIAN,
    };
    let dot_cfg = DotConfig {
        method: DotMethod::ReduceThenSend,
        pattern: RoutePattern::Naive,
        df,
        unit,
        tiles_per_core: tiles,
    };
    // ω/diag scaling factor for the update u += scale * r.
    let inv_diag_omega = opts.omega / StencilCoeffs::LAPLACIAN.center;
    let axpy_ns = block_op_ns(cost, unit, df, TileOpKind::EltwiseBinary, tiles, PipelineMode::Streamed);

    let mut u = dist_zeros(problem);
    let mut breakdown = Breakdown::new();
    let mut now: SimNs = 0.0;
    let mut history = Vec::new();
    let mut iters = 0;
    let mut converged = false;

    while iters < opts.max_iters {
        iters += 1;
        // r = b - A u  (one stencil + one axpy sweep).
        let (au, spmv_t) = run_stencil(grid, &stencil_cfg, &u, engine, cost)?;
        breakdown.add("spmv", spmv_t.iter_ns);
        now += spmv_t.iter_ns;
        let mut r: DistVector = b.to_vec();
        for (ri, aui) in r.iter_mut().zip(&au) {
            engine.axpy_into(ri, -1.0, aui)?;
        }
        breakdown.add("axpy", axpy_ns);
        now += axpy_ns;

        // u += (ω/D) r.
        for (ui, ri) in u.iter_mut().zip(&r) {
            engine.axpy_into(ui, inv_diag_omega, ri)?;
        }
        breakdown.add("axpy", axpy_ns);
        now += axpy_ns;

        // Periodic residual norm (global reduction).
        if iters % opts.check_every == 0 {
            let rr = run_dot(grid.rows, grid.cols, &dot_cfg, &r, &r, engine, cost)?;
            breakdown.add("norm", rr.total_ns);
            now += rr.total_ns;
            let rnorm = (rr.value.max(0.0) as f64).sqrt();
            history.push((iters, rnorm));
            if rnorm <= opts.tol_abs {
                converged = true;
                break;
            }
        }
    }

    breakdown.iterations = iters as u64;
    Ok(JacobiResult {
        u,
        iters,
        converged,
        residual_history: history,
        total_ns: now,
        per_iter_ns: if iters > 0 { now / iters as f64 } else { 0.0 },
        breakdown,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DataFormat;
    use crate::engine::NativeEngine;
    use crate::solver::problem::dist_random;

    #[test]
    fn jacobi_converges_on_spd_problem() {
        // The 7-pt Laplacian with Dirichlet walls is strictly diagonally
        // dominant at boundary-adjacent points and irreducible — Jacobi
        // converges (slowly).
        let p = Problem::new(2, 2, 3, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dist_random(&p, 3);
        let opts = JacobiOptions {
            max_iters: 3000,
            tol_abs: 1e-2,
            omega: 1.0,
            check_every: 10,
        };
        let res = solve_jacobi(&grid, &p, &b, &e, &cost, &opts).unwrap();
        assert!(res.converged, "history tail {:?}", res.residual_history.last());
        // Monotone-ish decrease.
        let first = res.residual_history.first().unwrap().1;
        let last = res.residual_history.last().unwrap().1;
        assert!(last < 0.01 * first);
    }

    #[test]
    fn pcg_needs_far_fewer_iterations_than_jacobi() {
        // The headline reason the paper implements CG rather than Jacobi
        // (and the advance over Brown & Barton, §2).
        use crate::profiler::Profiler;
        use crate::solver::pcg::{solve, PcgOptions, PcgVariant};
        let p = Problem::new(2, 2, 3, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dist_random(&p, 4);
        let tol = 5e-3;

        let jopts = JacobiOptions {
            max_iters: 5000,
            tol_abs: tol,
            omega: 1.0,
            check_every: 5,
        };
        let jac = solve_jacobi(&grid, &p, &b, &e, &cost, &jopts).unwrap();

        let mut popts = PcgOptions::new(PcgVariant::SplitFp32);
        popts.max_iters = 500;
        popts.tol_abs = tol;
        let mut prof = Profiler::disabled();
        let pcg = solve(&grid, &p, &b, &e, &cost, &popts, &mut prof).unwrap();

        assert!(jac.converged && pcg.converged);
        assert!(
            pcg.iters * 3 < jac.iters,
            "PCG {} iters vs Jacobi {}",
            pcg.iters,
            jac.iters
        );
    }

    #[test]
    fn check_every_reduces_reduction_cost() {
        let p = Problem::new(2, 2, 2, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dist_random(&p, 5);
        let mk = |every: usize| JacobiOptions {
            max_iters: 50,
            tol_abs: 0.0,
            omega: 1.0,
            check_every: every,
        };
        let each = solve_jacobi(&grid, &p, &b, &e, &cost, &mk(1)).unwrap();
        let sparse = solve_jacobi(&grid, &p, &b, &e, &cost, &mk(10)).unwrap();
        assert!(sparse.breakdown.get("norm") < each.breakdown.get("norm") / 5.0);
        assert!(sparse.total_ns < each.total_ns);
    }
}
