//! The preconditioned conjugate-gradient solver (§7): the model problem,
//! the Jacobi preconditioner, and the fused-BF16 / split-FP32 PCG drivers
//! composed from the numerical kernels. The matrix apply is abstracted
//! behind [`pcg::Operator`] — the matrix-free stencil and the general
//! sparse SpMV are interchangeable implementors. [`mesh`] distributes the
//! same solve over an N-die [`crate::device::DeviceMesh`] (the old
//! dual-die solver is its N=2 wrapper).

pub mod dualdie;
pub mod jacobi;
pub mod jacobi_iter;
pub mod mesh;
pub mod pcg;
pub mod problem;
pub mod resilient;
pub mod sstep;

pub use jacobi::JacobiPreconditioner;
pub use jacobi_iter::{solve_jacobi, JacobiOptions, JacobiResult};
pub use dualdie::{solve_pcg_dualdie, DualDieOptions, DualDieResult, EthLink};
pub use mesh::{
    mesh_dist_random, solve_pcg_mesh, MeshOptions, MeshPcgResult, MeshPhaseBreakdown,
};
pub use resilient::{checkpoint_cost, Checkpoint, FaultRuntime, ResilienceOptions};
pub use crate::ttm::{OverlapMode, Schedule};
pub use pcg::{solve, solve_operator, FusionMode, Operator, PcgOptions, PcgResult, PcgVariant};
pub use problem::{
    apply_laplacian_global, dist_from_fn, dist_random, dist_to_global, dist_zeros, DistVector,
    Problem,
};
