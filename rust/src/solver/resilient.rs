//! Solver-level resilience for faulted mesh solves (ISSUE 10): periodic
//! checkpoints of the PCG loop-carried state, residual-recompute SDC
//! detection, rollback-restart, and the fault-epoch runtime that
//! re-lowers components onto the degraded topology.
//!
//! The division of labor with [`crate::device::faults`]: the fault plan
//! is pure data and the device layer knows how to route around damage;
//! this module owns the *solver's* reaction — when to save state, how
//! much saving costs, when a silent corruption is detectable, and what
//! a fault epoch does to the pre-executed component outcomes.
//!
//! **Checkpoint contents and cost.** The classic PCG loop carries
//! exactly (x, r, p, δ) across iterations — z is recomputed from r
//! every iteration — so a checkpoint is those three vectors plus one
//! scalar: O(rows) bytes. Each die drains its shard to DRAM
//! ([`crate::timing::cost::CostModel::dram_stream_cycles`]) and mirrors
//! it to a neighbor over one Ethernet hop (so the state survives that
//! die's loss); [`checkpoint_cost`] prices both and the solver charges
//! them as explicit `checkpoint` / `rollback` ledger components.
//!
//! **SDC detection.** Every `check_interval` iterations the solver
//! recomputes the *true* residual ‖b − Ax‖ through the engine and
//! compares it to the recurrence residual ‖r‖. In a clean run the two
//! drift apart only by rounding; a corrupted q propagates into x and r
//! with a magnitude (≈1e3, [`crate::device::FaultPlan::sdc_magnitude`])
//! that blows the relative drift past any rounding envelope, so a 50%
//! threshold separates them cleanly. Checkpoints are only taken at
//! iterations that *pass* the check — a verified-state discipline that
//! guarantees rollback targets are uncorrupted.
//!
//! **Fault epochs.** At each iteration boundary the runtime samples
//! [`crate::device::FaultPlan::state_at`]; when the state changes it
//! re-lowers: surviving dies' programs re-execute with the degraded
//! per-link [`crate::device::EthSim`] factors, Ethernet phases are
//! [`crate::ttm::EtherPhase::remapped`] around dead dies and
//! [`crate::ttm::EtherPhase::rerouted`] around cut links, and each dead
//! die's subdomain is adopted by its nearest surviving neighbor (the
//! adopter's local work scales by the adopted count —
//! `scale_program`). The re-executed outcomes override the clean ones
//! until the state changes again, so charged times, ledgers, and span
//! graphs stay honest executions, never estimates — which is what keeps
//! the critical path wall-exact under every fault scenario
//! (`tests/prop_faults.rs`).

use std::collections::{BTreeMap, BTreeSet};

use crate::arch::constants::cycles_to_ns;
use crate::arch::DataFormat;
use crate::device::{DeviceMesh, EthSim, FaultPlan, FaultState};
use crate::solver::mesh::{scale_program, MeshLowering};
use crate::solver::problem::DistVector;
use crate::telemetry::{Resource, ResourceLedger};
use crate::timing::cost::CostModel;
use crate::timing::SimNs;
use crate::ttm::{Program, ProgramOutcome};

/// Checkpoint/rollback policy of a resilient solve.
#[derive(Debug, Clone, PartialEq)]
pub struct ResilienceOptions {
    /// Save (x, r, p, δ) every this many iterations; 0 disables
    /// checkpointing (and with it SDC detection and rollback).
    pub checkpoint_interval: usize,
    /// Recompute the true residual ‖b − Ax‖ every this many iterations
    /// and compare against the recurrence residual.
    pub check_interval: usize,
    /// Relative drift |true − recurrence| / max(true, recurrence) above
    /// which the trajectory is declared corrupted. Clean-run drift is
    /// rounding-scale; an SDC's is orders of magnitude — 0.5 separates
    /// them with huge margin on both sides.
    pub sdc_threshold: f64,
}

impl Default for ResilienceOptions {
    fn default() -> Self {
        Self::every(8)
    }
}

impl ResilienceOptions {
    /// Checkpoint and check every `k` iterations (`k = 0` disables both).
    pub fn every(k: usize) -> Self {
        Self {
            checkpoint_interval: k,
            check_interval: k.max(1),
            sdc_threshold: 0.5,
        }
    }

    /// No checkpoints, no checks — the k=0 baseline of the overhead sweep.
    pub fn disabled() -> Self {
        Self::every(0)
    }

    pub fn enabled(&self) -> bool {
        self.checkpoint_interval > 0
    }
}

/// One saved PCG state: everything the classic loop carries across an
/// iteration boundary.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub x: DistVector,
    pub r: DistVector,
    pub p: DistVector,
    pub delta: f64,
    /// Iteration the state was saved at (0 = before the first).
    pub iter: usize,
}

/// Price one checkpoint save (or rollback restore — same bytes, same
/// wires): each die drains its shard of the three state vectors to DRAM
/// and mirrors it one Ethernet hop to a neighbor. Returns the
/// per-resource ledger and its total, which the solver charges as an
/// explicit `checkpoint` / `rollback` component.
pub fn checkpoint_cost(
    mesh: &DeviceMesh,
    tiles: usize,
    df: DataFormat,
    cost: &CostModel,
) -> (ResourceLedger, SimNs) {
    let state_bytes = 3u64 * mesh.n_cores() as u64 * tiles as u64 * df.tile_bytes() as u64;
    let per_die = state_bytes / mesh.n_dies.max(1) as u64;
    let dram_ns = cycles_to_ns(cost.dram_stream_cycles(per_die));
    let eth_ns = if mesh.n_dies > 1 {
        mesh.link.transfer_ns(per_die)
    } else {
        0.0
    };
    let mut l = ResourceLedger::new();
    l.add(Resource::Dram, dram_ns);
    l.add(Resource::Ethernet, eth_ns);
    (l, dram_ns + eth_ns)
}

/// What one fault-epoch transition asks the solver to do: annotate the
/// event stream, charge the transport's retry-with-backoff penalty, and
/// (on die loss) roll back to the last checkpoint.
#[derive(Debug, Clone)]
pub struct EpochChange {
    /// Joined event annotations for the telemetry stream
    /// (`"die_down:3;link_down:0-1"`).
    pub annotation: String,
    /// Detection-timeout + bounded-retry penalty for links that went
    /// down with traffic in flight ([`FaultPlan::retry_penalty_ns`]).
    pub retry_ns: SimNs,
    /// A die was lost this epoch — state on it is gone; the solver must
    /// resume from the last checkpoint on the survivors.
    pub die_lost: bool,
}

/// The per-solve fault runtime: samples the plan at iteration
/// boundaries, rebuilds component outcomes on each epoch, and owns the
/// checkpoint/rollback state machine.
pub struct FaultRuntime {
    pub plan: FaultPlan,
    pub resilience: ResilienceOptions,
    /// Rollbacks performed (die loss + detected SDCs).
    pub rollbacks: u64,
    /// Fault-state transitions seen (also the retry PRNG draw index).
    pub epoch: u64,
    mesh: DeviceMesh,
    spmv_per_die: Vec<Program>,
    support: BTreeMap<String, Program>,
    state: FaultState,
    overrides: BTreeMap<String, ProgramOutcome>,
    checkpoint: Option<Checkpoint>,
}

impl FaultRuntime {
    /// Build from the clean lowering (the programs are cloned so epochs
    /// can re-derive faulted variants from pristine ones).
    pub fn new(
        plan: FaultPlan,
        resilience: ResilienceOptions,
        mesh: &DeviceMesh,
        lowering: &MeshLowering,
    ) -> Self {
        let support = lowering
            .components
            .iter()
            .filter(|p| p.name != "spmv")
            .map(|p| (p.name.clone(), p.clone()))
            .collect();
        Self {
            plan,
            resilience,
            rollbacks: 0,
            epoch: 0,
            mesh: mesh.clone(),
            spmv_per_die: lowering.spmv_per_die.clone(),
            support,
            state: FaultState::default(),
            overrides: BTreeMap::new(),
            checkpoint: None,
        }
    }

    /// The faulted outcome for a component, if the current epoch
    /// overrides the clean pre-executed one.
    pub fn outcome(&self, key: &str) -> Option<&ProgramOutcome> {
        self.overrides.get(key)
    }

    pub fn checkpoint_enabled(&self) -> bool {
        self.resilience.enabled()
    }

    /// Whether iteration `iter` ends with a checkpoint save.
    pub fn checkpoint_due(&self, iter: usize) -> bool {
        self.checkpoint_enabled() && iter % self.resilience.checkpoint_interval == 0
    }

    /// Whether iteration `iter` ends with a true-residual SDC check.
    pub fn check_due(&self, iter: usize) -> bool {
        self.checkpoint_enabled() && iter % self.resilience.check_interval == 0
    }

    /// Save the loop-carried state (clones — the solver keeps working on
    /// its own copies).
    pub fn save(&mut self, x: &DistVector, r: &DistVector, p: &DistVector, delta: f64, iter: usize) {
        self.checkpoint = Some(Checkpoint {
            x: x.clone(),
            r: r.clone(),
            p: p.clone(),
            delta,
            iter,
        });
    }

    /// Take the last checkpoint for a restore (counted as a rollback).
    /// `None` when checkpointing is disabled — the solver then keeps its
    /// current iterate and continues.
    pub fn rollback(&mut self) -> Option<Checkpoint> {
        let cp = self.checkpoint.clone();
        if cp.is_some() {
            self.rollbacks += 1;
        }
        cp
    }

    /// Corrupt the spmv output `q` if the plan scripts an SDC at this
    /// (1-based) iteration; returns the event annotation. Deterministic:
    /// block `iter % len`, element (0,0,0), additive
    /// [`FaultPlan::sdc_magnitude`].
    pub fn maybe_corrupt(&self, q: &mut DistVector, iter: usize) -> Option<String> {
        if !self.plan.sdc_at("spmv", iter) || q.is_empty() {
            return None;
        }
        let blk = &mut q[iter % q.len()];
        if blk.nz() == 0 {
            return None;
        }
        let v = blk.get(0, 0, 0);
        blk.set(0, 0, 0, v + self.plan.sdc_magnitude(iter));
        Some(format!("sdc:spmv@{iter}"))
    }

    /// Sample the plan at `now`; on a fault-state change, rebuild the
    /// component overrides for the new topology and return what the
    /// solver must charge/do. `None` while the state is unchanged (the
    /// overwhelmingly common case — one `state_at` scan per iteration).
    pub fn begin_iteration(
        &mut self,
        now: SimNs,
        cost: &CostModel,
    ) -> crate::Result<Option<EpochChange>> {
        if self.plan.is_empty() {
            return Ok(None);
        }
        let new = self.plan.state_at(&self.mesh, now);
        if new == self.state {
            return Ok(None);
        }
        let mut notes: Vec<String> = Vec::new();
        for d in new.down_dies.difference(&self.state.down_dies) {
            notes.push(format!("die_down:{d}"));
        }
        let new_links: Vec<(usize, usize)> = new
            .down_links
            .difference(&self.state.down_links)
            .copied()
            .collect();
        for (a, b) in &new_links {
            // Links that died *with* their die are folded into its note.
            if !new.down_dies.contains(a) && !new.down_dies.contains(b) {
                notes.push(format!("link_down:{a}-{b}"));
            }
        }
        for (l, f) in &new.slowdown {
            if !self.state.slowdown.contains(&(*l, *f)) {
                notes.push(format!("link_degrade:{}-{}x{}", l.0, l.1, f));
            }
        }
        if notes.is_empty() {
            // A degradation window closed (or a cut was superseded by a
            // die loss): the topology still re-lowers, silently faster.
            notes.push("fault_cleared".to_string());
        }
        let retry_ns = if new_links.is_empty() {
            0.0
        } else {
            self.plan.retry_penalty_ns(new_links.len(), self.epoch)
        };
        let die_lost = new.down_dies.len() > self.state.down_dies.len();
        self.rebuild(&new, cost)?;
        self.epoch += 1;
        self.state = new;
        Ok(Some(EpochChange {
            annotation: notes.join(";"),
            retry_ns,
            die_lost,
        }))
    }

    /// Re-lower + re-execute the components affected by `state`. Every
    /// override is a real execution on the degraded topology — the
    /// timing model's honesty invariant.
    fn rebuild(&mut self, state: &FaultState, cost: &CostModel) -> crate::Result<()> {
        self.overrides.clear();
        if state.is_clean() {
            return Ok(());
        }
        let down: Vec<(usize, usize)> = state.down_links.iter().copied().collect();
        let fmesh = self.mesh.with_down_links(&down);
        let survivors: BTreeSet<usize> = (0..self.mesh.n_dies)
            .filter(|d| !state.down_dies.contains(d))
            .collect();
        if survivors.is_empty() {
            return Err(crate::SimError::Other(
                "fault plan takes every die down — nothing left to solve on".to_string(),
            ));
        }
        if !fmesh.survivors_connected(&survivors) {
            return Err(crate::SimError::Other(format!(
                "fault plan disconnects the mesh: down links {:?} split the surviving dies {:?}",
                state.down_links, survivors
            )));
        }
        // Each dead die's subdomain migrates to its nearest surviving
        // neighbor (clean-topology hop count, ties to the lowest id).
        let mut adopt: BTreeMap<usize, usize> = BTreeMap::new();
        for &d in &state.down_dies {
            let adopter = survivors
                .iter()
                .copied()
                .min_by_key(|&s| (self.mesh.path(d, s).len(), s))
                .expect("survivors is nonempty");
            adopt.insert(d, adopter);
        }
        let mut load: BTreeMap<usize, u64> = BTreeMap::new();
        for &a in adopt.values() {
            *load.entry(a).or_insert(0) += 1;
        }
        let max_extra = load.values().copied().max().unwrap_or(0);

        let exec = |p: &Program| -> crate::Result<ProgramOutcome> {
            // Fresh per-program link tracker seeded with the epoch's
            // degradation factors — device start 0.0, like the clean
            // pre-executions, so span graphs graft identically.
            let mut sim = EthSim::new();
            sim.set_slowdown(&state.slowdown);
            crate::ttm::exec::execute_program_with(p, cost, 0.0, Some(&mut sim))
        };
        let transform = |e: &Option<crate::ttm::EtherPhase>| -> Option<crate::ttm::EtherPhase> {
            e.as_ref()
                .and_then(|e| e.remapped(&adopt))
                .map(|e| e.rerouted(&fmesh))
        };

        // spmv: every surviving die re-executes (adopters with their
        // adopted load folded in); the component binds on the slowest.
        let mut slowest: Option<ProgramOutcome> = None;
        for (d, p0) in self.spmv_per_die.iter().enumerate() {
            if state.down_dies.contains(&d) {
                continue;
            }
            let extra = load.get(&d).copied().unwrap_or(0);
            let mut p = if extra > 0 {
                scale_program(p0.clone(), 1 + extra)
            } else {
                p0.clone()
            };
            p.work.ether = transform(&p0.work.ether);
            p.footprint.eth_bytes = p.work.ether.as_ref().map_or(0, |e| e.bytes());
            let out = exec(&p)?;
            if slowest
                .as_ref()
                .map_or(true, |s| out.device_ns() > s.device_ns())
            {
                slowest = Some(out);
            }
        }
        let slowest = slowest.ok_or_else(|| {
            crate::SimError::Other("faulted spmv re-lowering produced no programs".to_string())
        })?;
        self.overrides.insert("spmv".to_string(), slowest);

        // dot/norm: the local fold binds on the most-loaded adopter, and
        // the all-reduce phase remaps/reroutes like the halo.
        for name in ["dot", "norm"] {
            let Some(p0) = self.support.get(name) else {
                continue;
            };
            let mut p = if max_extra > 0 {
                scale_program(p0.clone(), 1 + max_extra)
            } else {
                p0.clone()
            };
            p.work.ether = transform(&p0.work.ether);
            p.footprint.eth_bytes = p.work.ether.as_ref().map_or(0, |e| e.bytes());
            self.overrides.insert(name.to_string(), exec(&p)?);
        }
        // axpy/precond carry no Ethernet phase — they only change when
        // work migrated onto an adopter.
        if max_extra > 0 {
            for name in ["axpy", "precond"] {
                let Some(p0) = self.support.get(name) else {
                    continue;
                };
                let p = scale_program(p0.clone(), 1 + max_extra);
                self.overrides.insert(name.to_string(), exec(&p)?);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{EthLink, MeshTopology};
    use crate::engine::StencilCoeffs;
    use crate::kernels::stencil::{StencilConfig, StencilVariant};
    use crate::solver::mesh::{lower_mesh_components, MeshOptions};
    use crate::solver::pcg::{Operator, PcgOptions, PcgVariant};
    use crate::timing::cost::TileOpKind;

    fn runtime_on(plan: &str, n_dies: usize) -> FaultRuntime {
        let mesh = DeviceMesh::new(
            n_dies,
            1,
            2,
            MeshTopology::Torus2D { rows: 2, cols: n_dies / 2 },
            EthLink::default(),
        )
        .unwrap();
        let cfg = StencilConfig {
            df: DataFormat::Bf16,
            unit: crate::arch::ComputeUnit::Fpu,
            tiles_per_core: 2,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let opts = MeshOptions::new(PcgOptions::new(PcgVariant::FusedBf16));
        let lowering = lower_mesh_components(
            &mesh,
            &Operator::Stencil(cfg),
            &opts,
            2,
            TileOpKind::EltwiseUnary,
            &CostModel::default(),
        )
        .unwrap();
        FaultRuntime::new(
            FaultPlan::parse(plan).unwrap(),
            ResilienceOptions::default(),
            &mesh,
            &lowering,
        )
    }

    #[test]
    fn checkpoint_cost_scales_and_prices_both_wires() {
        let cost = CostModel::default();
        let mesh = DeviceMesh::new(4, 1, 2, MeshTopology::Line, EthLink::default()).unwrap();
        let (l, ns) = checkpoint_cost(&mesh, 2, DataFormat::Bf16, &cost);
        assert!(ns > 0.0);
        assert!((l.total() - ns).abs() < 1e-9, "ledger covers the charge exactly");
        let rows: Vec<Resource> = l.rows().map(|(r, _)| r).collect();
        assert!(rows.contains(&Resource::Dram) && rows.contains(&Resource::Ethernet));
        // More tiles per core => strictly more state to drain.
        let (_, ns4) = checkpoint_cost(&mesh, 4, DataFormat::Bf16, &cost);
        assert!(ns4 > ns);
        // A single die mirrors nowhere: DRAM only.
        let single = DeviceMesh::n150(1, 1).unwrap();
        let (l1, _) = checkpoint_cost(&single, 2, DataFormat::Bf16, &cost);
        assert!(l1.rows().all(|(r, _)| r == Resource::Dram));
    }

    #[test]
    fn epoch_rebuilds_overrides_and_charges_retry_once() {
        let cost = CostModel::default();
        let mut f = runtime_on("link_down:0-1@5us", 4);
        // Before the cut fires: no change.
        assert!(f.begin_iteration(0.0, &cost).unwrap().is_none());
        assert!(f.outcome("spmv").is_none());
        // At the cut: one epoch, a retry penalty, rerouted spmv/dot/norm.
        let ch = f.begin_iteration(6_000.0, &cost).unwrap().unwrap();
        assert_eq!(ch.annotation, "link_down:0-1");
        assert!(ch.retry_ns > 0.0);
        assert!(!ch.die_lost);
        assert!(f.outcome("spmv").is_some() && f.outcome("dot").is_some());
        // No migration => axpy/precond keep their clean outcomes.
        assert!(f.outcome("axpy").is_none());
        // Same state again: no new epoch.
        assert!(f.begin_iteration(7_000.0, &cost).unwrap().is_none());
        assert_eq!(f.epoch, 1);
    }

    #[test]
    fn die_loss_migrates_work_and_slows_every_component() {
        let cost = CostModel::default();
        let mut f = runtime_on("die_down:3@1us", 4);
        let clean_ns = {
            let mut g = runtime_on("", 4);
            assert!(g.begin_iteration(10.0, &cost).unwrap().is_none());
            // Clean runtime never overrides — compare against the epoch'd
            // runtime's own pristine programs through one manual exec.
            let mut sim = EthSim::new();
            crate::ttm::exec::execute_program_with(&g.spmv_per_die[0], &cost, 0.0, Some(&mut sim))
                .unwrap()
                .device_ns()
        };
        let ch = f.begin_iteration(2_000.0, &cost).unwrap().unwrap();
        assert!(ch.die_lost);
        assert!(ch.annotation.contains("die_down:3"));
        // The adopter carries two subdomains: spmv, axpy, and precond all
        // re-lowered, and the bound spmv is strictly slower than clean.
        for c in ["spmv", "dot", "norm", "axpy", "precond"] {
            assert!(f.outcome(c).is_some(), "{c} should be overridden after die loss");
        }
        assert!(f.outcome("spmv").unwrap().device_ns() > clean_ns);
    }

    #[test]
    fn disconnecting_plan_is_a_descriptive_error() {
        let cost = CostModel::default();
        // Cutting every link of die 0 without killing it strands it.
        let mut f = runtime_on("link_down:0-1@1;link_down:0-2@1;link_down:0-3@1", 4);
        let e = f.begin_iteration(10.0, &cost).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("disconnect"), "got: {msg}");
    }

    #[test]
    fn sdc_corruption_is_deterministic_and_targeted() {
        let f = runtime_on("sdc:spmv@3", 4);
        let blocks = 8;
        let mk = || -> DistVector {
            (0..blocks)
                .map(|_| crate::engine::CoreBlock::zeros(DataFormat::Bf16, 2))
                .collect()
        };
        let mut q1 = mk();
        let mut q2 = mk();
        assert!(f.maybe_corrupt(&mut q1, 2).is_none(), "wrong iteration: untouched");
        assert_eq!(q1, mk());
        let n1 = f.maybe_corrupt(&mut q1, 3).unwrap();
        let n2 = f.maybe_corrupt(&mut q2, 3).unwrap();
        assert_eq!(n1, "sdc:spmv@3");
        assert_eq!(n2, n1);
        assert_eq!(q1, q2, "same plan + seed => same corrupted bits");
        assert!(q1[3 % blocks].get(0, 0, 0).abs() >= 1.0e3);
    }

    #[test]
    fn rollback_returns_only_verified_checkpoints() {
        let mut f = runtime_on("", 4);
        assert!(f.rollback().is_none(), "no checkpoint yet");
        assert_eq!(f.rollbacks, 0, "a missing checkpoint is not a rollback");
        let v: DistVector = vec![crate::engine::CoreBlock::zeros(DataFormat::Bf16, 1)];
        f.save(&v, &v, &v, 0.25, 8);
        let cp = f.rollback().unwrap();
        assert_eq!(cp.iter, 8);
        assert_eq!(cp.delta, 0.25);
        assert_eq!(f.rollbacks, 1);
        // Intervals: due at multiples of k only.
        assert!(f.checkpoint_due(8) && !f.checkpoint_due(9));
        assert!(f.check_due(16));
        assert!(!ResilienceOptions::disabled().enabled());
    }
}
