//! Multi-device scaling (§8 future work): PCG across both Tensix dies of
//! the n300d.
//!
//! The n300d carries two Wormhole dies; §7.2 evaluates one ("future work
//! will explore full utilization of the n300d"). Dies connect over on-board
//! Ethernet links (the §3 die grid dedicates cells to Ethernet
//! management). We extend the solver across two dies by stacking the
//! domain along x: die 0 owns the top `rows×cols` core grid, die 1 the
//! bottom, and the seam between them exchanges halos over Ethernet instead
//! of the NoC. Global reductions reduce per-die, then combine + broadcast
//! the scalar across the link.
//!
//! Values are exact (the seam halos are stitched from the neighbor die's
//! blocks); timing adds the Ethernet seam costs to the per-die NoC/compute
//! times.

use crate::arch::constants::{SRAM_BYTES, SRAM_RESERVE_FUSED};
use crate::arch::DataFormat;
use crate::device::TensixGrid;
use crate::engine::{ComputeEngine, CoreBlock, Halos, StencilCoeffs};
use crate::kernels::eltwise::block_op_ns;
use crate::kernels::reduction::{run_dot, DotConfig, DotMethod};
use crate::kernels::stencil::{StencilConfig, StencilVariant};
use crate::noc::RoutePattern;
use crate::profiler::{Breakdown, Profiler};
use crate::solver::problem::Problem;
use crate::timing::cost::CostModel;
use crate::timing::SimNs;
use crate::ttm::{HostQueue, IterSchedule};

/// On-board Ethernet link between the two dies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EthLink {
    /// One-way message latency, ns (Ethernet MAC + SerDes; orders of
    /// magnitude above a NoC hop).
    pub latency_ns: f64,
    /// Usable bandwidth, GB/s (2×100 GbE per die pair ≈ 25 GB/s raw; we
    /// default to one link's usable rate).
    pub bw_gbs: f64,
}

impl Default for EthLink {
    fn default() -> Self {
        Self {
            latency_ns: 800.0,
            bw_gbs: 11.0,
        }
    }
}

impl EthLink {
    /// Transfer time for `bytes` over the link.
    pub fn transfer_ns(&self, bytes: u64) -> f64 {
        self.latency_ns + bytes as f64 / self.bw_gbs
    }
}

#[derive(Debug, Clone)]
pub struct DualDieOptions {
    pub max_iters: usize,
    pub tol_abs: f64,
    pub eth: EthLink,
}

impl Default for DualDieOptions {
    fn default() -> Self {
        Self {
            max_iters: 50,
            tol_abs: 1e-4,
            eth: EthLink::default(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct DualDieResult {
    pub iters: usize,
    pub converged: bool,
    pub residual_history: Vec<f64>,
    pub per_iter_ns: SimNs,
    pub total_ns: SimNs,
    /// Per-iteration Ethernet seam cost (halo + reduction combine).
    pub eth_ns_per_iter: SimNs,
    pub breakdown: Breakdown,
    /// Scheduler-derived launch accounting (one enqueue per solve).
    pub launch: crate::ttm::LaunchStats,
}

/// A logical dual-die distributed vector: blocks for die 0's rows×cols
/// cores followed by die 1's (row-major within each die).
pub type DualVector = Vec<CoreBlock>;

/// The distributed stencil over both dies: per-core halos gathered from
/// the (2·rows)×cols logical grid; the seam rows exchange across dies.
fn dual_stencil_values(
    rows: usize,
    cols: usize,
    nz: usize,
    x: &[CoreBlock],
    engine: &dyn ComputeEngine,
    coeffs: StencilCoeffs,
) -> crate::Result<Vec<CoreBlock>> {
    let total_rows = 2 * rows;
    assert_eq!(x.len(), total_rows * cols);
    let idx = |r: usize, c: usize| r * cols + c;
    let mut out = Vec::with_capacity(x.len());
    for r in 0..total_rows {
        for c in 0..cols {
            let nb = |dr: isize, dc: isize| -> Option<&CoreBlock> {
                let rr = r as isize + dr;
                let cc = c as isize + dc;
                if rr < 0 || cc < 0 || rr >= total_rows as isize || cc >= cols as isize {
                    None
                } else {
                    Some(&x[idx(rr as usize, cc as usize)])
                }
            };
            let halos = Halos::gather(nb(-1, 0), nb(1, 0), nb(0, -1), nb(0, 1));
            out.push(engine.stencil_apply(&x[idx(r, c)], &halos, coeffs)?);
        }
    }
    let _ = nz;
    Ok(out)
}

/// Per-iteration Ethernet seam bytes for the stencil halo: `cols` core
/// pairs each exchange one 16-element row per tile in both directions
/// (the seam is an x-boundary, so it is the cheap N/S row exchange — 32B
/// per tile at BF16).
fn seam_halo_bytes(cols: usize, nz: usize, df: DataFormat) -> u64 {
    2 * (cols as u64) * (nz as u64) * (16 * df.bytes()) as u64
}

/// Dual-die fused-BF16 PCG (values exact, timing = die-local + seam).
pub fn solve_pcg_dualdie(
    rows: usize,
    cols: usize,
    tiles: usize,
    b: &DualVector,
    engine: &dyn ComputeEngine,
    cost: &CostModel,
    opts: &DualDieOptions,
) -> crate::Result<DualDieResult> {
    let df = DataFormat::Bf16;
    let unit = crate::arch::ComputeUnit::Fpu;
    // Validate the per-die sub-grid + capacity with the single-die rules.
    let per_die = Problem::new(rows, cols, tiles, df);
    per_die.validate_capacity(true)?;
    let _ = TensixGrid::new(rows, cols)?;

    let n_blocks = 2 * rows * cols;
    assert_eq!(b.len(), n_blocks, "one block per core across both dies");
    let coeffs = StencilCoeffs::LAPLACIAN;

    // --- per-iteration timing: the same per-die component programs the
    // single-die fused PCG lowers, dispatched through one scheduler ------
    let stencil_cfg = StencilConfig {
        df,
        unit,
        tiles_per_core: tiles,
        variant: StencilVariant::FULL,
        coeffs,
    };
    // Die-local stencil: the single-die operator lowering over a per-die
    // grid (NoC halo schedule and outer-boundary zero fills included);
    // timing is data-independent, so one host-queue run covers every
    // iteration.
    let die_grid = TensixGrid::new(rows, cols)?;
    let stencil_prog = crate::solver::pcg::Operator::Stencil(stencil_cfg).lower(&die_grid, cost);
    let mut scratch = HostQueue::new(cost.calib.clone());
    let die_out = scratch.run(&stencil_prog, cost, 0.0, &mut Profiler::disabled())?;
    // Ethernet seam: halo bytes + one scalar combine + one broadcast per
    // global reduction. The seam exchange overlaps the NoC halo phase, so
    // the stencil takes whichever finishes later.
    let seam_halo_ns = opts.eth.transfer_ns(seam_halo_bytes(cols, tiles, df));
    let seam_scalar_ns = opts.eth.transfer_ns(32);
    let spmv_ns = die_out.device_ns().max(die_out.compute_ns + seam_halo_ns);

    let dot_cfg = DotConfig {
        method: DotMethod::ReduceThenSend,
        pattern: RoutePattern::Naive,
        df,
        unit,
        tiles_per_core: tiles,
    };
    let axpy_ns = block_op_ns(
        cost,
        unit,
        df,
        crate::timing::cost::TileOpKind::EltwiseBinary,
        tiles,
        crate::timing::cost::PipelineMode::Streamed,
    );
    let scale_ns = block_op_ns(
        cost,
        unit,
        df,
        crate::timing::cost::TileOpKind::EltwiseUnary,
        tiles,
        crate::timing::cost::PipelineMode::Streamed,
    );

    // The dual-die solve is the fused-BF16 variant (§7.1): its launch and
    // phase-gap accounting comes from the same scheduler — and the same
    // component programs and iteration order — as the single-die solver:
    // one enqueue per solve, a §7.3 device-side gap per boundary.
    let mut component_programs = vec![stencil_prog];
    component_programs.extend(crate::solver::pcg::lower_pcg_support_components(
        rows,
        cols,
        &dot_cfg,
        unit,
        df,
        tiles,
        crate::timing::cost::TileOpKind::EltwiseUnary,
        cost,
    ));
    let sched = IterSchedule::fused(
        "pcg_dualdie_fused",
        component_programs,
        &crate::solver::pcg::PCG_ITERATION,
        SRAM_BYTES - SRAM_RESERVE_FUSED,
    )?;
    let mut queue = HostQueue::new(cost.calib.clone());
    let mut prof = Profiler::disabled();

    // --- the solve (values on the logical 2R×C grid) --------------------
    let idx_all = |v: &DualVector| -> (Vec<CoreBlock>, Vec<CoreBlock>) {
        (v[..rows * cols].to_vec(), v[rows * cols..].to_vec())
    };
    let inv_diag = 1.0 / coeffs.center;
    let mut x: DualVector = (0..n_blocks).map(|_| CoreBlock::zeros(df, tiles)).collect();
    let mut r: DualVector = b.to_vec();
    let mut z: DualVector = r
        .iter()
        .map(|blk| engine.scale(blk, inv_diag))
        .collect::<crate::Result<_>>()?;
    let mut p = z.clone();

    // Distributed dot across both dies: per-die reduce + Ethernet combine.
    let dual_dot = |a: &DualVector,
                    bb: &DualVector,
                    engine: &dyn ComputeEngine,
                    cost: &CostModel|
     -> crate::Result<(f64, SimNs)> {
        let (a0, a1) = idx_all(a);
        let (b0, b1) = idx_all(bb);
        let d0 = run_dot(rows, cols, &dot_cfg, &a0, &b0, engine, cost)?;
        let d1 = run_dot(rows, cols, &dot_cfg, &a1, &b1, engine, cost)?;
        // Dies reduce concurrently; then one scalar hop + one broadcast.
        let t = d0.total_ns.max(d1.total_ns) + 2.0 * seam_scalar_ns;
        Ok((d0.value as f64 + d1.value as f64, t))
    };

    let mut breakdown = Breakdown::new();
    let mut now = 0.0f64;
    let mut eth_total = 0.0f64;
    let mut delta = {
        let (v, t) = dual_dot(&r, &z, engine, cost)?;
        now += t;
        v
    };
    // One enqueue for the whole dual-die solve; the §7.3 device-side
    // phase gaps come from the scheduler at every component boundary.
    now = sched.begin(&mut queue, now)?;
    macro_rules! component {
        ($name:expr, $ns:expr) => {{
            let ns: SimNs = $ns;
            now = sched.component(&mut queue, &mut prof, $name, ns, now)?;
            breakdown.add($name, ns);
        }};
    }
    let mut history = Vec::new();
    let mut iters = 0;
    let mut converged = false;
    while iters < opts.max_iters {
        iters += 1;
        let q = dual_stencil_values(rows, cols, tiles, &p, engine, coeffs)?;
        component!("spmv", spmv_ns);
        eth_total += seam_halo_ns;

        let (pq, t) = dual_dot(&p, &q, engine, cost)?;
        component!("dot", t);
        eth_total += 2.0 * seam_scalar_ns;
        if pq == 0.0 || !pq.is_finite() {
            break;
        }
        let alpha = (delta / pq) as f32;
        for (xi, pi) in x.iter_mut().zip(&p) {
            engine.axpy_into(xi, alpha, pi)?;
        }
        component!("axpy", axpy_ns);
        for (ri, qi) in r.iter_mut().zip(&q) {
            engine.axpy_into(ri, -alpha, qi)?;
        }
        component!("axpy", axpy_ns);

        let (rr, t) = dual_dot(&r, &r, engine, cost)?;
        component!("norm", t);
        eth_total += 2.0 * seam_scalar_ns;
        let rnorm = rr.max(0.0).sqrt();
        history.push(rnorm);
        if rnorm <= opts.tol_abs {
            converged = true;
            break;
        }

        z = r
            .iter()
            .map(|blk| engine.scale(blk, inv_diag))
            .collect::<crate::Result<_>>()?;
        component!("precond", scale_ns);
        let (dn, t) = dual_dot(&r, &z, engine, cost)?;
        component!("dot", t);
        eth_total += 2.0 * seam_scalar_ns;
        if delta == 0.0 {
            break;
        }
        let beta = (dn / delta) as f32;
        delta = dn;
        for (pi, zi) in p.iter_mut().zip(&z) {
            *pi = engine.axpy(zi, beta, pi)?;
        }
        component!("axpy", axpy_ns);
    }

    breakdown.iterations = iters as u64;
    Ok(DualDieResult {
        iters,
        converged,
        residual_history: history,
        per_iter_ns: if iters > 0 { now / iters as f64 } else { 0.0 },
        total_ns: now,
        eth_ns_per_iter: if iters > 0 { eth_total / iters as f64 } else { 0.0 },
        breakdown,
        launch: queue.stats.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::util::prng::Rng;

    fn dual_random(rows: usize, cols: usize, tiles: usize, seed: u64) -> DualVector {
        let mut rng = Rng::new(seed);
        (0..2 * rows * cols)
            .map(|_| CoreBlock::from_fn(DataFormat::Bf16, tiles, |_, _, _| rng.next_f32() - 0.5))
            .collect()
    }

    #[test]
    fn dual_die_pcg_reduces_residual() {
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(2, 2, 3, 1);
        let mut opts = DualDieOptions::default();
        opts.max_iters = 40;
        opts.tol_abs = 0.0;
        let res = solve_pcg_dualdie(2, 2, 3, &b, &e, &cost, &opts).unwrap();
        let first = res.residual_history[0];
        let min = res.residual_history.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min < 0.2 * first, "first {first} min {min}");
        assert!(res.eth_ns_per_iter > 0.0);
        // Fused schedule: one enqueue for the whole solve, gaps per
        // component — derived from the scheduler, not hard-coded here.
        assert_eq!(res.launch.launches, 1);
        assert!(res.launch.gap_ns > 0.0);
    }

    #[test]
    fn seam_values_match_single_logical_grid() {
        // The dual-die stencil over a 2·2×2 logical grid must equal the
        // single-grid stencil on a 4×2 TensixGrid (values don't care which
        // wires carried the halos).
        use crate::kernels::stencil::{run_stencil, StencilConfig, StencilVariant};
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(2, 2, 3, 7);
        let dual = dual_stencil_values(2, 2, 3, &b, &e, StencilCoeffs::LAPLACIAN).unwrap();

        let grid = TensixGrid::new(4, 2).unwrap();
        let cfg = StencilConfig {
            df: DataFormat::Bf16,
            unit: crate::arch::ComputeUnit::Fpu,
            tiles_per_core: 3,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let (single, _) = run_stencil(&grid, &cfg, &b, &e, &cost).unwrap();
        assert_eq!(dual, single);
    }

    #[test]
    fn ethernet_seam_is_visible_but_small() {
        // §8 expectation: multi-device scaling is viable because the seam
        // is a cheap N/S-row exchange; Ethernet latency must not dominate
        // a 64-tile iteration.
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(4, 4, 16, 9);
        let mut opts = DualDieOptions::default();
        opts.max_iters = 2;
        opts.tol_abs = 0.0;
        let res = solve_pcg_dualdie(4, 4, 16, &b, &e, &cost, &opts).unwrap();
        assert!(res.eth_ns_per_iter > 0.0);
        assert!(
            res.eth_ns_per_iter < 0.2 * res.per_iter_ns,
            "eth {} vs iter {}",
            res.eth_ns_per_iter,
            res.per_iter_ns
        );
    }

    #[test]
    fn capacity_still_enforced_per_die() {
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(1, 1, 165, 1);
        let opts = DualDieOptions::default();
        assert!(solve_pcg_dualdie(1, 1, 165, &b, &e, &cost, &opts).is_err());
    }
}
