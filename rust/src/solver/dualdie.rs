//! Multi-device scaling (§8 future work): PCG across both Tensix dies of
//! the n300d — now a thin N=2 wrapper over the general mesh solver.
//!
//! The n300d carries two Wormhole dies; §7.2 evaluates one ("future work
//! will explore full utilization of the n300d"). Dies connect over
//! on-board Ethernet links; the solver stacks the domain along x with the
//! seam exchanged over the link — exactly the [`crate::solver::mesh`]
//! decomposition at N = 2, which is what runs underneath. The public
//! [`DualDieOptions`]/[`DualDieResult`] types are unchanged, and
//! [`EthLink`] is re-exported from its new home in the device layer
//! ([`crate::device::mesh`]) for compatibility.
//!
//! Values are exact (the seam halos are stitched from the neighbor die's
//! blocks — bit-identical to a single logical grid of twice the rows);
//! timing adds the Ethernet seam costs to the per-die NoC/compute times.

pub use crate::device::mesh::EthLink;

use crate::arch::DataFormat;
use crate::device::{DeviceMesh, MeshTopology};
use crate::engine::{ComputeEngine, CoreBlock, StencilCoeffs};
use crate::kernels::stencil::{StencilConfig, StencilVariant};
use crate::profiler::{Breakdown, Profiler};
use crate::solver::mesh::{solve_pcg_mesh, MeshOptions};
use crate::solver::pcg::{Operator, PcgOptions, PcgVariant};
use crate::timing::cost::CostModel;
use crate::timing::SimNs;
use crate::ttm::{OverlapMode, Schedule};

#[derive(Debug, Clone)]
pub struct DualDieOptions {
    pub max_iters: usize,
    pub tol_abs: f64,
    pub eth: EthLink,
    /// Seam-overlap rule, passed through to the underlying N=2 mesh
    /// solve. `Serial` (the default) keeps the PR-4 seam model exactly;
    /// `Pipelined` hides the seam wait under the interior compute chain.
    pub overlap: OverlapMode,
    /// Communication-avoiding iteration schedule, passed through to the
    /// mesh solve. `Classic` (the default) keeps the historical
    /// trajectory and timings bit-exactly.
    pub schedule: Schedule,
    /// Die wiring, passed through to the underlying N=2 mesh. `Line`
    /// (the default) keeps the historical on-board point-to-point model;
    /// a `Torus2D` shape must multiply out to exactly 2 dies (`2x1` or
    /// `1x2`) or the solve is rejected.
    pub topology: MeshTopology,
}

impl Default for DualDieOptions {
    fn default() -> Self {
        Self {
            max_iters: 50,
            tol_abs: 1e-4,
            eth: EthLink::default(),
            overlap: OverlapMode::Serial,
            schedule: Schedule::Classic,
            topology: MeshTopology::Line,
        }
    }
}

#[derive(Debug, Clone)]
pub struct DualDieResult {
    pub iters: usize,
    pub converged: bool,
    pub residual_history: Vec<f64>,
    pub per_iter_ns: SimNs,
    pub total_ns: SimNs,
    /// Per-iteration Ethernet seam cost (halo + reduction combine).
    pub eth_ns_per_iter: SimNs,
    pub breakdown: Breakdown,
    /// Scheduler-derived launch accounting (one enqueue per solve).
    pub launch: crate::ttm::LaunchStats,
    /// Per-resource attribution of `total_ns`, passed through from the
    /// underlying N=2 mesh solve.
    pub ledger: crate::telemetry::SolveLedger,
}

/// A logical dual-die distributed vector: blocks for die 0's rows×cols
/// cores followed by die 1's (row-major within each die).
pub type DualVector = Vec<CoreBlock>;

/// Dual-die fused-BF16 PCG (values exact, timing = die-local + seam).
/// Thin wrapper: builds the two-die line mesh and runs the general
/// distributed solver.
pub fn solve_pcg_dualdie(
    rows: usize,
    cols: usize,
    tiles: usize,
    b: &DualVector,
    engine: &dyn ComputeEngine,
    cost: &CostModel,
    opts: &DualDieOptions,
) -> crate::Result<DualDieResult> {
    let mesh = DeviceMesh::new(2, rows, cols, opts.topology, opts.eth)?;
    assert_eq!(b.len(), mesh.n_cores(), "one block per core across both dies");

    let stencil_cfg = StencilConfig {
        df: DataFormat::Bf16,
        unit: crate::arch::ComputeUnit::Fpu,
        tiles_per_core: tiles,
        variant: StencilVariant::FULL,
        coeffs: StencilCoeffs::LAPLACIAN,
    };
    let mut popts = PcgOptions::new(PcgVariant::FusedBf16);
    popts.max_iters = opts.max_iters;
    popts.tol_abs = opts.tol_abs;
    let mut prof = Profiler::disabled();
    // Overlap and schedule pass straight through to the mesh solver; the
    // defaults (Serial + Classic) reproduce the PR-4 seam model — and
    // the historical DualDieResult timings — bit-exactly.
    let mopts = MeshOptions::new(popts)
        .with_overlap(opts.overlap)
        .with_schedule(opts.schedule);
    let res = solve_pcg_mesh(
        &mesh,
        b,
        &Operator::Stencil(stencil_cfg),
        engine,
        cost,
        &mopts,
        &mut prof,
    )?;
    Ok(DualDieResult {
        iters: res.iters,
        converged: res.converged,
        residual_history: res.residual_history,
        per_iter_ns: res.per_iter_ns,
        total_ns: res.total_ns,
        eth_ns_per_iter: res.eth_ns_per_iter,
        breakdown: res.breakdown,
        launch: res.launch,
        ledger: res.ledger,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::util::prng::Rng;

    fn dual_random(rows: usize, cols: usize, tiles: usize, seed: u64) -> DualVector {
        let mut rng = Rng::new(seed);
        (0..2 * rows * cols)
            .map(|_| CoreBlock::from_fn(DataFormat::Bf16, tiles, |_, _, _| rng.next_f32() - 0.5))
            .collect()
    }

    #[test]
    fn dual_die_pcg_reduces_residual() {
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(2, 2, 3, 1);
        let mut opts = DualDieOptions::default();
        opts.max_iters = 40;
        opts.tol_abs = 0.0;
        let res = solve_pcg_dualdie(2, 2, 3, &b, &e, &cost, &opts).unwrap();
        let first = res.residual_history[0];
        let min = res.residual_history.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min < 0.2 * first, "first {first} min {min}");
        assert!(res.eth_ns_per_iter > 0.0);
        // Fused schedule: one enqueue for the whole solve, gaps per
        // component — derived from the scheduler, not hard-coded here.
        assert_eq!(res.launch.launches, 1);
        assert!(res.launch.gap_ns > 0.0);
    }

    #[test]
    fn seam_values_match_single_logical_grid() {
        // The dual-die stencil over a 2·2×2 logical grid must equal the
        // single-grid stencil on a 4×2 TensixGrid (values don't care which
        // wires carried the halos).
        use crate::device::TensixGrid;
        use crate::kernels::stencil::run_stencil;
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(2, 2, 3, 7);
        let dual =
            crate::solver::mesh::mesh_stencil_values(4, 2, &b, &e, StencilCoeffs::LAPLACIAN, true)
                .unwrap();

        let grid = TensixGrid::new(4, 2).unwrap();
        let cfg = StencilConfig {
            df: DataFormat::Bf16,
            unit: crate::arch::ComputeUnit::Fpu,
            tiles_per_core: 3,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let (single, _) = run_stencil(&grid, &cfg, &b, &e, &cost).unwrap();
        assert_eq!(dual, single);
    }

    #[test]
    fn ethernet_seam_is_visible_but_small() {
        // §8 expectation: multi-device scaling is viable because the seam
        // is a cheap N/S-row exchange; Ethernet latency must not dominate
        // a 64-tile iteration.
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(4, 4, 16, 9);
        let mut opts = DualDieOptions::default();
        opts.max_iters = 2;
        opts.tol_abs = 0.0;
        let res = solve_pcg_dualdie(4, 4, 16, &b, &e, &cost, &opts).unwrap();
        assert!(res.eth_ns_per_iter > 0.0);
        assert!(
            res.eth_ns_per_iter < 0.2 * res.per_iter_ns,
            "eth {} vs iter {}",
            res.eth_ns_per_iter,
            res.per_iter_ns
        );
    }

    #[test]
    fn capacity_still_enforced_per_die() {
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(1, 1, 165, 1);
        let opts = DualDieOptions::default();
        assert!(solve_pcg_dualdie(1, 1, 165, &b, &e, &cost, &opts).is_err());
    }

    #[test]
    fn overlap_and_schedule_pass_through_to_the_mesh() {
        // The wrapper no longer hardcodes Serial/Classic: a pipelined +
        // prefetch dual-die solve must (a) keep the exact same residual
        // trajectory (both knobs are timing-only) and (b) be at least as
        // fast as the serial classic solve.
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(2, 2, 3, 21);
        let mut base = DualDieOptions::default();
        base.max_iters = 8;
        base.tol_abs = 0.0;
        let classic = solve_pcg_dualdie(2, 2, 3, &b, &e, &cost, &base).unwrap();

        let mut fast = base.clone();
        fast.overlap = OverlapMode::Pipelined;
        fast.schedule = Schedule::Prefetch;
        let led = solve_pcg_dualdie(2, 2, 3, &b, &e, &cost, &fast).unwrap();
        assert_eq!(led.residual_history, classic.residual_history);
        assert!(
            led.total_ns <= classic.total_ns,
            "prefetch+pipelined {} vs classic {}",
            led.total_ns,
            classic.total_ns
        );
    }

    #[test]
    fn topology_passes_through_and_wrong_shapes_are_rejected() {
        // A 2x1 torus on two dies degenerates to the same wiring as the
        // line (no wrap links below 3 dies per dimension), so the whole
        // solve — values AND timing — must be bit-identical. A shape
        // that doesn't multiply out to 2 dies must fail loudly, not
        // silently fall back to a line.
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(2, 2, 3, 33);
        let mut line = DualDieOptions::default();
        line.max_iters = 6;
        line.tol_abs = 0.0;
        let mut torus = line.clone();
        torus.topology = MeshTopology::Torus2D { rows: 2, cols: 1 };
        let lr = solve_pcg_dualdie(2, 2, 3, &b, &e, &cost, &line).unwrap();
        let tr = solve_pcg_dualdie(2, 2, 3, &b, &e, &cost, &torus).unwrap();
        assert_eq!(lr.residual_history, tr.residual_history);
        assert_eq!(lr.total_ns, tr.total_ns);
        assert_eq!(lr.eth_ns_per_iter, tr.eth_ns_per_iter);

        let mut bad = line.clone();
        bad.topology = MeshTopology::Torus2D { rows: 4, cols: 8 };
        let err = solve_pcg_dualdie(2, 2, 3, &b, &e, &cost, &bad).unwrap_err();
        assert!(
            err.to_string().contains("torus"),
            "expected a topology-shape error, got: {err}"
        );
    }

    #[test]
    fn wrapper_equals_mesh_n2() {
        // The wrapper is a pure re-labeling of the N=2 mesh solve: same
        // trajectory, same timing, same launch accounting.
        use crate::device::{DeviceMesh, MeshTopology};
        use crate::solver::mesh::solve_pcg_mesh;
        use crate::solver::pcg::{Operator, PcgOptions, PcgVariant};
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(2, 2, 4, 11);
        let mut opts = DualDieOptions::default();
        opts.max_iters = 6;
        opts.tol_abs = 0.0;
        let wrapped = solve_pcg_dualdie(2, 2, 4, &b, &e, &cost, &opts).unwrap();

        let mesh = DeviceMesh::new(2, 2, 2, MeshTopology::Line, opts.eth).unwrap();
        let mut popts = PcgOptions::new(PcgVariant::FusedBf16);
        popts.max_iters = 6;
        popts.tol_abs = 0.0;
        let cfg = StencilConfig {
            df: DataFormat::Bf16,
            unit: crate::arch::ComputeUnit::Fpu,
            tiles_per_core: 4,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let mut prof = Profiler::disabled();
        let mesh_res =
            solve_pcg_mesh(&mesh, &b, &Operator::Stencil(cfg), &e, &cost, &popts.into(), &mut prof)
                .unwrap();
        assert_eq!(wrapped.residual_history, mesh_res.residual_history);
        assert_eq!(wrapped.total_ns, mesh_res.total_ns);
        assert_eq!(wrapped.eth_ns_per_iter, mesh_res.eth_ns_per_iter);
        assert_eq!(wrapped.launch, mesh_res.launch);
    }
}
