//! Multi-device scaling (§8 future work): PCG across both Tensix dies of
//! the n300d.
//!
//! The n300d carries two Wormhole dies; §7.2 evaluates one ("future work
//! will explore full utilization of the n300d"). Dies connect over on-board
//! Ethernet links (the §3 die grid dedicates cells to Ethernet
//! management). We extend the solver across two dies by stacking the
//! domain along x: die 0 owns the top `rows×cols` core grid, die 1 the
//! bottom, and the seam between them exchanges halos over Ethernet instead
//! of the NoC. Global reductions reduce per-die, then combine + broadcast
//! the scalar across the link.
//!
//! Values are exact (the seam halos are stitched from the neighbor die's
//! blocks); timing adds the Ethernet seam costs to the per-die NoC/compute
//! times.

use crate::arch::DataFormat;
use crate::device::TensixGrid;
use crate::engine::{ComputeEngine, CoreBlock, Halos, StencilCoeffs};
use crate::kernels::eltwise::block_op_ns;
use crate::kernels::reduction::{run_dot, DotConfig, DotMethod};
use crate::kernels::stencil::{local_tile_cycles, StencilConfig, StencilVariant};
use crate::noc::RoutePattern;
use crate::profiler::Breakdown;
use crate::solver::problem::Problem;
use crate::timing::cost::CostModel;
use crate::timing::SimNs;

/// On-board Ethernet link between the two dies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EthLink {
    /// One-way message latency, ns (Ethernet MAC + SerDes; orders of
    /// magnitude above a NoC hop).
    pub latency_ns: f64,
    /// Usable bandwidth, GB/s (2×100 GbE per die pair ≈ 25 GB/s raw; we
    /// default to one link's usable rate).
    pub bw_gbs: f64,
}

impl Default for EthLink {
    fn default() -> Self {
        Self {
            latency_ns: 800.0,
            bw_gbs: 11.0,
        }
    }
}

impl EthLink {
    /// Transfer time for `bytes` over the link.
    pub fn transfer_ns(&self, bytes: u64) -> f64 {
        self.latency_ns + bytes as f64 / self.bw_gbs
    }
}

#[derive(Debug, Clone)]
pub struct DualDieOptions {
    pub max_iters: usize,
    pub tol_abs: f64,
    pub eth: EthLink,
}

impl Default for DualDieOptions {
    fn default() -> Self {
        Self {
            max_iters: 50,
            tol_abs: 1e-4,
            eth: EthLink::default(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct DualDieResult {
    pub iters: usize,
    pub converged: bool,
    pub residual_history: Vec<f64>,
    pub per_iter_ns: SimNs,
    pub total_ns: SimNs,
    /// Per-iteration Ethernet seam cost (halo + reduction combine).
    pub eth_ns_per_iter: SimNs,
    pub breakdown: Breakdown,
}

/// A logical dual-die distributed vector: blocks for die 0's rows×cols
/// cores followed by die 1's (row-major within each die).
pub type DualVector = Vec<CoreBlock>;

/// The distributed stencil over both dies: per-core halos gathered from
/// the (2·rows)×cols logical grid; the seam rows exchange across dies.
fn dual_stencil_values(
    rows: usize,
    cols: usize,
    nz: usize,
    x: &[CoreBlock],
    engine: &dyn ComputeEngine,
    coeffs: StencilCoeffs,
) -> crate::Result<Vec<CoreBlock>> {
    let total_rows = 2 * rows;
    assert_eq!(x.len(), total_rows * cols);
    let idx = |r: usize, c: usize| r * cols + c;
    let mut out = Vec::with_capacity(x.len());
    for r in 0..total_rows {
        for c in 0..cols {
            let nb = |dr: isize, dc: isize| -> Option<&CoreBlock> {
                let rr = r as isize + dr;
                let cc = c as isize + dc;
                if rr < 0 || cc < 0 || rr >= total_rows as isize || cc >= cols as isize {
                    None
                } else {
                    Some(&x[idx(rr as usize, cc as usize)])
                }
            };
            let halos = Halos::gather(nb(-1, 0), nb(1, 0), nb(0, -1), nb(0, 1));
            out.push(engine.stencil_apply(&x[idx(r, c)], &halos, coeffs)?);
        }
    }
    let _ = nz;
    Ok(out)
}

/// Per-iteration Ethernet seam bytes for the stencil halo: `cols` core
/// pairs each exchange one 16-element row per tile in both directions
/// (the seam is an x-boundary, so it is the cheap N/S row exchange — 32B
/// per tile at BF16).
fn seam_halo_bytes(cols: usize, nz: usize, df: DataFormat) -> u64 {
    2 * (cols as u64) * (nz as u64) * (16 * df.bytes()) as u64
}

/// Dual-die fused-BF16 PCG (values exact, timing = die-local + seam).
pub fn solve_pcg_dualdie(
    rows: usize,
    cols: usize,
    tiles: usize,
    b: &DualVector,
    engine: &dyn ComputeEngine,
    cost: &CostModel,
    opts: &DualDieOptions,
) -> crate::Result<DualDieResult> {
    let df = DataFormat::Bf16;
    let unit = crate::arch::ComputeUnit::Fpu;
    // Validate the per-die sub-grid + capacity with the single-die rules.
    let per_die = Problem::new(rows, cols, tiles, df);
    per_die.validate_capacity(true)?;
    let _ = TensixGrid::new(rows, cols)?;

    let n_blocks = 2 * rows * cols;
    assert_eq!(b.len(), n_blocks, "one block per core across both dies");
    let coeffs = StencilCoeffs::LAPLACIAN;

    // --- per-iteration timing (die-local part mirrors run_stencil) ------
    let stencil_cfg = StencilConfig {
        df,
        unit,
        tiles_per_core: tiles,
        variant: StencilVariant::FULL,
        coeffs,
    };
    let local_ns = crate::timing::cycles_ns(local_tile_cycles(cost, unit, df) * tiles as u64);
    // Die-local stencil timing: exactly the single-die simulation (the
    // stencil's timing is data-independent, so run it once on zeros over a
    // per-die grid — this includes the NoC halo schedule and the zero-fill
    // costs at the outer boundary).
    let die_grid = TensixGrid::new(rows, cols)?;
    let zeros: Vec<CoreBlock> = (0..rows * cols).map(|_| CoreBlock::zeros(df, tiles)).collect();
    let (_, die_timing) =
        crate::kernels::stencil::run_stencil(&die_grid, &stencil_cfg, &zeros, engine, cost)?;
    // Ethernet seam: halo bytes + one scalar combine + one broadcast per
    // global reduction. The seam exchange overlaps the NoC halo phase, so
    // the stencil takes whichever finishes later.
    let seam_halo_ns = opts.eth.transfer_ns(seam_halo_bytes(cols, tiles, df));
    let seam_scalar_ns = opts.eth.transfer_ns(32);
    let spmv_ns = die_timing.iter_ns.max(local_ns + seam_halo_ns);

    let dot_cfg = DotConfig {
        method: DotMethod::ReduceThenSend,
        pattern: RoutePattern::Naive,
        df,
        unit,
        tiles_per_core: tiles,
    };
    let axpy_ns = block_op_ns(
        cost,
        unit,
        df,
        crate::timing::cost::TileOpKind::EltwiseBinary,
        tiles,
        crate::timing::cost::PipelineMode::Streamed,
    );
    let scale_ns = block_op_ns(
        cost,
        unit,
        df,
        crate::timing::cost::TileOpKind::EltwiseUnary,
        tiles,
        crate::timing::cost::PipelineMode::Streamed,
    );

    // --- the solve (values on the logical 2R×C grid) --------------------
    let idx_all = |v: &DualVector| -> (Vec<CoreBlock>, Vec<CoreBlock>) {
        (v[..rows * cols].to_vec(), v[rows * cols..].to_vec())
    };
    let inv_diag = 1.0 / coeffs.center;
    let mut x: DualVector = (0..n_blocks).map(|_| CoreBlock::zeros(df, tiles)).collect();
    let mut r: DualVector = b.to_vec();
    let mut z: DualVector = r
        .iter()
        .map(|blk| engine.scale(blk, inv_diag))
        .collect::<crate::Result<_>>()?;
    let mut p = z.clone();

    // Distributed dot across both dies: per-die reduce + Ethernet combine.
    let dual_dot = |a: &DualVector,
                    bb: &DualVector,
                    engine: &dyn ComputeEngine,
                    cost: &CostModel|
     -> crate::Result<(f64, SimNs)> {
        let (a0, a1) = idx_all(a);
        let (b0, b1) = idx_all(bb);
        let d0 = run_dot(rows, cols, &dot_cfg, &a0, &b0, engine, cost)?;
        let d1 = run_dot(rows, cols, &dot_cfg, &a1, &b1, engine, cost)?;
        // Dies reduce concurrently; then one scalar hop + one broadcast.
        let t = d0.total_ns.max(d1.total_ns) + 2.0 * seam_scalar_ns;
        Ok((d0.value as f64 + d1.value as f64, t))
    };

    let mut breakdown = Breakdown::new();
    let mut now = 0.0f64;
    let mut eth_total = 0.0f64;
    // Same device-side phase gaps as the single-die fused kernel (§7.3).
    let gap_ns = cost.calib.inter_kernel_gap_ns;
    let mut delta = {
        let (v, t) = dual_dot(&r, &z, engine, cost)?;
        now += t;
        v
    };
    let mut history = Vec::new();
    let mut iters = 0;
    let mut converged = false;
    while iters < opts.max_iters {
        iters += 1;
        let q = dual_stencil_values(rows, cols, tiles, &p, engine, coeffs)?;
        breakdown.add("spmv", spmv_ns);
        now += spmv_ns + gap_ns;
        eth_total += seam_halo_ns;

        let (pq, t) = dual_dot(&p, &q, engine, cost)?;
        breakdown.add("dot", t);
        now += t + gap_ns;
        eth_total += 2.0 * seam_scalar_ns;
        if pq == 0.0 || !pq.is_finite() {
            break;
        }
        let alpha = (delta / pq) as f32;
        for (xi, pi) in x.iter_mut().zip(&p) {
            engine.axpy_into(xi, alpha, pi)?;
        }
        breakdown.add("axpy", axpy_ns);
        now += axpy_ns + gap_ns;
        for (ri, qi) in r.iter_mut().zip(&q) {
            engine.axpy_into(ri, -alpha, qi)?;
        }
        breakdown.add("axpy", axpy_ns);
        now += axpy_ns + gap_ns;

        let (rr, t) = dual_dot(&r, &r, engine, cost)?;
        breakdown.add("norm", t);
        now += t + gap_ns;
        eth_total += 2.0 * seam_scalar_ns;
        let rnorm = rr.max(0.0).sqrt();
        history.push(rnorm);
        if rnorm <= opts.tol_abs {
            converged = true;
            break;
        }

        z = r
            .iter()
            .map(|blk| engine.scale(blk, inv_diag))
            .collect::<crate::Result<_>>()?;
        breakdown.add("precond", scale_ns);
        now += scale_ns + gap_ns;
        let (dn, t) = dual_dot(&r, &z, engine, cost)?;
        breakdown.add("dot", t);
        now += t + gap_ns;
        eth_total += 2.0 * seam_scalar_ns;
        if delta == 0.0 {
            break;
        }
        let beta = (dn / delta) as f32;
        delta = dn;
        for (pi, zi) in p.iter_mut().zip(&z) {
            *pi = engine.axpy(zi, beta, pi)?;
        }
        breakdown.add("axpy", axpy_ns);
        now += axpy_ns + gap_ns;
    }

    breakdown.iterations = iters as u64;
    Ok(DualDieResult {
        iters,
        converged,
        residual_history: history,
        per_iter_ns: if iters > 0 { now / iters as f64 } else { 0.0 },
        total_ns: now,
        eth_ns_per_iter: if iters > 0 { eth_total / iters as f64 } else { 0.0 },
        breakdown,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::util::prng::Rng;

    fn dual_random(rows: usize, cols: usize, tiles: usize, seed: u64) -> DualVector {
        let mut rng = Rng::new(seed);
        (0..2 * rows * cols)
            .map(|_| CoreBlock::from_fn(DataFormat::Bf16, tiles, |_, _, _| rng.next_f32() - 0.5))
            .collect()
    }

    #[test]
    fn dual_die_pcg_reduces_residual() {
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(2, 2, 3, 1);
        let mut opts = DualDieOptions::default();
        opts.max_iters = 40;
        opts.tol_abs = 0.0;
        let res = solve_pcg_dualdie(2, 2, 3, &b, &e, &cost, &opts).unwrap();
        let first = res.residual_history[0];
        let min = res.residual_history.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min < 0.2 * first, "first {first} min {min}");
        assert!(res.eth_ns_per_iter > 0.0);
    }

    #[test]
    fn seam_values_match_single_logical_grid() {
        // The dual-die stencil over a 2·2×2 logical grid must equal the
        // single-grid stencil on a 4×2 TensixGrid (values don't care which
        // wires carried the halos).
        use crate::kernels::stencil::{run_stencil, StencilConfig, StencilVariant};
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(2, 2, 3, 7);
        let dual = dual_stencil_values(2, 2, 3, &b, &e, StencilCoeffs::LAPLACIAN).unwrap();

        let grid = TensixGrid::new(4, 2).unwrap();
        let cfg = StencilConfig {
            df: DataFormat::Bf16,
            unit: crate::arch::ComputeUnit::Fpu,
            tiles_per_core: 3,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let (single, _) = run_stencil(&grid, &cfg, &b, &e, &cost).unwrap();
        assert_eq!(dual, single);
    }

    #[test]
    fn ethernet_seam_is_visible_but_small() {
        // §8 expectation: multi-device scaling is viable because the seam
        // is a cheap N/S-row exchange; Ethernet latency must not dominate
        // a 64-tile iteration.
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(4, 4, 16, 9);
        let mut opts = DualDieOptions::default();
        opts.max_iters = 2;
        opts.tol_abs = 0.0;
        let res = solve_pcg_dualdie(4, 4, 16, &b, &e, &cost, &opts).unwrap();
        assert!(res.eth_ns_per_iter > 0.0);
        assert!(
            res.eth_ns_per_iter < 0.2 * res.per_iter_ns,
            "eth {} vs iter {}",
            res.eth_ns_per_iter,
            res.per_iter_ns
        );
    }

    #[test]
    fn capacity_still_enforced_per_die() {
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dual_random(1, 1, 165, 1);
        let opts = DualDieOptions::default();
        assert!(solve_pcg_dualdie(1, 1, 165, &b, &e, &cost, &opts).is_err());
    }
}
