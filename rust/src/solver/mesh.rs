//! Distributed PCG over an N-die [`DeviceMesh`] (§8 multi-device
//! scaling) — the generalization of the old two-die special case.
//!
//! The dies tile the logical core grid as a row-major die grid
//! ([`DeviceMesh::mesh_shape`]): on a 1D line/ring that is the N×1
//! column (die `d` owns logical core rows `[d·die_rows,
//! (d+1)·die_rows)`), on a 2D torus the domain splits along both axes.
//! The mesh-wide vector holds one block per *logical* core in row-major
//! order, so values are computed over the logical grid exactly as the
//! single-die solver would — the same stencil stitching, the same
//! canonical dot accumulation order — which is why an N-die trajectory
//! is **bit-identical** to the single-die trajectory on the same
//! problem, for every topology (pinned by `tests/prop_mesh.rs` and
//! `tests/prop_torus.rs`). Only *where the wires run* changes:
//!
//! - the seam halo between adjacent dies rides Ethernet instead of the
//!   NoC — an overlapping [`crate::ttm::EtherPhase`] on the lowered
//!   "spmv" program. A 1D mesh has N/S seams only; a 2D die grid also
//!   pays E/W seams, which carry 4× the bytes (the §6.3 face transpose:
//!   4 discontiguous 16-element segments per tile) — but halo *path
//!   lengths* stay one hop, and each die's seam perimeter shrinks as
//!   the die grid squares up;
//! - each dot product reduces per-die over the NoC tree, then combines +
//!   broadcasts across the mesh — an appended `EtherPhase` on the
//!   "dot"/"norm" programs: 32 B scalar beats chained on a line
//!   (both-ways fold + broadcast on a ring), or — under
//!   [`crate::kernels::DotMethod::SendTiles`] — tile payloads as a
//!   segmented ring all-reduce whose per-round bandwidth term is
//!   bytes/N. On a torus the same payloads ride the 2D
//!   [`EtherPhase::allreduce2d`] — a row phase then a column phase,
//!   O(√N) rounds per phase — which is what moves the strong-scaling
//!   knee past N=16.
//!
//! **Interior/boundary split + overlap.** Every per-die "spmv" program
//! carries its compute cycles split into an *interior* chain (die-local
//! data only) and a *boundary* chain (consumes the Ethernet seam):
//! seam-adjacent core rows in the stencil lowering
//! ([`crate::kernels::stencil::lower_stencil_die`]), cross-die gather
//! consumers in the sparse one. [`MeshOptions::overlap`] picks the
//! scheduler rule: [`OverlapMode::Serial`] charges the whole dependent
//! chain after the seam (`end = max(local, eth + riscv + compute)` —
//! the paper's model, bit-identical to the pre-split trajectory), while
//! [`OverlapMode::Pipelined`] runs the boundary chain concurrently with
//! the interior chain (per core, `end = max(interior, eth) + boundary`;
//! only the Ethernet wait is hidden, never the boundary compute) — the
//! iteration-level software pipeline of real multi-die stencils.
//! Values are engine-side and identical in both modes.
//!
//! **Contended links.** Ethernet phases execute through the per-link
//! occupancy tracker [`crate::device::EthSim`] (the inter-die
//! counterpart of `NocSim`): concurrent hops sharing a physical link
//! serialize on its bandwidth term, and the busiest link's utilization
//! surfaces in [`MeshPcgResult::eth_peak_link_util`], the per-program
//! `ProgramOutcome`, and the profiler's per-link zones.
//!
//! Both [`Operator::Stencil`] (per-die stencil lowering + analytic seam)
//! and [`Operator::Sparse`] (per-die program slices + the partition's
//! [`crate::sparse::DieCutPlan`]) are supported, under the same
//! [`IterSchedule`]-derived fused/split launch accounting as the
//! single-die solver: the host enqueues one mesh-wide program per
//! component dispatch (split) or one per solve (fused), independent of N.

use std::collections::BTreeMap;

use crate::arch::constants::{SRAM_BYTES, SRAM_RESERVE_FUSED};
use crate::device::{DeviceMesh, FaultEvent, FaultPlan};
use crate::engine::{ComputeEngine, CoreBlock, Halos, StencilCoeffs};
use crate::kernels::eltwise::lower_block_op;
use crate::kernels::reduction::{lower_dot_as, DotConfig, DotMethod};
use crate::profiler::{Breakdown, Profiler};
use crate::solver::pcg::{Operator, PcgOptions, Precond, PCG_ITERATION};
use crate::solver::problem::DistVector;
use crate::solver::resilient::{checkpoint_cost, FaultRuntime, ResilienceOptions};
use crate::telemetry::{Resource, SolveLedger, SolverEvent, SpanGraph, Telemetry};
use crate::timing::cost::{CostModel, PipelineMode, TileOpKind};
use crate::timing::SimNs;
use crate::solver::sstep;
use crate::ttm::{
    EtherPhase, HostQueue, IterSchedule, LaunchStats, OverlapMode, Program, ProgramOutcome,
    Schedule, SolveSpans,
};

/// Options of a mesh solve: the per-iteration PCG options plus the §8
/// seam-overlap rule. [`OverlapMode::Serial`] reproduces the paper's
/// model (and the pre-split trajectory) exactly; `Pipelined` lets the
/// scheduler hide the Ethernet seam wait under the interior compute chain —
/// values are identical either way, only the clock moves. The
/// communication-avoiding iteration schedule rides in
/// [`PcgOptions::schedule`] ([`MeshOptions::with_schedule`] sets it):
/// `Prefetch` issues iteration k+1's halo under iteration k's tail
/// (values bit-identical), `SStep(s)` batches a block's scalar
/// all-reduces into one combined round (values drift-bounded).
#[derive(Debug, Clone)]
pub struct MeshOptions {
    pub pcg: PcgOptions,
    pub overlap: OverlapMode,
    /// Scripted fault injection ([`FaultPlan`]). `None` (or an empty
    /// plan) is the fault-free path: bit-identical values AND
    /// clock-identical timing to a build without the fault layer
    /// (pinned by `tests/prop_faults.rs`). Requires the classic
    /// schedule.
    pub faults: Option<FaultPlan>,
    /// Checkpoint/rollback policy. `None` defaults to
    /// [`ResilienceOptions::every`]`(8)` when the plan scripts an SDC or
    /// die loss (those are unrecoverable without checkpoints), and to
    /// disabled otherwise.
    pub resilience: Option<ResilienceOptions>,
}

impl MeshOptions {
    pub fn new(pcg: PcgOptions) -> Self {
        Self {
            pcg,
            overlap: OverlapMode::Serial,
            faults: None,
            resilience: None,
        }
    }

    pub fn with_overlap(mut self, overlap: OverlapMode) -> Self {
        self.overlap = overlap;
        self
    }

    /// Inject the given fault plan during the solve.
    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Set the checkpoint/rollback policy explicitly.
    pub fn with_resilience(mut self, resilience: ResilienceOptions) -> Self {
        self.resilience = Some(resilience);
        self
    }

    /// Set the communication-avoiding iteration schedule (stored on the
    /// inner [`PcgOptions`], which owns every per-iteration knob).
    pub fn with_schedule(mut self, schedule: Schedule) -> Self {
        self.pcg.schedule = schedule;
        self
    }
}

impl From<PcgOptions> for MeshOptions {
    fn from(pcg: PcgOptions) -> Self {
        Self::new(pcg)
    }
}

/// Per-iteration device time split by transport — the
/// compute/NoC/Ethernet/dispatch view of the strong-scaling sweep.
/// Compute and communication phases can overlap (the seam halo hides
/// under the stencil compute), so the parts may sum past the critical
/// path.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MeshPhaseBreakdown {
    /// DRAM staging + RISC-V element loops + compute pipeline (slowest
    /// die), per iteration.
    pub compute_ns: SimNs,
    /// NoC data movement + reduction tree + broadcast, per iteration.
    pub noc_ns: SimNs,
    /// Inter-die Ethernet phases, per iteration.
    pub ether_ns: SimNs,
    /// Host launches, fused-kernel gaps, and residual readbacks, per
    /// iteration.
    pub dispatch_ns: SimNs,
}

#[derive(Debug, Clone)]
pub struct MeshPcgResult {
    pub x: DistVector,
    pub iters: usize,
    pub converged: bool,
    pub residual_history: Vec<f64>,
    pub total_ns: SimNs,
    pub per_iter_ns: SimNs,
    /// Per-iteration Ethernet time (seam halo + scalar all-reduces).
    pub eth_ns_per_iter: SimNs,
    /// Total bytes moved over Ethernet links during the solve.
    pub eth_bytes_total: u64,
    /// Peak per-link utilization across all components' Ethernet phases
    /// (1.0 = some physical link was the serialized bottleneck for a
    /// whole phase; 0.0 on a single die).
    pub eth_peak_link_util: f64,
    /// Per-component device time (the Fig-13 view).
    pub breakdown: Breakdown,
    /// Per-iteration transport split (compute / NoC / Ethernet / dispatch).
    pub phases: MeshPhaseBreakdown,
    pub launch: LaunchStats,
    /// Dies in the mesh this result was solved on.
    pub n_dies: usize,
    /// The communication-avoiding schedule the solve ran
    /// ([`PcgOptions::schedule`], echoed for the benches).
    pub schedule: Schedule,
    /// Per-link busy fraction of the *whole solve* window, from the one
    /// solve-scoped [`crate::device::EthSim`] every component's transfers
    /// replay into (unlike `eth_peak_link_util`, which is per-phase).
    pub eth_link_util_solve: Vec<(usize, usize, f64)>,
    /// Per-resource attribution of `total_ns` (conserves by construction;
    /// see [`crate::telemetry::SolveLedger`]).
    pub ledger: SolveLedger,
    /// Metrics + per-iteration solver events (empty when
    /// [`PcgOptions::telemetry`] is off).
    pub telemetry: Telemetry,
    /// Causal span graph of the solve: the host dispatch chain with every
    /// component's full program graph (per-core chains, reduce tree,
    /// Ethernet phases) grafted into its dispatch window. Its critical
    /// path equals `total_ns` exactly. Empty when telemetry is off.
    pub spans: SpanGraph,
    /// Checkpoint restores performed (die losses + detected SDCs); 0 on
    /// every fault-free solve.
    pub rollbacks: u64,
    /// Fault-state transitions the solve re-lowered through; 0 on every
    /// fault-free solve.
    pub fault_epochs: u64,
}

impl MeshPcgResult {
    /// Modeled host enqueues per iteration (§7.1 accounting; independent
    /// of the die count — the host dispatches mesh-wide programs).
    pub fn launches_per_iter(&self) -> f64 {
        self.launch.launches as f64 / self.iters.max(1) as f64
    }

    /// One-line bottleneck statement with the mesh size, e.g.
    /// `"ethernet-bound (54% of solve, dominated by dot, link 0-1) at N=4"`.
    pub fn bottleneck_verdict(&self) -> String {
        format!("{} at N={}", self.ledger.verdict(), self.n_dies)
    }

    /// Critical-path analysis of the recorded span graph (per-resource
    /// critical fractions and slack). Errors when telemetry was off.
    pub fn critpath(&self) -> Result<crate::telemetry::CritPathReport, String> {
        crate::telemetry::analyze(&self.spans)
    }

    /// Scalar all-reduce rounds the schedule paid per PCG iteration
    /// (3 for classic/prefetch, 1/s amortized for s-step) — the
    /// communication-avoidance headline column of the bench sweep.
    pub fn allreduce_rounds_per_iter(&self) -> f64 {
        self.schedule.allreduce_rounds_per_iter()
    }

    /// `(crit_eth_frac, crit_dispatch_frac)` — the share of the solve's
    /// critical path spent on Ethernet links and host dispatch, the knee
    /// metrics of the mesh-scaling sweep. `(0, 0)` when telemetry is off.
    pub fn crit_fracs(&self) -> (f64, f64) {
        match self.critpath() {
            Ok(rep) => (
                rep.frac(crate::telemetry::Resource::Ethernet),
                rep.frac(crate::telemetry::Resource::Dispatch),
            ),
            Err(_) => (0.0, 0.0),
        }
    }
}

/// The distributed stencil over the mesh's logical `(N·rows)×cols` core
/// grid: per-core halos gathered from the full logical grid, so the seam
/// rows stitch across dies — values identical to a single grid of the
/// same shape, no matter which wires carried the halos.
pub(crate) fn mesh_stencil_values(
    logical_rows: usize,
    cols: usize,
    x: &[CoreBlock],
    engine: &dyn ComputeEngine,
    coeffs: StencilCoeffs,
    halo_exchange: bool,
) -> crate::Result<Vec<CoreBlock>> {
    assert_eq!(x.len(), logical_rows * cols, "one block per logical core");
    let idx = |r: usize, c: usize| r * cols + c;
    let mut out = Vec::with_capacity(x.len());
    for r in 0..logical_rows {
        for c in 0..cols {
            let nb = |dr: isize, dc: isize| -> Option<&CoreBlock> {
                let rr = r as isize + dr;
                let cc = c as isize + dc;
                if rr < 0 || cc < 0 || rr >= logical_rows as isize || cc >= cols as isize {
                    None
                } else {
                    Some(&x[idx(rr as usize, cc as usize)])
                }
            };
            // The Fig-11 ablation variants apply on the mesh too: without
            // halo exchange every core computes against zero boundaries,
            // exactly like `run_stencil`.
            let halos = if halo_exchange {
                Halos::gather(nb(-1, 0), nb(1, 0), nb(0, -1), nb(0, 1))
            } else {
                Halos::none()
            };
            out.push(engine.stencil_apply(&x[idx(r, c)], &halos, coeffs)?);
        }
    }
    Ok(out)
}

/// One seam direction's bytes between vertically adjacent dies per
/// stencil application: the N/S row exchange — `cols` core pairs × one
/// 16-element tile row per z-tile (§6.3's cheap direction; the reason a
/// 1D mesh stacks dies along x).
pub fn seam_bytes_one_way(cols: usize, tiles: usize, df: crate::arch::DataFormat) -> u64 {
    (cols as u64) * (tiles as u64) * (16 * df.bytes()) as u64
}

/// One seam direction's bytes between horizontally adjacent dies per
/// stencil application: the E/W column exchange — `rows` core pairs × 4
/// discontiguous 16-element segments per z-tile (§6.3's expensive
/// direction: a face column is strided through the 32×32 tile, so each
/// tile contributes 64 elements of seam traffic, 4× the N/S cost).
/// Only 2D die grids pay this.
pub fn seam_bytes_one_way_ew(rows: usize, tiles: usize, df: crate::arch::DataFormat) -> u64 {
    (rows as u64) * (tiles as u64) * (64 * df.bytes()) as u64
}

/// Deterministic random mesh-wide right-hand side (one block per logical
/// core, die-major = logical row-major order).
pub fn mesh_dist_random(
    mesh: &DeviceMesh,
    tiles: usize,
    df: crate::arch::DataFormat,
    seed: u64,
) -> DistVector {
    let p =
        crate::solver::problem::Problem::new(mesh.logical_rows(), mesh.logical_cols(), tiles, df);
    crate::solver::problem::dist_random(&p, seed)
}

/// Scale one lowered program to `f` back-to-back applications of itself:
/// the per-core cycle/staging vectors, the NoC sends, and the reduction
/// tree's merge work and payload all multiply (f dot products fold f
/// partial beats per tree edge). SRAM stays put — the applications
/// reuse the same resident tiles. This is how the s-step "gram" and
/// "bupdate" components price a block's worth of reductions/axpys as
/// one dispatch. Also how the fault layer folds a dead die's adopted
/// subdomain into its adopter's program (`solver::resilient`).
pub(crate) fn scale_program(mut p: Program, f: u64) -> Program {
    for q in &mut p.work.data_movement {
        let one = q.sends.clone();
        for _ in 1..f {
            q.sends.extend(one.iter().cloned());
        }
    }
    for v in &mut p.work.dram_bytes {
        *v *= f;
    }
    for v in &mut p.work.riscv_cycles {
        *v *= f;
    }
    for v in &mut p.work.compute_cycles {
        *v *= f;
    }
    if let Some(rd) = &mut p.work.reduce {
        rd.merge_cycles *= f;
        rd.root_extra_cycles *= f;
        rd.payload_bytes *= f;
        rd.bcast_bytes *= f;
    }
    p.footprint.traffic_bytes *= f;
    p
}

/// Scalars the s-step combined all-reduce carries per block: the Gram
/// blocks C, E, F (s² each), g = Vᵀr (s), and rᵀr (1). Fixed at the
/// worst case — block 0 has no C/E values to fold, but component timing
/// is input-independent by design, so the payload is too.
pub fn sstep_gram_scalars(s: usize) -> u64 {
    (3 * s * s + s + 1) as u64
}

/// A lowered mesh component: the slowest die's execution outcome (the
/// component time) for one program name.
struct MeshComponent {
    outcome: ProgramOutcome,
}

impl MeshComponent {
    fn device_ns(&self) -> SimNs {
        self.outcome.device_ns()
    }
}

/// The lowered per-iteration components of a mesh solve.
pub struct MeshLowering {
    /// One representative program per component name — what the host
    /// enqueues (mesh-wide) per dispatch, and what the fused schedule's
    /// SRAM check binds on.
    pub components: Vec<Program>,
    /// Every per-die "spmv" program (≥ 1); the component time is the
    /// slowest die's. All carry the same mesh-global Ethernet phase.
    pub spmv_per_die: Vec<Program>,
}

/// Lower every per-iteration PCG component for the mesh. Public seam for
/// the determinism/launch-pin integration tests and the benches.
pub fn lower_mesh_components(
    mesh: &DeviceMesh,
    operator: &Operator<'_>,
    opts: &MeshOptions,
    tiles: usize,
    precond_kind: TileOpKind,
    cost: &CostModel,
) -> crate::Result<MeshLowering> {
    let df = opts.pcg.variant.df();
    let unit = opts.pcg.variant.unit();
    let (rows, cols) = (mesh.die_rows, mesh.die_cols);

    // The matrix apply: per-die lowering + the Ethernet seam.
    let mut spmv_per_die: Vec<Program> = match operator {
        Operator::Stencil(cfg) => {
            // One program per die: the same die sub-grid NoC halo
            // schedule, but the interior/boundary compute split depends
            // on which seams the die touches (a 1D end die one, a torus
            // interior die up to four). The seams themselves ride the
            // shared Ethernet phase. The domain is not periodic — wrap
            // links carry only all-reduce traffic, never halos — so
            // flows connect grid-adjacent die pairs only.
            let die_grid = mesh.die_grid()?;
            let (mesh_rows, mesh_cols) = mesh.mesh_shape();
            let ns_one_way = seam_bytes_one_way(cols, cfg.tiles_per_core, cfg.df);
            let ew_one_way = seam_bytes_one_way_ew(rows, cfg.tiles_per_core, cfg.df);
            let mut flows: Vec<(usize, usize, u64)> = Vec::new();
            for r in 0..mesh_rows {
                for c in 0..mesh_cols {
                    let d = mesh.die_at(r, c);
                    if r + 1 < mesh_rows {
                        let s = mesh.die_at(r + 1, c);
                        flows.push((d, s, ns_one_way));
                        flows.push((s, d, ns_one_way));
                    }
                    if c + 1 < mesh_cols {
                        let e = mesh.die_at(r, c + 1);
                        flows.push((d, e, ew_one_way));
                        flows.push((e, d, ew_one_way));
                    }
                }
            }
            let ether = EtherPhase::halo("halo", mesh, &flows);
            let eth_bytes = ether.as_ref().map_or(0, |e| e.bytes());
            // Only the touched-seam set distinguishes dies (≤ 9 variants
            // across any die grid), so memoize the lowering instead of
            // rebuilding the full NoC schedule per die.
            let mut variants: BTreeMap<(bool, bool, bool, bool), Program> = BTreeMap::new();
            (0..mesh.n_dies)
                .map(|d| {
                    let (dr, dc) = mesh.die_coord(d);
                    let seams = (dr > 0, dr + 1 < mesh_rows, dc > 0, dc + 1 < mesh_cols);
                    let mut p = variants
                        .entry(seams)
                        .or_insert_with(|| {
                            let mut p = crate::kernels::stencil::lower_stencil_die(
                                &die_grid, cfg, cost, seams.0, seams.1, seams.2, seams.3,
                            );
                            p.name = "spmv".to_string();
                            p.work.ether = ether.clone();
                            p.footprint.eth_bytes = eth_bytes;
                            p
                        })
                        .clone();
                    for k in &mut p.kernels {
                        k.ct_args.push(("die".to_string(), d.to_string()));
                    }
                    p
                })
                .collect()
        }
        Operator::Sparse(op) => op.lower_mesh(mesh, cost)?,
    };
    for p in &mut spmv_per_die {
        p.work.overlap = opts.overlap;
    }
    // The schedule keys one program per component name: bind on the
    // per-die candidate with the largest SRAM working set (they tie for
    // the stencil; the SpMV footprint is already the global maximum).
    let spmv = spmv_per_die
        .iter()
        .max_by_key(|p| p.footprint.sram_bytes)
        .cloned()
        .ok_or_else(|| {
            crate::SimError::Other("mesh spmv lowering produced no programs".to_string())
        })?;

    let dot_cfg = DotConfig {
        method: opts.pcg.dot_method,
        pattern: opts.pcg.dot_pattern,
        df,
        unit,
        tiles_per_core: tiles,
    };
    // The inter-die all-reduce payload follows the §5.1 granularity
    // choice: method 1 combines 32 B scalar beats, method 2 exchanges
    // whole partial tiles — which on a ring becomes the segmented ring
    // all-reduce whose per-round bandwidth term is bytes/N.
    let allreduce = match opts.pcg.dot_method {
        DotMethod::ReduceThenSend => EtherPhase::scalar_allreduce(mesh),
        DotMethod::SendTiles => EtherPhase::allreduce(mesh, df.tile_bytes() as u64),
    };
    let with_allreduce = |mut p: Program| {
        p.work.ether = allreduce.clone();
        p.footprint.eth_bytes = p.work.ether.as_ref().map_or(0, |e| e.bytes());
        p
    };
    let mut components = vec![spmv];
    match opts.pcg.schedule {
        Schedule::SStep(s) => {
            // The s-step block dispatches no per-dot all-reduces: one
            // "gram" component folds every scalar the block needs (m
            // local dot reductions + ONE combined m-scalar round over
            // Ethernet), and one "bupdate" component prices the block's
            // recurrence axpys (P/Q coupling: 2s² column updates; x/r
            // step: 2s more).
            let m = sstep_gram_scalars(s);
            let mut gram = scale_program(lower_dot_as("gram", rows, cols, &dot_cfg, cost), m);
            gram.work.ether = EtherPhase::allreduce(mesh, 4 * m);
            gram.footprint.eth_bytes = gram.work.ether.as_ref().map_or(0, |e| e.bytes());
            components.push(gram);
            components.push(scale_program(
                lower_block_op(
                    "bupdate",
                    rows,
                    cols,
                    cost,
                    unit,
                    df,
                    TileOpKind::EltwiseBinary,
                    tiles,
                    PipelineMode::Streamed,
                ),
                (2 * s * s + 2 * s) as u64,
            ));
        }
        Schedule::Classic | Schedule::Prefetch => {
            components.push(with_allreduce(lower_dot_as("dot", rows, cols, &dot_cfg, cost)));
            components.push(with_allreduce(lower_dot_as("norm", rows, cols, &dot_cfg, cost)));
            components.push(lower_block_op(
                "axpy",
                rows,
                cols,
                cost,
                unit,
                df,
                TileOpKind::EltwiseBinary,
                tiles,
                PipelineMode::Streamed,
            ));
        }
    }
    components.push(lower_block_op(
        "precond",
        rows,
        cols,
        cost,
        unit,
        df,
        precond_kind,
        tiles,
        PipelineMode::Streamed,
    ));
    Ok(MeshLowering {
        components,
        spmv_per_die,
    })
}

/// Solve `A x = b` with PCG distributed over the mesh. Values are
/// bit-identical to [`crate::solver::solve_operator`] on the same
/// logical problem — in either overlap mode; timing re-routes the seam
/// and the scalar combines over Ethernet, and
/// [`OverlapMode::Pipelined`] additionally hides the seam wait under
/// the interior compute chain. `b` holds one block per logical core,
/// die-major.
pub fn solve_pcg_mesh(
    mesh: &DeviceMesh,
    b: &DistVector,
    operator: &Operator<'_>,
    engine: &dyn ComputeEngine,
    cost: &CostModel,
    opts: &MeshOptions,
    profiler: &mut Profiler,
) -> crate::Result<MeshPcgResult> {
    let fused = opts.pcg.fused();
    let df = opts.pcg.variant.df();
    let logical_rows = mesh.logical_rows();
    let cols = mesh.logical_cols();
    if b.len() != mesh.n_cores() {
        return Err(crate::SimError::BadProblem {
            what: format!(
                "rhs has {} blocks for {} mesh cores ({} dies x {}x{})",
                b.len(),
                mesh.n_cores(),
                mesh.n_dies,
                mesh.die_rows,
                mesh.die_cols
            ),
        });
    }
    let Some(first) = b.first() else {
        return Err(crate::SimError::BadProblem {
            what: "empty right-hand side".to_string(),
        });
    };
    if first.df != df {
        return Err(crate::SimError::BadProblem {
            what: format!(
                "rhs data format {} does not match variant {}",
                first.df,
                opts.pcg.variant.label()
            ),
        });
    }
    let tiles = first.nz();
    // Per-die SRAM/DRAM budgets; the sparse operator performed its own
    // §7.2-style SRAM validation at construction.
    if matches!(operator, Operator::Stencil(_)) {
        mesh.validate_budgets(tiles, df, fused)?;
    }

    // ---- fault layer gate -----------------------------------------------
    // An empty plan is the fault-free path. A non-empty one (and any
    // explicit resilience policy) requires the classic schedule: the
    // prefetch/s-step re-timings assume the topology never changes
    // mid-solve, and rollback restores loop-carried state the s-step
    // block recurrence does not expose at iteration granularity.
    let fault_plan = opts.faults.as_ref().filter(|p| !p.is_empty());
    if let Some(plan) = fault_plan {
        plan.validate(mesh)?;
        if opts.pcg.schedule != Schedule::Classic {
            return Err(crate::SimError::Other(format!(
                "fault injection requires the classic schedule (got {:?})",
                opts.pcg.schedule
            )));
        }
        for e in &plan.events {
            if let FaultEvent::Sdc { component, .. } = e {
                if component != "spmv" {
                    return Err(crate::SimError::Other(format!(
                        "sdc injection supports component 'spmv' only (got '{component}')"
                    )));
                }
            }
        }
    }
    if opts.resilience.as_ref().is_some_and(|r| r.enabled())
        && opts.pcg.schedule != Schedule::Classic
    {
        return Err(crate::SimError::Other(format!(
            "checkpoint/rollback resilience requires the classic schedule (got {:?})",
            opts.pcg.schedule
        )));
    }

    // ---- preconditioner (engine-side; identical to single-die) ----------
    let precond = operator.jacobi(df, opts.pcg.precondition)?;
    let precond_kind = match &precond {
        Precond::Scalar(_) => TileOpKind::EltwiseUnary,
        Precond::PerElement(_) => TileOpKind::EltwiseBinary,
    };

    // ---- lower + pre-execute the per-iteration components ---------------
    // Component timing is input-independent, so each program runs once
    // through a scratch queue (per-role and per-link profiler zones are
    // emitted here); the iteration loop then advances the clock through
    // the IterSchedule like the single-die solver. The spmv component runs
    // every die's program and keeps the slowest — the mesh waits for its
    // slowest die.
    let lowering = lower_mesh_components(mesh, operator, opts, tiles, precond_kind, cost)?;
    let mut components: BTreeMap<String, MeshComponent> = BTreeMap::new();
    {
        let mut scratch = HostQueue::new(cost.calib.clone());
        // Pre-execute at enqueue time -launch_ns so the device start is
        // exactly 0.0 (x + (-x) == +0.0 in IEEE): the recorded span graphs
        // then graft into solve-time dispatch windows by adding the window
        // start alone — a constant offset that keeps the solve-level sink
        // bit-exactly on the solver's clock.
        let scratch_t0 = -cost.calib.kernel_launch_ns;
        let mut slowest_spmv: Option<(usize, ProgramOutcome)> = None;
        for (i, p) in lowering.spmv_per_die.iter().enumerate() {
            let outcome = scratch.run(p, cost, scratch_t0, &mut Profiler::disabled())?;
            if slowest_spmv
                .as_ref()
                .map_or(true, |(_, s)| outcome.device_ns() > s.device_ns())
            {
                slowest_spmv = Some((i, outcome));
            }
        }
        let (slow_die, outcome) = slowest_spmv.expect("at least one die");
        // Role and per-link Ethernet zones are emitted once, for the die
        // that binds the component time (every per-die program carries
        // the same mesh-global phase — re-emitting it per die would
        // duplicate the link zones).
        if profiler.enabled {
            scratch.run(&lowering.spmv_per_die[slow_die], cost, scratch_t0, profiler)?;
        }
        components.insert("spmv".to_string(), MeshComponent { outcome });
        for p in &lowering.components {
            if p.name == "spmv" {
                continue; // already covered, per die
            }
            let outcome = scratch.run(p, cost, scratch_t0, profiler)?;
            components.insert(p.name.clone(), MeshComponent { outcome });
        }
    }
    // The fault runtime exists when there is a plan to react to OR a
    // checkpoint policy to pay for; `None` is the fault-free fast path —
    // zero extra work per iteration, bit- and clock-identical.
    let mut frt: Option<FaultRuntime> = match fault_plan {
        Some(plan) => {
            let resilience = opts.resilience.clone().unwrap_or_else(|| {
                // SDC and die loss are unrecoverable without checkpoints;
                // default them on. Pure link faults need none.
                let needs = plan.events.iter().any(|e| {
                    matches!(e, FaultEvent::Sdc { .. } | FaultEvent::DieDown { .. })
                });
                if needs {
                    ResilienceOptions::default()
                } else {
                    ResilienceOptions::disabled()
                }
            });
            Some(FaultRuntime::new(plan.clone(), resilience, mesh, &lowering))
        }
        None => opts
            .resilience
            .clone()
            .filter(|r| r.enabled())
            .map(|r| FaultRuntime::new(FaultPlan::default(), r, mesh, &lowering)),
    };
    let schedule = opts.pcg.schedule;
    // Per-iteration (or per-block, under s-step) dispatch order.
    let iteration: Vec<&str> = match schedule {
        Schedule::SStep(s) => {
            let mut seq = Vec::with_capacity(2 * s + 2);
            for _ in 0..s {
                seq.push("precond");
                seq.push("spmv");
            }
            seq.push("gram");
            seq.push("bupdate");
            seq
        }
        Schedule::Classic | Schedule::Prefetch => PCG_ITERATION.to_vec(),
    };
    let sched = if fused {
        IterSchedule::fused(
            "pcg_mesh_fused",
            lowering.components.clone(),
            &iteration,
            SRAM_BYTES - SRAM_RESERVE_FUSED,
        )?
    } else {
        IterSchedule::split(lowering.components.clone(), &iteration)
    };
    let sched = if schedule == Schedule::Prefetch {
        // The cross-iteration edge: the next spmv's halo issues once the
        // last axpy of the current iteration starts.
        sched.with_cross_dep("spmv", "axpy")?
    } else {
        sched
    };

    // ---- prefetch: pre-execute the led spmv variant ----------------------
    // Under Schedule::Prefetch, iteration k+1's halo EtherPhase issues
    // `lead` ns before the spmv's device start — during iteration k's
    // dot/axpy tail, after the second dot's all-reduce has freed the
    // links. The led programs are pre-executed like the classic ones
    // (timing is input-independent); the solve dispatches them from
    // iteration 2 on, when a previous tail exists to hide under. Values
    // are untouched — only the exposed seam wait shrinks, so the solve
    // is never slower than classic (pinned in `tests/prop_schedule.rs`).
    if schedule == Schedule::Prefetch {
        if let Some(dep) = sched.cross_deps().first().cloned() {
            let component_ns: BTreeMap<String, SimNs> = components
                .iter()
                .map(|(k, c)| (k.clone(), c.device_ns()))
                .collect();
            let lead = sched.prefetch_lead_ns(&dep, &component_ns, &cost.calib);
            let mut scratch = HostQueue::new(cost.calib.clone());
            let scratch_t0 = -cost.calib.kernel_launch_ns;
            let mut slowest: Option<ProgramOutcome> = None;
            for p in &lowering.spmv_per_die {
                if !p.work.ether.as_ref().is_some_and(|e| e.overlaps_local) {
                    continue; // nothing to prefetch (single die)
                }
                let mut pf = p.clone();
                pf.work.ether_lead_ns = lead;
                let outcome = scratch.run(&pf, cost, scratch_t0, &mut Profiler::disabled())?;
                if slowest
                    .as_ref()
                    .map_or(true, |s| outcome.device_ns() > s.device_ns())
                {
                    slowest = Some(outcome);
                }
            }
            if let Some(outcome) = slowest {
                components.insert("spmv_pf".to_string(), MeshComponent { outcome });
            }
        }
    }

    // ---- the solve (values on the logical grid, identical to the
    // single-die trajectory) ----------------------------------------------
    let mesh_dot = |a: &DistVector, bb: &DistVector| -> crate::Result<f32> {
        // Canonical accumulation order — one partial per logical core,
        // folded in row-major order, exactly like the single-die
        // `run_dot`; the chain rides the combine ring die by die.
        let mut v = 0.0f32;
        for (x, y) in a.iter().zip(bb) {
            v += engine.dot_partial(x, y)?;
        }
        Ok(v)
    };
    let apply = |x: &DistVector| -> crate::Result<DistVector> {
        match operator {
            Operator::Stencil(cfg) => mesh_stencil_values(
                logical_rows,
                cols,
                x,
                engine,
                cfg.coeffs,
                cfg.variant.halo_exchange,
            ),
            Operator::Sparse(op) => op.apply_values(x, engine),
        }
    };

    let mut queue = HostQueue::new(cost.calib.clone());
    queue.telemetry = Telemetry::new(opts.pcg.telemetry);
    let mut telemetry = Telemetry::new(opts.pcg.telemetry);
    let mut ledger = SolveLedger::new();
    // Components charged since the last residual sample (drained into each
    // SolverEvent, so an event's window is one full iteration of work).
    let mut iter_component_ns: Vec<(String, SimNs)> = Vec::new();
    // ONE link-occupancy tracker for the whole solve (satellite of the
    // telemetry layer): every component's Ethernet transfers replay into it
    // at their solve-absolute times, so per-link busy fractions are of the
    // solve window, not of each component's isolated window.
    let mut solve_eth = crate::device::EthSim::new();
    let mut breakdown = Breakdown::new();
    let mut phases_total = MeshPhaseBreakdown::default();
    let mut eth_ns_total: SimNs = 0.0;
    let mut eth_bytes_total: u64 = 0;
    // Peak per-link utilization over every component's Ethernet phase —
    // the contended-link headline number of the strong-scaling sweep.
    let eth_peak_link_util: f64 = components
        .values()
        .flat_map(|c| c.outcome.eth_link_util.iter())
        .map(|&(_, _, u)| u)
        .fold(0.0, f64::max);
    let mut readbacks: u64 = 0;
    let mut now: SimNs = 0.0;
    let mut spans = SolveSpans::new(opts.pcg.telemetry);

    let mut x: DistVector = b.iter().map(|blk| CoreBlock::zeros(blk.df, blk.nz())).collect();
    let mut r: DistVector = b.to_vec();

    {
        let pre = now;
        now = sched.begin(&mut queue, now)?;
        if now > pre {
            spans.host("enqueue(pcg_mesh_fused)", pre, now);
        }
    }
    // `component!(name)` dispatches component `name`;
    // `component!(name, key)` dispatches under schedule name `name` but
    // charges the pre-executed outcome stored at `key` — how the
    // prefetch schedule swaps in the led "spmv_pf" variant without
    // changing the declared iteration sequence.
    macro_rules! component {
        ($name:expr) => {
            component!($name, $name)
        };
        ($name:expr, $key:expr) => {{
            // A fault epoch overrides the clean pre-executed outcome with
            // a re-execution on the degraded topology (None = clean).
            let o = match frt.as_ref().and_then(|f| f.outcome($key)) {
                Some(faulted) => faulted,
                None => &components[$key].outcome,
            };
            let ns = o.device_ns();
            let pre: SimNs = now;
            now = sched.component(&mut queue, profiler, $name, ns, now)?;
            breakdown.add($name, ns);
            phases_total.compute_ns += o.dram_ns + o.riscv_ns + o.compute_ns;
            phases_total.noc_ns += o.data_movement_ns + o.reduce_ns + o.bcast_ns;
            phases_total.ether_ns += o.ether_ns;
            eth_ns_total += o.ether_ns;
            eth_bytes_total += o.eth_bytes;
            if !o.eth_transfers.is_empty() {
                // This dispatch's device window in solve time is
                // [now - ns, now]; the scratch execution recorded its
                // transfers relative to o.start.
                solve_eth.replay(&o.eth_transfers, (now - ns) - o.start);
            }
            if opts.pcg.telemetry {
                // Mirror the queue's clock advance with the same float
                // expression, then graft the component program's own span
                // graph (recorded at device start 0) into the window — the
                // graft's sink lands bit-exactly on `now`.
                let start_m = if fused {
                    pre + cost.calib.inter_kernel_gap_ns
                } else {
                    pre + cost.calib.kernel_launch_ns
                };
                debug_assert_eq!(start_m + ns, now);
                spans.host(if fused { "gap" } else { "enqueue" }, pre, start_m);
                spans.window_program($name, &o.spans);
                ledger.charge($name, &o.ledger, ns);
                telemetry.count("dispatches", &[("component", $name)], 1);
                telemetry.add("component_device_ns", &[("component", $name)], ns);
                telemetry.add(
                    "component_eth_bytes",
                    &[("component", $name)],
                    o.eth_bytes as f64,
                );
                telemetry.series("component_ns", &[("component", $name)], now, ns);
                iter_component_ns.push(($name.to_string(), ns));
            }
        }};
    }

    // Shared between both loop shapes: the residual-sample bookkeeping
    // (readback charge, history entry, telemetry event).
    macro_rules! residual_sample {
        ($rnorm:expr, $iter:expr) => {{
            history.push($rnorm);
            let pre = now;
            now = sched.residual_readback(&mut queue, now);
            if now > pre {
                spans.host("readback", pre, now);
            }
            if !sched.is_fused() {
                readbacks += 1;
            }
            if opts.pcg.telemetry {
                telemetry.series("residual", &[], now, $rnorm);
                telemetry.event(SolverEvent {
                    t_ns: now,
                    iter: $iter as u64,
                    residual: $rnorm,
                    launches: queue.stats.launches,
                    component_ns: std::mem::take(&mut iter_component_ns),
                    fault: fault_note.take(),
                });
            } else {
                fault_note = None;
            }
        }};
    }
    // Fault annotations accumulated since the last residual sample
    // (epoch transitions, SDC injections/detections, rollbacks); drained
    // into that sample's SolverEvent. Stays `None` through every
    // fault-free iteration, so clean JSONL streams are byte-identical.
    let mut fault_note: Option<String> = None;
    fn merge_note(cur: &mut Option<String>, note: String) {
        *cur = Some(match cur.take() {
            Some(prev) => format!("{prev};{note}"),
            None => note,
        });
    }

    let mut history = Vec::new();
    let mut iters = 0;
    let mut converged = false;
    if let Schedule::SStep(s) = schedule {
        // ---- s-step blocks (Chronopoulos–Gear, monomial basis) ----------
        // Each block: s halo'd spmvs build the basis, ONE combined
        // all-reduce ("gram") makes every scalar visible, and the host
        // reconstructs the block's s iterations without further network
        // rounds ("bupdate"). Convergence is lagged one block — ‖r‖ only
        // becomes visible at the combined round, so the entering residual
        // gates the block and a converged solve stops WITHOUT applying.
        let mut pprev: Vec<DistVector> = Vec::new();
        let mut qprev: Vec<DistVector> = Vec::new();
        let mut wprev: Vec<Vec<f64>> = vec![vec![0.0; s]; s];
        let mut wprev_chol: Option<sstep::CholFactor> = None;
        while iters < opts.pcg.max_iters {
            // Basis: vₖ = M⁻¹uₖ₋₁ (u₀ = r), uₖ = A vₖ.
            let mut v_cols: Vec<DistVector> = Vec::with_capacity(s);
            let mut u_cols: Vec<DistVector> = Vec::with_capacity(s);
            for k in 0..s {
                let seed = if k == 0 { &r } else { &u_cols[k - 1] };
                let vk = precond.apply(engine, seed)?;
                component!("precond");
                let uk = apply(&vk)?;
                component!("spmv");
                v_cols.push(vk);
                u_cols.push(uk);
            }
            // Gram blocks, host f64 — every entry folds in the same
            // canonical row-major order as `mesh_dot`, and all of them
            // ride the one combined "gram" all-reduce.
            let np = pprev.len();
            let mut c_mat = vec![vec![0.0f64; s]; s];
            let mut e_mat = vec![vec![0.0f64; s]; s];
            for i in 0..np {
                for j in 0..s {
                    c_mat[i][j] = mesh_dot(&qprev[i], &v_cols[j])? as f64;
                    e_mat[i][j] = mesh_dot(&pprev[i], &u_cols[j])? as f64;
                }
            }
            let mut f_mat = vec![vec![0.0f64; s]; s];
            for i in 0..s {
                for j in 0..s {
                    f_mat[i][j] = mesh_dot(&v_cols[i], &u_cols[j])? as f64;
                }
            }
            let mut g = vec![0.0f64; s];
            for (j, v) in v_cols.iter().enumerate() {
                g[j] = mesh_dot(v, &r)? as f64;
            }
            let rr = mesh_dot(&r, &r)? as f64;
            component!("gram");
            let rnorm = rr.max(0.0).sqrt();
            residual_sample!(rnorm, iters);
            if rnorm <= opts.pcg.tol_abs {
                converged = true;
                break;
            }
            if !rr.is_finite() || f_mat.iter().flatten().any(|v| !v.is_finite()) {
                break; // breakdown, like classic's non-finite p·q
            }
            // B = −Wᵖʳᵉᵛ⁻¹C keeps the new block A-conjugate to the
            // previous one; W = PᵀAP assembles from reduced blocks only.
            let b_mat = match &wprev_chol {
                Some(chol) if np > 0 => sstep::coupling_b(chol, &c_mat),
                _ => vec![vec![0.0; s]; s],
            };
            let mut p_cols = v_cols;
            let mut q_cols = u_cols;
            for j in 0..s {
                for i in 0..np {
                    let bij = b_mat[i][j] as f32;
                    if bij != 0.0 {
                        for (pb, ob) in p_cols[j].iter_mut().zip(&pprev[i]) {
                            engine.axpy_into(pb, bij, ob)?;
                        }
                        for (qb, ob) in q_cols[j].iter_mut().zip(&qprev[i]) {
                            engine.axpy_into(qb, bij, ob)?;
                        }
                    }
                }
            }
            let w = sstep::next_w(&f_mat, &c_mat, &e_mat, &wprev, &b_mat);
            let chol = sstep::cholesky(&w);
            if chol.rank == 0 {
                break; // W lost positive definiteness entirely
            }
            // Block step: W a = g, then x += Pa, r −= Qa.
            let a = chol.solve(&g);
            for j in 0..s {
                let aj = a[j] as f32;
                if aj != 0.0 {
                    for (xi, pi) in x.iter_mut().zip(&p_cols[j]) {
                        engine.axpy_into(xi, aj, pi)?;
                    }
                    for (ri, qi) in r.iter_mut().zip(&q_cols[j]) {
                        engine.axpy_into(ri, -aj, qi)?;
                    }
                }
            }
            component!("bupdate");
            pprev = p_cols;
            qprev = q_cols;
            wprev = w;
            wprev_chol = Some(chol);
            iters = (iters + s).min(opts.pcg.max_iters);
        }
    } else {
        // ---- classic / prefetch: Algorithm 1, one residual per
        // iteration. Prefetch changes WHEN the halo rides the wire (the
        // "spmv_pf" outcome, from iteration 2 on), never what any kernel
        // computes — the trajectory is bit-identical to classic.
        let mut z = precond.apply(engine, &r)?;
        let mut p = z.clone();
        let mut delta = mesh_dot(&r, &z)? as f64;
        // Iteration-0 checkpoint: die loss or a detected SDC can fire
        // before the first periodic save, and both need a restore target.
        if let Some(f) = frt.as_mut() {
            if f.checkpoint_enabled() {
                f.save(&x, &r, &p, delta, 0);
                let (cl, cns) = checkpoint_cost(mesh, tiles, df, cost);
                let pre = now;
                now += cns;
                spans.window_ledger("checkpoint", &cl, pre, now);
                if opts.pcg.telemetry {
                    ledger.charge("checkpoint", &cl, cns);
                    telemetry.count("checkpoints", &[], 1);
                }
            }
        }
        while iters < opts.pcg.max_iters {
            iters += 1;
            // Fault-epoch boundary: sample the plan; on a change, charge
            // the transport's retry-with-backoff penalty, swap in
            // re-lowered component outcomes, and — on die loss — restore
            // the last checkpoint (the lost die's state is gone).
            if let Some(f) = frt.as_mut() {
                if let Some(ch) = f.begin_iteration(now, cost)? {
                    merge_note(&mut fault_note, ch.annotation.clone());
                    if opts.pcg.telemetry {
                        telemetry.count("fault_epochs", &[], 1);
                    }
                    if ch.retry_ns > 0.0 {
                        let pre = now;
                        now += ch.retry_ns;
                        spans.mark("retry", "fault", Resource::Retry, pre, now);
                        if opts.pcg.telemetry {
                            ledger.add_retry(ch.retry_ns);
                        }
                    }
                    if ch.die_lost {
                        if let Some(cp) = f.rollback() {
                            x = cp.x;
                            r = cp.r;
                            p = cp.p;
                            delta = cp.delta;
                            merge_note(&mut fault_note, format!("rollback@{}", cp.iter));
                            let (rl, rns) = checkpoint_cost(mesh, tiles, df, cost);
                            let pre = now;
                            now += rns;
                            spans.window_ledger("rollback", &rl, pre, now);
                            if opts.pcg.telemetry {
                                ledger.charge("rollback", &rl, rns);
                                telemetry.count("rollbacks", &[], 1);
                            }
                        }
                    }
                }
            }
            // q = A p (stencil seam or sparse cut over Ethernet).
            let mut q = apply(&p)?;
            if let Some(f) = frt.as_ref() {
                if let Some(note) = f.maybe_corrupt(&mut q, iters) {
                    merge_note(&mut fault_note, note);
                }
            }
            if iters > 1 && components.contains_key("spmv_pf") {
                component!("spmv", "spmv_pf");
            } else {
                component!("spmv");
            }

            // α = δ / (p·q)
            let pq_v = mesh_dot(&p, &q)? as f64;
            component!("dot");
            if pq_v == 0.0 || !pq_v.is_finite() {
                break;
            }
            let alpha = (delta / pq_v) as f32;

            // x += α p ; r -= α q
            for (xi, pi) in x.iter_mut().zip(&p) {
                engine.axpy_into(xi, alpha, pi)?;
            }
            component!("axpy");
            for (ri, qi) in r.iter_mut().zip(&q) {
                engine.axpy_into(ri, -alpha, qi)?;
            }
            component!("axpy");

            // ||r||₂ (absolute, §3.3).
            let rr = mesh_dot(&r, &r)? as f64;
            component!("norm");
            let rnorm = rr.max(0.0).sqrt();
            residual_sample!(rnorm, iters);
            if rnorm <= opts.pcg.tol_abs {
                converged = true;
                break;
            }

            // z = M⁻¹ r
            z = precond.apply(engine, &r)?;
            component!("precond");

            // δ' = r·z ; β = δ'/δ
            let delta_new = mesh_dot(&r, &z)? as f64;
            component!("dot");
            if delta == 0.0 || !delta_new.is_finite() {
                break;
            }
            let beta = (delta_new / delta) as f32;
            delta = delta_new;

            // p = z + β p
            for (pi, zi) in p.iter_mut().zip(&z) {
                *pi = engine.axpy(zi, beta, pi)?;
            }
            component!("axpy");

            // Resilience tail (iteration boundary — the schedule cursor
            // is clean here): every check_interval iterations recompute
            // the TRUE residual ‖b − Ax‖ through the engine — charged as
            // one extra spmv + norm — and compare it to the recurrence
            // residual. Rounding keeps them together; an SDC tears them
            // apart. On drift, restore the last checkpoint; otherwise
            // save one when due — only verified states are ever saved.
            if let Some(f) = frt.as_mut() {
                let mut rolled_back = false;
                if f.check_due(iters) {
                    let ax = apply(&x)?;
                    let mut diff = Vec::with_capacity(b.len());
                    for (bi, ai) in b.iter().zip(&ax) {
                        diff.push(engine.axpy(bi, -1.0, ai)?);
                    }
                    let true_norm = (mesh_dot(&diff, &diff)? as f64).max(0.0).sqrt();
                    let (so_ledger, so_ns, no_ledger, no_ns) = {
                        let so = f.outcome("spmv").unwrap_or(&components["spmv"].outcome);
                        let no = f.outcome("norm").unwrap_or(&components["norm"].outcome);
                        (so.ledger.clone(), so.device_ns(), no.ledger.clone(), no.device_ns())
                    };
                    let pre = now;
                    now += so_ns;
                    spans.window_ledger("sdc_check", &so_ledger, pre, now);
                    let pre = now;
                    now += no_ns;
                    spans.window_ledger("sdc_check", &no_ledger, pre, now);
                    if opts.pcg.telemetry {
                        ledger.charge("sdc_check", &so_ledger, so_ns);
                        ledger.charge("sdc_check", &no_ledger, no_ns);
                    }
                    let drift =
                        (true_norm - rnorm).abs() / true_norm.max(rnorm).max(1e-30);
                    if drift > f.resilience.sdc_threshold {
                        merge_note(&mut fault_note, format!("sdc_detected@{iters}"));
                        if let Some(cp) = f.rollback() {
                            x = cp.x;
                            r = cp.r;
                            p = cp.p;
                            delta = cp.delta;
                            rolled_back = true;
                            merge_note(&mut fault_note, format!("rollback@{}", cp.iter));
                            let (rl, rns) = checkpoint_cost(mesh, tiles, df, cost);
                            let pre = now;
                            now += rns;
                            spans.window_ledger("rollback", &rl, pre, now);
                            if opts.pcg.telemetry {
                                ledger.charge("rollback", &rl, rns);
                                telemetry.count("rollbacks", &[], 1);
                            }
                        }
                    }
                }
                if !rolled_back && f.checkpoint_due(iters) {
                    f.save(&x, &r, &p, delta, iters);
                    let (cl, cns) = checkpoint_cost(mesh, tiles, df, cost);
                    let pre = now;
                    now += cns;
                    spans.window_ledger("checkpoint", &cl, pre, now);
                    if opts.pcg.telemetry {
                        ledger.charge("checkpoint", &cl, cns);
                        telemetry.count("checkpoints", &[], 1);
                    }
                }
            }
        }
    }

    breakdown.iterations = iters as u64;
    let it = iters.max(1) as f64;
    let dispatch_total = queue.stats.launch_ns
        + queue.stats.gap_ns
        + readbacks as f64 * cost.calib.residual_readback_ns;
    // Dispatch row closes the ledger: every time advance was either a
    // component charge or host dispatch, so ledger.total.total() == total_ns.
    if opts.pcg.telemetry {
        ledger.add_dispatch(dispatch_total);
        ledger.iterations = iters as u64;
        telemetry.merge(&queue.telemetry);
    }
    Ok(MeshPcgResult {
        x,
        iters,
        converged,
        residual_history: history,
        total_ns: now,
        per_iter_ns: if iters > 0 { now / it } else { 0.0 },
        eth_ns_per_iter: if iters > 0 { eth_ns_total / it } else { 0.0 },
        eth_bytes_total,
        eth_peak_link_util,
        breakdown,
        phases: MeshPhaseBreakdown {
            compute_ns: phases_total.compute_ns / it,
            noc_ns: phases_total.noc_ns / it,
            ether_ns: phases_total.ether_ns / it,
            dispatch_ns: dispatch_total / it,
        },
        launch: queue.stats.clone(),
        n_dies: mesh.n_dies,
        schedule,
        eth_link_util_solve: solve_eth.utilization(now),
        ledger,
        telemetry,
        spans: spans.finish(now),
        rollbacks: frt.as_ref().map_or(0, |f| f.rollbacks),
        fault_epochs: frt.as_ref().map_or(0, |f| f.epoch),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DataFormat;
    use crate::engine::NativeEngine;
    use crate::kernels::stencil::{StencilConfig, StencilVariant};
    use crate::solver::pcg::PcgVariant;

    fn stencil_cfg(df: DataFormat, tiles: usize) -> StencilConfig {
        StencilConfig {
            df,
            unit: crate::arch::ComputeUnit::for_format(df),
            tiles_per_core: tiles,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        }
    }

    #[test]
    fn mesh_pcg_reduces_residual_and_counts_ethernet() {
        let mesh = DeviceMesh::new(
            4,
            1,
            2,
            crate::device::MeshTopology::Line,
            crate::device::EthLink::default(),
        )
        .unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let tiles = 3;
        let b = mesh_dist_random(&mesh, tiles, DataFormat::Bf16, 5);
        let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
        opts.max_iters = 30;
        opts.tol_abs = 0.0;
        let mut prof = Profiler::disabled();
        let res = solve_pcg_mesh(
            &mesh,
            &b,
            &Operator::Stencil(stencil_cfg(DataFormat::Bf16, tiles)),
            &e,
            &cost,
            &MeshOptions::new(opts),
            &mut prof,
        )
        .unwrap();
        let first = res.residual_history[0];
        let min = res.residual_history.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min < 0.2 * first, "first {first} min {min}");
        assert!(res.eth_ns_per_iter > 0.0);
        assert!(res.eth_bytes_total > 0);
        assert_eq!(res.launch.launches, 1, "fused: one enqueue per solve");
        assert!(res.launch.gap_ns > 0.0);
        assert!(res.phases.ether_ns > 0.0 && res.phases.compute_ns > 0.0);
        // The halo phase saturates its busiest link for the whole window.
        assert!(res.eth_peak_link_util > 0.9 && res.eth_peak_link_util <= 1.0);
    }

    #[test]
    fn single_die_mesh_has_no_ethernet() {
        let mesh = DeviceMesh::n150(2, 2).unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = mesh_dist_random(&mesh, 2, DataFormat::Fp32, 3);
        let mut opts = PcgOptions::new(PcgVariant::SplitFp32);
        opts.max_iters = 5;
        opts.tol_abs = 0.0;
        let mut prof = Profiler::disabled();
        let res = solve_pcg_mesh(
            &mesh,
            &b,
            &Operator::Stencil(stencil_cfg(DataFormat::Fp32, 2)),
            &e,
            &cost,
            &opts.into(),
            &mut prof,
        )
        .unwrap();
        assert_eq!(res.eth_bytes_total, 0);
        assert_eq!(res.eth_ns_per_iter, 0.0);
        assert_eq!(res.eth_peak_link_util, 0.0);
        assert_eq!(res.launch.launches, 8 * 5, "split: 8 enqueues/iter");
    }

    #[test]
    fn capacity_enforced_per_die() {
        let mesh = DeviceMesh::n300(1, 1).unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = mesh_dist_random(&mesh, 165, DataFormat::Bf16, 1);
        let opts = PcgOptions::new(PcgVariant::FusedBf16);
        let mut prof = Profiler::disabled();
        assert!(solve_pcg_mesh(
            &mesh,
            &b,
            &Operator::Stencil(stencil_cfg(DataFormat::Bf16, 165)),
            &e,
            &cost,
            &opts.into(),
            &mut prof,
        )
        .is_err());
    }
}
