//! Preconditioned conjugate gradient (Algorithm 1, §7) composed from the
//! three kernels, in the paper's two implementations:
//!
//! - **Fused BF16/FPU** (§7.1): all operations and iterations live in a
//!   single kernel; the residual norm is reduced and multicast on-device
//!   every iteration and never leaves SRAM. One host launch total.
//! - **Split FP32/SFPU** (§7.1): each component (SpMV, dots, axpys, norm,
//!   preconditioner) is its own kernel launch; the residual norm goes back
//!   to the host through DRAM every iteration.
//!
//! Following §3.3, convergence is checked on the **absolute** residual
//! norm (the subnormal flush makes relative residuals unreliable).

use crate::arch::{ComputeUnit, DataFormat};
use crate::device::TensixGrid;
use crate::engine::{ComputeEngine, StencilCoeffs};
use crate::kernels::eltwise::block_op_ns;
use crate::kernels::reduction::{run_dot, DotConfig, DotMethod};
use crate::kernels::stencil::{run_stencil, StencilConfig, StencilVariant};
use crate::noc::RoutePattern;
use crate::profiler::{Breakdown, Profiler};
use crate::solver::jacobi::JacobiPreconditioner;
use crate::solver::problem::{dist_zeros, DistVector, Problem};
use crate::timing::cost::{CostModel, PipelineMode, TileOpKind};
use crate::timing::SimNs;
use crate::ttm::{HostQueue, LaunchStats, Program};

/// The paper's two PCG implementations (§7.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PcgVariant {
    FusedBf16,
    SplitFp32,
}

impl PcgVariant {
    pub fn df(self) -> DataFormat {
        match self {
            PcgVariant::FusedBf16 => DataFormat::Bf16,
            PcgVariant::SplitFp32 => DataFormat::Fp32,
        }
    }

    pub fn unit(self) -> ComputeUnit {
        match self {
            PcgVariant::FusedBf16 => ComputeUnit::Fpu,
            PcgVariant::SplitFp32 => ComputeUnit::Sfpu,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PcgVariant::FusedBf16 => "Wormhole BF16 (fused, FPU)",
            PcgVariant::SplitFp32 => "Wormhole FP32 (split, SFPU)",
        }
    }
}

impl std::str::FromStr for PcgVariant {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "bf16" | "fused" | "fused-bf16" => Ok(PcgVariant::FusedBf16),
            "fp32" | "split" | "split-fp32" => Ok(PcgVariant::SplitFp32),
            _ => Err(format!("unknown PCG variant '{s}' (expected bf16|fp32)")),
        }
    }
}

#[derive(Debug, Clone)]
pub struct PcgOptions {
    pub variant: PcgVariant,
    pub max_iters: usize,
    /// Absolute residual threshold (§3.3).
    pub tol_abs: f64,
    pub dot_method: DotMethod,
    pub dot_pattern: RoutePattern,
    /// Use the Jacobi preconditioner (§7); `false` = plain CG ablation.
    pub precondition: bool,
}

impl PcgOptions {
    pub fn new(variant: PcgVariant) -> Self {
        Self {
            variant,
            max_iters: 100,
            tol_abs: 1e-6,
            dot_method: DotMethod::ReduceThenSend,
            dot_pattern: RoutePattern::Naive,
            precondition: true,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PcgResult {
    pub x: DistVector,
    pub iters: usize,
    pub converged: bool,
    pub residual_history: Vec<f64>,
    /// Simulated wall time of the whole solve.
    pub total_ns: SimNs,
    pub per_iter_ns: SimNs,
    /// Per-component device time (Fig 13).
    pub breakdown: Breakdown,
    pub launch: LaunchStats,
}

/// Solve `A x = b` (A = the 7-point Laplacian, zero Dirichlet) with PCG.
pub fn solve(
    grid: &TensixGrid,
    problem: &Problem,
    b: &DistVector,
    engine: &dyn ComputeEngine,
    cost: &CostModel,
    opts: &PcgOptions,
    profiler: &mut Profiler,
) -> crate::Result<PcgResult> {
    let fused = opts.variant == PcgVariant::FusedBf16;
    problem.validate_capacity(fused)?;
    if problem.df != opts.variant.df() {
        return Err(crate::SimError::BadProblem {
            what: format!(
                "problem data format {} does not match variant {}",
                problem.df,
                opts.variant.label()
            ),
        });
    }
    let df = opts.variant.df();
    let unit = opts.variant.unit();
    let tiles = problem.tiles_per_core;
    let calib = &cost.calib;
    let mut queue = HostQueue::new(calib.clone());
    let mut breakdown = Breakdown::new();
    let mut now: SimNs = 0.0;

    // Component timing helpers -------------------------------------------
    let stencil_cfg = StencilConfig {
        df,
        unit,
        tiles_per_core: tiles,
        variant: StencilVariant::FULL,
        coeffs: StencilCoeffs::LAPLACIAN,
    };
    let dot_cfg = DotConfig {
        method: opts.dot_method,
        pattern: opts.dot_pattern,
        df,
        unit,
        tiles_per_core: tiles,
    };
    let axpy_ns = block_op_ns(cost, unit, df, TileOpKind::EltwiseBinary, tiles, PipelineMode::Streamed);
    let scale_ns = block_op_ns(cost, unit, df, TileOpKind::EltwiseUnary, tiles, PipelineMode::Streamed);

    // Split-kernel component boundary: host launch. Fused: device-side
    // phase gap (§7.3 Tracy observation).
    let programs: std::collections::BTreeMap<&str, Program> = ["spmv", "dot", "axpy", "norm", "precond"]
        .iter()
        .map(|n| (*n, Program::standard(n)))
        .collect();
    macro_rules! component {
        ($name:expr, $ns:expr) => {{
            let ns: SimNs = $ns;
            if fused {
                now = queue.kernel_gap(now);
            } else {
                now = queue.enqueue(&programs[$name], now)?;
            }
            profiler.record($name, "device", now, now + ns);
            breakdown.add($name, ns);
            now += ns;
        }};
    }

    // ---- setup (x0 = 0 ⇒ r0 = b) ----------------------------------------
    let precond = if opts.precondition {
        JacobiPreconditioner::from_coeffs(StencilCoeffs::LAPLACIAN)?
    } else {
        JacobiPreconditioner::identity()
    };
    let mut x = dist_zeros(problem);
    let mut r: DistVector = b.to_vec();
    let apply_precond = |engine: &dyn ComputeEngine, r: &DistVector| -> crate::Result<DistVector> {
        r.iter().map(|blk| precond.apply(engine, blk)).collect()
    };
    let mut z = apply_precond(engine, &r)?;
    let mut p = z.clone();
    // δ0 = r·z
    let mut delta = run_dot(grid.rows, grid.cols, &dot_cfg, &r, &z, engine, cost)?.value as f64;

    // Fused variant: one launch for the whole solve.
    if fused {
        now = queue.enqueue(&Program::standard("pcg_fused"), now)?;
    }

    let mut history = Vec::new();
    let mut iters = 0;
    let mut converged = false;
    while iters < opts.max_iters {
        iters += 1;
        // q = A p (the stencil SpMV, §6).
        let (q, spmv_t) = run_stencil(grid, &stencil_cfg, &p, engine, cost)?;
        component!("spmv", spmv_t.iter_ns);

        // α = δ / (p·q)
        let pq = run_dot(grid.rows, grid.cols, &dot_cfg, &p, &q, engine, cost)?;
        component!("dot", pq.total_ns);
        let pq_v = pq.value as f64;
        if pq_v == 0.0 || !pq_v.is_finite() {
            break; // breakdown (numerically singular at this precision)
        }
        let alpha = (delta / pq_v) as f32;

        // x += α p ; r -= α q
        for (xi, pi) in x.iter_mut().zip(&p) {
            engine.axpy_into(xi, alpha, pi)?;
        }
        component!("axpy", axpy_ns);
        for (ri, qi) in r.iter_mut().zip(&q) {
            engine.axpy_into(ri, -alpha, qi)?;
        }
        component!("axpy", axpy_ns);

        // ||r||₂ (absolute, §3.3).
        let rr = run_dot(grid.rows, grid.cols, &dot_cfg, &r, &r, engine, cost)?;
        component!("norm", rr.total_ns);
        let rnorm = (rr.value.max(0.0) as f64).sqrt();
        history.push(rnorm);
        if !fused {
            now = queue.residual_readback(now);
        }
        if rnorm <= opts.tol_abs {
            converged = true;
            break;
        }

        // z = M⁻¹ r
        z = apply_precond(engine, &r)?;
        component!("precond", scale_ns);

        // δ' = r·z ; β = δ'/δ
        let rz = run_dot(grid.rows, grid.cols, &dot_cfg, &r, &z, engine, cost)?;
        component!("dot", rz.total_ns);
        let delta_new = rz.value as f64;
        if delta == 0.0 || !delta_new.is_finite() {
            break;
        }
        let beta = (delta_new / delta) as f32;
        delta = delta_new;

        // p = z + β p
        for (pi, zi) in p.iter_mut().zip(&z) {
            *pi = engine.axpy(zi, beta, pi)?;
        }
        component!("axpy", axpy_ns);
    }

    breakdown.iterations = iters as u64;
    Ok(PcgResult {
        x,
        iters,
        converged,
        residual_history: history,
        total_ns: now,
        per_iter_ns: if iters > 0 { now / iters as f64 } else { 0.0 },
        breakdown,
        launch: queue.stats.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::solver::problem::{apply_laplacian_global, dist_random, dist_to_global};

    fn residual_vs_truth(p: &Problem, x: &DistVector, b: &DistVector) -> f64 {
        let xg = dist_to_global(p, x);
        let bg = dist_to_global(p, b);
        let ax = apply_laplacian_global(p, &xg);
        ax.iter()
            .zip(&bg)
            .map(|(a, &bb)| (a - bb as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }

    #[test]
    fn fp32_pcg_converges_on_small_problem() {
        let p = Problem::new(2, 2, 4, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dist_random(&p, 7);
        let mut opts = PcgOptions::new(PcgVariant::SplitFp32);
        opts.max_iters = 400;
        opts.tol_abs = 1e-3;
        let mut prof = Profiler::disabled();
        let res = solve(&grid, &p, &b, &e, &cost, &opts, &mut prof).unwrap();
        assert!(res.converged, "residual history tail: {:?}", &res.residual_history.iter().rev().take(3).collect::<Vec<_>>());
        // True residual (independent oracle) close to the reported one.
        let true_r = residual_vs_truth(&p, &res.x, &b);
        assert!(true_r < 5e-3, "true residual {true_r}");
        // Residual history is (mostly) decreasing.
        let first = res.residual_history[0];
        let last = *res.residual_history.last().unwrap();
        assert!(last < 1e-2 * first);
    }

    #[test]
    fn bf16_pcg_reduces_residual() {
        // BF16 stalls well above FP32 accuracy but must make progress.
        let p = Problem::new(2, 2, 4, DataFormat::Bf16);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dist_random(&p, 8);
        let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
        opts.max_iters = 60;
        opts.tol_abs = 0.0; // run all iterations
        let mut prof = Profiler::disabled();
        let res = solve(&grid, &p, &b, &e, &cost, &opts, &mut prof).unwrap();
        let first = res.residual_history[0];
        let min = res
            .residual_history
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(
            min < 0.15 * first,
            "BF16 PCG should reduce the residual substantially: first {first}, min {min}"
        );
    }

    #[test]
    fn split_charges_launches_fused_does_not() {
        let pb = Problem::new(2, 2, 4, DataFormat::Bf16);
        let ps = Problem::new(2, 2, 4, DataFormat::Fp32);
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let mut prof = Profiler::disabled();

        let mut o_f = PcgOptions::new(PcgVariant::FusedBf16);
        o_f.max_iters = 5;
        o_f.tol_abs = 0.0;
        let rf = solve(&pb.make_grid().unwrap(), &pb, &dist_random(&pb, 1), &e, &cost, &o_f, &mut prof).unwrap();
        // One launch for the whole fused solve.
        assert_eq!(rf.launch.launches, 1);
        assert!(rf.launch.gap_ns > 0.0);

        let mut o_s = PcgOptions::new(PcgVariant::SplitFp32);
        o_s.max_iters = 5;
        o_s.tol_abs = 0.0;
        let rs = solve(&ps.make_grid().unwrap(), &ps, &dist_random(&ps, 1), &e, &cost, &o_s, &mut prof).unwrap();
        // 8 component launches per iteration.
        assert_eq!(rs.launch.launches, 8 * 5);
    }

    #[test]
    fn variant_format_mismatch_rejected() {
        let p = Problem::new(1, 1, 2, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let opts = PcgOptions::new(PcgVariant::FusedBf16);
        let b = dist_random(&p, 1);
        let mut prof = Profiler::disabled();
        assert!(solve(&grid, &p, &b, &e, &cost, &opts, &mut prof).is_err());
    }

    #[test]
    fn capacity_enforced_for_variant() {
        // 100 tiles FP32 split exceeds the 64-tile §7.2 ceiling.
        let p = Problem::new(1, 1, 100, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let opts = PcgOptions::new(PcgVariant::SplitFp32);
        let b = dist_random(&p, 1);
        let mut prof = Profiler::disabled();
        assert!(solve(&grid, &p, &b, &e, &cost, &opts, &mut prof).is_err());
    }

    #[test]
    fn breakdown_components_recorded() {
        let p = Problem::new(2, 2, 4, DataFormat::Bf16);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
        opts.max_iters = 3;
        opts.tol_abs = 0.0;
        let mut prof = Profiler::new();
        let res = solve(&grid, &p, &dist_random(&p, 2), &e, &cost, &opts, &mut prof).unwrap();
        for c in ["spmv", "dot", "axpy", "norm", "precond"] {
            assert!(res.breakdown.per_iter(c) > 0.0, "component {c} missing");
        }
        // SpMV is the computationally heavy component (§7.3).
        assert!(res.breakdown.per_iter("spmv") > res.breakdown.per_iter("axpy"));
        assert!(!prof.zones().is_empty());
    }
}
