//! Preconditioned conjugate gradient (Algorithm 1, §7) composed from the
//! numerical kernels, in the paper's two implementations:
//!
//! - **Fused BF16/FPU** (§7.1): all operations and iterations live in a
//!   single kernel; the residual norm is reduced and multicast on-device
//!   every iteration and never leaves SRAM. One host launch total.
//! - **Split FP32/SFPU** (§7.1): each component (SpMV, dots, axpys, norm,
//!   preconditioner) is its own kernel launch; the residual norm goes back
//!   to the host through DRAM every iteration.
//!
//! The matrix apply is abstracted behind [`Operator`]: the paper's
//! matrix-free 7-point stencil (§6) is one implementor, and the general
//! sparse SpMV ([`crate::kernels::spmv`]) is the other — so the same
//! solver runs on arbitrary SPD matrices. On the generated 3D Laplacian
//! over the stencil-aligned partition the two implementors produce
//! bit-identical values, so both paths walk the same iterate trajectory
//! (pinned by a test below).
//!
//! Following §3.3, convergence is checked on the **absolute** residual
//! norm (the subnormal flush makes relative residuals unreliable).

use std::collections::BTreeMap;

use crate::arch::constants::{SRAM_BYTES, SRAM_RESERVE_FUSED};
use crate::arch::{ComputeUnit, DataFormat};
use crate::device::TensixGrid;
use crate::engine::{ComputeEngine, CoreBlock, StencilCoeffs};
use crate::kernels::eltwise::{block_op_ns, lower_block_op};
use crate::kernels::reduction::{lower_dot_as, run_dot, DotConfig, DotMethod};
use crate::kernels::spmv::SpmvOperator;
use crate::kernels::stencil::{lower_stencil, run_stencil, StencilConfig, StencilVariant};
use crate::noc::RoutePattern;
use crate::profiler::{Breakdown, Profiler};
use crate::solver::jacobi::JacobiPreconditioner;
use crate::solver::problem::{DistVector, Problem};
use crate::telemetry::{ResourceLedger, SolveLedger, SolverEvent, SpanGraph, Telemetry};
use crate::tile::EltwiseOp;
use crate::timing::cost::{CostModel, PipelineMode, TileOpKind};
use crate::timing::SimNs;
use crate::ttm::{HostQueue, IterSchedule, LaunchStats, Program, Schedule, SolveSpans};

/// The paper's two PCG implementations (§7.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PcgVariant {
    FusedBf16,
    SplitFp32,
}

/// How the per-iteration component programs are dispatched. `Auto`
/// derives the paper's pairing (BF16 → fused, FP32 → split); the forced
/// modes decouple precision from launch accounting for ablations — the
/// values are engine-side and identical either way, which the
/// fused-vs-split trajectory pins exploit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FusionMode {
    #[default]
    Auto,
    ForceSplit,
    ForceFused,
}

/// The per-iteration component dispatch order of Algorithm 1 (§7),
/// shared by the single-die and dual-die solvers.
pub(crate) const PCG_ITERATION: [&str; 8] = [
    "spmv", "dot", "axpy", "axpy", "norm", "precond", "dot", "axpy",
];

/// Lower the non-operator per-iteration PCG component programs (dot,
/// norm, axpy, precond) for a `rows`×`cols` sub-grid — the one
/// construction both the single-die and dual-die solvers schedule from.
#[allow(clippy::too_many_arguments)]
pub(crate) fn lower_pcg_support_components(
    rows: usize,
    cols: usize,
    dot_cfg: &DotConfig,
    unit: ComputeUnit,
    df: DataFormat,
    tiles: usize,
    precond_kind: TileOpKind,
    cost: &CostModel,
) -> Vec<Program> {
    vec![
        lower_dot_as("dot", rows, cols, dot_cfg, cost),
        lower_dot_as("norm", rows, cols, dot_cfg, cost),
        lower_block_op(
            "axpy",
            rows,
            cols,
            cost,
            unit,
            df,
            TileOpKind::EltwiseBinary,
            tiles,
            PipelineMode::Streamed,
        ),
        lower_block_op(
            "precond",
            rows,
            cols,
            cost,
            unit,
            df,
            precond_kind,
            tiles,
            PipelineMode::Streamed,
        ),
    ]
}

impl PcgVariant {
    pub fn df(self) -> DataFormat {
        match self {
            PcgVariant::FusedBf16 => DataFormat::Bf16,
            PcgVariant::SplitFp32 => DataFormat::Fp32,
        }
    }

    pub fn unit(self) -> ComputeUnit {
        match self {
            PcgVariant::FusedBf16 => ComputeUnit::Fpu,
            PcgVariant::SplitFp32 => ComputeUnit::Sfpu,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PcgVariant::FusedBf16 => "Wormhole BF16 (fused, FPU)",
            PcgVariant::SplitFp32 => "Wormhole FP32 (split, SFPU)",
        }
    }
}

impl std::str::FromStr for PcgVariant {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "bf16" | "fused" | "fused-bf16" => Ok(PcgVariant::FusedBf16),
            "fp32" | "split" | "split-fp32" => Ok(PcgVariant::SplitFp32),
            _ => Err(format!("unknown PCG variant '{s}' (expected bf16|fp32)")),
        }
    }
}

/// The matrix-apply abstraction: what `q = A p` means for this solve.
#[derive(Debug)]
pub enum Operator<'a> {
    /// The matrix-free 7-point stencil (§6) — the paper's path.
    Stencil(StencilConfig),
    /// A general sparse matrix through the SELL SpMV kernel.
    Sparse(&'a SpmvOperator),
}

impl Operator<'_> {
    /// One application `A x`: values through the engine, simulated time of
    /// the slowest core as the component cost.
    pub fn apply(
        &self,
        grid: &TensixGrid,
        x: &DistVector,
        engine: &dyn ComputeEngine,
        cost: &CostModel,
    ) -> crate::Result<(DistVector, SimNs)> {
        match self {
            Operator::Stencil(cfg) => {
                let (y, t) = run_stencil(grid, cfg, x, engine, cost)?;
                Ok((y, t.iter_ns))
            }
            Operator::Sparse(op) => {
                let (y, t) = op.apply(grid, x, engine, cost)?;
                Ok((y, t.total_ns))
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Operator::Stencil(_) => "stencil (matrix-free)",
            Operator::Sparse(_) => "sparse (SELL SpMV)",
        }
    }

    /// Lower the matrix-apply to its component program (named "spmv" in
    /// the iteration schedule for both implementors).
    pub fn lower(&self, grid: &TensixGrid, cost: &CostModel) -> Program {
        match self {
            Operator::Stencil(cfg) => {
                let mut p = lower_stencil(grid, cfg, cost);
                p.name = "spmv".to_string();
                p
            }
            Operator::Sparse(op) => op.lower(cost),
        }
    }

    /// Build the Jacobi preconditioner M = diag(A) for this operator
    /// (shared with the mesh solver).
    pub(crate) fn jacobi(&self, df: DataFormat, enabled: bool) -> crate::Result<Precond> {
        if !enabled {
            return Ok(Precond::Scalar(JacobiPreconditioner::identity()));
        }
        match self {
            Operator::Stencil(cfg) => {
                Ok(Precond::Scalar(JacobiPreconditioner::from_coeffs(cfg.coeffs)?))
            }
            Operator::Sparse(op) => {
                // A uniform diagonal degrades to the same scalar scale the
                // stencil path uses (bit-identical application); otherwise
                // apply an element-wise multiply by 1/diag.
                if op.diagonal().iter().any(|&d| d == 0.0) {
                    return Err(crate::SimError::BadProblem {
                        what: "Jacobi preconditioner needs a nonzero diagonal".to_string(),
                    });
                }
                if let Some(d) = op.uniform_diagonal() {
                    Ok(Precond::Scalar(JacobiPreconditioner { inv_diag: 1.0 / d }))
                } else {
                    let inv: Vec<f32> = op.diagonal().iter().map(|&d| 1.0 / d).collect();
                    Ok(Precond::PerElement(op.part.dist_from_global(df, &inv)))
                }
            }
        }
    }
}

/// Jacobi preconditioner application form (shared with the mesh solver).
pub(crate) enum Precond {
    /// Uniform diagonal: z = (1/d) · r (one eltwise scale — §7).
    Scalar(JacobiPreconditioner),
    /// General diagonal: z = r ⊙ inv_diag (one eltwise multiply).
    PerElement(DistVector),
}

impl Precond {
    pub(crate) fn apply(&self, engine: &dyn ComputeEngine, r: &DistVector) -> crate::Result<DistVector> {
        match self {
            Precond::Scalar(j) => r.iter().map(|blk| j.apply(engine, blk)).collect(),
            Precond::PerElement(inv) => r
                .iter()
                .zip(inv)
                .map(|(blk, d)| engine.eltwise(EltwiseOp::Mul, blk, d))
                .collect(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct PcgOptions {
    pub variant: PcgVariant,
    pub max_iters: usize,
    /// Absolute residual threshold (§3.3).
    pub tol_abs: f64,
    pub dot_method: DotMethod,
    pub dot_pattern: RoutePattern,
    /// Use the Jacobi preconditioner (§7); `false` = plain CG ablation.
    pub precondition: bool,
    /// Launch-schedule override (default: derived from the variant).
    pub fusion: FusionMode,
    /// Communication-avoiding iteration schedule
    /// ([`crate::ttm::Schedule`]): `Classic` (default), `Prefetch`
    /// (iteration k+1's halo issues under iteration k's dot/axpy tail —
    /// values bit-identical, never slower), or `SStep(s)` (one combined
    /// all-reduce round every s iterations — values drift-bounded, not
    /// bit-identical). Only the mesh solver has Ethernet phases to
    /// reschedule; the single-die solver accepts and ignores it.
    pub schedule: Schedule,
    /// Record solve telemetry (metrics, per-iteration events, ledger
    /// attribution). Purely observational — solver values and timings are
    /// bit-identical either way (pinned by `tests/prop_telemetry.rs`).
    pub telemetry: bool,
}

impl PcgOptions {
    pub fn new(variant: PcgVariant) -> Self {
        Self {
            variant,
            max_iters: 100,
            tol_abs: 1e-6,
            dot_method: DotMethod::ReduceThenSend,
            dot_pattern: RoutePattern::Naive,
            precondition: true,
            fusion: FusionMode::Auto,
            schedule: Schedule::Classic,
            telemetry: true,
        }
    }

    /// Whether this solve runs the fused schedule (§7.1).
    pub fn fused(&self) -> bool {
        match self.fusion {
            FusionMode::Auto => self.variant == PcgVariant::FusedBf16,
            FusionMode::ForceSplit => false,
            FusionMode::ForceFused => true,
        }
    }
}

#[derive(Debug, Clone)]
pub struct PcgResult {
    pub x: DistVector,
    pub iters: usize,
    pub converged: bool,
    pub residual_history: Vec<f64>,
    /// Simulated wall time of the whole solve.
    pub total_ns: SimNs,
    pub per_iter_ns: SimNs,
    /// Per-component device time (Fig 13).
    pub breakdown: Breakdown,
    pub launch: LaunchStats,
    /// Per-resource attribution of `total_ns` (conserves by construction;
    /// see [`crate::telemetry::SolveLedger`]).
    pub ledger: SolveLedger,
    /// Metrics + per-iteration solver events (empty when
    /// [`PcgOptions::telemetry`] is off).
    pub telemetry: Telemetry,
    /// Causal span graph of the solve (host dispatch chain + per-window
    /// resource chains); its critical path equals `total_ns` exactly.
    /// Empty when [`PcgOptions::telemetry`] is off.
    pub spans: SpanGraph,
}

impl PcgResult {
    /// Modeled host enqueues per iteration (the §7.1 accounting: the
    /// split schedule pays one per component, the fused schedule one per
    /// solve).
    pub fn launches_per_iter(&self) -> f64 {
        self.launch.launches as f64 / self.iters.max(1) as f64
    }

    /// Critical-path analysis of the recorded span graph (per-resource
    /// critical fractions and slack). Errors when telemetry was off.
    pub fn critpath(&self) -> Result<crate::telemetry::CritPathReport, String> {
        crate::telemetry::analyze(&self.spans)
    }
}

/// Solve `A x = b` with A = the 7-point Laplacian (zero Dirichlet) — the
/// paper's configuration. Validates the §7.2 capacity model, then runs
/// [`solve_operator`] with the stencil operator.
pub fn solve(
    grid: &TensixGrid,
    problem: &Problem,
    b: &DistVector,
    engine: &dyn ComputeEngine,
    cost: &CostModel,
    opts: &PcgOptions,
    profiler: &mut Profiler,
) -> crate::Result<PcgResult> {
    problem.validate_capacity(opts.fused())?;
    if problem.df != opts.variant.df() {
        return Err(crate::SimError::BadProblem {
            what: format!(
                "problem data format {} does not match variant {}",
                problem.df,
                opts.variant.label()
            ),
        });
    }
    let stencil_cfg = StencilConfig {
        df: opts.variant.df(),
        unit: opts.variant.unit(),
        tiles_per_core: problem.tiles_per_core,
        variant: StencilVariant::FULL,
        coeffs: StencilCoeffs::LAPLACIAN,
    };
    solve_operator(grid, b, &Operator::Stencil(stencil_cfg), engine, cost, opts, profiler)
}

/// Solve `A x = b` with PCG for any [`Operator`]. Sparse operators carry
/// their own §7.2-style SRAM validation (performed at construction).
pub fn solve_operator(
    grid: &TensixGrid,
    b: &DistVector,
    operator: &Operator<'_>,
    engine: &dyn ComputeEngine,
    cost: &CostModel,
    opts: &PcgOptions,
    profiler: &mut Profiler,
) -> crate::Result<PcgResult> {
    let fused = opts.fused();
    let df = opts.variant.df();
    let unit = opts.variant.unit();
    if b.len() != grid.n_cores() {
        return Err(crate::SimError::BadProblem {
            what: format!("rhs has {} blocks for {} cores", b.len(), grid.n_cores()),
        });
    }
    let Some(first) = b.first() else {
        return Err(crate::SimError::BadProblem {
            what: "empty right-hand side".to_string(),
        });
    };
    if first.df != df {
        return Err(crate::SimError::BadProblem {
            what: format!(
                "rhs data format {} does not match variant {}",
                first.df,
                opts.variant.label()
            ),
        });
    }
    let tiles = first.nz();
    let calib = &cost.calib;
    let mut queue = HostQueue::new(calib.clone());
    queue.telemetry = Telemetry::new(opts.telemetry);
    let mut telemetry = Telemetry::new(opts.telemetry);
    let mut ledger = SolveLedger::new();
    let mut readbacks: u64 = 0;
    // Components charged since the last residual sample (drained into each
    // SolverEvent, so an event's window is one full iteration of work).
    let mut iter_component_ns: Vec<(String, SimNs)> = Vec::new();
    let mut breakdown = Breakdown::new();
    let mut now: SimNs = 0.0;
    let mut spans = SolveSpans::new(opts.telemetry);

    // Component timing helpers -------------------------------------------
    let dot_cfg = DotConfig {
        method: opts.dot_method,
        pattern: opts.dot_pattern,
        df,
        unit,
        tiles_per_core: tiles,
    };
    let axpy_ns = block_op_ns(cost, unit, df, TileOpKind::EltwiseBinary, tiles, PipelineMode::Streamed);
    let scale_ns = block_op_ns(cost, unit, df, TileOpKind::EltwiseUnary, tiles, PipelineMode::Streamed);

    // ---- setup (x0 = 0 ⇒ r0 = b) ----------------------------------------
    let precond = operator.jacobi(df, opts.precondition)?;
    // Scalar Jacobi is a unary scale (§7); the per-element form multiplies
    // by a resident inv-diag vector — a two-operand eltwise op.
    let (precond_ns, precond_kind) = match &precond {
        Precond::Scalar(_) => (scale_ns, TileOpKind::EltwiseUnary),
        Precond::PerElement(_) => (axpy_ns, TileOpKind::EltwiseBinary),
    };

    // Lower the per-iteration component programs once; the schedule
    // derives the §7.1 launch accounting from them (split: one enqueue
    // per component dispatch; fused: one enqueue per solve + §7.3
    // device-side gaps at component boundaries).
    let mut component_programs = vec![operator.lower(grid, cost)];
    component_programs.extend(lower_pcg_support_components(
        grid.rows,
        grid.cols,
        &dot_cfg,
        unit,
        df,
        tiles,
        precond_kind,
        cost,
    ));
    // Scratch pre-execution of each lowered component at t=0 (no queue, no
    // profiler, never dispatched): its per-resource ledger is what the solve
    // loop charges against the per-dispatch component times. Skipped when
    // telemetry is off — the ledger then stays empty.
    let mut component_ledgers: BTreeMap<String, ResourceLedger> = BTreeMap::new();
    if opts.telemetry {
        for p in &component_programs {
            let out = crate::ttm::exec::execute_program(p, cost, 0.0)?;
            component_ledgers.insert(p.name.clone(), out.ledger);
        }
    }
    let sched = if fused {
        IterSchedule::fused(
            "pcg_fused",
            component_programs,
            &PCG_ITERATION,
            SRAM_BYTES - SRAM_RESERVE_FUSED,
        )?
    } else {
        IterSchedule::split(component_programs, &PCG_ITERATION)
    };
    macro_rules! component {
        ($name:expr, $ns:expr) => {{
            let ns: SimNs = $ns;
            let pre: SimNs = now;
            now = sched.component(&mut queue, profiler, $name, ns, now)?;
            breakdown.add($name, ns);
            if opts.telemetry {
                // Mirror the queue's clock advance with the same float
                // expression, so the span chain lands bit-exactly on `now`.
                let start_m = if fused {
                    pre + calib.inter_kernel_gap_ns
                } else {
                    pre + calib.kernel_launch_ns
                };
                debug_assert_eq!(start_m + ns, now);
                spans.host(if fused { "gap" } else { "enqueue" }, pre, start_m);
                spans.window_ledger($name, &component_ledgers[$name], start_m, now);
                ledger.charge($name, &component_ledgers[$name], ns);
                telemetry.count("dispatches", &[("component", $name)], 1);
                telemetry.add("component_device_ns", &[("component", $name)], ns);
                telemetry.series("component_ns", &[("component", $name)], now, ns);
                iter_component_ns.push(($name.to_string(), ns));
            }
        }};
    }
    let mut x: DistVector = b.iter().map(|blk| CoreBlock::zeros(blk.df, blk.nz())).collect();
    let mut r: DistVector = b.to_vec();
    let mut z = precond.apply(engine, &r)?;
    let mut p = z.clone();
    // δ0 = r·z
    let mut delta = run_dot(grid.rows, grid.cols, &dot_cfg, &r, &z, engine, cost)?.value as f64;

    // Fused schedule: one launch for the whole solve.
    {
        let pre = now;
        now = sched.begin(&mut queue, now)?;
        if now > pre {
            spans.host("enqueue(pcg_fused)", pre, now);
        }
    }

    let mut history = Vec::new();
    let mut iters = 0;
    let mut converged = false;
    // The run_*/apply calls below re-lower their (input-independent)
    // programs every iteration. That is deliberate: the wrappers stay the
    // single execution path for values + timing, and at sub-grid scale
    // (≤ 56 cores) the host-side rebuild is noise next to the engine's
    // value computation. Hoist to pre-executed ProgramOutcomes only if a
    // profile ever shows otherwise.
    while iters < opts.max_iters {
        iters += 1;
        // q = A p (stencil §6 or general SpMV).
        let (q, spmv_ns) = operator.apply(grid, &p, engine, cost)?;
        component!("spmv", spmv_ns);

        // α = δ / (p·q)
        let pq = run_dot(grid.rows, grid.cols, &dot_cfg, &p, &q, engine, cost)?;
        component!("dot", pq.total_ns);
        let pq_v = pq.value as f64;
        if pq_v == 0.0 || !pq_v.is_finite() {
            break; // breakdown (numerically singular at this precision)
        }
        let alpha = (delta / pq_v) as f32;

        // x += α p ; r -= α q
        for (xi, pi) in x.iter_mut().zip(&p) {
            engine.axpy_into(xi, alpha, pi)?;
        }
        component!("axpy", axpy_ns);
        for (ri, qi) in r.iter_mut().zip(&q) {
            engine.axpy_into(ri, -alpha, qi)?;
        }
        component!("axpy", axpy_ns);

        // ||r||₂ (absolute, §3.3).
        let rr = run_dot(grid.rows, grid.cols, &dot_cfg, &r, &r, engine, cost)?;
        component!("norm", rr.total_ns);
        let rnorm = (rr.value.max(0.0) as f64).sqrt();
        history.push(rnorm);
        {
            let pre = now;
            now = sched.residual_readback(&mut queue, now);
            if now > pre {
                spans.host("readback", pre, now);
            }
        }
        if !sched.is_fused() {
            readbacks += 1;
        }
        if opts.telemetry {
            telemetry.series("residual", &[], now, rnorm);
            telemetry.event(SolverEvent {
                t_ns: now,
                iter: iters as u64,
                residual: rnorm,
                launches: queue.stats.launches,
                component_ns: std::mem::take(&mut iter_component_ns),
                fault: None,
            });
        }
        if rnorm <= opts.tol_abs {
            converged = true;
            break;
        }

        // z = M⁻¹ r
        z = precond.apply(engine, &r)?;
        component!("precond", precond_ns);

        // δ' = r·z ; β = δ'/δ
        let rz = run_dot(grid.rows, grid.cols, &dot_cfg, &r, &z, engine, cost)?;
        component!("dot", rz.total_ns);
        let delta_new = rz.value as f64;
        if delta == 0.0 || !delta_new.is_finite() {
            break;
        }
        let beta = (delta_new / delta) as f32;
        delta = delta_new;

        // p = z + β p
        for (pi, zi) in p.iter_mut().zip(&z) {
            *pi = engine.axpy(zi, beta, pi)?;
        }
        component!("axpy", axpy_ns);
    }

    breakdown.iterations = iters as u64;
    // Host dispatch overhead (the only time advances not charged through
    // `component!`) as an explicit row — solve-level conservation then holds
    // by construction: ledger.total.total() == total_ns.
    if opts.telemetry {
        ledger.add_dispatch(
            queue.stats.launch_ns
                + queue.stats.gap_ns
                + readbacks as f64 * calib.residual_readback_ns,
        );
        ledger.iterations = iters as u64;
        telemetry.merge(&queue.telemetry);
    }
    Ok(PcgResult {
        x,
        iters,
        converged,
        residual_history: history,
        total_ns: now,
        per_iter_ns: if iters > 0 { now / iters as f64 } else { 0.0 },
        breakdown,
        launch: queue.stats.clone(),
        ledger,
        telemetry,
        spans: spans.finish(now),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::kernels::spmv::{SpmvConfig, SpmvMode};
    use crate::solver::problem::{apply_laplacian_global, dist_random, dist_to_global};
    use crate::sparse::{laplacian_3d, CsrMatrix, RowPartition};

    fn residual_vs_truth(p: &Problem, x: &DistVector, b: &DistVector) -> f64 {
        let xg = dist_to_global(p, x);
        let bg = dist_to_global(p, b);
        let ax = apply_laplacian_global(p, &xg);
        ax.iter()
            .zip(&bg)
            .map(|(a, &bb)| (a - bb as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }

    #[test]
    fn fp32_pcg_converges_on_small_problem() {
        let p = Problem::new(2, 2, 4, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dist_random(&p, 7);
        let mut opts = PcgOptions::new(PcgVariant::SplitFp32);
        opts.max_iters = 400;
        opts.tol_abs = 1e-3;
        let mut prof = Profiler::disabled();
        let res = solve(&grid, &p, &b, &e, &cost, &opts, &mut prof).unwrap();
        assert!(res.converged, "residual history tail: {:?}", &res.residual_history.iter().rev().take(3).collect::<Vec<_>>());
        // True residual (independent oracle) close to the reported one.
        let true_r = residual_vs_truth(&p, &res.x, &b);
        assert!(true_r < 5e-3, "true residual {true_r}");
        // Residual history is (mostly) decreasing.
        let first = res.residual_history[0];
        let last = *res.residual_history.last().unwrap();
        assert!(last < 1e-2 * first);
    }

    #[test]
    fn bf16_pcg_reduces_residual() {
        // BF16 stalls well above FP32 accuracy but must make progress.
        let p = Problem::new(2, 2, 4, DataFormat::Bf16);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dist_random(&p, 8);
        let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
        opts.max_iters = 60;
        opts.tol_abs = 0.0; // run all iterations
        let mut prof = Profiler::disabled();
        let res = solve(&grid, &p, &b, &e, &cost, &opts, &mut prof).unwrap();
        let first = res.residual_history[0];
        let min = res
            .residual_history
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(
            min < 0.15 * first,
            "BF16 PCG should reduce the residual substantially: first {first}, min {min}"
        );
    }

    #[test]
    fn split_charges_launches_fused_does_not() {
        let pb = Problem::new(2, 2, 4, DataFormat::Bf16);
        let ps = Problem::new(2, 2, 4, DataFormat::Fp32);
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let mut prof = Profiler::disabled();

        let mut o_f = PcgOptions::new(PcgVariant::FusedBf16);
        o_f.max_iters = 5;
        o_f.tol_abs = 0.0;
        let rf = solve(&pb.make_grid().unwrap(), &pb, &dist_random(&pb, 1), &e, &cost, &o_f, &mut prof).unwrap();
        // One launch for the whole fused solve.
        assert_eq!(rf.launch.launches, 1);
        assert!(rf.launch.gap_ns > 0.0);

        let mut o_s = PcgOptions::new(PcgVariant::SplitFp32);
        o_s.max_iters = 5;
        o_s.tol_abs = 0.0;
        let rs = solve(&ps.make_grid().unwrap(), &ps, &dist_random(&ps, 1), &e, &cost, &o_s, &mut prof).unwrap();
        // 8 component launches per iteration.
        assert_eq!(rs.launch.launches, 8 * 5);
    }

    #[test]
    fn variant_format_mismatch_rejected() {
        let p = Problem::new(1, 1, 2, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let opts = PcgOptions::new(PcgVariant::FusedBf16);
        let b = dist_random(&p, 1);
        let mut prof = Profiler::disabled();
        assert!(solve(&grid, &p, &b, &e, &cost, &opts, &mut prof).is_err());
    }

    #[test]
    fn capacity_enforced_for_variant() {
        // 100 tiles FP32 split exceeds the 64-tile §7.2 ceiling.
        let p = Problem::new(1, 1, 100, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let opts = PcgOptions::new(PcgVariant::SplitFp32);
        let b = dist_random(&p, 1);
        let mut prof = Profiler::disabled();
        assert!(solve(&grid, &p, &b, &e, &cost, &opts, &mut prof).is_err());
    }

    #[test]
    fn breakdown_components_recorded() {
        let p = Problem::new(2, 2, 4, DataFormat::Bf16);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
        opts.max_iters = 3;
        opts.tol_abs = 0.0;
        let mut prof = Profiler::new();
        let res = solve(&grid, &p, &dist_random(&p, 2), &e, &cost, &opts, &mut prof).unwrap();
        for c in ["spmv", "dot", "axpy", "norm", "precond"] {
            assert!(res.breakdown.per_iter(c) > 0.0, "component {c} missing");
        }
        // SpMV is the computationally heavy component (§7.3).
        assert!(res.breakdown.per_iter("spmv") > res.breakdown.per_iter("axpy"));
        assert!(!prof.zones().is_empty());
    }

    #[test]
    fn sparse_laplacian_pcg_reproduces_stencil_trajectory() {
        // THE operator round-trip acceptance test: sparse PCG on the
        // generated Laplacian over the stencil-aligned partition walks the
        // exact iterate trajectory of the stencil path — same iteration
        // count and bit-identical residual history at FP32.
        let p = Problem::new(2, 2, 2, DataFormat::Fp32);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dist_random(&p, 7);
        let mut opts = PcgOptions::new(PcgVariant::SplitFp32);
        opts.max_iters = 400;
        opts.tol_abs = 1e-3;
        let mut prof = Profiler::disabled();
        let stencil = solve(&grid, &p, &b, &e, &cost, &opts, &mut prof).unwrap();

        let (nx, ny, nz) = p.dims();
        let a = laplacian_3d(nx, ny, nz);
        let part = RowPartition::stencil_aligned(2, 2, nz).unwrap();
        let op = SpmvOperator::new(&a, part, SpmvConfig::new(DataFormat::Fp32, SpmvMode::SramResident)).unwrap();
        let sparse =
            solve_operator(&grid, &b, &Operator::Sparse(&op), &e, &cost, &opts, &mut prof).unwrap();

        assert!(stencil.converged && sparse.converged);
        assert_eq!(stencil.iters, sparse.iters);
        assert_eq!(stencil.residual_history, sparse.residual_history, "exact at FP32");
        assert_eq!(stencil.x, sparse.x);
        // The explicit matrix pays for generality: its SpMV component is
        // strictly slower than the matrix-free stencil.
        assert!(sparse.breakdown.per_iter("spmv") > stencil.breakdown.per_iter("spmv"));
    }

    #[test]
    fn fused_sparse_pcg_single_launch_and_split_equivalent() {
        // Acceptance pin: PcgVariant::FusedBf16 × Operator::Sparse runs
        // through the fused schedule (one enqueue per solve), and forcing
        // the split schedule at the same precision changes only the
        // launch accounting — the residual trajectory is bit-identical.
        let p = Problem::new(2, 2, 2, DataFormat::Bf16);
        let grid = p.make_grid().unwrap();
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let b = dist_random(&p, 11);
        let (nx, ny, nz) = p.dims();
        let a = laplacian_3d(nx, ny, nz);
        let part = RowPartition::stencil_aligned(2, 2, nz).unwrap();
        let op = SpmvOperator::new(&a, part, SpmvConfig::new(DataFormat::Bf16, SpmvMode::SramResident)).unwrap();

        let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
        opts.max_iters = 10;
        opts.tol_abs = 0.0;
        let mut prof = Profiler::disabled();
        let fused =
            solve_operator(&grid, &b, &Operator::Sparse(&op), &e, &cost, &opts, &mut prof).unwrap();
        assert_eq!(fused.launch.launches, 1, "fused sparse: one enqueue per solve");
        assert!(fused.launch.gap_ns > 0.0);
        assert!(fused.launches_per_iter() < 1.0);

        opts.fusion = FusionMode::ForceSplit;
        let split =
            solve_operator(&grid, &b, &Operator::Sparse(&op), &e, &cost, &opts, &mut prof).unwrap();
        assert_eq!(split.launch.launches, 8 * 10, "split: 8 enqueues/iter");
        assert_eq!(fused.residual_history, split.residual_history, "values are schedule-independent");
        assert_eq!(fused.x, split.x);
        assert!(fused.launches_per_iter() < split.launches_per_iter());
        // Fewer launches means less modeled host time for the same work.
        assert!(fused.total_ns < split.total_ns);
    }

    #[test]
    fn sparse_pcg_converges_on_general_spd_matrix() {
        // Non-uniform diagonal (D·A·D scaling of a well-conditioned SPD
        // circulant) exercises the per-element Jacobi path on a row-block
        // partition.
        let n = 2 * 1024;
        let base = crate::sparse::circulant_spd(n, 7, 31).unwrap();
        let d = |i: usize| 1.0 + 0.25 * (i % 3) as f32;
        let scaled: Vec<(usize, usize, f32)> = base
            .triplets()
            .into_iter()
            .map(|(i, j, v)| (i, j, d(i) * v * d(j)))
            .collect();
        let a = CsrMatrix::from_triplets(n, n, &scaled).unwrap();
        assert!(a.is_symmetric(1e-5));
        let part = RowPartition::row_block(1, 2, n).unwrap();
        let op = SpmvOperator::new(&a, part.clone(), SpmvConfig::new(DataFormat::Fp32, SpmvMode::SramResident)).unwrap();
        assert_eq!(op.uniform_diagonal(), None);

        let grid = TensixGrid::new(1, 2).unwrap();
        let mut rng = crate::util::prng::Rng::new(21);
        let bg: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let b = part.dist_from_global(DataFormat::Fp32, &bg);
        let mut opts = PcgOptions::new(PcgVariant::SplitFp32);
        opts.max_iters = 500;
        opts.tol_abs = 1e-4;
        let mut prof = Profiler::disabled();
        let res =
            solve_operator(&grid, &b, &Operator::Sparse(&op), &e_native(), &cost_m(), &opts, &mut prof)
                .unwrap();
        assert!(res.converged, "tail: {:?}", res.residual_history.iter().rev().take(3).collect::<Vec<_>>());
        // Independent f64 oracle on the true residual.
        let xg = part.dist_to_global(&res.x);
        let ax = a.apply_f64(&xg);
        let true_r: f64 = ax
            .iter()
            .zip(&bg)
            .map(|(v, &bb)| (v - bb as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(true_r < 1e-2, "true residual {true_r}");
    }

    fn e_native() -> NativeEngine {
        NativeEngine::new()
    }

    fn cost_m() -> CostModel {
        CostModel::default()
    }

    #[test]
    fn unpreconditioned_sparse_cg_still_converges() {
        let n = 1024;
        let a = crate::sparse::circulant_spd(n, 5, 13).unwrap();
        let part = RowPartition::row_block(1, 1, n).unwrap();
        let op = SpmvOperator::new(&a, part.clone(), SpmvConfig::new(DataFormat::Fp32, SpmvMode::SramResident)).unwrap();
        let grid = TensixGrid::new(1, 1).unwrap();
        let ones = vec![1.0f32; n];
        let b = part.dist_from_global(DataFormat::Fp32, &ones);
        let mut opts = PcgOptions::new(PcgVariant::SplitFp32);
        opts.max_iters = 200;
        opts.tol_abs = 1e-4;
        opts.precondition = false;
        let mut prof = Profiler::disabled();
        let res = solve_operator(&grid, &b, &Operator::Sparse(&op), &e_native(), &cost_m(), &opts, &mut prof).unwrap();
        assert!(res.converged);
    }

    #[test]
    fn rhs_shape_validation() {
        let e = NativeEngine::new();
        let cost = CostModel::default();
        let grid = TensixGrid::new(1, 2).unwrap();
        let opts = PcgOptions::new(PcgVariant::SplitFp32);
        let mut prof = Profiler::disabled();
        let cfg = StencilConfig {
            df: DataFormat::Fp32,
            unit: ComputeUnit::Sfpu,
            tiles_per_core: 1,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        // Wrong block count for the grid.
        let b = vec![crate::engine::CoreBlock::zeros(DataFormat::Fp32, 1)];
        assert!(solve_operator(&grid, &b, &Operator::Stencil(cfg), &e, &cost, &opts, &mut prof).is_err());
    }
}
