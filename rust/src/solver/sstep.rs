//! Host-side f64 block algebra for the s-step (communication-avoiding)
//! PCG schedule ([`crate::ttm::Schedule::SStep`]).
//!
//! One block of s iterations builds a monomial basis V = [v₁…vₛ]
//! (vₖ = M⁻¹uₖ₋₁, uₖ = A vₖ, u₀ = r), then folds **every** scalar the
//! block needs — C = QᵖʳᵉᵛᵀV, E = PᵖʳᵉᵛᵀU, F = VᵀU, g = Vᵀr, rᵀr —
//! into ONE combined all-reduce round. The Chronopoulos–Gear recurrence
//! then reconstructs the block's directions without further network
//! traffic:
//!
//! - B = −Wᵖʳᵉᵛ⁻¹C couples the new basis to the previous block
//!   (P = V + PᵖʳᵉᵛB keeps cross-block A-conjugacy: PᵖʳᵉᵛᵀA P =
//!   C + WᵖʳᵉᵛB = 0);
//! - W = PᵀAP = F + CᵀB + BᵀE + BᵀWᵖʳᵉᵛB — assembled from already
//!   reduced blocks, no extra round;
//! - the block step solves W a = g (g = Pᵀr collapses to Vᵀr because
//!   r ⊥ span(Pᵖʳᵉᵛ) by construction) and applies x += Pa, r −= Qa.
//!
//! All of this is s×s with s ≤ 8, so the host does it in f64. The
//! monomial basis conditions like the power iteration — W's Cholesky can
//! lose positive definiteness in finite precision — so [`cholesky`]
//! truncates at the first non-positive pivot and the solve falls back to
//! the leading well-conditioned block (zero-extended), which degrades a
//! block toward fewer effective iterations instead of exploding. The
//! residual-trajectory drift vs classic PCG is property-bounded in
//! `tests/prop_schedule.rs`, not bit-exact.

/// A (possibly truncated) Cholesky factorization W ≈ LLᵀ of the leading
/// `rank`×`rank` block of an s×s Gram matrix.
#[derive(Debug, Clone)]
pub struct CholFactor {
    l: Vec<Vec<f64>>,
    /// Columns factored before the first non-positive pivot (0 = W has
    /// no positive leading pivot at all — total breakdown).
    pub rank: usize,
    n: usize,
}

/// Factor a symmetric matrix, truncating at the first pivot that is not
/// strictly positive and finite (the monomial-basis conditioning
/// fallback: the leading block is still an SPD Gram of the leading basis
/// columns, so a truncated solve is a shorter but valid descent step).
pub fn cholesky(w: &[Vec<f64>]) -> CholFactor {
    let n = w.len();
    let mut l = vec![vec![0.0f64; n]; n];
    let mut rank = n;
    for j in 0..n {
        let mut d = w[j][j];
        for k in 0..j {
            d -= l[j][k] * l[j][k];
        }
        if !(d > 0.0 && d.is_finite()) {
            rank = j;
            break;
        }
        let lj = d.sqrt();
        l[j][j] = lj;
        for (i, row) in w.iter().enumerate().skip(j + 1) {
            let mut v = row[j];
            for k in 0..j {
                v -= l[i][k] * l[j][k];
            }
            l[i][j] = v / lj;
        }
    }
    CholFactor { l, rank, n }
}

impl CholFactor {
    /// Solve (LLᵀ)y = rhs on the leading `rank` block; entries past the
    /// truncation point come back zero (those basis directions are
    /// dropped from the block step).
    pub fn solve(&self, rhs: &[f64]) -> Vec<f64> {
        let k = self.rank;
        let mut y = vec![0.0f64; self.n];
        for i in 0..k {
            let mut v = rhs[i];
            for j in 0..i {
                v -= self.l[i][j] * y[j];
            }
            y[i] = v / self.l[i][i];
        }
        for i in (0..k).rev() {
            let mut v = y[i];
            for j in (i + 1)..k {
                v -= self.l[j][i] * y[j];
            }
            y[i] = v / self.l[i][i];
        }
        y
    }
}

/// B = −Wᵖʳᵉᵛ⁻¹C, column by column through the (possibly truncated)
/// factor: rows past the truncation point are zero, dropping the
/// ill-conditioned previous directions from the coupling.
pub fn coupling_b(wprev: &CholFactor, c: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let n = c.len();
    let mut b = vec![vec![0.0f64; n]; n];
    for j in 0..n {
        let rhs: Vec<f64> = (0..n).map(|i| -c[i][j]).collect();
        let col = wprev.solve(&rhs);
        for (i, bi) in b.iter_mut().enumerate() {
            bi[j] = col[i];
        }
    }
    b
}

/// W = F + CᵀB + BᵀE + BᵀWᵖʳᵉᵛB, symmetrized (exactly symmetric in
/// exact arithmetic — the two triangles drift apart only by rounding, and
/// averaging them keeps the Cholesky honest). O(s⁴) with s ≤ 8.
pub fn next_w(
    f: &[Vec<f64>],
    c: &[Vec<f64>],
    e: &[Vec<f64>],
    wprev: &[Vec<f64>],
    b: &[Vec<f64>],
) -> Vec<Vec<f64>> {
    let n = f.len();
    let mut w = vec![vec![0.0f64; n]; n];
    for i in 0..n {
        for j in 0..n {
            let mut v = f[i][j];
            for k in 0..n {
                v += c[k][i] * b[k][j]; // (CᵀB)ᵢⱼ
                v += b[k][i] * e[k][j]; // (BᵀE)ᵢⱼ
                for l in 0..n {
                    v += b[k][i] * wprev[k][l] * b[l][j]; // (BᵀWᵖʳᵉᵛB)ᵢⱼ
                }
            }
            w[i][j] = v;
        }
    }
    for i in 0..n {
        for j in 0..i {
            let m = 0.5 * (w[i][j] + w[j][i]);
            w[i][j] = m;
            w[j][i] = m;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_solves_an_spd_system() {
        // W = [[4,2],[2,3]], W⁻¹ = 1/8 [[3,-2],[-2,4]].
        let w = vec![vec![4.0, 2.0], vec![2.0, 3.0]];
        let f = cholesky(&w);
        assert_eq!(f.rank, 2);
        let y = f.solve(&[2.0, 5.0]);
        assert!((y[0] - (-0.5)).abs() < 1e-12, "{y:?}");
        assert!((y[1] - 2.0).abs() < 1e-12, "{y:?}");
    }

    #[test]
    fn non_positive_pivot_truncates_not_explodes() {
        // Indefinite: the second pivot is negative — the factor keeps the
        // leading 1×1 block and the solve zero-extends.
        let w = vec![vec![1.0, 0.0], vec![0.0, -1.0]];
        let f = cholesky(&w);
        assert_eq!(f.rank, 1);
        assert_eq!(f.solve(&[3.0, 7.0]), vec![3.0, 0.0]);
        // A matrix with no positive leading pivot at all is rank 0 and
        // solves to the zero step (the solver treats this as breakdown).
        let bad = cholesky(&[vec![-1.0]]);
        assert_eq!(bad.rank, 0);
        assert_eq!(bad.solve(&[5.0]), vec![0.0]);
        // NaN pivots truncate too (finite-precision Gram gone wrong).
        let nan = cholesky(&[vec![f64::NAN]]);
        assert_eq!(nan.rank, 0);
    }

    #[test]
    fn coupling_cancels_cross_block_gram() {
        // With B = −W⁻¹C the coupled Gram C + WB must vanish — that is
        // the cross-block A-conjugacy the recurrence exists for.
        let w = vec![vec![4.0, 1.0], vec![1.0, 3.0]];
        let c = vec![vec![2.0, -1.0], vec![0.5, 1.5]];
        let b = coupling_b(&cholesky(&w), &c);
        for i in 0..2 {
            for j in 0..2 {
                let wb: f64 = (0..2).map(|k| w[i][k] * b[k][j]).sum();
                assert!((c[i][j] + wb).abs() < 1e-12);
            }
        }
        // Block 0 shape: zero C gives zero coupling.
        let z = coupling_b(&cholesky(&w), &vec![vec![0.0; 2]; 2]);
        assert!(z.iter().flatten().all(|&v| v == 0.0));
    }

    #[test]
    fn recurrence_is_symmetric_and_reduces_to_f() {
        let f = vec![vec![2.0, 0.7], vec![0.3, 5.0]];
        let zero = vec![vec![0.0; 2]; 2];
        // b = 0 (block 0): W is just F symmetrized.
        let w0 = next_w(&f, &zero, &zero, &zero, &zero);
        assert_eq!(w0[0][1], w0[1][0]);
        assert!((w0[0][1] - 0.5).abs() < 1e-12);
        assert_eq!(w0[0][0], 2.0);
        // General inputs still come out symmetric.
        let c = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let e = vec![vec![0.5, 0.1], vec![0.2, 0.9]];
        let wp = vec![vec![3.0, 0.4], vec![0.4, 2.0]];
        let b = vec![vec![0.3, -0.2], vec![0.1, 0.5]];
        let w = next_w(&f, &c, &e, &wp, &b);
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(w[i][j], w[j][i]);
            }
        }
    }
}
