//! `wormsim` — launcher for the Wormhole-numerics reproduction.
//!
//! Subcommands:
//!   info                      platform + architecture summary
//!   solve [opts]              run the PCG solver on a problem
//!   figures <id|all> [opts]   regenerate a paper figure (fig3 fig5 fig6
//!                             fig11 fig12a fig12b fig12c fig13)
//!   tables <id|all> [opts]    regenerate a paper table (t1 t2 t3)
//!
//! Common options:
//!   --engine native|pjrt      value engine (default native; pjrt executes
//!                             the AOT JAX/Pallas artifacts through PJRT)
//!   --artifacts DIR           artifact directory (default ./artifacts)
//!   --config FILE             mini-TOML file with [calib] overrides
//!   --iters N                 PCG iterations (figures: per-config timing runs)
//!   --seed N                  workload RNG seed

use std::process::ExitCode;

use wormsim::engine::{make_engine, EngineKind};
use wormsim::experiments::{run_figure, run_table, ExpContext};
use wormsim::kernels::DotMethod;
use wormsim::profiler::Profiler;
use wormsim::solver::{self, PcgOptions, PcgVariant, Problem};
use wormsim::timing::cost::CostModel;
use wormsim::timing::Calib;
use wormsim::util::cli;
use wormsim::util::stats::fmt_ns;

const VALUE_KEYS: &[&str] = &[
    "engine", "artifacts", "config", "iters", "seed", "grid", "tiles", "variant", "tol",
    "pattern", "method", "out", "trace", "dies", "topology", "overlap", "schedule", "suite",
    "threshold", "telemetry", "what-if", "faults", "checkpoint",
];
const FLAGS: &[&str] = &["help", "quiet", "emit-json", "smoke", "advisory"];

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print_usage();
        return ExitCode::SUCCESS;
    }
    let cmd = argv[0].clone();
    let rest = &argv[1..];
    let args = match cli::parse(rest, VALUE_KEYS, FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    if args.has_flag("help") {
        print_usage();
        return ExitCode::SUCCESS;
    }
    match dispatch(&cmd, &args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn build_context(args: &cli::Args) -> Result<ExpContext, String> {
    let mut calib = Calib::default();
    if let Some(cfg_path) = args.get("config") {
        let text = std::fs::read_to_string(cfg_path)
            .map_err(|e| format!("cannot read config {cfg_path}: {e}"))?;
        let doc = wormsim::util::tomlmini::Doc::parse(&text)?;
        calib.apply_overrides(&doc);
    }
    let engine_kind: EngineKind = args.get_parsed("engine", "native")?;
    let artifacts = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let engine = make_engine(engine_kind, &artifacts).map_err(|e| e.to_string())?;
    Ok(ExpContext {
        cost: CostModel::new(calib),
        engine,
        pcg_iters: args.get_usize("iters", 3)?,
        out_dir: std::path::PathBuf::from(args.get_or("out", "results")),
        seed: args.get_u64("seed", 20260710)?,
    })
}

fn dispatch(cmd: &str, args: &cli::Args) -> Result<(), String> {
    match cmd {
        "info" => cmd_info(args),
        "solve" => cmd_solve(args),
        "figures" => {
            let ctx = build_context(args)?;
            let id = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
            run_figure(&ctx, id).map_err(|e| e.to_string())
        }
        "tables" => {
            let ctx = build_context(args)?;
            let id = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
            run_table(&ctx, id).map_err(|e| e.to_string())
        }
        "bench" => cmd_bench(args),
        "bench-diff" => cmd_bench_diff(args),
        "critpath" => cmd_critpath(args),
        _ => Err(format!("unknown command '{cmd}' (try --help)")),
    }
}

fn cmd_info(args: &cli::Args) -> Result<(), String> {
    use wormsim::arch::constants::*;
    println!("wormsim — Tenstorrent Wormhole numerical-kernels reproduction");
    println!("  die grid:        {DIE_ROWS}x{DIE_COLS} ({TENSIX_PER_DIE} Tensix cores)");
    println!(
        "  compute subgrid: up to {}x{} ({} cores)",
        MAX_SUBGRID.0,
        MAX_SUBGRID.1,
        MAX_SUBGRID.0 * MAX_SUBGRID.1
    );
    println!("  SRAM/core:       {} KiB", SRAM_BYTES / 1024);
    println!("  clock:           {:.1} GHz", CLOCK_HZ / 1e9);
    println!("  tile:            1024 elements (32x32 / 64x16 stencil)");
    if args.get_or("engine", "native") == "pjrt" {
        let artifacts = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
        let store = wormsim::runtime::ArtifactStore::new(&artifacts).map_err(|e| e.to_string())?;
        println!("  PJRT platform:   {}", store.platform());
        println!(
            "  artifacts:       {} in {}",
            store.list().len(),
            artifacts.display()
        );
    }
    Ok(())
}

fn cmd_solve(args: &cli::Args) -> Result<(), String> {
    let ctx = build_context(args)?;
    let variant: PcgVariant = args.get_parsed("variant", "bf16")?;
    let (rows, cols) = args.get_grid("grid", (4, 4))?;
    let tiles = args.get_usize("tiles", 16)?;
    let topology: wormsim::device::MeshTopology = args.get_parsed("topology", "line")?;
    // An explicit torus shape pins the die count; `--dies` may be
    // omitted (and must agree with rows*cols when given).
    let dies = match (args.get("dies"), topology) {
        (None, wormsim::device::MeshTopology::Torus2D { rows, cols }) => rows * cols,
        _ => args.get_usize("dies", 1)?,
    };
    if dies > 1 {
        return cmd_solve_mesh(args, &ctx, variant, rows, cols, tiles, dies, topology);
    }
    if args.get("faults").is_some() || args.get("checkpoint").is_some() {
        return Err("--faults/--checkpoint apply to multi-die solves (--dies N > 1)".into());
    }
    let problem = Problem::new(rows, cols, tiles, variant.df());
    let grid = problem.make_grid().map_err(|e| e.to_string())?;

    let mut opts = PcgOptions::new(variant);
    opts.max_iters = args.get_usize("iters", 100)?;
    opts.tol_abs = args.get_f64("tol", 1e-4)?;
    opts.dot_pattern = args.get_parsed("pattern", "naive")?;
    opts.dot_method = match args.get_or("method", "1") {
        "1" => DotMethod::ReduceThenSend,
        "2" => DotMethod::SendTiles,
        m => return Err(format!("--method expects 1 or 2, got '{m}'")),
    };

    let (nx, ny, nz) = problem.dims();
    println!(
        "PCG {} on {nx}x{ny}x{nz} ({} elements), {rows}x{cols} cores x {tiles} tiles, engine {}",
        variant.label(),
        problem.elems(),
        ctx.engine.name()
    );
    let b = solver::dist_random(&problem, ctx.seed);
    let mut prof = Profiler::new();
    let res = solver::solve(&grid, &problem, &b, ctx.engine.as_ref(), &ctx.cost, &opts, &mut prof)
        .map_err(|e| e.to_string())?;
    println!(
        "  {} after {} iterations, residual {:.3e}",
        if res.converged { "converged" } else { "stopped" },
        res.iters,
        res.residual_history.last().copied().unwrap_or(f64::NAN)
    );
    println!(
        "  simulated device time: total {}, per iteration {}",
        fmt_ns(res.total_ns),
        fmt_ns(res.per_iter_ns)
    );
    if !args.has_flag("quiet") {
        println!();
        println!("{}", res.breakdown.render("per-component device time"));
        println!(
            "launches {} ({:.2}/iter, {}), device gaps {}",
            res.launch.launches,
            res.launches_per_iter(),
            fmt_ns(res.launch.launch_ns),
            fmt_ns(res.launch.gap_ns)
        );
        println!("verdict: {}", res.ledger.verdict());
    }
    // Per-iteration solver telemetry as JSONL: --telemetry out.jsonl.
    if let Some(tel_path) = args.get("telemetry") {
        res.telemetry
            .write_events_jsonl(std::path::Path::new(tel_path))
            .map_err(|e| format!("cannot write telemetry {tel_path}: {e}"))?;
        println!("wrote solver telemetry to {tel_path}");
    }
    // Tracy-style timeline export (§3.4): --trace out.json, viewable in
    // chrome://tracing or Perfetto — zones plus telemetry counter tracks.
    if let Some(trace_path) = args.get("trace") {
        wormsim::profiler::write_chrome_trace_full(
            &prof,
            &res.telemetry.counter_tracks(),
            &res.spans.flow_events(),
            std::path::Path::new(trace_path),
        )
        .map_err(|e| format!("cannot write trace {trace_path}: {e}"))?;
        println!("wrote simulated-time trace to {trace_path}");
    }
    Ok(())
}

/// Multi-die solve: `--grid RxC` is the *per-die* sub-grid; the domain
/// splits over `--dies N` dies wired as `--topology
/// line|ring|torus:RxC` (1D topologies stack along x; a torus tiles
/// both axes, and its shape implies `--dies` when the flag is omitted).
#[allow(clippy::too_many_arguments)]
fn cmd_solve_mesh(
    args: &cli::Args,
    ctx: &ExpContext,
    variant: PcgVariant,
    rows: usize,
    cols: usize,
    tiles: usize,
    dies: usize,
    topology: wormsim::device::MeshTopology,
) -> Result<(), String> {
    use wormsim::device::{DeviceMesh, EthLink};
    use wormsim::engine::StencilCoeffs;
    use wormsim::kernels::stencil::{StencilConfig, StencilVariant};
    use wormsim::solver::Operator;

    let overlap: wormsim::solver::OverlapMode = args.get_parsed("overlap", "serial")?;
    let schedule: wormsim::solver::Schedule = args.get_parsed("schedule", "classic")?;
    // Scripted faults: `--faults SPEC` (inline grammar, `@file`, or a
    // `.json` path) and `--checkpoint K` (checkpoint/rollback every K
    // iterations; a plan scripting SDC or die loss implies a default
    // policy when the flag is omitted).
    let fault_plan = match args.get("faults") {
        Some(spec) => Some(wormsim::device::FaultPlan::load(spec)?),
        None => None,
    };
    let resilience = match args.get("checkpoint") {
        Some(_) => {
            Some(wormsim::solver::ResilienceOptions::every(args.get_usize("checkpoint", 8)?))
        }
        None => None,
    };
    let mesh = DeviceMesh::new(dies, rows, cols, topology, EthLink::for_dies(dies))
        .map_err(|e| e.to_string())?;

    let mut opts = PcgOptions::new(variant);
    opts.max_iters = args.get_usize("iters", 100)?;
    opts.tol_abs = args.get_f64("tol", 1e-4)?;
    opts.dot_pattern = args.get_parsed("pattern", "naive")?;
    opts.dot_method = match args.get_or("method", "1") {
        "1" => DotMethod::ReduceThenSend,
        "2" => DotMethod::SendTiles,
        m => return Err(format!("--method expects 1 or 2, got '{m}'")),
    };
    let df = variant.df();
    let stencil_cfg = StencilConfig {
        df,
        unit: variant.unit(),
        tiles_per_core: tiles,
        variant: StencilVariant::FULL,
        coeffs: StencilCoeffs::LAPLACIAN,
    };
    println!(
        "PCG {} on {dies} x {rows}x{cols}-core dies ({} mesh, {} cores), {tiles} tiles/core, {} overlap, {} schedule, engine {}",
        variant.label(),
        topology.label(),
        mesh.n_cores(),
        overlap.label(),
        schedule.label(),
        ctx.engine.name()
    );
    let b = solver::mesh_dist_random(&mesh, tiles, df, ctx.seed);
    let mut prof = Profiler::new();
    let mut mopts =
        wormsim::solver::MeshOptions::new(opts).with_overlap(overlap).with_schedule(schedule);
    if let Some(plan) = fault_plan {
        println!("  fault plan: {} scripted event(s)", plan.events.len());
        mopts = mopts.with_faults(plan);
    }
    if let Some(r) = resilience {
        mopts = mopts.with_resilience(r);
    }
    let res = solver::solve_pcg_mesh(
        &mesh,
        &b,
        &Operator::Stencil(stencil_cfg),
        ctx.engine.as_ref(),
        &ctx.cost,
        &mopts,
        &mut prof,
    )
    .map_err(|e| e.to_string())?;
    println!(
        "  {} after {} iterations, residual {:.3e}",
        if res.converged { "converged" } else { "stopped" },
        res.iters,
        res.residual_history.last().copied().unwrap_or(f64::NAN)
    );
    println!(
        "  simulated device time: total {}, per iteration {}",
        fmt_ns(res.total_ns),
        fmt_ns(res.per_iter_ns)
    );
    if res.fault_epochs > 0 || res.rollbacks > 0 {
        println!(
            "  faults: {} epoch change(s), {} rollback(s), retry time {}",
            res.fault_epochs,
            res.rollbacks,
            fmt_ns(res.ledger.total.get(wormsim::telemetry::Resource::Retry))
        );
    }
    if !args.has_flag("quiet") {
        println!();
        println!("{}", res.breakdown.render("per-component device time"));
        println!(
            "transport split per iteration: compute {}, NoC {}, Ethernet {}, dispatch {}",
            fmt_ns(res.phases.compute_ns),
            fmt_ns(res.phases.noc_ns),
            fmt_ns(res.phases.ether_ns),
            fmt_ns(res.phases.dispatch_ns)
        );
        println!(
            "launches {} ({:.2}/iter), device gaps {}, Ethernet {} bytes/solve, peak link util {:.0}%, all-reduce rounds {:.2}/iter",
            res.launch.launches,
            res.launches_per_iter(),
            fmt_ns(res.launch.gap_ns),
            res.eth_bytes_total,
            100.0 * res.eth_peak_link_util,
            res.allreduce_rounds_per_iter()
        );
        println!("verdict: {}", res.bottleneck_verdict());
    }
    if let Some(tel_path) = args.get("telemetry") {
        res.telemetry
            .write_events_jsonl(std::path::Path::new(tel_path))
            .map_err(|e| format!("cannot write telemetry {tel_path}: {e}"))?;
        println!("wrote solver telemetry to {tel_path}");
    }
    if let Some(trace_path) = args.get("trace") {
        wormsim::profiler::write_chrome_trace_full(
            &prof,
            &res.telemetry.counter_tracks(),
            &res.spans.flow_events(),
            std::path::Path::new(trace_path),
        )
        .map_err(|e| format!("cannot write trace {trace_path}: {e}"))?;
        println!("wrote simulated-time trace to {trace_path}");
    }
    Ok(())
}

/// `wormsim critpath [--dies N] [--what-if SPEC] [--trace out.json]` —
/// run a (mesh) PCG solve, extract the critical path of its causal span
/// graph, and print the per-resource report. `--what-if` re-walks the
/// same graph under counterfactual scalings (`eth_bw=2x,dispatch=0`)
/// and prints the predicted solve time — no re-simulation. `--trace`
/// writes the Perfetto trace with span-dependency flow arrows.
fn cmd_critpath(args: &cli::Args) -> Result<(), String> {
    use wormsim::device::{DeviceMesh, EthLink, MeshTopology};
    use wormsim::engine::StencilCoeffs;
    use wormsim::kernels::stencil::{StencilConfig, StencilVariant};
    use wormsim::solver::Operator;
    use wormsim::telemetry::{retime, WhatIf};

    let ctx = build_context(args)?;
    let variant: PcgVariant = args.get_parsed("variant", "bf16")?;
    let (rows, cols) = args.get_grid("grid", (4, 4))?;
    let tiles = args.get_usize("tiles", 16)?;
    let topology: MeshTopology = args.get_parsed("topology", "line")?;
    // As in `solve`: a torus shape implies the die count when `--dies`
    // is omitted.
    let dies = match (args.get("dies"), topology) {
        (None, MeshTopology::Torus2D { rows, cols }) => rows * cols,
        _ => args.get_usize("dies", 4)?,
    };
    let overlap: wormsim::solver::OverlapMode = args.get_parsed("overlap", "serial")?;
    let schedule: wormsim::solver::Schedule = args.get_parsed("schedule", "classic")?;
    let mesh = DeviceMesh::new(dies, rows, cols, topology, EthLink::for_dies(dies))
        .map_err(|e| e.to_string())?;

    let mut opts = PcgOptions::new(variant);
    opts.max_iters = args.get_usize("iters", 10)?;
    opts.tol_abs = args.get_f64("tol", 0.0)?;
    opts.dot_method = match args.get_or("method", "1") {
        "1" => DotMethod::ReduceThenSend,
        "2" => DotMethod::SendTiles,
        m => return Err(format!("--method expects 1 or 2, got '{m}'")),
    };
    let df = variant.df();
    let stencil_cfg = StencilConfig {
        df,
        unit: variant.unit(),
        tiles_per_core: tiles,
        variant: StencilVariant::FULL,
        coeffs: StencilCoeffs::LAPLACIAN,
    };
    println!(
        "critpath: PCG {} on {dies} x {rows}x{cols}-core dies ({} mesh), {tiles} tiles/core, {} overlap, {} schedule",
        variant.label(),
        topology.label(),
        overlap.label(),
        schedule.label()
    );
    let b = solver::mesh_dist_random(&mesh, tiles, df, ctx.seed);
    let mut prof = Profiler::new();
    let res = solver::solve_pcg_mesh(
        &mesh,
        &b,
        &Operator::Stencil(stencil_cfg),
        ctx.engine.as_ref(),
        &ctx.cost,
        &wormsim::solver::MeshOptions::new(opts).with_overlap(overlap).with_schedule(schedule),
        &mut prof,
    )
    .map_err(|e| e.to_string())?;
    let report = res.critpath()?;
    println!();
    println!("{}", report.render());
    if let Some(spec) = args.get("what-if") {
        let w = WhatIf::parse(spec)?;
        let predicted = retime(&res.spans, &w)?;
        println!();
        println!(
            "what-if [{}]: predicted solve time {} (recorded {}, {:+.1}%)",
            w.describe(),
            fmt_ns(predicted),
            fmt_ns(res.total_ns),
            100.0 * (predicted / res.total_ns - 1.0)
        );
    }
    if let Some(trace_path) = args.get("trace") {
        wormsim::profiler::write_chrome_trace_full(
            &prof,
            &res.telemetry.counter_tracks(),
            &res.spans.flow_events(),
            std::path::Path::new(trace_path),
        )
        .map_err(|e| format!("cannot write trace {trace_path}: {e}"))?;
        println!("wrote simulated-time trace to {trace_path}");
    }
    Ok(())
}

/// `wormsim bench [suite] [--smoke] [--emit-json] [--out DIR]` — run the
/// deterministic simulated-figure sweeps and (optionally) write
/// `BENCH_<suite>.json` snapshots for `bench-diff`.
fn cmd_bench(args: &cli::Args) -> Result<(), String> {
    let suite = args
        .get("suite")
        .map(|s| s.to_string())
        .or_else(|| args.positional.first().cloned())
        .unwrap_or_else(|| "all".to_string());
    let smoke = args.has_flag("smoke");
    if args.has_flag("emit-json") {
        let out_dir = std::path::PathBuf::from(args.get_or("out", "."));
        let paths = wormsim::experiments::benchsuite::write_snapshots(&suite, smoke, &out_dir)
            .map_err(|e| e.to_string())?;
        for p in paths {
            println!("wrote {}", p.display());
        }
    } else {
        for snap in
            wormsim::experiments::benchsuite::build(&suite, smoke).map_err(|e| e.to_string())?
        {
            print!("{}", snap.to_json());
        }
    }
    Ok(())
}

/// `wormsim bench-diff BASE.json NEW.json [--threshold F] [--advisory]` —
/// compare two snapshots. Exit-code contract (pinned by a test below):
/// **strict** (default) exits non-zero on regressions *or* on any
/// read/parse failure; **--advisory** always exits 0 — regressions and
/// errors are still printed, but never fail the invocation (the CI
/// early-warning lane must not block merges).
fn cmd_bench_diff(args: &cli::Args) -> Result<(), String> {
    match bench_diff_strict(args) {
        Ok(()) => Ok(()),
        Err(e) if args.has_flag("advisory") => {
            println!("advisory: {e} — not failing");
            Ok(())
        }
        Err(e) => Err(e),
    }
}

fn bench_diff_strict(args: &cli::Args) -> Result<(), String> {
    use wormsim::telemetry::BenchSnapshot;
    let [base_path, new_path] = match args.positional.as_slice() {
        [a, b] => [a, b],
        _ => return Err("bench-diff expects two snapshot paths: BASE.json NEW.json".into()),
    };
    let threshold = args.get_f64("threshold", 0.05)?;
    let base = BenchSnapshot::read(std::path::Path::new(base_path))
        .map_err(|e| format!("cannot read {base_path}: {e}"))?;
    let new = BenchSnapshot::read(std::path::Path::new(new_path))
        .map_err(|e| format!("cannot read {new_path}: {e}"))?;
    let d = wormsim::telemetry::diff(&base, &new, threshold);
    println!(
        "bench-diff {base_path} -> {new_path} (threshold {:.1}%)",
        100.0 * threshold
    );
    let show = |e: &wormsim::telemetry::DiffEntry| {
        format!("{}: {:.6e} -> {:.6e} ({:+.1}%)", e.id, e.a, e.b, 100.0 * e.rel)
    };
    for r in &d.regressions {
        println!("  REGRESSION {}", show(r));
    }
    for i in &d.improvements {
        println!("  improvement {}", show(i));
    }
    for m in &d.missing {
        println!("  missing in new: {m}");
    }
    for a in &d.added {
        println!("  added in new: {a}");
    }
    let compared = base.metrics.len() - d.missing.len();
    if d.regressions.is_empty() {
        println!(
            "no regressions ({compared} metrics compared, {} improvements)",
            d.improvements.len()
        );
        Ok(())
    } else {
        Err(format!("{} regression(s) beyond threshold", d.regressions.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use wormsim::telemetry::{BenchSnapshot, Better};

    fn parse_args(rest: &[&str]) -> cli::Args {
        let rest: Vec<String> = rest.iter().map(|s| s.to_string()).collect();
        cli::parse(&rest, VALUE_KEYS, FLAGS).unwrap()
    }

    /// The bench-diff exit-code contract: strict fails on regressions and
    /// on unreadable snapshots; --advisory always exits 0 (still printing
    /// what it found).
    #[test]
    fn bench_diff_exit_contract_advisory_vs_strict() {
        let dir = std::env::temp_dir().join("wormsim_bench_diff_contract");
        let base_p = dir.join("base.json");
        let new_p = dir.join("new.json");
        let mut base = BenchSnapshot::new("pcg");
        base.push("iter_ns", &[], 100.0, "ns", Better::Lower);
        base.write(&base_p).unwrap();
        let mut worse = BenchSnapshot::new("pcg");
        worse.push("iter_ns", &[], 150.0, "ns", Better::Lower);
        worse.write(&new_p).unwrap();
        let base_s = base_p.to_str().unwrap();
        let new_s = new_p.to_str().unwrap();

        // Strict: a regression beyond threshold fails the invocation.
        assert!(cmd_bench_diff(&parse_args(&[base_s, new_s])).is_err());
        // Advisory: the same regression still exits 0.
        assert!(cmd_bench_diff(&parse_args(&[base_s, new_s, "--advisory"])).is_ok());
        // Identical snapshots pass either way.
        assert!(cmd_bench_diff(&parse_args(&[base_s, base_s])).is_ok());
        assert!(cmd_bench_diff(&parse_args(&[base_s, base_s, "--advisory"])).is_ok());
        // Unreadable snapshot: strict fails, advisory still exits 0.
        let missing = dir.join("nope.json");
        let missing_s = missing.to_str().unwrap();
        assert!(cmd_bench_diff(&parse_args(&[base_s, missing_s])).is_err());
        assert!(cmd_bench_diff(&parse_args(&[base_s, missing_s, "--advisory"])).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Parse hardening at the CLI boundary: malformed specs must be
    /// rejected with a descriptive error *before* any solving starts, not
    /// panic or silently degrade. Each pin names the offending flag.
    #[test]
    fn solve_rejects_malformed_specs_at_the_cli() {
        // Gibberish fault spec.
        let e = cmd_solve(&parse_args(&["--dies", "2", "--faults", "gibberish"])).unwrap_err();
        assert!(e.contains("fault"), "want a fault-spec error, got: {e}");
        // Fault event addressed past the mesh (die 9 of 2).
        let e = cmd_solve(&parse_args(&["--dies", "2", "--faults", "die_down:9@1us"]))
            .unwrap_err();
        assert!(e.contains('9'), "want the out-of-range die named, got: {e}");
        // Degenerate torus shape.
        let e = cmd_solve(&parse_args(&["--topology", "torus:0x4"])).unwrap_err();
        assert!(e.contains("torus"), "want a torus-shape error, got: {e}");
        // s-step of 0 (and 1) are not schedules.
        let e = cmd_solve(&parse_args(&["--dies", "2", "--schedule", "sstep:0"])).unwrap_err();
        assert!(e.contains("2..=8"), "want the s-step range named, got: {e}");
        // --dies disagreeing with an explicit torus shape.
        let e = cmd_solve(&parse_args(&["--dies", "3", "--topology", "torus:2x4"]))
            .unwrap_err();
        assert!(!e.is_empty());
        // Fault flags on a single-die solve point at --dies.
        let e = cmd_solve(&parse_args(&["--faults", "die_down:0@1us"])).unwrap_err();
        assert!(e.contains("--dies"), "want the multi-die hint, got: {e}");
    }
}

fn print_usage() {
    println!(
        "wormsim — Numerical kernels on a simulated Tenstorrent Wormhole\n\n\
         USAGE: wormsim <command> [options]\n\n\
         COMMANDS:\n  \
         info                    platform + architecture summary\n  \
         solve                   run the PCG solver (--grid 8x7 --tiles 64 --variant bf16|fp32\n                          \
         --iters N --tol X --pattern naive|center --method 1|2)\n                          \
         multi-die: --dies N --topology line|ring|torus:RxC --overlap serial|pipelined\n                          \
         (torus:RxC implies --dies RxC when the flag is omitted)\n                          \
         --schedule classic|prefetch|sstep:<s>  communication-avoiding schedule\n                          \
         (prefetch: halo rides the previous iteration's tail, bit-identical values;\n                          \
         sstep:<s>: ONE combined all-reduce per s iterations, s in 2..=8)\n                          \
         (--grid = per-die sub-grid)\n                          \
         --faults SPEC|F.json    scripted faults (classic schedule), e.g.\n                          \
         'link_down:0-1@5us;link_degrade:2-3x4@10us;die_down:3@1ms;sdc:spmv@20'\n                          \
         also @file with one event per line, or a JSON plan\n                          \
         --checkpoint K          checkpoint/rollback every K iterations (0 disables;\n                          \
         default 8 when the plan scripts sdc/die_down)\n  \
         figures <id|all>        regenerate paper figures: fig3 fig5 fig6 fig11 fig12a fig12b fig12c fig13\n                          \
         extensions (§8): energy dualdie jacobi ext; solve supports --trace out.json\n  \
         tables <id|all>         regenerate paper tables: t1 t2 t3\n  \
         bench [suite]           deterministic simulated-figure sweeps (pcg|spmv|figures|resilience|all)\n                          \
         --emit-json writes BENCH_<suite>.json (--out DIR, --smoke for CI subset)\n  \
         bench-diff A.json B.json  compare snapshots (--threshold 0.05; --advisory always exits 0)\n  \
         critpath                critical-path report of a mesh solve's causal span graph\n                          \
         (--dies N --topology line|ring|torus:RxC --grid RxC --overlap serial|pipelined\n                          \
         --schedule classic|prefetch|sstep:<s>)\n                          \
         --what-if eth_bw=2x,eth_lat=0.5x,dispatch=0  re-time the graph, print predicted\n                          \
         solve time (eth_lat scales only the per-hop latency share of Ethernet spans)\n                          \
         --trace out.json        Perfetto trace with span-dependency flow arrows\n\n\
         COMMON OPTIONS:\n  \
         --engine native|pjrt    value engine (pjrt runs the AOT JAX/Pallas artifacts)\n  \
         --artifacts DIR         artifact directory (default: artifacts)\n  \
         --config FILE           mini-TOML [calib] overrides\n  \
         --seed N --iters N --out DIR\n  \
         --telemetry out.jsonl   (solve) per-iteration solver events as JSONL\n  \
         --trace out.json        (solve) Perfetto trace: zones + counter tracks"
    );
}
