//! Tile-granularity compute operations as executed by the Tensix compute
//! units: element-wise arithmetic, scaling, reductions, and the face-wise
//! transpose (§3.3, §6.3). These are the *value* semantics; cycle costs are
//! charged separately by [`crate::timing::cost`].

use crate::arch::bf16::bf16_round;
use crate::arch::constants::FACE;
use crate::arch::DataFormat;
use crate::tile::data::Tile;

/// Element-wise binary operations supported by both compute units (§4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EltwiseOp {
    Add,
    Sub,
    Mul,
}

impl EltwiseOp {
    #[inline]
    pub fn apply(self, a: f32, b: f32) -> f32 {
        match self {
            EltwiseOp::Add => a + b,
            EltwiseOp::Sub => a - b,
            EltwiseOp::Mul => a * b,
        }
    }
}

fn quant(df: DataFormat, v: f32) -> f32 {
    match df {
        DataFormat::Bf16 => bf16_round(v),
        _ => crate::arch::bf16::ftz_f32(v),
    }
}

/// §Perf optimization 4: monomorphized per-element quantization so the
/// format dispatch is hoisted out of the element loops (these run ~10^7
/// elements per simulated PCG iteration at the Table-3 size).
#[inline(always)]
fn q<const BF16: bool>(v: f32) -> f32 {
    if BF16 {
        bf16_round(v)
    } else {
        crate::arch::bf16::ftz_f32(v)
    }
}

fn map2<const BF16: bool>(a: &[f32], b: &[f32], f: impl Fn(f32, f32) -> f32) -> Vec<f32> {
    a.iter().zip(b).map(|(&x, &y)| q::<BF16>(f(x, y))).collect()
}

macro_rules! by_format {
    ($df:expr, $mono:ident, $($args:expr),*) => {
        match $df {
            DataFormat::Bf16 => $mono::<true>($($args),*),
            _ => $mono::<false>($($args),*),
        }
    };
}

/// c = a `op` b, rounding through the output tile's data format.
pub fn eltwise(op: EltwiseOp, a: &Tile, b: &Tile) -> Tile {
    assert_eq!(a.shape, b.shape, "eltwise shape mismatch");
    assert_eq!(a.df, b.df, "eltwise format mismatch");
    let data = by_format!(a.df, map2, &a.data, &b.data, |x, y| op.apply(x, y));
    Tile {
        shape: a.shape,
        df: a.df,
        data,
    }
}

fn scale_impl<const BF16: bool>(a: &[f32], alpha: f32) -> Vec<f32> {
    a.iter().map(|&x| q::<BF16>(alpha * x)).collect()
}

/// out = alpha * a (scalar scale; used for stencil coefficients and the
/// Jacobi preconditioner's 1/diag scaling).
pub fn scale(a: &Tile, alpha: f32) -> Tile {
    let data = by_format!(a.df, scale_impl, &a.data, alpha);
    Tile {
        shape: a.shape,
        df: a.df,
        data,
    }
}

/// out = a + alpha * b (fused axpy-style update at tile granularity).
pub fn axpy(a: &Tile, alpha: f32, b: &Tile) -> Tile {
    assert_eq!(a.shape, b.shape);
    assert_eq!(a.df, b.df);
    let data = by_format!(a.df, map2, &a.data, &b.data, |x, y| x + alpha * y);
    Tile {
        shape: a.shape,
        df: a.df,
        data,
    }
}

fn axpy_into_impl<const BF16: bool>(a: &mut [f32], alpha: f32, b: &[f32]) {
    for (x, &y) in a.iter_mut().zip(b) {
        *x = q::<BF16>(*x + alpha * y);
    }
}

/// a ← a + alpha * b in place (same rounding as [`axpy`], no allocation).
pub fn axpy_into(a: &mut Tile, alpha: f32, b: &Tile) {
    assert_eq!(a.shape, b.shape);
    assert_eq!(a.df, b.df);
    by_format!(a.df, axpy_into_impl, &mut a.data, alpha, &b.data)
}

fn accumulate_impl<const BF16: bool>(dst: &mut [f32], src: &[f32]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = q::<BF16>(*d + s);
    }
}

/// Accumulate `src` into `dst` in place (dst += src).
pub fn accumulate(dst: &mut Tile, src: &Tile) {
    assert_eq!(dst.shape, src.shape);
    assert_eq!(dst.df, src.df);
    by_format!(dst.df, accumulate_impl, &mut dst.data, &src.data)
}

/// Reduce a tile to the sum of its elements.
///
/// The *device* accumulates partial sums in the destination register at the
/// operand precision; we model BF16 reductions as accumulating in FP32 and
/// rounding the final value (the FPU reduction accumulates at ≥16-bit in
/// Dst; exact accumulator width is not architecturally documented — see
/// DESIGN.md §7). FP32 reductions accumulate in FP32.
pub fn reduce_sum(a: &Tile) -> f32 {
    let s: f32 = a.data.iter().sum();
    quant(a.df, s)
}

fn dot_impl<const BF16: bool>(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        s += q::<BF16>(x * y);
    }
    q::<BF16>(s)
}

/// Dot-product partial: sum(a .* b) for one tile, with the element-wise
/// multiply rounded at operand precision before accumulation (this is what
/// the two-step mul-then-reduce device sequence produces).
pub fn dot_partial(a: &Tile, b: &Tile) -> f32 {
    assert_eq!(a.shape, b.shape);
    assert_eq!(a.df, b.df);
    by_format!(a.df, dot_impl, &a.data, &b.data)
}

/// Face-wise transpose (§6.3, Fig 10): the matrix unit transposes a tile as
/// four independent 16×16 sub-matrices. For a 64×16 tile (4×1 face grid)
/// each face transposes in place; the logical effect on the full tile is
/// NOT a global transpose — boundary columns become 4 discontiguous rows.
pub fn transpose_faces(a: &Tile) -> Tile {
    let (frows, fcols) = a.shape.face_grid();
    let mut out = Tile::zeros(a.shape, a.df);
    for fr in 0..frows {
        for fc in 0..fcols {
            for i in 0..FACE {
                for j in 0..FACE {
                    let v = a.get(fr * FACE + i, fc * FACE + j);
                    out.set(fr * FACE + j, fc * FACE + i, v);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tile::layout::TileShape;

    fn t(f: impl Fn(usize, usize) -> f32) -> Tile {
        Tile::from_fn(TileShape::STENCIL, DataFormat::Fp32, f)
    }

    #[test]
    fn eltwise_ops() {
        let a = t(|r, c| (r + c) as f32);
        let b = t(|_, _| 2.0);
        assert_eq!(eltwise(EltwiseOp::Add, &a, &b).get(3, 4), 9.0);
        assert_eq!(eltwise(EltwiseOp::Sub, &a, &b).get(3, 4), 5.0);
        assert_eq!(eltwise(EltwiseOp::Mul, &a, &b).get(3, 4), 14.0);
    }

    #[test]
    fn scale_and_axpy() {
        let a = t(|_, _| 3.0);
        let b = t(|_, _| 4.0);
        assert_eq!(scale(&a, -2.0).get(0, 0), -6.0);
        assert_eq!(axpy(&a, 0.5, &b).get(0, 0), 5.0);
        let mut acc = a.clone();
        accumulate(&mut acc, &b);
        assert_eq!(acc.get(5, 5), 7.0);
    }

    #[test]
    fn bf16_eltwise_rounds() {
        let a = Tile::from_vec(TileShape::STENCIL, DataFormat::Bf16, vec![256.0; 1024]);
        let b = Tile::from_vec(TileShape::STENCIL, DataFormat::Bf16, vec![1.0; 1024]);
        // 256 + 1 = 257 rounds to 256 in bf16.
        assert_eq!(eltwise(EltwiseOp::Add, &a, &b).get(0, 0), 256.0);
    }

    #[test]
    fn reductions() {
        let a = t(|_, _| 1.0);
        assert_eq!(reduce_sum(&a), 1024.0);
        let b = t(|_, _| 2.0);
        assert_eq!(dot_partial(&a, &b), 2048.0);
    }

    #[test]
    fn transpose_faces_is_involution() {
        let a = t(|r, c| (r * 31 + c * 7) as f32);
        let tt = transpose_faces(&transpose_faces(&a));
        assert_eq!(tt, a);
    }

    #[test]
    fn transpose_breaks_column_into_face_rows() {
        // §6.3/Fig 10: the West boundary column (col 0, 64 elements) maps to
        // rows 0, 16, 32, 48 of the face-transposed tile.
        let a = t(|r, c| if c == 0 { 1000.0 + r as f32 } else { 0.0 });
        let tr = transpose_faces(&a);
        for face in 0..4 {
            for j in 0..FACE {
                let orig_row = face * FACE + j;
                assert_eq!(tr.get(face * FACE, j), 1000.0 + orig_row as f32);
            }
        }
        // Everything outside those four rows is zero.
        for r in 0..64 {
            if r % FACE != 0 {
                assert!(tr.row(r).iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn square_tile_face_transpose_differs_from_global() {
        let a = Tile::from_fn(TileShape::SQUARE, DataFormat::Fp32, |r, c| {
            (r * 32 + c) as f32
        });
        let tr = transpose_faces(&a);
        // Within the top-left face it matches a global transpose...
        assert_eq!(tr.get(0, 1), a.get(1, 0));
        // ...but element (0,16) stays in the top-right face (global
        // transpose would move a.get(16,0) there).
        assert_eq!(tr.get(0, 16), a.get(0, 16 + 0)); // face-local transpose of (0,16)→(0,16)
    }
}
