//! Tile shapes and the logical↔physical index mapping (paper §3.1, Fig 2).
//!
//! Tiles are 1024 elements, logically row-major. Physically they are stored
//! as 16×16 subtiles ("faces"), themselves row-major, interleaved in face
//! row-major order. For the 32×32 tile this is the Fig-2 interleaving; for
//! the 64×16 stencil tile the face grid is 4×1, which makes the physical
//! layout coincide with plain row-major — each 16-element row is one
//! contiguous 32B (BF16) unit, the property §6.2 exploits for pointer-shift
//! construction of N/S stencil tiles.

use crate::arch::constants::{FACE, TILE_ELEMS};

/// Shape of a tile in logical (rows, cols).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileShape {
    pub rows: usize,
    pub cols: usize,
}

impl TileShape {
    pub const SQUARE: TileShape = TileShape { rows: 32, cols: 32 };
    pub const STENCIL: TileShape = TileShape { rows: 64, cols: 16 };

    pub const fn elems(self) -> usize {
        self.rows * self.cols
    }

    /// Face grid dimensions (frows, fcols).
    pub const fn face_grid(self) -> (usize, usize) {
        (self.rows / FACE, self.cols / FACE)
    }

    pub fn validate(self) {
        assert_eq!(self.elems(), TILE_ELEMS, "tiles are 1024 elements");
        assert_eq!(self.rows % FACE, 0, "rows must be a multiple of 16");
        assert_eq!(self.cols % FACE, 0, "cols must be a multiple of 16");
    }

    /// Map logical (r, c) to the physical element offset under face
    /// interleaving.
    pub fn phys_index(self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.rows && c < self.cols);
        let (_, fcols) = self.face_grid();
        let (fr, fc) = (r / FACE, c / FACE);
        let face_idx = fr * fcols + fc;
        let (ir, ic) = (r % FACE, c % FACE);
        face_idx * FACE * FACE + ir * FACE + ic
    }

    /// Inverse of [`phys_index`].
    pub fn logical_index(self, phys: usize) -> (usize, usize) {
        debug_assert!(phys < self.elems());
        let (_, fcols) = self.face_grid();
        let face_idx = phys / (FACE * FACE);
        let within = phys % (FACE * FACE);
        let (fr, fc) = (face_idx / fcols, face_idx % fcols);
        let (ir, ic) = (within / FACE, within % FACE);
        (fr * FACE + ir, fc * FACE + ic)
    }

    /// True when the physical layout is identical to logical row-major —
    /// the 64×16 property motivating the paper's stencil tile choice.
    pub fn phys_is_row_major(self) -> bool {
        self.cols == FACE
    }
}

/// Reorder a logical row-major buffer into physical (face-interleaved) order.
pub fn to_physical(shape: TileShape, logical: &[f32]) -> Vec<f32> {
    shape.validate();
    assert_eq!(logical.len(), shape.elems());
    let mut phys = vec![0.0f32; shape.elems()];
    for r in 0..shape.rows {
        for c in 0..shape.cols {
            phys[shape.phys_index(r, c)] = logical[r * shape.cols + c];
        }
    }
    phys
}

/// Reorder a physical buffer back to logical row-major order.
pub fn to_logical(shape: TileShape, phys: &[f32]) -> Vec<f32> {
    shape.validate();
    assert_eq!(phys.len(), shape.elems());
    let mut logical = vec![0.0f32; shape.elems()];
    for r in 0..shape.rows {
        for c in 0..shape.cols {
            logical[r * shape.cols + c] = phys[shape.phys_index(r, c)];
        }
    }
    logical
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_validate() {
        TileShape::SQUARE.validate();
        TileShape::STENCIL.validate();
        assert_eq!(TileShape::SQUARE.face_grid(), (2, 2));
        assert_eq!(TileShape::STENCIL.face_grid(), (4, 1));
    }

    #[test]
    fn fig2_interleaving_square_tile() {
        // Fig 2: for a 32×32 tile, element (0,16) (start of the top-right
        // face) lands at physical offset 256 — after the whole first face.
        let s = TileShape::SQUARE;
        assert_eq!(s.phys_index(0, 0), 0);
        assert_eq!(s.phys_index(0, 15), 15);
        assert_eq!(s.phys_index(0, 16), 256);
        assert_eq!(s.phys_index(1, 0), 16);
        assert_eq!(s.phys_index(16, 0), 512);
        assert_eq!(s.phys_index(16, 16), 768);
        assert_eq!(s.phys_index(31, 31), 1023);
    }

    #[test]
    fn stencil_tile_is_physically_row_major() {
        // §6.2: the 64×16 choice makes rows contiguous 32B units.
        let s = TileShape::STENCIL;
        assert!(s.phys_is_row_major());
        assert!(!TileShape::SQUARE.phys_is_row_major());
        for r in 0..s.rows {
            for c in 0..s.cols {
                assert_eq!(s.phys_index(r, c), r * s.cols + c);
            }
        }
    }

    #[test]
    fn phys_logical_roundtrip() {
        for shape in [TileShape::SQUARE, TileShape::STENCIL] {
            for phys in 0..shape.elems() {
                let (r, c) = shape.logical_index(phys);
                assert_eq!(shape.phys_index(r, c), phys);
            }
        }
    }

    #[test]
    fn buffer_reorder_roundtrip() {
        let shape = TileShape::SQUARE;
        let logical: Vec<f32> = (0..1024).map(|i| i as f32).collect();
        let phys = to_physical(shape, &logical);
        assert_ne!(phys, logical); // square tile really interleaves
        assert_eq!(to_logical(shape, &phys), logical);
    }
}
