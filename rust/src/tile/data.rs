//! Tile storage. Values are held as f32; when the tile's [`DataFormat`] is
//! BF16, every value is maintained exactly-representable in bf16 by the
//! tile operations (which round through the [`crate::arch::bf16`] datapath).

use crate::arch::bf16::bf16_round;
use crate::arch::DataFormat;
use crate::tile::layout::TileShape;

/// A logical row-major tile of `shape.rows × shape.cols` values.
#[derive(Debug, Clone, PartialEq)]
pub struct Tile {
    pub shape: TileShape,
    pub df: DataFormat,
    pub data: Vec<f32>,
}

impl Tile {
    pub fn zeros(shape: TileShape, df: DataFormat) -> Tile {
        shape.validate();
        Tile {
            shape,
            df,
            data: vec![0.0; shape.elems()],
        }
    }

    pub fn from_vec(shape: TileShape, df: DataFormat, mut data: Vec<f32>) -> Tile {
        shape.validate();
        assert_eq!(data.len(), shape.elems(), "tile data length mismatch");
        if df == DataFormat::Bf16 {
            for v in data.iter_mut() {
                *v = bf16_round(*v);
            }
        }
        Tile { shape, df, data }
    }

    /// Fill from a generator over logical (row, col).
    pub fn from_fn(shape: TileShape, df: DataFormat, mut f: impl FnMut(usize, usize) -> f32) -> Tile {
        let mut data = Vec::with_capacity(shape.elems());
        for r in 0..shape.rows {
            for c in 0..shape.cols {
                data.push(f(r, c));
            }
        }
        Tile::from_vec(shape, df, data)
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.shape.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        let v = if self.df == DataFormat::Bf16 { bf16_round(v) } else { v };
        self.data[r * self.shape.cols + c] = v;
    }

    /// One logical row as a slice.
    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.shape.cols;
        &self.data[r * c..(r + 1) * c]
    }

    /// One logical column, copied out.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.shape.rows).map(|r| self.get(r, c)).collect()
    }

    /// Total bytes this tile occupies in SRAM/DRAM at its data format.
    pub fn bytes(&self) -> usize {
        self.shape.elems() * self.df.bytes()
    }

    /// Round every element through the tile's data format (no-op for FP32).
    pub fn requantize(&mut self) {
        if self.df == DataFormat::Bf16 {
            for v in self.data.iter_mut() {
                *v = bf16_round(*v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tile::from_fn(TileShape::STENCIL, DataFormat::Fp32, |r, c| {
            (r * 100 + c) as f32
        });
        assert_eq!(t.get(0, 0), 0.0);
        assert_eq!(t.get(3, 7), 307.0);
        assert_eq!(t.row(2)[5], 205.0);
        assert_eq!(t.col(1)[4], 401.0);
        assert_eq!(t.bytes(), 4096);
    }

    #[test]
    fn bf16_tiles_quantize_on_construction() {
        let t = Tile::from_vec(TileShape::STENCIL, DataFormat::Bf16, vec![257.0; 1024]);
        assert_eq!(t.get(0, 0), 256.0); // 257 not representable in bf16
        assert_eq!(t.bytes(), 2048);
    }

    #[test]
    fn bf16_set_quantizes() {
        let mut t = Tile::zeros(TileShape::SQUARE, DataFormat::Bf16);
        t.set(1, 1, 513.0);
        assert_eq!(t.get(1, 1), 512.0);
        let mut t32 = Tile::zeros(TileShape::SQUARE, DataFormat::Fp32);
        t32.set(1, 1, 513.0);
        assert_eq!(t32.get(1, 1), 513.0);
    }

    #[test]
    #[should_panic]
    fn wrong_length_panics() {
        let _ = Tile::from_vec(TileShape::SQUARE, DataFormat::Fp32, vec![0.0; 10]);
    }
}
