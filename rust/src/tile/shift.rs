//! Shifted-tile construction for the stencil computation (§6.2, Figs 9–10).
//!
//! To add a neighbor component to the center tile, the device first builds
//! a tile holding neighbor values at center positions:
//!
//! - **Row shifts** (N/S in the paper's figures; the ±x stencil direction in
//!   our grid mapping) are produced by incrementing/decrementing a circular
//!   buffer's read pointer by one 32B row and copying — possible because the
//!   64×16 tile stores rows contiguously (see [`crate::tile::layout`]).
//! - **Column shifts** (E/W; ±y) cannot be produced by pointer arithmetic;
//!   they need transpose → row shift (+ 4 halo-row fills at face
//!   boundaries) → transpose (§6.3, Fig 10).
//!
//! Two implementations are provided: the straightforward *logical* shifts,
//! and [`shift_physical`], which reproduces the device's actual pointer /
//! transpose pipeline step by step. A property test asserts they agree —
//! that equivalence is exactly the §6.2–6.3 correctness argument.

use crate::arch::constants::FACE;
use crate::tile::data::Tile;
use crate::tile::ops::transpose_faces;

/// Which neighbor component a shifted tile represents. Directions follow
/// the paper's Fig 9: `North` means "neighbor at row-1 aligned to center".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShiftDir {
    North,
    South,
    East,
    West,
}

impl ShiftDir {
    pub const ALL: [ShiftDir; 4] = [
        ShiftDir::North,
        ShiftDir::South,
        ShiftDir::East,
        ShiftDir::West,
    ];

    /// Row shifts are pointer-trick cheap; column shifts need transposes.
    pub fn needs_transpose(self) -> bool {
        matches!(self, ShiftDir::East | ShiftDir::West)
    }
}

/// Construct the shifted tile for `dir` with `halo` supplying the boundary
/// line (length = cols for N/S, rows for E/W). `halo = None` means zero
/// fill (global Dirichlet boundary, §6.3).
pub fn shift_logical(center: &Tile, dir: ShiftDir, halo: Option<&[f32]>) -> Tile {
    let (rows, cols) = (center.shape.rows, center.shape.cols);
    let mut out = Tile::zeros(center.shape, center.df);
    match dir {
        // out[r][c] = center[r-1][c]; row 0 from the north halo row.
        ShiftDir::North => {
            for r in 1..rows {
                for c in 0..cols {
                    out.set(r, c, center.get(r - 1, c));
                }
            }
            fill_row(&mut out, 0, halo, cols);
        }
        // out[r][c] = center[r+1][c]; last row from the south halo row.
        ShiftDir::South => {
            for r in 0..rows - 1 {
                for c in 0..cols {
                    out.set(r, c, center.get(r + 1, c));
                }
            }
            fill_row(&mut out, rows - 1, halo, cols);
        }
        // out[r][c] = center[r][c-1]; col 0 from the west halo column.
        ShiftDir::West => {
            for r in 0..rows {
                for c in 1..cols {
                    out.set(r, c, center.get(r, c - 1));
                }
            }
            fill_col(&mut out, 0, halo, rows);
        }
        // out[r][c] = center[r][c+1]; last col from the east halo column.
        ShiftDir::East => {
            for r in 0..rows {
                for c in 0..cols - 1 {
                    out.set(r, c, center.get(r, c + 1));
                }
            }
            fill_col(&mut out, cols - 1, halo, rows);
        }
    }
    out
}

fn fill_row(t: &mut Tile, r: usize, halo: Option<&[f32]>, cols: usize) {
    if let Some(h) = halo {
        assert_eq!(h.len(), cols, "N/S halo must be one row");
        for c in 0..cols {
            t.set(r, c, h[c]);
        }
    }
}

fn fill_col(t: &mut Tile, c: usize, halo: Option<&[f32]>, rows: usize) {
    if let Some(h) = halo {
        assert_eq!(h.len(), rows, "E/W halo must be one column");
        for r in 0..rows {
            t.set(r, c, h[r]);
        }
    }
}

/// Shift a tile's rows by reading through a displaced pointer, exactly as
/// the CB pointer-manipulation trick does (§6.2): `offset_rows = -1`
/// reproduces "decrement the read pointer by one 32B row" (north),
/// `+1` increments (south). Rows that fall outside the tile are the halo
/// rows the NoC exchange must fill; they are returned as the indices in
/// `missing` and zero-filled here.
pub fn pointer_row_shift(center: &Tile, offset_rows: isize) -> (Tile, Vec<usize>) {
    let rows = center.shape.rows as isize;
    let cols = center.shape.cols;
    let mut out = Tile::zeros(center.shape, center.df);
    let mut missing = Vec::new();
    for r in 0..rows {
        let src = r + offset_rows;
        if src < 0 || src >= rows {
            missing.push(r as usize);
            continue; // left zero; caller overwrites with halo
        }
        for c in 0..cols {
            out.set(r as usize, c, center.get(src as usize, c));
        }
    }
    (out, missing)
}

/// The device pipeline for an E/W shift (§6.3): face transpose → per-face
/// row shift with 4 halo fills at face-boundary rows → face transpose back.
/// `halo` is the full boundary column (len = rows) or `None` for zero fill.
/// Returns the shifted tile plus the number of discontiguous halo segments
/// (always 4 for a 64×16 tile — the cost model charges 4 NoC sends, §6.3).
pub fn shift_physical_ew(center: &Tile, dir: ShiftDir, halo: Option<&[f32]>) -> (Tile, usize) {
    assert!(dir.needs_transpose(), "use pointer_row_shift for N/S");
    let rows = center.shape.rows;
    let (frows, _) = center.shape.face_grid();

    // Step 1: transpose each 16×16 face.
    let tr = transpose_faces(center);

    // Step 2: within each face, shift rows. An East shift of the original
    // (out[r][c] = center[r][c+1]) becomes, per face, a row shift upward in
    // the transposed domain; the vacated within-face row (15 for East, 0
    // for West) is the halo segment for that face.
    let mut shifted = Tile::zeros(tr.shape, tr.df);
    let mut segments = 0usize;
    for f in 0..frows {
        let base = f * FACE;
        for j in 0..FACE {
            let src_j = match dir {
                ShiftDir::East => j as isize + 1,
                ShiftDir::West => j as isize - 1,
                _ => unreachable!(),
            };
            if !(0..FACE as isize).contains(&src_j) {
                // Halo fill: transposed row `base+j` holds, for face f,
                // the boundary column entries center[base..base+16][halo_col]
                // transposed — i.e. halo[base + i] at column i.
                segments += 1;
                if let Some(h) = halo {
                    assert_eq!(h.len(), rows, "E/W halo must be one column");
                    for i in 0..FACE {
                        shifted.set(base + j, i, h[base + i]);
                    }
                }
                continue;
            }
            for i in 0..FACE {
                shifted.set(base + j, i, tr.get(base + src_j as usize, i));
            }
        }
    }

    // Step 3: transpose back.
    (transpose_faces(&shifted), segments)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DataFormat;
    use crate::tile::layout::TileShape;
    use crate::util::prng::Rng;

    fn random_tile(seed: u64) -> Tile {
        let mut rng = Rng::new(seed);
        Tile::from_fn(TileShape::STENCIL, DataFormat::Fp32, |_, _| {
            rng.next_f32() * 2.0 - 1.0
        })
    }

    #[test]
    fn north_south_shift_semantics() {
        let t = Tile::from_fn(TileShape::STENCIL, DataFormat::Fp32, |r, c| {
            (r * 16 + c) as f32
        });
        let halo: Vec<f32> = (0..16).map(|c| 9000.0 + c as f32).collect();
        let n = shift_logical(&t, ShiftDir::North, Some(&halo));
        assert_eq!(n.get(0, 3), 9003.0); // halo row
        assert_eq!(n.get(5, 3), t.get(4, 3));
        let s = shift_logical(&t, ShiftDir::South, Some(&halo));
        assert_eq!(s.get(63, 3), 9003.0);
        assert_eq!(s.get(5, 3), t.get(6, 3));
    }

    #[test]
    fn east_west_shift_semantics() {
        let t = Tile::from_fn(TileShape::STENCIL, DataFormat::Fp32, |r, c| {
            (r * 16 + c) as f32
        });
        let halo: Vec<f32> = (0..64).map(|r| 5000.0 + r as f32).collect();
        let e = shift_logical(&t, ShiftDir::East, Some(&halo));
        assert_eq!(e.get(7, 15), 5007.0); // east boundary column
        assert_eq!(e.get(7, 3), t.get(7, 4));
        let w = shift_logical(&t, ShiftDir::West, Some(&halo));
        assert_eq!(w.get(7, 0), 5007.0);
        assert_eq!(w.get(7, 3), t.get(7, 2));
    }

    #[test]
    fn zero_fill_boundary() {
        let t = random_tile(1);
        let n = shift_logical(&t, ShiftDir::North, None);
        assert!(n.row(0).iter().all(|&v| v == 0.0));
        let e = shift_logical(&t, ShiftDir::East, None);
        assert!((0..64).all(|r| e.get(r, 15) == 0.0));
    }

    #[test]
    fn pointer_shift_matches_logical_on_interior() {
        let t = random_tile(2);
        let (north, missing) = pointer_row_shift(&t, -1);
        assert_eq!(missing, vec![0]);
        let expect = shift_logical(&t, ShiftDir::North, None);
        assert_eq!(north, expect);
        let (south, missing) = pointer_row_shift(&t, 1);
        assert_eq!(missing, vec![63]);
        assert_eq!(south, shift_logical(&t, ShiftDir::South, None));
    }

    #[test]
    fn physical_ew_pipeline_matches_logical() {
        // The §6.3 transpose pipeline must produce exactly the logical
        // column shift — this is the paper's correctness argument.
        for seed in 0..8 {
            let t = random_tile(seed);
            let halo: Vec<f32> = (0..64).map(|r| (r as f32).sin()).collect();
            for dir in [ShiftDir::East, ShiftDir::West] {
                let (phys, segs) = shift_physical_ew(&t, dir, Some(&halo));
                let logical = shift_logical(&t, dir, Some(&halo));
                assert_eq!(phys, logical, "dir {dir:?} seed {seed}");
                // §6.3: E/W halo is exchanged as 4 discontiguous segments.
                assert_eq!(segs, 4);
            }
        }
    }

    #[test]
    fn physical_ew_zero_fill_matches_logical() {
        let t = random_tile(11);
        for dir in [ShiftDir::East, ShiftDir::West] {
            let (phys, _) = shift_physical_ew(&t, dir, None);
            assert_eq!(phys, shift_logical(&t, dir, None));
        }
    }
}
