//! The tile abstraction — the central tt-metal data structure (§3.1):
//! logical/physical layouts, compute ops, and stencil shift construction.

pub mod data;
pub mod layout;
pub mod ops;
pub mod shift;

pub use data::Tile;
pub use layout::TileShape;
pub use ops::EltwiseOp;
pub use shift::ShiftDir;
