//! Host-side Compressed Sparse Row storage.
//!
//! CSR is the subsystem's *assembly* format: generators, the Matrix Market
//! reader, and the partitioner all speak CSR, and the correctness oracle
//! ([`CsrMatrix::apply_f64`]) runs on it. The device-facing format is
//! SELL-C-σ ([`crate::sparse::sell`]), converted from CSR per core.
//!
//! Within a row, entries are kept in **insertion order** — they are *not*
//! sorted by column. This is load-bearing: the 3D-Laplacian generator emits
//! each row's entries in the stencil kernel's canonical accumulation order
//! (center, x±, y±, z±), which is what lets the sparse SpMV reproduce the
//! matrix-free stencil engine bit-for-bit (see `kernels::spmv`).

use crate::error::{Result, SimError};

/// A general sparse matrix in CSR, FP32 values with 32-bit column indices
/// (the same index width cuSPARSE uses, §7.3).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    /// `row_ptr[i]..row_ptr[i+1]` spans row `i` in `col_idx`/`vals`.
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
}

impl CsrMatrix {
    /// Build from raw arrays, validating the invariants.
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        vals: Vec<f32>,
    ) -> Result<Self> {
        if row_ptr.len() != n_rows + 1 {
            return Err(SimError::BadProblem {
                what: format!("CSR row_ptr length {} != n_rows+1 {}", row_ptr.len(), n_rows + 1),
            });
        }
        if row_ptr[0] != 0 || *row_ptr.last().unwrap() != col_idx.len() {
            return Err(SimError::BadProblem {
                what: "CSR row_ptr must start at 0 and end at nnz".to_string(),
            });
        }
        if col_idx.len() != vals.len() {
            return Err(SimError::BadProblem {
                what: format!("CSR col_idx/vals length mismatch: {} vs {}", col_idx.len(), vals.len()),
            });
        }
        if row_ptr.windows(2).any(|w| w[0] > w[1]) {
            return Err(SimError::BadProblem {
                what: "CSR row_ptr not monotonically non-decreasing".to_string(),
            });
        }
        if let Some(&c) = col_idx.iter().find(|&&c| c as usize >= n_cols) {
            return Err(SimError::BadProblem {
                what: format!("CSR column index {c} out of range for {n_cols} columns"),
            });
        }
        Ok(Self {
            n_rows,
            n_cols,
            row_ptr,
            col_idx,
            vals,
        })
    }

    /// Build from (row, col, val) triplets. Triplets are bucketed by row;
    /// **within a row the given order is preserved** (see module docs).
    /// Duplicate (row, col) pairs are kept as separate entries.
    pub fn from_triplets(
        n_rows: usize,
        n_cols: usize,
        triplets: &[(usize, usize, f32)],
    ) -> Result<Self> {
        let mut per_row: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n_rows];
        for &(r, c, v) in triplets {
            if r >= n_rows || c >= n_cols {
                return Err(SimError::BadProblem {
                    what: format!("triplet ({r}, {c}) out of range for {n_rows}x{n_cols}"),
                });
            }
            per_row[r].push((c as u32, v));
        }
        let nnz = triplets.len();
        let mut row_ptr = Vec::with_capacity(n_rows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        row_ptr.push(0);
        for row in &per_row {
            for &(c, v) in row {
                col_idx.push(c);
                vals.push(v);
            }
            row_ptr.push(col_idx.len());
        }
        Self::new(n_rows, n_cols, row_ptr, col_idx, vals)
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Column indices and values of row `i`.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (lo, hi) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    pub fn row_nnz(&self, i: usize) -> usize {
        self.row_ptr[i + 1] - self.row_ptr[i]
    }

    pub fn max_row_nnz(&self) -> usize {
        (0..self.n_rows).map(|i| self.row_nnz(i)).max().unwrap_or(0)
    }

    /// Mean nonzeros per row.
    pub fn avg_row_nnz(&self) -> f64 {
        if self.n_rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n_rows as f64
        }
    }

    /// The matrix diagonal; absent entries read as 0. Duplicate diagonal
    /// entries sum (Matrix Market permits them).
    pub fn diagonal(&self) -> Vec<f32> {
        let mut d = vec![0.0f32; self.n_rows.min(self.n_cols)];
        for (i, di) in d.iter_mut().enumerate() {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                if c as usize == i {
                    *di += v;
                }
            }
        }
        d
    }

    /// y = A x in f64 — the subsystem's correctness oracle (the device path
    /// accumulates at operand precision; this does not).
    pub fn apply_f64(&self, x: &[f32]) -> Vec<f64> {
        assert_eq!(x.len(), self.n_cols, "SpMV operand length mismatch");
        let mut y = vec![0.0f64; self.n_rows];
        for (i, yi) in y.iter_mut().enumerate() {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0f64;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v as f64 * x[c as usize] as f64;
            }
            *yi = acc;
        }
        y
    }

    /// Structural + numerical symmetry check (duplicates summed), used to
    /// gate PCG which requires an SPD operator.
    pub fn is_symmetric(&self, tol: f32) -> bool {
        if self.n_rows != self.n_cols {
            return false;
        }
        let mut map = std::collections::BTreeMap::<(u32, u32), f32>::new();
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                *map.entry((i as u32, c)).or_insert(0.0) += v;
            }
        }
        map.iter().all(|(&(r, c), &v)| {
            let vt = map.get(&(c, r)).copied().unwrap_or(0.0);
            (v - vt).abs() <= tol * v.abs().max(vt.abs()).max(1.0)
        })
    }

    /// All entries as (row, col, val) triplets in storage order.
    pub fn triplets(&self) -> Vec<(usize, usize, f32)> {
        let mut out = Vec::with_capacity(self.nnz());
        for i in 0..self.n_rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                out.push((i, c as usize, v));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CsrMatrix {
        // [ 2 -1  0 ]
        // [-1  2 -1 ]
        // [ 0 -1  2 ]
        CsrMatrix::from_triplets(
            3,
            3,
            &[
                (0, 0, 2.0),
                (0, 1, -1.0),
                (1, 0, -1.0),
                (1, 1, 2.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 2.0),
            ],
        )
        .unwrap()
    }

    #[test]
    fn triplet_roundtrip_preserves_row_order() {
        // Within-row insertion order must survive (the stencil accumulation
        // order depends on it): row 0 deliberately emits col 2 before col 0.
        let m = CsrMatrix::from_triplets(2, 3, &[(0, 2, 5.0), (0, 0, 1.0), (1, 1, 3.0)]).unwrap();
        let (cols, vals) = m.row(0);
        assert_eq!(cols, &[2, 0]);
        assert_eq!(vals, &[5.0, 1.0]);
        assert_eq!(m.triplets(), vec![(0, 2, 5.0), (0, 0, 1.0), (1, 1, 3.0)]);
    }

    #[test]
    fn apply_matches_dense() {
        let m = small();
        let y = m.apply_f64(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![0.0, 0.0, 4.0]);
        assert_eq!(m.nnz(), 7);
        assert_eq!(m.max_row_nnz(), 3);
        assert!((m.avg_row_nnz() - 7.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn diagonal_and_symmetry() {
        let m = small();
        assert_eq!(m.diagonal(), vec![2.0, 2.0, 2.0]);
        assert!(m.is_symmetric(0.0));
        let asym = CsrMatrix::from_triplets(2, 2, &[(0, 1, 1.0), (1, 0, 2.0)]).unwrap();
        assert!(!asym.is_symmetric(1e-6));
        let rect = CsrMatrix::from_triplets(2, 3, &[(0, 0, 1.0)]).unwrap();
        assert!(!rect.is_symmetric(1e-6));
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        assert!(CsrMatrix::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // short row_ptr
        assert!(CsrMatrix::new(1, 1, vec![0, 2], vec![0], vec![1.0]).is_err()); // end != nnz
        assert!(CsrMatrix::new(1, 2, vec![0, 1], vec![2], vec![1.0]).is_err()); // col range
        assert!(CsrMatrix::from_triplets(2, 2, &[(2, 0, 1.0)]).is_err());
        assert!(CsrMatrix::new(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]).is_err());
    }

    #[test]
    fn empty_rows_allowed() {
        let m = CsrMatrix::from_triplets(3, 3, &[(1, 1, 4.0)]).unwrap();
        assert_eq!(m.row_nnz(0), 0);
        assert_eq!(m.row_nnz(1), 1);
        assert_eq!(m.apply_f64(&[1.0, 1.0, 1.0]), vec![0.0, 4.0, 0.0]);
    }
}
