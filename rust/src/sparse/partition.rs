//! Row-block distribution of sparse matrices and vectors over the Tensix
//! grid.
//!
//! Each core owns a fixed number of *vector slots* — `tiles_per_core`
//! 64×16 tiles, 1024 elements each, exactly the [`CoreBlock`] shape every
//! kernel in the crate consumes — and the matrix rows that produce those
//! slots. Two slot↔row mappings exist:
//!
//! - [`VectorLayout::RowBlock`]: contiguous row ranges in natural order;
//!   the general case for arbitrary matrices.
//! - [`VectorLayout::StencilAligned`]: the §6.1 stencil distribution
//!   (element `(i, j, k)` on core `(i/64, j/16)`, tile `k`, position
//!   `(i%64, j%16)`). Distributed vectors are then *block-for-block
//!   identical* to the stencil solver's, which is what lets sparse PCG on
//!   the generated Laplacian reproduce the stencil PCG trajectory exactly.
//!
//! The partitioner also answers the two §7.2-style resource questions:
//! does each core's share fit in SRAM ([`RowPartition::check_sram`], via
//! the [`crate::device::Sram`] bump allocator), and how much NoC gather
//! traffic do remote `x` entries cost ([`RowPartition::gather_plan`],
//! derived from the column-index footprint of each core's rows).

use std::collections::{BTreeMap, BTreeSet};

use crate::arch::constants::{L1_ALIGN, TILE_ELEMS};
use crate::arch::DataFormat;
use crate::device::{Coord, Sram};
use crate::engine::CoreBlock;
use crate::error::{Result, SimError};
use crate::sparse::csr::CsrMatrix;

/// How vector elements (= matrix rows) map onto core-local slots.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VectorLayout {
    /// Core `c` owns rows `[c·R, (c+1)·R)` with `R = tiles_per_core·1024`;
    /// slot order is row order. Trailing slots past `n` are padding.
    RowBlock,
    /// The stencil §6.1 mapping on an `nx × ny × nz` domain
    /// (`nx = 64·grid_rows`, `ny = 16·grid_cols`, `nz = tiles_per_core`).
    StencilAligned { nx: usize, ny: usize, nz: usize },
}

/// A row-block partition of an `n`-row matrix over a core grid.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowPartition {
    pub grid_rows: usize,
    pub grid_cols: usize,
    /// Matrix dimension (= global vector length).
    pub n: usize,
    /// Tiles per core; `tiles_per_core × 1024` slots per core.
    pub tiles_per_core: usize,
    pub layout: VectorLayout,
}

/// NoC gather requirements derived from the column-index footprint: which
/// remote `x` entries each core needs for one SpMV.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GatherPlan {
    /// Per consumer core: owner core → number of *distinct* remote columns
    /// (each entry is fetched once per SpMV and reused from SRAM).
    pub per_core: Vec<BTreeMap<usize, usize>>,
    /// Total remote entries across all cores.
    pub remote_entries: u64,
    /// Column references satisfied from the core's own block.
    pub local_references: u64,
}

impl GatherPlan {
    /// One batched message per (owner, consumer) pair.
    pub fn messages(&self) -> u64 {
        self.per_core.iter().map(|m| m.len() as u64).sum()
    }

    /// Payload bytes at `df`, each pair's batch rounded up to the 32 B
    /// L1/NoC beat (§3.3).
    pub fn bytes(&self, df: DataFormat) -> u64 {
        self.per_core
            .iter()
            .flat_map(|m| m.values())
            .map(|&cnt| ((cnt * df.bytes()) as u64).div_ceil(L1_ALIGN as u64) * L1_ALIGN as u64)
            .sum()
    }

    /// Remote entries the given core must receive.
    pub fn remote_entries_of(&self, core: usize) -> usize {
        self.per_core[core].values().sum()
    }
}

/// The inter-die communication plan of a partition spanning an x-stacked
/// die mesh (derived from a [`GatherPlan`]): every remote `x` reference is
/// classified die-local (NoC) or cross-die (Ethernet), with per-die-pair
/// entry and byte totals at the same 32 B per-(owner, consumer) batch
/// granularity the NoC gather uses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DieCutPlan {
    pub n_dies: usize,
    /// Core-grid rows each die owns.
    pub rows_per_die: usize,
    /// Core-grid columns each die owns (the full grid width on 1D
    /// x-stacked meshes; a 2D die grid splits the columns too).
    pub cols_per_die: usize,
    /// (owner die → consumer die) → distinct remote entries crossing the
    /// cut per SpMV.
    pub entries: BTreeMap<(usize, usize), u64>,
    /// (owner die → consumer die) → payload bytes per SpMV.
    pub bytes: BTreeMap<(usize, usize), u64>,
    /// Remote entries each die satisfies over its own NoC.
    pub intra_entries: Vec<u64>,
    /// Payload bytes each die's NoC carries for those entries, at the
    /// same per-(owner, consumer) 32 B batch rounding as the Ethernet
    /// side — so `cut_bytes() + intra_bytes.sum()` is exactly the
    /// single-die [`GatherPlan::bytes`] total (no double counting, no
    /// dropped batch; pinned in `tests/prop_sparse.rs`).
    pub intra_bytes: Vec<u64>,
}

impl DieCutPlan {
    /// Total entries crossing any die boundary per SpMV.
    pub fn cut_entries(&self) -> u64 {
        self.entries.values().sum()
    }

    /// Directed (src_die, dst_die, bytes) flows for the Ethernet halo
    /// phase lowering.
    pub fn flows(&self) -> Vec<(usize, usize, u64)> {
        self.bytes
            .iter()
            .map(|(&(owner, consumer), &b)| (owner, consumer, b))
            .collect()
    }

    /// Total bytes crossing any die boundary per SpMV.
    pub fn cut_bytes(&self) -> u64 {
        self.bytes.values().sum()
    }
}

impl RowPartition {
    /// Natural-order row blocks: `tiles_per_core` is the smallest tile
    /// count that covers `ceil(n / cores)` rows.
    pub fn row_block(grid_rows: usize, grid_cols: usize, n: usize) -> Result<Self> {
        if grid_rows == 0 || grid_cols == 0 || n == 0 {
            return Err(SimError::BadProblem {
                what: format!("empty partition: {grid_rows}x{grid_cols} grid, n = {n}"),
            });
        }
        let cores = grid_rows * grid_cols;
        let tiles_per_core = n.div_ceil(cores).div_ceil(TILE_ELEMS);
        Ok(Self {
            grid_rows,
            grid_cols,
            n,
            tiles_per_core,
            layout: VectorLayout::RowBlock,
        })
    }

    /// The stencil-compatible layout for an Eq.-1-ordered matrix on the
    /// implied `64·grid_rows × 16·grid_cols × nz` domain.
    pub fn stencil_aligned(grid_rows: usize, grid_cols: usize, nz: usize) -> Result<Self> {
        if grid_rows == 0 || grid_cols == 0 || nz == 0 {
            return Err(SimError::BadProblem {
                what: format!("empty partition: {grid_rows}x{grid_cols} grid, nz = {nz}"),
            });
        }
        let (nx, ny) = (64 * grid_rows, 16 * grid_cols);
        Ok(Self {
            grid_rows,
            grid_cols,
            n: nx * ny * nz,
            tiles_per_core: nz,
            layout: VectorLayout::StencilAligned { nx, ny, nz },
        })
    }

    pub fn n_cores(&self) -> usize {
        self.grid_rows * self.grid_cols
    }

    /// Vector slots per core.
    pub fn slots_per_core(&self) -> usize {
        self.tiles_per_core * TILE_ELEMS
    }

    pub fn core_coord(&self, core: usize) -> Coord {
        Coord::new(core / self.grid_cols, core % self.grid_cols)
    }

    /// Global row held by `(core, slot)`; `None` for padding slots.
    pub fn slot_to_global(&self, core: usize, slot: usize) -> Option<usize> {
        debug_assert!(slot < self.slots_per_core());
        match self.layout {
            VectorLayout::RowBlock => {
                let g = core * self.slots_per_core() + slot;
                (g < self.n).then_some(g)
            }
            VectorLayout::StencilAligned { nx, ny, .. } => {
                let (gr, gc) = (core / self.grid_cols, core % self.grid_cols);
                let z = slot / TILE_ELEMS;
                let xr = (slot % TILE_ELEMS) / 16;
                let yc = slot % 16;
                let (i, j) = (gr * 64 + xr, gc * 16 + yc);
                Some(i + nx * (j + ny * z))
            }
        }
    }

    /// Owning `(core, slot)` of global row `g`.
    pub fn global_to_slot(&self, g: usize) -> (usize, usize) {
        debug_assert!(g < self.n);
        match self.layout {
            VectorLayout::RowBlock => (g / self.slots_per_core(), g % self.slots_per_core()),
            VectorLayout::StencilAligned { nx, ny, .. } => {
                let i = g % nx;
                let j = (g / nx) % ny;
                let z = g / (nx * ny);
                let core = (i / 64) * self.grid_cols + j / 16;
                let slot = z * TILE_ELEMS + (i % 64) * 16 + j % 16;
                (core, slot)
            }
        }
    }

    /// Owning core of global row `g`.
    pub fn owner(&self, g: usize) -> usize {
        self.global_to_slot(g).0
    }

    /// Scatter a global vector into per-core blocks (padding slots zero).
    pub fn dist_from_global(&self, df: DataFormat, x: &[f32]) -> Vec<CoreBlock> {
        assert_eq!(x.len(), self.n, "global vector length mismatch");
        (0..self.n_cores())
            .map(|core| {
                CoreBlock::from_fn(df, self.tiles_per_core, |z, xr, yc| {
                    let slot = z * TILE_ELEMS + xr * 16 + yc;
                    self.slot_to_global(core, slot).map_or(0.0, |g| x[g])
                })
            })
            .collect()
    }

    /// Gather per-core blocks back to a global vector.
    pub fn dist_to_global(&self, v: &[CoreBlock]) -> Vec<f32> {
        assert_eq!(v.len(), self.n_cores(), "one block per core");
        let mut out = vec![0.0f32; self.n];
        for (core, block) in v.iter().enumerate() {
            let flat = block.to_flat();
            for (slot, &val) in flat.iter().enumerate() {
                if let Some(g) = self.slot_to_global(core, slot) {
                    out[g] = val;
                }
            }
        }
        out
    }

    /// Derive the NoC gather plan from the matrix's column-index footprint:
    /// for every core, the distinct columns its rows reference that live on
    /// another core, grouped by owner.
    pub fn gather_plan(&self, a: &CsrMatrix) -> Result<GatherPlan> {
        if a.n_rows != self.n || a.n_cols != self.n {
            return Err(SimError::BadProblem {
                what: format!(
                    "matrix {}x{} does not match partition over n = {}",
                    a.n_rows, a.n_cols, self.n
                ),
            });
        }
        let mut per_core = Vec::with_capacity(self.n_cores());
        let mut remote_entries = 0u64;
        let mut local_references = 0u64;
        for core in 0..self.n_cores() {
            let mut remote: BTreeSet<usize> = BTreeSet::new();
            for slot in 0..self.slots_per_core() {
                let Some(g) = self.slot_to_global(core, slot) else {
                    continue;
                };
                let (cols, _) = a.row(g);
                for &c in cols {
                    if self.owner(c as usize) == core {
                        local_references += 1;
                    } else {
                        remote.insert(c as usize);
                    }
                }
            }
            let mut by_owner: BTreeMap<usize, usize> = BTreeMap::new();
            for c in remote {
                *by_owner.entry(self.owner(c)).or_insert(0) += 1;
            }
            remote_entries += by_owner.values().map(|&v| v as u64).sum::<u64>();
            per_core.push(by_owner);
        }
        Ok(GatherPlan {
            per_core,
            remote_entries,
            local_references,
        })
    }

    /// Split a gather plan by die for an x-stacked mesh of `n_dies` dies
    /// (die `d` owns core-grid rows `[d·R/N, (d+1)·R/N)`): per-die-pair
    /// cut entries/bytes for the Ethernet halo, and the per-die remainder
    /// that stays on the NoC. `df` fixes the byte accounting at the same
    /// 32 B batch rounding as [`GatherPlan::bytes`].
    pub fn die_cut(&self, gather: &GatherPlan, n_dies: usize, df: DataFormat) -> Result<DieCutPlan> {
        self.die_cut_grid(gather, n_dies, 1, df)
    }

    /// The 2D-die-grid generalization of [`Self::die_cut`]: dies tile
    /// the core grid as a row-major `mesh_rows × mesh_cols` grid, die
    /// (r, c) owning core rows `[r·grid_rows/mesh_rows, …)` × columns
    /// `[c·grid_cols/mesh_cols, …)`. `die_cut` is exactly the
    /// `mesh_cols = 1` column.
    pub fn die_cut_grid(
        &self,
        gather: &GatherPlan,
        mesh_rows: usize,
        mesh_cols: usize,
        df: DataFormat,
    ) -> Result<DieCutPlan> {
        if mesh_rows == 0
            || mesh_cols == 0
            || self.grid_rows % mesh_rows != 0
            || self.grid_cols % mesh_cols != 0
        {
            return Err(SimError::BadProblem {
                what: format!(
                    "{}x{} core grid does not split over a {mesh_rows}x{mesh_cols} die grid",
                    self.grid_rows, self.grid_cols
                ),
            });
        }
        let n_dies = mesh_rows * mesh_cols;
        if gather.per_core.len() != self.n_cores() {
            return Err(SimError::BadProblem {
                what: format!(
                    "gather plan covers {} cores, partition has {}",
                    gather.per_core.len(),
                    self.n_cores()
                ),
            });
        }
        let rows_per_die = self.grid_rows / mesh_rows;
        let cols_per_die = self.grid_cols / mesh_cols;
        let die_of = |core: usize| {
            let coord = self.core_coord(core);
            (coord.row / rows_per_die) * mesh_cols + coord.col / cols_per_die
        };
        let mut entries: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        let mut bytes: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        let mut intra_entries = vec![0u64; n_dies];
        let mut intra_bytes = vec![0u64; n_dies];
        for (consumer, by_owner) in gather.per_core.iter().enumerate() {
            let cd = die_of(consumer);
            for (&owner, &cnt) in by_owner {
                let od = die_of(owner);
                // Every (owner, consumer) batch is classified exactly
                // once, at the same 32 B beat rounding on both sides of
                // the split, so the cut + the per-die NoC remainder
                // reproduce the single-die gather bytes exactly.
                let batch = ((cnt * df.bytes()) as u64).div_ceil(L1_ALIGN as u64) * L1_ALIGN as u64;
                if od == cd {
                    intra_entries[cd] += cnt as u64;
                    intra_bytes[cd] += batch;
                } else {
                    *entries.entry((od, cd)).or_insert(0) += cnt as u64;
                    *bytes.entry((od, cd)).or_insert(0) += batch;
                }
            }
        }
        Ok(DieCutPlan {
            n_dies,
            rows_per_die,
            cols_per_die,
            entries,
            bytes,
            intra_entries,
            intra_bytes,
        })
    }

    /// Check one core's SpMV working set against L1 SRAM using the
    /// [`Sram`] bump allocator. `regions` is a list of (name, bytes)
    /// allocations on top of `reserve` bytes of program/stack/CB space;
    /// the error carries the §7.2-style exhaustion detail.
    pub fn check_sram(&self, core: usize, reserve: usize, regions: &[(&str, usize)]) -> Result<usize> {
        let coord = self.core_coord(core);
        let mut sram = Sram::new(&format!("core({},{})", coord.row, coord.col));
        sram.alloc("reserved(program/stack/CB)", reserve)?;
        for &(name, bytes) in regions {
            sram.alloc(name, bytes)?;
        }
        Ok(sram.used())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::mtx::{banded, laplacian_3d};
    use crate::util::prng::Rng;

    #[test]
    fn row_block_mapping_roundtrip() {
        let p = RowPartition::row_block(2, 2, 5000).unwrap();
        // 5000 / 4 cores = 1250 rows → 2 tiles (2048 slots) per core.
        assert_eq!(p.tiles_per_core, 2);
        assert_eq!(p.slots_per_core(), 2048);
        for g in [0usize, 1, 2047, 2048, 4999] {
            let (core, slot) = p.global_to_slot(g);
            assert_eq!(p.slot_to_global(core, slot), Some(g));
        }
        // Core 2 owns rows [4096, 5000); its slots past 903 are padding.
        assert_eq!(p.slot_to_global(2, 903), Some(4999));
        assert_eq!(p.slot_to_global(2, 904), None);
    }

    #[test]
    fn stencil_aligned_matches_problem_layout() {
        use crate::arch::DataFormat;
        use crate::solver::problem::{dist_random, dist_to_global, Problem};
        let prob = Problem::new(2, 2, 3, DataFormat::Fp32);
        let part = RowPartition::stencil_aligned(2, 2, 3).unwrap();
        assert_eq!(part.n, prob.elems());
        // A stencil-layout DistVector and the partition agree block-for-block.
        let v = dist_random(&prob, 99);
        let global = dist_to_global(&prob, &v);
        let re = part.dist_from_global(DataFormat::Fp32, &global);
        assert_eq!(v, re);
        assert_eq!(part.dist_to_global(&v), global);
        // Eq.-1 index ↔ (core, slot) agreement with Problem::global_index.
        let g = prob.global_index(70, 20, 2); // core (1,1)
        let (core, slot) = part.global_to_slot(g);
        assert_eq!(core, 3);
        assert_eq!(slot, 2 * 1024 + 6 * 16 + 4);
    }

    #[test]
    fn dist_roundtrip_row_block() {
        use crate::arch::DataFormat;
        let p = RowPartition::row_block(1, 3, 2500).unwrap();
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..2500).map(|_| rng.next_f32()).collect();
        let blocks = p.dist_from_global(DataFormat::Fp32, &x);
        assert_eq!(blocks.len(), 3);
        assert_eq!(p.dist_to_global(&blocks), x);
    }

    #[test]
    fn laplacian_gather_footprint_is_the_halo() {
        // On the stencil-aligned Laplacian, remote columns are exactly the
        // §6.1 halo: each core-boundary face contributes one entry per
        // boundary element.
        let part = RowPartition::stencil_aligned(2, 2, 2).unwrap();
        let a = laplacian_3d(128, 32, 2);
        let plan = part.gather_plan(&a).unwrap();
        // Core 0 (top-left) needs its South (x+) and East (y+) faces:
        // 16 y-cols × nz from the south row-neighbor and 64 x-rows × nz
        // from the east col-neighbor.
        let c0 = &plan.per_core[0];
        assert_eq!(c0.len(), 2);
        assert_eq!(c0[&2], 16 * 2); // south neighbor: core index 2 (row 1, col 0)
        assert_eq!(c0[&1], 64 * 2); // east neighbor: core index 1
        assert_eq!(plan.remote_entries, 4 * (16 * 2 + 64 * 2) as u64);
        assert!(plan.local_references > 0);
        assert_eq!(plan.messages(), 8);
    }

    #[test]
    fn banded_row_block_gather_only_touches_adjacent_blocks() {
        let part = RowPartition::row_block(1, 4, 4 * 1024).unwrap();
        let a = banded(4 * 1024, 3).unwrap();
        let plan = part.gather_plan(&a).unwrap();
        // Interior cores see exactly their two neighbors, 3 entries each.
        let c1 = &plan.per_core[1];
        assert_eq!(c1.len(), 2);
        assert_eq!(c1[&0], 3);
        assert_eq!(c1[&2], 3);
        // Bytes round up to the 32 B beat per pair.
        use crate::arch::DataFormat;
        assert_eq!(plan.bytes(DataFormat::Fp32), plan.messages() * 32);
    }

    #[test]
    fn die_cut_of_laplacian_is_the_seam_halo() {
        use crate::arch::DataFormat;
        // A 2-die x-stacked split of the 2×2 stencil-aligned partition:
        // the cut is exactly the §6.1 x-face between core rows — 16·nz
        // entries per boundary core pair, each direction.
        let part = RowPartition::stencil_aligned(2, 2, 2).unwrap();
        let a = laplacian_3d(128, 32, 2);
        let plan = part.gather_plan(&a).unwrap();
        let cut = part.die_cut(&plan, 2, DataFormat::Fp32).unwrap();
        assert_eq!(cut.rows_per_die, 1);
        assert_eq!(cut.entries[&(0, 1)], 2 * 16 * 2); // two core pairs × 16·nz
        assert_eq!(cut.entries[&(1, 0)], 2 * 16 * 2);
        assert_eq!(cut.cut_entries(), 4 * 16 * 2);
        // Per (owner-core, consumer-core) batch, 32 B-aligned: 32 FP32
        // entries = 128 B per batch, 2 batches per direction.
        assert_eq!(cut.bytes[&(0, 1)], 2 * 128);
        // What does not cross the cut stays on each die's NoC: the E/W
        // faces (64·nz per core pair).
        assert_eq!(cut.intra_entries, vec![2 * 64 * 2, 2 * 64 * 2]);
        assert_eq!(
            cut.cut_entries() + cut.intra_entries.iter().sum::<u64>(),
            plan.remote_entries
        );
        // Byte-level conservation at batch granularity: Ethernet cut +
        // per-die NoC remainder = the single-die gather total.
        assert_eq!(
            cut.cut_bytes() + cut.intra_bytes.iter().sum::<u64>(),
            plan.bytes(DataFormat::Fp32)
        );
        // One die: everything is NoC-local.
        let whole = part.die_cut(&plan, 1, DataFormat::Fp32).unwrap();
        assert_eq!(whole.cut_entries(), 0);
        assert!(whole.flows().is_empty());
        // Rows must split evenly over dies.
        assert!(part.die_cut(&plan, 3, DataFormat::Fp32).is_err());
    }

    #[test]
    fn die_cut_grid_splits_both_axes() {
        use crate::arch::DataFormat;
        // A 2×2 die grid over the 2×2 stencil-aligned partition: one
        // core per die. Both the x faces (N/S, 16·nz entries per pair)
        // and the y faces (E/W, 64·nz per pair) now cross die cuts;
        // nothing stays on any die's NoC.
        let part = RowPartition::stencil_aligned(2, 2, 2).unwrap();
        let a = laplacian_3d(128, 32, 2);
        let plan = part.gather_plan(&a).unwrap();
        let cut = part.die_cut_grid(&plan, 2, 2, DataFormat::Fp32).unwrap();
        assert_eq!((cut.rows_per_die, cut.cols_per_die), (1, 1));
        assert_eq!(cut.n_dies, 4);
        // Vertical faces: dies 0↔2 and 1↔3, 16·nz entries each direction.
        assert_eq!(cut.entries[&(0, 2)], 16 * 2);
        assert_eq!(cut.entries[&(2, 0)], 16 * 2);
        // Horizontal faces: dies 0↔1 and 2↔3, 64·nz entries each.
        assert_eq!(cut.entries[&(0, 1)], 64 * 2);
        assert_eq!(cut.entries[&(1, 0)], 64 * 2);
        assert_eq!(cut.intra_entries, vec![0; 4]);
        // Conservation still holds at batch granularity.
        assert_eq!(
            cut.cut_bytes() + cut.intra_bytes.iter().sum::<u64>(),
            plan.bytes(DataFormat::Fp32)
        );
        // The 1D x-stacked cut is exactly the mesh_cols = 1 column.
        assert_eq!(
            part.die_cut(&plan, 2, DataFormat::Fp32).unwrap(),
            part.die_cut_grid(&plan, 2, 1, DataFormat::Fp32).unwrap()
        );
        // Both axes must split evenly.
        assert!(part.die_cut_grid(&plan, 2, 3, DataFormat::Fp32).is_err());
        assert!(part.die_cut_grid(&plan, 0, 2, DataFormat::Fp32).is_err());
    }

    #[test]
    fn sram_check_reports_exhaustion() {
        let p = RowPartition::row_block(1, 1, 1024).unwrap();
        assert!(p.check_sram(0, 256 * 1024, &[("vals", 64 * 1024)]).is_ok());
        let err = p
            .check_sram(0, 256 * 1024, &[("vals", 2 * 1024 * 1024)])
            .unwrap_err();
        assert!(matches!(err, SimError::SramExhausted { .. }));
    }

    #[test]
    fn size_mismatch_rejected() {
        let p = RowPartition::row_block(1, 2, 100).unwrap();
        let a = banded(64, 2).unwrap();
        assert!(p.gather_plan(&a).is_err());
        assert!(RowPartition::row_block(0, 2, 10).is_err());
        assert!(RowPartition::stencil_aligned(1, 1, 0).is_err());
    }
}
