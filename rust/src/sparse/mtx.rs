//! Matrix Market I/O and matrix generators.
//!
//! The reader accepts the coordinate format (`real`, `integer`, `pattern`;
//! `general` or `symmetric`) — enough for the SuiteSparse-style test
//! matrices sparse solver studies are run on. The generators produce the
//! three workload families the subsystem is benchmarked with:
//!
//! - [`laplacian_3d`] — the §7 model problem as an *explicit* matrix. Rows
//!   follow the paper's Eq.-1 ordering and each row's entries follow the
//!   stencil kernel's canonical accumulation order (center, x±, y±, z±),
//!   which makes the SpMV path bit-identical to the matrix-free stencil.
//! - [`circulant_spd`] — random symmetric positive-definite circulant with
//!   an exactly uniform nnz/row (the zero-padding-free case, matching the
//!   [`crate::baseline::sell`] traffic model's uniform-row assumption).
//! - [`banded`] — SPD band matrix with ragged boundary rows (the padding
//!   stress case for SELL).

use std::path::Path;

use crate::error::{Result, SimError};
use crate::sparse::csr::CsrMatrix;
use crate::util::prng::Rng;

fn bad(what: impl Into<String>) -> SimError {
    SimError::Config(what.into())
}

/// Parse a Matrix Market document from text.
pub fn parse_mtx(text: &str) -> Result<CsrMatrix> {
    let mut lines = text.lines();
    let header = lines.next().ok_or_else(|| bad("empty MatrixMarket file"))?;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 5 || !h[0].eq_ignore_ascii_case("%%MatrixMarket") {
        return Err(bad(format!("not a MatrixMarket header: '{header}'")));
    }
    let (object, format, field, symmetry) = (
        h[1].to_ascii_lowercase(),
        h[2].to_ascii_lowercase(),
        h[3].to_ascii_lowercase(),
        h[4].to_ascii_lowercase(),
    );
    if object != "matrix" || format != "coordinate" {
        return Err(bad(format!("unsupported MatrixMarket object/format: {object}/{format}")));
    }
    let pattern = match field.as_str() {
        "real" | "integer" => false,
        "pattern" => true,
        other => return Err(bad(format!("unsupported MatrixMarket field '{other}'"))),
    };
    let symmetric = match symmetry.as_str() {
        "general" => false,
        "symmetric" => true,
        other => return Err(bad(format!("unsupported MatrixMarket symmetry '{other}'"))),
    };

    let mut size: Option<(usize, usize, usize)> = None;
    let mut triplets: Vec<(usize, usize, f32)> = Vec::new();
    let mut mirrored = 0usize;
    for (lineno, raw) in lines.enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('%') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        let ctx = || format!("MatrixMarket line {}: '{line}'", lineno + 2);
        if size.is_none() {
            if toks.len() != 3 {
                return Err(bad(format!("{}: expected 'rows cols nnz'", ctx())));
            }
            let p = |s: &str| s.parse::<usize>().map_err(|e| bad(format!("{}: {e}", ctx())));
            size = Some((p(toks[0])?, p(toks[1])?, p(toks[2])?));
            continue;
        }
        let want = if pattern { 2 } else { 3 };
        if toks.len() < want {
            return Err(bad(format!("{}: expected {want} fields", ctx())));
        }
        let i: usize = toks[0].parse().map_err(|e| bad(format!("{}: {e}", ctx())))?;
        let j: usize = toks[1].parse().map_err(|e| bad(format!("{}: {e}", ctx())))?;
        if i == 0 || j == 0 {
            return Err(bad(format!("{}: MatrixMarket indices are 1-based", ctx())));
        }
        let v: f32 = if pattern {
            1.0
        } else {
            toks[2].parse().map_err(|e| bad(format!("{}: {e}", ctx())))?
        };
        triplets.push((i - 1, j - 1, v));
        if symmetric && i != j {
            triplets.push((j - 1, i - 1, v));
            mirrored += 1;
        }
    }
    let (n_rows, n_cols, nnz) = size.ok_or_else(|| bad("MatrixMarket file has no size line"))?;
    // For symmetric files, `nnz` declares the stored (one-triangle)
    // entries; the mirrors we synthesized do not count against it.
    if triplets.len() - mirrored != nnz {
        return Err(bad(format!(
            "MatrixMarket entry count {} does not match declared nnz {nnz}",
            triplets.len() - mirrored
        )));
    }
    // Canonical (row, col) order — MTX files carry no meaningful order.
    triplets.sort_by_key(|&(i, j, _)| (i, j));
    CsrMatrix::from_triplets(n_rows, n_cols, &triplets)
}

/// Read a Matrix Market file from disk.
pub fn read_mtx(path: &Path) -> Result<CsrMatrix> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| bad(format!("reading {}: {e}", path.display())))?;
    parse_mtx(&text)
}

/// Serialize as `coordinate real general` (1-based, row-major).
pub fn write_mtx(a: &CsrMatrix) -> String {
    let mut out = String::from("%%MatrixMarket matrix coordinate real general\n");
    out.push_str(&format!("{} {} {}\n", a.n_rows, a.n_cols, a.nnz()));
    for (i, j, v) in a.triplets() {
        out.push_str(&format!("{} {} {v:e}\n", i + 1, j + 1));
    }
    out
}

/// The 7-point 3D Laplacian with zero Dirichlet boundaries on an
/// `nx × ny × nz` grid, as an explicit sparse matrix.
///
/// Row/column ordering is the paper's Eq. 1 (`g = i + nx*(j + ny*k)`), and
/// each row's entries are emitted in the stencil kernel's canonical
/// accumulation order — center (+6), x−, x+, y−, y+, z−, z+ (each −1) —
/// with out-of-domain neighbors skipped. Preserving this order end to end
/// is what lets `kernels::spmv` reproduce
/// [`crate::engine::ComputeEngine::stencil_apply`] bit-for-bit.
pub fn laplacian_3d(nx: usize, ny: usize, nz: usize) -> CsrMatrix {
    let n = nx * ny * nz;
    let g = |i: usize, j: usize, k: usize| i + nx * (j + ny * k);
    let mut row_ptr = Vec::with_capacity(n + 1);
    let mut col_idx = Vec::with_capacity(7 * n);
    let mut vals = Vec::with_capacity(7 * n);
    row_ptr.push(0);
    for k in 0..nz {
        for j in 0..ny {
            for i in 0..nx {
                col_idx.push(g(i, j, k) as u32);
                vals.push(6.0);
                // Canonical stencil accumulation order: x−, x+, y−, y+,
                // z−, z+, skipping out-of-domain (zero Dirichlet).
                let neighbors = [
                    (i > 0).then(|| g(i - 1, j, k)),
                    (i + 1 < nx).then(|| g(i + 1, j, k)),
                    (j > 0).then(|| g(i, j - 1, k)),
                    (j + 1 < ny).then(|| g(i, j + 1, k)),
                    (k > 0).then(|| g(i, j, k - 1)),
                    (k + 1 < nz).then(|| g(i, j, k + 1)),
                ];
                for c in neighbors.into_iter().flatten() {
                    col_idx.push(c as u32);
                    vals.push(-1.0);
                }
                row_ptr.push(col_idx.len());
            }
        }
    }
    CsrMatrix::new(n, n, row_ptr, col_idx, vals).expect("generator invariants")
}

/// Random symmetric positive-definite circulant with an exactly uniform
/// `nnz_per_row` (≥ 1): distinct offsets `d ∈ [1, n/2)` each carry one
/// value on the ±d wrap-around diagonals; for an **even** `nnz_per_row`
/// the self-paired offset `n/2` (requires even `n`) contributes one more
/// entry per row. The main diagonal is `1 + Σ |v_d over the row|` (strict
/// diagonal dominance of a symmetric matrix ⇒ SPD). Every row stores
/// exactly `nnz_per_row` entries, so the SELL conversion is padding-free
/// — the uniform-row case the cuSPARSE Sliced-ELL traffic model assumes.
pub fn circulant_spd(n: usize, nnz_per_row: usize, seed: u64) -> Result<CsrMatrix> {
    if nnz_per_row == 0 {
        return Err(SimError::BadProblem {
            what: "circulant_spd needs nnz_per_row >= 1".to_string(),
        });
    }
    let use_half = nnz_per_row % 2 == 0;
    if use_half && n % 2 != 0 {
        return Err(SimError::BadProblem {
            what: format!("circulant_spd: even nnz_per_row {nnz_per_row} needs an even n, got {n}"),
        });
    }
    let m = if use_half { (nnz_per_row - 2) / 2 } else { (nnz_per_row - 1) / 2 };
    // Paired offsets must be distinct and < n/2 so +d and −d never collide
    // (n/2 itself is reserved for the self-paired even case).
    if n < 2 * m + 2 {
        return Err(SimError::BadProblem {
            what: format!("circulant_spd: n = {n} too small for {nnz_per_row} nnz/row"),
        });
    }
    let mut rng = Rng::new(seed);
    let mut offsets = std::collections::BTreeSet::new();
    while offsets.len() < m {
        let half = (n - 1) / 2;
        offsets.insert(1 + rng.below(half as u64) as usize);
    }
    let offvals: Vec<(usize, f32)> = offsets
        .into_iter()
        .map(|d| (d, -(0.1 + 0.9 * rng.next_f32())))
        .collect();
    let half_val: f32 = if use_half { -(0.1 + 0.9 * rng.next_f32()) } else { 0.0 };
    let diag: f32 =
        1.0 + 2.0 * offvals.iter().map(|(_, v)| v.abs()).sum::<f32>() + half_val.abs();

    let mut row_ptr = Vec::with_capacity(n + 1);
    let mut col_idx = Vec::with_capacity(n * nnz_per_row);
    let mut vals = Vec::with_capacity(n * nnz_per_row);
    row_ptr.push(0);
    for i in 0..n {
        // Ascending-column order within the row.
        let mut entries: Vec<(usize, f32)> = vec![(i, diag)];
        for &(d, v) in &offvals {
            entries.push(((i + d) % n, v));
            entries.push(((i + n - d) % n, v));
        }
        if use_half {
            entries.push(((i + n / 2) % n, half_val));
        }
        entries.sort_by_key(|&(c, _)| c);
        for (c, v) in entries {
            col_idx.push(c as u32);
            vals.push(v);
        }
        row_ptr.push(col_idx.len());
    }
    CsrMatrix::new(n, n, row_ptr, col_idx, vals)
}

/// SPD band matrix: `a_ii = 2·hb`, `a_ij = −1` for `0 < |i−j| ≤ hb` (the
/// band analog of the 1D Laplacian). Boundary rows are shorter — the
/// ragged case that exercises SELL padding.
pub fn banded(n: usize, half_bandwidth: usize) -> Result<CsrMatrix> {
    if half_bandwidth == 0 || half_bandwidth >= n {
        return Err(SimError::BadProblem {
            what: format!("banded: half bandwidth {half_bandwidth} out of range for n = {n}"),
        });
    }
    let mut triplets = Vec::new();
    for i in 0..n {
        let lo = i.saturating_sub(half_bandwidth);
        let hi = (i + half_bandwidth).min(n - 1);
        for j in lo..=hi {
            let v = if i == j { 2.0 * half_bandwidth as f32 } else { -1.0 };
            triplets.push((i, j, v));
        }
    }
    CsrMatrix::from_triplets(n, n, &triplets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_general_real() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % a comment\n\
                    3 3 4\n\
                    1 1 2.0\n\
                    2 2 3.0\n\
                    3 3 4.0\n\
                    1 3 -1.5\n";
        let m = parse_mtx(text).unwrap();
        assert_eq!((m.n_rows, m.n_cols, m.nnz()), (3, 3, 4));
        assert_eq!(m.diagonal(), vec![2.0, 3.0, 4.0]);
        let (cols, vals) = m.row(0);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[2.0, -1.5]);
    }

    #[test]
    fn parse_symmetric_mirrors_off_diagonal() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    2 2 3\n\
                    1 1 2.0\n\
                    2 1 -1.0\n\
                    2 2 2.0\n";
        let m = parse_mtx(text).unwrap();
        assert_eq!(m.nnz(), 4);
        assert!(m.is_symmetric(0.0));
        assert_eq!(m.row(0).0, &[0, 1]);
    }

    #[test]
    fn parse_pattern_and_errors() {
        let m = parse_mtx("%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 2\n2 1\n")
            .unwrap();
        assert_eq!(m.vals, vec![1.0, 1.0]);
        assert!(parse_mtx("nonsense").is_err());
        assert!(parse_mtx("%%MatrixMarket matrix array real general\n2 2\n").is_err());
        assert!(parse_mtx("%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 5.0\n").is_err());
        assert!(parse_mtx("%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 5.0\n").is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let a = banded(20, 3).unwrap();
        let text = write_mtx(&a);
        let b = parse_mtx(&text).unwrap();
        // banded emits ascending columns, so the canonical reorder is a
        // no-op and the round trip is exact.
        assert_eq!(a, b);
    }

    #[test]
    fn laplacian_matches_global_oracle() {
        use crate::arch::DataFormat;
        use crate::solver::problem::{apply_laplacian_global, Problem};
        let p = Problem::new(1, 1, 3, DataFormat::Fp32);
        let (nx, ny, nz) = p.dims();
        let a = laplacian_3d(nx, ny, nz);
        assert_eq!(a.n_rows, p.elems());
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..p.elems()).map(|_| rng.next_f32() - 0.5).collect();
        let want = apply_laplacian_global(&p, &x);
        let got = a.apply_f64(&x);
        for (idx, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-9, "elem {idx}: {g} vs {w}");
        }
    }

    #[test]
    fn laplacian_rows_follow_stencil_order() {
        // Interior row: center first, then x−, x+, y−, y+, z−, z+.
        let nx = 4;
        let ny = 4;
        let a = laplacian_3d(nx, ny, 3);
        let g = 1 + nx * (1 + ny); // (1,1,1): fully interior
        let (cols, vals) = a.row(g);
        let expect: Vec<u32> = vec![
            g as u32,
            (g - 1) as u32,
            (g + 1) as u32,
            (g - nx) as u32,
            (g + nx) as u32,
            (g - nx * ny) as u32,
            (g + nx * ny) as u32,
        ];
        assert_eq!(cols, expect.as_slice());
        assert_eq!(vals[0], 6.0);
        assert!(vals[1..].iter().all(|&v| v == -1.0));
        // Corner row keeps the same relative order, skipping the missing.
        let (cols0, _) = a.row(0);
        assert_eq!(cols0, &[0, 1, nx as u32, (nx * ny) as u32]);
    }

    #[test]
    fn circulant_uniform_and_spd_shaped() {
        let a = circulant_spd(64, 7, 42).unwrap();
        assert_eq!(a.n_rows, 64);
        for i in 0..64 {
            assert_eq!(a.row_nnz(i), 7, "row {i}");
        }
        assert!(a.is_symmetric(1e-6));
        // Strict diagonal dominance.
        let d = a.diagonal();
        for i in 0..64 {
            let (cols, vals) = a.row(i);
            let off: f32 = cols
                .iter()
                .zip(vals)
                .filter(|(&c, _)| c as usize != i)
                .map(|(_, v)| v.abs())
                .sum();
            assert!(d[i] > off, "row {i}: diag {} vs off {off}", d[i]);
        }
        // Even nnz/row: the self-paired n/2 offset keeps rows uniform.
        let even = circulant_spd(64, 8, 5).unwrap();
        for i in 0..64 {
            assert_eq!(even.row_nnz(i), 8, "row {i}");
        }
        assert!(even.is_symmetric(1e-6));
        // Even nnz/row needs an even n; too-small n rejected.
        assert!(circulant_spd(9, 4, 1).is_err());
        assert!(circulant_spd(4, 7, 1).is_err());
        assert!(circulant_spd(8, 0, 1).is_err());
    }

    #[test]
    fn banded_shape() {
        let a = banded(10, 2).unwrap();
        assert!(a.is_symmetric(0.0));
        assert_eq!(a.row_nnz(0), 3);
        assert_eq!(a.row_nnz(5), 5);
        assert_eq!(a.diagonal(), vec![4.0; 10]);
        assert!(banded(5, 0).is_err());
    }
}
