//! SELL-C-σ — the device-facing sparse format.
//!
//! Sliced ELLPACK groups rows into *slices* of C consecutive rows; each
//! slice is padded to its own widest row and stored column-major (all
//! first-nonzeros of the slice, then all second-nonzeros, …). A σ-row
//! sorting window orders rows by descending length before slicing, which
//! trims padding when row lengths vary.
//!
//! We fix **C = 32** ([`SELL_SLICE_HEIGHT`]): one slice is exactly two
//! 16×16 tile faces (§3.1) — the granularity at which the unpacker moves
//! data — and 32 FP32 values are one 128 B unpack beat, so a slice column
//! maps onto whole faces of the 1024-element operand tiles the compute
//! units consume. σ is a tuning knob: σ = 1 disables sorting (identity
//! permutation), which the stencil-aligned partition relies on.

use crate::arch::DataFormat;
use crate::error::{Result, SimError};
use crate::sparse::csr::CsrMatrix;

/// Slice height C: two tile faces / one 128 B FP32 unpack beat (see
/// module docs).
pub const SELL_SLICE_HEIGHT: usize = 32;

/// Occupancy statistics of a SELL conversion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SellStats {
    /// True nonzeros of the source matrix.
    pub nnz: usize,
    /// Stored entries after slice padding (Σ slice_width × C).
    pub padded_nnz: usize,
    pub n_slices: usize,
    /// Widest slice (max nnz/row after windowed sorting).
    pub max_width: usize,
}

impl SellStats {
    /// Fraction of stored entries that are real nonzeros.
    pub fn occupancy(&self) -> f64 {
        if self.padded_nnz == 0 {
            1.0
        } else {
            self.nnz as f64 / self.padded_nnz as f64
        }
    }

    /// Stored-to-real entry ratio (≥ 1; the SELL padding overhead).
    pub fn overhead(&self) -> f64 {
        if self.nnz == 0 {
            1.0
        } else {
            self.padded_nnz as f64 / self.nnz as f64
        }
    }
}

/// Validate a SELL-C-σ parameter pair: positive slice height, and σ
/// either 1 or a multiple of C (windows that split a slice would make
/// the permutation ambiguous).
fn validate_params(c: usize, sigma: usize) -> Result<()> {
    if c == 0 {
        return Err(SimError::BadProblem {
            what: "SELL slice height must be positive".to_string(),
        });
    }
    if sigma != 1 && sigma % c != 0 {
        return Err(SimError::BadProblem {
            what: format!("SELL σ = {sigma} must be 1 or a multiple of C = {c}"),
        });
    }
    Ok(())
}

/// Closed-form padded-entry count of a SELL-C-σ conversion, computed from
/// the CSR row lengths without building the matrix: rows are length-sorted
/// (descending, stable) within each σ window, chunked into C-row slices
/// (the last slice padded to full height), and each slice stores
/// `C × max(row length in slice)` entries. Rejects exactly the (C, σ)
/// pairs [`SellMatrix::from_csr`] rejects; property-tested against the
/// entries it actually stores.
pub fn padded_nnz_formula(a: &CsrMatrix, c: usize, sigma: usize) -> Result<usize> {
    validate_params(c, sigma)?;
    let order = sorted_row_order(a, c, sigma);
    let mut padded = 0;
    for slice in order.chunks(c) {
        let width = slice
            .iter()
            .map(|&r| if r == usize::MAX { 0 } else { a.row_nnz(r) })
            .max()
            .unwrap_or(0);
        padded += width * c;
    }
    Ok(padded)
}

/// Row order after windowed sorting, padded with `usize::MAX` virtual rows
/// to a multiple of the slice height.
fn sorted_row_order(a: &CsrMatrix, c: usize, sigma: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..a.n_rows).collect();
    if sigma > 1 {
        for window in order.chunks_mut(sigma) {
            // Stable: ties keep ascending row index, so conversion is
            // deterministic.
            window.sort_by_key(|&r| std::cmp::Reverse(a.row_nnz(r)));
        }
    }
    let slots = a.n_rows.div_ceil(c) * c;
    order.resize(slots, usize::MAX);
    order
}

/// A sparse matrix in SELL-C-σ.
#[derive(Debug, Clone, PartialEq)]
pub struct SellMatrix {
    pub n_rows: usize,
    pub n_cols: usize,
    /// Slice height C.
    pub c: usize,
    /// Sorting window in rows (1 = no sorting).
    pub sigma: usize,
    /// `slice_ptr[s]..slice_ptr[s+1]` spans slice `s` in `col_idx`/`vals`.
    pub slice_ptr: Vec<usize>,
    /// Padded width (max nnz/row) of each slice.
    pub slice_width: Vec<usize>,
    /// Column-major within each slice: entry (k, r) of slice s sits at
    /// `slice_ptr[s] + k * c + r`. Padding entries carry col 0, val 0.
    pub col_idx: Vec<u32>,
    pub vals: Vec<f32>,
    /// `perm[slot] = original row` for slot `s * c + r`; `usize::MAX`
    /// marks the virtual rows that pad the final slice.
    pub perm: Vec<usize>,
    /// True nonzero count of each slot's row (reconstruction needs it:
    /// genuinely-stored zero values must survive a CSR round-trip).
    pub slot_nnz: Vec<usize>,
}

impl SellMatrix {
    /// Convert from CSR. `sigma` must be 1 or a multiple of `c` (windows
    /// that split a slice would make the permutation ambiguous).
    pub fn from_csr(a: &CsrMatrix, c: usize, sigma: usize) -> Result<Self> {
        validate_params(c, sigma)?;
        let perm = sorted_row_order(a, c, sigma);
        let n_slices = perm.len() / c;
        let slot_nnz: Vec<usize> = perm
            .iter()
            .map(|&r| if r == usize::MAX { 0 } else { a.row_nnz(r) })
            .collect();
        let mut slice_ptr = Vec::with_capacity(n_slices + 1);
        let mut slice_width = Vec::with_capacity(n_slices);
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        slice_ptr.push(0);
        for s in 0..n_slices {
            let slots = s * c..(s + 1) * c;
            let width = slot_nnz[slots.clone()].iter().copied().max().unwrap_or(0);
            for k in 0..width {
                for slot in slots.clone() {
                    if k < slot_nnz[slot] {
                        let row = perm[slot];
                        let (cols, rvals) = a.row(row);
                        col_idx.push(cols[k]);
                        vals.push(rvals[k]);
                    } else {
                        col_idx.push(0);
                        vals.push(0.0);
                    }
                }
            }
            slice_width.push(width);
            slice_ptr.push(col_idx.len());
        }
        Ok(Self {
            n_rows: a.n_rows,
            n_cols: a.n_cols,
            c,
            sigma,
            slice_ptr,
            slice_width,
            col_idx,
            vals,
            perm,
            slot_nnz,
        })
    }

    pub fn n_slices(&self) -> usize {
        self.slice_width.len()
    }

    /// True nonzeros (excluding padding).
    pub fn nnz(&self) -> usize {
        self.slot_nnz.iter().sum()
    }

    /// Stored entries including padding.
    pub fn padded_nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn stats(&self) -> SellStats {
        SellStats {
            nnz: self.nnz(),
            padded_nnz: self.padded_nnz(),
            n_slices: self.n_slices(),
            max_width: self.slice_width.iter().copied().max().unwrap_or(0),
        }
    }

    /// The k-th stored entry (col, val) of the row in `slot`, or None past
    /// that row's true length.
    pub fn slot_entry(&self, slot: usize, k: usize) -> Option<(u32, f32)> {
        if k >= self.slot_nnz[slot] {
            return None;
        }
        let s = slot / self.c;
        let r = slot % self.c;
        let at = self.slice_ptr[s] + k * self.c + r;
        Some((self.col_idx[at], self.vals[at]))
    }

    /// Invert the conversion: original row order, per-row entry order, and
    /// every (row, col, val) — including explicitly stored zeros — are
    /// restored exactly; padding is dropped.
    pub fn to_csr(&self) -> Result<CsrMatrix> {
        let mut per_row: Vec<Vec<(u32, f32)>> = vec![Vec::new(); self.n_rows];
        for (slot, &row) in self.perm.iter().enumerate() {
            if row == usize::MAX {
                continue;
            }
            for k in 0..self.slot_nnz[slot] {
                let (c, v) = self.slot_entry(slot, k).unwrap();
                per_row[row].push((c, v));
            }
        }
        let mut row_ptr = vec![0usize];
        let mut col_idx = Vec::new();
        let mut vals = Vec::new();
        for row in &per_row {
            for &(c, v) in row {
                col_idx.push(c);
                vals.push(v);
            }
            row_ptr.push(col_idx.len());
        }
        CsrMatrix::new(self.n_rows, self.n_cols, row_ptr, col_idx, vals)
    }

    /// y = A x in f64 over the padded storage (padding contributes 0).
    pub fn apply_f64(&self, x: &[f32]) -> Vec<f64> {
        assert_eq!(x.len(), self.n_cols, "SpMV operand length mismatch");
        let mut y = vec![0.0f64; self.n_rows];
        for (slot, &row) in self.perm.iter().enumerate() {
            if row == usize::MAX {
                continue;
            }
            let mut acc = 0.0f64;
            for k in 0..self.slot_nnz[slot] {
                let (c, v) = self.slot_entry(slot, k).unwrap();
                acc += v as f64 * x[c as usize] as f64;
            }
            y[row] = acc;
        }
        y
    }

    /// Bytes of stored values at `df` (padding included — it is moved and
    /// multiplied like any other entry).
    pub fn value_bytes(&self, df: DataFormat) -> u64 {
        (self.padded_nnz() * df.bytes()) as u64
    }

    /// Bytes of stored 32-bit column indices.
    pub fn index_bytes(&self) -> u64 {
        (self.padded_nnz() * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_csr(seed: u64, n_rows: usize, n_cols: usize, max_row: usize) -> CsrMatrix {
        let mut rng = Rng::new(seed);
        let mut t = Vec::new();
        for r in 0..n_rows {
            let k = rng.below(max_row as u64 + 1) as usize;
            for _ in 0..k {
                t.push((r, rng.below(n_cols as u64) as usize, rng.next_f32() * 2.0 - 1.0));
            }
        }
        CsrMatrix::from_triplets(n_rows, n_cols, &t).unwrap()
    }

    #[test]
    fn uniform_rows_have_no_padding() {
        // 64 rows, exactly 3 nnz each → occupancy 1.0 regardless of σ.
        let t: Vec<_> = (0..64)
            .flat_map(|r| (0..3).map(move |k| (r, (r + k) % 64, 1.0 + k as f32)))
            .collect();
        let a = CsrMatrix::from_triplets(64, 64, &t).unwrap();
        for sigma in [1, 32, 64] {
            let s = SellMatrix::from_csr(&a, SELL_SLICE_HEIGHT, sigma).unwrap();
            assert_eq!(s.n_slices(), 2);
            assert_eq!(s.padded_nnz(), 64 * 3);
            assert_eq!(s.stats().occupancy(), 1.0);
            assert_eq!(s.stats().overhead(), 1.0);
        }
    }

    #[test]
    fn column_major_slice_layout() {
        // Rows 0..32 with 2 nnz, one wide row: entry (k, r) at ptr + k*C + r.
        let mut t = Vec::new();
        for r in 0..32 {
            t.push((r, r, 10.0 + r as f32));
            t.push((r, (r + 1) % 32, -1.0));
        }
        let a = CsrMatrix::from_triplets(32, 32, &t).unwrap();
        let s = SellMatrix::from_csr(&a, 32, 1).unwrap();
        assert_eq!(s.n_slices(), 1);
        assert_eq!(s.slice_width, vec![2]);
        // k = 0 column holds every row's first entry (the diagonal).
        for r in 0..32 {
            assert_eq!(s.col_idx[r], r as u32);
            assert_eq!(s.vals[r], 10.0 + r as f32);
            assert_eq!(s.vals[32 + r], -1.0);
        }
    }

    #[test]
    fn sigma_sorting_reduces_padding() {
        // One long row per 32: unsorted, every slice pads to the long row;
        // sorted with σ = n, the long rows share a slice.
        let mut t = Vec::new();
        for r in 0..128usize {
            let k = if r % 32 == 0 { 16 } else { 2 };
            for j in 0..k {
                t.push((r, (r + j) % 128, 1.0));
            }
        }
        let a = CsrMatrix::from_triplets(128, 128, &t).unwrap();
        let unsorted = SellMatrix::from_csr(&a, 32, 1).unwrap();
        let sorted = SellMatrix::from_csr(&a, 32, 128).unwrap();
        assert!(sorted.padded_nnz() < unsorted.padded_nnz());
        assert_eq!(sorted.nnz(), unsorted.nnz());
        // Both round-trip to the same matrix.
        assert_eq!(sorted.to_csr().unwrap(), a);
        assert_eq!(unsorted.to_csr().unwrap(), a);
    }

    #[test]
    fn roundtrip_random_including_ragged_tail() {
        for seed in 0..5 {
            // 50 rows: final slice has 18 virtual rows.
            let a = random_csr(seed, 50, 40, 9);
            for sigma in [1, 32, 64] {
                let s = SellMatrix::from_csr(&a, 32, sigma).unwrap();
                assert_eq!(s.to_csr().unwrap(), a, "seed {seed} σ {sigma}");
                assert_eq!(s.nnz(), a.nnz());
                assert_eq!(s.padded_nnz(), padded_nnz_formula(&a, 32, sigma).unwrap());
            }
        }
    }

    #[test]
    fn apply_matches_csr_oracle() {
        let a = random_csr(7, 70, 70, 6);
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..70).map(|_| rng.next_f32() - 0.5).collect();
        let want = a.apply_f64(&x);
        for sigma in [1, 64] {
            let s = SellMatrix::from_csr(&a, 32, sigma).unwrap();
            let got = s.apply_f64(&x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn bad_sigma_rejected() {
        let a = random_csr(1, 10, 10, 3);
        assert!(SellMatrix::from_csr(&a, 32, 48).is_err());
        assert!(SellMatrix::from_csr(&a, 0, 1).is_err());
        // The formula rejects exactly the same parameter pairs.
        assert!(padded_nnz_formula(&a, 32, 48).is_err());
        assert!(padded_nnz_formula(&a, 0, 1).is_err());
    }

    #[test]
    fn storage_byte_accounting() {
        let a = random_csr(2, 64, 64, 5);
        let s = SellMatrix::from_csr(&a, 32, 1).unwrap();
        let p = s.padded_nnz() as u64;
        assert_eq!(s.value_bytes(DataFormat::Fp32), 4 * p);
        assert_eq!(s.value_bytes(DataFormat::Bf16), 2 * p);
        assert_eq!(s.index_bytes(), 4 * p);
    }
}
