//! General sparse matrices in tile-native formats.
//!
//! The paper's solver applies exactly one matrix — the hard-coded 7-point
//! stencil. This subsystem generalizes that: it represents arbitrary
//! sparse (SPD, for PCG) matrices host-side, partitions them over the
//! simulated Tensix grid, and hands the device-facing pieces to
//! [`crate::kernels::spmv`], which executes SpMV with engine-produced
//! values and cost-model/NoC-simulated timing. The pipeline is
//!
//! ```text
//! MatrixMarket / generator → CsrMatrix → RowPartition → per-core
//!     SellMatrix → kernels::spmv::SpmvOperator → solver::pcg::Operator
//! ```
//!
//! # Why SELL-C-σ with slice height 32
//!
//! The device format is SELL-C-σ ([`sell`]) — the format the paper's
//! cuSPARSE GPU baseline uses (§7.3, "state-of-the-art ... for matrices
//! with limited row-length variability") — with **C = 32** locked to the
//! tile geometry: tiles are 1024 elements with 16×16 faces (§3.1), so one
//! 32-row slice column is exactly two faces, and 32 FP32 values are one
//! 128 B unpack beat. A slice column therefore lands on whole faces of
//! the operand tiles the FPU/SFPU consume, and the per-slice padding ELL
//! would spend on the whole matrix is confined to 32-row groups. σ
//! (length-sorting window) stays a knob; σ = 1 preserves row order, which
//! the stencil-aligned layout requires.
//!
//! Formats and roles:
//!
//! - [`csr`] — host assembly/interchange format + f64 oracle.
//! - [`sell`] — device storage format, padding/occupancy accounting.
//! - [`mtx`] — Matrix Market I/O and generators (3D Laplacian in
//!   stencil-canonical order, uniform-row random SPD circulant, SPD band).
//! - [`partition`] — row-block and stencil-aligned distribution, per-core
//!   SRAM footprint checks, NoC gather planning from the column footprint.

pub mod csr;
pub mod mtx;
pub mod partition;
pub mod sell;

pub use csr::CsrMatrix;
pub use mtx::{banded, circulant_spd, laplacian_3d, parse_mtx, read_mtx, write_mtx};
pub use partition::{DieCutPlan, GatherPlan, RowPartition, VectorLayout};
pub use sell::{padded_nnz_formula, SellMatrix, SellStats, SELL_SLICE_HEIGHT};
