//! The Tensix compute grid (§3): a sub-grid of the 10×12 die selected for a
//! run (up to the 8×7 maximum the paper uses), with cardinal-neighbor
//! queries for the stencil halo exchange and coordinate bookkeeping for the
//! NoC.

use crate::arch::constants::MAX_SUBGRID;
use crate::device::core::{Coord, TensixCore};
use crate::error::{Result, SimError};
use crate::tile::ShiftDir;

/// A rectangular sub-grid of Tensix cores.
#[derive(Debug)]
pub struct TensixGrid {
    pub rows: usize,
    pub cols: usize,
    pub cores: Vec<TensixCore>,
}

impl TensixGrid {
    /// Create an `rows × cols` compute sub-grid (§7.2: ≤ 8×7).
    pub fn new(rows: usize, cols: usize) -> Result<Self> {
        if rows == 0 || cols == 0 {
            return Err(SimError::BadProblem {
                what: format!("empty grid {rows}x{cols}"),
            });
        }
        if rows > MAX_SUBGRID.0 || cols > MAX_SUBGRID.1 {
            return Err(SimError::SubgridTooLarge {
                rows,
                cols,
                max_rows: MAX_SUBGRID.0,
                max_cols: MAX_SUBGRID.1,
            });
        }
        let mut cores = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                cores.push(TensixCore::new(Coord::new(r, c)));
            }
        }
        Ok(Self { rows, cols, cores })
    }

    pub fn n_cores(&self) -> usize {
        self.rows * self.cols
    }

    pub fn index(&self, coord: Coord) -> Result<usize> {
        if coord.row >= self.rows || coord.col >= self.cols {
            return Err(SimError::BadCoord {
                row: coord.row,
                col: coord.col,
                rows: self.rows,
                cols: self.cols,
            });
        }
        Ok(coord.row * self.cols + coord.col)
    }

    pub fn core(&self, coord: Coord) -> Result<&TensixCore> {
        Ok(&self.cores[self.index(coord)?])
    }

    pub fn core_mut(&mut self, coord: Coord) -> Result<&mut TensixCore> {
        let i = self.index(coord)?;
        Ok(&mut self.cores[i])
    }

    pub fn coords(&self) -> impl Iterator<Item = Coord> + '_ {
        (0..self.rows).flat_map(move |r| (0..self.cols).map(move |c| Coord::new(r, c)))
    }

    /// Cardinal neighbor of `coord` in the *domain* sense used by the
    /// stencil (§6.1): None at the sub-grid boundary (zero-fill there).
    ///
    /// Direction convention matches [`ShiftDir`]: the North *component*
    /// tile needs data from the row-above neighbor, etc. Grid row 0 is the
    /// top.
    pub fn neighbor(&self, coord: Coord, dir: ShiftDir) -> Option<Coord> {
        let (r, c) = (coord.row as isize, coord.col as isize);
        let (nr, nc) = match dir {
            ShiftDir::North => (r - 1, c),
            ShiftDir::South => (r + 1, c),
            ShiftDir::West => (r, c - 1),
            ShiftDir::East => (r, c + 1),
        };
        if nr < 0 || nc < 0 || nr >= self.rows as isize || nc >= self.cols as isize {
            None
        } else {
            Some(Coord::new(nr as usize, nc as usize))
        }
    }

    /// The core nearest the grid center — the root for the "center" NoC
    /// reduction pattern (§5.2).
    pub fn center(&self) -> Coord {
        Coord::new(self.rows / 2, self.cols / 2)
    }

    /// Top-left core — the root for the "naive" pattern (§5.2).
    pub fn top_left(&self) -> Coord {
        Coord::new(0, 0)
    }

    pub fn reset_all(&mut self) {
        for c in &mut self.cores {
            c.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_limits() {
        let g = TensixGrid::new(8, 7).unwrap();
        assert_eq!(g.n_cores(), 56);
        assert!(matches!(
            TensixGrid::new(9, 7),
            Err(SimError::SubgridTooLarge { .. })
        ));
        assert!(matches!(
            TensixGrid::new(8, 8),
            Err(SimError::SubgridTooLarge { .. })
        ));
        assert!(TensixGrid::new(0, 3).is_err());
        assert!(TensixGrid::new(1, 1).is_ok());
    }

    #[test]
    fn indexing_roundtrip() {
        let g = TensixGrid::new(4, 4).unwrap();
        let mut seen = std::collections::HashSet::new();
        for coord in g.coords() {
            let i = g.index(coord).unwrap();
            assert!(seen.insert(i));
            assert_eq!(g.core(coord).unwrap().coord, coord);
        }
        assert_eq!(seen.len(), 16);
        assert!(g.index(Coord::new(4, 0)).is_err());
    }

    #[test]
    fn neighbors_and_boundaries() {
        let g = TensixGrid::new(3, 3).unwrap();
        let mid = Coord::new(1, 1);
        assert_eq!(g.neighbor(mid, ShiftDir::North), Some(Coord::new(0, 1)));
        assert_eq!(g.neighbor(mid, ShiftDir::South), Some(Coord::new(2, 1)));
        assert_eq!(g.neighbor(mid, ShiftDir::West), Some(Coord::new(1, 0)));
        assert_eq!(g.neighbor(mid, ShiftDir::East), Some(Coord::new(1, 2)));
        // Domain edges: zero-fill side has no neighbor.
        assert_eq!(g.neighbor(Coord::new(0, 0), ShiftDir::North), None);
        assert_eq!(g.neighbor(Coord::new(0, 0), ShiftDir::West), None);
        assert_eq!(g.neighbor(Coord::new(2, 2), ShiftDir::South), None);
        assert_eq!(g.neighbor(Coord::new(2, 2), ShiftDir::East), None);
    }

    #[test]
    fn roots() {
        let g = TensixGrid::new(8, 7).unwrap();
        assert_eq!(g.top_left(), Coord::new(0, 0));
        assert_eq!(g.center(), Coord::new(4, 3));
        let g1 = TensixGrid::new(1, 1).unwrap();
        assert_eq!(g1.center(), Coord::new(0, 0));
    }
}
