//! The multi-die device mesh (§8 multi-device scaling).
//!
//! A Wormhole system is a set of Tensix dies joined by Ethernet: one die
//! on an n150, two on the n300 (on-board links), thirty-two in a Galaxy
//! (backplane links). This module is the device-layer model of that
//! fabric: [`EthLink`] (the typed link and its transfer cost — formerly a
//! solver-private detail of `solver::dualdie`), [`MeshTopology`]
//! (line/ring), [`DeviceMesh`] — N identical die sub-grids stacked
//! along x, with link-path lookup and per-die SRAM/DRAM budget checks —
//! and [`EthSim`], the per-link occupancy tracker (the inter-die
//! counterpart of [`crate::noc::NocSim`]) through which the scheduler
//! times every Ethernet hop, so concurrent transfers sharing a physical
//! link serialize instead of riding independent pipes.
//!
//! The mesh is pure topology + cost: *what* moves over which link per
//! solver component is decided by the lowerings (they attach
//! [`crate::ttm::EtherPhase`]s to programs), and *when* it is charged by
//! the one scheduler in [`crate::ttm::exec::execute_program`].

use std::collections::BTreeMap;

use crate::arch::constants::N300D_DRAM_BYTES;
use crate::arch::specs::{EthLinkSpec, ETH_BACKPLANE, ETH_ONBOARD, GALAXY_DIES};
use crate::arch::DataFormat;
use crate::device::TensixGrid;
use crate::error::{Result, SimError};
use crate::timing::SimNs;

/// A die-to-die Ethernet link (§3: the die grid dedicates cells to
/// Ethernet management; §8 names multi-device scaling as future work).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EthLink {
    /// One-way message latency, ns (Ethernet MAC + SerDes; orders of
    /// magnitude above a NoC hop).
    pub latency_ns: f64,
    /// Usable bandwidth, GB/s (2×100 GbE per die pair ≈ 25 GB/s raw; we
    /// default to one link's usable rate).
    pub bw_gbs: f64,
}

impl Default for EthLink {
    fn default() -> Self {
        Self::onboard()
    }
}

impl EthLink {
    pub fn from_spec(spec: EthLinkSpec) -> Self {
        Self {
            latency_ns: spec.latency_ns,
            bw_gbs: spec.bw_gbs,
        }
    }

    /// The n300 on-board die-to-die link (the dual-die solver's default).
    pub fn onboard() -> Self {
        Self::from_spec(ETH_ONBOARD)
    }

    /// The Galaxy backplane link (longer traces, retimers).
    pub fn backplane() -> Self {
        Self::from_spec(ETH_BACKPLANE)
    }

    /// The link class a system of `n_dies` uses: on-board up to the n300
    /// pair, backplane beyond — the one place the scale→link mapping
    /// lives (drivers must not restate it).
    pub fn for_dies(n_dies: usize) -> Self {
        if n_dies > 2 {
            Self::backplane()
        } else {
            Self::onboard()
        }
    }

    /// Transfer time for `bytes` over the link.
    pub fn transfer_ns(&self, bytes: u64) -> f64 {
        self.latency_ns + bytes as f64 / self.bw_gbs
    }
}

/// One completed transfer over a physical Ethernet link, as recorded by
/// [`EthSim`] (absolute simulated times; feeds the per-link profiler
/// zones).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EthTransfer {
    /// Undirected physical link, as a (lower, higher) die pair.
    pub link: (usize, usize),
    pub start: SimNs,
    pub end: SimNs,
    pub bytes: u64,
}

/// Per-link Ethernet occupancy tracker — the inter-die counterpart of
/// [`crate::noc::NocSim`]. Each physical link is a shared wire, not an
/// independent pipe: a transfer holds its link from the moment it begins
/// until the last byte is out, and a concurrent transfer wanting the same
/// link queues behind it, paying its own full latency + bandwidth term
/// once the wire frees. Transfers on distinct links never interact.
///
/// The scheduler drives one `EthSim` per program execution
/// ([`crate::ttm::EtherPhase::run`]); the recorded busy windows surface
/// as per-link utilization in `ProgramOutcome` and as profiler zones.
#[derive(Debug, Default)]
pub struct EthSim {
    link_free: BTreeMap<(usize, usize), SimNs>,
    busy_ns: BTreeMap<(usize, usize), SimNs>,
    pub transfers: Vec<EthTransfer>,
    pub messages: u64,
    pub bytes: u64,
}

impl EthSim {
    pub fn new() -> Self {
        Self::default()
    }

    /// Move `bytes` from `src_die` to `dst_die` over their (undirected)
    /// physical link, with the payload ready at `start`. The transfer
    /// begins when both the payload and the link are ready and occupies
    /// the link for the full `EthLink::transfer_ns` window — two
    /// concurrent hops on one link serialize. Returns the completion
    /// time.
    pub fn transfer(
        &mut self,
        link: &EthLink,
        src_die: usize,
        dst_die: usize,
        bytes: u64,
        start: SimNs,
    ) -> SimNs {
        let key = (src_die.min(dst_die), src_die.max(dst_die));
        let free = self.link_free.get(&key).copied().unwrap_or(0.0);
        let begin = start.max(free);
        let end = begin + link.transfer_ns(bytes);
        self.link_free.insert(key, end);
        *self.busy_ns.entry(key).or_insert(0.0) += end - begin;
        self.transfers.push(EthTransfer {
            link: key,
            start: begin,
            end,
            bytes,
        });
        self.messages += 1;
        self.bytes += bytes;
        end
    }

    /// Per-link busy fraction of a window of `span_ns` (sorted by link;
    /// `span_ns <= 0` yields an empty report). A link at 1.0 was the
    /// serialized bottleneck for the whole window.
    pub fn utilization(&self, span_ns: SimNs) -> Vec<(usize, usize, f64)> {
        if span_ns <= 0.0 {
            return Vec::new();
        }
        self.busy_ns
            .iter()
            .map(|(&(a, b), &busy)| (a, b, busy / span_ns))
            .collect()
    }

    /// Per-link busy nanoseconds, sorted by link.
    pub fn per_link_busy(&self) -> Vec<((usize, usize), SimNs)> {
        self.busy_ns.iter().map(|(&l, &b)| (l, b)).collect()
    }

    /// Re-record transfers that already ran on another tracker, shifted by
    /// `offset` into this tracker's timeline. This is how
    /// [`crate::solver::solve_pcg_mesh`] carries ONE link-occupancy tracker
    /// across all component programs of a solve: each component was timed
    /// in isolation (its own window), and its transfers are replayed here
    /// at their solve-absolute times. Replaying never re-times anything —
    /// component windows are disjoint in solve time, so each transfer must
    /// start at or after its link's free time (debug-asserted), and the
    /// recorded begin/end are preserved exactly.
    pub fn replay(&mut self, transfers: &[EthTransfer], offset: SimNs) {
        for t in transfers {
            let begin = t.start + offset;
            let end = t.end + offset;
            let free = self.link_free.get(&t.link).copied().unwrap_or(0.0);
            debug_assert!(
                begin + 1e-6 >= free,
                "replayed transfer on link {:?} begins at {begin} before the link frees at {free}",
                t.link
            );
            self.link_free.insert(t.link, end);
            *self.busy_ns.entry(t.link).or_insert(0.0) += end - begin;
            self.transfers.push(EthTransfer {
                link: t.link,
                start: begin,
                end,
                bytes: t.bytes,
            });
            self.messages += 1;
            self.bytes += t.bytes;
        }
    }
}

/// How the dies are wired together.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeshTopology {
    /// A chain: die d links to d±1 (n300 = a 2-die line).
    Line,
    /// A chain closed into a ring (Galaxy-style): die N−1 links back to
    /// die 0, halving worst-case path lengths.
    Ring,
}

impl MeshTopology {
    pub fn label(self) -> &'static str {
        match self {
            MeshTopology::Line => "line",
            MeshTopology::Ring => "ring",
        }
    }
}

impl std::str::FromStr for MeshTopology {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "line" | "chain" => Ok(MeshTopology::Line),
            "ring" => Ok(MeshTopology::Ring),
            _ => Err(format!("unknown mesh topology '{s}' (expected line|ring)")),
        }
    }
}

/// N identical Tensix die sub-grids joined by Ethernet links. Dies stack
/// the domain along x (die d owns logical core rows
/// `[d·die_rows, (d+1)·die_rows)`), generalizing the n300 dual-die
/// decomposition to arbitrary N.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceMesh {
    pub n_dies: usize,
    /// Per-die compute sub-grid shape (§7.2: ≤ 8×7).
    pub die_rows: usize,
    pub die_cols: usize,
    pub topology: MeshTopology,
    /// Uniform link model (per-topology preset from `arch::specs`).
    pub link: EthLink,
}

impl DeviceMesh {
    pub fn new(
        n_dies: usize,
        die_rows: usize,
        die_cols: usize,
        topology: MeshTopology,
        link: EthLink,
    ) -> Result<Self> {
        if n_dies == 0 {
            return Err(SimError::BadProblem {
                what: "mesh needs at least one die".to_string(),
            });
        }
        if n_dies > GALAXY_DIES {
            return Err(SimError::BadProblem {
                what: format!("{n_dies} dies exceeds the {GALAXY_DIES}-die Galaxy ceiling"),
            });
        }
        // Per-die sub-grid obeys the single-die rules (§7.2 ≤ 8×7).
        let _ = TensixGrid::new(die_rows, die_cols)?;
        Ok(Self {
            n_dies,
            die_rows,
            die_cols,
            topology,
            link,
        })
    }

    /// One die, no links — the n150.
    pub fn n150(die_rows: usize, die_cols: usize) -> Result<Self> {
        Self::new(1, die_rows, die_cols, MeshTopology::Line, EthLink::onboard())
    }

    /// Two dies over the on-board link — the n300.
    pub fn n300(die_rows: usize, die_cols: usize) -> Result<Self> {
        Self::new(2, die_rows, die_cols, MeshTopology::Line, EthLink::onboard())
    }

    /// Thirty-two dies on a backplane ring — the Galaxy.
    pub fn galaxy(die_rows: usize, die_cols: usize) -> Result<Self> {
        Self::new(
            GALAXY_DIES,
            die_rows,
            die_cols,
            MeshTopology::Ring,
            EthLink::backplane(),
        )
    }

    pub fn cores_per_die(&self) -> usize {
        self.die_rows * self.die_cols
    }

    pub fn n_cores(&self) -> usize {
        self.n_dies * self.cores_per_die()
    }

    /// Logical core-grid rows across the whole mesh (x-stacked dies).
    pub fn logical_rows(&self) -> usize {
        self.n_dies * self.die_rows
    }

    /// The per-die compute sub-grid (identical for every die).
    pub fn die_grid(&self) -> Result<TensixGrid> {
        TensixGrid::new(self.die_rows, self.die_cols)
    }

    /// Die owning a logical (mesh-wide, row-major) core index.
    pub fn die_of_core(&self, core: usize) -> usize {
        (core / self.die_cols) / self.die_rows
    }

    /// The undirected links of the topology, as (lower, higher) die pairs.
    pub fn links(&self) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = (0..self.n_dies.saturating_sub(1)).map(|d| (d, d + 1)).collect();
        if self.topology == MeshTopology::Ring && self.n_dies > 2 {
            out.push((0, self.n_dies - 1));
        }
        out
    }

    pub fn are_linked(&self, a: usize, b: usize) -> bool {
        let (lo, hi) = (a.min(b), a.max(b));
        self.links().contains(&(lo, hi))
    }

    /// Link-path lookup: the undirected links a transfer from die `a` to
    /// die `b` traverses, in order. On a line this is the straight chain;
    /// on a ring, the shorter arc (ties go through the chain).
    pub fn path(&self, a: usize, b: usize) -> Vec<(usize, usize)> {
        assert!(a < self.n_dies && b < self.n_dies, "die index out of range");
        if a == b {
            return Vec::new();
        }
        let (lo, hi) = (a.min(b), a.max(b));
        let inner = hi - lo;
        let outer = self.n_dies - inner;
        let use_wrap = self.topology == MeshTopology::Ring && self.n_dies > 2 && outer < inner;
        if use_wrap {
            // lo → 0 → wrap link → N−1 → hi.
            let mut hops: Vec<(usize, usize)> = (0..lo).rev().map(|d| (d, d + 1)).collect();
            hops.push((0, self.n_dies - 1));
            hops.extend((hi..self.n_dies - 1).map(|d| (d, d + 1)));
            hops
        } else {
            (lo..hi).map(|d| (d, d + 1)).collect()
        }
    }

    /// Number of links on the `a`→`b` path.
    pub fn path_len(&self, a: usize, b: usize) -> usize {
        self.path(a, b).len()
    }

    /// Serial transfer time of `bytes` from die `a` to die `b` (each hop
    /// is a store-and-forward over one link).
    pub fn transfer_ns(&self, a: usize, b: usize, bytes: u64) -> f64 {
        self.path_len(a, b) as f64 * self.link.transfer_ns(bytes)
    }

    /// Per-die resource budgets for a PCG-shaped resident problem: the
    /// §7.2 SRAM ceiling (via the capacity model) and the per-die DRAM
    /// share of the vector working set. `vectors` is the number of
    /// resident whole-domain vectors (use the §7.2 counts).
    pub fn validate_budgets(&self, tiles_per_core: usize, df: DataFormat, fused: bool) -> Result<()> {
        let problem =
            crate::solver::problem::Problem::new(self.die_rows, self.die_cols, tiles_per_core, df);
        problem.validate_capacity(fused)?;
        // DRAM: each die backs its resident vectors (plus staging) out of
        // its own GDDR6 share — n300d ships 24 GB for two dies.
        let dram_per_die = N300D_DRAM_BYTES / 2;
        let vectors = if fused {
            crate::arch::constants::PCG_VECTORS_FUSED
        } else {
            crate::arch::constants::PCG_VECTORS_SPLIT
        };
        let per_die_bytes =
            (self.cores_per_die() * tiles_per_core * df.tile_bytes() * vectors) as u64;
        if per_die_bytes > dram_per_die {
            return Err(SimError::BadProblem {
                what: format!(
                    "per-die vector working set {per_die_bytes} B exceeds the {dram_per_die} B DRAM share"
                ),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_presets() {
        let m = DeviceMesh::n300(4, 4).unwrap();
        assert_eq!(m.n_dies, 2);
        assert_eq!(m.n_cores(), 32);
        assert_eq!(m.logical_rows(), 8);
        assert_eq!(m.link, EthLink::onboard());
        assert_eq!(m.links(), vec![(0, 1)]);

        let g = DeviceMesh::galaxy(8, 7).unwrap();
        assert_eq!(g.n_dies, 32);
        assert_eq!(g.topology, MeshTopology::Ring);
        assert_eq!(g.link, EthLink::backplane());
        assert_eq!(g.links().len(), 32); // chain + wrap

        assert!(DeviceMesh::new(0, 1, 1, MeshTopology::Line, EthLink::default()).is_err());
        assert!(DeviceMesh::new(33, 1, 1, MeshTopology::Line, EthLink::default()).is_err());
        // Per-die grid still obeys the §7.2 sub-grid ceiling.
        assert!(DeviceMesh::new(2, 9, 7, MeshTopology::Line, EthLink::default()).is_err());
    }

    #[test]
    fn link_transfer_cost_matches_dualdie_model() {
        // The moved EthLink keeps the dual-die solver's exact cost model.
        let l = EthLink::default();
        assert_eq!(l.latency_ns, 800.0);
        assert_eq!(l.bw_gbs, 11.0);
        assert_eq!(l.transfer_ns(0), 800.0);
        assert!((l.transfer_ns(1100) - 900.0).abs() < 1e-9);
        assert!(EthLink::backplane().latency_ns > EthLink::onboard().latency_ns);
        // The one scale→link-class mapping the drivers share.
        assert_eq!(EthLink::for_dies(1), EthLink::onboard());
        assert_eq!(EthLink::for_dies(2), EthLink::onboard());
        assert_eq!(EthLink::for_dies(4), EthLink::backplane());
    }

    #[test]
    fn path_lookup_line_vs_ring() {
        let line = DeviceMesh::new(8, 1, 1, MeshTopology::Line, EthLink::default()).unwrap();
        assert_eq!(line.path(2, 2), vec![]);
        assert_eq!(line.path(1, 4), vec![(1, 2), (2, 3), (3, 4)]);
        assert_eq!(line.path(4, 1), vec![(1, 2), (2, 3), (3, 4)]);
        assert_eq!(line.path_len(0, 7), 7);

        let ring = DeviceMesh::new(8, 1, 1, MeshTopology::Ring, EthLink::default()).unwrap();
        // 0 → 7 goes over the wrap link.
        assert_eq!(ring.path(0, 7), vec![(0, 7)]);
        assert_eq!(ring.path_len(1, 6), 3); // 1→0→7→6
        assert!(ring.path(1, 6).contains(&(0, 7)));
        // Shorter arcs keep the chain, and every pair is no longer than on
        // the line.
        assert_eq!(ring.path(1, 3), vec![(1, 2), (2, 3)]);
        for a in 0..8 {
            for b in 0..8 {
                assert!(ring.path_len(a, b) <= line.path_len(a, b));
            }
        }
    }

    #[test]
    fn die_of_core_follows_x_stacking() {
        let m = DeviceMesh::new(4, 2, 3, MeshTopology::Line, EthLink::default()).unwrap();
        assert_eq!(m.die_of_core(0), 0);
        assert_eq!(m.die_of_core(m.cores_per_die() - 1), 0);
        assert_eq!(m.die_of_core(m.cores_per_die()), 1);
        assert_eq!(m.die_of_core(m.n_cores() - 1), 3);
    }

    #[test]
    fn eth_sim_serializes_shared_link_and_reports_utilization() {
        let link = EthLink::default(); // 800 ns latency, 11 GB/s
        let mut sim = EthSim::new();
        // Two concurrent hops on the SAME physical link (0↔1, both
        // directions): the second queues behind the first — analytic
        // end time is exactly 2 × (latency + bytes/bw).
        let one = link.transfer_ns(1100); // 800 + 100 = 900 ns
        let a = sim.transfer(&link, 0, 1, 1100, 0.0);
        let b = sim.transfer(&link, 1, 0, 1100, 0.0);
        assert!((a - one).abs() < 1e-9);
        assert!((b - 2.0 * one).abs() < 1e-9, "serialized, not independent pipes");
        // A hop on a different link at the same time does not queue.
        let c = sim.transfer(&link, 1, 2, 1100, 0.0);
        assert!((c - one).abs() < 1e-9);
        assert_eq!(sim.messages, 3);
        assert_eq!(sim.bytes, 3 * 1100);
        // Utilization over the busy window: link (0,1) was occupied the
        // whole time, link (1,2) half of it.
        let util = sim.utilization(b);
        assert_eq!(util.len(), 2);
        assert!((util[0].2 - 1.0).abs() < 1e-9, "(0,1) saturated: {util:?}");
        assert!((util[1].2 - 0.5).abs() < 1e-9, "(1,2) half-busy: {util:?}");
        assert!(sim.utilization(0.0).is_empty());
        // The recorded transfers carry the queueing.
        assert_eq!(sim.transfers[1].start, a);
        assert_eq!(sim.transfers[1].link, (0, 1));
    }

    #[test]
    fn replay_carries_transfers_across_component_windows() {
        let link = EthLink::default();
        // Component A ran in its own window [0, ...].
        let mut a = EthSim::new();
        a.transfer(&link, 0, 1, 1100, 0.0);
        a.transfer(&link, 1, 0, 1100, 0.0);
        // Component B likewise timed in isolation.
        let mut b = EthSim::new();
        b.transfer(&link, 1, 2, 2200, 100.0);
        // Solve-level tracker: A's window starts at 10_000, B's after it.
        let mut solve = EthSim::new();
        solve.replay(&a.transfers, 10_000.0);
        solve.replay(&b.transfers, 50_000.0);
        assert_eq!(solve.messages, 3);
        assert_eq!(solve.bytes, 2 * 1100 + 2200);
        // Transfer times are the component times shifted, exactly.
        assert_eq!(solve.transfers[0].start, a.transfers[0].start + 10_000.0);
        assert_eq!(solve.transfers[1].end, a.transfers[1].end + 10_000.0);
        assert_eq!(solve.transfers[2].start, b.transfers[0].start + 50_000.0);
        // Per-link busy sums the windows.
        let busy = solve.per_link_busy();
        assert_eq!(busy.len(), 2);
        assert_eq!(busy[0].0, (0, 1));
        assert!((busy[0].1 - 2.0 * link.transfer_ns(1100)).abs() < 1e-9);
        assert!((busy[1].1 - link.transfer_ns(2200)).abs() < 1e-9);
    }

    #[test]
    fn budget_checks_per_die() {
        use crate::arch::DataFormat;
        let m = DeviceMesh::n300(1, 1).unwrap();
        assert!(m.validate_budgets(164, DataFormat::Bf16, true).is_ok());
        // §7.2 per-die SRAM ceiling is enforced through the mesh.
        assert!(m.validate_budgets(165, DataFormat::Bf16, true).is_err());
    }
}
