//! The multi-die device mesh (§8 multi-device scaling).
//!
//! A Wormhole system is a set of Tensix dies joined by Ethernet: one die
//! on an n150, two on the n300 (on-board links), thirty-two in a Galaxy
//! (backplane links). This module is the device-layer model of that
//! fabric: [`EthLink`] (the typed link and its transfer cost — formerly a
//! solver-private detail of `solver::dualdie`), [`MeshTopology`]
//! (line/ring/2D torus), [`DeviceMesh`] — N identical die sub-grids
//! tiled over a rectangular die grid (a 1D topology is the Rx1 column),
//! with link-path lookup and per-die SRAM/DRAM budget checks —
//! and [`EthSim`], the per-link occupancy tracker (the inter-die
//! counterpart of [`crate::noc::NocSim`]) through which the scheduler
//! times every Ethernet hop, so concurrent transfers sharing a physical
//! link serialize instead of riding independent pipes.
//!
//! The mesh is pure topology + cost: *what* moves over which link per
//! solver component is decided by the lowerings (they attach
//! [`crate::ttm::EtherPhase`]s to programs), and *when* it is charged by
//! the one scheduler in [`crate::ttm::exec::execute_program`].

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::arch::constants::N300D_DRAM_BYTES;
use crate::arch::specs::{EthLinkSpec, ETH_BACKPLANE, ETH_ONBOARD, GALAXY_DIES};
use crate::arch::DataFormat;
use crate::device::TensixGrid;
use crate::error::{Result, SimError};
use crate::timing::SimNs;

/// A die-to-die Ethernet link (§3: the die grid dedicates cells to
/// Ethernet management; §8 names multi-device scaling as future work).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EthLink {
    /// One-way message latency, ns (Ethernet MAC + SerDes; orders of
    /// magnitude above a NoC hop).
    pub latency_ns: f64,
    /// Usable bandwidth, GB/s (2×100 GbE per die pair ≈ 25 GB/s raw; we
    /// default to one link's usable rate).
    pub bw_gbs: f64,
}

impl Default for EthLink {
    fn default() -> Self {
        Self::onboard()
    }
}

impl EthLink {
    pub fn from_spec(spec: EthLinkSpec) -> Self {
        Self {
            latency_ns: spec.latency_ns,
            bw_gbs: spec.bw_gbs,
        }
    }

    /// The n300 on-board die-to-die link (the dual-die solver's default).
    pub fn onboard() -> Self {
        Self::from_spec(ETH_ONBOARD)
    }

    /// The Galaxy backplane link (longer traces, retimers).
    pub fn backplane() -> Self {
        Self::from_spec(ETH_BACKPLANE)
    }

    /// The link class a system of `n_dies` uses: on-board up to the n300
    /// pair, backplane beyond — the one place the scale→link mapping
    /// lives (drivers must not restate it).
    pub fn for_dies(n_dies: usize) -> Self {
        if n_dies > 2 {
            Self::backplane()
        } else {
            Self::onboard()
        }
    }

    /// Transfer time for `bytes` over the link.
    pub fn transfer_ns(&self, bytes: u64) -> f64 {
        self.latency_ns + bytes as f64 / self.bw_gbs
    }
}

/// One completed transfer over a physical Ethernet link, as recorded by
/// [`EthSim`] (absolute simulated times; feeds the per-link profiler
/// zones).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EthTransfer {
    /// Undirected physical link, as a (lower, higher) die pair.
    pub link: (usize, usize),
    pub start: SimNs,
    pub end: SimNs,
    pub bytes: u64,
}

/// Per-link Ethernet occupancy tracker — the inter-die counterpart of
/// [`crate::noc::NocSim`]. Each physical link is a shared wire, not an
/// independent pipe: a transfer holds its link from the moment it begins
/// until the last byte is out, and a concurrent transfer wanting the same
/// link queues behind it, paying its own full latency + bandwidth term
/// once the wire frees. Transfers on distinct links never interact.
///
/// The scheduler drives one `EthSim` per program execution
/// ([`crate::ttm::EtherPhase::run`]); the recorded busy windows surface
/// as per-link utilization in `ProgramOutcome` and as profiler zones.
#[derive(Debug, Default)]
pub struct EthSim {
    link_free: BTreeMap<(usize, usize), SimNs>,
    busy_ns: BTreeMap<(usize, usize), SimNs>,
    pub transfers: Vec<EthTransfer>,
    pub messages: u64,
    pub bytes: u64,
    /// Per-link service-time multipliers (≥ 1.0) for degraded links — a
    /// transfer over a degraded link holds the wire `factor` times
    /// longer. Empty (the default) leaves every transfer bit-identical
    /// to the undegraded model; the fault layer populates it from the
    /// [`crate::device::FaultPlan`] window active at the component's
    /// execution epoch.
    link_slowdown: BTreeMap<(usize, usize), f64>,
}

impl EthSim {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install per-link degradation factors (pairs normalized to
    /// (lower, higher)). Replaces any previous map.
    pub fn set_slowdown(&mut self, factors: &[((usize, usize), f64)]) {
        self.link_slowdown = factors
            .iter()
            .map(|&((a, b), f)| ((a.min(b), a.max(b)), f))
            .collect();
    }

    /// Move `bytes` from `src_die` to `dst_die` over their (undirected)
    /// physical link, with the payload ready at `start`. The transfer
    /// begins when both the payload and the link are ready and occupies
    /// the link for the full `EthLink::transfer_ns` window — two
    /// concurrent hops on one link serialize. Returns the completion
    /// time.
    pub fn transfer(
        &mut self,
        link: &EthLink,
        src_die: usize,
        dst_die: usize,
        bytes: u64,
        start: SimNs,
    ) -> SimNs {
        let key = (src_die.min(dst_die), src_die.max(dst_die));
        let free = self.link_free.get(&key).copied().unwrap_or(0.0);
        let begin = start.max(free);
        let mut service = link.transfer_ns(bytes);
        if let Some(&factor) = self.link_slowdown.get(&key) {
            service *= factor;
        }
        let end = begin + service;
        self.link_free.insert(key, end);
        *self.busy_ns.entry(key).or_insert(0.0) += end - begin;
        self.transfers.push(EthTransfer {
            link: key,
            start: begin,
            end,
            bytes,
        });
        self.messages += 1;
        self.bytes += bytes;
        end
    }

    /// Per-link busy fraction of a window of `span_ns` (sorted by link;
    /// `span_ns <= 0` yields an empty report). A link at 1.0 was the
    /// serialized bottleneck for the whole window.
    pub fn utilization(&self, span_ns: SimNs) -> Vec<(usize, usize, f64)> {
        if span_ns <= 0.0 {
            return Vec::new();
        }
        self.busy_ns
            .iter()
            .map(|(&(a, b), &busy)| (a, b, busy / span_ns))
            .collect()
    }

    /// Per-link busy nanoseconds, sorted by link.
    pub fn per_link_busy(&self) -> Vec<((usize, usize), SimNs)> {
        self.busy_ns.iter().map(|(&l, &b)| (l, b)).collect()
    }

    /// Re-record transfers that already ran on another tracker, shifted by
    /// `offset` into this tracker's timeline. This is how
    /// [`crate::solver::solve_pcg_mesh`] carries ONE link-occupancy tracker
    /// across all component programs of a solve: each component was timed
    /// in isolation (its own window), and its transfers are replayed here
    /// at their solve-absolute times. Replaying never re-times anything —
    /// component windows are disjoint in solve time, so each transfer must
    /// start at or after its link's free time (debug-asserted), and the
    /// recorded begin/end are preserved exactly.
    pub fn replay(&mut self, transfers: &[EthTransfer], offset: SimNs) {
        for t in transfers {
            let begin = t.start + offset;
            let end = t.end + offset;
            let free = self.link_free.get(&t.link).copied().unwrap_or(0.0);
            debug_assert!(
                begin + 1e-6 >= free,
                "replayed transfer on link {:?} begins at {begin} before the link frees at {free}",
                t.link
            );
            self.link_free.insert(t.link, end);
            *self.busy_ns.entry(t.link).or_insert(0.0) += end - begin;
            self.transfers.push(EthTransfer {
                link: t.link,
                start: begin,
                end,
                bytes: t.bytes,
            });
            self.messages += 1;
            self.bytes += t.bytes;
        }
    }
}

/// How the dies are wired together.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeshTopology {
    /// A chain: die d links to d±1 (n300 = a 2-die line).
    Line,
    /// A chain closed into a ring (Galaxy-style): die N−1 links back to
    /// die 0, halving worst-case path lengths.
    Ring,
    /// A 2D torus of `rows × cols` dies — the physical Galaxy wiring
    /// (4×8). Each die links to its four grid neighbours, with a wrap
    /// link closing every row (when `cols > 2`) and every column (when
    /// `rows > 2`), exactly as each 1D `Ring` closes its chain. Paths
    /// are dimension-ordered (row dimension, then column dimension),
    /// and each dimension independently picks direct-vs-wrap by hop
    /// count — the off-die analogue of the on-die NOC0/NOC1 choice in
    /// [`crate::noc::route`], where the NoC is itself a pair of
    /// unidirectional 2D torus networks and directional route selection
    /// changes hop counts ~2×.
    Torus2D { rows: usize, cols: usize },
}

impl MeshTopology {
    pub fn label(self) -> String {
        match self {
            MeshTopology::Line => "line".to_string(),
            MeshTopology::Ring => "ring".to_string(),
            MeshTopology::Torus2D { rows, cols } => format!("torus:{rows}x{cols}"),
        }
    }

    /// The most-square torus factoring of `n_dies` (rows ≤ cols): the
    /// per-N default when a sweep asks for "a torus" without fixing the
    /// shape. 32 → 4×8 (the Galaxy wiring), 8 → 2×4, 4 → 2×2, 2 → 1×2.
    pub fn torus_for(n_dies: usize) -> Self {
        let mut rows = 1;
        let mut d = 1;
        while d * d <= n_dies {
            if n_dies % d == 0 {
                rows = d;
            }
            d += 1;
        }
        MeshTopology::Torus2D {
            rows,
            cols: n_dies / rows.max(1),
        }
    }
}

impl std::str::FromStr for MeshTopology {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        let lower = s.to_ascii_lowercase();
        if let Some(shape) = lower.strip_prefix("torus:") {
            let (r, c) = shape
                .split_once('x')
                .ok_or_else(|| format!("torus topology wants a shape like 'torus:4x8', got '{s}'"))?;
            let rows: usize = r
                .parse()
                .map_err(|_| format!("bad torus rows in '{s}'"))?;
            let cols: usize = c
                .parse()
                .map_err(|_| format!("bad torus cols in '{s}'"))?;
            if rows == 0 || cols == 0 {
                return Err(format!("torus shape must be nonzero, got '{s}'"));
            }
            return Ok(MeshTopology::Torus2D { rows, cols });
        }
        match lower.as_str() {
            "line" | "chain" => Ok(MeshTopology::Line),
            "ring" => Ok(MeshTopology::Ring),
            _ => Err(format!(
                "unknown mesh topology '{s}' (expected line|ring|torus:RxC)"
            )),
        }
    }
}

/// N identical Tensix die sub-grids joined by Ethernet links. Dies tile
/// the logical core grid as a row-major die grid ([`Self::mesh_shape`]):
/// die (r, c) owns logical core rows `[r·die_rows, (r+1)·die_rows)` ×
/// columns `[c·die_cols, (c+1)·die_cols)`. A 1D topology is the N×1
/// column — dies stack the domain along x, generalizing the n300
/// dual-die decomposition to arbitrary N — and a 2D torus splits it
/// along both axes (4-seam halos).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceMesh {
    pub n_dies: usize,
    /// Per-die compute sub-grid shape (§7.2: ≤ 8×7).
    pub die_rows: usize,
    pub die_cols: usize,
    pub topology: MeshTopology,
    /// Uniform link model (per-topology preset from `arch::specs`).
    pub link: EthLink,
    /// Links currently out of service, as normalized (lower, higher)
    /// die pairs. Empty on every mesh built by [`Self::new`]; the fault
    /// layer derives faulted meshes with [`Self::with_down_links`].
    /// [`Self::path`] routes around these (BFS fallback when the
    /// dimension-ordered route is cut); [`Self::links`] still reports
    /// the physical wiring.
    pub down: BTreeSet<(usize, usize)>,
}

impl DeviceMesh {
    pub fn new(
        n_dies: usize,
        die_rows: usize,
        die_cols: usize,
        topology: MeshTopology,
        link: EthLink,
    ) -> Result<Self> {
        if n_dies == 0 {
            return Err(SimError::BadProblem {
                what: "mesh needs at least one die".to_string(),
            });
        }
        if n_dies > GALAXY_DIES {
            return Err(SimError::BadProblem {
                what: format!("{n_dies} dies exceeds the {GALAXY_DIES}-die Galaxy ceiling"),
            });
        }
        if let MeshTopology::Torus2D { rows, cols } = topology {
            if rows * cols != n_dies {
                return Err(SimError::BadProblem {
                    what: format!(
                        "torus shape {rows}x{cols} covers {} dies but the mesh has {n_dies}",
                        rows * cols
                    ),
                });
            }
        }
        // Per-die sub-grid obeys the single-die rules (§7.2 ≤ 8×7).
        let _ = TensixGrid::new(die_rows, die_cols)?;
        Ok(Self {
            n_dies,
            die_rows,
            die_cols,
            topology,
            link,
            down: BTreeSet::new(),
        })
    }

    /// A copy of this mesh with the given links marked out of service
    /// (pairs normalized; unknown pairs are ignored by routing since no
    /// path ever used them). The original mesh is untouched — fault-free
    /// callers never see a `down` set.
    pub fn with_down_links(&self, links: &[(usize, usize)]) -> Self {
        let mut m = self.clone();
        m.down = links.iter().map(|&(a, b)| (a.min(b), a.max(b))).collect();
        m
    }

    /// One die, no links — the n150.
    pub fn n150(die_rows: usize, die_cols: usize) -> Result<Self> {
        Self::new(1, die_rows, die_cols, MeshTopology::Line, EthLink::onboard())
    }

    /// Two dies over the on-board link — the n300.
    pub fn n300(die_rows: usize, die_cols: usize) -> Result<Self> {
        Self::new(2, die_rows, die_cols, MeshTopology::Line, EthLink::onboard())
    }

    /// Thirty-two dies on a backplane ring — the Galaxy.
    pub fn galaxy(die_rows: usize, die_cols: usize) -> Result<Self> {
        Self::new(
            GALAXY_DIES,
            die_rows,
            die_cols,
            MeshTopology::Ring,
            EthLink::backplane(),
        )
    }

    /// Thirty-two dies on the physical Galaxy backplane wiring: a 4×8
    /// 2D torus (each die links to four neighbours, every row and
    /// column closed by a wrap link).
    pub fn galaxy_torus(die_rows: usize, die_cols: usize) -> Result<Self> {
        Self::new(
            GALAXY_DIES,
            die_rows,
            die_cols,
            MeshTopology::Torus2D { rows: 4, cols: 8 },
            EthLink::backplane(),
        )
    }

    pub fn cores_per_die(&self) -> usize {
        self.die_rows * self.die_cols
    }

    pub fn n_cores(&self) -> usize {
        self.n_dies * self.cores_per_die()
    }

    /// The die grid shape as (mesh_rows, mesh_cols). 1D topologies are
    /// the N×1 column — dies stack the domain along x exactly as before.
    pub fn mesh_shape(&self) -> (usize, usize) {
        match self.topology {
            MeshTopology::Torus2D { rows, cols } => (rows, cols),
            _ => (self.n_dies, 1),
        }
    }

    /// Die-grid coordinate of a die id (dies are row-major over the die
    /// grid).
    pub fn die_coord(&self, die: usize) -> (usize, usize) {
        let (_, cols) = self.mesh_shape();
        (die / cols, die % cols)
    }

    /// Die id at a die-grid coordinate.
    pub fn die_at(&self, r: usize, c: usize) -> usize {
        let (_, cols) = self.mesh_shape();
        r * cols + c
    }

    /// Logical core-grid rows across the whole mesh (die-grid rows ×
    /// per-die rows; a 1D mesh x-stacks its dies as before).
    pub fn logical_rows(&self) -> usize {
        self.mesh_shape().0 * self.die_rows
    }

    /// Logical core-grid columns across the whole mesh (die-grid cols ×
    /// per-die cols; `die_cols` on any 1D mesh).
    pub fn logical_cols(&self) -> usize {
        self.mesh_shape().1 * self.die_cols
    }

    /// The per-die compute sub-grid (identical for every die).
    pub fn die_grid(&self) -> Result<TensixGrid> {
        TensixGrid::new(self.die_rows, self.die_cols)
    }

    /// Die owning a logical (mesh-wide, row-major) core index.
    pub fn die_of_core(&self, core: usize) -> usize {
        let row = core / self.logical_cols();
        let col = core % self.logical_cols();
        self.die_at(row / self.die_rows, col / self.die_cols)
    }

    /// The undirected links of the topology, as (lower, higher) die
    /// pairs, sorted. Line/Ring keep the chain (+ wrap); a torus links
    /// each die to its four grid neighbours and closes each row/column
    /// with a wrap link when that dimension is longer than 2 (a 2-long
    /// dimension's "wrap" would duplicate the direct link, exactly as a
    /// 2-die `Ring` degenerates to the line).
    pub fn links(&self) -> Vec<(usize, usize)> {
        if let MeshTopology::Torus2D { rows, cols } = self.topology {
            let mut out: Vec<(usize, usize)> = Vec::new();
            for r in 0..rows {
                for c in 0..cols {
                    let d = self.die_at(r, c);
                    if c + 1 < cols {
                        out.push((d, self.die_at(r, c + 1)));
                    }
                    if r + 1 < rows {
                        out.push((d, self.die_at(r + 1, c)));
                    }
                }
            }
            if cols > 2 {
                for r in 0..rows {
                    out.push((self.die_at(r, 0), self.die_at(r, cols - 1)));
                }
            }
            if rows > 2 {
                for c in 0..cols {
                    out.push((self.die_at(0, c), self.die_at(rows - 1, c)));
                }
            }
            out.sort_unstable();
            out.dedup();
            return out;
        }
        let mut out: Vec<(usize, usize)> = (0..self.n_dies.saturating_sub(1)).map(|d| (d, d + 1)).collect();
        if self.topology == MeshTopology::Ring && self.n_dies > 2 {
            out.push((0, self.n_dies - 1));
        }
        out
    }

    pub fn are_linked(&self, a: usize, b: usize) -> bool {
        let (lo, hi) = (a.min(b), a.max(b));
        self.links().contains(&(lo, hi))
    }

    /// Link-path lookup: the undirected links a transfer from die `a` to
    /// die `b` traverses, in order. On a line this is the straight chain;
    /// on a ring, the shorter arc (ties go through the chain). On a
    /// torus the route is dimension-ordered — all row-dimension hops
    /// first, then all column-dimension hops, the off-die mirror of the
    /// on-die X-then-Y `noc::route::xy_route` — and each dimension
    /// independently takes its shorter arc (direct vs wrap, ties
    /// direct), the NOC0-vs-NOC1 directional choice applied per
    /// dimension.
    pub fn path(&self, a: usize, b: usize) -> Vec<(usize, usize)> {
        let nominal = self.nominal_path(a, b);
        if self.down.is_empty() || nominal.iter().all(|h| !self.down.contains(h)) {
            return nominal;
        }
        // The dimension-ordered (or arc) route crosses a down link:
        // fall back to a shortest path over the live links — the same
        // BFS the prop_torus oracle uses to certify nominal routes.
        self.bfs_path(a, b).unwrap_or_else(|| {
            panic!(
                "no live route from die {a} to die {b}: down links {:?} disconnect the mesh",
                self.down
            )
        })
    }

    /// The fault-oblivious route (dimension-ordered on a torus, shorter
    /// arc on a ring, chain on a line) — what [`Self::path`] returns
    /// whenever no down link cuts it.
    fn nominal_path(&self, a: usize, b: usize) -> Vec<(usize, usize)> {
        assert!(a < self.n_dies && b < self.n_dies, "die index out of range");
        if a == b {
            return Vec::new();
        }
        if let MeshTopology::Torus2D { rows, cols } = self.topology {
            let (ar, ac) = self.die_coord(a);
            let (br, bc) = self.die_coord(b);
            let mut hops = Vec::new();
            // Row dimension first, at the source column.
            let mut prev = ar;
            for r in dim_steps(rows, ar, br) {
                let (x, y) = (self.die_at(prev, ac), self.die_at(r, ac));
                hops.push((x.min(y), x.max(y)));
                prev = r;
            }
            // Then the column dimension, at the destination row.
            let mut prev = ac;
            for c in dim_steps(cols, ac, bc) {
                let (x, y) = (self.die_at(br, prev), self.die_at(br, c));
                hops.push((x.min(y), x.max(y)));
                prev = c;
            }
            return hops;
        }
        let (lo, hi) = (a.min(b), a.max(b));
        let inner = hi - lo;
        let outer = self.n_dies - inner;
        let use_wrap = self.topology == MeshTopology::Ring && self.n_dies > 2 && outer < inner;
        if use_wrap {
            // lo → 0 → wrap link → N−1 → hi.
            let mut hops: Vec<(usize, usize)> = (0..lo).rev().map(|d| (d, d + 1)).collect();
            hops.push((0, self.n_dies - 1));
            hops.extend((hi..self.n_dies - 1).map(|d| (d, d + 1)));
            hops
        } else {
            (lo..hi).map(|d| (d, d + 1)).collect()
        }
    }

    /// The physical links minus the down set — the edges routing may
    /// actually use.
    pub fn live_links(&self) -> Vec<(usize, usize)> {
        self.links()
            .into_iter()
            .filter(|l| !self.down.contains(l))
            .collect()
    }

    /// Shortest live route from `a` to `b` by breadth-first search over
    /// [`Self::live_links`] (the prop_torus oracle machinery, promoted
    /// to a routing fallback). `None` when the down set disconnects the
    /// pair. Neighbor order follows the sorted link list, so the result
    /// is deterministic.
    pub fn bfs_path(&self, a: usize, b: usize) -> Option<Vec<(usize, usize)>> {
        assert!(a < self.n_dies && b < self.n_dies, "die index out of range");
        if a == b {
            return Some(Vec::new());
        }
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); self.n_dies];
        for (x, y) in self.live_links() {
            adj[x].push(y);
            adj[y].push(x);
        }
        let mut prev: Vec<Option<usize>> = vec![None; self.n_dies];
        let mut seen = vec![false; self.n_dies];
        seen[a] = true;
        let mut queue = VecDeque::from([a]);
        while let Some(d) = queue.pop_front() {
            if d == b {
                let mut hops = Vec::new();
                let mut cur = b;
                while let Some(p) = prev[cur] {
                    hops.push((p.min(cur), p.max(cur)));
                    cur = p;
                }
                hops.reverse();
                return Some(hops);
            }
            for &n in &adj[d] {
                if !seen[n] {
                    seen[n] = true;
                    prev[n] = Some(d);
                    queue.push_back(n);
                }
            }
        }
        None
    }

    /// Whether every pair of `survivors` can still reach each other over
    /// the live links, ignoring dies not in the set (the solver checks
    /// this before resuming on a degraded mesh).
    pub fn survivors_connected(&self, survivors: &BTreeSet<usize>) -> bool {
        let Some(&first) = survivors.iter().next() else {
            return true;
        };
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); self.n_dies];
        for (x, y) in self.live_links() {
            if survivors.contains(&x) && survivors.contains(&y) {
                adj[x].push(y);
                adj[y].push(x);
            }
        }
        let mut seen = vec![false; self.n_dies];
        seen[first] = true;
        let mut queue = VecDeque::from([first]);
        let mut reached = 1usize;
        while let Some(d) = queue.pop_front() {
            for &n in &adj[d] {
                if !seen[n] {
                    seen[n] = true;
                    reached += 1;
                    queue.push_back(n);
                }
            }
        }
        reached == survivors.len()
    }

    /// Number of links on the `a`→`b` path.
    pub fn path_len(&self, a: usize, b: usize) -> usize {
        self.path(a, b).len()
    }

    /// Serial transfer time of `bytes` from die `a` to die `b` (each hop
    /// is a store-and-forward over one link).
    pub fn transfer_ns(&self, a: usize, b: usize, bytes: u64) -> f64 {
        self.path_len(a, b) as f64 * self.link.transfer_ns(bytes)
    }

    /// Per-die resource budgets for a PCG-shaped resident problem: the
    /// §7.2 SRAM ceiling (via the capacity model) and the per-die DRAM
    /// share of the vector working set. `vectors` is the number of
    /// resident whole-domain vectors (use the §7.2 counts).
    pub fn validate_budgets(&self, tiles_per_core: usize, df: DataFormat, fused: bool) -> Result<()> {
        let problem =
            crate::solver::problem::Problem::new(self.die_rows, self.die_cols, tiles_per_core, df);
        problem.validate_capacity(fused)?;
        // DRAM: each die backs its resident vectors (plus staging) out of
        // its own GDDR6 share — n300d ships 24 GB for two dies.
        let dram_per_die = N300D_DRAM_BYTES / 2;
        let vectors = if fused {
            crate::arch::constants::PCG_VECTORS_FUSED
        } else {
            crate::arch::constants::PCG_VECTORS_SPLIT
        };
        let per_die_bytes =
            (self.cores_per_die() * tiles_per_core * df.tile_bytes() * vectors) as u64;
        if per_die_bytes > dram_per_die {
            return Err(SimError::BadProblem {
                what: format!(
                    "per-die vector working set {per_die_bytes} B exceeds the {dram_per_die} B DRAM share"
                ),
            });
        }
        Ok(())
    }
}

/// The coordinates visited (source excluded) walking one torus dimension
/// of length `len` from `from` to `to`, stepping ±1 with wraparound.
/// Takes the shorter arc; ties and 2-long dimensions go direct (no wrap
/// link exists below length 3).
fn dim_steps(len: usize, from: usize, to: usize) -> Vec<usize> {
    if from == to {
        return Vec::new();
    }
    let direct = from.abs_diff(to);
    let wrap = len - direct;
    let use_wrap = len > 2 && wrap < direct;
    let step_down = (from > to) ^ use_wrap;
    let count = if use_wrap { wrap } else { direct };
    let mut cur = from;
    (0..count)
        .map(|_| {
            cur = if step_down { (cur + len - 1) % len } else { (cur + 1) % len };
            cur
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_presets() {
        let m = DeviceMesh::n300(4, 4).unwrap();
        assert_eq!(m.n_dies, 2);
        assert_eq!(m.n_cores(), 32);
        assert_eq!(m.logical_rows(), 8);
        assert_eq!(m.link, EthLink::onboard());
        assert_eq!(m.links(), vec![(0, 1)]);

        let g = DeviceMesh::galaxy(8, 7).unwrap();
        assert_eq!(g.n_dies, 32);
        assert_eq!(g.topology, MeshTopology::Ring);
        assert_eq!(g.link, EthLink::backplane());
        assert_eq!(g.links().len(), 32); // chain + wrap

        assert!(DeviceMesh::new(0, 1, 1, MeshTopology::Line, EthLink::default()).is_err());
        assert!(DeviceMesh::new(33, 1, 1, MeshTopology::Line, EthLink::default()).is_err());
        // Per-die grid still obeys the §7.2 sub-grid ceiling.
        assert!(DeviceMesh::new(2, 9, 7, MeshTopology::Line, EthLink::default()).is_err());
    }

    #[test]
    fn link_transfer_cost_matches_dualdie_model() {
        // The moved EthLink keeps the dual-die solver's exact cost model.
        let l = EthLink::default();
        assert_eq!(l.latency_ns, 800.0);
        assert_eq!(l.bw_gbs, 11.0);
        assert_eq!(l.transfer_ns(0), 800.0);
        assert!((l.transfer_ns(1100) - 900.0).abs() < 1e-9);
        assert!(EthLink::backplane().latency_ns > EthLink::onboard().latency_ns);
        // The one scale→link-class mapping the drivers share.
        assert_eq!(EthLink::for_dies(1), EthLink::onboard());
        assert_eq!(EthLink::for_dies(2), EthLink::onboard());
        assert_eq!(EthLink::for_dies(4), EthLink::backplane());
    }

    #[test]
    fn path_lookup_line_vs_ring() {
        let line = DeviceMesh::new(8, 1, 1, MeshTopology::Line, EthLink::default()).unwrap();
        assert_eq!(line.path(2, 2), vec![]);
        assert_eq!(line.path(1, 4), vec![(1, 2), (2, 3), (3, 4)]);
        assert_eq!(line.path(4, 1), vec![(1, 2), (2, 3), (3, 4)]);
        assert_eq!(line.path_len(0, 7), 7);

        let ring = DeviceMesh::new(8, 1, 1, MeshTopology::Ring, EthLink::default()).unwrap();
        // 0 → 7 goes over the wrap link.
        assert_eq!(ring.path(0, 7), vec![(0, 7)]);
        assert_eq!(ring.path_len(1, 6), 3); // 1→0→7→6
        assert!(ring.path(1, 6).contains(&(0, 7)));
        // Shorter arcs keep the chain, and every pair is no longer than on
        // the line.
        assert_eq!(ring.path(1, 3), vec![(1, 2), (2, 3)]);
        for a in 0..8 {
            for b in 0..8 {
                assert!(ring.path_len(a, b) <= line.path_len(a, b));
            }
        }
    }

    #[test]
    fn die_of_core_follows_x_stacking() {
        let m = DeviceMesh::new(4, 2, 3, MeshTopology::Line, EthLink::default()).unwrap();
        assert_eq!(m.die_of_core(0), 0);
        assert_eq!(m.die_of_core(m.cores_per_die() - 1), 0);
        assert_eq!(m.die_of_core(m.cores_per_die()), 1);
        assert_eq!(m.die_of_core(m.n_cores() - 1), 3);
    }

    #[test]
    fn torus_parse_label_and_presets() {
        let t: MeshTopology = "torus:4x8".parse().unwrap();
        assert_eq!(t, MeshTopology::Torus2D { rows: 4, cols: 8 });
        assert_eq!(t.label(), "torus:4x8");
        // Bare "torus" is not a topology — shapes are explicit (sweeps
        // that want a per-N default use `torus_for`).
        assert!("torus".parse::<MeshTopology>().is_err());
        assert!("torus:0x4".parse::<MeshTopology>().is_err());
        assert!("torus:4".parse::<MeshTopology>().is_err());
        assert_eq!(MeshTopology::torus_for(32), MeshTopology::Torus2D { rows: 4, cols: 8 });
        assert_eq!(MeshTopology::torus_for(8), MeshTopology::Torus2D { rows: 2, cols: 4 });
        assert_eq!(MeshTopology::torus_for(4), MeshTopology::Torus2D { rows: 2, cols: 2 });
        assert_eq!(MeshTopology::torus_for(2), MeshTopology::Torus2D { rows: 1, cols: 2 });
        assert_eq!(MeshTopology::torus_for(1), MeshTopology::Torus2D { rows: 1, cols: 1 });

        let g = DeviceMesh::galaxy_torus(8, 7).unwrap();
        assert_eq!(g.n_dies, 32);
        assert_eq!(g.mesh_shape(), (4, 8));
        assert_eq!(g.link, EthLink::backplane());
        // 4×8 torus: 28 row-direct + 24 col-direct + 4 row wraps + 8 col
        // wraps.
        assert_eq!(g.links().len(), 64);
        // Shape must cover the die count exactly — a real error, not a
        // panic.
        assert!(DeviceMesh::new(
            2,
            1,
            1,
            MeshTopology::Torus2D { rows: 4, cols: 8 },
            EthLink::default()
        )
        .is_err());
    }

    #[test]
    fn torus_coords_and_logical_grid() {
        let m = DeviceMesh::new(
            8,
            2,
            3,
            MeshTopology::Torus2D { rows: 2, cols: 4 },
            EthLink::default(),
        )
        .unwrap();
        assert_eq!(m.die_coord(0), (0, 0));
        assert_eq!(m.die_coord(3), (0, 3));
        assert_eq!(m.die_coord(5), (1, 1));
        assert_eq!(m.die_at(1, 1), 5);
        assert_eq!(m.logical_rows(), 4);
        assert_eq!(m.logical_cols(), 12);
        // Core (row 2, col 7) of the 4×12 logical grid sits on die (1, 2).
        assert_eq!(m.die_of_core(2 * 12 + 7), m.die_at(1, 2));
        // On 1D meshes the generalized mapping reproduces x-stacking.
        let line = DeviceMesh::new(4, 2, 3, MeshTopology::Line, EthLink::default()).unwrap();
        for core in 0..line.n_cores() {
            assert_eq!(line.die_of_core(core), (core / 3) / 2);
        }
    }

    #[test]
    fn torus_paths_are_dimension_ordered_with_per_dim_wrap() {
        let m = DeviceMesh::new(
            32,
            1,
            1,
            MeshTopology::Torus2D { rows: 4, cols: 8 },
            EthLink::default(),
        )
        .unwrap();
        // Same row: pure column-dimension route, wrap when shorter.
        assert_eq!(m.path(0, 2), vec![(0, 1), (1, 2)]);
        assert_eq!(m.path(0, 7), vec![(0, 7)]); // column wrap
        // Same column: row-dimension route with the column wrap link.
        assert_eq!(m.path(0, 24), vec![(0, 24)]); // row wrap (die (3,0))
        // Mixed: row hops first (at the source column), then column
        // hops (at the destination row).
        assert_eq!(m.path(1, 10), vec![(1, 9), (9, 10)]);
        // Worst case is (rows/2 + cols/2) hops, vs 16 on the 1D ring.
        let mut worst = 0;
        for a in 0..32 {
            for b in 0..32 {
                worst = worst.max(m.path_len(a, b));
            }
        }
        assert_eq!(worst, 4 / 2 + 8 / 2);
        // Every hop in every path is a physical link.
        let links = m.links();
        for a in 0..32 {
            for b in 0..32 {
                for hop in m.path(a, b) {
                    assert!(links.contains(&hop), "path {a}->{b} uses non-link {hop:?}");
                }
            }
        }
    }

    #[test]
    fn degenerate_torus_matches_ring_wiring() {
        // An N×1 (or 1×N) torus is exactly the N-die ring: same links,
        // same paths.
        for n in [2usize, 5, 8] {
            let ring = DeviceMesh::new(n, 1, 1, MeshTopology::Ring, EthLink::default()).unwrap();
            let col = DeviceMesh::new(
                n,
                1,
                1,
                MeshTopology::Torus2D { rows: n, cols: 1 },
                EthLink::default(),
            )
            .unwrap();
            let row = DeviceMesh::new(
                n,
                1,
                1,
                MeshTopology::Torus2D { rows: 1, cols: n },
                EthLink::default(),
            )
            .unwrap();
            let sorted = |mut v: Vec<(usize, usize)>| {
                v.sort_unstable();
                v
            };
            assert_eq!(col.links(), sorted(ring.links()), "N={n} column torus");
            assert_eq!(row.links(), sorted(ring.links()), "N={n} row torus");
            // Paths traverse the same link sets (hop order within a path
            // only feeds order-insensitive per-link accumulation).
            for a in 0..n {
                for b in 0..n {
                    assert_eq!(sorted(col.path(a, b)), sorted(ring.path(a, b)), "N={n} {a}->{b}");
                    assert_eq!(sorted(row.path(a, b)), sorted(ring.path(a, b)), "N={n} {a}->{b}");
                }
            }
        }
    }

    #[test]
    fn eth_sim_serializes_shared_link_and_reports_utilization() {
        let link = EthLink::default(); // 800 ns latency, 11 GB/s
        let mut sim = EthSim::new();
        // Two concurrent hops on the SAME physical link (0↔1, both
        // directions): the second queues behind the first — analytic
        // end time is exactly 2 × (latency + bytes/bw).
        let one = link.transfer_ns(1100); // 800 + 100 = 900 ns
        let a = sim.transfer(&link, 0, 1, 1100, 0.0);
        let b = sim.transfer(&link, 1, 0, 1100, 0.0);
        assert!((a - one).abs() < 1e-9);
        assert!((b - 2.0 * one).abs() < 1e-9, "serialized, not independent pipes");
        // A hop on a different link at the same time does not queue.
        let c = sim.transfer(&link, 1, 2, 1100, 0.0);
        assert!((c - one).abs() < 1e-9);
        assert_eq!(sim.messages, 3);
        assert_eq!(sim.bytes, 3 * 1100);
        // Utilization over the busy window: link (0,1) was occupied the
        // whole time, link (1,2) half of it.
        let util = sim.utilization(b);
        assert_eq!(util.len(), 2);
        assert!((util[0].2 - 1.0).abs() < 1e-9, "(0,1) saturated: {util:?}");
        assert!((util[1].2 - 0.5).abs() < 1e-9, "(1,2) half-busy: {util:?}");
        assert!(sim.utilization(0.0).is_empty());
        // The recorded transfers carry the queueing.
        assert_eq!(sim.transfers[1].start, a);
        assert_eq!(sim.transfers[1].link, (0, 1));
    }

    #[test]
    fn replay_carries_transfers_across_component_windows() {
        let link = EthLink::default();
        // Component A ran in its own window [0, ...].
        let mut a = EthSim::new();
        a.transfer(&link, 0, 1, 1100, 0.0);
        a.transfer(&link, 1, 0, 1100, 0.0);
        // Component B likewise timed in isolation.
        let mut b = EthSim::new();
        b.transfer(&link, 1, 2, 2200, 100.0);
        // Solve-level tracker: A's window starts at 10_000, B's after it.
        let mut solve = EthSim::new();
        solve.replay(&a.transfers, 10_000.0);
        solve.replay(&b.transfers, 50_000.0);
        assert_eq!(solve.messages, 3);
        assert_eq!(solve.bytes, 2 * 1100 + 2200);
        // Transfer times are the component times shifted, exactly.
        assert_eq!(solve.transfers[0].start, a.transfers[0].start + 10_000.0);
        assert_eq!(solve.transfers[1].end, a.transfers[1].end + 10_000.0);
        assert_eq!(solve.transfers[2].start, b.transfers[0].start + 50_000.0);
        // Per-link busy sums the windows.
        let busy = solve.per_link_busy();
        assert_eq!(busy.len(), 2);
        assert_eq!(busy[0].0, (0, 1));
        assert!((busy[0].1 - 2.0 * link.transfer_ns(1100)).abs() < 1e-9);
        assert!((busy[1].1 - link.transfer_ns(2200)).abs() < 1e-9);
    }

    #[test]
    fn path_routes_around_down_links() {
        let m = DeviceMesh::new(
            8,
            1,
            1,
            MeshTopology::Torus2D { rows: 2, cols: 4 },
            EthLink::default(),
        )
        .unwrap();
        // Nominal 0→1 is the direct link.
        assert_eq!(m.path(0, 1), vec![(0, 1)]);
        // Cut it: the BFS fallback finds a live detour of physical links.
        let f = m.with_down_links(&[(0, 1)]);
        let detour = f.path(0, 1);
        assert!(!detour.contains(&(0, 1)), "detour reuses the cut link: {detour:?}");
        assert!(!detour.is_empty());
        let live = f.live_links();
        for hop in &detour {
            assert!(live.contains(hop), "detour hop {hop:?} is not a live link");
        }
        // Consecutive hops chain 0 → … → 1.
        let mut at = 0usize;
        for &(x, y) in &detour {
            at = if x == at { y } else { x };
        }
        assert_eq!(at, 1);
        // Routes the cut does not touch are returned verbatim.
        assert_eq!(f.path(2, 3), m.path(2, 3));
        // An empty down set is the identity on every pair.
        let same = m.with_down_links(&[]);
        for a in 0..8 {
            for b in 0..8 {
                assert_eq!(same.path(a, b), m.path(a, b), "{a}->{b}");
            }
        }
        // Cutting every link off die 0 disconnects it.
        let dead = m.with_down_links(&[(0, 1), (0, 3), (0, 4)]);
        assert!(dead.bfs_path(0, 5).is_none());
        let survivors: BTreeSet<usize> = (1..8).collect();
        assert!(dead.survivors_connected(&survivors));
        assert!(!dead.survivors_connected(&(0..8).collect()));
    }

    #[test]
    fn eth_sim_slowdown_stretches_only_degraded_links() {
        let link = EthLink::default(); // 800 + bytes/11 ns
        let one = link.transfer_ns(1100); // 900 ns
        let mut sim = EthSim::new();
        sim.set_slowdown(&[((1, 0), 3.0)]); // normalized to (0,1)
        let a = sim.transfer(&link, 0, 1, 1100, 0.0);
        assert!((a - 3.0 * one).abs() < 1e-9, "degraded link: {a}");
        let b = sim.transfer(&link, 1, 2, 1100, 0.0);
        assert!((b - one).abs() < 1e-9, "clean link unaffected: {b}");
        // Queueing still serializes on the degraded wire.
        let c = sim.transfer(&link, 1, 0, 1100, 0.0);
        assert!((c - 6.0 * one).abs() < 1e-9, "queued behind slow transfer: {c}");
        // An empty map is bit-identical to the undegraded model.
        let mut clean = EthSim::new();
        clean.set_slowdown(&[]);
        assert_eq!(clean.transfer(&link, 0, 1, 1100, 0.0), one);
    }

    #[test]
    fn budget_checks_per_die() {
        use crate::arch::DataFormat;
        let m = DeviceMesh::n300(1, 1).unwrap();
        assert!(m.validate_budgets(164, DataFormat::Bf16, true).is_ok());
        // §7.2 per-die SRAM ceiling is enforced through the mesh.
        assert!(m.validate_budgets(165, DataFormat::Bf16, true).is_err());
    }
}
