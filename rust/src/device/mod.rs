//! Device model: Tensix cores (SRAM, circular buffers), DRAM, and the
//! compute grid (paper §3).

pub mod cb;
pub mod core;
pub mod dram;
pub mod grid;
pub mod sram;

pub use cb::CircularBuffer;
pub use core::{Coord, CoreCounters, TensixCore};
pub use dram::Dram;
pub use grid::TensixGrid;
pub use sram::Sram;
