//! Device model: Tensix cores (SRAM, circular buffers), DRAM, the compute
//! grid (paper §3), and the multi-die Ethernet mesh (§8).

pub mod cb;
pub mod core;
pub mod dram;
pub mod faults;
pub mod grid;
pub mod mesh;
pub mod sram;

pub use cb::CircularBuffer;
pub use core::{Coord, CoreCounters, TensixCore};
pub use dram::Dram;
pub use faults::{FaultEvent, FaultPlan, FaultState};
pub use grid::TensixGrid;
pub use mesh::{DeviceMesh, EthLink, EthSim, EthTransfer, MeshTopology};
pub use sram::Sram;
