//! Deterministic fault injection for mesh solves (ISSUE 10).
//!
//! A [`FaultPlan`] is a seedable *script* of component failures — link
//! cuts, per-window link degradation, die loss, and silent data
//! corruption (SDC) — parsed from a compact `--faults` spec string or a
//! JSON file and threaded through `MeshOptions` into the mesh solver.
//! The plan itself is pure data: it never mutates the mesh. The solver
//! samples it at iteration boundaries ([`FaultPlan::state_at`]) and
//! reacts — rerouting via [`crate::device::DeviceMesh::path`]'s BFS
//! fallback, re-lowering onto the degraded topology, charging the retry
//! penalty ([`FaultPlan::retry_penalty_ns`]) to the ledger's `retry`
//! row, and rolling back to the last checkpoint on die loss or a
//! detected SDC (`solver::resilient`).
//!
//! Spec grammar (`;`-separated events, times take `ns`/`us`/`ms`
//! suffixes, default ns):
//!
//! ```text
//! link_down:A-B@T            cut the A↔B link at time T
//! link_degrade:A-B@T0..T1xF  multiply A↔B transfer durations by F in [T0,T1)
//! die_down:D@T               die D is lost at time T
//! sdc:COMP@ITER              corrupt COMP's output at iteration ITER
//! seed:N                     retry/corruption PRNG seed (default 0)
//! ```
//!
//! e.g. `--faults 'link_down:0-1@5us;sdc:spmv@20'`. Determinism: the
//! same plan + seed always yields the same retry counts and the same
//! corrupted bits, so faulted solves are exactly reproducible.

use std::collections::BTreeSet;

use crate::timing::SimNs;
use crate::util::jsonmini::Json;
use crate::util::prng::Rng;

/// One scripted fault event. Link endpoints are stored normalized
/// (`a < b`) so they match [`crate::device::EthSim`]'s link keys.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultEvent {
    /// The a↔b Ethernet link is permanently cut at `t_ns`.
    LinkDown { a: usize, b: usize, t_ns: SimNs },
    /// Transfers on a↔b take `factor`× as long while `t0_ns <= t < t1_ns`
    /// (a flapping or error-correcting link; factor ≥ 1).
    LinkDegrade { a: usize, b: usize, factor: f64, t0_ns: SimNs, t1_ns: SimNs },
    /// Die `die` is permanently lost at `t_ns` (all its links go down;
    /// its subdomain's work migrates to a surviving neighbor).
    DieDown { die: usize, t_ns: SimNs },
    /// The named component's output vector is silently corrupted at the
    /// given 1-based PCG iteration (a bit-flip-class soft error).
    Sdc { component: String, iter: usize },
}

/// The topology-affecting fault state active at one instant: which dies
/// and links are down and how surviving links are degraded. The solver
/// re-lowers whenever this changes between iterations (a "fault epoch").
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultState {
    pub down_dies: BTreeSet<usize>,
    /// Normalized (min, max) down link keys — explicit `link_down`s plus
    /// every mesh link incident to a down die.
    pub down_links: BTreeSet<(usize, usize)>,
    /// Per-link transfer-duration multipliers (product of active
    /// degradation windows), sorted by link key.
    pub slowdown: Vec<((usize, usize), f64)>,
}

impl FaultState {
    pub fn is_clean(&self) -> bool {
        self.down_dies.is_empty() && self.down_links.is_empty() && self.slowdown.is_empty()
    }
}

/// A deterministic, seedable script of fault events.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub events: Vec<FaultEvent>,
}

/// Timeout before a transfer on a newly-dead link is declared lost.
pub const RETRY_TIMEOUT_NS: f64 = 50_000.0;
/// Bounded retries before the transport reroutes around the link.
pub const RETRY_MAX: u64 = 3;
/// Exponential backoff factor between successive retries.
pub const RETRY_BACKOFF: f64 = 2.0;

/// Parse a time literal with an optional ns/us/ms suffix (default ns).
fn parse_time(s: &str) -> Result<SimNs, String> {
    let (num, mult) = if let Some(v) = s.strip_suffix("ns") {
        (v, 1.0)
    } else if let Some(v) = s.strip_suffix("us") {
        (v, 1_000.0)
    } else if let Some(v) = s.strip_suffix("ms") {
        (v, 1_000_000.0)
    } else {
        (s, 1.0)
    };
    let t: f64 = num
        .trim()
        .parse()
        .map_err(|_| format!("bad time literal '{s}' (expected e.g. 5us, 2500ns)"))?;
    if !t.is_finite() || t < 0.0 {
        return Err(format!("time '{s}' must be finite and >= 0"));
    }
    Ok(t * mult)
}

/// Parse a `A-B` die pair into a normalized (min, max) key.
fn parse_pair(s: &str) -> Result<(usize, usize), String> {
    let (a, b) = s
        .split_once('-')
        .ok_or_else(|| format!("bad link '{s}' (expected A-B die pair)"))?;
    let a: usize = a.trim().parse().map_err(|_| format!("bad die index '{a}' in link '{s}'"))?;
    let b: usize = b.trim().parse().map_err(|_| format!("bad die index '{b}' in link '{s}'"))?;
    if a == b {
        return Err(format!("link '{s}' joins a die to itself"));
    }
    Ok((a.min(b), a.max(b)))
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the `;`-separated spec grammar (see module docs).
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut plan = FaultPlan::default();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, rest) = entry
                .split_once(':')
                .ok_or_else(|| format!("fault event '{entry}' is not kind:spec"))?;
            match kind.trim() {
                "seed" => {
                    plan.seed = rest
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad fault seed '{rest}'"))?;
                }
                "link_down" => {
                    let (pair, t) = rest
                        .split_once('@')
                        .ok_or_else(|| format!("link_down '{entry}' needs A-B@TIME"))?;
                    let (a, b) = parse_pair(pair)?;
                    plan.events.push(FaultEvent::LinkDown { a, b, t_ns: parse_time(t)? });
                }
                "link_degrade" => {
                    let (pair, win) = rest
                        .split_once('@')
                        .ok_or_else(|| format!("link_degrade '{entry}' needs A-B@T0..T1xF"))?;
                    let (a, b) = parse_pair(pair)?;
                    let (range, factor) = win
                        .rsplit_once('x')
                        .ok_or_else(|| format!("link_degrade '{entry}' needs a xFACTOR suffix"))?;
                    let (t0, t1) = range
                        .split_once("..")
                        .ok_or_else(|| format!("link_degrade '{entry}' needs a T0..T1 window"))?;
                    let factor: f64 = factor
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad degrade factor '{factor}' in '{entry}'"))?;
                    if !(factor.is_finite() && factor >= 1.0) {
                        return Err(format!(
                            "degrade factor {factor} in '{entry}' must be >= 1 (slower, not faster)"
                        ));
                    }
                    let (t0_ns, t1_ns) = (parse_time(t0)?, parse_time(t1)?);
                    if t1_ns <= t0_ns {
                        return Err(format!("degrade window '{entry}' is empty (T1 <= T0)"));
                    }
                    plan.events.push(FaultEvent::LinkDegrade { a, b, factor, t0_ns, t1_ns });
                }
                "die_down" => {
                    let (die, t) = rest
                        .split_once('@')
                        .ok_or_else(|| format!("die_down '{entry}' needs DIE@TIME"))?;
                    let die: usize = die
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad die index '{die}' in '{entry}'"))?;
                    plan.events.push(FaultEvent::DieDown { die, t_ns: parse_time(t)? });
                }
                "sdc" => {
                    let (comp, iter) = rest
                        .split_once('@')
                        .ok_or_else(|| format!("sdc '{entry}' needs COMPONENT@ITER"))?;
                    let comp = comp.trim();
                    if comp.is_empty() {
                        return Err(format!("sdc '{entry}' names no component"));
                    }
                    let iter: usize = iter
                        .trim()
                        .parse()
                        .map_err(|_| format!("bad sdc iteration '{iter}' in '{entry}'"))?;
                    if iter == 0 {
                        return Err(format!("sdc iteration in '{entry}' is 1-based, got 0"));
                    }
                    plan.events.push(FaultEvent::Sdc { component: comp.to_string(), iter });
                }
                other => {
                    return Err(format!(
                        "unknown fault kind '{other}' (expected link_down|link_degrade|die_down|sdc|seed)"
                    ))
                }
            }
        }
        Ok(plan)
    }

    /// Parse the JSON file form:
    /// `{"seed":1,"events":[{"kind":"link_down","a":0,"b":1,"t_ns":5000}, ...]}`.
    pub fn from_json(text: &str) -> Result<Self, String> {
        let v = Json::parse(text)?;
        let mut plan = FaultPlan::default();
        plan.seed = v.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let events = v
            .get("events")
            .and_then(Json::as_arr)
            .ok_or("fault JSON needs an \"events\" array")?;
        let num = |e: &Json, k: &str| -> Result<f64, String> {
            e.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("fault event missing numeric \"{k}\""))
        };
        for e in events {
            let kind = e
                .get("kind")
                .and_then(Json::as_str)
                .ok_or("fault event missing \"kind\"")?;
            match kind {
                "link_down" => {
                    let (a, b) = (num(e, "a")? as usize, num(e, "b")? as usize);
                    plan.events.push(FaultEvent::LinkDown {
                        a: a.min(b),
                        b: a.max(b),
                        t_ns: num(e, "t_ns")?,
                    });
                }
                "link_degrade" => {
                    let (a, b) = (num(e, "a")? as usize, num(e, "b")? as usize);
                    plan.events.push(FaultEvent::LinkDegrade {
                        a: a.min(b),
                        b: a.max(b),
                        factor: num(e, "factor")?,
                        t0_ns: num(e, "t0_ns")?,
                        t1_ns: num(e, "t1_ns")?,
                    });
                }
                "die_down" => plan.events.push(FaultEvent::DieDown {
                    die: num(e, "die")? as usize,
                    t_ns: num(e, "t_ns")?,
                }),
                "sdc" => plan.events.push(FaultEvent::Sdc {
                    component: e
                        .get("component")
                        .and_then(Json::as_str)
                        .ok_or("sdc event missing \"component\"")?
                        .to_string(),
                    iter: num(e, "iter")? as usize,
                }),
                other => return Err(format!("unknown fault kind '{other}' in JSON")),
            }
        }
        Ok(plan)
    }

    /// Load from a spec string, or — when the argument names a `.json`
    /// path (or is prefixed with `@`) — from a JSON file.
    pub fn load(spec: &str) -> Result<Self, String> {
        let path = spec.strip_prefix('@').or_else(|| {
            std::path::Path::new(spec)
                .extension()
                .is_some_and(|e| e == "json")
                .then_some(spec)
        });
        match path {
            Some(p) => {
                let text = std::fs::read_to_string(p)
                    .map_err(|e| format!("cannot read fault plan {p}: {e}"))?;
                Self::from_json(&text)
            }
            None => Self::parse(spec),
        }
    }

    /// Check every event against a mesh: die/link indices in range and
    /// links that actually exist in the topology.
    pub fn validate(&self, mesh: &crate::device::DeviceMesh) -> crate::Result<()> {
        let err = |m: String| Err(crate::SimError::Other(m));
        for e in &self.events {
            match e {
                FaultEvent::LinkDown { a, b, .. } | FaultEvent::LinkDegrade { a, b, .. } => {
                    if *b >= mesh.n_dies {
                        return err(format!(
                            "fault link {a}-{b} outside the {}-die mesh",
                            mesh.n_dies
                        ));
                    }
                    if !mesh.are_linked(*a, *b) {
                        return err(format!(
                            "fault link {a}-{b} does not exist in the {} topology",
                            mesh.topology.label()
                        ));
                    }
                }
                FaultEvent::DieDown { die, .. } => {
                    if *die >= mesh.n_dies {
                        return err(format!(
                            "fault die {die} outside the {}-die mesh",
                            mesh.n_dies
                        ));
                    }
                    if mesh.n_dies < 2 {
                        return err("die_down needs at least 2 dies to migrate work".to_string());
                    }
                }
                FaultEvent::Sdc { component, .. } => {
                    if component.is_empty() {
                        return err("sdc event names no component".to_string());
                    }
                }
            }
        }
        Ok(())
    }

    /// Dies down at or before `t`.
    pub fn down_dies_at(&self, t: SimNs) -> BTreeSet<usize> {
        self.events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::DieDown { die, t_ns } if *t_ns <= t => Some(*die),
                _ => None,
            })
            .collect()
    }

    /// The full topology-affecting state at `t`: down dies, down links
    /// (explicit cuts plus every mesh link touching a down die), and the
    /// active per-link slowdown factors.
    pub fn state_at(&self, mesh: &crate::device::DeviceMesh, t: SimNs) -> FaultState {
        let down_dies = self.down_dies_at(t);
        let mut down_links: BTreeSet<(usize, usize)> = self
            .events
            .iter()
            .filter_map(|e| match e {
                FaultEvent::LinkDown { a, b, t_ns } if *t_ns <= t => Some((*a, *b)),
                _ => None,
            })
            .collect();
        if !down_dies.is_empty() {
            for (a, b) in mesh.links() {
                if down_dies.contains(&a) || down_dies.contains(&b) {
                    down_links.insert((a, b));
                }
            }
        }
        let mut slowdown: std::collections::BTreeMap<(usize, usize), f64> =
            std::collections::BTreeMap::new();
        for e in &self.events {
            if let FaultEvent::LinkDegrade { a, b, factor, t0_ns, t1_ns } = e {
                if *t0_ns <= t && t < *t1_ns && !down_links.contains(&(*a, *b)) {
                    *slowdown.entry((*a, *b)).or_insert(1.0) *= factor;
                }
            }
        }
        FaultState {
            down_dies,
            down_links,
            slowdown: slowdown.into_iter().collect(),
        }
    }

    /// Whether `component`'s output is corrupted at (1-based) `iter`.
    pub fn sdc_at(&self, component: &str, iter: usize) -> bool {
        self.events.iter().any(|e| {
            matches!(e, FaultEvent::Sdc { component: c, iter: i }
                if c == component && *i == iter)
        })
    }

    /// Retry-with-backoff penalty paid when `n_lost` links with in-flight
    /// traffic go down: each loss costs one detection timeout plus a
    /// seed-deterministic number of exponentially backed-off retries
    /// before the transport gives up and reroutes. `draw` indexes the
    /// fault occurrence so successive losses draw fresh (but still
    /// deterministic) retry counts.
    pub fn retry_penalty_ns(&self, n_lost: usize, draw: u64) -> SimNs {
        let mut total = 0.0;
        let mut rng = Rng::new(self.seed ^ 0x9e3779b97f4a7c15 ^ draw);
        for _ in 0..n_lost {
            let retries = 1 + rng.below(RETRY_MAX);
            let mut cost = RETRY_TIMEOUT_NS; // detection timeout
            let mut step = RETRY_TIMEOUT_NS;
            for _ in 0..retries {
                step *= RETRY_BACKOFF;
                cost += step;
            }
            total += cost;
        }
        total
    }

    /// Deterministic corruption magnitude for an SDC event (a large
    /// additive perturbation, as a flipped exponent bit would make).
    pub fn sdc_magnitude(&self, iter: usize) -> f32 {
        let mut rng = Rng::new(self.seed ^ 0x5dc_f107 ^ iter as u64);
        1.0e3 * (1.0 + rng.next_f32())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceMesh, EthLink, MeshTopology};

    #[test]
    fn spec_grammar_round_trips_each_kind() {
        let p = FaultPlan::parse(
            "seed:7; link_down:1-0@5us; link_degrade:2-3@1us..2msx4.0; die_down:3@20us; sdc:spmv@20",
        )
        .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.events.len(), 4);
        // Pairs normalize to (min, max); times scale by suffix.
        assert_eq!(p.events[0], FaultEvent::LinkDown { a: 0, b: 1, t_ns: 5_000.0 });
        assert_eq!(
            p.events[1],
            FaultEvent::LinkDegrade { a: 2, b: 3, factor: 4.0, t0_ns: 1_000.0, t1_ns: 2_000_000.0 }
        );
        assert_eq!(p.events[2], FaultEvent::DieDown { die: 3, t_ns: 20_000.0 });
        assert_eq!(p.events[3], FaultEvent::Sdc { component: "spmv".to_string(), iter: 20 });
        // The CI smoke spec parses.
        FaultPlan::parse("link_down:0-1@5us;sdc:spmv@20").unwrap();
        // Empty spec = empty plan.
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn bad_specs_return_descriptive_errors() {
        for (spec, needle) in [
            ("melt:0@1us", "unknown fault kind"),
            ("link_down:0@5us", "A-B"),
            ("link_down:2-2@5us", "itself"),
            ("link_down:0-1@yesterday", "bad time"),
            ("link_down:0-1@-5", ">= 0"),
            ("link_degrade:0-1@1..2", "xFACTOR"),
            ("link_degrade:0-1@1..2x0.5", ">= 1"),
            ("link_degrade:0-1@2..1x4", "empty"),
            ("sdc:@20", "no component"),
            ("sdc:spmv@0", "1-based"),
            ("die_down:x@1", "bad die index"),
            ("garbage", "kind:spec"),
        ] {
            let e = FaultPlan::parse(spec).unwrap_err();
            assert!(e.contains(needle), "spec '{spec}' gave '{e}', wanted '{needle}'");
        }
    }

    #[test]
    fn json_form_matches_spec_form() {
        let json = r#"{"seed":7,"events":[
            {"kind":"link_down","a":1,"b":0,"t_ns":5000},
            {"kind":"link_degrade","a":2,"b":3,"factor":4.0,"t0_ns":1000,"t1_ns":2000000},
            {"kind":"die_down","die":3,"t_ns":20000},
            {"kind":"sdc","component":"spmv","iter":20}]}"#;
        let from_json = FaultPlan::from_json(json).unwrap();
        let from_spec = FaultPlan::parse(
            "seed:7; link_down:1-0@5us; link_degrade:2-3@1us..2msx4.0; die_down:3@20us; sdc:spmv@20",
        )
        .unwrap();
        assert_eq!(from_json, from_spec);
        assert!(FaultPlan::from_json("{}").is_err());
        assert!(FaultPlan::from_json(r#"{"events":[{"kind":"warp"}]}"#).is_err());
    }

    #[test]
    fn state_at_windows_and_die_loss_links() {
        let mesh =
            DeviceMesh::new(8, 1, 2, MeshTopology::Torus2D { rows: 2, cols: 4 }, EthLink::default())
                .unwrap();
        let p = FaultPlan::parse("link_down:0-1@5us; link_degrade:1-2@1us..3usx4; die_down:6@9us")
            .unwrap();
        p.validate(&mesh).unwrap();
        // Before anything fires: clean.
        assert!(p.state_at(&mesh, 0.0).is_clean());
        // Inside the degrade window only.
        let s = p.state_at(&mesh, 2_000.0);
        assert!(s.down_links.is_empty());
        assert_eq!(s.slowdown, vec![((1, 2), 4.0)]);
        // Past the window, at the link cut.
        let s = p.state_at(&mesh, 5_000.0);
        assert_eq!(s.down_links.iter().copied().collect::<Vec<_>>(), vec![(0, 1)]);
        assert!(s.slowdown.is_empty());
        // Die loss takes every incident link down with it.
        let s = p.state_at(&mesh, 10_000.0);
        assert_eq!(s.down_dies.iter().copied().collect::<Vec<_>>(), vec![6]);
        assert!(s.down_links.contains(&(0, 1)));
        for l in mesh.links() {
            assert_eq!(s.down_links.contains(&l) || !(l.0 == 6 || l.1 == 6), true);
        }
        // Validation rejects out-of-mesh and non-existent links.
        assert!(FaultPlan::parse("die_down:9@1").unwrap().validate(&mesh).is_err());
        assert!(FaultPlan::parse("link_down:0-7@1").unwrap().validate(&mesh).is_err());
        let single = DeviceMesh::n150(1, 1).unwrap();
        assert!(FaultPlan::parse("die_down:0@1").unwrap().validate(&single).is_err());
    }

    #[test]
    fn retry_penalty_is_deterministic_and_bounded() {
        let p = FaultPlan { seed: 42, events: Vec::new() };
        let a = p.retry_penalty_ns(2, 0);
        let b = p.retry_penalty_ns(2, 0);
        assert_eq!(a, b, "same seed + draw => same penalty");
        assert!(a > 0.0);
        // Bounded: detection + at most RETRY_MAX backed-off retries per link.
        let mut worst_one = RETRY_TIMEOUT_NS;
        let mut step = RETRY_TIMEOUT_NS;
        for _ in 0..RETRY_MAX {
            step *= RETRY_BACKOFF;
            worst_one += step;
        }
        assert!(a <= 2.0 * worst_one + 1e-9);
        // Different draws decorrelate (distinct fault occurrences).
        assert!(p.retry_penalty_ns(1, 1) > 0.0);
        // Corruption magnitude is deterministic and large.
        assert_eq!(p.sdc_magnitude(20), p.sdc_magnitude(20));
        assert!(p.sdc_magnitude(20) >= 1.0e3);
    }
}
