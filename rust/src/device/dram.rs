//! Device DRAM model (§3): 24 GB of GDDR6 behind the NoC, with the §3.3
//! alignment rules (reads 32B-aligned, writes 16B-aligned) enforced, and
//! byte counters feeding the bandwidth model.

use crate::arch::constants::{DRAM_READ_ALIGN, DRAM_WRITE_ALIGN};
use crate::error::{Result, SimError};

/// Byte-addressable device DRAM with alignment checking.
///
/// Values are stored as f32 words for the numeric path; the capacity checks
/// use the element count times the *nominal* data-format width so BF16
/// problems see BF16 footprints.
#[derive(Debug)]
pub struct Dram {
    capacity_bytes: u64,
    /// Backing store, sparsely grown. Keyed by nominal byte offset.
    data: Vec<f32>,
    /// Nominal bytes per stored element (2 for BF16, 4 for FP32).
    elem_bytes: usize,
    pub bytes_read: u64,
    pub bytes_written: u64,
}

impl Dram {
    pub fn new(capacity_bytes: u64, elem_bytes: usize) -> Self {
        Self {
            capacity_bytes,
            data: Vec::new(),
            elem_bytes,
            bytes_read: 0,
            bytes_written: 0,
        }
    }

    fn check(&self, what: &'static str, offset: u64, len_bytes: usize, align: usize) -> Result<()> {
        if offset % align as u64 != 0 {
            return Err(SimError::Misaligned {
                what,
                value: offset as usize,
                align,
            });
        }
        if offset + len_bytes as u64 > self.capacity_bytes {
            return Err(SimError::DramRange {
                offset,
                len: len_bytes,
                capacity: self.capacity_bytes,
            });
        }
        Ok(())
    }

    /// Write `values` at nominal byte `offset` (16B-aligned, §3.3).
    pub fn write(&mut self, offset: u64, values: &[f32]) -> Result<()> {
        let len_bytes = values.len() * self.elem_bytes;
        self.check("DRAM write", offset, len_bytes, DRAM_WRITE_ALIGN)?;
        let start = offset as usize / self.elem_bytes;
        if self.data.len() < start + values.len() {
            self.data.resize(start + values.len(), 0.0);
        }
        self.data[start..start + values.len()].copy_from_slice(values);
        self.bytes_written += len_bytes as u64;
        Ok(())
    }

    /// Read `count` elements from nominal byte `offset` (32B-aligned, §3.3).
    pub fn read(&mut self, offset: u64, count: usize) -> Result<Vec<f32>> {
        let len_bytes = count * self.elem_bytes;
        self.check("DRAM read", offset, len_bytes, DRAM_READ_ALIGN)?;
        let start = offset as usize / self.elem_bytes;
        let mut out = vec![0.0f32; count];
        let have = self.data.len().saturating_sub(start).min(count);
        out[..have].copy_from_slice(&self.data[start..start + have]);
        self.bytes_read += len_bytes as u64;
        Ok(out)
    }

    pub fn capacity(&self) -> u64 {
        self.capacity_bytes
    }

    pub fn reset_counters(&mut self) {
        self.bytes_read = 0;
        self.bytes_written = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let mut d = Dram::new(1 << 20, 4);
        let vals: Vec<f32> = (0..256).map(|i| i as f32).collect();
        d.write(1024, &vals).unwrap();
        let back = d.read(1024, 256).unwrap();
        assert_eq!(back, vals);
        assert_eq!(d.bytes_written, 1024);
        assert_eq!(d.bytes_read, 1024);
    }

    #[test]
    fn alignment_rules_match_section_3_3() {
        let mut d = Dram::new(1 << 20, 4);
        // Writes: 16B alignment. Offset 16 is fine, 8 is not.
        assert!(d.write(16, &[1.0; 4]).is_ok());
        assert!(matches!(
            d.write(8, &[1.0; 4]),
            Err(SimError::Misaligned { align: 16, .. })
        ));
        // Reads: 32B alignment. Offset 16 is NOT fine.
        assert!(d.read(32, 8).is_ok());
        assert!(matches!(
            d.read(16, 8),
            Err(SimError::Misaligned { align: 32, .. })
        ));
    }

    #[test]
    fn capacity_enforced() {
        let mut d = Dram::new(64, 4);
        assert!(matches!(
            d.write(0, &[0.0; 32]),
            Err(SimError::DramRange { .. })
        ));
    }

    #[test]
    fn unwritten_reads_zero() {
        let mut d = Dram::new(1 << 16, 2);
        assert_eq!(d.read(0, 4).unwrap(), vec![0.0; 4]);
    }
}
