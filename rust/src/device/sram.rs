//! Per-core L1 SRAM capacity model (§3, §7.2).
//!
//! A bump allocator with named regions and 16B alignment (§3.3). The
//! allocator is how the paper's maximum-problem-size ceilings arise: the
//! solver asks for program/stack/CB reservations and then as many tile
//! slots as fit (tested against §7.2's 64 FP32 / 164 BF16 tiles per core).

use crate::arch::constants::{L1_ALIGN, SRAM_BYTES};
use crate::error::{Result, SimError};

#[derive(Debug, Clone)]
pub struct Allocation {
    pub name: String,
    pub offset: usize,
    pub len: usize,
}

/// L1 SRAM of one Tensix core.
#[derive(Debug, Clone)]
pub struct Sram {
    capacity: usize,
    cursor: usize,
    allocations: Vec<Allocation>,
    core_label: String,
}

impl Sram {
    pub fn new(core_label: &str) -> Self {
        Self::with_capacity(core_label, SRAM_BYTES)
    }

    pub fn with_capacity(core_label: &str, capacity: usize) -> Self {
        Self {
            capacity,
            cursor: 0,
            allocations: Vec::new(),
            core_label: core_label.to_string(),
        }
    }

    fn align_up(x: usize, align: usize) -> usize {
        x.div_ceil(align) * align
    }

    /// Allocate `len` bytes aligned to L1_ALIGN; returns the offset.
    pub fn alloc(&mut self, name: &str, len: usize) -> Result<usize> {
        let start = Self::align_up(self.cursor, L1_ALIGN);
        let end = start.checked_add(len).ok_or(SimError::Other(
            "SRAM allocation size overflow".to_string(),
        ))?;
        if end > self.capacity {
            return Err(SimError::SramExhausted {
                core: self.core_label.clone(),
                requested: len,
                available: self.capacity.saturating_sub(start),
                capacity: self.capacity,
            });
        }
        self.cursor = end;
        self.allocations.push(Allocation {
            name: name.to_string(),
            offset: start,
            len,
        });
        Ok(start)
    }

    pub fn used(&self) -> usize {
        self.cursor
    }

    pub fn free(&self) -> usize {
        self.capacity - Self::align_up(self.cursor, L1_ALIGN).min(self.capacity)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn allocations(&self) -> &[Allocation] {
        &self.allocations
    }

    /// Release everything (used between experiment phases; real tt-metal
    /// frees per-program).
    pub fn reset(&mut self) {
        self.cursor = 0;
        self.allocations.clear();
    }

    /// How many tile slots of `tile_bytes` fit after reserving
    /// `reserve_bytes` for program/stack/CBs — the §7.2 capacity question.
    pub fn max_tiles(&self, reserve_bytes: usize, tile_bytes: usize) -> usize {
        self.capacity.saturating_sub(reserve_bytes) / tile_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::constants::{
        PCG_VECTORS_FUSED, PCG_VECTORS_SPLIT, SRAM_RESERVE_FUSED, SRAM_RESERVE_SPLIT,
    };
    use crate::arch::DataFormat;

    #[test]
    fn alloc_and_alignment() {
        let mut s = Sram::with_capacity("t", 1024);
        let a = s.alloc("a", 10).unwrap();
        let b = s.alloc("b", 10).unwrap();
        assert_eq!(a, 0);
        assert_eq!(b % L1_ALIGN, 0);
        assert!(b >= 10);
        assert_eq!(s.allocations().len(), 2);
    }

    #[test]
    fn exhaustion_reports_details() {
        let mut s = Sram::with_capacity("core(1,2)", 100);
        let err = s.alloc("big", 200).unwrap_err();
        match err {
            SimError::SramExhausted {
                core, requested, ..
            } => {
                assert_eq!(core, "core(1,2)");
                assert_eq!(requested, 200);
            }
            e => panic!("wrong error {e}"),
        }
    }

    #[test]
    fn reset_frees_everything() {
        let mut s = Sram::with_capacity("t", 4096);
        s.alloc("x", 1000).unwrap();
        assert!(s.used() > 0);
        s.reset();
        assert_eq!(s.used(), 0);
        s.alloc("y", 4000).unwrap();
    }

    #[test]
    fn paper_capacity_ceilings() {
        // §7.2 via the allocator itself.
        let s = Sram::new("t");
        let fp32_slot = PCG_VECTORS_SPLIT * DataFormat::Fp32.tile_bytes();
        assert_eq!(s.max_tiles(SRAM_RESERVE_SPLIT, fp32_slot), 64);
        let bf16_slot = PCG_VECTORS_FUSED * DataFormat::Bf16.tile_bytes();
        assert_eq!(s.max_tiles(SRAM_RESERVE_FUSED, bf16_slot), 164);
    }
}
