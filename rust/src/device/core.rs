//! A Tensix core (§3, Fig 1): local SRAM, circular buffers, and the five
//! baby RISC-V cores (2 NoC data movement + 3 compute-side movement/issue).
//! Compute-unit *values* are produced by [`crate::engine`]; compute-unit
//! *cycles* by [`crate::timing`]. The core object owns capacity and
//! staging state plus per-core activity counters for the profiler.

use std::collections::BTreeMap;

use crate::device::cb::CircularBuffer;
use crate::device::sram::Sram;
use crate::error::{Result, SimError};
use crate::tile::Tile;

/// Grid coordinate of a core (row, col) within the compute sub-grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Coord {
    pub row: usize,
    pub col: usize,
}

impl Coord {
    pub fn new(row: usize, col: usize) -> Self {
        Self { row, col }
    }

    /// Manhattan distance (the XY-routing hop count on a mesh).
    pub fn manhattan(self, other: Coord) -> usize {
        self.row.abs_diff(other.row) + self.col.abs_diff(other.col)
    }
}

impl std::fmt::Display for Coord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "({},{})", self.row, self.col)
    }
}

/// Per-core activity counters, aggregated by the profiler.
#[derive(Debug, Clone, Default)]
pub struct CoreCounters {
    pub tiles_unpacked: u64,
    pub tiles_packed: u64,
    pub fpu_ops: u64,
    pub sfpu_ops: u64,
    pub noc_sends: u64,
    pub noc_recvs: u64,
    pub bytes_sent: u64,
    pub bytes_received: u64,
    pub zero_fills: u64,
}

/// One Tensix compute core.
#[derive(Debug)]
pub struct TensixCore {
    pub coord: Coord,
    pub sram: Sram,
    /// Circular buffers by tt-metal-style index name ("cb_in0", ...).
    pub cbs: BTreeMap<String, CircularBuffer>,
    /// Named resident vectors: each is this core's column of tiles (§6.1).
    pub vectors: BTreeMap<String, Vec<Tile>>,
    pub counters: CoreCounters,
}

impl TensixCore {
    pub fn new(coord: Coord) -> Self {
        Self {
            coord,
            sram: Sram::new(&format!("core{coord}")),
            cbs: BTreeMap::new(),
            vectors: BTreeMap::new(),
            counters: CoreCounters::default(),
        }
    }

    /// Create a circular buffer, allocating its SRAM.
    pub fn create_cb(&mut self, name: &str, page_bytes: usize, num_pages: usize) -> Result<()> {
        let cb = CircularBuffer::new(name, page_bytes, num_pages);
        self.sram.alloc(&format!("cb:{name}"), cb.sram_bytes())?;
        self.cbs.insert(name.to_string(), cb);
        Ok(())
    }

    pub fn cb(&mut self, name: &str) -> Result<&mut CircularBuffer> {
        self.cbs
            .get_mut(name)
            .ok_or_else(|| SimError::Other(format!("no circular buffer '{name}'")))
    }

    /// Allocate and store a named vector of `tiles`, charging SRAM.
    pub fn alloc_vector(&mut self, name: &str, tiles: Vec<Tile>) -> Result<()> {
        let bytes: usize = tiles.iter().map(|t| t.bytes()).sum();
        self.sram.alloc(&format!("vec:{name}"), bytes)?;
        self.vectors.insert(name.to_string(), tiles);
        Ok(())
    }

    pub fn vector(&self, name: &str) -> Result<&Vec<Tile>> {
        self.vectors
            .get(name)
            .ok_or_else(|| SimError::Other(format!("no vector '{name}' on core {}", self.coord)))
    }

    pub fn vector_mut(&mut self, name: &str) -> Result<&mut Vec<Tile>> {
        self.vectors
            .get_mut(name)
            .ok_or_else(|| SimError::Other(format!("no vector '{name}' on core {}", self.coord)))
    }

    /// Drop all program state (between experiments).
    pub fn reset(&mut self) {
        self.sram.reset();
        self.cbs.clear();
        self.vectors.clear();
        self.counters = CoreCounters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DataFormat;
    use crate::tile::{Tile, TileShape};

    #[test]
    fn coord_math() {
        let a = Coord::new(1, 2);
        let b = Coord::new(4, 0);
        assert_eq!(a.manhattan(b), 5);
        assert_eq!(a.to_string(), "(1,2)");
    }

    #[test]
    fn cb_creation_charges_sram() {
        let mut core = TensixCore::new(Coord::new(0, 0));
        let before = core.sram.free();
        core.create_cb("cb_in0", 2048, 4).unwrap();
        assert_eq!(before - core.sram.free(), 2048 * 4);
        assert!(core.cb("cb_in0").is_ok());
        assert!(core.cb("nope").is_err());
    }

    #[test]
    fn vector_storage_charges_sram() {
        let mut core = TensixCore::new(Coord::new(0, 0));
        let tiles: Vec<Tile> = (0..4)
            .map(|_| Tile::zeros(TileShape::STENCIL, DataFormat::Bf16))
            .collect();
        let before = core.sram.free();
        core.alloc_vector("x", tiles).unwrap();
        assert_eq!(before - core.sram.free(), 4 * 2048);
        assert_eq!(core.vector("x").unwrap().len(), 4);
    }

    #[test]
    fn sram_exhaustion_propagates() {
        let mut core = TensixCore::new(Coord::new(0, 0));
        // 164 BF16 tiles × 5 vectors > 1.5MB must fail.
        for v in 0..5 {
            let tiles: Vec<Tile> = (0..164)
                .map(|_| Tile::zeros(TileShape::STENCIL, DataFormat::Bf16))
                .collect();
            let r = core.alloc_vector(&format!("v{v}"), tiles);
            if v < 4 {
                assert!(r.is_ok(), "vector {v} should fit");
            } else {
                assert!(r.is_err(), "fifth 164-tile vector must not fit");
            }
        }
    }

    #[test]
    fn reset_clears_state() {
        let mut core = TensixCore::new(Coord::new(2, 3));
        core.create_cb("cb", 2048, 2).unwrap();
        core.counters.fpu_ops = 10;
        core.reset();
        assert!(core.cbs.is_empty());
        assert_eq!(core.counters.fpu_ops, 0);
        assert_eq!(core.sram.used(), 0);
    }
}
