//! Circular buffers (§3.2): statically-allocated SRAM FIFO queues that
//! stage tiles between the NoC cores, the unpacker/packer, and the compute
//! units, and synchronize the five baby RISC-V cores.
//!
//! The API mirrors tt-metal: `reserve_back` / `push_back` on the producer
//! side, `wait_front` / `pop_front` on the consumer side. We additionally
//! model the paper's extension (§6.2): manual increment/decrement of the
//! read pointer in multiples of 32B, used to construct shifted stencil
//! tiles without data movement.

use crate::arch::constants::CB_PTR_ALIGN;
use crate::error::{Result, SimError};
use crate::tile::Tile;

/// A FIFO of tile pages in SRAM.
#[derive(Debug, Clone)]
pub struct CircularBuffer {
    pub name: String,
    /// Bytes per page (one tile at the CB's data format).
    pub page_bytes: usize,
    /// Capacity in pages.
    pub num_pages: usize,
    /// In-flight pages (reserved but not yet pushed).
    reserved: usize,
    /// Queue of resident tiles (front = oldest).
    queue: std::collections::VecDeque<Tile>,
    /// Read-pointer displacement in bytes (the §6.2 extension). Applied to
    /// the *front* tile when it is consumed via [`front_shifted`].
    read_ptr_offset: isize,
    /// Statistics for the profiler.
    pub total_pushes: u64,
    pub total_pops: u64,
}

impl CircularBuffer {
    pub fn new(name: &str, page_bytes: usize, num_pages: usize) -> Self {
        assert!(num_pages > 0, "CB needs at least one page");
        Self {
            name: name.to_string(),
            page_bytes,
            num_pages,
            reserved: 0,
            queue: std::collections::VecDeque::new(),
            read_ptr_offset: 0,
            total_pushes: 0,
            total_pops: 0,
        }
    }

    pub fn sram_bytes(&self) -> usize {
        self.page_bytes * self.num_pages
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Producer: reserve space for `pages` pages, failing (in real hardware,
    /// blocking) if the FIFO cannot hold them.
    pub fn reserve_back(&mut self, pages: usize) -> Result<()> {
        let pending = self.queue.len() + self.reserved + pages;
        if pending > self.num_pages {
            return Err(SimError::CbOverflow {
                name: self.name.clone(),
                capacity: self.num_pages,
                pending,
            });
        }
        self.reserved += pages;
        Ok(())
    }

    /// Producer: publish a tile into previously reserved space.
    pub fn push_back(&mut self, tile: Tile) -> Result<()> {
        if self.reserved == 0 {
            // tt-metal requires reserve before push; we enforce it.
            return Err(SimError::CbOverflow {
                name: self.name.clone(),
                capacity: self.num_pages,
                pending: self.queue.len() + 1,
            });
        }
        self.reserved -= 1;
        self.queue.push_back(tile);
        self.total_pushes += 1;
        Ok(())
    }

    /// Consumer: access the front tile (wait_front in tt-metal).
    pub fn wait_front(&self) -> Result<&Tile> {
        self.queue.front().ok_or_else(|| SimError::CbUnderflow {
            name: self.name.clone(),
        })
    }

    /// Consumer: remove the front tile.
    pub fn pop_front(&mut self) -> Result<Tile> {
        let t = self.queue.pop_front().ok_or_else(|| SimError::CbUnderflow {
            name: self.name.clone(),
        })?;
        self.total_pops += 1;
        self.read_ptr_offset = 0; // pointer games do not survive a pop
        Ok(t)
    }

    /// §6.2 extension: displace the read pointer by `delta` bytes (multiple
    /// of 32B; positive = increment). The displacement is interpreted in
    /// whole rows of the front tile when consumed via [`front_shifted`].
    pub fn shift_read_ptr(&mut self, delta: isize) -> Result<()> {
        if delta % CB_PTR_ALIGN as isize != 0 {
            return Err(SimError::CbPtrAlign {
                name: self.name.clone(),
                delta,
                align: CB_PTR_ALIGN,
            });
        }
        self.read_ptr_offset += delta;
        Ok(())
    }

    pub fn read_ptr_offset(&self) -> isize {
        self.read_ptr_offset
    }

    /// Consume the front tile through the displaced read pointer: the copy
    /// operation the paper uses to build N/S shifted tiles. Returns the
    /// shifted tile and the row indices that fell outside the original
    /// tile (to be halo-filled by the caller).
    pub fn front_shifted(&self) -> Result<(Tile, Vec<usize>)> {
        let front = self.wait_front()?;
        let row_bytes = front.shape.cols * front.df.bytes();
        debug_assert_eq!(row_bytes, CB_PTR_ALIGN * row_bytes / CB_PTR_ALIGN);
        let offset_rows = self.read_ptr_offset / row_bytes as isize;
        Ok(crate::tile::shift::pointer_row_shift(front, offset_rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DataFormat;
    use crate::tile::{Tile, TileShape};

    fn tile(v: f32) -> Tile {
        Tile::from_vec(TileShape::STENCIL, DataFormat::Bf16, vec![v; 1024])
    }

    #[test]
    fn fifo_semantics() {
        let mut cb = CircularBuffer::new("cb0", 2048, 2);
        cb.reserve_back(1).unwrap();
        cb.push_back(tile(1.0)).unwrap();
        cb.reserve_back(1).unwrap();
        cb.push_back(tile(2.0)).unwrap();
        assert_eq!(cb.len(), 2);
        assert_eq!(cb.wait_front().unwrap().get(0, 0), 1.0);
        assert_eq!(cb.pop_front().unwrap().get(0, 0), 1.0);
        assert_eq!(cb.pop_front().unwrap().get(0, 0), 2.0);
        assert!(cb.is_empty());
        assert_eq!(cb.total_pushes, 2);
        assert_eq!(cb.total_pops, 2);
    }

    #[test]
    fn overflow_and_underflow() {
        let mut cb = CircularBuffer::new("cb0", 2048, 1);
        cb.reserve_back(1).unwrap();
        assert!(matches!(
            cb.reserve_back(1),
            Err(SimError::CbOverflow { .. })
        ));
        cb.push_back(tile(1.0)).unwrap();
        assert!(matches!(cb.reserve_back(1), Err(SimError::CbOverflow { .. })));
        cb.pop_front().unwrap();
        assert!(matches!(cb.pop_front(), Err(SimError::CbUnderflow { .. })));
    }

    #[test]
    fn push_without_reserve_rejected() {
        let mut cb = CircularBuffer::new("cb0", 2048, 4);
        assert!(cb.push_back(tile(1.0)).is_err());
    }

    #[test]
    fn pointer_shift_alignment_enforced() {
        let mut cb = CircularBuffer::new("cb0", 2048, 2);
        // §6.2: pointers move in multiples of 32B only.
        assert!(matches!(
            cb.shift_read_ptr(33),
            Err(SimError::CbPtrAlign { .. })
        ));
        cb.shift_read_ptr(32).unwrap();
        cb.shift_read_ptr(-64).unwrap();
        assert_eq!(cb.read_ptr_offset(), -32);
    }

    #[test]
    fn front_shifted_builds_north_tile() {
        let mut cb = CircularBuffer::new("cb0", 2048, 1);
        let t = Tile::from_fn(TileShape::STENCIL, DataFormat::Bf16, |r, _| r as f32);
        cb.reserve_back(1).unwrap();
        cb.push_back(t.clone()).unwrap();
        // One 32B row decrement = north shift for a BF16 64×16 tile.
        cb.shift_read_ptr(-32).unwrap();
        let (shifted, missing) = cb.front_shifted().unwrap();
        assert_eq!(missing, vec![0]);
        assert_eq!(shifted.get(5, 0), 4.0);
        // Pop resets the pointer.
        cb.pop_front().unwrap();
        assert_eq!(cb.read_ptr_offset(), 0);
    }
}
