//! Fig 5: weak scaling of the dot product — granularity method 1 (reduce
//! to scalar per core) vs method 2 (reduce only at the root), SFPU FP32,
//! 64 tiles per core, naive routing.

use crate::kernels::reduction::{run_dot, DotConfig, DotMethod};
use crate::noc::RoutePattern;
use crate::solver::{dist_random, Problem};
use crate::util::csv::CsvWriter;
use crate::util::stats::fmt_ns;
use crate::util::table::Table;

use super::{ExpContext, GRID_LADDER};

pub fn run(ctx: &ExpContext) -> crate::Result<()> {
    let tiles = 64;
    let mut table = Table::new(
        "Fig 5 — Dot-product weak scaling (SFPU FP32, 64 tiles/core, naive routing)",
        &["grid", "cores", "method 1 (scalar)", "method 2 (tiles)", "m1 vs m2"],
    );
    let mut csv = CsvWriter::new(&["grid", "cores", "m1_ns", "m2_ns", "m1_advantage_pct"]);

    for (r, c) in GRID_LADDER {
        let p = Problem::new(r, c, tiles, crate::arch::DataFormat::Fp32);
        let a = dist_random(&p, ctx.seed);
        let b = dist_random(&p, ctx.seed + 1);
        let mut out = Vec::new();
        for method in [DotMethod::ReduceThenSend, DotMethod::SendTiles] {
            let cfg = DotConfig::paper_section5(method, RoutePattern::Naive, tiles);
            out.push(run_dot(r, c, &cfg, &a, &b, ctx.engine.as_ref(), &ctx.cost)?);
        }
        let adv = 100.0 * (out[1].total_ns - out[0].total_ns) / out[1].total_ns;
        table.row(vec![
            format!("{r}x{c}"),
            format!("{}", r * c),
            fmt_ns(out[0].total_ns),
            fmt_ns(out[1].total_ns),
            format!("{adv:+.1}%"),
        ]);
        csv.row(&[
            format!("{r}x{c}"),
            format!("{}", r * c),
            format!("{:.1}", out[0].total_ns),
            format!("{:.1}", out[1].total_ns),
            format!("{adv:.2}"),
        ]);
    }

    println!("{}", table.render());
    println!("paper shape: methods within a few percent, method 1 slightly ahead at scale (1.8% at 8x7), converging at 1x1 (§5.1)\n");
    ctx.save_csv("fig5_dot_weak_scaling", &csv);
    Ok(())
}
