//! Tables 1–3 runners.

use crate::arch::constants::*;
use crate::arch::specs::ALL_SPECS;
use crate::arch::DataFormat;
use crate::baseline::H100Model;
use crate::kernels::DotMethod;
use crate::noc::RoutePattern;
use crate::profiler::Profiler;
use crate::solver::{self, PcgOptions, PcgVariant, Problem};
use crate::util::csv::CsvWriter;
use crate::util::table::Table;

use super::ExpContext;

/// Table 1: single-cycle capabilities of the Wormhole FPU.
pub fn run_t1(ctx: &ExpContext) -> crate::Result<()> {
    let mut t = Table::new(
        "Table 1 — Single-cycle capabilities of the Wormhole FPU",
        &["operation", "size"],
    );
    t.row(vec![
        "Matrix Multiply".into(),
        format!(
            "{}x{} x {}x{} = {}x{}",
            FPU_MATMUL_SHAPE.0 .0,
            FPU_MATMUL_SHAPE.0 .1,
            FPU_MATMUL_SHAPE.1 .0,
            FPU_MATMUL_SHAPE.1 .1,
            FPU_MATMUL_SHAPE.0 .0,
            FPU_MATMUL_SHAPE.0 .1
        ),
    ]);
    t.row(vec!["Reduction".into(), format!("{FACE}x{FACE}")]);
    t.row(vec!["Element-wise Add/Sub/Mul".into(), "8x16".into()]);
    println!("{}", t.render());
    let mut csv = CsvWriter::new(&["operation", "size"]);
    csv.row(&["matmul".into(), "8x16 x 16x16 = 8x16".into()]);
    csv.row(&["reduction".into(), "16x16".into()]);
    csv.row(&["eltwise".into(), "8x16".into()]);
    ctx.save_csv("table1_fpu", &csv);
    Ok(())
}

/// Table 2: accelerator characteristics.
pub fn run_t2(ctx: &ExpContext) -> crate::Result<()> {
    let mut t = Table::new(
        "Table 2 — Accelerator characteristics",
        &[
            "spec", "vendor", "TDP (W)", "node", "mem BW (GB/s)", "memory", "FP8", "FP16", "FP32",
        ],
    );
    let mut csv = CsvWriter::new(&[
        "name", "vendor", "tdp_w", "node", "mem_bw_gbs", "memory", "fp8_tflops", "fp16_tflops",
        "fp32_tflops",
    ]);
    for s in ALL_SPECS {
        t.row(vec![
            s.name.into(),
            s.vendor.into(),
            format!("{:.0}", s.tdp_w),
            s.process_node.into(),
            format!("{:.0}", s.peak_mem_bw_gbs),
            s.memory.into(),
            format!("{:.0}", s.fp8_tflops),
            format!("{:.1}", s.fp16_tflops),
            format!("{:.1}", s.fp32_tflops),
        ]);
        csv.row(&[
            s.name.to_string(),
            s.vendor.to_string(),
            format!("{}", s.tdp_w),
            s.process_node.to_string(),
            format!("{}", s.peak_mem_bw_gbs),
            s.memory.to_string(),
            format!("{}", s.fp8_tflops),
            format!("{}", s.fp16_tflops),
            format!("{}", s.fp32_tflops),
        ]);
    }
    println!("{}", t.render());
    ctx.save_csv("table2_specs", &csv);
    Ok(())
}

/// Table 3: PCG time/iteration for the 512×112×64 grid — H100 model vs
/// simulated Wormhole BF16 and FP32 on 8×7 cores, 64 tiles/core.
pub fn run_t3(ctx: &ExpContext) -> crate::Result<()> {
    let mut t = Table::new(
        "Table 3 — PCG time per iteration, 512x112x64 grid (8x7 cores, 64 tiles/core)",
        &["implementation", "time/iter (ms)", "paper (ms)", "vs paper"],
    );
    let mut csv = CsvWriter::new(&["implementation", "iter_ms", "paper_ms", "rel_err_pct"]);

    let emit = |t: &mut Table, csv: &mut CsvWriter, name: &str, ms: f64, paper: f64| {
        let rel = 100.0 * (ms - paper) / paper;
        t.row(vec![
            name.into(),
            format!("{ms:.2}"),
            format!("{paper:.2}"),
            format!("{rel:+.0}%"),
        ]);
        csv.row(&[
            name.to_string(),
            format!("{ms:.4}"),
            format!("{paper:.2}"),
            format!("{rel:.1}"),
        ]);
    };

    // H100 analytic model.
    let n = 512 * 112 * 64;
    let h100 = H100Model::default().cg_iteration(n);
    emit(&mut t, &mut csv, "H100", h100.total_ns / 1e6, 0.28);

    // Wormhole variants (simulated).
    for (variant, paper_ms) in [(PcgVariant::FusedBf16, 1.20), (PcgVariant::SplitFp32, 2.45)] {
        let p = Problem::new(8, 7, 64, variant.df());
        let grid = p.make_grid()?;
        let b = solver::dist_random(&p, ctx.seed);
        let mut opts = PcgOptions::new(variant);
        opts.max_iters = ctx.pcg_iters;
        opts.tol_abs = 0.0;
        opts.dot_method = DotMethod::ReduceThenSend;
        opts.dot_pattern = RoutePattern::Naive;
        let mut prof = Profiler::disabled();
        let res = solver::solve(&grid, &p, &b, ctx.engine.as_ref(), &ctx.cost, &opts, &mut prof)?;
        let label = match variant {
            PcgVariant::FusedBf16 => "Wormhole BF16",
            PcgVariant::SplitFp32 => "Wormhole FP32",
        };
        emit(&mut t, &mut csv, label, res.per_iter_ns / 1e6, paper_ms);
    }

    println!("{}", t.render());
    println!("paper: H100 0.28, Wormhole BF16 1.20, Wormhole FP32 2.45 ms/iter (Table 3)\n");
    ctx.save_csv("table3_pcg", &csv);
    let _ = DataFormat::Bf16; // (used via variants)
    Ok(())
}
