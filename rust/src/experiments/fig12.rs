//! Fig 12: PCG scaling on the simulated Wormhole.
//!
//! (a) strong scaling, FP32 split-kernel, fixed 64×16-tile problem (64
//!     tiles/core at the smallest 4×4 grid);
//! (b) strong scaling, BF16 fused-kernel, fixed 164×4-tile problem
//!     (671,744 elements of x; 164 tiles/core at 2×2);
//! (c) weak scaling at the §7.2 maximum problem size per core (FP32: 64
//!     tiles, BF16: 164 tiles), normalized per tile.
//!
//! Strong-scaling note: the paper's layout assigns each core a column of
//! tiles; redistributing a fixed tile count across more cores gives
//! `ceil(total / cores)` tiles per core (the last fraction of a tile is
//! padded). Timing depends only on (grid, tiles/core), which this captures
//! exactly.

use crate::kernels::DotMethod;
use crate::noc::RoutePattern;
use crate::profiler::Profiler;
use crate::solver::{self, PcgOptions, PcgVariant, Problem};
use crate::util::csv::CsvWriter;
use crate::util::stats::fmt_ns;
use crate::util::table::Table;

use super::{ExpContext, GRID_LADDER};

/// Per-iteration PCG time for one configuration.
fn pcg_iter_ns(
    ctx: &ExpContext,
    grid: (usize, usize),
    tiles: usize,
    variant: PcgVariant,
) -> crate::Result<f64> {
    let p = Problem::new(grid.0, grid.1, tiles, variant.df());
    let g = p.make_grid()?;
    let b = solver::dist_random(&p, ctx.seed);
    let mut opts = PcgOptions::new(variant);
    opts.max_iters = ctx.pcg_iters;
    opts.tol_abs = 0.0; // run exactly max_iters for stable timing
    opts.dot_method = DotMethod::ReduceThenSend;
    opts.dot_pattern = RoutePattern::Naive;
    let mut prof = Profiler::disabled();
    let res = solver::solve(&g, &p, &b, ctx.engine.as_ref(), &ctx.cost, &opts, &mut prof)?;
    Ok(res.per_iter_ns)
}

fn strong_scaling(
    ctx: &ExpContext,
    title: &str,
    csv_name: &str,
    variant: PcgVariant,
    total_tiles: usize,
    grids: &[(usize, usize)],
) -> crate::Result<()> {
    let mut table = Table::new(title, &["grid", "cores", "tiles/core", "time/iter", "speedup", "efficiency"]);
    let mut csv = CsvWriter::new(&["grid", "cores", "tiles_per_core", "iter_ns", "speedup", "efficiency"]);
    let mut base: Option<(usize, f64)> = None; // (cores, iter_ns)
    for &(r, c) in grids {
        let cores = r * c;
        let tiles = total_tiles.div_ceil(cores);
        let ns = pcg_iter_ns(ctx, (r, c), tiles, variant)?;
        let (c0, n0) = *base.get_or_insert((cores, ns));
        let speedup = n0 / ns;
        let eff = speedup / (cores as f64 / c0 as f64);
        table.row(vec![
            format!("{r}x{c}"),
            format!("{cores}"),
            format!("{tiles}"),
            fmt_ns(ns),
            format!("{speedup:.2}x"),
            format!("{:.0}%", eff * 100.0),
        ]);
        csv.row(&[
            format!("{r}x{c}"),
            format!("{cores}"),
            format!("{tiles}"),
            format!("{ns:.1}"),
            format!("{speedup:.3}"),
            format!("{eff:.3}"),
        ]);
    }
    println!("{}", table.render());
    ctx.save_csv(csv_name, &csv);
    Ok(())
}

/// Fig 12a: FP32 strong scaling, 64×16 tiles (1024 tiles ⇒ 64/core at 4×4).
pub fn run_strong_fp32(ctx: &ExpContext) -> crate::Result<()> {
    strong_scaling(
        ctx,
        "Fig 12a — PCG strong scaling, FP32 split-kernel (fixed 64x16-tile problem)",
        "fig12a_strong_fp32",
        PcgVariant::SplitFp32,
        64 * 16,
        &[(4, 4), (4, 6), (6, 6), (6, 7), (8, 7)],
    )?;
    println!("paper shape: good strong scaling with slight irregularity (§7.2)\n");
    Ok(())
}

/// Fig 12b: BF16 strong scaling, 164×4 tiles (671,744 elements; 164/core at 2×2).
pub fn run_strong_bf16(ctx: &ExpContext) -> crate::Result<()> {
    strong_scaling(
        ctx,
        "Fig 12b — PCG strong scaling, BF16 fused-kernel (fixed 164x4-tile problem)",
        "fig12b_strong_bf16",
        PcgVariant::FusedBf16,
        164 * 4,
        &[(2, 2), (4, 4), (6, 6), (8, 7)],
    )?;
    println!("paper shape: the FPU implementation scales well strongly (§7.2)\n");
    Ok(())
}

/// Fig 12c: weak scaling at max problem size per core, normalized per tile.
pub fn run_weak(ctx: &ExpContext) -> crate::Result<()> {
    let mut table = Table::new(
        "Fig 12c — PCG weak scaling at max size/core (normalized per tile)",
        &["grid", "cores", "FP32 64t (ns/tile)", "BF16 164t (ns/tile)", "fp32/bf16"],
    );
    let mut csv = CsvWriter::new(&[
        "grid", "cores", "fp32_iter_ns", "fp32_ns_per_tile", "bf16_iter_ns", "bf16_ns_per_tile",
        "ratio",
    ]);
    for (r, c) in GRID_LADDER {
        let fp32 = pcg_iter_ns(ctx, (r, c), 64, PcgVariant::SplitFp32)?;
        let bf16 = pcg_iter_ns(ctx, (r, c), 164, PcgVariant::FusedBf16)?;
        let fp32_pt = fp32 / 64.0;
        let bf16_pt = bf16 / 164.0;
        table.row(vec![
            format!("{r}x{c}"),
            format!("{}", r * c),
            format!("{fp32_pt:.0}"),
            format!("{bf16_pt:.0}"),
            format!("{:.2}x", fp32_pt / bf16_pt),
        ]);
        csv.row(&[
            format!("{r}x{c}"),
            format!("{}", r * c),
            format!("{fp32:.1}"),
            format!("{fp32_pt:.2}"),
            format!("{bf16:.1}"),
            format!("{bf16_pt:.2}"),
            format!("{:.3}", fp32_pt / bf16_pt),
        ]);
    }
    println!("{}", table.render());
    println!("paper shape: both weak scale well; SFPU/FP32 ≈2x slower than FPU/BF16 per problem size (§7.2)\n");
    ctx.save_csv("fig12c_weak", &csv);
    Ok(())
}
