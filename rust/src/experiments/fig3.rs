//! Fig 3: single-core roofline for 16-bit element-wise addition, with the
//! FPU and SFPU implementation variants at 256 tiles per core (262,144
//! elements).

use crate::arch::{ComputeUnit, DataFormat};
use crate::kernels::eltwise::eltwise_stream_timing;
use crate::util::csv::CsvWriter;
use crate::util::stats::fmt_ns;
use crate::util::table::Table;

use super::ExpContext;

pub fn run(ctx: &ExpContext) -> crate::Result<()> {
    let cost = &ctx.cost;
    let tiles = 256; // the paper's Fig-3 data points
    let df = DataFormat::Bf16;

    let mut table = Table::new(
        "Fig 3 — Roofline, 16-bit eltwise add (single Tensix core, 256 tiles)",
        &["variant", "AI (FLOP/B)", "GFLOP/s", "cycles/tile", "roofline bound", "% of bound", "core time"],
    );
    let mut csv = CsvWriter::new(&[
        "variant", "ai_flop_per_byte", "gflops", "cycles_per_tile", "bw_bound_gflops",
        "pct_of_bound", "core_ns", "dram_ns",
    ]);

    for unit in [ComputeUnit::Fpu, ComputeUnit::Sfpu] {
        let t = eltwise_stream_timing(cost, unit, df, tiles);
        let bound = (cost.sram_bw_gbs() * t.ai).min(cost.peak_gflops(unit, df));
        let pct = 100.0 * t.gflops / bound;
        table.row(vec![
            format!("{unit} BF16"),
            format!("{:.4}", t.ai),
            format!("{:.2}", t.gflops),
            format!("{}", t.cycles_per_tile),
            format!("{bound:.2}"),
            format!("{pct:.1}%"),
            fmt_ns(t.core_ns),
        ]);
        csv.row(&[
            format!("{unit}"),
            format!("{:.6}", t.ai),
            format!("{:.3}", t.gflops),
            format!("{}", t.cycles_per_tile),
            format!("{bound:.3}"),
            format!("{pct:.2}"),
            format!("{:.1}", t.core_ns),
            format!("{:.1}", t.dram_ns),
        ]);
    }

    // The roofline curve itself (for re-plotting): attainable = min(peak,
    // BW × AI) for each unit.
    let mut curve = CsvWriter::new(&["ai_flop_per_byte", "fpu_roof_gflops", "sfpu_roof_gflops"]);
    let mut ai = 1.0 / 64.0;
    while ai <= 16.0 {
        let bw = cost.sram_bw_gbs();
        let fpu = (bw * ai).min(cost.peak_gflops(ComputeUnit::Fpu, df));
        let sfpu = (bw * ai).min(cost.peak_gflops(ComputeUnit::Sfpu, df));
        curve.row(&[format!("{ai:.5}"), format!("{fpu:.3}"), format!("{sfpu:.3}")]);
        ai *= 2.0f64.sqrt();
    }

    println!("{}", table.render());
    println!(
        "paper shape: FPU near the BW roofline at AI=1/6; SFPU ≈6x slower at AI≈1/16 (§4)\n"
    );
    ctx.save_csv("fig3_points", &csv);
    ctx.save_csv("fig3_roofline", &curve);
    Ok(())
}
