//! Fig 11: weak scaling of the stencil/SpMV (BF16, FPU, 64 tiles/core),
//! with the ablation variants isolating the halo exchange and the
//! zero-fill boundary handling (§6.3).

use crate::kernels::stencil::{run_stencil, StencilConfig, StencilVariant};
use crate::solver::{dist_random, Problem};
use crate::util::csv::CsvWriter;
use crate::util::stats::fmt_ns;
use crate::util::table::Table;

use super::{ExpContext, GRID_LADDER};

pub fn run(ctx: &ExpContext) -> crate::Result<()> {
    let tiles = 64;
    let variants = [
        StencilVariant::FULL,
        StencilVariant::NO_HALO,
        StencilVariant::NO_ZERO_FILL,
        StencilVariant::NEITHER,
    ];
    let mut table = Table::new(
        "Fig 11 — Stencil weak scaling (BF16 FPU, 64 tiles/core)",
        &["grid", "cores", "full", "no halo", "no zero fill", "neither"],
    );
    let mut csv = CsvWriter::new(&[
        "grid", "cores", "variant", "iter_ns", "compute_ns", "halo_ns", "zero_fill_ns",
        "messages", "bytes",
    ]);

    for (r, c) in GRID_LADDER {
        let p = Problem::new(r, c, tiles, crate::arch::DataFormat::Bf16);
        let grid = p.make_grid()?;
        let x = dist_random(&p, ctx.seed);
        let mut cells = vec![format!("{r}x{c}"), format!("{}", r * c)];
        for v in variants {
            let cfg = StencilConfig::paper_fig11(tiles, v);
            let (_, t) = run_stencil(&grid, &cfg, &x, ctx.engine.as_ref(), &ctx.cost)?;
            cells.push(fmt_ns(t.iter_ns));
            csv.row(&[
                format!("{r}x{c}"),
                format!("{}", r * c),
                v.label().to_string(),
                format!("{:.1}", t.iter_ns),
                format!("{:.1}", t.compute_ns),
                format!("{:.1}", t.halo_ns),
                format!("{:.1}", t.zero_fill_ns),
                format!("{}", t.messages),
                format!("{}", t.bytes),
            ]);
        }
        table.row(cells);
    }

    println!("{}", table.render());
    println!("paper shape: near-perfect weak scaling; 1x1 (and mildly 2x2) elevated by zero-fill cost; 'neither' flat; halo exchange cheap relative to local compute (§6.3)\n");
    ctx.save_csv("fig11_stencil_weak_scaling", &csv);
    Ok(())
}
