//! Fig 6: center vs naive reduction routing — speedup as the problem size
//! (tiles per core) scales, method 2, SFPU FP32, plus the small-grid
//! series where the center pattern's routing-logic overhead makes the
//! speedup negative (§5.2).

use crate::kernels::reduction::{run_dot, DotConfig, DotMethod};
use crate::noc::RoutePattern;
use crate::solver::{dist_random, Problem};
use crate::util::csv::CsvWriter;
use crate::util::stats::fmt_ns;
use crate::util::table::Table;

use super::ExpContext;

pub fn run(ctx: &ExpContext) -> crate::Result<()> {
    let tile_sweep = [1usize, 2, 4, 8, 16, 32, 64, 128];
    let mut table = Table::new(
        "Fig 6 — Center-vs-naive routing speedup (method 2, SFPU FP32, 100-iter avg)",
        &["grid", "tiles/core", "naive", "center", "speedup"],
    );
    let mut csv = CsvWriter::new(&["grid", "tiles_per_core", "naive_ns", "center_ns", "speedup_pct"]);

    let run_pair = |r: usize, c: usize, tiles: usize| -> crate::Result<(f64, f64)> {
        let p = Problem::new(r, c, tiles, crate::arch::DataFormat::Fp32);
        let a = dist_random(&p, ctx.seed);
        let b = dist_random(&p, ctx.seed + 1);
        let naive = run_dot(
            r, c,
            &DotConfig::paper_section5(DotMethod::SendTiles, RoutePattern::Naive, tiles),
            &a, &b, ctx.engine.as_ref(), &ctx.cost,
        )?;
        let center = run_dot(
            r, c,
            &DotConfig::paper_section5(DotMethod::SendTiles, RoutePattern::Center, tiles),
            &a, &b, ctx.engine.as_ref(), &ctx.cost,
        )?;
        Ok((naive.total_ns, center.total_ns))
    };

    // Small grid first — the left of the paper's figure, where speedup is
    // negative because the routing-logic overhead outweighs the shorter
    // paths (§5.2).
    for (r, c, tiles) in [(2usize, 2usize, 1usize), (2, 2, 4)] {
        let (n, ce) = run_pair(r, c, tiles)?;
        let sp = 100.0 * (n - ce) / n;
        table.row(vec![
            format!("{r}x{c}"),
            format!("{tiles}"),
            fmt_ns(n),
            fmt_ns(ce),
            format!("{sp:+.1}%"),
        ]);
        csv.row(&[
            format!("{r}x{c}"),
            format!("{tiles}"),
            format!("{n:.1}"),
            format!("{ce:.1}"),
            format!("{sp:.2}"),
        ]);
    }

    // Full 8×7 grid across the tiles-per-core sweep.
    for tiles in tile_sweep {
        let (n, ce) = run_pair(8, 7, tiles)?;
        let sp = 100.0 * (n - ce) / n;
        table.row(vec![
            "8x7".to_string(),
            format!("{tiles}"),
            fmt_ns(n),
            fmt_ns(ce),
            format!("{sp:+.1}%"),
        ]);
        csv.row(&[
            "8x7".to_string(),
            format!("{tiles}"),
            format!("{n:.1}"),
            format!("{ce:.1}"),
            format!("{sp:.2}"),
        ]);
    }

    println!("{}", table.render());
    println!("paper shape: ~+15% at 1 tile/core on the full grid, negligible by 128 tiles/core, negative on the smallest grids (§5.2)\n");
    ctx.save_csv("fig6_routing_speedup", &csv);
    Ok(())
}
