//! Extension experiments beyond the paper's evaluation — the §8 future-work
//! items built into this reproduction:
//!
//! - `energy`:   TDP-proxy energy-to-solution comparison (§8: "energy-to-
//!               solution could be measured relatively accurately and would
//!               be a useful addition").
//! - `dualdie`:  PCG across both n300d dies over the Ethernet seam (§8:
//!               "future work should explore multi-device scaling").
//! - `jacobi`:   the Jacobi iterative method vs PCG — the Brown & Barton
//!               (§2) algorithm on this substrate.

use crate::arch::DataFormat;
use crate::baseline::{wormhole_utilization, EnergyModel, H100Model};
use crate::engine::CoreBlock;
use crate::kernels::DotMethod;
use crate::noc::RoutePattern;
use crate::profiler::Profiler;
use crate::solver::{
    self, solve_jacobi, solve_pcg_dualdie, DualDieOptions, JacobiOptions, PcgOptions, PcgVariant,
    Problem,
};
use crate::util::csv::CsvWriter;
use crate::util::prng::Rng;
use crate::util::stats::fmt_ns;
use crate::util::table::Table;

use super::ExpContext;

/// Energy-to-solution table: Table-3 configuration, per-iteration energy
/// and energy for a fixed-iteration solve.
pub fn run_energy(ctx: &ExpContext) -> crate::Result<()> {
    let iters = 100u64;
    let wh = EnergyModel::n150d();
    let gpu = EnergyModel::h100();
    let util = wormhole_utilization(8, 7);

    // Per-iteration times from the calibrated models/simulation.
    let h100_ns = H100Model::default().cg_iteration(512 * 112 * 64).total_ns;
    let mut rows: Vec<(String, f64, f64, f64)> = Vec::new(); // (name, ns/iter, W, mJ/iter)
    rows.push((
        "H100".into(),
        h100_ns,
        gpu.power_w(1.0),
        gpu.energy_per_iter_mj(h100_ns, 1.0),
    ));
    for (variant, label) in [
        (PcgVariant::FusedBf16, "Wormhole BF16 (n150d die)"),
        (PcgVariant::SplitFp32, "Wormhole FP32 (n150d die)"),
    ] {
        let p = Problem::new(8, 7, 64, variant.df());
        let grid = p.make_grid()?;
        let b = solver::dist_random(&p, ctx.seed);
        let mut opts = PcgOptions::new(variant);
        opts.max_iters = 1;
        opts.tol_abs = 0.0;
        opts.dot_method = DotMethod::ReduceThenSend;
        opts.dot_pattern = RoutePattern::Naive;
        let mut prof = Profiler::disabled();
        let res = solver::solve(&grid, &p, &b, ctx.engine.as_ref(), &ctx.cost, &opts, &mut prof)?;
        rows.push((
            label.into(),
            res.per_iter_ns,
            wh.power_w(util),
            wh.energy_per_iter_mj(res.per_iter_ns, util),
        ));
    }

    let mut table = Table::new(
        "Extension — energy-to-solution (TDP proxy, 512x112x64, 100 iterations)",
        &["implementation", "time/iter", "power (W)", "mJ/iter", "J/solve", "energy vs H100"],
    );
    let mut csv = CsvWriter::new(&["implementation", "iter_ns", "power_w", "mj_per_iter", "j_per_solve", "energy_ratio"]);
    let base_mj = rows[0].3;
    for (name, ns, w, mj) in &rows {
        table.row(vec![
            name.clone(),
            fmt_ns(*ns),
            format!("{w:.0}"),
            format!("{mj:.2}"),
            format!("{:.2}", mj * iters as f64 / 1e3),
            format!("{:.1}x", mj / base_mj),
        ]);
        csv.row(&[
            name.clone(),
            format!("{ns:.1}"),
            format!("{w:.1}"),
            format!("{mj:.4}"),
            format!("{:.4}", mj * iters as f64 / 1e3),
            format!("{:.3}", mj / base_mj),
        ]);
    }
    println!("{}", table.render());
    println!(
        "§7.3/§8 framing: the time gap (4.4x/9.1x) shrinks to a {:.1}x/{:.1}x energy gap at the\n\
         n150d's 160 W TDP vs the H100's 350 W — the power-relative view the paper argues for.\n",
        rows[1].3 / base_mj,
        rows[2].3 / base_mj
    );
    ctx.save_csv("ext_energy", &csv);
    Ok(())
}

/// Dual-die weak scaling: the same per-die load on one die vs two dies
/// joined by the Ethernet seam.
pub fn run_dualdie(ctx: &ExpContext) -> crate::Result<()> {
    let tiles = 16;
    let mut table = Table::new(
        "Extension — n300d dual-die PCG (BF16 fused, weak scaling across dies)",
        &["config", "cores", "elements", "time/iter", "eth seam/iter", "per-tile ns"],
    );
    let mut csv = CsvWriter::new(&["config", "cores", "elements", "iter_ns", "eth_ns", "ns_per_tile"]);

    // Single die reference (4x4).
    let p = Problem::new(4, 4, tiles, DataFormat::Bf16);
    let grid = p.make_grid()?;
    let b = solver::dist_random(&p, ctx.seed);
    let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
    opts.max_iters = ctx.pcg_iters;
    opts.tol_abs = 0.0;
    let mut prof = Profiler::disabled();
    let single = solver::solve(&grid, &p, &b, ctx.engine.as_ref(), &ctx.cost, &opts, &mut prof)?;
    table.row(vec![
        "1 die, 4x4".into(),
        "16".into(),
        format!("{}", p.elems()),
        fmt_ns(single.per_iter_ns),
        "-".into(),
        format!("{:.0}", single.per_iter_ns / tiles as f64),
    ]);
    csv.row(&[
        "1die_4x4".into(),
        "16".into(),
        format!("{}", p.elems()),
        format!("{:.1}", single.per_iter_ns),
        "0".into(),
        format!("{:.2}", single.per_iter_ns / tiles as f64),
    ]);

    // Two dies, 4x4 each (same per-die load, 2x the problem).
    let mut rng = Rng::new(ctx.seed);
    let b2: Vec<CoreBlock> = (0..2 * 16)
        .map(|_| CoreBlock::from_fn(DataFormat::Bf16, tiles, |_, _, _| rng.next_f32() - 0.5))
        .collect();
    let mut dopts = DualDieOptions::default();
    dopts.max_iters = ctx.pcg_iters;
    dopts.tol_abs = 0.0;
    let dual = solve_pcg_dualdie(4, 4, tiles, &b2, ctx.engine.as_ref(), &ctx.cost, &dopts)?;
    table.row(vec![
        "2 dies, 4x4 each".into(),
        "32".into(),
        format!("{}", 2 * p.elems()),
        fmt_ns(dual.per_iter_ns),
        fmt_ns(dual.eth_ns_per_iter),
        format!("{:.0}", dual.per_iter_ns / tiles as f64),
    ]);
    csv.row(&[
        "2die_4x4".into(),
        "32".into(),
        format!("{}", 2 * p.elems()),
        format!("{:.1}", dual.per_iter_ns),
        format!("{:.1}", dual.eth_ns_per_iter),
        format!("{:.2}", dual.per_iter_ns / tiles as f64),
    ]);

    println!("{}", table.render());
    let overhead = 100.0 * (dual.per_iter_ns - single.per_iter_ns) / single.per_iter_ns;
    println!(
        "dual-die weak-scaling overhead: {overhead:+.1}% per iteration (Ethernet seam = {} per\n\
         iteration); the seam is an N/S-row exchange, the cheap direction (§6.3), which is why\n\
         stacking dies along x is the natural n300d decomposition.\n",
        fmt_ns(dual.eth_ns_per_iter)
    );
    ctx.save_csv("ext_dualdie", &csv);
    Ok(())
}

/// Jacobi (Brown & Barton's method, §2) vs PCG on the same problem.
pub fn run_jacobi(ctx: &ExpContext) -> crate::Result<()> {
    let p = Problem::new(4, 4, 8, DataFormat::Fp32);
    let grid = p.make_grid()?;
    let b = solver::dist_random(&p, ctx.seed);
    let tol = 1e-1;

    let jopts = JacobiOptions {
        max_iters: 20_000,
        tol_abs: tol,
        omega: 1.0,
        check_every: 10,
    };
    let jac = solve_jacobi(&grid, &p, &b, ctx.engine.as_ref(), &ctx.cost, &jopts)?;

    let mut popts = PcgOptions::new(PcgVariant::SplitFp32);
    popts.max_iters = 1000;
    popts.tol_abs = tol;
    let mut prof = Profiler::disabled();
    let pcg = solver::solve(&grid, &p, &b, ctx.engine.as_ref(), &ctx.cost, &popts, &mut prof)?;

    let mut table = Table::new(
        "Extension — Jacobi (Brown & Barton, §2) vs PCG, FP32, 4x4 cores x 8 tiles",
        &["solver", "iterations", "time/iter", "time to |r|<=1e-1", "global reductions"],
    );
    let mut csv = CsvWriter::new(&["solver", "iters", "iter_ns", "total_ns", "reductions"]);
    table.row(vec![
        "Jacobi".into(),
        format!("{}", jac.iters),
        fmt_ns(jac.per_iter_ns),
        fmt_ns(jac.total_ns),
        format!("{}", jac.iters / jopts.check_every),
    ]);
    table.row(vec![
        "PCG".into(),
        format!("{}", pcg.iters),
        fmt_ns(pcg.per_iter_ns),
        fmt_ns(pcg.total_ns),
        format!("{}", 3 * pcg.iters),
    ]);
    csv.row(&[
        "jacobi".into(),
        format!("{}", jac.iters),
        format!("{:.1}", jac.per_iter_ns),
        format!("{:.1}", jac.total_ns),
        format!("{}", jac.iters / jopts.check_every),
    ]);
    csv.row(&[
        "pcg".into(),
        format!("{}", pcg.iters),
        format!("{:.1}", pcg.per_iter_ns),
        format!("{:.1}", pcg.total_ns),
        format!("{}", 3 * pcg.iters),
    ]);
    println!("{}", table.render());
    println!(
        "PCG pays 3 global reductions per iteration but needs {:.0}x fewer iterations —\n\
         the trade this paper's CG work makes over the Grayskull Jacobi study (§2).\n",
        jac.iters as f64 / pcg.iters as f64
    );
    ctx.save_csv("ext_jacobi_vs_pcg", &csv);
    Ok(())
}
