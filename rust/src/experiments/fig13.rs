//! Fig 13: per-component breakdown of the PCG iteration — H100 (analytic
//! baseline model) vs Wormhole BF16 (simulated, fused kernel) at the
//! Table-3 problem (512×112×64 on 8×7 cores, 64 tiles/core). Kernel launch
//! and other overheads are excluded from the bars, as in the paper.

use crate::arch::DataFormat;
use crate::baseline::H100Model;
use crate::kernels::DotMethod;
use crate::noc::RoutePattern;
use crate::profiler::Profiler;
use crate::solver::{self, PcgOptions, PcgVariant, Problem};
use crate::util::csv::CsvWriter;
use crate::util::stats::fmt_ns;
use crate::util::table::Table;

use super::ExpContext;

pub const COMPONENTS: [&str; 4] = ["norm", "dot", "axpy", "spmv"];

pub fn run(ctx: &ExpContext) -> crate::Result<()> {
    // H100 side.
    let p = Problem::new(8, 7, 64, DataFormat::Bf16);
    let n = p.elems();
    let h100 = H100Model::default().cg_iteration(n);

    // Wormhole BF16 side.
    let grid = p.make_grid()?;
    let b = solver::dist_random(&p, ctx.seed);
    let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
    opts.max_iters = ctx.pcg_iters;
    opts.tol_abs = 0.0;
    opts.dot_method = DotMethod::ReduceThenSend;
    opts.dot_pattern = RoutePattern::Naive;
    let mut prof = Profiler::new();
    let wh = solver::solve(&grid, &p, &b, ctx.engine.as_ref(), &ctx.cost, &opts, &mut prof)?;

    let mut table = Table::new(
        &format!("Fig 13 — PCG component breakdown, {}x{}x{} grid (launch/overheads excluded)", 512, 112, 64),
        &["component", "H100", "Wormhole BF16", "WH/H100"],
    );
    let mut csv = CsvWriter::new(&["component", "h100_ns", "wormhole_bf16_ns", "ratio"]);
    for comp in COMPONENTS {
        let h = h100.breakdown.per_iter(comp);
        let w = wh.breakdown.per_iter(comp);
        // `precond` is folded into axpy on the GPU side (§7.3's Kokkos
        // implementation); add it to the Wormhole axpy bar for parity.
        let w = if comp == "axpy" {
            w + wh.breakdown.per_iter("precond")
        } else {
            w
        };
        table.row(vec![
            comp.to_string(),
            fmt_ns(h),
            fmt_ns(w),
            format!("{:.1}x", w / h),
        ]);
        csv.row(&[
            comp.to_string(),
            format!("{h:.1}"),
            format!("{w:.1}"),
            format!("{:.3}", w / h),
        ]);
    }
    println!("{}", table.render());
    println!(
        "component sums: H100 {} of {} total; Wormhole {} of {} total (§7.3: zone sums \
         undercount the wall time)\n",
        fmt_ns(h100.components_ns),
        fmt_ns(h100.total_ns),
        fmt_ns(wh.breakdown.total_per_iter()),
        fmt_ns(wh.per_iter_ns),
    );
    ctx.save_csv("fig13_breakdown", &csv);
    Ok(())
}
