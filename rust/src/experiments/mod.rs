//! Experiment runners: one per paper table and figure (DESIGN.md §5).
//!
//! Every runner prints the paper-style rows and writes a CSV under
//! `results/`, so each artifact in the paper's evaluation section can be
//! regenerated with `wormsim figures <id>` / `wormsim tables <id>` (or
//! `cargo bench`, which drives the same runners).

pub mod benchsuite;
pub mod ext;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig3;
pub mod fig5;
pub mod fig6;
pub mod tables;

use std::path::PathBuf;

use crate::engine::{ComputeEngine, NativeEngine};
use crate::timing::cost::CostModel;

/// Shared context for experiment runs.
pub struct ExpContext {
    pub cost: CostModel,
    pub engine: Box<dyn ComputeEngine>,
    /// PCG iterations to simulate for per-iteration figures (timing is
    /// deterministic per iteration; more iterations only smooth the value
    /// path).
    pub pcg_iters: usize,
    pub out_dir: PathBuf,
    pub seed: u64,
}

impl Default for ExpContext {
    fn default() -> Self {
        Self {
            cost: CostModel::default(),
            engine: Box::new(NativeEngine::new()),
            pcg_iters: 3,
            out_dir: PathBuf::from("results"),
            seed: 20260710,
        }
    }
}

impl ExpContext {
    pub fn save_csv(&self, name: &str, csv: &crate::util::csv::CsvWriter) {
        let path = self.out_dir.join(format!("{name}.csv"));
        match csv.write(&path) {
            Ok(()) => println!("→ wrote {}", path.display()),
            Err(e) => eprintln!("! failed to write {}: {e}", path.display()),
        }
    }
}

/// The grid ladder used by the weak-scaling figures (1×1 … 8×7, §7.2).
pub const GRID_LADDER: [(usize, usize); 8] =
    [(1, 1), (2, 2), (3, 3), (4, 4), (5, 5), (6, 6), (7, 7), (8, 7)];

/// All experiment ids, in paper order.
pub const ALL_FIGURES: [&str; 7] = ["fig3", "fig5", "fig6", "fig11", "fig12a", "fig12b", "fig12c"];
pub const ALL_TABLES: [&str; 3] = ["t1", "t2", "t3"];

/// Dispatch a figure runner by id. "fig13" is also accepted under figures.
pub fn run_figure(ctx: &ExpContext, id: &str) -> crate::Result<()> {
    match id {
        "fig3" => fig3::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig6" => fig6::run(ctx),
        "fig11" => fig11::run(ctx),
        "fig12a" => fig12::run_strong_fp32(ctx),
        "fig12b" => fig12::run_strong_bf16(ctx),
        "fig12c" => fig12::run_weak(ctx),
        "fig13" => fig13::run(ctx),
        "energy" => ext::run_energy(ctx),
        "dualdie" => ext::run_dualdie(ctx),
        "jacobi" => ext::run_jacobi(ctx),
        "ext" => {
            ext::run_energy(ctx)?;
            ext::run_dualdie(ctx)?;
            ext::run_jacobi(ctx)
        }
        "all" => {
            for f in ALL_FIGURES {
                run_figure(ctx, f)?;
            }
            fig13::run(ctx)
        }
        _ => Err(crate::SimError::Config(format!(
            "unknown figure '{id}' (expected one of {ALL_FIGURES:?}, fig13, all)"
        ))),
    }
}

pub fn run_table(ctx: &ExpContext, id: &str) -> crate::Result<()> {
    match id {
        "t1" => tables::run_t1(ctx),
        "t2" => tables::run_t2(ctx),
        "t3" => tables::run_t3(ctx),
        "all" => {
            for t in ALL_TABLES {
                run_table(ctx, t)?;
            }
            Ok(())
        }
        _ => Err(crate::SimError::Config(format!(
            "unknown table '{id}' (expected one of {ALL_TABLES:?}, all)"
        ))),
    }
}
