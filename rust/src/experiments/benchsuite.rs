//! Machine-readable bench snapshots (`wormsim bench --emit-json`).
//!
//! Each builder runs a deterministic sweep through the public solver/kernel
//! API and returns a [`BenchSnapshot`] of *simulated* figures only — no
//! wall-clock, no timestamps — so regenerating with an unchanged model is
//! byte-stable and the committed `BENCH_<name>.json` files diff cleanly.
//! `smoke` trims each sweep to a CI-sized subset whose metric ids are a
//! strict subset of the full sweep's, so `wormsim bench-diff` against a
//! committed full snapshot compares the matching ids and reports the rest
//! as missing (advisory).

use std::path::{Path, PathBuf};

use crate::arch::{ComputeUnit, DataFormat};
use crate::device::{DeviceMesh, EthLink, MeshTopology};
use crate::engine::{NativeEngine, StencilCoeffs};
use crate::kernels::reduction::{lower_dot_as, DotConfig, DotMethod};
use crate::kernels::spmv::{SpmvConfig, SpmvMode, SpmvOperator};
use crate::kernels::stencil::{lower_stencil, StencilConfig, StencilVariant};
use crate::noc::RoutePattern;
use crate::profiler::Profiler;
use crate::solver::{
    self, MeshOptions, Operator, OverlapMode, PcgOptions, PcgVariant, Schedule,
};
use crate::sparse::{circulant_spd, RowPartition};
use crate::telemetry::{BenchSnapshot, Better};
use crate::timing::cost::CostModel;
use crate::ttm::exec::execute_program;
use crate::util::prng::Rng;

/// The provenance note every builder stamps: these are simulated figures,
/// reproducible with the in-repo model at the recorded configuration.
const PROVENANCE: &str = "simulated (wormsim cost model); regenerate with `wormsim bench --emit-json`";

/// The N-die strong-scaling PCG sweep (the `bench_pcg` mesh sweep as
/// data): fixed element count, per-die 8×7 cores, 64 total z-tiles split
/// across dies, fused BF16, over (overlap, schedule, topology)
/// configurations — serial/pipelined classic plus the
/// communication-avoiding prefetch and sstep:4 schedules under pipelined
/// overlap on the 1D line, and the most-square 2D torus
/// ([`MeshTopology::torus_for`]) for the bracketing (serial, classic)
/// and (pipelined, sstep:4) configs — the knee-vs-fix comparison.
pub fn pcg_snapshot(smoke: bool) -> crate::Result<BenchSnapshot> {
    let (rows, cols, total_tiles) = (8usize, 7usize, 64usize);
    let dies: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8, 16, 32] };
    let mut s = BenchSnapshot::new("pcg");
    s.meta("provenance", PROVENANCE);
    s.meta(
        "config",
        "strong scaling: per-die 8x7 cores, 64 total z-tiles split across dies; \
         line topology for all four (overlap, schedule) configs, torus_for(N) for \
         (serial, classic) and (pipelined, sstep:4)",
    );
    s.meta("variant", "bf16-fused");
    s.meta("max_iters", "2 (sstep: one block of s)");
    s.meta("seed", "42");
    let cost = CostModel::default();
    let engine = NativeEngine::new();
    let configs = [
        (OverlapMode::Serial, Schedule::Classic, false),
        (OverlapMode::Pipelined, Schedule::Classic, false),
        (OverlapMode::Pipelined, Schedule::Prefetch, false),
        (OverlapMode::Pipelined, Schedule::SStep(4), false),
        (OverlapMode::Serial, Schedule::Classic, true),
        (OverlapMode::Pipelined, Schedule::SStep(4), true),
    ];
    for (overlap, schedule, torus) in configs {
        for &n in dies {
            let tiles = total_tiles / n;
            let topology =
                if torus { MeshTopology::torus_for(n) } else { MeshTopology::Line };
            let mesh = DeviceMesh::new(n, rows, cols, topology, EthLink::for_dies(n))?;
            let cfg = StencilConfig {
                df: DataFormat::Bf16,
                unit: ComputeUnit::Fpu,
                tiles_per_core: tiles,
                variant: StencilVariant::FULL,
                coeffs: StencilCoeffs::LAPLACIAN,
            };
            let b = solver::mesh_dist_random(&mesh, tiles, DataFormat::Bf16, 42);
            let mut opts = PcgOptions::new(PcgVariant::FusedBf16);
            opts.max_iters = match schedule {
                Schedule::SStep(s) => s,
                _ => 2,
            };
            opts.tol_abs = 0.0;
            let mut prof = Profiler::disabled();
            let res = solver::solve_pcg_mesh(
                &mesh,
                &b,
                &Operator::Stencil(cfg),
                &engine,
                &cost,
                &MeshOptions::new(opts).with_overlap(overlap).with_schedule(schedule),
                &mut prof,
            )?;
            let nstr = n.to_string();
            let sched_label = schedule.label();
            let topo_label = topology.label();
            let labels = [
                ("dies", nstr.as_str()),
                ("topology", topo_label.as_str()),
                ("overlap", overlap.label()),
                ("schedule", sched_label.as_str()),
            ];
            let it = res.iters.max(1) as f64;
            s.push("iter_ns", &labels, res.per_iter_ns, "ns", Better::Lower);
            s.push("compute_ns", &labels, res.phases.compute_ns, "ns", Better::Lower);
            s.push("noc_ns", &labels, res.phases.noc_ns, "ns", Better::Lower);
            s.push("eth_ns", &labels, res.phases.ether_ns, "ns", Better::Lower);
            s.push("dispatch_ns", &labels, res.phases.dispatch_ns, "ns", Better::Lower);
            s.push(
                "eth_bytes_per_iter",
                &labels,
                res.eth_bytes_total as f64 / it,
                "bytes",
                Better::Lower,
            );
            s.push(
                "allreduce_rounds_per_iter",
                &labels,
                res.allreduce_rounds_per_iter(),
                "count",
                Better::Lower,
            );
            // Round depth of ONE scalar all-reduce on this wiring — the
            // topology lever in isolation (line O(N) chain, ring both-ways
            // fold + broadcast, torus row-phase + column-phase O(√N)).
            let eth_rounds = crate::ttm::EtherPhase::scalar_allreduce(&mesh)
                .map_or(0, |e| e.rounds.len());
            s.push(
                "eth_rounds_per_allreduce",
                &labels,
                eth_rounds as f64,
                "count",
                Better::Lower,
            );
            s.push(
                "launches_per_iter",
                &labels,
                res.launches_per_iter(),
                "count",
                Better::Info,
            );
            s.push(
                "peak_link_util",
                &labels,
                res.eth_peak_link_util,
                "fraction",
                Better::Info,
            );
            // Critical-path attribution from the causal span graph: the
            // share of the solve's longest dependency chain spent on
            // Ethernet links / host dispatch (the knee diagnosis).
            let (crit_eth, crit_dispatch) = res.crit_fracs();
            s.push("crit_eth_frac", &labels, crit_eth, "fraction", Better::Info);
            s.push(
                "crit_dispatch_frac",
                &labels,
                crit_dispatch,
                "fraction",
                Better::Info,
            );
        }
    }
    Ok(s)
}

/// SELL SpMV timing sweep (the `bench_spmv` configuration as data):
/// uniform-row circulant SPD, nnz/row × streaming mode.
pub fn spmv_snapshot(smoke: bool) -> crate::Result<BenchSnapshot> {
    let nnzs: &[usize] = if smoke { &[7] } else { &[7, 27, 64] };
    let (grid_rows, grid_cols, tiles) = (2usize, 2usize, 2usize);
    let grid = crate::device::TensixGrid::new(grid_rows, grid_cols)?;
    let n = grid_rows * grid_cols * tiles * 1024;
    let mut s = BenchSnapshot::new("spmv");
    s.meta("provenance", PROVENANCE);
    s.meta("config", "uniform circulant SPD, 2x2 grid, 2 tiles/core, fp32");
    let cost = CostModel::default();
    let engine = NativeEngine::new();
    for &nnz in nnzs {
        let a = circulant_spd(n, nnz, 2026)?;
        let part = RowPartition::row_block(grid_rows, grid_cols, n)?;
        let mut rng = Rng::new(11);
        let xg: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let x = part.dist_from_global(DataFormat::Fp32, &xg);
        for mode in [SpmvMode::DramStream, SpmvMode::SramResident] {
            let tag = match mode {
                SpmvMode::DramStream => "dram-stream",
                SpmvMode::SramResident => "sram-resident",
            };
            let op = match SpmvOperator::new(
                &a,
                part.clone(),
                SpmvConfig::new(DataFormat::Fp32, mode),
            ) {
                Ok(op) => op,
                Err(_) => continue, // over SRAM budget at this nnz — skipped
            };
            let (_, t) = op.apply(&grid, &x, &engine, &cost)?;
            let nnz_str = nnz.to_string();
            let labels = [("nnz", nnz_str.as_str()), ("mode", tag)];
            s.push("spmv_ns", &labels, t.total_ns, "ns", Better::Lower);
            s.push("achieved_gbs", &labels, t.achieved_gbs(), "GB/s", Better::Higher);
        }
    }
    Ok(s)
}

/// Kernel-level timing figures (dot method/pattern, stencil) through the
/// lowered-program executor — pure timing, no engine values.
pub fn figures_snapshot(smoke: bool) -> crate::Result<BenchSnapshot> {
    let grids: &[(usize, usize)] = if smoke { &[(4, 4)] } else { &[(4, 4), (8, 7)] };
    let tiles = 16usize;
    let mut s = BenchSnapshot::new("figures");
    s.meta("provenance", PROVENANCE);
    s.meta("config", "lowered kernel programs, bf16, 16 tiles/core");
    let cost = CostModel::default();
    for &(rows, cols) in grids {
        let gstr = format!("{rows}x{cols}");
        for (method, mtag) in [
            (DotMethod::ReduceThenSend, "reduce-then-send"),
            (DotMethod::SendTiles, "send-tiles"),
        ] {
            for (pattern, ptag) in
                [(RoutePattern::Naive, "naive"), (RoutePattern::Center, "center")]
            {
                let cfg = DotConfig {
                    method,
                    pattern,
                    df: DataFormat::Bf16,
                    unit: ComputeUnit::Fpu,
                    tiles_per_core: tiles,
                };
                let p = lower_dot_as("dot", rows, cols, &cfg, &cost);
                let out = execute_program(&p, &cost, 0.0)?;
                let labels = [("grid", gstr.as_str()), ("method", mtag), ("pattern", ptag)];
                s.push("dot_ns", &labels, out.device_ns(), "ns", Better::Lower);
            }
        }
        let grid = crate::device::TensixGrid::new(rows, cols)?;
        let cfg = StencilConfig {
            df: DataFormat::Bf16,
            unit: ComputeUnit::Fpu,
            tiles_per_core: tiles,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let p = lower_stencil(&grid, &cfg, &cost);
        let out = execute_program(&p, &cost, 0.0)?;
        s.push(
            "stencil_ns",
            &[("grid", gstr.as_str())],
            out.device_ns(),
            "ns",
            Better::Lower,
        );
    }
    Ok(s)
}

/// The fault-tolerance sweep (`BENCH_resilience.json`): checkpoint
/// overhead as a function of the interval `k` on a fault-free solve, and
/// end-to-end recovery overhead under each scripted fault class. All
/// figures are simulated and deterministic — the fault plan is part of
/// the configuration, so the "faulted" numbers regenerate byte-stable.
///
/// Two families of metrics:
///   * `total_ns{checkpoint=k}` and `checkpoint_overhead_frac{checkpoint=k}`
///     — the k∈{0,8,32} interval sweep with NO faults (k=0 is the
///     baseline; its overhead row is exactly 0 by construction). This is
///     the cost side of the interval trade-off: smaller k = more
///     checkpoint traffic per solve.
///   * `total_ns{fault=...}`, `recovery_overhead_frac{fault=...}`,
///     `rollbacks`/`fault_epochs`/`retry_ns{fault=...}` — one scripted
///     scenario per fault class, against the same clean baseline. This is
///     the benefit side: time-to-recover (rollback depth) shrinks as k
///     shrinks, so the knee of overhead-vs-recovery sits near the k where
///     checkpoint cost per interval matches expected rework.
pub fn resilience_snapshot(smoke: bool) -> crate::Result<BenchSnapshot> {
    use crate::device::FaultPlan;
    use crate::solver::ResilienceOptions;
    use crate::telemetry::Resource;

    let (rows, cols, tiles) = (4usize, 4usize, 8usize);
    let dies = 8usize;
    // Same iteration count in smoke and full runs so the smoke subset's
    // metric *values* (not just ids) match the committed full snapshot.
    let iters = 32usize;
    let mut s = BenchSnapshot::new("resilience");
    s.meta("provenance", PROVENANCE);
    s.meta(
        "config",
        "8 dies torus:2x4, per-die 4x4 cores, 8 tiles/core, split-fp32, fixed \
         iteration count; fault scenarios scripted via FaultPlan specs",
    );
    s.meta("variant", "fp32-split");
    s.meta("seed", "42");
    let cost = CostModel::default();
    let engine = NativeEngine::new();
    let mesh = DeviceMesh::new(
        dies,
        rows,
        cols,
        MeshTopology::torus_for(dies),
        EthLink::for_dies(dies),
    )?;
    let b = solver::mesh_dist_random(&mesh, tiles, DataFormat::Fp32, 42);
    let run = |faults: Option<&str>,
               checkpoint: Option<usize>|
     -> crate::Result<solver::MeshPcgResult> {
        let cfg = StencilConfig {
            df: DataFormat::Fp32,
            unit: ComputeUnit::Fpu,
            tiles_per_core: tiles,
            variant: StencilVariant::FULL,
            coeffs: StencilCoeffs::LAPLACIAN,
        };
        let mut opts = PcgOptions::new(PcgVariant::SplitFp32);
        opts.max_iters = iters;
        opts.tol_abs = 0.0;
        let mut mopts = MeshOptions::new(opts);
        if let Some(spec) = faults {
            mopts = mopts.with_faults(
                FaultPlan::parse(spec).map_err(crate::SimError::Config)?,
            );
        }
        if let Some(k) = checkpoint {
            mopts = mopts.with_resilience(ResilienceOptions::every(k));
        }
        let mut prof = Profiler::disabled();
        solver::solve_pcg_mesh(
            &mesh,
            &b,
            &Operator::Stencil(cfg),
            &engine,
            &cost,
            &mopts,
            &mut prof,
        )
    };

    // Cost side: checkpoint-interval sweep, no faults. k=0 doubles as the
    // clean baseline for the recovery scenarios below.
    let ks: &[usize] = if smoke { &[0, 8] } else { &[0, 8, 32] };
    let mut clean_total = 0.0f64;
    for &k in ks {
        let res = run(None, Some(k))?;
        if k == 0 {
            clean_total = res.total_ns;
        }
        let kstr = k.to_string();
        let labels = [("checkpoint", kstr.as_str())];
        s.push("total_ns", &labels, res.total_ns, "ns", Better::Lower);
        s.push(
            "checkpoint_overhead_frac",
            &labels,
            res.total_ns / clean_total - 1.0,
            "fraction",
            Better::Lower,
        );
    }

    // Benefit side: one scenario per fault class. Times are absolute
    // simulated offsets; with this fixed configuration they land
    // mid-solve, and determinism holds wherever they land.
    let scenarios: &[(&str, &str)] = if smoke {
        &[("sdc", "sdc:spmv@6")]
    } else {
        &[
            ("link_down", "link_down:0-1@40us"),
            ("link_degrade", "link_degrade:0-1@20us..400usx8"),
            ("die_down", "die_down:7@40us"),
            ("sdc", "sdc:spmv@6"),
        ]
    };
    for &(name, spec) in scenarios {
        let res = run(Some(spec), None)?;
        let labels = [("fault", name)];
        s.push("faulted_total_ns", &labels, res.total_ns, "ns", Better::Lower);
        s.push(
            "recovery_overhead_frac",
            &labels,
            res.total_ns / clean_total - 1.0,
            "fraction",
            Better::Lower,
        );
        s.push("rollbacks", &labels, res.rollbacks as f64, "count", Better::Info);
        s.push(
            "fault_epochs",
            &labels,
            res.fault_epochs as f64,
            "count",
            Better::Info,
        );
        s.push(
            "retry_ns",
            &labels,
            res.ledger.total.get(Resource::Retry),
            "ns",
            Better::Info,
        );
    }
    Ok(s)
}

/// Build the snapshots of one suite (or `"all"`).
pub fn build(suite: &str, smoke: bool) -> crate::Result<Vec<BenchSnapshot>> {
    match suite {
        "pcg" => Ok(vec![pcg_snapshot(smoke)?]),
        "spmv" => Ok(vec![spmv_snapshot(smoke)?]),
        "figures" => Ok(vec![figures_snapshot(smoke)?]),
        "resilience" => Ok(vec![resilience_snapshot(smoke)?]),
        "all" => Ok(vec![
            pcg_snapshot(smoke)?,
            spmv_snapshot(smoke)?,
            figures_snapshot(smoke)?,
            resilience_snapshot(smoke)?,
        ]),
        other => Err(crate::SimError::Config(format!(
            "unknown bench suite '{other}' (expected pcg|spmv|figures|resilience|all)"
        ))),
    }
}

/// Build and write `BENCH_<name>.json` under `out_dir`; returns the paths.
pub fn write_snapshots(suite: &str, smoke: bool, out_dir: &Path) -> crate::Result<Vec<PathBuf>> {
    let mut paths = Vec::new();
    for snap in build(suite, smoke)? {
        let path = out_dir.join(format!("BENCH_{}.json", snap.name));
        snap.write(&path)
            .map_err(|e| crate::SimError::Artifact(format!("write {}: {e}", path.display())))?;
        paths.push(path);
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_snapshots_build_and_round_trip() {
        for snap in build("all", true).unwrap() {
            assert!(!snap.metrics.is_empty(), "{} is empty", snap.name);
            let back = BenchSnapshot::parse(&snap.to_json()).unwrap();
            assert_eq!(back, snap);
            // Self-diff of a freshly built snapshot is clean.
            let d = crate::telemetry::diff(&snap, &snap, 0.05);
            assert!(d.regressions.is_empty() && d.missing.is_empty());
        }
    }

    #[test]
    fn smoke_ids_are_a_subset_of_full_ids() {
        // The CI smoke run must diff cleanly against a committed full
        // snapshot: every smoke metric id exists in the full sweep.
        let smoke = pcg_snapshot(true).unwrap();
        let full_ids: Vec<String> = pcg_snapshot(false)
            .unwrap()
            .metrics
            .iter()
            .map(|m| m.id())
            .collect();
        for m in &smoke.metrics {
            assert!(full_ids.contains(&m.id()), "{} missing from full sweep", m.id());
        }
    }
}
