//! NoC routing (§3): the Wormhole NoC physically connects cardinal
//! neighbors in a 2D torus; the hardware routes arbitrary core-to-core
//! messages. We model dimension-ordered (X-then-Y) routing over the
//! *sub-grid* mesh — the paper's reduction patterns only ever route within
//! the selected compute sub-grid, and torus wraparound links connect cores
//! outside it, so mesh distances are the relevant ones.

use crate::device::Coord;

/// A directed physical link between adjacent cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Link {
    pub from: Coord,
    pub to: Coord,
}

/// The XY route from `src` to `dst`: all X (column) movement first, then Y
/// (row) movement, as directed links.
pub fn xy_route(src: Coord, dst: Coord) -> Vec<Link> {
    let mut links = Vec::with_capacity(src.manhattan(dst));
    let mut cur = src;
    // X dimension = columns.
    while cur.col != dst.col {
        let next = Coord::new(
            cur.row,
            if dst.col > cur.col { cur.col + 1 } else { cur.col - 1 },
        );
        links.push(Link { from: cur, to: next });
        cur = next;
    }
    // Y dimension = rows.
    while cur.row != dst.row {
        let next = Coord::new(
            if dst.row > cur.row { cur.row + 1 } else { cur.row - 1 },
            cur.col,
        );
        links.push(Link { from: cur, to: next });
        cur = next;
    }
    links
}

/// Hop count of the XY route (Manhattan distance).
pub fn hops(src: Coord, dst: Coord) -> usize {
    src.manhattan(dst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn route_length_is_manhattan() {
        let s = Coord::new(1, 1);
        let d = Coord::new(4, 6);
        let r = xy_route(s, d);
        assert_eq!(r.len(), 8);
        assert_eq!(hops(s, d), 8);
    }

    #[test]
    fn route_is_x_then_y_and_contiguous() {
        let r = xy_route(Coord::new(2, 0), Coord::new(0, 2));
        // First the column moves, then the row moves.
        assert_eq!(r[0].from, Coord::new(2, 0));
        assert_eq!(r[0].to, Coord::new(2, 1));
        assert_eq!(r[1].to, Coord::new(2, 2));
        assert_eq!(r[2].to, Coord::new(1, 2));
        assert_eq!(r[3].to, Coord::new(0, 2));
        // Contiguity: each link starts where the previous ended.
        for w in r.windows(2) {
            assert_eq!(w[0].to, w[1].from);
        }
    }

    #[test]
    fn self_route_is_empty() {
        assert!(xy_route(Coord::new(3, 3), Coord::new(3, 3)).is_empty());
        assert_eq!(hops(Coord::new(3, 3), Coord::new(3, 3)), 0);
    }

    #[test]
    fn unit_routes() {
        let r = xy_route(Coord::new(0, 0), Coord::new(0, 1));
        assert_eq!(r.len(), 1);
        let r = xy_route(Coord::new(5, 2), Coord::new(4, 2));
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].to, Coord::new(4, 2));
    }
}
