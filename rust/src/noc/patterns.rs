//! Global-reduction routing patterns over the NoC (§5.2).
//!
//! - **Naive**: data flows leftward along each row, then up column 0 to the
//!   top-left core. Each core handles at most 2 incoming partials.
//! - **Center**: data flows toward the grid's center column within each
//!   row, then along the center column to the center core, minimizing
//!   distance and spreading load across links; the center core handles up
//!   to 4 incoming partials.
//! - **Direct** (§5 notes it but does not evaluate it): every core sends
//!   straight to the root, which performs the whole reduction — provided
//!   for the ablation bench.
//!
//! A pattern yields a reduction *tree*; the dot-product kernel executes the
//! tree against the NoC simulator, merging partials at every hop ("only the
//! sum of all incoming partial results is sent onward", §5).

use std::collections::BTreeMap;

use crate::device::Coord;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePattern {
    Naive,
    Center,
    Direct,
}

impl std::str::FromStr for RoutePattern {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Ok(RoutePattern::Naive),
            "center" => Ok(RoutePattern::Center),
            "direct" => Ok(RoutePattern::Direct),
            _ => Err(format!("unknown routing pattern '{s}'")),
        }
    }
}

/// A reduction tree: every non-root core has exactly one parent.
#[derive(Debug, Clone)]
pub struct ReduceTree {
    pub root: Coord,
    pub parent: BTreeMap<Coord, Coord>,
}

impl ReduceTree {
    /// Children of each core, derived from the parent map.
    pub fn children(&self) -> BTreeMap<Coord, Vec<Coord>> {
        let mut ch: BTreeMap<Coord, Vec<Coord>> = BTreeMap::new();
        for (&c, &p) in &self.parent {
            ch.entry(p).or_default().push(c);
        }
        ch
    }

    /// Depth of a core (hops-in-tree to the root).
    pub fn depth(&self, mut c: Coord) -> usize {
        let mut d = 0;
        while let Some(&p) = self.parent.get(&c) {
            c = p;
            d += 1;
            assert!(d <= 10_000, "cycle in reduction tree at {c}");
        }
        d
    }

    /// Cores ordered leaves-first (deepest first), suitable for a single
    /// forward execution pass.
    pub fn topo_order(&self) -> Vec<Coord> {
        let mut coords: Vec<Coord> = self
            .parent
            .keys()
            .copied()
            .chain(std::iter::once(self.root))
            .collect();
        coords.sort();
        coords.dedup();
        coords.sort_by_key(|c| std::cmp::Reverse(self.depth(*c)));
        coords
    }

    /// Maximum number of children any core has (the §5.2 routing-logic
    /// complexity measure: ≤2 for naive, ≤4 for center).
    pub fn max_fan_in(&self) -> usize {
        self.children().values().map(|v| v.len()).max().unwrap_or(0)
    }
}

/// Build the reduction tree for `pattern` on an `rows × cols` grid.
pub fn reduce_tree(pattern: RoutePattern, rows: usize, cols: usize) -> ReduceTree {
    assert!(rows > 0 && cols > 0);
    let mut parent = BTreeMap::new();
    match pattern {
        RoutePattern::Naive => {
            // Leftward along rows, then up column 0 (§5.2).
            let root = Coord::new(0, 0);
            for r in 0..rows {
                for c in 0..cols {
                    let me = Coord::new(r, c);
                    if c > 0 {
                        parent.insert(me, Coord::new(r, c - 1));
                    } else if r > 0 {
                        parent.insert(me, Coord::new(r - 1, 0));
                    }
                }
            }
            ReduceTree { root, parent }
        }
        RoutePattern::Center => {
            let root = Coord::new(rows / 2, cols / 2);
            for r in 0..rows {
                for c in 0..cols {
                    let me = Coord::new(r, c);
                    if me == root {
                        continue;
                    }
                    let p = if c != root.col {
                        // Move along the row toward the center column.
                        Coord::new(r, if c > root.col { c - 1 } else { c + 1 })
                    } else {
                        // On the center column: move toward the center row.
                        Coord::new(if r > root.row { r - 1 } else { r + 1 }, c)
                    };
                    parent.insert(me, p);
                }
            }
            ReduceTree { root, parent }
        }
        RoutePattern::Direct => {
            let root = Coord::new(rows / 2, cols / 2);
            for r in 0..rows {
                for c in 0..cols {
                    let me = Coord::new(r, c);
                    if me != root {
                        parent.insert(me, root);
                    }
                }
            }
            ReduceTree { root, parent }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_reach_root(t: &ReduceTree, rows: usize, cols: usize) {
        for r in 0..rows {
            for c in 0..cols {
                let d = t.depth(Coord::new(r, c)); // panics on cycle
                assert!(d <= rows * cols);
            }
        }
    }

    #[test]
    fn naive_tree_structure() {
        let t = reduce_tree(RoutePattern::Naive, 4, 5);
        assert_eq!(t.root, Coord::new(0, 0));
        assert_eq!(t.parent.len(), 19);
        all_reach_root(&t, 4, 5);
        // §5.2: at most 2 incoming per core.
        assert!(t.max_fan_in() <= 2, "fan-in {}", t.max_fan_in());
        // Row interior chains point left.
        assert_eq!(t.parent[&Coord::new(2, 3)], Coord::new(2, 2));
        // Column 0 chains point up.
        assert_eq!(t.parent[&Coord::new(2, 0)], Coord::new(1, 0));
    }

    #[test]
    fn center_tree_structure() {
        let t = reduce_tree(RoutePattern::Center, 8, 7);
        assert_eq!(t.root, Coord::new(4, 3));
        all_reach_root(&t, 8, 7);
        // §5.2: the center core handles up to 4 incoming.
        assert!(t.max_fan_in() <= 4);
        assert_eq!(t.children()[&t.root].len(), 4);
        // Rows converge toward the center column.
        assert_eq!(t.parent[&Coord::new(0, 0)], Coord::new(0, 1));
        assert_eq!(t.parent[&Coord::new(0, 6)], Coord::new(0, 5));
    }

    #[test]
    fn center_shallower_than_naive() {
        // The center pattern minimizes distance traveled (§5.2).
        let n = reduce_tree(RoutePattern::Naive, 8, 7);
        let c = reduce_tree(RoutePattern::Center, 8, 7);
        let max_depth = |t: &ReduceTree| {
            (0..8)
                .flat_map(|r| (0..7).map(move |cc| Coord::new(r, cc)))
                .map(|x| t.depth(x))
                .max()
                .unwrap()
        };
        assert!(max_depth(&c) < max_depth(&n));
    }

    #[test]
    fn single_core_grid_trivial() {
        for p in [RoutePattern::Naive, RoutePattern::Center, RoutePattern::Direct] {
            let t = reduce_tree(p, 1, 1);
            assert!(t.parent.is_empty());
            assert_eq!(t.root, Coord::new(0, 0));
        }
    }

    #[test]
    fn direct_tree_fans_into_root() {
        let t = reduce_tree(RoutePattern::Direct, 3, 3);
        assert_eq!(t.max_fan_in(), 8);
        all_reach_root(&t, 3, 3);
    }

    #[test]
    fn topo_order_children_before_parents() {
        for p in [RoutePattern::Naive, RoutePattern::Center] {
            let t = reduce_tree(p, 5, 5);
            let order = t.topo_order();
            let pos: BTreeMap<Coord, usize> =
                order.iter().enumerate().map(|(i, c)| (*c, i)).collect();
            for (&c, &par) in &t.parent {
                assert!(pos[&c] < pos[&par], "{c} must precede parent {par}");
            }
            assert_eq!(order.len(), 25);
        }
    }
}
