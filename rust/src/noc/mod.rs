//! Network-on-chip model (§3, §5): XY routing, link-serialized message
//! timing, and the global-reduction routing patterns.

pub mod patterns;
pub mod route;
pub mod sim;

pub use patterns::{reduce_tree, ReduceTree, RoutePattern};
pub use route::{hops, xy_route, Link};
pub use sim::{Delivery, NocSim};
