//! Event-ordered NoC timing simulation.
//!
//! Messages traverse their XY route with wormhole (cut-through) switching:
//! the head flit pays per-hop router latency; the body streams at the link
//! bandwidth; each traversed link is occupied for the serialization time,
//! so concurrent messages sharing a link serialize. This captures the §5.2
//! contention difference between the naive (all rows converge on column 0)
//! and center routing patterns.

use std::collections::HashMap;

use crate::device::Coord;
use crate::noc::route::{xy_route, Link};
use crate::timing::calib::Calib;
use crate::timing::SimNs;

/// Accounting for one delivered message.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Delivery {
    /// When the sender's RISC-V finished issuing (sender busy until then).
    pub issue_done: SimNs,
    /// When the last byte arrived at the destination.
    pub arrival: SimNs,
}

/// NoC simulator state: per-link next-free times.
#[derive(Debug, Default)]
pub struct NocSim {
    link_free: HashMap<Link, SimNs>,
    pub messages_sent: u64,
    pub bytes_sent: u64,
    pub max_link_busy_ns: SimNs,
    /// Cumulative busy time across all links: each traversal holds its
    /// link for the hop + serialization window. A telemetry gauge (total
    /// link occupancy), not a wall-clock quantity.
    pub link_busy_ns: SimNs,
}

impl NocSim {
    pub fn new() -> Self {
        Self::default()
    }

    /// Send `bytes` from `src` to `dst`, with the sender ready at `start`.
    /// Returns issue-done and arrival times. Messages to self are free
    /// beyond the issue cost (data is already in L1).
    pub fn send(
        &mut self,
        calib: &Calib,
        src: Coord,
        dst: Coord,
        bytes: u64,
        start: SimNs,
    ) -> Delivery {
        self.send_with_issue(calib, src, dst, bytes, start, calib.noc_issue_cycles)
    }

    /// Like [`send`](Self::send), but with an explicit issue cost — used by
    /// batched send loops (halo exchange) where only the first transaction
    /// pays the cold `noc_issue_cycles` (§6.3 model; see
    /// [`crate::timing::calib::NOC_BATCH_ISSUE_CYCLES`]).
    pub fn send_with_issue(
        &mut self,
        calib: &Calib,
        src: Coord,
        dst: Coord,
        bytes: u64,
        start: SimNs,
        issue_cycles: u64,
    ) -> Delivery {
        let cyc = |c: u64| crate::timing::cycles_ns(c);
        let issue_done = start + cyc(issue_cycles);
        self.messages_sent += 1;
        self.bytes_sent += bytes;
        if src == dst {
            return Delivery {
                issue_done,
                arrival: issue_done,
            };
        }
        let ser_ns = cyc(bytes.div_ceil(calib.noc_link_bytes_per_clk));
        let hop_ns = cyc(calib.noc_hop_cycles);
        // Head traverses hop by hop; each link is held for the
        // serialization window starting when the head enters it.
        let mut head = issue_done;
        for link in xy_route(src, dst) {
            let free = self.link_free.get(&link).copied().unwrap_or(0.0);
            head = head.max(free) + hop_ns;
            let busy_until = head + ser_ns;
            self.link_free.insert(link, busy_until);
            self.link_busy_ns += hop_ns + ser_ns;
            if busy_until > self.max_link_busy_ns {
                self.max_link_busy_ns = busy_until;
            }
        }
        let arrival = head + ser_ns + cyc(calib.noc_recv_cycles);
        Delivery { issue_done, arrival }
    }

    /// Multicast `bytes` from `root` to every core in `dests` (the §5
    /// result broadcast). The Wormhole NoC supports multicast writes; we
    /// model a single issue whose arrival at each destination is bounded by
    /// the farthest hop distance, with the shared links serialized once.
    pub fn multicast(
        &mut self,
        calib: &Calib,
        root: Coord,
        dests: &[Coord],
        bytes: u64,
        start: SimNs,
    ) -> SimNs {
        let cyc = |c: u64| crate::timing::cycles_ns(c);
        let issue_done = start + cyc(calib.noc_issue_cycles);
        self.messages_sent += 1;
        self.bytes_sent += bytes * dests.len().max(1) as u64;
        let ser_ns = cyc(bytes.div_ceil(calib.noc_link_bytes_per_clk));
        let hop_ns = cyc(calib.noc_hop_cycles);
        let max_hops = dests
            .iter()
            .map(|d| root.manhattan(*d))
            .max()
            .unwrap_or(0) as f64;
        self.link_busy_ns += max_hops * hop_ns + ser_ns;
        issue_done + max_hops * hop_ns + ser_ns + cyc(calib.noc_recv_cycles)
    }

    pub fn reset(&mut self) {
        self.link_free.clear();
        self.messages_sent = 0;
        self.bytes_sent = 0;
        self.max_link_busy_ns = 0.0;
        self.link_busy_ns = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c() -> Calib {
        Calib::default()
    }

    #[test]
    fn arrival_after_issue_and_scales_with_distance() {
        let calib = c();
        let mut noc = NocSim::new();
        let d1 = noc.send(&calib, Coord::new(0, 0), Coord::new(0, 1), 32, 0.0);
        let mut noc2 = NocSim::new();
        let d5 = noc2.send(&calib, Coord::new(0, 0), Coord::new(0, 5), 32, 0.0);
        assert!(d1.arrival > d1.issue_done);
        assert!(d5.arrival > d1.arrival, "longer route takes longer");
        // 4 extra hops exactly.
        let hop = crate::timing::cycles_ns(calib.noc_hop_cycles);
        assert!((d5.arrival - d1.arrival - 4.0 * hop).abs() < 1e-6);
    }

    #[test]
    fn bigger_payload_takes_longer() {
        let calib = c();
        let mut noc = NocSim::new();
        let small = noc.send(&calib, Coord::new(0, 0), Coord::new(2, 2), 32, 0.0);
        noc.reset();
        let big = noc.send(&calib, Coord::new(0, 0), Coord::new(2, 2), 4096, 0.0);
        assert!(big.arrival > small.arrival);
    }

    #[test]
    fn shared_link_serializes() {
        let calib = c();
        let mut noc = NocSim::new();
        // Two large messages over the same link at the same time.
        let a = noc.send(&calib, Coord::new(0, 0), Coord::new(0, 1), 4096, 0.0);
        let b = noc.send(&calib, Coord::new(0, 0), Coord::new(0, 1), 4096, 0.0);
        // Second arrival delayed by at least one serialization window.
        let ser = crate::timing::cycles_ns(4096_u64.div_ceil(calib.noc_link_bytes_per_clk));
        assert!(b.arrival >= a.arrival + ser * 0.99);

        // Disjoint links do not interfere.
        let mut noc2 = NocSim::new();
        let x = noc2.send(&calib, Coord::new(0, 0), Coord::new(0, 1), 4096, 0.0);
        let y = noc2.send(&calib, Coord::new(5, 0), Coord::new(5, 1), 4096, 0.0);
        assert!((x.arrival - y.arrival).abs() < 1e-6);
    }

    #[test]
    fn link_busy_accumulates_per_traversal() {
        let calib = c();
        let mut noc = NocSim::new();
        assert_eq!(noc.link_busy_ns, 0.0);
        noc.send(&calib, Coord::new(0, 0), Coord::new(0, 2), 64, 0.0);
        let hop = crate::timing::cycles_ns(calib.noc_hop_cycles);
        let ser = crate::timing::cycles_ns(64_u64.div_ceil(calib.noc_link_bytes_per_clk));
        // Two links traversed, each held hop + ser.
        assert!((noc.link_busy_ns - 2.0 * (hop + ser)).abs() < 1e-9);
        // Self-sends never touch a link.
        let before = noc.link_busy_ns;
        noc.send(&calib, Coord::new(1, 1), Coord::new(1, 1), 4096, 0.0);
        assert_eq!(noc.link_busy_ns, before);
        noc.reset();
        assert_eq!(noc.link_busy_ns, 0.0);
    }

    #[test]
    fn self_send_is_cheap() {
        let calib = c();
        let mut noc = NocSim::new();
        let d = noc.send(&calib, Coord::new(1, 1), Coord::new(1, 1), 4096, 0.0);
        assert_eq!(d.arrival, d.issue_done);
    }

    #[test]
    fn multicast_bounded_by_farthest() {
        let calib = c();
        let mut noc = NocSim::new();
        let near = noc.multicast(&calib, Coord::new(0, 0), &[Coord::new(0, 1)], 32, 0.0);
        noc.reset();
        let far = noc.multicast(
            &calib,
            Coord::new(0, 0),
            &[Coord::new(0, 1), Coord::new(7, 6)],
            32,
            0.0,
        );
        assert!(far > near);
    }
}
