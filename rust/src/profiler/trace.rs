//! Chrome-tracing (about://tracing / Perfetto) export of profiler zones —
//! the visualization role Tracy plays in the paper's methodology (§3.4).
//!
//! Zones become complete ("X") events; scopes (cores / host) become
//! threads of one process, giving the per-core timeline view over
//! *simulated* time. The writer emits the JSON by hand (serde is
//! unavailable offline).

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

use crate::profiler::zones::Profiler;

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Serialize all recorded zones as a Chrome trace. Timestamps are the
/// simulated nanoseconds converted to microseconds (the trace format's
/// unit).
pub fn to_chrome_trace(profiler: &Profiler) -> String {
    // Stable thread ids per scope.
    let mut tids: BTreeMap<&str, usize> = BTreeMap::new();
    for z in profiler.zones() {
        let next = tids.len() + 1;
        tids.entry(z.scope.as_str()).or_insert(next);
    }
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    // Thread name metadata.
    for (scope, tid) in &tids {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{tid},\
             \"args\":{{\"name\":\"{}\"}}}}",
            escape(scope)
        ));
    }
    for z in profiler.zones() {
        let tid = tids[z.scope.as_str()];
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"ph\":\"X\",\"pid\":1,\"tid\":{tid},\
             \"ts\":{:.3},\"dur\":{:.3}}}",
            escape(&z.name),
            z.start / 1e3,
            z.duration() / 1e3
        ));
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

/// Write the trace to `path` (creating parents).
pub fn write_chrome_trace(profiler: &Profiler, path: &Path) -> io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, to_chrome_trace(profiler))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_valid_minimal_json() {
        let mut p = Profiler::new();
        p.record("spmv", "device", 0.0, 1000.0);
        p.record("dot", "device", 1000.0, 1500.0);
        p.record("launch", "host", 0.0, 200.0);
        let s = to_chrome_trace(&p);
        // Structural checks (no serde; keep it honest with a parser-lite).
        assert!(s.starts_with('{') && s.ends_with('}'));
        assert_eq!(s.matches("\"ph\":\"X\"").count(), 3);
        assert_eq!(s.matches("thread_name").count(), 2);
        assert!(s.contains("\"name\":\"spmv\""));
        assert!(s.contains("\"dur\":1.000"));
        // Balanced braces/brackets.
        let depth = s.chars().fold((0i32, 0i32), |(b, k), c| match c {
            '{' => (b + 1, k),
            '}' => (b - 1, k),
            '[' => (b, k + 1),
            ']' => (b, k - 1),
            _ => (b, k),
        });
        assert_eq!(depth, (0, 0));
    }

    #[test]
    fn escaping_quotes() {
        let mut p = Profiler::new();
        p.record("we\"ird", "sc\\ope", 0.0, 1.0);
        let s = to_chrome_trace(&p);
        assert!(s.contains("we\\\"ird"));
        assert!(s.contains("sc\\\\ope"));
    }

    #[test]
    fn writes_file() {
        let mut p = Profiler::new();
        p.record("z", "host", 0.0, 5.0);
        let dir = std::env::temp_dir().join("wormsim_trace_test");
        let path = dir.join("t.json");
        write_chrome_trace(&p, &path).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("traceEvents"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
