//! Chrome-tracing (about://tracing / Perfetto) export of profiler zones —
//! the visualization role Tracy plays in the paper's methodology (§3.4).
//!
//! Zones become complete ("X") events. Scopes map to named processes and
//! threads via metadata ("M") events — device core scopes under the
//! "device" process, Ethernet link scopes under "ethernet", host dispatch
//! under "host" — with explicit sort indices so traces open in a stable,
//! readable order instead of anonymous tid soup. Telemetry time series
//! ([`CounterTrack`]) render as counter ("C") events on a fourth
//! "counters" process, interleaved on the same simulated timeline, so
//! residual decay and link occupancy sit directly under the zones that
//! produced them. The writer emits the JSON by hand (serde is unavailable
//! offline).

use std::collections::BTreeMap;
use std::io;
use std::path::Path;

use crate::profiler::zones::Profiler;
use crate::timing::SimNs;

/// One counter track: a named series of `(simulated ns, value)` samples.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CounterTrack {
    pub name: String,
    pub samples: Vec<(SimNs, f64)>,
}

/// One causal arrow between two scopes, rendered as a Chrome flow-event
/// pair: a start ("s") event at `(from_scope, from_ts)` and a binding
/// finish ("f", `"bp":"e"`) event at `(to_scope, to_ts)` sharing `id`.
/// Derived from span-graph dependency edges
/// ([`crate::telemetry::SpanGraph::flow_events`]) so cross-die
/// halo/all-reduce causality is visible in the trace.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowEvent {
    pub name: String,
    /// Nonzero id shared by the "s"/"f" pair, unique per arrow.
    pub id: u64,
    pub from_scope: String,
    pub from_ts: SimNs,
    pub to_scope: String,
    pub to_ts: SimNs,
}

/// Escape a string for embedding inside JSON double quotes. Handles
/// quotes, backslashes, newlines, tabs, and other control characters.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

const PID_DEVICE: usize = 1;
const PID_ETHERNET: usize = 2;
const PID_HOST: usize = 3;
const PID_COUNTERS: usize = 4;

fn pid_of_scope(scope: &str) -> usize {
    match scope {
        "host" => PID_HOST,
        "ethernet" => PID_ETHERNET,
        _ => PID_DEVICE,
    }
}

fn process_name(pid: usize) -> &'static str {
    match pid {
        PID_HOST => "host",
        PID_ETHERNET => "ethernet",
        PID_COUNTERS => "counters",
        _ => "device",
    }
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

/// Serialize zones plus counter tracks as a Chrome trace. Timestamps are
/// the simulated nanoseconds converted to microseconds (the trace
/// format's unit).
pub fn to_chrome_trace_with(profiler: &Profiler, counters: &[CounterTrack]) -> String {
    to_chrome_trace_full(profiler, counters, &[])
}

/// Serialize zones, counter tracks, and span-graph flow arrows as a
/// Chrome trace. With no flows the output is identical to
/// [`to_chrome_trace_with`].
pub fn to_chrome_trace_full(
    profiler: &Profiler,
    counters: &[CounterTrack],
    flows: &[FlowEvent],
) -> String {
    // Stable (pid, tid) per scope: tids count up within each process in
    // scope-name order. Flow endpoints register scopes too, so arrows to
    // a scope with no zones still land on a named thread.
    let mut scopes: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
    for z in profiler.zones() {
        scopes.entry(z.scope.as_str()).or_insert((0, 0));
    }
    for f in flows {
        scopes.entry(f.from_scope.as_str()).or_insert((0, 0));
        scopes.entry(f.to_scope.as_str()).or_insert((0, 0));
    }
    let mut next_tid: BTreeMap<usize, usize> = BTreeMap::new();
    for (scope, slot) in scopes.iter_mut() {
        let pid = pid_of_scope(scope);
        let tid = next_tid.entry(pid).or_insert(0);
        *tid += 1;
        *slot = (pid, *tid);
    }

    let mut events: Vec<String> = Vec::new();
    // Process metadata, in pid order.
    let mut pids: Vec<usize> = scopes.values().map(|&(pid, _)| pid).collect();
    if !counters.is_empty() {
        pids.push(PID_COUNTERS);
    }
    pids.sort_unstable();
    pids.dedup();
    for pid in &pids {
        events.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
             \"args\":{{\"name\":\"{}\"}}}}",
            escape(process_name(*pid))
        ));
        events.push(format!(
            "{{\"name\":\"process_sort_index\",\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\
             \"args\":{{\"sort_index\":{pid}}}}}"
        ));
    }
    // Thread metadata.
    for (scope, &(pid, tid)) in &scopes {
        events.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
             \"args\":{{\"name\":\"{}\"}}}}",
            escape(scope)
        ));
        events.push(format!(
            "{{\"name\":\"thread_sort_index\",\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\
             \"args\":{{\"sort_index\":{tid}}}}}"
        ));
    }
    // Zones.
    for z in profiler.zones() {
        let (pid, tid) = scopes[z.scope.as_str()];
        events.push(format!(
            "{{\"name\":\"{}\",\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\
             \"ts\":{:.3},\"dur\":{:.3}}}",
            escape(&z.name),
            z.start / 1e3,
            z.duration() / 1e3
        ));
    }
    // Flow arrows: an "s"/"f" pair per span-graph edge.
    for f in flows {
        let (fp, ft) = scopes[f.from_scope.as_str()];
        let (tp, tt) = scopes[f.to_scope.as_str()];
        events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"span-dep\",\"ph\":\"s\",\"id\":{},\
             \"pid\":{fp},\"tid\":{ft},\"ts\":{:.3}}}",
            escape(&f.name),
            f.id,
            f.from_ts / 1e3
        ));
        events.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"span-dep\",\"ph\":\"f\",\"bp\":\"e\",\"id\":{},\
             \"pid\":{tp},\"tid\":{tt},\"ts\":{:.3}}}",
            escape(&f.name),
            f.id,
            f.to_ts / 1e3
        ));
    }
    // Counter tracks.
    for track in counters {
        for &(t_ns, v) in &track.samples {
            events.push(format!(
                "{{\"name\":\"{}\",\"ph\":\"C\",\"pid\":{PID_COUNTERS},\"tid\":0,\
                 \"ts\":{:.3},\"args\":{{\"value\":{}}}}}",
                escape(&track.name),
                t_ns / 1e3,
                json_num(v)
            ));
        }
    }

    let mut out = String::from("{\"traceEvents\":[");
    out.push_str(&events.join(","));
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

/// Serialize all recorded zones as a Chrome trace (no counter tracks).
pub fn to_chrome_trace(profiler: &Profiler) -> String {
    to_chrome_trace_with(profiler, &[])
}

/// Write the trace (zones + counter tracks) to `path`, creating parents.
pub fn write_chrome_trace_with(
    profiler: &Profiler,
    counters: &[CounterTrack],
    path: &Path,
) -> io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, to_chrome_trace_with(profiler, counters))
}

/// Write the trace to `path` (creating parents).
pub fn write_chrome_trace(profiler: &Profiler, path: &Path) -> io::Result<()> {
    write_chrome_trace_with(profiler, &[], path)
}

/// Write the trace (zones + counters + flow arrows) to `path`.
pub fn write_chrome_trace_full(
    profiler: &Profiler,
    counters: &[CounterTrack],
    flows: &[FlowEvent],
    path: &Path,
) -> io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, to_chrome_trace_full(profiler, counters, flows))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_balanced(s: &str) {
        let depth = s.chars().fold((0i32, 0i32), |(b, k), c| match c {
            '{' => (b + 1, k),
            '}' => (b - 1, k),
            '[' => (b, k + 1),
            ']' => (b, k - 1),
            _ => (b, k),
        });
        assert_eq!(depth, (0, 0));
    }

    #[test]
    fn emits_valid_minimal_json() {
        let mut p = Profiler::new();
        p.record("spmv", "device", 0.0, 1000.0);
        p.record("dot", "device", 1000.0, 1500.0);
        p.record("launch", "host", 0.0, 200.0);
        let s = to_chrome_trace(&p);
        // Structural checks (no serde; keep it honest with a parser-lite).
        assert!(s.starts_with('{') && s.ends_with('}'));
        assert_eq!(s.matches("\"ph\":\"X\"").count(), 3);
        assert_eq!(s.matches("thread_name").count(), 2);
        assert!(s.contains("\"name\":\"spmv\""));
        assert!(s.contains("\"dur\":1.000"));
        assert_balanced(&s);
    }

    #[test]
    fn escaping_quotes() {
        let mut p = Profiler::new();
        p.record("we\"ird", "sc\\ope", 0.0, 1.0);
        let s = to_chrome_trace(&p);
        assert!(s.contains("we\\\"ird"));
        assert!(s.contains("sc\\\\ope"));
    }

    #[test]
    fn escaping_newlines_tabs_and_controls() {
        let mut p = Profiler::new();
        p.record("multi\nline", "tab\there", 0.0, 1.0);
        p.record("bell\u{7}", "device", 0.0, 1.0);
        let s = to_chrome_trace(&p);
        assert!(s.contains("multi\\nline"));
        assert!(s.contains("tab\\there"));
        assert!(s.contains("bell\\u0007"));
        // No raw control characters may survive into the JSON text.
        assert!(!s.chars().any(|c| (c as u32) < 0x20));
        assert_balanced(&s);
    }

    #[test]
    fn processes_and_threads_are_named_and_sorted() {
        let mut p = Profiler::new();
        p.record("spmv", "(0,0)", 0.0, 10.0);
        p.record("halo:eth0-1", "ethernet", 0.0, 5.0);
        p.record("launch", "host", 0.0, 1.0);
        let s = to_chrome_trace(&p);
        // Three processes in use, each named with a sort index.
        assert_eq!(s.matches("process_name").count(), 3);
        assert_eq!(s.matches("process_sort_index").count(), 3);
        assert!(s.contains("\"args\":{\"name\":\"device\"}"));
        assert!(s.contains("\"args\":{\"name\":\"ethernet\"}"));
        assert!(s.contains("\"args\":{\"name\":\"host\"}"));
        // Ethernet scope lands on the ethernet process, host on host.
        assert_eq!(s.matches("thread_sort_index").count(), 3);
        assert!(s.contains(
            "{\"name\":\"halo:eth0-1\",\"ph\":\"X\",\"pid\":2,\"tid\":1"
        ));
        assert!(s.contains("{\"name\":\"launch\",\"ph\":\"X\",\"pid\":3,\"tid\":1"));
        assert_balanced(&s);
    }

    #[test]
    fn counter_tracks_emit_c_events() {
        let mut p = Profiler::new();
        p.record("spmv", "device", 0.0, 1000.0);
        let tracks = vec![CounterTrack {
            name: "residual".to_string(),
            samples: vec![(0.0, 1.0), (1000.0, 0.25)],
        }];
        let s = to_chrome_trace_with(&p, &tracks);
        assert_eq!(s.matches("\"ph\":\"C\"").count(), 2);
        assert!(s.contains("{\"name\":\"residual\",\"ph\":\"C\",\"pid\":4,\"tid\":0,\"ts\":1.000,\"args\":{\"value\":0.25}}"));
        // Counter process is named.
        assert!(s.contains("\"args\":{\"name\":\"counters\"}"));
        // No counters → no counter process metadata.
        let s2 = to_chrome_trace(&p);
        assert!(!s2.contains("counters"));
        assert_balanced(&s);
    }

    #[test]
    fn flow_events_emit_s_f_pairs_on_scope_threads() {
        let mut p = Profiler::new();
        p.record("spmv", "device", 0.0, 1000.0);
        p.record("halo:eth0-1", "ethernet", 200.0, 600.0);
        let flows = vec![FlowEvent {
            name: "compute->eth:halo".to_string(),
            id: 1,
            from_scope: "device".to_string(),
            from_ts: 200.0,
            to_scope: "ethernet".to_string(),
            to_ts: 200.0,
        }];
        let s = to_chrome_trace_full(&p, &[], &flows);
        assert_eq!(s.matches("\"ph\":\"s\"").count(), 1);
        assert_eq!(s.matches("\"ph\":\"f\"").count(), 1);
        assert!(s.contains("\"ph\":\"s\",\"id\":1,\"pid\":1,\"tid\":1,\"ts\":0.200"));
        assert!(s.contains("\"ph\":\"f\",\"bp\":\"e\",\"id\":1,\"pid\":2,\"tid\":1,\"ts\":0.200"));
        assert_balanced(&s);
        // No flows → byte-identical to the plain writer.
        assert_eq!(to_chrome_trace_full(&p, &[], &[]), to_chrome_trace_with(&p, &[]));
    }

    #[test]
    fn flow_endpoint_scopes_get_threads_without_zones() {
        let mut p = Profiler::new();
        p.record("spmv", "device", 0.0, 1000.0);
        let flows = vec![FlowEvent {
            name: "launch->work".to_string(),
            id: 7,
            from_scope: "host".to_string(),
            from_ts: 0.0,
            to_scope: "device".to_string(),
            to_ts: 0.0,
        }];
        let s = to_chrome_trace_full(&p, &[], &flows);
        // The host process/thread exists purely from the flow endpoint.
        assert!(s.contains("\"args\":{\"name\":\"host\"}"));
        assert!(s.contains("\"ph\":\"s\",\"id\":7,\"pid\":3,\"tid\":1"));
        assert_balanced(&s);
    }

    #[test]
    fn writes_file() {
        let mut p = Profiler::new();
        p.record("z", "host", 0.0, 5.0);
        let dir = std::env::temp_dir().join("wormsim_trace_test");
        let path = dir.join("t.json");
        write_chrome_trace(&p, &path).unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("traceEvents"));
        write_chrome_trace_with(
            &p,
            &[CounterTrack {
                name: "c".to_string(),
                samples: vec![(0.0, 1.0)],
            }],
            &path,
        )
        .unwrap();
        assert!(std::fs::read_to_string(&path).unwrap().contains("\"ph\":\"C\""));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
