//! Component breakdown reports (Fig 13).

use std::collections::BTreeMap;

use crate::timing::SimNs;
use crate::util::table::Table;

/// Per-component time breakdown for one solver configuration, in
/// nanoseconds per iteration. The Fig-13 components are `norm`, `dot`,
/// `axpy`, `spmv`; `other` captures launch/readback/sync time that the
/// paper's device-side Tracy zones do not include (§7.3 notes the zone sum
/// is about half the measured per-iteration time on Wormhole).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Breakdown {
    pub components: BTreeMap<String, SimNs>,
    pub iterations: u64,
}

impl Breakdown {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, ns: SimNs) {
        *self.components.entry(name.to_string()).or_insert(0.0) += ns;
    }

    pub fn get(&self, name: &str) -> SimNs {
        self.components.get(name).copied().unwrap_or(0.0)
    }

    /// Per-iteration value of one component.
    pub fn per_iter(&self, name: &str) -> SimNs {
        if self.iterations == 0 {
            0.0
        } else {
            self.get(name) / self.iterations as f64
        }
    }

    /// Sum of all components (per iteration).
    pub fn total_per_iter(&self) -> SimNs {
        if self.iterations == 0 {
            return 0.0;
        }
        self.components.values().sum::<f64>() / self.iterations as f64
    }

    /// Render the Fig-13 style rows: component, time/iter, share.
    pub fn render(&self, title: &str) -> String {
        let mut t = Table::new(title, &["component", "time/iter", "share"]);
        let total = self.total_per_iter().max(1e-30);
        for (name, _) in &self.components {
            let v = self.per_iter(name);
            t.row(vec![
                name.clone(),
                crate::util::stats::fmt_ns(v),
                format!("{:.1}%", 100.0 * v / total),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_and_normalizes() {
        let mut b = Breakdown::new();
        b.add("spmv", 100.0);
        b.add("spmv", 100.0);
        b.add("dot", 50.0);
        b.iterations = 2;
        assert_eq!(b.per_iter("spmv"), 100.0);
        assert_eq!(b.per_iter("dot"), 25.0);
        assert_eq!(b.total_per_iter(), 125.0);
    }

    #[test]
    fn zero_iterations_safe() {
        let b = Breakdown::new();
        assert_eq!(b.per_iter("x"), 0.0);
        assert_eq!(b.total_per_iter(), 0.0);
    }

    #[test]
    fn renders_shares() {
        let mut b = Breakdown::new();
        b.add("spmv", 75.0);
        b.add("dot", 25.0);
        b.iterations = 1;
        let s = b.render("test");
        assert!(s.contains("75.0%"));
        assert!(s.contains("25.0%"));
    }
}
