//! Tracy-like profiler over *simulated* time (§3.4).
//!
//! The paper instruments host and device code with Tracy zones and
//! visualizes per-core activity; it also times components by "removing
//! portions of the algorithm and timing the remainder". We reproduce the
//! zone mechanism over simulated nanoseconds: kernels open zones per
//! component (norm/dot/axpy/spmv/...), per core or per launch, and reports
//! aggregate them into the Fig-13-style component breakdown.

pub mod report;
pub mod trace;
pub mod zones;

pub use report::Breakdown;
pub use trace::{
    to_chrome_trace, to_chrome_trace_full, to_chrome_trace_with, write_chrome_trace,
    write_chrome_trace_full, write_chrome_trace_with, CounterTrack, FlowEvent,
};
pub use zones::{Profiler, Zone};
