//! Zone recording over simulated time.

use std::collections::BTreeMap;

use crate::timing::SimNs;

/// A closed profiling zone.
#[derive(Debug, Clone, PartialEq)]
pub struct Zone {
    /// Component name ("spmv", "dot", "axpy", "norm", "halo", ...).
    pub name: String,
    /// Optional core label ("(r,c)") or "host".
    pub scope: String,
    pub start: SimNs,
    pub end: SimNs,
}

impl Zone {
    pub fn duration(&self) -> SimNs {
        self.end - self.start
    }
}

/// Collects zones during a simulated run.
///
/// The enabled state is explicit at construction ([`Profiler::with_enabled`]);
/// `new()`, `default()`, and `disabled()` are the three spellings of it, and
/// `default()` == `new()` (enabled) — the derived `Default` used to disagree
/// with `new()` by starting disabled.
#[derive(Debug)]
pub struct Profiler {
    pub enabled: bool,
    zones: Vec<Zone>,
}

impl Default for Profiler {
    fn default() -> Self {
        Self::new()
    }
}

impl Profiler {
    /// The single constructor: every other constructor routes through here.
    pub fn with_enabled(enabled: bool) -> Self {
        Self {
            enabled,
            zones: Vec::new(),
        }
    }

    pub fn new() -> Self {
        Self::with_enabled(true)
    }

    /// A disabled profiler records nothing (the paper observes that
    /// extensive zone tracing perturbs performance; we keep the same
    /// on/off discipline even though simulated time is unperturbed).
    /// `record` checks `enabled` before pushing, so a disabled profiler
    /// allocates nothing on the hot path — pinned by
    /// `tests/prop_telemetry.rs::disabled_profiler_stays_empty_through_mesh_solve`.
    pub fn disabled() -> Self {
        Self::with_enabled(false)
    }

    pub fn record(&mut self, name: &str, scope: &str, start: SimNs, end: SimNs) {
        debug_assert!(end >= start, "zone '{name}' ends before it starts");
        if self.enabled {
            self.zones.push(Zone {
                name: name.to_string(),
                scope: scope.to_string(),
                start,
                end,
            });
        }
    }

    pub fn zones(&self) -> &[Zone] {
        &self.zones
    }

    /// Total time per component name (summed across scopes).
    pub fn totals_by_name(&self) -> BTreeMap<String, SimNs> {
        let mut m = BTreeMap::new();
        for z in &self.zones {
            *m.entry(z.name.clone()).or_insert(0.0) += z.duration();
        }
        m
    }

    /// Per-scope timeline (sorted by start) — the Tracy per-core view.
    pub fn timeline(&self, scope: &str) -> Vec<&Zone> {
        let mut v: Vec<&Zone> = self.zones.iter().filter(|z| z.scope == scope).collect();
        v.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
        v
    }

    pub fn clear(&mut self) {
        self.zones.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let mut p = Profiler::new();
        p.record("spmv", "(0,0)", 0.0, 10.0);
        p.record("spmv", "(0,1)", 0.0, 12.0);
        p.record("dot", "(0,0)", 10.0, 15.0);
        let totals = p.totals_by_name();
        assert_eq!(totals["spmv"], 22.0);
        assert_eq!(totals["dot"], 5.0);
        assert_eq!(p.zones().len(), 3);
    }

    #[test]
    fn timeline_is_sorted_per_scope() {
        let mut p = Profiler::new();
        p.record("b", "(0,0)", 5.0, 6.0);
        p.record("a", "(0,0)", 1.0, 2.0);
        p.record("c", "(1,1)", 0.0, 1.0);
        let tl = p.timeline("(0,0)");
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[0].name, "a");
        assert_eq!(tl[1].name, "b");
    }

    #[test]
    fn disabled_records_nothing() {
        let mut p = Profiler::disabled();
        p.record("spmv", "host", 0.0, 1.0);
        assert!(p.zones().is_empty());
        assert!(p.totals_by_name().is_empty());
    }

    #[test]
    fn default_is_enabled_like_new() {
        assert!(Profiler::default().enabled);
        assert!(Profiler::new().enabled);
        assert!(Profiler::with_enabled(true).enabled);
        assert!(!Profiler::with_enabled(false).enabled);
        assert!(!Profiler::disabled().enabled);
    }
}
