//! Minimal JSON parser/serializer for bench snapshots and telemetry, in the
//! same offline-substrate spirit as [`crate::util::tomlmini`] (the image
//! vendors no serde).  Supports the full JSON value grammar; numbers are
//! parsed as `f64`, which is exact for every integer the snapshots carry.

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Look up `key` in an object; `None` for other variants or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing content at byte {}", p.pos));
        }
        Ok(v)
    }

    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                out.push_str(&escape(s));
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    out.push_str(&escape(k));
                    out.push_str("\":");
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_json_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }
}

/// Escape a string for embedding inside JSON double quotes.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn literal(&mut self, lit: &str) -> Result<(), String> {
        let end = self.pos + lit.len();
        if self.bytes.get(self.pos..end) == Some(lit.as_bytes()) {
            self.pos = end;
            Ok(())
        } else {
            Err(format!("expected '{lit}' at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.literal("null").map(|_| Json::Null),
            Some(b't') => self.literal("true").map(|_| Json::Bool(true)),
            Some(b'f') => self.literal("false").map(|_| Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            )),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "invalid utf8 in number".to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{text}' at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| "truncated \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => {
                            return Err(format!("bad escape {:?}", other.map(|c| c as char)))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one full UTF-8 character.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf8 in string".to_string())?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            pairs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let v = Json::parse(
            r#"{"name":"pcg","n":[1,2.5,-3e2],"ok":true,"none":null,"sub":{"k":"v"}}"#,
        )
        .unwrap();
        assert_eq!(v.get("name").and_then(Json::as_str), Some("pcg"));
        let arr = v.get("n").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("none"), Some(&Json::Null));
        assert_eq!(
            v.get("sub").and_then(|s| s.get("k")).and_then(Json::as_str),
            Some("v")
        );
    }

    #[test]
    fn round_trips_escapes_and_unicode() {
        let original = Json::Obj(vec![(
            "weird \"name\"\\with\nnewline\ttab".to_string(),
            Json::Str("π ≈ 3.14159".to_string()),
        )]);
        let text = original.to_json_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, original);
        // \u escapes parse too.
        let v = Json::parse(r#""a\u0041\u00e9""#).unwrap();
        assert_eq!(v, Json::Str("aAé".to_string()));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} trailing").is_err());
        assert!(Json::parse("nope").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n \"a\" : [ 1 , 2 ] \t}\r\n").unwrap();
        assert_eq!(v.get("a").and_then(Json::as_arr).map(|a| a.len()), Some(2));
    }

    #[test]
    fn control_characters_escape_and_round_trip() {
        // Every C0 control character must serialize to a \-escape (the
        // short forms for \n \r \t, \u00xx for the rest) — a raw control
        // byte inside quotes is invalid JSON.
        let all_controls: String = (0u32..0x20).map(|c| char::from_u32(c).unwrap()).collect();
        let text = Json::Str(all_controls.clone()).to_json_string();
        for b in text.bytes() {
            assert!(b >= 0x20, "raw control byte {b:#04x} in serialized string");
        }
        assert!(text.contains("\\u0000"));
        assert!(text.contains("\\u001f"));
        assert!(text.contains("\\n") && text.contains("\\r") && text.contains("\\t"));
        assert_eq!(Json::parse(&text).unwrap(), Json::Str(all_controls));
        // \b and \f short escapes parse back to the control chars too.
        assert_eq!(
            Json::parse(r#""\b\f""#).unwrap(),
            Json::Str("\u{8}\u{c}".to_string())
        );
    }

    #[test]
    fn non_finite_floats_serialize_as_null() {
        // JSON has no NaN/Infinity literals; the writer degrades them to
        // null rather than emitting an unparsable document.
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(v).to_json_string(), "null");
        }
        let doc = Json::Arr(vec![Json::Num(1.0), Json::Num(f64::NAN), Json::Num(2.0)]);
        let text = doc.to_json_string();
        assert_eq!(text, "[1,null,2]");
        // And the degraded form still parses.
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back,
            Json::Arr(vec![Json::Num(1.0), Json::Null, Json::Num(2.0)])
        );
    }

    #[test]
    fn nested_arrays_round_trip() {
        let doc = Json::Arr(vec![
            Json::Arr(vec![]),
            Json::Arr(vec![Json::Arr(vec![Json::Num(1.0), Json::Num(-2.5)])]),
            Json::Obj(vec![(
                "rows".to_string(),
                Json::Arr(vec![
                    Json::Arr(vec![Json::Str("a\nb".to_string()), Json::Null]),
                    Json::Arr(vec![Json::Bool(false)]),
                ]),
            )]),
        ]);
        let text = doc.to_json_string();
        assert_eq!(Json::parse(&text).unwrap(), doc);
        // Serialization is a fixed point: parse -> write is byte-stable.
        assert_eq!(Json::parse(&text).unwrap().to_json_string(), text);
    }
}
