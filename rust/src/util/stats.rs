//! Small statistics helpers used by the bench harness and experiment
//! runners (criterion is unavailable offline; see DESIGN.md §4 S20).

/// Summary statistics over a sample of measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
}

impl Summary {
    pub fn from_samples(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "summary of empty sample set");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for speedup aggregation across problem sizes).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Relative error |a-b| / max(|b|, eps).
pub fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-30)
}

/// Human formatting for nanosecond quantities.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Human formatting for byte quantities.
pub fn fmt_bytes(b: f64) -> String {
    if b < 1024.0 {
        format!("{b:.0} B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile_sorted(&xs, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile_sorted(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500.0 ns");
        assert_eq!(fmt_ns(1_500.0), "1.50 us");
        assert_eq!(fmt_ns(2_500_000.0), "2.500 ms");
        assert_eq!(fmt_bytes(2048.0), "2.0 KiB");
    }

    #[test]
    #[should_panic]
    fn summary_rejects_empty() {
        let _ = Summary::from_samples(&[]);
    }
}
