//! Aligned plain-text table rendering for terminal output of the paper's
//! tables and figure data series.

#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: Some(title.to_string()),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncols)
                .map(|i| format!(" {:<w$} ", cells[i], w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(vec!["short".into(), "1".into()]);
        t.row(vec!["a-much-longer-name".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("name"));
        // all data rows same width
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
