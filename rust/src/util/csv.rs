//! Tiny CSV writer for experiment results (`results/*.csv`).
//!
//! Each experiment runner emits one CSV whose rows mirror exactly what is
//! printed to the terminal, so figures can be re-plotted externally.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "csv row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", escape_row(&self.header));
        for r in &self.rows {
            let _ = writeln!(out, "{}", escape_row(r));
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: &Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_string())
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }
}

fn escape_cell(cell: &str) -> String {
    if cell.contains([',', '"', '\n']) {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

fn escape_row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| escape_cell(c))
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into(), "2".into()]);
        w.row(&["x,y".into(), "q\"z".into()]);
        let s = w.to_string();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "1,2");
        assert_eq!(lines[2], "\"x,y\",\"q\"\"z\"");
        assert_eq!(w.n_rows(), 2);
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["1".into()]);
    }

    #[test]
    fn writes_to_disk() {
        let dir = std::env::temp_dir().join("wormsim_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::new(&["x"]);
        w.row(&["7".into()]);
        w.write(&path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert!(back.contains("7"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
