//! Minimal TOML-subset parser for experiment/launcher configs
//! (`configs/*.toml`). Supports `[section]`, `key = value` with string,
//! integer, float, boolean, and `"8x7"`-style values, plus `#` comments.
//! serde/toml are unavailable offline; this covers exactly what the config
//! system needs and fails loudly on anything else.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// A parsed document: section name -> key -> value. Keys before any
/// `[section]` land in the "" (root) section.
#[derive(Debug, Default, Clone)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(format!("line {}: malformed section '{raw}'", lineno + 1));
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected 'key = value', got '{raw}'", lineno + 1))?;
            let key = key.trim().to_string();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let val = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key)?.as_str()
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key)?.as_int()
    }

    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.as_float()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key)?.as_bool()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is respected.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Result<Value, String> {
    if v.is_empty() {
        return Err("empty value".to_string());
    }
    if v.starts_with('"') {
        if v.len() < 2 || !v.ends_with('"') {
            return Err(format!("unterminated string {v}"));
        }
        return Ok(Value::Str(v[1..v.len() - 1].to_string()));
    }
    match v {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = v.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{v}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
            # top comment
            name = "pcg"        # inline comment
            [solver]
            grid = "8x7"
            tiles = 64
            tol = 1e-6
            fused = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("", "name"), Some("pcg"));
        assert_eq!(doc.get_str("solver", "grid"), Some("8x7"));
        assert_eq!(doc.get_int("solver", "tiles"), Some(64));
        assert!((doc.get_float("solver", "tol").unwrap() - 1e-6).abs() < 1e-18);
        assert_eq!(doc.get_bool("solver", "fused"), Some(true));
    }

    #[test]
    fn hash_in_string_kept() {
        let doc = Doc::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.get_str("", "s"), Some("a#b"));
    }

    #[test]
    fn errors_are_located() {
        let e = Doc::parse("x\n").unwrap_err();
        assert!(e.contains("line 1"), "{e}");
        let e = Doc::parse("[bad\n").unwrap_err();
        assert!(e.contains("malformed section"), "{e}");
        let e = Doc::parse("k = @@\n").unwrap_err();
        assert!(e.contains("cannot parse"), "{e}");
    }

    #[test]
    fn int_vs_float_distinction() {
        let doc = Doc::parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(doc.get_int("", "a"), Some(3));
        assert_eq!(doc.get_int("", "b"), None);
        assert_eq!(doc.get_float("", "b"), Some(3.5));
        // int degrades to float on request
        assert_eq!(doc.get_float("", "a"), Some(3.0));
    }
}
