//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use this: warmup, repeated timed runs, and a
//! summary line per benchmark, plus CSV output under `results/bench/`.
//! Measurements are wall-clock for host-side (L3) code paths; *simulated*
//! device time is reported separately by the experiment runners.

use std::time::Instant;

use crate::util::csv::CsvWriter;
use crate::util::stats::{fmt_ns, Summary};

pub struct BenchConfig {
    pub warmup_iters: usize,
    pub sample_count: usize,
    pub iters_per_sample: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Keep benches quick; env overrides for careful runs.
        let env = |k: &str, d: usize| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        Self {
            warmup_iters: env("WORMSIM_BENCH_WARMUP", 3),
            sample_count: env("WORMSIM_BENCH_SAMPLES", 10),
            iters_per_sample: env("WORMSIM_BENCH_ITERS", 1),
        }
    }
}

pub struct BenchResult {
    pub name: String,
    /// Wall-clock summary of per-iteration time, nanoseconds.
    pub wall_ns: Summary,
    /// Optional simulated device time per iteration, nanoseconds.
    pub sim_ns: Option<f64>,
}

pub struct Bencher {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
    suite: String,
}

impl Bencher {
    pub fn new(suite: &str) -> Self {
        println!("== bench suite: {suite} ==");
        Self {
            cfg: BenchConfig::default(),
            results: Vec::new(),
            suite: suite.to_string(),
        }
    }

    /// Time `f`, which performs one logical iteration and may return a
    /// simulated-time figure (ns) to report alongside wall clock.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut() -> Option<f64>) {
        let mut sim_ns = None;
        for _ in 0..self.cfg.warmup_iters {
            sim_ns = f().or(sim_ns);
        }
        let mut samples = Vec::with_capacity(self.cfg.sample_count);
        for _ in 0..self.cfg.sample_count {
            let t0 = Instant::now();
            for _ in 0..self.cfg.iters_per_sample {
                sim_ns = f().or(sim_ns);
            }
            samples.push(t0.elapsed().as_nanos() as f64 / self.cfg.iters_per_sample as f64);
        }
        let wall = Summary::from_samples(&samples);
        match sim_ns {
            Some(s) => println!(
                "{name:<48} wall {:>12} ± {:>10}   sim {:>12}",
                fmt_ns(wall.mean),
                fmt_ns(wall.std_dev),
                fmt_ns(s)
            ),
            None => println!(
                "{name:<48} wall {:>12} ± {:>10}",
                fmt_ns(wall.mean),
                fmt_ns(wall.std_dev)
            ),
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            wall_ns: wall,
            sim_ns,
        });
    }

    /// Machine-readable snapshot of this suite's results: the simulated-time
    /// channel as directional metrics (lower is better), wall-clock means as
    /// contextual info only (host timing varies by machine and load, so it
    /// must never trip `bench-diff`).
    pub fn snapshot(&self) -> crate::telemetry::BenchSnapshot {
        use crate::telemetry::{BenchSnapshot, Better};
        let mut s = BenchSnapshot::new(&self.suite);
        for r in &self.results {
            let labels = [("bench", r.name.as_str())];
            if let Some(sim) = r.sim_ns {
                s.push("sim_ns", &labels, sim, "ns", Better::Lower);
            }
            s.push("wall_mean_ns", &labels, r.wall_ns.mean, "ns", Better::Info);
        }
        s
    }

    /// Write the suite results as CSV and print a footer. Call at the end of
    /// every bench main().
    pub fn finish(self) {
        let mut csv = CsvWriter::new(&[
            "bench", "wall_mean_ns", "wall_std_ns", "wall_min_ns", "wall_p95_ns", "sim_ns",
        ]);
        for r in &self.results {
            csv.row(&[
                r.name.clone(),
                format!("{:.1}", r.wall_ns.mean),
                format!("{:.1}", r.wall_ns.std_dev),
                format!("{:.1}", r.wall_ns.min),
                format!("{:.1}", r.wall_ns.p95),
                r.sim_ns.map(|s| format!("{s:.1}")).unwrap_or_default(),
            ]);
        }
        let path = std::path::Path::new("results/bench").join(format!("{}.csv", self.suite));
        match csv.write(&path) {
            Ok(()) => println!("== wrote {} ==", path.display()),
            Err(e) => println!("== failed to write {}: {e} ==", path.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_records() {
        std::env::set_var("WORMSIM_BENCH_SAMPLES", "3");
        let mut b = Bencher::new("selftest");
        let mut acc = 0u64;
        b.bench("trivial", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
            Some(123.0)
        });
        assert_eq!(b.results.len(), 1);
        assert_eq!(b.results[0].sim_ns, Some(123.0));
        assert!(b.results[0].wall_ns.mean >= 0.0);
        // Snapshot: sim channel is directional, wall is info-only.
        let s = b.snapshot();
        assert_eq!(s.name, "selftest");
        let sim = s.find("sim_ns{bench=trivial}").unwrap();
        assert_eq!(sim.value, 123.0);
        assert_eq!(sim.better, crate::telemetry::Better::Lower);
        let wall = s.find("wall_mean_ns{bench=trivial}").unwrap();
        assert_eq!(wall.better, crate::telemetry::Better::Info);
        std::env::remove_var("WORMSIM_BENCH_SAMPLES");
    }
}
