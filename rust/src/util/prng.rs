//! Deterministic pseudo-random number generation.
//!
//! The offline image ships no `rand` crate, so we implement SplitMix64 and
//! xoshiro256++ from the published reference algorithms (Blackman & Vigna).
//! These power workload generation, property-based testing, and the
//! randomized solver problems. Determinism matters: every experiment in
//! EXPERIMENTS.md cites a seed so runs are reproducible.

/// SplitMix64: used to seed xoshiro and for cheap one-off streams.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — the main generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Build from a 64-bit seed via SplitMix64 (the canonical seeding recipe).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased for our use).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply rejection-free approximation; bias is < 2^-64 * n,
        // negligible for simulation workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (used to build well-conditioned RHS).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_uniform_f32(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf.iter_mut() {
            *v = lo + (hi - lo) * self.next_f32();
        }
    }

    /// Random boolean with probability `p` of `true`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Choose a random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the published algorithm.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism: same seed, same stream.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_deterministic_and_distinct() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let mut r3 = Rng::new(43);
        let s1: Vec<u64> = (0..16).map(|_| r1.next_u64()).collect();
        let s2: Vec<u64> = (0..16).map(|_| r2.next_u64()).collect();
        let s3: Vec<u64> = (0..16).map(|_| r3.next_u64()).collect();
        assert_eq!(s1, s2);
        assert_ne!(s1, s3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn normal_mean_and_var_reasonable() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
