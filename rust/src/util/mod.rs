//! Offline substrate utilities built from scratch (the image vendors only
//! the `xla` crate closure — no clap/criterion/serde/proptest/rand/tokio).
//! See DESIGN.md §4 S20.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod fsatomic;
pub mod jsonmini;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod table;
pub mod tomlmini;
