//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports the subset the `wormsim` launcher needs: subcommands,
//! `--flag`, `--key value`, `--key=value`, and positional arguments, with
//! generated usage text and typed accessors.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub values: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Parse `--key` through the type's single `FromStr` impl, falling
    /// back to `default` when absent. This is the one parse path for
    /// enum-valued options (engine kind, PCG variant, route pattern) —
    /// callers must not open-code string matches next to it.
    pub fn get_parsed<T>(&self, key: &str, default: &str) -> Result<T, String>
    where
        T: std::str::FromStr<Err = String>,
    {
        self.get_or(key, default).parse()
    }

    /// Parse "8x7" style grid specs.
    pub fn get_grid(&self, key: &str, default: (usize, usize)) -> Result<(usize, usize), String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => parse_grid(v),
        }
    }
}

pub fn parse_grid(v: &str) -> Result<(usize, usize), String> {
    let parts: Vec<&str> = v.split(['x', 'X']).collect();
    if parts.len() != 2 {
        return Err(format!("expected RxC grid spec like '8x7', got '{v}'"));
    }
    let r = parts[0]
        .trim()
        .parse()
        .map_err(|_| format!("bad grid rows in '{v}'"))?;
    let c = parts[1]
        .trim()
        .parse()
        .map_err(|_| format!("bad grid cols in '{v}'"))?;
    Ok((r, c))
}

/// Parse "512x112x64" style 3D extents.
pub fn parse_dims3(v: &str) -> Result<(usize, usize, usize), String> {
    let parts: Vec<&str> = v.split(['x', 'X']).collect();
    if parts.len() != 3 {
        return Err(format!("expected NxNxN dims like '512x112x64', got '{v}'"));
    }
    let p = |s: &str| -> Result<usize, String> {
        s.trim().parse().map_err(|_| format!("bad dimension in '{v}'"))
    };
    Ok((p(parts[0])?, p(parts[1])?, p(parts[2])?))
}

/// Tokenize argv (after the subcommand) into an `Args`.
/// Flags listed in `flag_names` are boolean; everything else `--key` takes a
/// value. Unknown `--keys` are an error so typos fail fast.
pub fn parse(
    argv: &[String],
    value_keys: &[&str],
    flag_names: &[&str],
) -> Result<Args, String> {
    let mut args = Args::default();
    let mut i = 0;
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(stripped) = tok.strip_prefix("--") {
            let (key, inline_val) = match stripped.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (stripped.to_string(), None),
            };
            if flag_names.contains(&key.as_str()) {
                if inline_val.is_some() {
                    return Err(format!("flag --{key} does not take a value"));
                }
                args.flags.push(key);
            } else if value_keys.contains(&key.as_str()) {
                let val = match inline_val {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| format!("--{key} expects a value"))?
                    }
                };
                args.values.insert(key, val);
            } else {
                return Err(format!("unknown option --{key}"));
            }
        } else {
            args.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let a = parse(
            &sv(&["--grid", "8x7", "--verbose", "fig5", "--tiles=64"]),
            &["grid", "tiles"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.get("grid"), Some("8x7"));
        assert_eq!(a.get_usize("tiles", 0).unwrap(), 64);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["fig5".to_string()]);
    }

    #[test]
    fn unknown_key_is_error() {
        assert!(parse(&sv(&["--nope", "1"]), &["grid"], &[]).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&sv(&["--grid"]), &["grid"], &[]).is_err());
    }

    #[test]
    fn grid_and_dims_parsing() {
        assert_eq!(parse_grid("8x7").unwrap(), (8, 7));
        assert_eq!(parse_dims3("512x112x64").unwrap(), (512, 112, 64));
        assert!(parse_grid("8").is_err());
        assert!(parse_dims3("8x7").is_err());
        assert!(parse_grid("axb").is_err());
    }

    #[test]
    fn get_parsed_routes_through_fromstr() {
        let a = parse(&sv(&["--engine", "pjrt"]), &["engine"], &[]).unwrap();
        let k: crate::engine::EngineKind = a.get_parsed("engine", "native").unwrap();
        assert_eq!(k, crate::engine::EngineKind::Pjrt);
        let d: crate::engine::EngineKind = a.get_parsed("missing-key", "native").unwrap();
        assert_eq!(d, crate::engine::EngineKind::Native);
        let bad = parse(&sv(&["--engine", "cuda"]), &["engine"], &[]).unwrap();
        assert!(bad.get_parsed::<crate::engine::EngineKind>("engine", "native").is_err());
    }

    #[test]
    fn mesh_topology_routes_through_fromstr() {
        // The launcher's --dies/--topology options share the one enum
        // parse path with every other enum-valued option.
        let a = parse(&sv(&["--dies", "4", "--topology", "ring"]), &["dies", "topology"], &[]).unwrap();
        assert_eq!(a.get_usize("dies", 1).unwrap(), 4);
        let t: crate::device::MeshTopology = a.get_parsed("topology", "line").unwrap();
        assert_eq!(t, crate::device::MeshTopology::Ring);
        let d: crate::device::MeshTopology = a.get_parsed("missing", "line").unwrap();
        assert_eq!(d, crate::device::MeshTopology::Line);
        assert!("torus".parse::<crate::device::MeshTopology>().is_err());
    }

    #[test]
    fn typed_accessors_defaults() {
        let a = parse(&sv(&[]), &["n"], &[]).unwrap();
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_f64("n", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_or("n", "x"), "x");
    }

    #[test]
    fn flag_with_value_is_error() {
        assert!(parse(&sv(&["--verbose=1"]), &[], &["verbose"]).is_err());
    }
}
