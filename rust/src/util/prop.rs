//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! Provides generator combinators over [`crate::util::prng::Rng`] and a
//! `check` runner with failure-case reporting plus naive shrinking for
//! integer-vector inputs. Used by `rust/tests/prop_invariants.rs` and
//! module-level property tests on routing, tiles, batching, and solver
//! state invariants.

use crate::util::prng::Rng;

/// Number of cases per property (overridable via WORMSIM_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("WORMSIM_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(128)
}

/// A generator of values of type T.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Rng) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Self { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.f)(rng)
    }

    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |r| g((self.f)(r)))
    }
}

/// usize in [lo, hi] inclusive.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(hi >= lo);
    Gen::new(move |r| lo + r.below((hi - lo + 1) as u64) as usize)
}

/// f32 uniform in [lo, hi).
pub fn f32_in(lo: f32, hi: f32) -> Gen<f32> {
    Gen::new(move |r| lo + (hi - lo) * r.next_f32())
}

/// f32 from a "nasty" distribution: normals, subnormals, zeros, extremes.
/// Exercises the BF16 flush-to-zero path.
pub fn f32_nasty() -> Gen<f32> {
    Gen::new(|r| match r.below(8) {
        0 => 0.0,
        1 => -0.0,
        2 => f32::MIN_POSITIVE / 2.0, // subnormal
        3 => -f32::MIN_POSITIVE / 4.0,
        4 => 1e30,
        5 => -1e-30,
        6 => (r.next_f32() - 0.5) * 2e3,
        _ => (r.next_f32() - 0.5) * 2.0,
    })
}

/// Vec of length in [min_len, max_len] from an element generator.
pub fn vec_of<T: 'static>(elem: Gen<T>, min_len: usize, max_len: usize) -> Gen<Vec<T>> {
    assert!(max_len >= min_len);
    Gen::new(move |r| {
        let n = min_len + r.below((max_len - min_len + 1) as u64) as usize;
        (0..n).map(|_| elem.sample(r)).collect()
    })
}

/// Pair of two generators.
pub fn pair<A: 'static, B: 'static>(a: Gen<A>, b: Gen<B>) -> Gen<(A, B)> {
    Gen::new(move |r| (a.sample(r), b.sample(r)))
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult {
    Ok { cases: usize },
    Failed { case: usize, message: String },
}

/// Run `prop` against `cases` random inputs from `gen`; panics with a
/// seed-reproducible report on failure.
pub fn check<T: std::fmt::Debug + 'static>(
    name: &str,
    seed: u64,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let cases = default_cases();
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed}):\n  \
                 input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Like `check`, but the property returns bool.
pub fn check_bool<T: std::fmt::Debug + 'static>(
    name: &str,
    seed: u64,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    check(name, seed, gen, |t| {
        if prop(t) {
            Ok(())
        } else {
            Err("predicate returned false".to_string())
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let g = vec_of(usize_in(0, 100), 0, 32);
        check("sum-ge-max", 1, &g, |v| {
            let sum: usize = v.iter().sum();
            let max = v.iter().copied().max().unwrap_or(0);
            if sum >= max {
                Ok(())
            } else {
                Err(format!("sum {sum} < max {max}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        let g = usize_in(0, 10);
        check_bool("always-fails", 2, &g, |_| false);
    }

    #[test]
    fn nasty_floats_cover_subnormals() {
        let g = f32_nasty();
        let mut rng = Rng::new(3);
        let mut saw_subnormal = false;
        let mut saw_zero = false;
        for _ in 0..1000 {
            let x = g.sample(&mut rng);
            if x != 0.0 && x.abs() < f32::MIN_POSITIVE {
                saw_subnormal = true;
            }
            if x == 0.0 {
                saw_zero = true;
            }
        }
        assert!(saw_subnormal && saw_zero);
    }

    #[test]
    fn generators_deterministic_per_seed() {
        let g = vec_of(f32_in(-1.0, 1.0), 1, 8);
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        for _ in 0..10 {
            assert_eq!(g.sample(&mut r1), g.sample(&mut r2));
        }
    }
}
