//! Atomic file writes (temp-then-rename).
//!
//! Bench snapshots and telemetry JSONL are consumed by other processes
//! (`bench-diff`, trace viewers, CI artifact uploads). A plain
//! `fs::write` interrupted mid-flush leaves a truncated file that those
//! consumers choke on; [`write_atomic`] stages the contents in a
//! sibling `.tmp` file and renames it into place, so the destination is
//! only ever absent, the previous complete version, or the new complete
//! version — never half-written. The rename stays within the target's
//! directory (same filesystem), where POSIX rename is atomic.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The staging path a write to `path` uses: a dot-prefixed `.tmp`
/// sibling in the same directory.
pub fn staging_path(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "out".to_string());
    path.with_file_name(format!(".{name}.tmp"))
}

/// Write `contents` to `path` atomically: stage in the sibling
/// [`staging_path`], then rename over the destination. On any error the
/// staging file is removed and `path` is untouched.
pub fn write_atomic(path: &Path, contents: &str) -> io::Result<()> {
    let tmp = staging_path(path);
    fs::write(&tmp, contents)?;
    match fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = fs::remove_file(&tmp);
            Err(e)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn destination_is_absent_or_complete_never_truncated() {
        let dir = std::env::temp_dir().join("wormsim_fsatomic_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let _ = std::fs::remove_file(&path);
        // Simulate an interrupted write: the staging file holds a torn
        // prefix, the rename never happened. The destination must not
        // exist — a consumer polling for it sees nothing, not garbage.
        std::fs::write(staging_path(&path), "{\"trunca").unwrap();
        assert!(!path.exists(), "half-written stage must not surface at the destination");
        // A completed write replaces the stage with the full contents.
        write_atomic(&path, "{\"ok\":true}\n").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\"ok\":true}\n");
        assert!(!staging_path(&path).exists(), "stage cleaned up after rename");
        // Overwrites go through the same stage: the destination is the
        // old complete version until the instant it is the new one.
        write_atomic(&path, "{\"ok\":false}\n").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{\"ok\":false}\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn staging_stays_in_the_destination_directory() {
        let p = Path::new("/a/b/BENCH_pcg.json");
        let s = staging_path(p);
        assert_eq!(s.parent(), p.parent());
        assert_eq!(s.file_name().unwrap().to_str().unwrap(), ".BENCH_pcg.json.tmp");
    }
}
