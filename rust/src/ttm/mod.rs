//! A tt-metal-shaped host programming layer (§3).
//!
//! tt-metal programs consist of a host program that allocates buffers,
//! builds `Program`s out of per-core kernels (two NoC data-movement
//! kernels + one compute kernel), enqueues them on a command queue, and
//! synchronizes. This module models that structure and its costs:
//! program construction, per-launch dispatch overhead, and the
//! fused-vs-split launch accounting that differentiates the paper's two
//! PCG variants (§7.1).

pub mod exec;
pub mod launch;
pub mod program;

pub use exec::{stencil_tile_kernel, KernelStats, TileHalos};
pub use launch::{HostQueue, LaunchStats};
pub use program::{KernelRole, KernelSpec, Program};
