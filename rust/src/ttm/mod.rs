//! A tt-metal-shaped host programming layer (§3).
//!
//! tt-metal programs consist of a host program that allocates buffers,
//! builds `Program`s out of per-core kernels (two NoC data-movement
//! kernels + one compute kernel), enqueues them on a command queue, and
//! synchronizes. This module models that structure and its costs as the
//! repo's single execution pipeline:
//!
//! 1. every kernel **lowers** to a [`Program`] (kernel specs + the
//!    per-core [`Workload`] + a resource [`Footprint`]);
//! 2. [`HostQueue::run`] **dispatches** it — charging the per-enqueue
//!    launch overhead exactly once — and [`exec::execute_program`]
//!    produces the per-phase device timing (NoC data movement, RISC-V
//!    element loops, compute pipeline, DRAM staging, reductions) and the
//!    per-role profiler zones;
//! 3. iterative solvers derive their fused-vs-split launch accounting
//!    (§7.1) from an [`IterSchedule`] over the component programs;
//!    [`Program::fuse`] merges them under the §7.2 SRAM budget.
//!
//! No kernel or solver module computes dispatch, gap, or readback costs
//! itself; those constants are only applied here.

pub mod exec;
pub mod launch;
pub mod program;

pub use exec::{
    execute_program, execute_program_with, stencil_tile_kernel, KernelStats, ProgramOutcome,
    TileHalos,
};
pub use launch::{CrossDep, HostQueue, IterSchedule, LaunchStats, SolveSpans};
pub use program::{
    EthHop, EtherPhase, Footprint, FusedProgram, KernelRole, KernelSpec, NocSend, OverlapMode,
    Program, ReduceSpec, Schedule, SendQueue, Workload,
};
