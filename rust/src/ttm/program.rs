//! Programs and kernel specs (the tt-metal structural model), plus the
//! lowered per-core workload the scheduler executes.
//!
//! A [`Program`] is the unit of dispatch: the reader/compute/writer
//! [`KernelSpec`]s launched together on the sub-grid, the per-core
//! [`Workload`] those kernels perform (NoC sends, RISC-V element loops,
//! compute-pipeline cycles, DRAM staging, an optional global reduction),
//! and a resource [`Footprint`]. Kernels *lower* to this IR
//! (`kernels::{eltwise, reduction, stencil, spmv}` each provide a
//! `lower_*` constructor); the scheduler in [`crate::ttm::exec`] +
//! [`crate::ttm::launch`] is the only place dispatch overhead, per-phase
//! timing, and profiler zones are produced.
//!
//! Multi-die workloads additionally carry an **interior/boundary split**
//! of their per-core cycles (`boundary_*_cycles`: the chain that
//! consumes inter-die seam data, always a carve-out of the same totals)
//! and an [`OverlapMode`] telling the scheduler whether the boundary
//! chain is charged serially after the [`EtherPhase`] (the paper's
//! model) or pipelined concurrently with the interior chain. Ethernet
//! hops themselves execute through the [`crate::device::EthSim`]
//! per-link occupancy tracker, so concurrent hops sharing a physical
//! link serialize instead of riding independent pipes.
//!
//! [`Program::fuse`] merges compatible per-iteration programs into a
//! [`FusedProgram`] — the §7.1 fused-kernel PCG — subject to an SRAM
//! capacity check on the binding per-core footprint.

use crate::device::mesh::{EthLink, EthSim};
use crate::device::Coord;
use crate::noc::RoutePattern;
use crate::timing::SimNs;

/// How an overlapping Ethernet phase composes with the per-core local
/// phase (the §8 seam-hiding rule the scheduler applies):
///
/// - **Serial** (the default, and the paper's model): the dependent
///   RISC-V + compute chain is charged entirely after the seam lands —
///   `end = max(local, eth + riscv + compute)`.
/// - **Pipelined**: the lowering split each core's cycles into an
///   *interior* chain (independent of the seam) and a *boundary* chain
///   (consumes seam data); the boundary chain runs concurrently with the
///   interior chain as soon as the Ethernet phase drains —
///   each core ends at `max(interior, eth) + boundary` (only the seam
///   *wait* is hidden — the boundary compute still runs on the core's
///   one pipeline) — the software pipeline real multi-die stencils use
///   (seam of iteration k+1 under interior compute of iteration k).
///
/// Programs whose workload carries no boundary split (or no overlapping
/// Ethernet phase) time identically in both modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverlapMode {
    #[default]
    Serial,
    Pipelined,
}

impl OverlapMode {
    pub fn label(self) -> &'static str {
        match self {
            OverlapMode::Serial => "serial",
            OverlapMode::Pipelined => "pipelined",
        }
    }
}

impl std::str::FromStr for OverlapMode {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "serial" => Ok(OverlapMode::Serial),
            "pipelined" | "pipeline" | "overlap" => Ok(OverlapMode::Pipelined),
            _ => Err(format!("unknown overlap mode '{s}' (expected serial|pipelined)")),
        }
    }
}

/// Which communication-avoiding iteration schedule the solver drives
/// through its [`crate::ttm::IterSchedule`] (ROADMAP item 3; the knob
/// that attacks the Ethernet terms the critical-path analyzer blamed for
/// the strong-scaling knee):
///
/// - **Classic**: the paper's back-to-back component order — halo, two
///   scalar all-reduces, every iteration.
/// - **Prefetch**: iteration k+1's halo `EtherPhase` issues during
///   iteration k's dot/axpy tail (a cross-*component* dependency edge,
///   generalizing `OverlapMode::Pipelined`'s intra-component hiding).
///   Values are bit-identical to Classic and the solve is never slower —
///   both property-pinned.
/// - **SStep(s)**: the s-step/pipelined-CG recurrence — one *combined*
///   Gram all-reduce per block of s iterations instead of 2s scalar
///   rounds, paying extra compute-bound axpy flops for the Ethernet
///   latency term. Trajectories drift from Classic in higher-order
///   rounding terms only (property-bounded, not bit-identical).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Schedule {
    #[default]
    Classic,
    Prefetch,
    SStep(usize),
}

impl Schedule {
    pub fn label(self) -> String {
        match self {
            Schedule::Classic => "classic".to_string(),
            Schedule::Prefetch => "prefetch".to_string(),
            Schedule::SStep(s) => format!("sstep:{s}"),
        }
    }

    /// Scalar all-reduce rounds the schedule pays per PCG iteration:
    /// classic and prefetch keep Algorithm 1's three (dot, norm, dot);
    /// s-step folds a block's worth into one combined round.
    pub fn allreduce_rounds_per_iter(self) -> f64 {
        match self {
            Schedule::Classic | Schedule::Prefetch => 3.0,
            Schedule::SStep(s) => 1.0 / s.max(1) as f64,
        }
    }
}

impl std::str::FromStr for Schedule {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "classic" => Ok(Schedule::Classic),
            "prefetch" => Ok(Schedule::Prefetch),
            other => {
                if let Some(step) = other.strip_prefix("sstep:") {
                    let k: usize = step.parse().map_err(|_| {
                        format!("bad s-step block size '{step}' in schedule '{s}'")
                    })?;
                    if !(2..=8).contains(&k) {
                        return Err(format!(
                            "s-step block size must be in 2..=8 (monomial-basis conditioning), got {k}"
                        ));
                    }
                    Ok(Schedule::SStep(k))
                } else {
                    Err(format!(
                        "unknown schedule '{s}' (expected classic|prefetch|sstep:<s>)"
                    ))
                }
            }
        }
    }
}

/// Which baby RISC-V a kernel runs on (§3): the two NoC data-movement
/// cores, or the compute cores collectively.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelRole {
    /// NoC core 0: DRAM/NoC → SRAM ("reader").
    Reader,
    /// NoC core 1: SRAM → DRAM/NoC ("writer").
    Writer,
    /// The three compute-side RISC-Vs driving unpack/math/pack.
    Compute,
}

/// Description of one device kernel within a program.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelSpec {
    pub name: String,
    pub role: KernelRole,
    /// Compile-time args (tile counts, CB indices, ...), recorded for
    /// reporting parity with tt-metal's kernel args.
    pub ct_args: Vec<(String, String)>,
}

impl KernelSpec {
    pub fn new(name: &str, role: KernelRole) -> Self {
        Self {
            name: name.to_string(),
            role,
            ct_args: Vec::new(),
        }
    }

    pub fn arg(mut self, key: &str, value: impl std::fmt::Display) -> Self {
        self.ct_args.push((key.to_string(), value.to_string()));
        self
    }
}

/// One asynchronous NoC write issued by a data-movement RISC-V.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NocSend {
    pub src: Coord,
    pub dst: Coord,
    pub bytes: u64,
    /// Cold transactions pay the full `noc_issue_cycles`; warm follow-ups
    /// in a batched loop pay `noc_batch_issue_cycles` (§6.3).
    pub cold: bool,
}

/// The sends one core's writer RISC-V issues, in program order.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SendQueue {
    pub sends: Vec<NocSend>,
}

/// Global tree-reduction + broadcast phase (the dot kernel's network
/// part, §5): executed by the scheduler after every core's local phase.
#[derive(Debug, Clone, PartialEq)]
pub struct ReduceSpec {
    pub pattern: RoutePattern,
    /// Payload per tree edge (one 32 B scalar beat, or a whole tile).
    pub payload_bytes: u64,
    /// Cycles to merge one inbound partial at a receiving core.
    pub merge_cycles: u64,
    /// Extra cycles at the root after the tree drains (§5.1 method-2
    /// final tile→scalar reduce).
    pub root_extra_cycles: u64,
    /// Result broadcast payload (0 = no broadcast back).
    pub bcast_bytes: u64,
}

/// One Ethernet transfer between two dies within an inter-die round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EthHop {
    pub src_die: usize,
    pub dst_die: usize,
    pub bytes: u64,
}

/// The inter-die Ethernet phase of a program (§8 multi-device scaling):
/// sequential *rounds* of concurrent link transfers, derived by the
/// lowering from a [`crate::device::DeviceMesh`] topology. Three step
/// shapes use it:
///
/// - **halo exchange** (`overlaps_local`): one round, one hop per loaded
///   link carrying both directions' seam bytes; it overlaps the NoC halo
///   phase, but the dependent compute cannot finish before the seam data
///   lands;
/// - **scalar combine + broadcast**: 2(N−1) single-hop rounds along the
///   chain (on a line, a reduction tree degenerates to exactly this);
/// - **ring all-reduce**: ⌈(N−1)/2⌉ both-ways combine rounds plus a both-ways
///   broadcast for scalar beats, or — for tile payloads
///   ([`EtherPhase::allreduce`]) — the segmented reduce-scatter +
///   all-gather whose per-round bandwidth term is bytes/N;
/// - **2D all-reduce** ([`EtherPhase::allreduce2d`], torus meshes): a
///   row phase (all die rows reduce concurrently) then a column phase,
///   O(√N) rounds per phase instead of O(N).
///
/// The scheduler ([`crate::ttm::exec::execute_program`]) is the only
/// place this phase is turned into time, alongside NoC and compute —
/// every hop via the [`EthSim`] per-link occupancy tracker, so hops
/// sharing a physical link serialize ([`EtherPhase::run`]).
#[derive(Debug, Clone, PartialEq)]
pub struct EtherPhase {
    /// Reporting label ("halo", "allreduce", ...).
    pub label: String,
    /// Dies the phase spans (hop indices must stay below this).
    pub n_dies: usize,
    /// Uniform link model (per-topology preset from `arch::specs`).
    pub link: EthLink,
    /// Sequential rounds; hops within a round run concurrently on their
    /// links.
    pub rounds: Vec<Vec<EthHop>>,
    /// Whether the phase overlaps the local NoC/compute phase (halo
    /// exchange) or strictly follows it (reductions).
    pub overlaps_local: bool,
}

impl EtherPhase {
    /// Halo-shaped phase: route each (src_die, dst_die, bytes) flow along
    /// the mesh's link path and load every traversed link; all loaded
    /// links transfer concurrently in one round (each die pair owns its
    /// own wires). Opposite directions of one link share its usable rate,
    /// so their bytes accumulate — exactly the dual-die seam model.
    /// Returns `None` when no flow crosses a link (single-die meshes).
    pub fn halo(
        label: &str,
        mesh: &crate::device::DeviceMesh,
        flows: &[(usize, usize, u64)],
    ) -> Option<Self> {
        use std::collections::BTreeMap;
        let mut per_link: BTreeMap<(usize, usize), u64> = BTreeMap::new();
        for &(a, b, bytes) in flows {
            if bytes == 0 {
                continue;
            }
            for link in mesh.path(a, b) {
                *per_link.entry(link).or_insert(0) += bytes;
            }
        }
        if per_link.is_empty() {
            return None;
        }
        let round: Vec<EthHop> = per_link
            .into_iter()
            .map(|((a, b), bytes)| EthHop { src_die: a, dst_die: b, bytes })
            .collect();
        Some(Self {
            label: label.to_string(),
            n_dies: mesh.n_dies,
            link: mesh.link,
            rounds: vec![round],
            overlaps_local: true,
        })
    }

    /// Scalar combine + broadcast across the mesh (the dot products'
    /// network step past the per-die NoC reduction). One 32 B beat per
    /// hop — [`EtherPhase::allreduce`] with the minimum payload.
    /// Returns `None` on a single die.
    pub fn scalar_allreduce(mesh: &crate::device::DeviceMesh) -> Option<Self> {
        Self::allreduce(mesh, 32)
    }

    /// All-reduce of a `payload_bytes` partial across the mesh. Two
    /// shapes, picked by what dominates the link cost:
    ///
    /// - **latency-bound** (payloads of one 32 B beat, or any payload on
    ///   a line): combine down the chain, broadcast back — 2(N−1)
    ///   single-hop rounds, each carrying the whole payload; a ring
    ///   folds *and* broadcasts both ways around the wrap link, paying
    ///   2⌈(N−1)/2⌉ rounds — about half the chain's.
    /// - **bandwidth-bound** (payloads above one beat on a ring of
    ///   N > 2): the classic ring all-reduce — a reduce-scatter plus an
    ///   all-gather of 2(N−1) rounds, each round all N links carrying
    ///   one ⌈payload/N⌉ segment (32 B-beat aligned), so the per-round
    ///   bandwidth term is bytes/N. This is what makes
    ///   [`crate::kernels::DotMethod::SendTiles`] tile payloads honest
    ///   across dies (ROADMAP "mesh-aware reductions at tile
    ///   granularity").
    ///
    /// Returns `None` on a single die. A 2D torus mesh routes through
    /// [`EtherPhase::allreduce2d`] (row phase then column phase), which
    /// is the whole point of the topology: O(√N) rounds per phase
    /// instead of O(N).
    pub fn allreduce(mesh: &crate::device::DeviceMesh, payload_bytes: u64) -> Option<Self> {
        if matches!(mesh.topology, crate::device::MeshTopology::Torus2D { .. }) {
            return Self::allreduce2d(mesh, payload_bytes);
        }
        let n = mesh.n_dies;
        if n < 2 {
            return None;
        }
        let closed = mesh.topology == crate::device::MeshTopology::Ring && n > 2;
        let members: Vec<usize> = (0..n).collect();
        Some(Self {
            label: "allreduce".to_string(),
            n_dies: n,
            link: mesh.link,
            rounds: allreduce_rounds(&members, closed, payload_bytes),
            overlaps_local: false,
        })
    }

    /// 2D all-reduce over a torus die grid: a **row phase** — every die
    /// row runs its own 1D all-reduce concurrently (all rows' round-k
    /// hops share one round; their links are disjoint) — then a
    /// **column phase** that all-reduces the now row-complete partials
    /// down every column. Each phase is the 1D shape over √N-ish
    /// members (closed whenever that dimension has a wrap link), so a
    /// 4×8 torus pays 8 + 4 = 12 scalar rounds where the 32-ring pays
    /// 32 and the line 62. Degenerate 1×N / N×1 shapes produce exactly
    /// the 1D ring's rounds. Returns `None` on a single die or a
    /// non-torus mesh.
    pub fn allreduce2d(mesh: &crate::device::DeviceMesh, payload_bytes: u64) -> Option<Self> {
        let crate::device::MeshTopology::Torus2D { rows, cols } = mesh.topology else {
            return None;
        };
        if mesh.n_dies < 2 {
            return None;
        }
        let mut rounds: Vec<Vec<EthHop>> = Vec::new();
        let mut merge = |groups: Vec<Vec<usize>>, closed: bool| {
            let per_group: Vec<Vec<Vec<EthHop>>> = groups
                .iter()
                .map(|members| allreduce_rounds(members, closed, payload_bytes))
                .collect();
            let n_rounds = per_group.iter().map(|r| r.len()).max().unwrap_or(0);
            for k in 0..n_rounds {
                rounds.push(per_group.iter().filter_map(|r| r.get(k)).flatten().copied().collect());
            }
        };
        if cols > 1 {
            merge(
                (0..rows).map(|r| (0..cols).map(|c| mesh.die_at(r, c)).collect()).collect(),
                cols > 2,
            );
        }
        if rows > 1 {
            merge(
                (0..cols).map(|c| (0..rows).map(|r| mesh.die_at(r, c)).collect()).collect(),
                rows > 2,
            );
        }
        Some(Self {
            label: "allreduce2d".to_string(),
            n_dies: mesh.n_dies,
            link: mesh.link,
            rounds,
            overlaps_local: false,
        })
    }

    /// Drive the phase through a per-link occupancy tracker starting at
    /// `start`: rounds are serial (a round begins when the previous one
    /// fully drains), hops within a round start together — but hops
    /// sharing a physical link serialize on it, and a sim carried across
    /// phases makes earlier traffic (e.g. a halo still draining) delay
    /// this one honestly. Returns the completion time.
    pub fn run(&self, sim: &mut EthSim, start: SimNs) -> SimNs {
        let mut cursor = start;
        for round in &self.rounds {
            let mut round_end = cursor;
            for hop in round {
                let done = sim.transfer(&self.link, hop.src_die, hop.dst_die, hop.bytes, cursor);
                round_end = round_end.max(done);
            }
            cursor = round_end;
        }
        cursor
    }

    /// Phase duration under the contended-link model (a fresh
    /// [`EthSim`]): identical to the old independent-pipe sum of
    /// per-round maxima whenever no round loads one link twice.
    pub fn duration_ns(&self) -> f64 {
        self.run(&mut EthSim::new(), 0.0)
    }

    /// The latency-bound portion of [`duration_ns`](Self::duration_ns):
    /// rounds are serial, so each pays at least one fixed per-hop link
    /// latency on its busiest link (more when one round loads a link
    /// twice — hops sharing a wire serialize and each pays its own
    /// latency). This is the term the what-if `eth_lat=` knob scales,
    /// separately from the payload term `eth_bw=` covers: scalar
    /// all-reduces are almost pure latency, halo rounds mostly payload.
    pub fn chain_latency_ns(&self) -> f64 {
        self.rounds
            .iter()
            .map(|round| {
                let mut per_link: std::collections::BTreeMap<(usize, usize), u64> =
                    std::collections::BTreeMap::new();
                for h in round {
                    let key = (h.src_die.min(h.dst_die), h.src_die.max(h.dst_die));
                    *per_link.entry(key).or_insert(0) += 1;
                }
                per_link.values().copied().max().unwrap_or(0) as f64 * self.link.latency_ns
            })
            .sum()
    }

    /// The phase with every hop endpoint remapped through `adopt` (a
    /// dead die's hops handed to the surviving die that adopted its
    /// subdomain): hops collapsing to a self-loop are dropped (that
    /// traffic became die-local), same-pair hops within a round merge
    /// their bytes, and rounds emptied entirely vanish. Returns `None`
    /// when nothing still crosses a link. An empty `adopt` map returns
    /// the phase unchanged.
    pub fn remapped(&self, adopt: &std::collections::BTreeMap<usize, usize>) -> Option<Self> {
        if adopt.is_empty() {
            return Some(self.clone());
        }
        let owner = |d: usize| adopt.get(&d).copied().unwrap_or(d);
        let mut rounds: Vec<Vec<EthHop>> = Vec::new();
        for round in &self.rounds {
            let mut per_pair: std::collections::BTreeMap<(usize, usize), u64> =
                std::collections::BTreeMap::new();
            for h in round {
                let (s, d) = (owner(h.src_die), owner(h.dst_die));
                if s != d {
                    *per_pair.entry((s, d)).or_insert(0) += h.bytes;
                }
            }
            if !per_pair.is_empty() {
                rounds.push(
                    per_pair
                        .into_iter()
                        .map(|((s, d), bytes)| EthHop { src_die: s, dst_die: d, bytes })
                        .collect(),
                );
            }
        }
        if rounds.is_empty() {
            return None;
        }
        Some(Self { rounds, ..self.clone() })
    }

    /// The phase with every hop routed over the mesh's live links: a
    /// hop whose direct link is down expands into the store-and-forward
    /// chain along [`crate::device::DeviceMesh::path`], and a round
    /// containing multi-link hops becomes one sub-round per path
    /// segment (segment i of every expanded hop travels in sub-round i,
    /// so unaffected hops keep their intra-round concurrency and
    /// detoured payloads forward one link per sub-round). A mesh with
    /// no down links returns the phase unchanged.
    pub fn rerouted(&self, mesh: &crate::device::DeviceMesh) -> Self {
        if mesh.down.is_empty() {
            return self.clone();
        }
        let mut rounds: Vec<Vec<EthHop>> = Vec::new();
        for round in &self.rounds {
            let expanded: Vec<Vec<EthHop>> = round
                .iter()
                .map(|h| {
                    let mut cur = h.src_die;
                    mesh.path(h.src_die, h.dst_die)
                        .into_iter()
                        .map(|(x, y)| {
                            let next = if x == cur { y } else { x };
                            let seg = EthHop { src_die: cur, dst_die: next, bytes: h.bytes };
                            cur = next;
                            seg
                        })
                        .collect()
                })
                .collect();
            let depth = expanded.iter().map(|p| p.len()).max().unwrap_or(0);
            for k in 0..depth {
                rounds.push(expanded.iter().filter_map(|p| p.get(k)).copied().collect());
            }
        }
        Self { rounds, ..self.clone() }
    }

    /// Total bytes crossing Ethernet in one application of the phase.
    pub fn bytes(&self) -> u64 {
        self.rounds.iter().flatten().map(|h| h.bytes).sum()
    }

    /// Total link messages in one application of the phase.
    pub fn messages(&self) -> u64 {
        self.rounds.iter().map(|r| r.len() as u64).sum()
    }
}

/// The rounds of a 1D all-reduce over an ordered group of `members`
/// (die ids): the exact shapes [`EtherPhase::allreduce`] has always
/// produced, generalized from dies `0..n` to arbitrary member lists so a
/// 2D torus can run one per die row/column. `closed` marks a ring (the
/// last member links back to the first): tile payloads then use the
/// segmented ring all-reduce, and scalar beats fold and broadcast both
/// ways around the wrap; open chains combine down and broadcast back up.
fn allreduce_rounds(members: &[usize], closed: bool, payload_bytes: u64) -> Vec<Vec<EthHop>> {
    let n = members.len();
    if n < 2 {
        return Vec::new();
    }
    if closed && payload_bytes > 32 {
        // Segmented ring all-reduce: round r, every member d forwards
        // one segment to member (d+1) mod n; all n links busy each
        // round. Segments align up to the 32 B beat (§3.3).
        let seg = (payload_bytes.div_ceil(n as u64)).div_ceil(32) * 32;
        let round: Vec<EthHop> = (0..n)
            .map(|d| EthHop { src_die: members[d], dst_die: members[(d + 1) % n], bytes: seg })
            .collect();
        return vec![round; 2 * (n - 1)];
    }
    let beat = payload_bytes;
    let mut rounds: Vec<Vec<EthHop>> = Vec::new();
    if closed {
        // Combine both ways around the ring toward member 0: a forward
        // arc …→m2→m1→m0 and a backward arc m_s→m_(s+1)→…→m(n−1)→m0
        // (closing over the wrap link) fold concurrently, mirroring the
        // both-ways broadcast below — ⌈(n−1)/2⌉ rounds instead of the
        // open chain's n−1. Disjoint links per round: the arcs never
        // share an edge, and the two final hops into m0 use the first
        // and the wrap link.
        let fwd_len = (n - 1).div_ceil(2); // members 1..=fwd_len
        let bwd_len = n - 1 - fwd_len; // members fwd_len+1..n
        for t in 0..fwd_len {
            let d = fwd_len - t;
            let mut round =
                vec![EthHop { src_die: members[d], dst_die: members[d - 1], bytes: beat }];
            if t < bwd_len {
                let d = fwd_len + 1 + t;
                round.push(EthHop {
                    src_die: members[d],
                    dst_die: members[(d + 1) % n],
                    bytes: beat,
                });
            }
            rounds.push(round);
        }
    } else {
        // Combine: member d folds its partial into d−1's accumulator.
        for d in (1..n).rev() {
            rounds.push(vec![EthHop {
                src_die: members[d],
                dst_die: members[d - 1],
                bytes: beat,
            }]);
        }
    }
    if closed {
        // Broadcast both ways around the ring from the first member: a
        // forward wave m0→m1→m2→… and a backward wave m0→m(n−1)→m(n−2)→…
        // (over the wrap link) meet in the middle.
        let mut fwd = 0usize; // highest member the forward wave reached
        let mut bwd = n; // lowest member the backward wave reached (n = none)
        while fwd + 1 < bwd {
            let mut round =
                vec![EthHop { src_die: members[fwd], dst_die: members[fwd + 1], bytes: beat }];
            fwd += 1;
            if bwd - 1 > fwd {
                round.push(EthHop {
                    src_die: members[bwd % n],
                    dst_die: members[bwd - 1],
                    bytes: beat,
                });
                bwd -= 1;
            }
            rounds.push(round);
        }
    } else {
        // Broadcast back up the chain.
        for d in 0..n - 1 {
            rounds.push(vec![EthHop { src_die: members[d], dst_die: members[d + 1], bytes: beat }]);
        }
    }
    rounds
}

/// The lowered per-core device work of one program application. Produced
/// by kernel lowerings; consumed only by the scheduler.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    /// Sub-grid shape (rows, cols); cores are indexed row-major.
    pub grid: (usize, usize),
    /// NoC sends grouped per sending core, issued sequentially per core.
    pub data_movement: Vec<SendQueue>,
    /// Per-core DRAM staging bytes, charged before the local phase.
    pub dram_bytes: Vec<u64>,
    /// Per-core baby-RISC-V element-loop cycles (zero fills, indexed
    /// gather/scatter tile assembly).
    pub riscv_cycles: Vec<u64>,
    /// Per-core compute-pipeline cycles (tile ops).
    pub compute_cycles: Vec<u64>,
    /// Per-core portion of `riscv_cycles` that consumes inter-die seam
    /// data (the *boundary* chain of the interior/boundary split; entry
    /// `i` must not exceed `riscv_cycles[i]`). Empty = no split.
    pub boundary_riscv_cycles: Vec<u64>,
    /// Per-core portion of `compute_cycles` that consumes inter-die seam
    /// data (entry `i` must not exceed `compute_cycles[i]`).
    pub boundary_compute_cycles: Vec<u64>,
    /// How an overlapping Ethernet phase composes with the split chains.
    pub overlap: OverlapMode,
    /// Optional global reduction after the local phase.
    pub reduce: Option<ReduceSpec>,
    /// Optional inter-die Ethernet phase (multi-die programs only).
    pub ether: Option<EtherPhase>,
    /// How many ns before this program's device start its overlapping
    /// `ether` phase was issued (the cross-iteration prefetch window: the
    /// halo of iteration k+1 launched under iteration k's dot/axpy tail).
    /// 0 = issued at program start (classic). Only meaningful for an
    /// overlapping phase; the scheduler subtracts the already-elapsed
    /// lead from the exposed seam wait, so a larger lead never slows the
    /// program down.
    pub ether_lead_ns: SimNs,
}

impl Default for Workload {
    fn default() -> Self {
        Self {
            grid: (1, 1),
            data_movement: Vec::new(),
            dram_bytes: Vec::new(),
            riscv_cycles: Vec::new(),
            compute_cycles: Vec::new(),
            boundary_riscv_cycles: Vec::new(),
            boundary_compute_cycles: Vec::new(),
            overlap: OverlapMode::Serial,
            reduce: None,
            ether: None,
            ether_lead_ns: 0.0,
        }
    }
}

impl Workload {
    pub fn n_cores(&self) -> usize {
        self.grid.0 * self.grid.1
    }

    /// Row-major core index of a grid coordinate.
    pub fn core_index(&self, c: Coord) -> usize {
        c.row * self.grid.1 + c.col
    }
}

/// Resource/traffic footprint of one program application.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Footprint {
    /// Resident vector tiles per core.
    pub tiles_per_core: usize,
    /// Largest per-core SRAM working set, bytes (checked by
    /// [`Program::fuse`] against the fused-kernel budget).
    pub sram_bytes: usize,
    /// Bytes one application moves (DRAM staging + NoC + result
    /// writeback) — the single traffic number per program.
    pub traffic_bytes: u64,
    /// Bytes one application moves over inter-die Ethernet links (zero
    /// for single-die programs).
    pub eth_bytes: u64,
}

/// A program: the set of kernels launched together on the sub-grid.
/// tt-metal launches all three kernels concurrently on every core; the
/// split-kernel PCG enqueues one `Program` per component per iteration,
/// the fused PCG a single program for the whole solve (§7.1).
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    pub name: String,
    pub kernels: Vec<KernelSpec>,
    pub work: Workload,
    pub footprint: Footprint,
}

impl Program {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            kernels: Vec::new(),
            work: Workload::default(),
            footprint: Footprint::default(),
        }
    }

    pub fn with_kernel(mut self, k: KernelSpec) -> Self {
        self.kernels.push(k);
        self
    }

    pub fn with_work(mut self, work: Workload) -> Self {
        self.work = work;
        self
    }

    pub fn with_footprint(mut self, footprint: Footprint) -> Self {
        self.footprint = footprint;
        self
    }

    /// The standard three-kernel shape (§3): reader + compute + writer.
    pub fn standard(name: &str) -> Self {
        Program::new(name)
            .with_kernel(KernelSpec::new(&format!("{name}_reader"), KernelRole::Reader))
            .with_kernel(KernelSpec::new(&format!("{name}_compute"), KernelRole::Compute))
            .with_kernel(KernelSpec::new(&format!("{name}_writer"), KernelRole::Writer))
    }

    /// Validate the tt-metal constraint: at most one kernel per role, and
    /// per-core workload vectors consistent with the sub-grid.
    pub fn validate(&self) -> crate::Result<()> {
        for role in [KernelRole::Reader, KernelRole::Writer, KernelRole::Compute] {
            let n = self.kernels.iter().filter(|k| k.role == role).count();
            if n > 1 {
                return Err(crate::SimError::Other(format!(
                    "program '{}' has {n} kernels for role {role:?} (max 1 per core)",
                    self.name
                )));
            }
        }
        let n = self.work.n_cores();
        for (what, len) in [
            ("dram_bytes", self.work.dram_bytes.len()),
            ("riscv_cycles", self.work.riscv_cycles.len()),
            ("compute_cycles", self.work.compute_cycles.len()),
            ("boundary_riscv_cycles", self.work.boundary_riscv_cycles.len()),
            ("boundary_compute_cycles", self.work.boundary_compute_cycles.len()),
        ] {
            if len > n {
                return Err(crate::SimError::Other(format!(
                    "program '{}': {what} has {len} entries for {n} cores",
                    self.name
                )));
            }
        }
        // The boundary chain is a *split* of the per-core total, never
        // extra work: each entry must fit inside the matching total.
        for (what, boundary, total) in [
            ("riscv", &self.work.boundary_riscv_cycles, &self.work.riscv_cycles),
            ("compute", &self.work.boundary_compute_cycles, &self.work.compute_cycles),
        ] {
            for (i, &b) in boundary.iter().enumerate() {
                let t = total.get(i).copied().unwrap_or(0);
                if b > t {
                    return Err(crate::SimError::Other(format!(
                        "program '{}': core {i} boundary {what} chain ({b} cycles) exceeds its total ({t})",
                        self.name
                    )));
                }
            }
        }
        let (rows, cols) = self.work.grid;
        for queue in &self.work.data_movement {
            for s in &queue.sends {
                for c in [s.src, s.dst] {
                    if c.row >= rows || c.col >= cols {
                        return Err(crate::SimError::Other(format!(
                            "program '{}': NoC send touches core ({},{}) outside the {rows}x{cols} sub-grid",
                            self.name, c.row, c.col
                        )));
                    }
                }
            }
        }
        if let Some(eth) = &self.work.ether {
            for hop in eth.rounds.iter().flatten() {
                if hop.src_die == hop.dst_die
                    || hop.src_die >= eth.n_dies
                    || hop.dst_die >= eth.n_dies
                {
                    return Err(crate::SimError::Other(format!(
                        "program '{}': Ethernet hop {}->{} invalid for a {}-die mesh",
                        self.name, hop.src_die, hop.dst_die, eth.n_dies
                    )));
                }
            }
        }
        if !(self.work.ether_lead_ns >= 0.0 && self.work.ether_lead_ns.is_finite()) {
            return Err(crate::SimError::Other(format!(
                "program '{}': ether_lead_ns {} must be finite and non-negative",
                self.name, self.work.ether_lead_ns
            )));
        }
        if self.work.ether_lead_ns > 0.0
            && !self.work.ether.as_ref().is_some_and(|e| e.overlaps_local)
        {
            return Err(crate::SimError::Other(format!(
                "program '{}': ether_lead_ns set without an overlapping Ethernet phase to prefetch",
                self.name
            )));
        }
        Ok(())
    }

    /// Merge compatible per-iteration programs into one fused program
    /// (§7.1). Compatibility: every part targets the same sub-grid, and
    /// the binding per-core SRAM working set (the parts share the
    /// resident vector pool, so the largest part binds) fits
    /// `sram_budget` bytes.
    pub fn fuse(name: &str, parts: Vec<Program>, sram_budget: usize) -> crate::Result<FusedProgram> {
        let Some(first) = parts.first() else {
            return Err(crate::SimError::Other(format!(
                "fused program '{name}' needs at least one part"
            )));
        };
        let grid = first.work.grid;
        for p in &parts {
            p.validate()?;
            if p.work.grid != grid {
                return Err(crate::SimError::Other(format!(
                    "cannot fuse '{}' ({:?} grid) with '{}' ({:?} grid)",
                    first.name, grid, p.name, p.work.grid
                )));
            }
        }
        let sram = parts.iter().map(|p| p.footprint.sram_bytes).max().unwrap_or(0);
        if sram > sram_budget {
            return Err(crate::SimError::Other(format!(
                "fused program '{name}' needs {sram} B of SRAM per core, budget {sram_budget} B (§7.2)"
            )));
        }
        Ok(FusedProgram {
            name: name.to_string(),
            parts,
        })
    }
}

/// A fused program: per-iteration component programs merged into one
/// persistent device program, dispatched with a single host enqueue;
/// component boundaries inside it cost only the §7.3 device-side gap.
#[derive(Debug, Clone, PartialEq)]
pub struct FusedProgram {
    pub name: String,
    pub parts: Vec<Program>,
}

impl FusedProgram {
    /// Combined footprint: binding (max) SRAM working set, summed traffic.
    pub fn footprint(&self) -> Footprint {
        Footprint {
            tiles_per_core: self.parts.iter().map(|p| p.footprint.tiles_per_core).max().unwrap_or(0),
            sram_bytes: self.parts.iter().map(|p| p.footprint.sram_bytes).max().unwrap_or(0),
            traffic_bytes: self.parts.iter().map(|p| p.footprint.traffic_bytes).sum(),
            eth_bytes: self.parts.iter().map(|p| p.footprint.eth_bytes).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_program_shape() {
        let p = Program::standard("spmv");
        assert_eq!(p.kernels.len(), 3);
        p.validate().unwrap();
        assert!(p.kernels.iter().any(|k| k.role == KernelRole::Reader));
        assert!(p.kernels.iter().any(|k| k.role == KernelRole::Compute));
        assert!(p.kernels.iter().any(|k| k.role == KernelRole::Writer));
    }

    #[test]
    fn duplicate_role_rejected() {
        let p = Program::new("bad")
            .with_kernel(KernelSpec::new("a", KernelRole::Compute))
            .with_kernel(KernelSpec::new("b", KernelRole::Compute));
        assert!(p.validate().is_err());
    }

    #[test]
    fn kernel_args_recorded() {
        let k = KernelSpec::new("reader", KernelRole::Reader)
            .arg("num_tiles", 64)
            .arg("cb", "cb_in0");
        assert_eq!(k.ct_args.len(), 2);
        assert_eq!(k.ct_args[0], ("num_tiles".to_string(), "64".to_string()));
    }

    #[test]
    fn workload_shape_validated() {
        let mut p = Program::standard("x");
        p.work.grid = (1, 1);
        p.work.compute_cycles = vec![10, 20];
        assert!(p.validate().is_err());
    }

    #[test]
    fn out_of_grid_send_rejected() {
        let mut p = Program::standard("x");
        p.work.grid = (2, 2);
        p.work.data_movement = vec![SendQueue {
            sends: vec![NocSend {
                src: Coord::new(0, 0),
                dst: Coord::new(0, 2), // aliases core (1,0) row-major
                bytes: 32,
                cold: true,
            }],
        }];
        assert!(p.validate().is_err());
    }

    #[test]
    fn ether_phase_duration_and_validation() {
        let link = EthLink::default();
        let phase = EtherPhase {
            label: "halo".to_string(),
            n_dies: 3,
            link,
            rounds: vec![
                vec![
                    EthHop { src_die: 0, dst_die: 1, bytes: 1100 },
                    EthHop { src_die: 1, dst_die: 2, bytes: 2200 },
                ],
                vec![EthHop { src_die: 2, dst_die: 1, bytes: 0 }],
            ],
            overlaps_local: true,
        };
        // Round 1: concurrent hops, the bigger one binds; round 2: latency
        // only. Serial across rounds.
        let want = link.transfer_ns(2200) + link.transfer_ns(0);
        assert!((phase.duration_ns() - want).abs() < 1e-9);
        assert_eq!(phase.bytes(), 3300);
        assert_eq!(phase.messages(), 3);

        let mut p = Program::standard("mesh");
        p.work.ether = Some(phase);
        p.validate().unwrap();
        // Out-of-mesh or self hops are rejected.
        let mut bad = Program::standard("bad");
        bad.work.ether = Some(EtherPhase {
            label: "x".to_string(),
            n_dies: 2,
            link,
            rounds: vec![vec![EthHop { src_die: 0, dst_die: 2, bytes: 1 }]],
            overlaps_local: false,
        });
        assert!(bad.validate().is_err());
        let mut self_hop = Program::standard("self");
        self_hop.work.ether = Some(EtherPhase {
            label: "x".to_string(),
            n_dies: 2,
            link,
            rounds: vec![vec![EthHop { src_die: 1, dst_die: 1, bytes: 1 }]],
            overlaps_local: false,
        });
        assert!(self_hop.validate().is_err());
    }

    #[test]
    fn halo_phase_accumulates_per_link() {
        use crate::device::{DeviceMesh, MeshTopology};
        let mesh = DeviceMesh::new(3, 1, 2, MeshTopology::Line, EthLink::default()).unwrap();
        // Both directions of each seam share the link; unrelated seams run
        // concurrently in the one round.
        let phase = EtherPhase::halo(
            "halo",
            &mesh,
            &[(0, 1, 100), (1, 0, 100), (1, 2, 300), (2, 1, 300)],
        )
        .unwrap();
        assert!(phase.overlaps_local);
        assert_eq!(phase.rounds.len(), 1);
        assert_eq!(phase.bytes(), 800);
        let loaded: Vec<(usize, usize, u64)> = phase.rounds[0]
            .iter()
            .map(|h| (h.src_die, h.dst_die, h.bytes))
            .collect();
        assert_eq!(loaded, vec![(0, 1, 200), (1, 2, 600)]);
        assert!((phase.duration_ns() - mesh.link.transfer_ns(600)).abs() < 1e-9);
        // Single-die mesh: no phase at all.
        let single = DeviceMesh::n150(1, 2).unwrap();
        assert!(EtherPhase::halo("halo", &single, &[]).is_none());
    }

    #[test]
    fn scalar_allreduce_round_counts() {
        use crate::device::{DeviceMesh, MeshTopology};
        let link = EthLink::default();
        // N=2 line: one combine hop + one broadcast hop — exactly the
        // dual-die "one scalar hop + one broadcast".
        let n2 = DeviceMesh::n300(1, 1).unwrap();
        let p2 = EtherPhase::scalar_allreduce(&n2).unwrap();
        assert_eq!(p2.rounds.len(), 2);
        assert!(!p2.overlaps_local);
        assert!((p2.duration_ns() - 2.0 * link.transfer_ns(32)).abs() < 1e-9);

        // Line N=4: 3 combine + 3 broadcast rounds.
        let l4 = DeviceMesh::new(4, 1, 1, MeshTopology::Line, link).unwrap();
        assert_eq!(EtherPhase::scalar_allreduce(&l4).unwrap().rounds.len(), 6);
        // Ring N=4: combine and broadcast both fold both ways around the
        // wrap — 2 + 2 rounds vs the line's 3 + 3.
        let r4 = DeviceMesh::new(4, 1, 1, MeshTopology::Ring, link).unwrap();
        let pr = EtherPhase::scalar_allreduce(&r4).unwrap();
        assert_eq!(pr.rounds.len(), 4);
        pr.rounds.iter().flatten().for_each(|h| assert_eq!(h.bytes, 32));
        // The combine's two arcs land every partial at die 0: the forward
        // arc 2→1→0 and the wrap hop 3→0.
        let combine_hops: Vec<(usize, usize)> =
            pr.rounds[..2].iter().flatten().map(|h| (h.src_die, h.dst_die)).collect();
        assert_eq!(combine_hops, vec![(2, 1), (3, 0), (1, 0)]);
        // Every die is reached by the broadcast.
        let reached: std::collections::BTreeSet<usize> =
            pr.rounds[2..].iter().flatten().map(|h| h.dst_die).collect();
        assert_eq!(reached, (1..4).collect());
        // Single die: no network step.
        assert!(EtherPhase::scalar_allreduce(&DeviceMesh::n150(1, 1).unwrap()).is_none());
    }

    #[test]
    fn allreduce2d_row_then_column_rounds() {
        use crate::device::{DeviceMesh, MeshTopology};
        let link = EthLink::default();
        // 2×2 torus: 2 row rounds (both rows concurrent) + 2 column
        // rounds — vs 6 on the 4-die line, 4 on the ring.
        let t22 = DeviceMesh::new(
            4,
            1,
            1,
            MeshTopology::Torus2D { rows: 2, cols: 2 },
            link,
        )
        .unwrap();
        let p = EtherPhase::scalar_allreduce(&t22).unwrap();
        assert_eq!(p.label, "allreduce2d");
        assert!(!p.overlaps_local);
        assert_eq!(p.rounds.len(), 4);
        // Round 0 carries both rows' combines on disjoint links.
        assert_eq!(
            p.rounds[0],
            vec![
                EthHop { src_die: 1, dst_die: 0, bytes: 32 },
                EthHop { src_die: 3, dst_die: 2, bytes: 32 },
            ]
        );
        // Column phase reduces the row-complete partials down column 0/1.
        assert_eq!(
            p.rounds[2],
            vec![
                EthHop { src_die: 2, dst_die: 0, bytes: 32 },
                EthHop { src_die: 3, dst_die: 1, bytes: 32 },
            ]
        );
        // Duration: 4 latency-bound beats, no link loaded twice per round.
        assert!((p.duration_ns() - 4.0 * link.transfer_ns(32)).abs() < 1e-9);

        // Galaxy 4×8: 8 row rounds (4 both-ways combine + 4 both-ways
        // bcast on each closed row ring) + 4 column rounds — vs 32 on
        // the 1D 32-ring and 62 on the line. This is the knee-killer.
        let g = DeviceMesh::galaxy_torus(1, 1).unwrap();
        assert_eq!(EtherPhase::scalar_allreduce(&g).unwrap().rounds.len(), 12);

        // Degenerate shapes reproduce the 1D ring's rounds exactly —
        // for scalar beats and for segmented tile payloads.
        for n in [4usize, 8] {
            let ring = DeviceMesh::new(n, 1, 1, MeshTopology::Ring, link).unwrap();
            for shape in [
                MeshTopology::Torus2D { rows: 1, cols: n },
                MeshTopology::Torus2D { rows: n, cols: 1 },
            ] {
                let torus = DeviceMesh::new(n, 1, 1, shape, link).unwrap();
                for payload in [32u64, 2048] {
                    let a = EtherPhase::allreduce(&ring, payload).unwrap();
                    let b = EtherPhase::allreduce(&torus, payload).unwrap();
                    assert_eq!(a.rounds, b.rounds, "{shape:?} payload {payload}");
                }
            }
        }

        // Tile payloads still take the segmented ring along each closed
        // dimension: 8 segments of ceil(2048/8 → 256) per row round.
        let tiles = EtherPhase::allreduce(&g, 2048).unwrap();
        assert_eq!(tiles.label, "allreduce2d");
        assert_eq!(tiles.rounds[0].len(), 4 * 8); // all 4 rows' rings busy
        assert_eq!(tiles.rounds[0][0].bytes, 256);
    }

    #[test]
    fn overlap_mode_parse_and_labels() {
        assert_eq!("serial".parse::<OverlapMode>().unwrap(), OverlapMode::Serial);
        assert_eq!("Pipelined".parse::<OverlapMode>().unwrap(), OverlapMode::Pipelined);
        assert!("both".parse::<OverlapMode>().is_err());
        assert_eq!(OverlapMode::default(), OverlapMode::Serial);
        assert_eq!(OverlapMode::Pipelined.label(), "pipelined");
    }

    #[test]
    fn schedule_parse_labels_and_rounds() {
        assert_eq!("classic".parse::<Schedule>().unwrap(), Schedule::Classic);
        assert_eq!("Prefetch".parse::<Schedule>().unwrap(), Schedule::Prefetch);
        assert_eq!("sstep:4".parse::<Schedule>().unwrap(), Schedule::SStep(4));
        assert_eq!(Schedule::default(), Schedule::Classic);
        assert_eq!(Schedule::SStep(8).label(), "sstep:8");
        assert_eq!(Schedule::Prefetch.label(), "prefetch");
        // Block sizes outside the conditioning-safe window are rejected,
        // as is anything unparsable — each with a descriptive error, not
        // a panic or silent acceptance.
        assert!("sstep:0".parse::<Schedule>().unwrap_err().contains("2..=8"));
        assert!("sstep:1".parse::<Schedule>().unwrap_err().contains("2..=8"));
        assert!("sstep:9".parse::<Schedule>().is_err());
        assert!("sstep:12".parse::<Schedule>().unwrap_err().contains("2..=8"));
        assert!("sstep:".parse::<Schedule>().is_err());
        assert!("eager".parse::<Schedule>().is_err());
        // Classic and prefetch keep Algorithm 1's three all-reduces per
        // iteration; sstep amortizes one combined round over the block.
        assert_eq!(Schedule::Classic.allreduce_rounds_per_iter(), 3.0);
        assert_eq!(Schedule::Prefetch.allreduce_rounds_per_iter(), 3.0);
        assert_eq!(Schedule::SStep(4).allreduce_rounds_per_iter(), 0.25);
    }

    #[test]
    fn ether_lead_requires_an_overlapping_phase() {
        let link = EthLink::default();
        let overlapping = EtherPhase {
            label: "halo".to_string(),
            n_dies: 2,
            link,
            rounds: vec![vec![EthHop { src_die: 0, dst_die: 1, bytes: 64 }]],
            overlaps_local: true,
        };
        let mut p = Program::standard("spmv");
        p.work.ether = Some(overlapping.clone());
        p.work.ether_lead_ns = 500.0;
        p.validate().unwrap();
        // Lead time on a phase that strictly follows the local work makes
        // no sense: there is nothing to issue early against.
        let mut appended = overlapping;
        appended.overlaps_local = false;
        p.work.ether = Some(appended);
        assert!(p.validate().is_err());
        // Neither does a lead without any Ethernet phase at all, or a
        // negative / non-finite lead.
        p.work.ether = None;
        assert!(p.validate().is_err());
        p.work.ether_lead_ns = 0.0;
        p.validate().unwrap();
        p.work.ether_lead_ns = -1.0;
        assert!(p.validate().is_err());
        p.work.ether_lead_ns = f64::NAN;
        assert!(p.validate().is_err());
    }

    #[test]
    fn boundary_chain_must_fit_inside_totals() {
        let mut p = Program::standard("seam");
        p.work.grid = (1, 2);
        p.work.compute_cycles = vec![100, 100];
        p.work.riscv_cycles = vec![10, 0];
        p.work.boundary_compute_cycles = vec![40, 100];
        p.work.boundary_riscv_cycles = vec![10];
        p.validate().unwrap();
        // A boundary entry larger than its total is extra work, not a
        // split — rejected.
        p.work.boundary_compute_cycles = vec![40, 101];
        assert!(p.validate().is_err());
        p.work.boundary_compute_cycles = vec![40, 100];
        p.work.boundary_riscv_cycles = vec![11];
        assert!(p.validate().is_err());
        // So is a boundary vector longer than the grid.
        p.work.boundary_riscv_cycles = vec![0; 3];
        assert!(p.validate().is_err());
    }

    #[test]
    fn allreduce_payload_shapes() {
        use crate::device::{DeviceMesh, MeshTopology};
        let link = EthLink::default();
        // Scalar beats keep the latency-minimizing chain shape exactly.
        let l4 = DeviceMesh::new(4, 1, 1, MeshTopology::Line, link).unwrap();
        let r4 = DeviceMesh::new(4, 1, 1, MeshTopology::Ring, link).unwrap();
        assert_eq!(EtherPhase::allreduce(&l4, 32), EtherPhase::scalar_allreduce(&l4));
        assert_eq!(EtherPhase::allreduce(&r4, 32), EtherPhase::scalar_allreduce(&r4));

        // Tile payloads on a ring: segmented ring all-reduce — 2(N−1)
        // rounds, every round all N links carrying one ⌈payload/N⌉
        // segment (beat-aligned), so bytes/round scale as payload/N.
        let tile = 2048u64;
        let ring = EtherPhase::allreduce(&r4, tile).unwrap();
        assert_eq!(ring.rounds.len(), 2 * 3);
        for round in &ring.rounds {
            assert_eq!(round.len(), 4);
            // One hop per physical link per round: no self-contention.
            let links: std::collections::BTreeSet<(usize, usize)> = round
                .iter()
                .map(|h| (h.src_die.min(h.dst_die), h.src_die.max(h.dst_die)))
                .collect();
            assert_eq!(links.len(), 4);
            round.iter().for_each(|h| assert_eq!(h.bytes, 512));
        }
        assert_eq!(ring.bytes(), 6 * 4 * 512);
        // Each round is one concurrent segment wave: duration = one
        // segment transfer per round.
        assert!((ring.duration_ns() - 6.0 * link.transfer_ns(512)).abs() < 1e-9);
        // The same payload on a line keeps the chain (no wrap link to
        // close the ring); every hop carries the whole payload.
        let line = EtherPhase::allreduce(&l4, tile).unwrap();
        assert_eq!(line.rounds.len(), 6);
        line.rounds.iter().flatten().for_each(|h| assert_eq!(h.bytes, tile));
        // A segment never beat-misaligns: 100 B over 4 dies → 32 B beats.
        let odd = EtherPhase::allreduce(&r4, 100).unwrap();
        odd.rounds.iter().flatten().for_each(|h| assert_eq!(h.bytes, 32));
    }

    #[test]
    fn phase_run_serializes_shared_links_within_a_round() {
        let link = EthLink::default();
        // Two same-round hops on one physical link (0↔1 both ways, not
        // aggregated): the contended model charges them back to back —
        // the analytic 2×(latency + bytes/bw) — where the old
        // independent-pipe model charged a single window.
        let phase = EtherPhase {
            label: "contended".to_string(),
            n_dies: 2,
            link,
            rounds: vec![vec![
                EthHop { src_die: 0, dst_die: 1, bytes: 1100 },
                EthHop { src_die: 1, dst_die: 0, bytes: 1100 },
            ]],
            overlaps_local: true,
        };
        let want = 2.0 * link.transfer_ns(1100);
        assert!((phase.duration_ns() - want).abs() < 1e-9);
        // An EthSim carried across phases delays later traffic honestly.
        let mut sim = crate::device::EthSim::new();
        let first_end = phase.run(&mut sim, 0.0);
        let second_end = phase.run(&mut sim, 0.0);
        assert!((second_end - 2.0 * first_end).abs() < 1e-9);
    }

    #[test]
    fn remapped_collapses_dead_die_hops() {
        let link = EthLink::default();
        let phase = EtherPhase {
            label: "allreduce".to_string(),
            n_dies: 4,
            link,
            rounds: vec![
                vec![
                    EthHop { src_die: 3, dst_die: 2, bytes: 32 },
                    EthHop { src_die: 1, dst_die: 0, bytes: 32 },
                ],
                vec![EthHop { src_die: 2, dst_die: 0, bytes: 32 }],
            ],
            overlaps_local: false,
        };
        // Empty map: unchanged.
        assert_eq!(phase.remapped(&std::collections::BTreeMap::new()), Some(phase.clone()));
        // Die 3's subdomain adopted by die 2: its hop into 2 becomes a
        // self-loop and is dropped; everything else survives.
        let adopt: std::collections::BTreeMap<usize, usize> = [(3usize, 2usize)].into();
        let m = phase.remapped(&adopt).unwrap();
        assert_eq!(m.rounds.len(), 2);
        assert_eq!(m.rounds[0], vec![EthHop { src_die: 1, dst_die: 0, bytes: 32 }]);
        assert_eq!(m.rounds[1], vec![EthHop { src_die: 2, dst_die: 0, bytes: 32 }]);
        // Same-pair hops merge their bytes after remapping.
        let two = EtherPhase {
            rounds: vec![vec![
                EthHop { src_die: 3, dst_die: 0, bytes: 100 },
                EthHop { src_die: 2, dst_die: 0, bytes: 30 },
            ]],
            ..phase.clone()
        };
        let merged = two.remapped(&adopt).unwrap();
        assert_eq!(merged.rounds, vec![vec![EthHop { src_die: 2, dst_die: 0, bytes: 130 }]]);
        // A phase whose every hop collapses vanishes.
        let seam = EtherPhase {
            rounds: vec![vec![EthHop { src_die: 3, dst_die: 2, bytes: 64 }]],
            ..phase.clone()
        };
        assert_eq!(seam.remapped(&adopt), None);
    }

    #[test]
    fn rerouted_expands_cut_hops_store_and_forward() {
        use crate::device::{DeviceMesh, MeshTopology};
        let link = EthLink::default();
        let mesh = DeviceMesh::new(
            8,
            1,
            1,
            MeshTopology::Torus2D { rows: 2, cols: 4 },
            link,
        )
        .unwrap();
        let phase = EtherPhase {
            label: "halo".to_string(),
            n_dies: 8,
            link,
            rounds: vec![vec![
                EthHop { src_die: 0, dst_die: 1, bytes: 640 },
                EthHop { src_die: 2, dst_die: 3, bytes: 320 },
            ]],
            overlaps_local: true,
        };
        // No down links: bit-identical clone.
        assert_eq!(phase.rerouted(&mesh), phase);
        // Cut (0,1): that hop detours over live links, one link per
        // sub-round; the untouched hop rides sub-round 0 as before.
        let cut = mesh.with_down_links(&[(0, 1)]);
        let r = phase.rerouted(&cut);
        assert!(r.rounds.len() > 1, "multi-link detour forwards across sub-rounds");
        assert_eq!(r.rounds[0][1], EthHop { src_die: 2, dst_die: 3, bytes: 320 });
        // The detour's segments chain 0 → … → 1 without the cut link,
        // each carrying the full payload.
        let detour: Vec<EthHop> = r
            .rounds
            .iter()
            .flatten()
            .copied()
            .filter(|h| h.bytes == 640)
            .collect();
        assert_eq!(detour.len(), r.rounds.len());
        let mut at = 0usize;
        for h in &detour {
            assert_eq!(h.src_die, at);
            let key = (h.src_die.min(h.dst_die), h.src_die.max(h.dst_die));
            assert_ne!(key, (0, 1), "detour reuses the cut link");
            at = h.dst_die;
        }
        assert_eq!(at, 1);
        // Every produced program still validates.
        let mut p = Program::standard("halo");
        p.work.ether = Some(r);
        p.validate().unwrap();
    }

    #[test]
    fn fuse_requires_matching_grids_and_capacity() {
        let mut a = Program::standard("a");
        a.work.grid = (2, 2);
        a.footprint.sram_bytes = 100;
        let mut b = Program::standard("b");
        b.work.grid = (2, 2);
        b.footprint.sram_bytes = 400;

        let fused = Program::fuse("ab", vec![a.clone(), b.clone()], 500).unwrap();
        // The parts share the vector pool: the largest part binds.
        assert_eq!(fused.footprint().sram_bytes, 400);

        assert!(Program::fuse("ab", vec![a.clone(), b.clone()], 300).is_err());
        let mut c = Program::standard("c");
        c.work.grid = (1, 2);
        assert!(Program::fuse("ac", vec![a, c], 1 << 20).is_err());
        assert!(Program::fuse("empty", vec![], 1 << 20).is_err());
    }
}
